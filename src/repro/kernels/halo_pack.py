"""Fused pack + device-initiated remote put with signal (paper Alg. 3/4/5).

TPU mapping of the paper's NVSHMEM kernels:

  * ``nvshmem_put_signal_nbi`` / TMA remote store  ->
        ``pltpu.make_async_remote_copy`` — TPU RDMA is *natively*
        put-with-signal: the receiver's ``recv_sem`` IS the signal, and
        ``wait_recv`` is the acquire side (paper's acquire_wait on
        ctx.signal[p]).
  * warp-level pack/transmit pipelining (Alg. 3 line 7)  ->
        chunk-grained DMA issue: each packed chunk's remote copy starts as
        soon as that chunk is gathered, while the next chunk packs.
  * depOffset dependency partitioning (Alg. 4)  ->
        chunks whose index-map entries reference the previous pulse's halo
        slots wait on THAT pulse's recv semaphore only; independent chunks
        are packed and transmitted immediately.

All kernels run under ``interpret=True`` on CPU for validation (the
container has no TPU); the grid/BlockSpec structure is the TPU-native
design.  Jitted wrappers live in ops.py, pure-jnp oracles in ref.py.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# --------------------------------------------------------------------------
# 1. pack kernel: gather rows by index map into a contiguous send buffer
# --------------------------------------------------------------------------

def _pack_kernel(idx_ref, src_ref, out_ref, *, chunk: int, feat: int):
    """Grid step packs one chunk: out[c*C:(c+1)*C] = src[idx[c*C:(c+1)*C]].

    Negative indices are padding and produce zero rows (the paper's
    index-map entries are dense; ours carry explicit padding so capacity
    buffers have static shape).  When the output buffer is wire-dtyped
    (compressed halo payloads) the gathered rows are quantized in-register
    before the store: quantize fuses into pack, so the wire format never
    materializes in HBM — only the packed send buffer is compressed.
    """
    c = pl.program_id(0)
    idx = idx_ref[pl.ds(c * chunk, chunk)]
    valid = idx >= 0
    safe = jnp.maximum(idx, 0)
    rows = src_ref[safe, :]                      # gathered chunk
    rows = jnp.where(valid[:, None], rows, jnp.zeros((), rows.dtype))
    out_ref[pl.ds(c * chunk, chunk), :] = rows.astype(out_ref.dtype)


def pack(src: jax.Array, index_map: jax.Array, chunk: int = 128,
         interpret: bool = True, wire_dtype=None) -> jax.Array:
    """Pack rows of ``src`` (P, F) selected by ``index_map`` (M,).

    ``wire_dtype`` (e.g. ``"bfloat16"``) returns the packed buffer in
    that dtype with the cast fused into the gather (quantize-into-pack).
    """
    M = index_map.shape[0]
    F = src.shape[-1]
    out_dtype = src.dtype if wire_dtype is None else jnp.dtype(wire_dtype)
    chunk = min(chunk, M)
    while M % chunk:
        chunk -= 1
    return pl.pallas_call(
        functools.partial(_pack_kernel, chunk=chunk, feat=F),
        grid=(M // chunk,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY),
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        out_shape=jax.ShapeDtypeStruct((M, F), out_dtype),
        interpret=interpret,
    )(index_map, src)


# --------------------------------------------------------------------------
# 1b. unpack kernel: scatter-add received rows back by index map
# --------------------------------------------------------------------------

def _unpack_add_kernel(idx_ref, rows_ref, dst_ref, out_ref, *, chunk: int):
    """Grid step c: out[idx[c*C:(c+1)*C]] += rows[c*C:(c+1)*C].

    The reverse-path unpack (paper's CommUnpackF): received force rows are
    accumulated into the destination selected by the index map.  Indices
    must be non-negative and unique (halo-plan index maps are dense and
    collision-free by construction); grid step 0 seeds the output with the
    destination buffer.
    """
    c = pl.program_id(0)

    @pl.when(c == 0)
    def _():
        out_ref[...] = dst_ref[...]

    idx = idx_ref[pl.ds(c * chunk, chunk)]
    rows = rows_ref[pl.ds(c * chunk, chunk), :]
    out_ref[idx, :] = out_ref[idx, :] + rows


def unpack_add(dst: jax.Array, index_map: jax.Array, rows: jax.Array,
               chunk: int = 128, interpret: bool = True) -> jax.Array:
    """Scatter-add ``rows`` (M, F) into ``dst`` (P, F) at ``index_map``."""
    M = index_map.shape[0]
    chunk = min(chunk, M)
    while M % chunk:
        chunk -= 1
    return pl.pallas_call(
        functools.partial(_unpack_add_kernel, chunk=chunk),
        grid=(M // chunk,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY),
                  pl.BlockSpec(memory_space=pl.ANY),
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        out_shape=jax.ShapeDtypeStruct(dst.shape, dst.dtype),
        interpret=interpret,
    )(index_map, rows, dst)


# --------------------------------------------------------------------------
# 2. put-with-signal: pack + remote copy to the +1 ring neighbor
# --------------------------------------------------------------------------

def _put_signal_kernel(idx_ref, src_ref, out_ref, scratch, send_sem,
                       recv_sem, *, chunk: int, axis: str, ring: int,
                       shift: int):
    """One pulse of a ring halo exchange, chunk-pipelined.

    Packs chunk c into VMEM scratch, then immediately starts the remote
    copy into the receiver's out buffer (fused pack+comm+notify); the
    final wait drains the receives (the signal acquire).  ``shift`` is the
    ring offset of the put target: -1 for the coordinate (forward) halo
    (send to -1, receive from +1), +1 for the force-return (reverse) path.

    When the scratch/out buffers are wire-dtyped (compressed halo
    payloads) the quantizing cast happens in-register between gather and
    the scratch store, so both the VMEM staging buffer AND the remote DMA
    move wire-sized rows — the wire format never round-trips through HBM
    on the send side.
    """
    c = pl.program_id(0)
    n_chunks = pl.num_programs(0)
    my = jax.lax.axis_index(axis)
    neighbor = jax.lax.rem(my + ring + shift, ring)

    idx = idx_ref[pl.ds(c * chunk, chunk)]
    valid = idx >= 0
    rows = src_ref[jnp.maximum(idx, 0), :]
    rows = jnp.where(valid[:, None], rows, 0.0).astype(scratch.dtype)
    scratch[pl.ds(0, chunk), :] = rows

    copy = pltpu.make_async_remote_copy(
        src_ref=scratch.at[pl.ds(0, chunk), :],
        dst_ref=out_ref.at[pl.ds(c * chunk, chunk), :],
        send_sem=send_sem, recv_sem=recv_sem,
        device_id=neighbor, device_id_type=pltpu.DeviceIdType.LOGICAL)
    copy.start()
    copy.wait()                                   # drain send+recv signals


def put_signal(src: jax.Array, index_map: jax.Array, axis: str, ring: int,
               chunk: int = 128, interpret: bool = True,
               shift: int = -1, wire_dtype=None) -> jax.Array:
    """Device-initiated halo put: returns this device's RECEIVED buffer.

    Must run inside shard_map over ``axis`` (ring size ``ring``).
    ``shift=-1`` puts to the -1 neighbor (coordinate halo, receive from
    +1); ``shift=+1`` puts to the +1 neighbor (force-return path).
    ``wire_dtype`` (e.g. ``"bfloat16"``) makes scratch, DMA, and the
    returned receive buffer wire-dtyped (quantize fused into pack).
    """
    M = index_map.shape[0]
    F = src.shape[-1]
    out_dtype = src.dtype if wire_dtype is None else jnp.dtype(wire_dtype)
    chunk = min(chunk, M)
    while M % chunk:
        chunk -= 1
    return pl.pallas_call(
        functools.partial(_put_signal_kernel, chunk=chunk, axis=axis,
                          ring=ring, shift=shift),
        grid=(M // chunk,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY),
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        out_shape=jax.ShapeDtypeStruct((M, F), out_dtype),
        scratch_shapes=[pltpu.VMEM((chunk, F), out_dtype),
                        pltpu.SemaphoreType.DMA,
                        pltpu.SemaphoreType.DMA],
        interpret=interpret,
    )(index_map, src)


# --------------------------------------------------------------------------
# 3. fused two-pulse exchange with dependency partitioning (Alg. 3+4)
# --------------------------------------------------------------------------

def _fused_pulses_kernel(idx_ref, src_ref, out_ref, scratch,
                         send_sem, recv_sem, dep_sem,
                         *, chunk: int, axis: str, ring: int,
                         n_pulses: int, m: int, n_local: int):
    """Grid (pulse, chunk).  Pulse p's index entries < n_local gather from
    local data (independent — packed/sent immediately); entries >= n_local
    reference pulse p-1's receive buffer (dependent — the chunk first
    acquires p-1's dependency token).  This is Alg. 4's depOffset split
    with the signal wait fused into the same kernel (Alg. 5): the remote
    copy's recv semaphore is the data signal, dep_sem carries the
    last-completing-chunk release notification to the next pulse.

    Staged forwarding reads pulse p-1's receive buffer verbatim, so wire
    compression of this kernel would re-round at every hop; multi-pulse
    dims therefore always ship dense (see SignalBackend.fwd).
    """
    p = pl.program_id(0)
    c = pl.program_id(1)
    n_chunks = pl.num_programs(1)
    my = jax.lax.axis_index(axis)
    neighbor = jax.lax.rem(my + ring - 1, ring)

    idx = idx_ref[p, pl.ds(c * chunk, chunk)]
    valid = idx >= 0
    safe = jnp.maximum(idx, 0)
    is_dep = valid & (safe >= n_local)

    def _dep_chunks(pulse):
        """Number of chunks of ``pulse`` containing dependent entries."""
        row = idx_ref[pulse, :]
        dep = (row >= n_local).reshape(n_chunks, chunk)
        return jnp.sum(jnp.any(dep, axis=1).astype(jnp.int32))

    # dependent chunks acquire the previous pulse's completion token;
    # independent chunks proceed immediately (the fused-design payoff).
    @pl.when(jnp.logical_and(p > 0, jnp.any(is_dep)))
    def _():
        pltpu.semaphore_wait(dep_sem, 1)

    local_rows = src_ref[jnp.minimum(safe, n_local - 1), :]
    prev = jnp.maximum(p - 1, 0)
    halo_rows = out_ref[prev, jnp.minimum(jnp.maximum(safe - n_local, 0),
                                          m - 1), :]
    rows = jnp.where(is_dep[:, None], halo_rows, local_rows)
    scratch[pl.ds(0, chunk), :] = jnp.where(valid[:, None], rows, 0.0)

    copy = pltpu.make_async_remote_copy(
        src_ref=scratch.at[pl.ds(0, chunk), :],
        dst_ref=out_ref.at[p, pl.ds(c * chunk, chunk), :],
        send_sem=send_sem, recv_sem=recv_sem,
        device_id=neighbor, device_id_type=pltpu.DeviceIdType.LOGICAL)
    copy.start()
    copy.wait()

    # last-completing chunk of pulse p releases exactly one token per
    # dependent chunk of pulse p+1 (paper Alg. 5: only the last block
    # emits the release, keeping signal traffic minimal)
    @pl.when(jnp.logical_and(c == n_chunks - 1, p < n_pulses - 1))
    def _():
        pltpu.semaphore_signal(dep_sem, _dep_chunks(p + 1))


def fused_pulses(src: jax.Array, index_maps: jax.Array, axis: str,
                 ring: int, n_local: int, chunk: int = 64,
                 interpret: bool = True) -> jax.Array:
    """Fused multi-pulse staged exchange along one ring axis.

    src: (P, F) local rows; index_maps: (n_pulses, M) with entries in
    [0, n_local) selecting local rows and [n_local, n_local+M) selecting
    rows of the previous pulse's receive buffer (staged forwarding).
    Returns (n_pulses, M, F): this device's receive buffers.
    """
    n_pulses, M = index_maps.shape
    F = src.shape[-1]
    chunk = min(chunk, M)
    while M % chunk:
        chunk -= 1
    return pl.pallas_call(
        functools.partial(_fused_pulses_kernel, chunk=chunk, axis=axis,
                          ring=ring, n_pulses=n_pulses, m=M,
                          n_local=n_local),
        grid=(n_pulses, M // chunk),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY),
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        out_shape=jax.ShapeDtypeStruct((n_pulses, M, F), src.dtype),
        scratch_shapes=[pltpu.VMEM((chunk, F), src.dtype),
                        pltpu.SemaphoreType.DMA,
                        pltpu.SemaphoreType.DMA,
                        pltpu.SemaphoreType.REGULAR],
        interpret=interpret,
    )(index_maps, src)
