"""Pure-jnp/numpy oracles for every Pallas kernel (allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.md.system import ForceField


# ---- halo_pack.pack --------------------------------------------------------

def pack_ref(src: np.ndarray, index_map: np.ndarray) -> np.ndarray:
    rows = np.take(src, np.maximum(index_map, 0), axis=0)
    rows[index_map < 0] = 0.0
    return rows


# ---- halo_pack.unpack_add --------------------------------------------------

def unpack_add_ref(dst: np.ndarray, index_map: np.ndarray,
                   rows: np.ndarray) -> np.ndarray:
    out = np.array(dst, copy=True)
    np.add.at(out, index_map, rows)
    return out


# ---- halo_pack.put_signal (ring exchange oracle across shards) -------------

def put_signal_ref(srcs, index_maps):
    """srcs: list over devices of (P, F); device d receives from d+1."""
    ring = len(srcs)
    return [pack_ref(srcs[(d + 1) % ring], index_maps[(d + 1) % ring])
            for d in range(ring)]


# ---- halo_pack.fused_pulses (staged multi-pulse oracle) ---------------------

def fused_pulses_ref(srcs, index_maps, n_local: int):
    """Staged forwarding oracle.

    srcs: list over devices of (P, F); index_maps: list over devices of
    (n_pulses, M) with entries >= n_local referencing the SENDER's
    previous-pulse receive buffer.  Returns per-device (n_pulses, M, F).
    """
    ring = len(srcs)
    n_pulses, M = index_maps[0].shape
    F = srcs[0].shape[-1]
    recv = [np.zeros((n_pulses, M, F), srcs[0].dtype) for _ in range(ring)]
    for p in range(n_pulses):
        for d in range(ring):
            s = (d + 1) % ring                   # sender
            idx = index_maps[s][p]
            rows = np.zeros((M, F), srcs[0].dtype)
            for j, i in enumerate(idx):
                if i < 0:
                    continue
                if i < n_local:
                    rows[j] = srcs[s][i]
                else:
                    rows[j] = recv[s][p - 1, i - n_local]
            recv[d][p] = rows
    return recv


# ---- nonbonded.pair_forces ---------------------------------------------------

def pair_forces_ref(a, b, ta, tb, same, ff: ForceField):
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    ta = np.asarray(ta)
    tb = np.asarray(tb)
    same = np.asarray(same)
    N, K, _ = a.shape
    eps_t = np.asarray(ff.eps)
    sig_t = np.asarray(ff.sigma)
    fa = np.zeros((N, K, 3))
    fb = np.zeros((N, K, 3))
    pe = np.zeros((N,))
    for n in range(N):
        for i in range(K):
            if ta[n, i] < 0:
                continue
            for j in range(K):
                if tb[n, j] < 0:
                    continue
                if same[n] and j <= i:
                    continue
                dx = a[n, i, :3] - b[n, j, :3]
                r2 = float(dx @ dx)
                if r2 >= ff.r_cut ** 2:
                    continue
                eps = eps_t[ta[n, i], tb[n, j]]
                sig = sig_t[ta[n, i], tb[n, j]]
                sr6 = (sig * sig / r2) ** 3
                sr12 = sr6 ** 2
                fac = 24 * eps * (2 * sr12 - sr6) / r2
                src6 = (sig * sig / ff.r_cut ** 2) ** 3
                e = 4 * eps * ((sr12 - sr6) - (src6 ** 2 - src6))
                qq = a[n, i, 3] * b[n, j, 3]
                fac += qq * (r2 ** -1.5 - 2 * ff.k_rf)
                e += qq * (r2 ** -0.5 + ff.k_rf * r2 - ff.c_rf)
                fa[n, i] += fac * dx
                fb[n, j] -= fac * dx
                pe[n] += e
    return fa, fb, pe


# ---- flash_attention ----------------------------------------------------------

def flash_attention_ref(q, k, v, causal: bool = True):
    """q: (BH, L, G, hd); k/v: (BH, S, hd) -> (BH, L, G, hd), f32 math."""
    qf = np.asarray(q, np.float64)
    kf = np.asarray(k, np.float64)
    vf = np.asarray(v, np.float64)
    BH, L, G, hd = qf.shape
    S = kf.shape[1]
    logits = np.einsum("blgd,bsd->blgs", qf, kf) / np.sqrt(hd)
    if causal:
        mask = np.arange(L)[:, None] >= np.arange(S)[None, :]
        logits = np.where(mask[None, :, None, :], logits, -1e30)
    logits -= logits.max(axis=-1, keepdims=True)
    p = np.exp(logits)
    p /= p.sum(axis=-1, keepdims=True)
    return np.einsum("blgs,bsd->blgd", p, vf)
