"""Flash attention (GQA, causal) with explicit VMEM tiling.

Grid (batch*kv_head, q_blocks, kv_blocks); the kv dimension is the
innermost (sequential on TPU), so the online-softmax running max/denom/
accumulator persist in VMEM scratch across kv steps and the output block
is written once on the last kv step.  Q/K/V blocks stream HBM->VMEM via
BlockSpecs; block sizes default to MXU-aligned 128/256.

Causal blocks fully above the diagonal are skipped with ``pl.when``
(no compute; the fetch is already pipelined).  Matches the pure-jnp
``blocked_attention`` in models/attention.py; ref.py holds the oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc,
                  *, bq: int, bk: int, causal: bool, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    if causal:
        # skip blocks fully above the diagonal (no overlap)
        run = qi * bq + bq - 1 >= ki * bk
    else:
        run = jnp.bool_(True)

    @pl.when(run)
    def _():
        q = q_ref[0].astype(jnp.float32) * scale       # (bq, G, hd)
        k = k_ref[0]                                   # (bk, hd)
        logits = jax.lax.dot_general(
            q.astype(k.dtype), k,
            (((2,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)        # (bq, G, bk)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(
                jnp.int32, (bq, 1, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(
                jnp.int32, (bq, 1, bk), 2)
            logits = jnp.where(qpos >= kpos, logits, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=-1)
        m_sc[...] = m_new
        v = v_ref[0]                                   # (bk, hd)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v,
            (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)        # (bq, G, hd)
        acc_sc[...] = acc_sc[...] * corr[..., None] + pv

    @pl.when(ki == nk - 1)
    def _():
        out = acc_sc[...] / jnp.maximum(l_sc[...], 1e-30)[..., None]
        o_ref[0] = out.astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128,
                    bk: int = 256, interpret: bool = True):
    """q: (BH, L, G, hd) grouped queries; k, v: (BH, S, hd).

    BH = batch * kv_heads (flattened); G = q heads per kv head.
    Returns (BH, L, G, hd).
    """
    BH, L, G, hd = q.shape
    S = k.shape[1]
    bq = min(bq, L)
    while L % bq:
        bq -= 1
    bk = min(bk, S)
    while S % bk:
        bk -= 1
    grid = (BH, L // bq, S // bk)
    scale = hd ** -0.5

    kern = functools.partial(_flash_kernel, bq=bq, bk=bk, causal=causal,
                             scale=scale)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, G, hd), lambda b, i, j: (b, i, 0, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, G, hd), lambda b, i, j: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, L, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, G), jnp.float32),
            pltpu.VMEM((bq, G), jnp.float32),
            pltpu.VMEM((bq, G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
