"""Jitted public wrappers around the Pallas kernels.

``interpret`` defaults to True because this container is CPU-only; on a
real TPU deployment these flip to compiled mode unchanged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.md.system import ForceField
from repro.kernels import flash_attention as _fa
from repro.kernels import halo_pack as _hp
from repro.kernels import nonbonded as _nb


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def pack(src, index_map, chunk: int = 128, interpret: bool = True):
    return _hp.pack(src, index_map, chunk=chunk, interpret=interpret)


def put_signal(src, index_map, *, axis: str, ring: int, chunk: int = 128,
               interpret: bool = True):
    """Must be called inside shard_map over ``axis``."""
    return _hp.put_signal(src, index_map, axis, ring, chunk=chunk,
                          interpret=interpret)


def fused_pulses(src, index_maps, *, axis: str, ring: int, n_local: int,
                 chunk: int = 64, interpret: bool = True):
    """Fused dependency-partitioned multi-pulse exchange (shard_map)."""
    return _hp.fused_pulses(src, index_maps, axis, ring, n_local,
                            chunk=chunk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("ff", "block", "interpret"))
def pair_forces(a, b, ta, tb, same, ff: ForceField, block: int = 8,
                interpret: bool = True):
    return _nb.pair_forces(a, b, ta, tb, same, ff, block=block,
                           interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q, k, v, causal: bool = True, bq: int = 128,
                    bk: int = 256, interpret: bool = True):
    return _fa.flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                               interpret=interpret)
