"""Cluster-pair LJ + reaction-field force kernel (the paper's hot loop).

GROMACS' non-bonded kernels interact i-clusters with j-clusters from the
pair list; our cell scheme (see core/md/forces.py) interacts K-atom cell
pairs across the 14-offset eighth-shell stencil.  This Pallas kernel
computes one batch of cell pairs: given packed A-cells and B-cells
(N, K, 4) [x, y, z, q] plus per-pair type tables, it produces forces on
both sides and the pair potential energy.

TPU adaptation (vs the CUDA cluster kernel): the K x K pair interaction
tile is computed as VPU-vectorized broadcasts in VMEM (K is padded to the
8x128 register tile), one cell pair block per grid step; HBM->VMEM
streaming is expressed through BlockSpecs so the working set stays
resident.  Validated in interpret mode against ref.py / the engine's jnp
path.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.md.system import ForceField


def _pair_kernel(a_ref, b_ref, ta_ref, tb_ref, same_ref, *rest,
                 r_cut2, k_rf, c_rf, kk: int, use_counts: bool):
    if use_counts:
        (cnta_ref, cntb_ref, eps_ref, sig_ref,
         fa_ref, fb_ref, pe_ref) = rest
    else:
        eps_ref, sig_ref, fa_ref, fb_ref, pe_ref = rest
    a = a_ref[...]                                # (C, K, 4)
    b = b_ref[...]
    ta = ta_ref[...]                              # (C, K) int32
    tb = tb_ref[...]
    same = same_ref[...]                          # (C,) 1 if A is B
    eps_t = eps_ref[...]                          # (T, T) LJ tables in VMEM
    sig_t = sig_ref[...]

    pos_a, q_a = a[..., :3], a[..., 3]
    pos_b, q_b = b[..., :3], b[..., 3]
    if use_counts:
        # per-pair slot bounds: binning packs each cell's atoms into a
        # contiguous slot prefix, so slot < count IS slot validity
        iota = jax.lax.broadcasted_iota(jnp.int32, ta.shape, 1)
        valid_a = iota < cnta_ref[...][:, None]
        valid_b = iota < cntb_ref[...][:, None]
    else:
        valid_a, valid_b = ta >= 0, tb >= 0

    dx = pos_a[:, :, None, :] - pos_b[:, None, :, :]
    r2 = jnp.sum(dx * dx, axis=-1)
    mask = valid_a[:, :, None] & valid_b[:, None, :]
    mask &= r2 < r_cut2
    # same-cell pairs take the strict upper triangle (each pair once);
    # distinct cells interact fully — slots never alias across cells
    tri = jnp.triu(jnp.ones((kk, kk), jnp.bool_), k=1)[None]
    full = jnp.ones((1, kk, kk), jnp.bool_)
    mask &= jnp.where(same[:, None, None] > 0, tri, full)

    r2s = jnp.where(mask, r2, 1.0)
    inv_r2 = 1.0 / r2s
    tai = jnp.clip(ta, 0, eps_t.shape[0] - 1)
    tbi = jnp.clip(tb, 0, eps_t.shape[0] - 1)
    eps = eps_t[tai[:, :, None], tbi[:, None, :]]
    sig = sig_t[tai[:, :, None], tbi[:, None, :]]
    sr2 = sig * sig * inv_r2
    sr6 = sr2 * sr2 * sr2
    sr12 = sr6 * sr6
    fac_lj = 24.0 * eps * (2.0 * sr12 - sr6) * inv_r2
    src2 = sig * sig / r_cut2
    src6 = src2 * src2 * src2
    e_lj = 4.0 * eps * ((sr12 - sr6) - (src6 * src6 - src6))
    inv_r = jnp.sqrt(inv_r2)
    qq = q_a[:, :, None] * q_b[:, None, :]
    fac_c = qq * (inv_r * inv_r2 - 2.0 * k_rf)
    e_c = qq * (inv_r + k_rf * r2s - c_rf)
    fac = jnp.where(mask, fac_lj + fac_c, 0.0)
    pe = jnp.where(mask, e_lj + e_c, 0.0)

    fvec = fac[..., None] * dx
    fa_ref[...] = jnp.sum(fvec, axis=2)
    fb_ref[...] = -jnp.sum(fvec, axis=1)
    pe_ref[...] = jnp.sum(pe, axis=(1, 2))


def pair_forces(a, b, ta, tb, same, ff: ForceField, block: int = 8,
                interpret: bool = True, cnt_a=None, cnt_b=None):
    """Forces + energies for N cell pairs.

    a, b: (N, K, 4) packed [x, y, z, q]; ta, tb: (N, K) atom types with
    -1 padding; same: (N,) nonzero when a pair is a cell with itself
    (triangle masking).  ``cnt_a`` / ``cnt_b`` (N,) int32, when given,
    supply per-pair slot bounds: slot validity becomes ``slot < count``
    (the packed-prefix invariant of ``cells.bin_to_cells``) instead of
    the per-slot type test — the form the tiered pair schedule feeds,
    where the batch K is already the pair's bucketed bound.  Returns
    (fa (N,K,3), fb (N,K,3), pe (N,)).
    """
    N, K, _ = a.shape
    block = min(block, N)
    while N % block:
        block -= 1
    grid = (N // block,)
    use_counts = cnt_a is not None
    kern = functools.partial(
        _pair_kernel,
        r_cut2=ff.r_cut ** 2, k_rf=ff.k_rf, c_rf=ff.c_rf, kk=K,
        use_counts=use_counts)
    bs = lambda *shape: pl.BlockSpec(shape, lambda i: (i,) + (0,) *
                                     (len(shape) - 1))
    eps_t = jnp.asarray(ff.eps, a.dtype)
    sig_t = jnp.asarray(ff.sigma, a.dtype)
    T = eps_t.shape[0]
    tbl = pl.BlockSpec((T, T), lambda i: (0, 0))
    in_specs = [bs(block, K, 4), bs(block, K, 4),
                bs(block, K), bs(block, K), bs(block)]
    args = [a, b, ta, tb, same]
    if use_counts:
        in_specs += [bs(block), bs(block)]
        args += [cnt_a.astype(jnp.int32), cnt_b.astype(jnp.int32)]
    in_specs += [tbl, tbl]
    args += [eps_t, sig_t]
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=[bs(block, K, 3), bs(block, K, 3), bs(block)],
        out_shape=[jax.ShapeDtypeStruct((N, K, 3), a.dtype),
                   jax.ShapeDtypeStruct((N, K, 3), a.dtype),
                   jax.ShapeDtypeStruct((N,), a.dtype)],
        interpret=interpret,
    )(*args)


# --------------------------------------------------------------------------
# scatter-accumulate epilogue: batched pair forces -> extended force array
# --------------------------------------------------------------------------

def _scatter_accum_kernel(ia_ref, ib_ref, fa_ref, fb_ref, out_ref, *,
                          chunk: int):
    """Grid step c accumulates chunk c's per-pair forces into their cells.

    Cell indices REPEAT across pairs (every base cell anchors 14 stencil
    pairs), so rows are added one pair at a time inside the chunk — the
    TPU grid is sequential, which makes the accumulation deterministic
    (the analogue of GROMACS' per-cluster force reduction order).
    """
    c = pl.program_id(0)

    @pl.when(c == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    def body(i, _):
        row = c * chunk + i
        ia = ia_ref[row]
        ib = ib_ref[row]
        out_ref[ia, :, :] = out_ref[ia, :, :] + fa_ref[row, :, :]
        out_ref[ib, :, :] = out_ref[ib, :, :] + fb_ref[row, :, :]
        return 0

    jax.lax.fori_loop(0, chunk, body, 0)


def scatter_accum(cell_a, cell_b, fa, fb, n_cells: int, chunk: int = 8,
                  interpret: bool = True):
    """Pallas epilogue: sum (N, K, 3) pair forces into (n_cells, K, 3).

    ``cell_a`` / ``cell_b`` are per-pair flat cell indices in
    ``[0, n_cells)`` (padding pairs must point at a sentinel row the
    caller slices off).  Duplicate indices accumulate.
    """
    N, K, _ = fa.shape
    if N == 0:
        return jnp.zeros((n_cells, K, 3), fa.dtype)
    chunk = min(chunk, N)
    while N % chunk:
        chunk -= 1
    return pl.pallas_call(
        functools.partial(_scatter_accum_kernel, chunk=chunk),
        grid=(N // chunk,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * 4,
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        out_shape=jax.ShapeDtypeStruct((n_cells, K, 3), fa.dtype),
        interpret=interpret,
    )(cell_a, cell_b, fa, fb)


def pair_forces_accum(a, b, ta, tb, same, cell_a, cell_b, ff: ForceField,
                      n_cells: int, block: int = 8, interpret: bool = True,
                      epilogue: str = "xla", cnt_a=None, cnt_b=None):
    """``pair_forces`` extended with the scatter-accumulate epilogue.

    Computes one batch of cell-pair forces and accumulates both sides
    into a fresh ``(n_cells, K, 3)`` extended force array (plus the
    per-pair energies).  ``cnt_a`` / ``cnt_b`` thread the per-pair slot
    bounds through to the kernel's validity masks (the tiered pair
    schedule's batches are sized per tier, not to one rectangular
    ``K_exec``).  ``epilogue="pallas"`` drives the sequential
    :func:`scatter_accum` kernel — the TPU-native shape of the fused
    NB-force + reduction stage; ``"xla"`` lowers the same accumulation
    as an XLA scatter-add (duplicate-safe, and the faster choice under
    interpret mode on CPU).  Both orders are fixed per compilation.
    """
    fa, fb, pe = pair_forces(a, b, ta, tb, same, ff, block=block,
                             interpret=interpret, cnt_a=cnt_a, cnt_b=cnt_b)
    if epilogue == "pallas":
        F = scatter_accum(cell_a, cell_b, fa, fb, n_cells,
                          interpret=interpret)
    else:
        F = jnp.zeros((n_cells, fa.shape[1], 3), fa.dtype)
        F = F.at[cell_a].add(fa, mode="drop")
        F = F.at[cell_b].add(fb, mode="drop")
    return F, pe
