"""repro: fused GPU-initiated halo exchange, rebuilt as a TPU/JAX framework."""
__version__ = "1.0.0"
