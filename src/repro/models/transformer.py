"""Decoder-only LM assembly: dense / MoE / RWKV / Mamba-hybrid / VLM-prefix.

Layers are grouped into the config's ``pattern_unit`` (e.g. Jamba's
[7 mamba + 1 attn] block); units are scanned with stacked parameters so the
HLO stays one-unit-sized regardless of depth, and each unit is rematerialized
in training.  Caches/states are likewise stacked per unit, so decode is a
single scan as well.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, LayerSpec
from repro.models import attention as attn
from repro.models import mamba as mam
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models.layers import (
    ParamDef,
    ParamDefs,
    abstract_params,
    cross_entropy,
    init_params,
    cast_floats,
    mlp_defs,
    mlp_fwd,
    norm_defs,
    norm_fwd,
    param_specs,
    stack_defs,
)
from repro.parallel.sharding import ShardingCtx


def _layer_defs(cfg: ArchConfig, spec: LayerSpec) -> ParamDefs:
    d: ParamDefs = {"ln1": norm_defs(cfg.d_model, cfg.use_bias)}
    if spec.kind == "attn":
        d["attn"] = attn.attn_defs(cfg)
    elif spec.kind == "mamba":
        d["mamba"] = mam.mamba_defs(cfg)
    elif spec.kind == "rwkv":
        d["rwkv"] = rwkv_mod.rwkv_defs(cfg)["tm"]
    else:
        raise ValueError(spec.kind)
    d["ln2"] = norm_defs(cfg.d_model, cfg.use_bias)
    if spec.kind == "rwkv":
        d["cm"] = rwkv_mod.rwkv_defs(cfg)["cm"]
    elif spec.moe:
        d["moe"] = moe_mod.moe_defs(cfg)
    else:
        d["mlp"] = mlp_defs(cfg.d_model, cfg.d_ff, cfg.mlp_type,
                            cfg.use_bias)
    return d


def unit_defs(cfg: ArchConfig) -> ParamDefs:
    return {f"layer{i}": _layer_defs(cfg, s)
            for i, s in enumerate(cfg.pattern_unit)}


class LM:
    """Decoder-only language model over a pattern-unit stack."""

    def __init__(self, cfg: ArchConfig, ctx: ShardingCtx,
                 moe_dispatch: str = "fused"):
        self.cfg = cfg
        self.ctx = ctx
        self.moe_dispatch = moe_dispatch
        V = cfg.padded_vocab
        self.defs: ParamDefs = {
            "embed": ParamDef((V, cfg.d_model), "small_normal", tp_dim=0),
            "units": stack_defs(unit_defs(cfg), cfg.n_units),
            "final_norm": norm_defs(cfg.d_model, cfg.use_bias),
        }
        if not cfg.tie_embeddings:
            self.defs["lm_head"] = ParamDef((cfg.d_model, V),
                                            "small_normal", tp_dim=1)
        self.cdt = jnp.dtype(cfg.compute_dtype)
        self.pdt = jnp.dtype(cfg.param_dtype)
        self._vocab_bias = None

    # ---- params ------------------------------------------------------------

    def init(self, rng):
        return init_params(rng, self.defs, self.pdt)

    def abstract(self):
        return abstract_params(self.defs, self.pdt)

    def specs(self):
        unit_sp = param_specs(unit_defs(self.cfg), self.ctx, stacked=True)
        # expert weights use the manual EP (+expert-TP) placement so the
        # global shardings match the shard_map region's in_specs exactly
        for i, spec in enumerate(self.cfg.pattern_unit):
            if spec.moe:
                unit_sp[f"layer{i}"]["moe"].update(
                    moe_mod.stacked_expert_specs(self.cfg, self.ctx))
        out = {
            "embed": param_specs({"e": self.defs["embed"]}, self.ctx)["e"],
            "units": unit_sp,
            "final_norm": jax.tree.map(lambda _: P(),
                                       param_specs(
                                           {"n": self.defs["final_norm"]},
                                           self.ctx)["n"]),
        }
        if "lm_head" in self.defs:
            out["lm_head"] = param_specs(
                {"h": self.defs["lm_head"]}, self.ctx)["h"]
        return out

    def _unit_gather_spec(self):
        """Per-iteration specs for the SLICED unit params: FSDP axis
        dropped (gathered) on dense weights, expert weights left sharded.

        Constraining the slice inside the scan body pins the FSDP
        all-gather to the loop body — otherwise XLA can hoist a gather of
        the whole layer stack out of the loop, defeating FSDP entirely.
        """
        ctx = self.ctx
        unit_sp = param_specs(unit_defs(self.cfg), self.ctx, stacked=False)
        for i, spec in enumerate(self.cfg.pattern_unit):
            if spec.moe:
                unit_sp[f"layer{i}"]["moe"].update(
                    moe_mod.expert_specs(self.cfg, self.ctx))

        def drop_fsdp(path_spec):
            dims = [None if d == ctx.fsdp_axis else d for d in path_spec]
            return P(*dims)

        def walk(tree, under_moe=False):
            out = {}
            for k, v in tree.items():
                if isinstance(v, dict):
                    out[k] = walk(v, under_moe or k == "moe")
                else:
                    out[k] = v if under_moe else drop_fsdp(v)
            return out

        return walk(unit_sp)

    def _constrain_unit(self, p_unit):
        if self.ctx.fsdp_axis is None:
            return p_unit
        specs = self._unit_gather_spec()
        mesh = self.ctx.mesh
        return jax.tree.map(
            lambda x, s: lax.with_sharding_constraint(
                x, jax.sharding.NamedSharding(mesh, s)),
            p_unit, specs, is_leaf=lambda x: not isinstance(x, dict))

    # ---- layers ------------------------------------------------------------

    def _layer(self, i: int, spec: LayerSpec, p, x, positions,
               cache=None, cache_index=None):
        cfg, ctx = self.cfg, self.ctx
        aux = {}
        h = norm_fwd(p["ln1"], x, cfg.norm_eps)
        new_cache = {}
        if spec.kind == "attn":
            out, nc = attn.attention_fwd(
                p["attn"], h, cfg, ctx, positions=positions,
                cache=None if cache is None else cache.get("attn"),
                cache_index=cache_index)
            if nc is not None:
                new_cache["attn"] = nc
        elif spec.kind == "mamba":
            out, ns = mam.mamba_fwd(
                p["mamba"], h, cfg,
                state=None if cache is None else cache.get("mamba"))
            if ns is not None:
                new_cache["mamba"] = ns
        else:  # rwkv time mix
            out, ns = rwkv_mod.rwkv_time_mix(
                p["rwkv"], h, cfg,
                state=None if cache is None else cache.get("rwkv_tm"))
            if ns is not None:
                new_cache["rwkv_tm"] = ns
        x = x + out

        h = norm_fwd(p["ln2"], x, cfg.norm_eps)
        if spec.kind == "rwkv":
            out, ns = rwkv_mod.rwkv_channel_mix(
                p["cm"], h,
                state=None if cache is None else cache.get("rwkv_cm"))
            if ns is not None:
                new_cache["rwkv_cm"] = ns
        elif spec.moe:
            out, aux = moe_mod.moe_fwd(p["moe"], h, cfg, ctx,
                                       self.moe_dispatch)
        else:
            out = mlp_fwd(p["mlp"], h, cfg.mlp_type)
        x = x + out
        return x, aux, (new_cache if cache is not None else None)

    def _unit(self, p_unit, x, positions, cache_unit=None, cache_index=None):
        aux_sum = {"moe_lb": jnp.zeros((), jnp.float32),
                   "moe_z": jnp.zeros((), jnp.float32)}
        new_cache = {}
        # NOTE: per-layer remat inside multi-layer units was tried for
        # Jamba (hypothesis: coexisting SSM backward residuals) and
        # REFUTED — peak memory unchanged, +20% compute and +16%
        # collective bytes from the extra recompute (EXPERIMENTS.md §Perf)
        per_layer_remat = False
        for i, spec in enumerate(self.cfg.pattern_unit):
            c = None if cache_unit is None else cache_unit[f"layer{i}"]
            layer_fn = functools.partial(self._layer, i, spec,
                                         cache=c, cache_index=cache_index)
            if per_layer_remat:
                layer_fn = jax.checkpoint(
                    layer_fn,
                    policy=jax.checkpoint_policies.nothing_saveable)
            x, aux, nc = layer_fn(p_unit[f"layer{i}"], x, positions)
            for k, v in aux.items():
                aux_sum[k] = aux_sum[k] + v
            if nc is not None:
                new_cache[f"layer{i}"] = nc
        return x, aux_sum, (new_cache if cache_unit is not None else None)

    # ---- stacks ------------------------------------------------------------

    def _run_stack(self, params, x, positions, cache=None, cache_index=None,
                   remat: Optional[bool] = None):
        ctx = self.ctx
        remat = self.cfg.remat if remat is None else remat

        if cache is None:
            def body(carry, p_unit):
                x, aux_acc = carry
                x = ctx.act(x, ctx.batch_spec(), None, None)

                def unit_fn(p, x):
                    # cast the SHARD to bf16 first so the FSDP all-gather
                    # moves bf16, not f32 (halves gather bytes + transients)
                    p = self._constrain_unit(cast_floats(p, self.cdt))
                    y, aux, _ = self._unit(p, x, positions)
                    return y, aux
                if remat:
                    pol = jax.checkpoint_policies.nothing_saveable \
                        if self.cfg.remat_policy == "nothing" else \
                        jax.checkpoint_policies \
                        .dots_with_no_batch_dims_saveable
                    unit_fn = jax.checkpoint(unit_fn, policy=pol)
                x, aux = unit_fn(p_unit, x)
                aux_acc = {k: aux_acc[k] + aux[k] for k in aux_acc}
                return (x, aux_acc), None

            aux0 = {"moe_lb": jnp.zeros((), jnp.float32),
                    "moe_z": jnp.zeros((), jnp.float32)}
            (x, aux), _ = lax.scan(body, (x, aux0), params["units"])
            return x, aux, None

        # cache rides the CARRY with in-place per-unit slice updates so
        # the donated buffers alias through the scan (a cache in scan-ys
        # would materialize a second full-cache output buffer)
        def body(carry, xs):
            x, cache_all = carry
            p_unit, idx = xs
            p_unit = self._constrain_unit(cast_floats(p_unit, self.cdt))
            cache_unit = jax.tree.map(
                lambda c: lax.dynamic_index_in_dim(c, idx, 0,
                                                   keepdims=False),
                cache_all)
            x, _, new_cache = self._unit(p_unit, x, positions,
                                         cache_unit=cache_unit,
                                         cache_index=cache_index)
            cache_all = jax.tree.map(
                lambda c, n: lax.dynamic_update_index_in_dim(
                    c, n.astype(c.dtype), idx, 0),
                cache_all, new_cache)
            return (x, cache_all), None

        n_units = self.cfg.n_units
        (x, new_cache), _ = lax.scan(
            body, (x, cache), (params["units"], jnp.arange(n_units)))
        return x, {}, new_cache

    # ---- public entry points -------------------------------------------------

    def _embed(self, params, tokens):
        x = jnp.take(params["embed"], tokens, axis=0).astype(self.cdt)
        return x

    def _logits(self, params, x):
        x = norm_fwd(params["final_norm"], x, self.cfg.norm_eps)
        head = params.get("lm_head")
        if head is None:
            head = params["embed"].T
        logits = x @ head.astype(self.cdt)
        V, Vp = self.cfg.vocab, self.cfg.padded_vocab
        if Vp != V:
            bias = jnp.where(jnp.arange(Vp) < V, 0.0, -1e30)
            logits = logits + bias.astype(logits.dtype)
        return logits

    def loss_fn(self, params, batch):
        """batch: tokens (B, L+1) [+ prefix_embeds (B, P, d) for vlm]."""
        cfg, ctx = self.cfg, self.ctx
        tokens = batch["tokens"]
        x = self._embed(params, tokens[:, :-1])
        labels = tokens[:, 1:]
        prefix = batch.get("prefix_embeds")
        if prefix is not None:
            x = jnp.concatenate([prefix.astype(self.cdt), x], axis=1)
        B, L, _ = x.shape
        x = ctx.act(x, ctx.batch_spec(), None, None)
        positions = jnp.broadcast_to(jnp.arange(L), (B, L))
        x, aux, _ = self._run_stack(params, x, positions)
        if prefix is not None:
            x = x[:, prefix.shape[1]:]
        logits = self._logits(params, x)
        loss = cross_entropy(logits, labels)
        metrics = {"ce": loss}
        if cfg.moe is not None:
            loss = loss + 0.01 * aux["moe_lb"] / cfg.n_layers \
                + 1e-3 * aux["moe_z"] / cfg.n_layers
            metrics.update(aux)
        return loss, metrics

    def prefill(self, params, batch, cache=None):
        """Prefill logits for the LAST position (optionally filling cache)."""
        ctx = self.ctx
        tokens = batch["tokens"]
        x = self._embed(params, tokens)
        prefix = batch.get("prefix_embeds")
        if prefix is not None:
            x = jnp.concatenate([prefix.astype(self.cdt), x], axis=1)
        B, L, _ = x.shape
        x = ctx.act(x, ctx.batch_spec(), None, None)
        positions = jnp.broadcast_to(jnp.arange(L), (B, L))
        if cache is None:
            x, _, _ = self._run_stack(params, x, positions, remat=False)
            return self._logits(params, x[:, -1:])[:, 0], None
        x, _, new_cache = self._run_stack(params, x, positions, cache=cache,
                                          cache_index=0, remat=False)
        return self._logits(params, x[:, -1:])[:, 0], new_cache

    def decode_step(self, params, token, pos, cache):
        """token (B, 1) int32, pos scalar int32 index into the cache."""
        B = token.shape[0]
        x = self._embed(params, token)
        positions = jnp.broadcast_to(jnp.reshape(pos, (-1, 1)), (B, 1))
        x, _, new_cache = self._run_stack(params, x, positions, cache=cache,
                                          cache_index=pos, remat=False)
        logits = self._logits(params, x)[:, 0]
        return logits, new_cache

    # ---- caches ----------------------------------------------------------------

    def cache_shapes(self, batch: int, max_len: int):
        """Abstract per-unit cache stack (stack dim 0 = units)."""
        cfg, ctx = self.cfg, self.ctx
        n = cfg.n_units
        out = {}
        for i, spec in enumerate(cfg.pattern_unit):
            c = {}
            if spec.kind == "attn":
                hk = ctx.kv_heads_eff(cfg.n_kv_heads, cfg.n_heads)
                shp = (n, batch, max_len, hk, cfg.head_dim)
                c["attn"] = {"k": jax.ShapeDtypeStruct(shp, self.cdt),
                             "v": jax.ShapeDtypeStruct(shp, self.cdt)}
            elif spec.kind == "mamba":
                di, ds, dc = (cfg.d_inner_mamba, cfg.mamba_d_state,
                              cfg.mamba_d_conv)
                c["mamba"] = {
                    "conv": jax.ShapeDtypeStruct((n, batch, dc - 1, di),
                                                 self.cdt),
                    "ssm": jax.ShapeDtypeStruct((n, batch, di, ds),
                                                jnp.float32)}
            else:
                H, hd, d = cfg.rwkv_heads, cfg.rwkv_head_dim, cfg.d_model
                c["rwkv_tm"] = {
                    "shift_tm": jax.ShapeDtypeStruct((n, batch, 1, d),
                                                     self.cdt),
                    "wkv": jax.ShapeDtypeStruct((n, batch, H, hd, hd),
                                                jnp.float32)}
                c["rwkv_cm"] = {
                    "shift_cm": jax.ShapeDtypeStruct((n, batch, 1, d),
                                                     self.cdt)}
            out[f"layer{i}"] = c
        return out

    def cache_specs(self):
        """PartitionSpecs matching cache_shapes."""
        cfg, ctx = self.cfg, self.ctx
        b = ctx.batch_spec() if ctx.batch_axes else None
        seq = ctx.seq_axes[0] if ctx.seq_axes else None
        kva = ctx.kv_head_axis(cfg.n_kv_heads, cfg.n_heads)
        # unshardable KV heads (llama4 40H/8kv, whisper 12H): shard the
        # cache SEQUENCE over the model axis instead — decode becomes a
        # distributed flash-decode with an LSE merge (GSPMD inserts it)
        if kva is None and seq is None:
            seq = ctx.model_axis
        out = {}
        for i, spec in enumerate(cfg.pattern_unit):
            c = {}
            if spec.kind == "attn":
                s = P(None, b, seq, kva, None)
                c["attn"] = {"k": s, "v": s}
            elif spec.kind == "mamba":
                tp = ctx.model_axis
                c["mamba"] = {"conv": P(None, b, None, tp),
                              "ssm": P(None, b, tp, None)}
            else:
                c["rwkv_tm"] = {"shift_tm": P(None, b, None, None),
                                "wkv": P(None, b, None, None, None)}
                c["rwkv_cm"] = {"shift_cm": P(None, b, None, None)}
            out[f"layer{i}"] = c
        return out

    def init_cache(self, batch: int, max_len: int):
        shapes = self.cache_shapes(batch, max_len)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)
