"""Mixture-of-Experts with expert parallelism over the model axis.

The paper's dependency-partitioning insight (pack & send independent data
immediately, wait only for what truly depends on earlier communication) is
applied to EP dispatch: tokens routed to experts resident on the local
model rank are computed IMMEDIATELY and never enter the all-to-all; only
remote tokens ride the collective.  XLA can then overlap the remote
all-to-all with the local expert FFN — the EP analogue of overlapping the
pulse-0 transfer with local force computation.

Dispatch paths:
  * ``dense``       — every expert on every token (reference oracle; also
                      the fallback when n_experts isn't divisible by TP)
  * ``serialized``  — all tokens through one all-to-all (MPI-flavored
                      baseline: local tokens also wait for the collective)
  * ``fused``       — local-first dependency-partitioned dispatch (ours)
Decode/small-batch uses a replicated-dispatch path (tokens replicated over
the model axis, experts local, outputs psum'd) — no all-to-all at all.

The EP region is a FULLY-MANUAL shard_map over every mesh axis (partial-
auto shard_map nested in scan+remat trips an XLA-CPU partitioner crash,
"Invalid binary instruction opcode copy").  Under FSDP the expert weights
are additionally tensor-parallel over the data axis (2-D expert sharding:
EP x expert-TP), so e.g. llama4's 400B of experts store at
params/(16*16) per device with no weight gathering — the hidden dim is
contracted locally and partial outputs psum over 'data'.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ArchConfig, MoECfg
from repro.models.layers import ParamDef, ParamDefs, mlp_defs, mlp_fwd
from repro.parallel.sharding import ShardingCtx


def moe_defs(cfg: ArchConfig) -> ParamDefs:
    m = cfg.moe
    d = cfg.d_model
    defs: ParamDefs = {
        "router": ParamDef((d, m.n_experts), "small_normal"),
        "w_gate": ParamDef((m.n_experts, d, m.d_expert), tp_dim=0),
        "w_up": ParamDef((m.n_experts, d, m.d_expert), tp_dim=0),
        "w_down": ParamDef((m.n_experts, m.d_expert, d), tp_dim=0),
    }
    if m.shared_expert:
        defs["shared"] = mlp_defs(d, m.d_expert, "swiglu", False)
    return defs


def expert_specs(cfg: ArchConfig, ctx: ShardingCtx):
    """PartitionSpecs for expert weights: EP over model (+TP over data)."""
    t = ctx.fsdp_axis  # 2-D expert sharding only when FSDP is on
    if cfg.moe.n_experts % ctx.tp != 0:
        return {"router": P(), "w_gate": P(), "w_up": P(), "w_down": P()}
    return {
        "router": P(),
        "w_gate": P(ctx.model_axis, None, t),
        "w_up": P(ctx.model_axis, None, t),
        "w_down": P(ctx.model_axis, t, None),
    }


def stacked_expert_specs(cfg: ArchConfig, ctx: ShardingCtx):
    """expert_specs with the layer-stack dim prepended (scan-stacked)."""
    return {k: P(*((None,) + tuple(v)))
            for k, v in expert_specs(cfg, ctx).items()}


def _route(x2d, router_w, m: MoECfg):
    """Top-k routing (select-then-softmax) + aux losses, in f32."""
    logits = (x2d.astype(jnp.float32) @ router_w.astype(jnp.float32))
    gates_full = jax.nn.softmax(logits, axis=-1)
    top_g, top_e = lax.top_k(logits, m.top_k)
    top_g = jax.nn.softmax(top_g, axis=-1)
    # switch-style load-balance loss + router z-loss
    T = x2d.shape[0]
    density = jnp.mean(gates_full, axis=0)
    counts = jnp.zeros((m.n_experts,), jnp.float32).at[top_e.reshape(-1)] \
        .add(1.0, mode="drop") / (T * m.top_k)
    lb_loss = m.n_experts * jnp.sum(density * counts)
    z_loss = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    return top_e, top_g, {"moe_lb": lb_loss, "moe_z": z_loss}


def _expert_ffn(wg, wu, wd, xe, mlp_type: str, tp_axis: Optional[str]):
    """Batched expert MLP: xe (E_loc, C', d) -> (E_loc, C', d).

    With ``tp_axis`` the hidden dim of wg/wu (and the contraction dim of
    wd) is sharded over that axis; partial outputs are psum'd.
    """
    if mlp_type == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg)) * \
            jnp.einsum("ecd,edf->ecf", xe, wu)
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xe, wu))
    y = jnp.einsum("ecf,efd->ecd", h, wd)
    if tp_axis is not None:
        y = lax.psum(y, tp_axis)
    return y


def _dispatch_tables(top_e, top_g, n_experts: int, capacity: int):
    """Sort-based dispatch: slot assignment with capacity dropping."""
    T, K = top_e.shape
    flat_e = top_e.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank_sorted = jnp.arange(T * K) - first
    rank = jnp.zeros((T * K,), jnp.int32).at[order] \
        .set(rank_sorted.astype(jnp.int32))
    keep = rank < capacity
    slot = jnp.where(keep, flat_e * capacity + rank, n_experts * capacity)
    return slot, keep


def _scatter_tokens(x2d, slot, keep, n_experts, capacity, K):
    T, d = x2d.shape
    buf = jnp.zeros((n_experts * capacity + 1, d), x2d.dtype)
    src = jnp.repeat(x2d, K, axis=0)
    slot = jnp.minimum(slot, n_experts * capacity)
    buf = buf.at[slot].add(jnp.where(keep[:, None], src, 0.0),
                           mode="drop")
    return buf[:-1].reshape(n_experts, capacity, d)


def _gather_outputs(out_buf, slot, keep, gates, T, K):
    d = out_buf.shape[-1]
    flat = jnp.concatenate(
        [out_buf.reshape(-1, d), jnp.zeros((1, d), out_buf.dtype)])
    per_assign = flat[jnp.minimum(slot, flat.shape[0] - 1)]
    per_assign = per_assign * (keep * gates.reshape(-1)).astype(
        per_assign.dtype)[:, None]
    return per_assign.reshape(T, K, d).sum(axis=1)


def moe_fwd(p, x, cfg: ArchConfig, ctx: ShardingCtx,
            dispatch: str = "fused"):
    """MoE FFN layer.  x: (B, L, d).  Returns (out, aux_losses)."""
    m = cfg.moe
    B, L, d = x.shape
    tp = ctx.tp

    if m.n_experts % tp != 0 and dispatch != "dense":
        # experts not shardable over TP (tiny smoke configs): dense oracle
        dispatch = "dense"

    if dispatch == "dense":
        x2d = x.reshape(-1, d)
        top_e, top_g, aux = _route(x2d, p["router"], m)
        outs = jnp.zeros_like(x2d)
        for e in range(m.n_experts):          # reference oracle (tiny cfgs)
            pe = {k: p[k][e] for k in ("w_gate", "w_up", "w_down")}
            if cfg.mlp_type == "swiglu":
                h = jax.nn.silu(x2d @ pe["w_gate"]) * (x2d @ pe["w_up"])
            else:
                h = jax.nn.gelu(x2d @ pe["w_up"])
            oe = h @ pe["w_down"]
            w = jnp.sum(jnp.where(top_e == e, top_g, 0.0),
                        axis=-1).astype(oe.dtype)
            outs = outs + oe * w[:, None]
        out = outs.reshape(B, L, d)
    else:
        tokens_per_rank = (B * L * max(ctx.dp, 1)) // max(ctx.dp, 1) // tp
        b_loc = B // max(ctx.dp, 1)
        if (b_loc * L) % tp == 0 and (b_loc * L) // tp >= 1 and L > 1:
            out, aux = _moe_manual(p, x, cfg, ctx, dispatch, ep=True)
        else:
            out, aux = _moe_manual(p, x, cfg, ctx, dispatch, ep=False)

    if m.shared_expert:
        out = out + mlp_fwd(p["shared"], x, "swiglu")
    return out, aux


def _moe_manual(p, x, cfg: ArchConfig, ctx: ShardingCtx, dispatch: str,
                ep: bool):
    """Fully-manual shard_map EP dispatch (all mesh axes manual)."""
    m = cfg.moe
    B, L, d = x.shape
    tp = ctx.tp
    e_loc = m.n_experts // tp
    exp_tp = ctx.fsdp_axis        # 2-D expert sharding axis (or None)
    bspec = ctx.batch_spec()
    model = ctx.model_axis
    all_axes = tuple(ctx.mesh.axis_names)

    def body(x_loc, router, wg, wu, wd):
        my = lax.axis_index(model)
        x2d = x_loc.reshape(-1, d)
        Ttot = x2d.shape[0]

        if ep:
            T = Ttot // tp
            x_my = lax.dynamic_slice_in_dim(x2d, my * T, T, axis=0)
            top_e, top_g, aux = _route(x_my, router, m)
            cap = _capacity(T, m, m.n_experts)
            slot, keep = _dispatch_tables(top_e, top_g, m.n_experts, cap)
            buf = _scatter_tokens(x_my, slot, keep, m.n_experts, cap,
                                  m.top_k)

            if dispatch == "fused":
                # paper technique: local-first dependency partitioning —
                # my experts' tokens never enter the all-to-all.
                e0 = my * e_loc
                local_buf = lax.dynamic_slice_in_dim(buf, e0, e_loc, 0)
                remote_buf = lax.dynamic_update_slice_in_dim(
                    buf, jnp.zeros_like(local_buf), e0, 0)
                shuf = _a2a_fwd(remote_buf, tp, e_loc, model)
                local_out = _expert_ffn(wg, wu, wd, local_buf,
                                        cfg.mlp_type, exp_tp)
                remote_out = _expert_ffn(wg, wu, wd, shuf,
                                         cfg.mlp_type, exp_tp)
                back = _a2a_bwd(remote_out, tp, e_loc, model)
                back = lax.dynamic_update_slice_in_dim(
                    back, local_out +
                    lax.dynamic_slice_in_dim(back, e0, e_loc, 0), e0, 0)
                out_buf = back
            else:
                shuf = _a2a_fwd(buf, tp, e_loc, model)
                eout = _expert_ffn(wg, wu, wd, shuf, cfg.mlp_type, exp_tp)
                out_buf = _a2a_bwd(eout, tp, e_loc, model)

            out_my = _gather_outputs(out_buf, slot, keep, top_g, T,
                                     m.top_k)
            out = jnp.zeros((Ttot, d), out_my.dtype)
            out = lax.dynamic_update_slice_in_dim(out, out_my, my * T, 0)
            out = lax.psum(out, model)
        else:
            # replicated dispatch (decode / tiny token counts): every model
            # rank routes all tokens, computes its local experts, psum.
            top_e, top_g, aux = _route(x2d, router, m)
            cap = _capacity(Ttot, m, m.n_experts)
            e0 = my * e_loc
            rel = top_e - e0
            mine = (rel >= 0) & (rel < e_loc)
            slot, keep = _dispatch_tables(
                jnp.where(mine, rel, e_loc), top_g, e_loc, cap)
            keep = keep & mine.reshape(-1)
            buf = _scatter_tokens(x2d, slot, keep, e_loc, cap, m.top_k)
            out_buf = _expert_ffn(wg, wu, wd, buf, cfg.mlp_type, exp_tp)
            out = _gather_outputs(out_buf, slot, keep, top_g, Ttot,
                                  m.top_k)
            out = lax.psum(out, model)

        aux = {k: lax.pmean(v, all_axes) for k, v in aux.items()}
        return out.reshape(x_loc.shape), aux

    es = expert_specs(cfg, ctx)
    fn = shard_map(
        body, mesh=ctx.mesh,
        in_specs=(P(bspec), es["router"], es["w_gate"], es["w_up"],
                  es["w_down"]),
        out_specs=(P(bspec), P()),
        check_vma=False)
    return fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])


def _a2a_fwd(buf, tp, e_loc, axis):
    """(E, C, d) on every rank -> (E_loc, tp*C, d) on the expert's owner."""
    E, C, d = buf.shape
    b = buf.reshape(tp, e_loc, C, d)
    shuf = lax.all_to_all(b, axis, split_axis=0, concat_axis=0, tiled=False)
    return jnp.moveaxis(shuf, 0, 1).reshape(e_loc, tp * C, d)


def _a2a_bwd(out, tp, e_loc, axis):
    """(E_loc, tp*C, d) -> (E, C, d) back on the token's source rank."""
    e_loc_, TC, d = out.shape
    C = TC // tp
    b = jnp.moveaxis(out.reshape(e_loc_, tp, C, d), 1, 0)
    shuf = lax.all_to_all(b, axis, split_axis=0, concat_axis=0, tiled=False)
    return shuf.reshape(tp * e_loc_, C, d)


def _capacity(tokens: int, m: MoECfg, n_experts: int) -> int:
    c = int(tokens * m.top_k * m.capacity_factor / n_experts) + 1
    return max(4, ((c + 3) // 4) * 4)
