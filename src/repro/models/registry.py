"""Model registry: config -> model bundle (LM / EncDec)."""
from __future__ import annotations

from repro.configs.base import ArchConfig
from repro.models.encdec import EncDec
from repro.models.transformer import LM
from repro.parallel.sharding import ShardingCtx


def build_model(cfg: ArchConfig, ctx: ShardingCtx, **opts):
    if cfg.is_encdec:
        return EncDec(cfg, ctx, **opts)
    return LM(cfg, ctx, **opts)
