"""Whisper-style encoder-decoder backbone.

The audio frontend (mel + convs) is a STUB per the assignment: inputs are
precomputed frame embeddings (B, T_enc, d_model).  Sinusoidal positions are
used on both sides (upstream whisper uses sinusoidal encoder / learned
decoder positions; learned tables don't extend to the 32k stress shapes, so
both sides are sinusoidal here — recorded in DESIGN.md).

Decode carries per-layer self-attention caches plus cross-attention K/V
computed once from the encoder output.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models.layers import (
    ParamDef,
    ParamDefs,
    abstract_params,
    cast_floats,
    cross_entropy,
    init_params,
    linear,
    mlp_defs,
    mlp_fwd,
    norm_defs,
    norm_fwd,
    param_specs,
    stack_defs,
)
from repro.parallel.sharding import ShardingCtx


def sinusoid(positions, d_model: int):
    """(..., L) -> (..., L, d) sinusoidal embedding, f32."""
    half = d_model // 2
    freq = jnp.exp(-math.log(10_000.0) *
                   jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def enc_layer_defs(cfg: ArchConfig) -> ParamDefs:
    return {
        "ln1": norm_defs(cfg.d_model, cfg.use_bias),
        "attn": attn.attn_defs(cfg),
        "ln2": norm_defs(cfg.d_model, cfg.use_bias),
        "mlp": mlp_defs(cfg.d_model, cfg.d_ff, cfg.mlp_type, cfg.use_bias),
    }


def dec_layer_defs(cfg: ArchConfig) -> ParamDefs:
    return {
        "ln1": norm_defs(cfg.d_model, cfg.use_bias),
        "attn": attn.attn_defs(cfg),
        "lnx": norm_defs(cfg.d_model, cfg.use_bias),
        "xattn": attn.attn_defs(cfg, cross=True),
        "ln2": norm_defs(cfg.d_model, cfg.use_bias),
        "mlp": mlp_defs(cfg.d_model, cfg.d_ff, cfg.mlp_type, cfg.use_bias),
    }


class EncDec:
    def __init__(self, cfg: ArchConfig, ctx: ShardingCtx, **_):
        self.cfg = cfg
        self.ctx = ctx
        V = cfg.padded_vocab
        self.defs: ParamDefs = {
            "embed": ParamDef((V, cfg.d_model), "small_normal", tp_dim=0),
            "enc_units": stack_defs(enc_layer_defs(cfg), cfg.encoder_layers),
            "dec_units": stack_defs(dec_layer_defs(cfg), cfg.n_layers),
            "enc_norm": norm_defs(cfg.d_model, cfg.use_bias),
            "final_norm": norm_defs(cfg.d_model, cfg.use_bias),
            "lm_head": ParamDef((cfg.d_model, V), "small_normal", tp_dim=1),
        }
        self.cdt = jnp.dtype(cfg.compute_dtype)
        self.pdt = jnp.dtype(cfg.param_dtype)

    # ---- params -----------------------------------------------------------

    def init(self, rng):
        return init_params(rng, self.defs, self.pdt)

    def abstract(self):
        return abstract_params(self.defs, self.pdt)

    def specs(self):
        cfg, ctx = self.cfg, self.ctx
        return {
            "embed": param_specs({"e": self.defs["embed"]}, ctx)["e"],
            "enc_units": param_specs(enc_layer_defs(cfg), ctx, stacked=True),
            "dec_units": param_specs(dec_layer_defs(cfg), ctx, stacked=True),
            "enc_norm": jax.tree.map(
                lambda _: P(), param_specs({"n": self.defs["enc_norm"]},
                                           ctx)["n"]),
            "final_norm": jax.tree.map(
                lambda _: P(), param_specs({"n": self.defs["final_norm"]},
                                           ctx)["n"]),
            "lm_head": param_specs({"h": self.defs["lm_head"]}, ctx)["h"],
        }

    # ---- encoder ------------------------------------------------------------

    def encode(self, params, frames):
        cfg, ctx = self.cfg, self.ctx
        B, T, _ = frames.shape
        pos = jnp.broadcast_to(jnp.arange(T), (B, T))
        x = frames.astype(self.cdt) + sinusoid(pos, cfg.d_model) \
            .astype(self.cdt)
        x = ctx.act(x, ctx.batch_spec(), None, None)

        def body(x, p):
            p = cast_floats(p, self.cdt)
            def unit(p, x):
                h = norm_fwd(p["ln1"], x, cfg.norm_eps)
                o, _ = attn.attention_fwd(p["attn"], h, cfg, ctx,
                                          positions=pos, causal=False,
                                          rope=False)
                x = x + o
                h = norm_fwd(p["ln2"], x, cfg.norm_eps)
                return x + mlp_fwd(p["mlp"], h, cfg.mlp_type)
            if cfg.remat:
                unit = jax.checkpoint(
                    unit, policy=jax.checkpoint_policies.nothing_saveable)
            return unit(p, x), None

        x, _ = lax.scan(body, x, params["enc_units"])
        return norm_fwd(params["enc_norm"], x, cfg.norm_eps)

    # ---- decoder ------------------------------------------------------------

    def _dec_unit(self, p, x, positions, enc_out=None, cache=None,
                  cache_index=None):
        cfg, ctx = self.cfg, self.ctx
        h = norm_fwd(p["ln1"], x, cfg.norm_eps)
        o, nc_self = attn.attention_fwd(
            p["attn"], h, cfg, ctx, positions=positions, rope=False,
            cache=None if cache is None else cache["attn"],
            cache_index=cache_index)
        x = x + o
        h = norm_fwd(p["lnx"], x, cfg.norm_eps)
        if cache is None:
            o, _ = attn.attention_fwd(p["xattn"], h, cfg, ctx,
                                      positions=positions, causal=False,
                                      rope=False, kv_x=enc_out,
                                      kv_positions=jnp.zeros_like(positions))
        else:
            # decode: cross K/V precomputed at encode time
            B, L, _ = h.shape
            hq, hd = cfg.n_heads, cfg.head_dim
            q = linear(h, p["xattn"]["wq"], p["xattn"].get("bq")) \
                .reshape(B, L, hq, hd)
            xk, xv = cache["xk"], cache["xv"]
            if L == 1:
                o = attn.decode_attention(q, xk, xv, xk.shape[1])
            else:
                o = attn.blocked_attention(q, xk, xv, causal=False)
            o = linear(o.reshape(B, L, hq * hd), p["xattn"]["wo"],
                       p["xattn"].get("bo"))
        x = x + o
        h = norm_fwd(p["ln2"], x, cfg.norm_eps)
        x = x + mlp_fwd(p["mlp"], h, cfg.mlp_type)
        new_cache = None
        if cache is not None:
            new_cache = {"attn": nc_self, "xk": cache["xk"],
                         "xv": cache["xv"]}
        return x, new_cache

    def decode_stack(self, params, x, positions, enc_out=None, cache=None,
                     cache_index=None, remat=None):
        cfg, ctx = self.cfg, self.ctx
        remat = cfg.remat if remat is None else remat
        if cache is None:
            def body(x, p):
                p = cast_floats(p, self.cdt)
                def unit(p, x):
                    return self._dec_unit(p, x, positions, enc_out)[0]
                if remat:
                    unit = jax.checkpoint(
                        unit,
                        policy=jax.checkpoint_policies.nothing_saveable)
                return unit(p, x), None
            x, _ = lax.scan(body, x, params["dec_units"])
            return x, None

        def body(carry, xs):
            x, cache_all = carry
            p, idx = xs
            p = cast_floats(p, self.cdt)
            c = jax.tree.map(
                lambda a: lax.dynamic_index_in_dim(a, idx, 0,
                                                   keepdims=False),
                cache_all)
            x, nc = self._dec_unit(p, x, positions, cache=c,
                                   cache_index=cache_index)
            cache_all = jax.tree.map(
                lambda a, n: lax.dynamic_update_index_in_dim(
                    a, n.astype(a.dtype), idx, 0), cache_all, nc)
            return (x, cache_all), None
        n = self.cfg.n_layers
        (x, new_cache), _ = lax.scan(
            body, (x, cache), (params["dec_units"], jnp.arange(n)))
        return x, new_cache

    # ---- entry points ----------------------------------------------------------

    def _logits(self, params, x):
        cfg = self.cfg
        x = norm_fwd(params["final_norm"], x, cfg.norm_eps)
        logits = x @ params["lm_head"].astype(self.cdt)
        V, Vp = cfg.vocab, cfg.padded_vocab
        if Vp != V:
            logits = logits + jnp.where(jnp.arange(Vp) < V, 0.0,
                                        -1e30).astype(logits.dtype)
        return logits

    def loss_fn(self, params, batch):
        cfg, ctx = self.cfg, self.ctx
        enc_out = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        x = jnp.take(params["embed"], tokens[:, :-1], axis=0) \
            .astype(self.cdt)
        B, L, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(L), (B, L))
        x = x + sinusoid(pos, cfg.d_model).astype(self.cdt)
        x = ctx.act(x, ctx.batch_spec(), None, None)
        x, _ = self.decode_stack(params, x, pos, enc_out)
        loss = cross_entropy(self._logits(params, x), tokens[:, 1:])
        return loss, {"ce": loss}

    def build_cross_cache(self, params, enc_out):
        """Precompute per-layer cross K/V from the encoder output."""
        cfg, ctx = self.cfg, self.ctx
        B, T, _ = enc_out.shape
        hkv, hd = cfg.n_kv_heads, cfg.head_dim

        def body(_, p):
            p = cast_floats(p, self.cdt)
            k = linear(enc_out, p["xattn"]["wk"], p["xattn"].get("bk")) \
                .reshape(B, T, hkv, hd)
            v = linear(enc_out, p["xattn"]["wv"], p["xattn"].get("bv")) \
                .reshape(B, T, hkv, hd)
            k, v = attn.repeat_kv(k, v, cfg, ctx)
            return None, (k.astype(self.cdt), v.astype(self.cdt))

        _, (xk, xv) = lax.scan(body, None, params["dec_units"])
        return xk, xv

    def decode_step(self, params, token, pos, cache):
        cfg = self.cfg
        B = token.shape[0]
        x = jnp.take(params["embed"], token, axis=0).astype(self.cdt)
        positions = jnp.broadcast_to(jnp.reshape(pos, (-1, 1)), (B, 1))
        x = x + sinusoid(positions, cfg.d_model).astype(self.cdt)
        x, new_cache = self.decode_stack(params, x, positions, cache=cache,
                                         cache_index=pos, remat=False)
        return self._logits(params, x)[:, 0], new_cache

    def prefill(self, params, batch, cache=None):
        """Encode + teacher-forced prefix -> last-position logits + cache."""
        cfg, ctx = self.cfg, self.ctx
        enc_out = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        x = jnp.take(params["embed"], tokens, axis=0).astype(self.cdt)
        B, L, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(L), (B, L))
        x = x + sinusoid(pos, cfg.d_model).astype(self.cdt)
        if cache is None:
            x, _ = self.decode_stack(params, x, pos, enc_out, remat=False)
            return self._logits(params, x[:, -1:])[:, 0], None
        xk, xv = self.build_cross_cache(params, enc_out)
        cache = jax.tree.map(lambda a: a, cache)
        cache = dict(cache)  # shallow; leaves replaced below
        cache = {"attn": cache["attn"], "xk": xk, "xv": xv}
        x, new_cache = self.decode_stack(params, x, pos, cache=cache,
                                         cache_index=0, remat=False)
        return self._logits(params, x[:, -1:])[:, 0], new_cache

    # ---- caches -----------------------------------------------------------------

    def cache_shapes(self, batch: int, max_len: int):
        cfg, ctx = self.cfg, self.ctx
        n = cfg.n_layers
        hk = ctx.kv_heads_eff(cfg.n_kv_heads, cfg.n_heads)
        shp = (n, batch, max_len, hk, cfg.head_dim)
        xshp = (n, batch, cfg.encoder_seq, hk, cfg.head_dim)
        return {
            "attn": {"k": jax.ShapeDtypeStruct(shp, self.cdt),
                     "v": jax.ShapeDtypeStruct(shp, self.cdt)},
            "xk": jax.ShapeDtypeStruct(xshp, self.cdt),
            "xv": jax.ShapeDtypeStruct(xshp, self.cdt),
        }

    def cache_specs(self):
        ctx = self.ctx
        b = ctx.batch_spec() if ctx.batch_axes else None
        kva = ctx.kv_head_axis(self.cfg.n_kv_heads, self.cfg.n_heads)
        seq = ctx.model_axis if kva is None else None
        s = P(None, b, seq, kva, None)
        # cross K/V stay replicated on seq (encoder length is short)
        x = P(None, b, None, kva, None)
        return {"attn": {"k": s, "v": s}, "xk": x, "xv": x}

    def init_cache(self, batch: int, max_len: int):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.cache_shapes(batch, max_len))
