"""Mamba (S6) block for the Jamba hybrid — chunked associative-scan core.

The inner dimension ``d_inner`` is tensor-parallel over the model axis
(column-parallel in_proj, row-parallel out_proj), so the per-chunk scan
workspace (B, C, d_inner_loc, d_state) stays VMEM-friendly.  The selective
recurrence h_t = dA_t * h_{t-1} + dBx_t is a gated linear recurrence:
within a chunk we use ``lax.associative_scan`` (log-depth, products of
dA in (0,1) -> numerically stable), across chunks a ``lax.scan`` carries
the (B, d_inner, d_state) state — the same chunk/state structure a TPU
kernel would use.

Decode carries (conv window, ssm state) per layer.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models.layers import ParamDef, ParamDefs


def dt_rank(cfg: ArchConfig) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


def mamba_defs(cfg: ArchConfig) -> ParamDefs:
    d = cfg.d_model
    di = cfg.d_inner_mamba
    ds = cfg.mamba_d_state
    dc = cfg.mamba_d_conv
    r = dt_rank(cfg)
    return {
        "in_proj": ParamDef((d, 2 * di), tp_dim=1),
        "conv_w": ParamDef((di, dc), "normal", tp_dim=0, scale=0.5),
        "conv_b": ParamDef((di,), "zeros", tp_dim=0),
        "x_proj": ParamDef((di, r + 2 * ds), tp_dim=0),
        "dt_proj": ParamDef((r, di), tp_dim=1),
        "dt_bias": ParamDef((di,), "zeros", tp_dim=0),
        "A_log": ParamDef((di, ds), "ones", tp_dim=0),
        "D": ParamDef((di,), "ones", tp_dim=0),
        "out_proj": ParamDef((di, d), tp_dim=0),
    }


def _causal_conv(x, w, b, window_init=None):
    """Depthwise causal conv over L via shifted adds.  x: (B, L, di)."""
    B, L, di = x.shape
    dc = w.shape[1]
    if window_init is None:
        pad = jnp.zeros((B, dc - 1, di), x.dtype)
    else:
        pad = window_init
    xp = jnp.concatenate([pad, x], axis=1)
    out = jnp.zeros_like(x)
    for j in range(dc):
        out = out + xp[:, j:j + L] * w[:, j].astype(x.dtype)
    new_window = xp[:, L:L + dc - 1] if dc > 1 else pad[:, :0]
    return out + b.astype(x.dtype), new_window


def _ssm_chunk(carry_h, chunk, A):
    """One chunk of the selective scan.  chunk: dict of (B, C, ...)."""
    dt, Bc, Cc, xin = chunk
    dA = jnp.exp(dt[..., None] * A)                       # (B,C,di,ds)
    dBx = dt[..., None] * Bc[:, :, None, :] * xin[..., None]

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    a_cum, b_cum = lax.associative_scan(combine, (dA, dBx), axis=1)
    h_all = b_cum + a_cum * carry_h[:, None]              # (B,C,di,ds)
    y = jnp.einsum("bcds,bcs->bcd", h_all, Cc)
    return h_all[:, -1], y


def mamba_fwd(p, x, cfg: ArchConfig, *, chunk: int = 128,
              state: Optional[dict] = None):
    """x: (B, L, d).  With ``state`` set (decode), L must be 1.

    Returns (out, new_state_or_None).
    """
    B, L, d = x.shape
    di = cfg.d_inner_mamba
    ds = cfg.mamba_d_state
    r = dt_rank(cfg)
    cdt = jnp.dtype(cfg.mamba_scan_dtype)

    xz = (x @ p["in_proj"]).astype(cdt)
    xin, z = xz[..., :di], xz[..., di:]
    win0 = None if state is None else state["conv"].astype(cdt)
    xin, new_win = _causal_conv(xin, p["conv_w"].astype(cdt),
                                p["conv_b"], win0)
    xin = jax.nn.silu(xin)

    proj = xin @ p["x_proj"].astype(cdt)
    dt = jax.nn.softplus(proj[..., :r] @ p["dt_proj"].astype(cdt)
                         + p["dt_bias"].astype(cdt))
    Bc = proj[..., r:r + ds]
    Cc = proj[..., r + ds:]
    A = -jnp.exp(p["A_log"].astype(cdt))                  # (di, ds)

    if state is not None and L == 1:
        # single-token decode: one recurrence step
        h = state["ssm"].astype(cdt)                      # (B, di, ds)
        dA = jnp.exp(dt[:, 0, :, None] * A)
        h = dA * h + dt[:, 0, :, None] * Bc[:, 0, None, :] \
            * xin[:, 0, :, None]
        y = jnp.einsum("bds,bs->bd", h, Cc[:, 0])[:, None]
        new_state = {"conv": new_win.astype(x.dtype),
                     "ssm": h.astype(jnp.float32)}
    else:
        C = chunk
        while L % C:
            C -= 1
        n = L // C
        h0 = jnp.zeros((B, di, ds), cdt) if state is None \
            else state["ssm"].astype(cdt)
        seqs = tuple(a.reshape(B, n, C, -1).swapaxes(0, 1)
                     for a in (dt, Bc, Cc, xin))

        def step(h, ch):
            h, y = _ssm_chunk(h, ch, A)
            return h, y

        h_final, ys = lax.scan(step, h0, seqs)            # (n, B, C, di)
        y = ys.swapaxes(0, 1).reshape(B, L, di)
        new_state = None if state is None else {
            "conv": new_win.astype(x.dtype),
            "ssm": h_final.astype(jnp.float32)}

    y = y + xin * p["D"].astype(cdt)
    y = y * jax.nn.silu(z)
    out = (y.astype(x.dtype)) @ p["out_proj"]
    return out, new_state


def mamba_state_shapes(cfg: ArchConfig, batch: int, n_layers: int, dtype):
    di, ds, dc = cfg.d_inner_mamba, cfg.mamba_d_state, cfg.mamba_d_conv
    return {
        "conv": jax.ShapeDtypeStruct((n_layers, batch, dc - 1, di), dtype),
        "ssm": jax.ShapeDtypeStruct((n_layers, batch, di, ds), jnp.float32),
    }
