"""Shared layer primitives + the ParamDef system.

Params are plain pytrees (nested dicts of jnp arrays).  Each module declares
its parameters once as ``ParamDef``s (shape, initializer, TP dim); the same
declaration drives initialization, abstract shapes for the dry-run, and
PartitionSpecs — so placement can never drift from the parameter tree.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import ShardingCtx, param_spec


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    init: str = "normal"          # normal | zeros | ones | small_normal
    tp_dim: Optional[int] = None  # dim carrying tensor parallelism
    scale: Optional[float] = None


ParamDefs = Dict[str, "ParamDefs | ParamDef"]  # nested


def _init_one(rng, d: ParamDef, dtype):
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    scale = d.scale
    if scale is None:
        fan_in = d.shape[0] if len(d.shape) > 1 else d.shape[-1]
        scale = 1.0 / math.sqrt(max(fan_in, 1))
    if d.init == "small_normal":
        scale = 0.02
    return scale * jax.random.normal(rng, d.shape, dtype)


def init_params(rng, defs: ParamDefs, dtype):
    flat = _flatten(defs)
    keys = jax.random.split(rng, len(flat))
    leaves = {path: _init_one(k, d, dtype)
              for k, (path, d) in zip(keys, flat.items())}
    return _unflatten(leaves)


def abstract_params(defs: ParamDefs, dtype):
    flat = _flatten(defs)
    return _unflatten({p: jax.ShapeDtypeStruct(d.shape, dtype)
                       for p, d in flat.items()})


def param_specs(defs: ParamDefs, ctx: ShardingCtx, stacked: bool = False):
    flat = _flatten(defs)
    out = {}
    for path, d in flat.items():
        shape = d.shape
        tp = d.tp_dim
        if stacked:
            shape = (1,) + tuple(shape)   # placeholder stack dim
            tp = None if tp is None else (tp + 1 if tp >= 0 else tp)
        spec = param_spec(ctx, shape, tp, stacked=stacked)
        out[path] = spec
    return _unflatten(out)


def stack_defs(defs: ParamDefs, n: int) -> ParamDefs:
    """Prepend the scan-stack dim to every def (layer-stacked params)."""
    flat = _flatten(defs)
    out = {}
    for path, d in flat.items():
        tp = d.tp_dim
        out[path] = ParamDef((n,) + tuple(d.shape), d.init,
                             None if tp is None else
                             (tp + 1 if tp >= 0 else tp), d.scale)
    return _unflatten(out)


def _flatten(defs, prefix=()):
    flat = {}
    for k, v in defs.items():
        if isinstance(v, ParamDef):
            flat[prefix + (k,)] = v
        else:
            flat.update(_flatten(v, prefix + (k,)))
    return flat


def _unflatten(flat):
    out: dict = {}
    for path, v in flat.items():
        node = out
        for k in path[:-1]:
            node = node.setdefault(k, {})
        node[path[-1]] = v
    return out


# --------------------------------------------------------------------------
# numerics
# --------------------------------------------------------------------------

def rms_norm(x, scale, eps: float):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x, scale, bias, eps: float):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(dt)


def linear(x, w, b=None):
    y = x @ w
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def rotary(x, positions, theta: float):
    """RoPE on the last dim of (..., L, H, hd) given positions (..., L)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = jnp.arange(half, dtype=jnp.float32)
    inv = theta ** (-freq / half)
    ang = positions.astype(jnp.float32)[..., None] * inv        # (..., L, half)
    sin = jnp.sin(ang)[..., None, :]                            # (..., L, 1, half)
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin],
        axis=-1).astype(x.dtype)


def cast_floats(tree, dtype):
    """Cast float leaves to the compute dtype (mixed-precision forward)."""
    def f(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree.map(f, tree)


def norm_defs(d_model: int, use_bias: bool) -> ParamDefs:
    d: ParamDefs = {"scale": ParamDef((d_model,), "ones")}
    if use_bias:
        d["bias"] = ParamDef((d_model,), "zeros")
    return d


def norm_fwd(p, x, eps: float):
    """RMSNorm, or LayerNorm when the arch uses biases (whisper/starcoder2)."""
    if "bias" in p:
        return layer_norm(x, p["scale"], p["bias"], eps)
    return rms_norm(x, p["scale"], eps)


# ---- MLP -------------------------------------------------------------------

def mlp_defs(d_model: int, d_ff: int, mlp_type: str,
             use_bias: bool) -> ParamDefs:
    defs: ParamDefs = {}
    if mlp_type == "swiglu":
        defs["w_gate"] = ParamDef((d_model, d_ff), tp_dim=1)
        defs["w_up"] = ParamDef((d_model, d_ff), tp_dim=1)
    else:
        defs["w_up"] = ParamDef((d_model, d_ff), tp_dim=1)
        if use_bias:
            defs["b_up"] = ParamDef((d_ff,), "zeros", tp_dim=0)
    defs["w_down"] = ParamDef((d_ff, d_model), tp_dim=0)
    if use_bias:
        defs["b_down"] = ParamDef((d_model,), "zeros")
    return defs


def mlp_fwd(p, x, mlp_type: str):
    if mlp_type == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(linear(x, p["w_up"], p.get("b_up")))
    return linear(h, p["w_down"], p.get("b_down"))


# ---- losses -----------------------------------------------------------------

def cross_entropy(logits, labels, mask=None, z_loss: float = 1e-4):
    """Token-mean CE with z-loss, in f32; labels < 0 are ignored."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(
        lf, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if z_loss:
        nll = nll + z_loss * lse ** 2
    valid = labels >= 0
    if mask is not None:
        valid = valid & mask
    w = valid.astype(jnp.float32)
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)
