"""RWKV6 "Finch" block: data-dependent decay linear attention + channel mix.

Attention-free: the paper's halo-exchange technique does not apply to the
token mixer (O(1) recurrent state, no KV halo) — see DESIGN.md
§Arch-applicability.  The WKV recurrence

    S_t = diag(w_t) S_{t-1} + k_t^T v_t,   o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

runs as a sequential ``lax.scan`` carrying S (B, H, hd, hd) — the numerically
safe formulation (chunked matrix forms need exp(-cum log w) factors that
overflow for fast decays; a TPU kernel would run the same sequential loop
over a VMEM-resident chunk, see kernels/).

Simplifications vs upstream RWKV6 (recorded here deliberately): the five
token-shift interpolations use static learned mu (not the data-dependent
ddlerp LoRA); the decay LoRA (w0 + tanh(x A) B) IS data-dependent as in the
paper since it defines the architecture's headline feature.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models.layers import ParamDef, ParamDefs, rms_norm


def rwkv_defs(cfg: ArchConfig) -> ParamDefs:
    d = cfg.d_model
    H = cfg.rwkv_heads
    hd = cfg.rwkv_head_dim
    lora = cfg.rwkv_decay_lora
    ff = cfg.d_ff
    return {
        "tm": {  # time mix
            "mu": ParamDef((5, d), "small_normal"),       # r,k,v,w,g shifts
            "Wr": ParamDef((d, d), tp_dim=1),
            "Wk": ParamDef((d, d), tp_dim=1),
            "Wv": ParamDef((d, d), tp_dim=1),
            "Wg": ParamDef((d, d), tp_dim=1),
            "Wo": ParamDef((d, d), tp_dim=0),
            "w0": ParamDef((d,), "zeros"),
            "wA": ParamDef((d, lora), "small_normal"),
            "wB": ParamDef((lora, d), "small_normal"),
            "u": ParamDef((H, hd), "small_normal"),
            "ln_x": ParamDef((d,), "ones"),
        },
        "cm": {  # channel mix
            "mu": ParamDef((2, d), "small_normal"),       # k, r shifts
            "Wk": ParamDef((d, ff), tp_dim=1),
            "Wv": ParamDef((ff, d), tp_dim=0),
            "Wr": ParamDef((d, d), tp_dim=1),
        },
    }


def _token_shift(x, last):
    """Shift right by one token; ``last`` (B, 1, d) is the decode carry."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _mix(x, xx, mu):
    return x + (xx - x) * mu.astype(x.dtype)


def _wkv_scan(r, k, v, w, u, state):
    """Sequential WKV recurrence.  r/k/v/w: (B, L, H, hd) f32."""
    B, L, H, hd = r.shape
    seq = tuple(a.swapaxes(0, 1) for a in (r, k, v, w))   # (L, B, H, hd)

    def step(S, inp):
        rt, kt, vt, wt = inp
        kv = kt[..., :, None] * vt[..., None, :]          # (B,H,hd,hd)
        # o_t = r (S_{t-1} + diag(u) k^T v): the bonus term contracts the
        # key dim with u folded in elementwise.
        out = jnp.einsum("bhk,bhkv->bhv", rt, S) + \
            jnp.einsum("bhk,bhk,bhkv->bhv", rt, u[None], kv)
        S = wt[..., :, None] * S + kv
        return S, out

    S_final, outs = lax.scan(step, state, seq)
    return outs.swapaxes(0, 1), S_final                   # (B,L,H,hd)


def rwkv_time_mix(p, x, cfg: ArchConfig, state: Optional[dict] = None):
    B, L, d = x.shape
    H, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
    last = None if state is None else state["shift_tm"]
    xx = _token_shift(x, last)
    mu = p["mu"]
    xr = _mix(x, xx, mu[0])
    xk = _mix(x, xx, mu[1])
    xv = _mix(x, xx, mu[2])
    xw = _mix(x, xx, mu[3])
    xg = _mix(x, xx, mu[4])

    f32 = jnp.float32
    r = (xr @ p["Wr"]).astype(f32).reshape(B, L, H, hd)
    k = (xk @ p["Wk"]).astype(f32).reshape(B, L, H, hd)
    v = (xv @ p["Wv"]).astype(f32).reshape(B, L, H, hd)
    g = jax.nn.silu((xg @ p["Wg"]).astype(f32))
    # data-dependent decay (the Finch feature)
    ww = p["w0"].astype(f32) + \
        jnp.tanh(xw.astype(f32) @ p["wA"].astype(f32)) @ p["wB"].astype(f32)
    w = jnp.exp(-jnp.exp(ww)).reshape(B, L, H, hd)

    S0 = jnp.zeros((B, H, hd, hd), f32) if state is None \
        else state["wkv"].astype(f32)
    out, S = _wkv_scan(r, k, v, w, p["u"].astype(f32), S0)
    out = out.reshape(B, L, d)
    out = rms_norm(out, p["ln_x"], cfg.norm_eps)          # per-channel norm
    out = (out * g).astype(x.dtype) @ p["Wo"]
    new_state = None
    if state is not None:
        new_state = {"shift_tm": x[:, -1:],
                     "wkv": S}
    return out, new_state


def rwkv_channel_mix(p, x, state: Optional[dict] = None):
    last = None if state is None else state["shift_cm"]
    xx = _token_shift(x, last)
    xk = _mix(x, xx, p["mu"][0])
    xr = _mix(x, xx, p["mu"][1])
    k = jnp.square(jax.nn.relu(xk @ p["Wk"]))
    kv = k @ p["Wv"]
    out = jax.nn.sigmoid(xr @ p["Wr"]) * kv
    new_state = None if state is None else {"shift_cm": x[:, -1:]}
    return out, new_state


def rwkv_state_shapes(cfg: ArchConfig, batch: int, n_layers: int, dtype):
    H, hd, d = cfg.rwkv_heads, cfg.rwkv_head_dim, cfg.d_model
    return {
        "shift_tm": jax.ShapeDtypeStruct((n_layers, batch, 1, d), dtype),
        "shift_cm": jax.ShapeDtypeStruct((n_layers, batch, 1, d), dtype),
        "wkv": jax.ShapeDtypeStruct((n_layers, batch, H, hd, hd),
                                    jnp.float32),
    }
