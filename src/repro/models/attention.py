"""GQA attention: blocked (flash-style) causal/full attention + KV caches.

Sharding strategy is auto-selected per arch (see DESIGN.md):
  * ``head``  — q heads divisible by TP: heads shard over the model axis;
    KV heads use the grouped-replication policy from parallel/sharding.
  * ``seq``   — q heads not divisible by TP (starcoder2 36H, llama4 40H,
    whisper 12H at TP=16): the q sequence shards over the model axis and
    heads stay whole; KV is gathered.  Decode (L=1) always computes with
    whole heads.

The blocked kernel is a pure-JAX flash attention: outer scan over q chunks,
inner scan over kv chunks, online max/denominator in f32.  The Pallas TPU
kernel in kernels/flash_attention.py implements the same contraction with
explicit VMEM tiling; models use this path for lowering portability, the
kernel is validated against ref.py separately.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models.layers import ParamDef, ParamDefs, linear, rms_norm, rotary
from repro.parallel.sharding import ShardingCtx

NEG_INF = -1e30


def attn_defs(cfg: ArchConfig, cross: bool = False) -> ParamDefs:
    d, hd = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    defs: ParamDefs = {
        "wq": ParamDef((d, hq * hd), tp_dim=1),
        "wk": ParamDef((d, hkv * hd), tp_dim=1),
        "wv": ParamDef((d, hkv * hd), tp_dim=1),
        "wo": ParamDef((hq * hd, d), tp_dim=0),
    }
    if cfg.use_bias:
        defs["bq"] = ParamDef((hq * hd,), "zeros", tp_dim=0)
        defs["bk"] = ParamDef((hkv * hd,), "zeros", tp_dim=0)
        defs["bv"] = ParamDef((hkv * hd,), "zeros", tp_dim=0)
        defs["bo"] = ParamDef((d,), "zeros")
    if cfg.qk_norm and not cross:
        defs["q_norm"] = ParamDef((hd,), "ones")
        defs["k_norm"] = ParamDef((hd,), "ones")
    return defs


def shard_mode(cfg: ArchConfig, ctx: ShardingCtx) -> str:
    return "head" if cfg.n_heads % ctx.tp == 0 else "seq"


def _chunk(n: int, target: int) -> int:
    c = min(n, target)
    while n % c:
        c -= 1
    return max(c, 1)


def _project_qkv(p, x, kv_x, cfg: ArchConfig, positions, kv_positions,
                 rope: bool):
    B, L = x.shape[0], x.shape[1]
    S = kv_x.shape[1]
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = linear(x, p["wq"], p.get("bq")).reshape(B, L, hq, hd)
    k = linear(kv_x, p["wk"], p.get("bk")).reshape(B, S, hkv, hd)
    v = linear(kv_x, p["wv"], p.get("bv")).reshape(B, S, hkv, hd)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if rope:
        q = rotary(q, positions, cfg.rope_theta)
        k = rotary(k, kv_positions, cfg.rope_theta)
    return q, k, v


def repeat_kv(k, v, cfg: ArchConfig, ctx: ShardingCtx):
    """Grouped replication of KV heads so the cache/einsum shard over TP."""
    r = ctx.kv_repeat(cfg.n_kv_heads, cfg.n_heads)
    if r > 1:
        k = jnp.repeat(k, r, axis=2)
        v = jnp.repeat(v, r, axis=2)
    return k, v


def blocked_attention(q, k, v, *, causal: bool, q_offset=0,
                      q_chunk: int = 1024, kv_chunk: int = 2048,
                      kv_len_mask: Optional[jnp.ndarray] = None):
    """Flash-style attention.  q: (B, L, H, hd); k/v: (B, S, Hkv_eff, hd).

    Heads are grouped (H = Hkv_eff * G).  Returns (B, L, H, hd).
    ``kv_len_mask`` (B, S) masks padded cache slots during decode.
    """
    B, L, H, hd = q.shape
    S, HK = k.shape[1], k.shape[2]
    G = H // HK
    scale = hd ** -0.5
    qc = _chunk(L, q_chunk)
    kc = _chunk(S, kv_chunk)
    nq, nk = L // qc, S // kc

    # stay in the storage dtype; accumulate in f32 via the dot's
    # preferred_element_type (a f32 .astype of a cache slice gets hoisted
    # by XLA into an f32 copy of the WHOLE stacked cache)
    q = (q.astype(jnp.float32) * scale).astype(q.dtype) \
        .reshape(B, nq, qc, HK, G, hd)
    q = jnp.moveaxis(q, 1, 0)                       # (nq, B, qc, HK, G, hd)
    kf = jnp.moveaxis(k.reshape(B, nk, kc, HK, hd), 1, 0)
    vf = jnp.moveaxis(v.reshape(B, nk, kc, HK, hd), 1, 0)
    if kv_len_mask is not None:
        lm = jnp.moveaxis(kv_len_mask.reshape(B, nk, kc), 1, 0)
    else:
        lm = None

    q_pos = q_offset + jnp.arange(L).reshape(nq, qc)
    k_pos = jnp.arange(S).reshape(nk, kc)

    def q_block(carry, qi):
        qb, qp = qi

        def kv_block(acc, ki):
            kb, vb, kp, kmask = ki
            m, l, o = acc
            logits = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb,
                                preferred_element_type=jnp.float32)
            mask = jnp.ones((qc, kc), bool)
            if causal:
                mask = qp[:, None] >= kp[None, :]
            if kmask is not None:
                mask = mask & kmask[:, None, None, None, :]
                logits = jnp.where(mask, logits, NEG_INF)
            else:
                logits = jnp.where(mask[None, None, None], logits, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            o_new = o * corr[..., None] + \
                jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb,
                           preferred_element_type=jnp.float32)
            return (m_new, l_new, o_new), None

        m0 = jnp.full((B, HK, G, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, HK, G, qc), jnp.float32)
        o0 = jnp.zeros((B, HK, G, qc, hd), jnp.float32)
        xs = (kf, vf, k_pos, lm) if lm is not None else (kf, vf, k_pos)
        if lm is None:
            (m, l, o), _ = lax.scan(
                lambda a, x: kv_block(a, (x[0], x[1], x[2], None)),
                (m0, l0, o0), xs)
        else:
            (m, l, o), _ = lax.scan(kv_block, (m0, l0, o0), xs)
        out = o / jnp.maximum(l, 1e-30)[..., None]
        return carry, out                            # (B, HK, G, qc, hd)

    _, outs = lax.scan(q_block, None, (q, q_pos))    # (nq, B, HK, G, qc, hd)
    out = jnp.moveaxis(outs, 0, 3)                   # (B, HK, G, nq, qc, hd)
    return out.reshape(B, HK * G, L, hd).transpose(0, 2, 1, 3) \
        .astype(v.dtype)


def decode_attention(q, k_cache, v_cache, cache_len):
    """Single-token attention over a (possibly padded) cache.

    q: (B, 1, H, hd); caches: (B, S, HK, hd); cache_len: () or (B,) valid
    prefix length (the new token's K/V must already be written).
    """
    B, _, H, hd = q.shape
    S, HK = k_cache.shape[1], k_cache.shape[2]
    G = H // HK
    qf = (q.astype(jnp.float32).reshape(B, HK, G, hd) * hd ** -0.5) \
        .astype(k_cache.dtype)
    logits = jnp.einsum("bhgd,bkhd->bhgk", qf, k_cache,
                        preferred_element_type=jnp.float32)
    pos = jnp.arange(S)
    valid = pos[None, :] < jnp.reshape(cache_len, (-1, 1))
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, hd).astype(v_cache.dtype)


def attention_fwd(p, x, cfg: ArchConfig, ctx: ShardingCtx, *,
                  positions, causal: bool = True, rope: bool = True,
                  kv_x=None, kv_positions=None,
                  cache: Optional[dict] = None, cache_index=None):
    """Full attention sub-layer (projection + core + output proj).

    With ``cache`` set this is a decode step: x is (B, 1, d), the new K/V
    are written at ``cache_index`` and attention runs over the cache.
    Returns (out, new_cache_or_None).
    """
    B, L, _ = x.shape
    mode = shard_mode(cfg, ctx)
    kv_x = x if kv_x is None else kv_x
    kv_positions = positions if kv_positions is None else kv_positions
    q, k, v = _project_qkv(p, x, kv_x, cfg, positions, kv_positions, rope)
    k, v = repeat_kv(k, v, cfg, ctx)
    kva = ctx.kv_head_axis(cfg.n_kv_heads, cfg.n_heads)

    new_cache = None
    if cache is not None:
        # write new kv into the cache at cache_index (donated buffers)
        kc, vc = cache["k"], cache["v"]
        kc = lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype),
                                             cache_index, axis=1)
        vc = lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype),
                                             cache_index, axis=1)
        bspec = ctx.batch_spec() if ctx.batch_axes else None
        seq_ax = ctx.seq_axes[0] if ctx.seq_axes else None
        if kva is None and seq_ax is None:
            seq_ax = ctx.model_axis     # cache seq-sharded (see cache_specs)
        kc = ctx.act(kc, bspec, seq_ax, kva, None)
        vc = ctx.act(vc, bspec, seq_ax, kva, None)
        new_cache = {"k": kc, "v": vc}
        if L > 1:
            # prefill: causal attention over the freshly projected prefix
            out = blocked_attention(q, k, v, causal=True,
                                    q_offset=cache_index)
        else:
            out = decode_attention(q, kc, vc, cache_index + 1)
    elif cache_index is None and kv_x is not x:
        # encoder-decoder cross attention (training): full, non-causal
        out = blocked_attention(q, k, v, causal=False)
    else:
        if mode == "head":
            q = ctx.act(q, ctx.batch_spec(), None, ctx.model_axis, None)
            k = ctx.act(k, ctx.batch_spec(), None, kva, None)
            v = ctx.act(v, ctx.batch_spec(), None, kva, None)
        else:
            # seq sharding: q sequence over model axis, kv gathered
            q = ctx.act(q, ctx.batch_spec(), ctx.model_axis, None, None)
            k = ctx.act(k, ctx.batch_spec(), None, None, None)
            v = ctx.act(v, ctx.batch_spec(), None, None, None)
        out = blocked_attention(q, k, v, causal=causal)

    out = out.reshape(B, L, cfg.n_heads * cfg.head_dim)
    out = linear(out, p["wo"], p.get("bo"))
    return out, new_cache


def init_cache_shapes(cfg: ArchConfig, ctx: ShardingCtx, batch: int,
                      max_len: int, n_attn_layers: int, dtype):
    """Abstract KV cache for one stack of attention layers (stacked dim 0)."""
    hk = ctx.kv_heads_eff(cfg.n_kv_heads, cfg.n_heads)
    shape = (n_attn_layers, batch, max_len, hk, cfg.head_dim)
    return {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype)}
