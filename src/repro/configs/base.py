"""Architecture configs: the 10 assigned LM-family archs + shape grid.

Every config is exact per the assignment table (public-literature values);
``reduce()`` derives the same-family smoke config (small layers/width/
experts/vocab) used by CPU tests.  The FULL configs are exercised only via
the dry-run (ShapeDtypeStruct lowering, no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_expert: int              # expert hidden dim
    shared_expert: bool = False
    capacity_factor: float = 1.25
    every: int = 1             # MoE FFN on layers where (i % every == every-1)


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str                  # "attn" | "mamba" | "rwkv"
    moe: bool = False          # MoE FFN instead of dense on this layer


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    qk_norm: bool = False
    use_bias: bool = False
    mlp_type: str = "swiglu"   # swiglu | gelu
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    moe: Optional[MoECfg] = None
    pattern_unit: Tuple[LayerSpec, ...] = (LayerSpec("attn"),)

    # enc-dec (whisper): encoder layers with full attention + cross-attn decoder
    encoder_layers: int = 0
    encoder_seq: int = 1500    # precomputed frame embeddings (stub frontend)

    # vlm (internvl): prefix patch embeddings from the stubbed ViT
    prefix_tokens: int = 0     # e.g. 256 visual tokens per image

    # mamba (jamba) dims
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_scan_dtype: str = "float32"   # bf16 halves SSM chunk traffic
    # rwkv dims
    rwkv_head_dim: int = 64
    rwkv_decay_lora: int = 64

    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "nothing"   # nothing | dots (save matmul outputs:
                                    # no recompute psums in bwd, more memory)

    # ---- derived ---------------------------------------------------------

    @property
    def n_units(self) -> int:
        assert self.n_layers % len(self.pattern_unit) == 0, self.name
        return self.n_layers // len(self.pattern_unit)

    @property
    def padded_vocab(self) -> int:
        """Pad to a multiple of 128 (MXU lanes x TP=16 divisibility)."""
        return ((self.vocab + 127) // 128) * 128

    @property
    def d_inner_mamba(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k: token mixing without a full-attention
        KV-vs-seq quadratic prefill (SSM / linear-attention / hybrid)."""
        return any(s.kind in ("mamba", "rwkv") for s in self.pattern_unit)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def reduce(self) -> "ArchConfig":
        """Same-family smoke config: tiny dims, same layer pattern."""
        moe = None
        if self.moe is not None:
            # generous capacity so tiny-config tests see no routing drops
            moe = dataclasses.replace(self.moe, n_experts=4,
                                      top_k=min(2, self.moe.top_k),
                                      d_expert=64, capacity_factor=8.0)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=2 * len(self.pattern_unit),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=16,
            d_ff=128,
            vocab=512,
            moe=moe,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=16 if self.encoder_layers else self.encoder_seq,
            prefix_tokens=8 if self.prefix_tokens else 0,
            rwkv_head_dim=16,
            rwkv_decay_lora=8,
            mamba_d_state=8,
            param_dtype="float32",
            compute_dtype="float32",
            remat=False,
        )


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    kind: str                  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCfg("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCfg("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCfg("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCfg("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeCfg) -> Tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic token mixing."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "skip(full-attn)"
    return True, ""
