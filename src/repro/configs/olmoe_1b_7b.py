"""OLMoE-1B-7B: 64 experts, top-8, expert ff=1024 [arXiv:2409.02060]."""
from repro.configs.base import ArchConfig, LayerSpec, MoECfg

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab=50304,
    head_dim=128,
    qk_norm=True,
    mlp_type="swiglu",
    moe=MoECfg(n_experts=64, top_k=8, d_expert=1024, every=1),
    pattern_unit=(LayerSpec("attn", moe=True),),
)
