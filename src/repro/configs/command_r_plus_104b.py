"""Command-R+ 104B: GQA, no biases [hf:CohereForAI/c4ai-command-r-v01]."""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33792,
    vocab=256000,
    head_dim=128,
    use_bias=False,
    mlp_type="swiglu",
    rope_theta=75_000_000.0,
    pattern_unit=(LayerSpec("attn"),),
)
