"""Mistral-Nemo-12B, 128k ctx [hf:mistralai/Mistral-Nemo-Base-2407].

head_dim is 128 (q projection 4096-wide), decoupled from d_model/n_heads.
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=131072,
    head_dim=128,
    mlp_type="swiglu",
    rope_theta=1_000_000.0,
    pattern_unit=(LayerSpec("attn"),),
)
