"""InternVL2-26B LM backbone (InternLM2-20B) [arXiv:2404.16821; hf].

[vlm]: the InternViT-6B vision frontend is a STUB — ``input_specs()``
provides precomputed patch embeddings (256 visual tokens) prepended to the
text sequence; the transformer backbone below is modeled in full.
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92553,
    head_dim=128,
    mlp_type="swiglu",
    rope_theta=1_000_000.0,
    pattern_unit=(LayerSpec("attn"),),
    prefix_tokens=256,
)
