"""The paper's own workload: grappa-like MD systems (see core/md)."""
from repro.core.md.system import GRAPPA_SIZES, make_grappa_like

make_system = make_grappa_like
SIZES = GRAPPA_SIZES
