"""Qwen3-1.7B: qk_norm + GQA [hf:Qwen/Qwen3-8B family]."""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=6144,
    vocab=151936,
    head_dim=128,
    qk_norm=True,
    mlp_type="swiglu",
    rope_theta=1_000_000.0,
    pattern_unit=(LayerSpec("attn"),),
)
