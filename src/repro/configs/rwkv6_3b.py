"""RWKV6-3B "Finch": attention-free, data-dependent decay [arXiv:2404.05892].

The paper's halo-exchange technique is inapplicable to its token mixing
(O(1) recurrent state, no KV halo) — see DESIGN.md §Arch-applicability.
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=0,              # attention-free
    n_kv_heads=0,
    d_ff=8960,
    vocab=65536,
    head_dim=64,
    mlp_type="gelu",        # channel-mix uses squared-relu internally
    pattern_unit=(LayerSpec("rwkv"),),
    rwkv_head_dim=64,
    rwkv_decay_lora=64,
)
