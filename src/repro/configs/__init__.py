from repro.configs.base import (
    ArchConfig, LayerSpec, MoECfg, SHAPES, ShapeCfg, shape_applicable)
from repro.configs.registry import ARCH_IDS, all_configs, get_config

__all__ = ["ArchConfig", "LayerSpec", "MoECfg", "SHAPES", "ShapeCfg",
           "shape_applicable", "ARCH_IDS", "all_configs", "get_config"]
