"""Config registry: one module per assigned architecture + the MD workload."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ArchConfig

ARCH_IDS = (
    "internvl2_26b",
    "mistral_nemo_12b",
    "command_r_plus_104b",
    "qwen3_1_7b",
    "starcoder2_7b",
    "whisper_small",
    "olmoe_1b_7b",
    "llama4_maverick_400b_a17b",
    "rwkv6_3b",
    "jamba_v0_1_52b",
)

_ALIASES = {
    "internvl2-26b": "internvl2_26b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "command-r-plus-104b": "command_r_plus_104b",
    "qwen3-1.7b": "qwen3_1_7b",
    "starcoder2-7b": "starcoder2_7b",
    "whisper-small": "whisper_small",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "rwkv6-3b": "rwkv6_3b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
}


def get_config(arch: str) -> ArchConfig:
    mod_name = _ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    if mod_name not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
