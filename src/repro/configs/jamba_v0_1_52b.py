"""Jamba-v0.1-52B: Mamba+attention 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887].

Pattern unit = 8 layers with attention at position 4 (1:7 attn:mamba) and
MoE FFN on every second layer (odd positions), 4 units = 32 layers.
"""
from repro.configs.base import ArchConfig, LayerSpec, MoECfg

_UNIT = tuple(
    LayerSpec("attn" if i == 4 else "mamba", moe=(i % 2 == 1))
    for i in range(8)
)

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    head_dim=128,
    mlp_type="swiglu",
    moe=MoECfg(n_experts=16, top_k=2, d_expert=14336, every=2),
    pattern_unit=_UNIT,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
)
