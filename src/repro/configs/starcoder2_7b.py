"""StarCoder2-7B: GQA kv=4, RoPE, biased projections, GELU MLP
[arXiv:2402.19173]."""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab=49152,
    head_dim=128,
    use_bias=True,
    mlp_type="gelu",
    rope_theta=1_000_000.0,
    pattern_unit=(LayerSpec("attn"),),
)
