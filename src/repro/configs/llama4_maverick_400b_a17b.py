"""Llama-4-Maverick 400B-A17B: MoE 128e top-1 + shared expert, early fusion
[hf:meta-llama/Llama-4 family; unverified].

Maverick interleaves dense and MoE FFN layers (every=2) and adds a shared
expert on MoE layers; active params ~17B per token.
"""
from repro.configs.base import ArchConfig, LayerSpec, MoECfg

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    head_dim=128,
    qk_norm=True,
    mlp_type="swiglu",
    rope_theta=500_000.0,
    moe=MoECfg(n_experts=128, top_k=1, d_expert=8192, shared_expert=True,
               every=2),
    pattern_unit=(LayerSpec("attn", moe=False), LayerSpec("attn", moe=True)),
)
