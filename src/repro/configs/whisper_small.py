"""Whisper-small enc-dec backbone [arXiv:2212.04356].

[audio]: the conv/mel frontend is a STUB — ``input_specs()`` provides
precomputed frame embeddings (1500 x d_model) to the encoder.  12 encoder +
12 decoder layers, MHA (kv == heads), GELU, biases, learned positions
(modeled as RoPE-free absolute embeddings).
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,            # decoder layers
    encoder_layers=12,
    encoder_seq=1500,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    head_dim=64,
    use_bias=True,
    mlp_type="gelu",
    pattern_unit=(LayerSpec("attn"),),
)
