"""AdamW with global-norm clipping, cosine schedule, ZeRO-sharded states.

Optimizer state shardings mirror the parameter shardings (which under FSDP
are already fully sharded = ZeRO-3); for non-FSDP runs ``zero1_specs``
additionally spreads the f32 m/v/master states over the data axis (ZeRO-1),
the standard memory lever at scale.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import ShardingCtx


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step):
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * \
        (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.minimum(warm, 1.0) * jnp.where(s < cfg.warmup_steps,
                                                       1.0, cos)


def init_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def abstract_state(abstract_params):
    f = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {
        "step": jax.ShapeDtypeStruct((), jnp.int32),
        "m": jax.tree.map(f, abstract_params),
        "v": jax.tree.map(f, abstract_params),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, params, grads, state):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_state = {"step": step,
                 "m": tdef.unflatten([o[1] for o in out]),
                 "v": tdef.unflatten([o[2] for o in out])}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}


def zero1_specs(param_specs_tree, abstract_params_tree, ctx: ShardingCtx):
    """Optimizer-state specs: params' specs + data-axis sharding (ZeRO-1).

    For each state leaf, if the param spec leaves a divisible dim free the
    data axis is added there; FSDP params are already fully sharded and
    keep their spec.
    """
    if not ctx.batch_axes:
        axis = None
    else:
        axis = ctx.batch_axes[-1]

    def f(spec, p):
        if axis is None or ctx.fsdp_axis is not None:
            return spec
        size = ctx.mesh.shape[axis]
        dims = list(spec) + [None] * (len(p.shape) - len(spec))
        for i, n in enumerate(p.shape):
            if dims[i] is None and n % size == 0 and n >= size:
                dims[i] = axis
                break
        return P(*dims)

    state_spec = jax.tree.map(f, param_specs_tree, abstract_params_tree,
                              is_leaf=lambda x: isinstance(x, P))
    return {"step": P(), "m": state_spec, "v": state_spec}
