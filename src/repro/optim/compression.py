"""Error-feedback gradient compression for the cross-pod (DCN) axis.

TPU analogue of the paper's transport adaptivity (§5.1: fine-grained
zero-copy over NVLink vs coarsened staged puts over InfiniBand): intra-pod
gradient reductions ride ICI uncompressed, while the slow pod axis can use
int8 quantization (4x fewer DCN bytes) or top-k sparsification, both with
error feedback so the compression bias is corrected over steps.

All functions are per-tensor and run inside a ``shard_map`` manual over the
``pod`` axis (see launch/steps.py); the collective itself is an all-gather
of the compressed payload + local reduction, so the HLO collective bytes
shrink measurably — verified in the multi-pod §Perf entries.

The int8 scale/quantize/dequant primitives are shared with the compressed
halo path (:mod:`repro.core.wire` — one implementation, both wires): the
scale is taken over finite entries only and nonfinite entries quantize
to 0, so a single NaN gradient element can no longer poison the whole
tensor's dequant through ``max(|g|)``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.wire import int8_dequantize, int8_encode


def ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _int8_reduce(g, axis: str):
    """Quantize to int8, all-gather over the pod axis, dequant + mean."""
    q, scale, err = int8_encode(g)
    qs = lax.all_gather(q, axis)                       # int8 on the wire
    ss = lax.all_gather(scale, axis)
    deq = int8_dequantize(qs, ss.reshape((-1,) + (1,) * (qs.ndim - 1)))
    out = jnp.mean(deq, axis=0)
    return out, err


def _topk_reduce(g, axis: str, frac: float):
    """Keep the top-|frac| fraction by magnitude; EF holds the rest."""
    flat = g.reshape(-1)
    n = flat.shape[0]
    k = max(1, int(n * frac))
    vals, idx = lax.top_k(jnp.abs(flat), k)
    sel = flat[idx]
    vg = lax.all_gather(sel, axis)                     # f32 values (k each)
    ig = lax.all_gather(idx, axis)                     # s32 indices
    npods = vg.shape[0]
    acc = jnp.zeros((n,), jnp.float32)
    for p in range(npods):                             # npods is tiny (2)
        acc = acc.at[ig[p]].add(vg[p], mode="drop")
    out = (acc / npods).reshape(g.shape)
    err = flat.at[idx].set(0.0).reshape(g.shape)
    return out, err


def compressed_pod_mean(grads, ef_state, mode: Optional[str],
                        axis: str = "pod", topk_frac: float = 0.02):
    """Mean-reduce grads over the pod axis with optional compression.

    Returns (reduced_grads, new_ef_state).  ``mode`` in
    {None, "int8", "topk"}.  With mode None this is a plain psum-mean and
    ef_state passes through.
    """
    if mode is None:
        return jax.tree.map(lambda g: lax.pmean(g, axis), grads), ef_state

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        if mode == "int8":
            out, err = _int8_reduce(gf, axis)
        elif mode == "topk":
            out, err = _topk_reduce(gf, axis, topk_frac)
        else:
            raise ValueError(mode)
        return out.astype(g.dtype), err

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(ef_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]))
