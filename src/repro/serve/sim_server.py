"""SimServer: continuous batching of many independent MD replicas.

One vmapped block program per bucketed ``(n_rows, n_atoms)`` shape stacks
replica lanes of the existing device-local MD block bodies
(``MDEngine.local_programs``) under one ``shard_map``; replicas are
admitted into free rows and retired from finished ones at block
boundaries, so churn never recompiles — the ``serve/compiles`` counter
(incremented inside the to-be-jitted body, i.e. once per trace) equals
the number of distinct shapes ever touched.

Isolation is bitwise, not approximate: a lane's trajectory equals a solo
:class:`MDEngine` run of the same replica (same seed, same bucket box)
element-for-element, regardless of co-residents, admission order, or
neighbor retirement.  Three ingredients make that hold (proven by
``tests/test_serve_md.py``):

* every replica of an atom bucket shares the bucket's canonical box
  (``make_grappa_like(n, box_atoms=bucket)``) and hence its cell layout;
* the sparse backend runs a *static worst-case tier ladder*
  (``static_ladder=True``): the exec schedule is data-independent, and
  sentinel rows are physics-inert, so lanes never couple through shapes;
* the per-cycle order replicates the solo driver exactly — retire →
  admit → rebin (+ prune) → block — with retirement reads happening
  post-block, where the solo run's final state also sits.

Fault handling is per-lane: the engines' ``health`` observer (bitwise
neutral) reports per-step non-finite counts per lane; a poisoned lane is
retired with a typed :class:`ReplicaFault` at the next boundary while
co-residents continue untouched.  Per-block deadlines reuse the LM
server's :class:`WaveTimeout` / :class:`Watchdog` spine, and
replica-step accounting reuses its ``masked_tokens`` helper (useful
steps = the requested budget, never the padded block multiple).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import ensure_barrier_batching, shard_map_norep
from repro.core.md.domain import AXES
from repro.core.md.engine import MDEngine
from repro.core.md.pair_schedule import SLOT_QUANTUM
from repro.core.md.schedule_opt import tier_plan
from repro.core.md.system import MDSystem
from repro.launch.mesh import make_mesh
from repro.obs import MetricsRegistry
from repro.resilience.faults import ResilienceError, WaveTimeout
from repro.resilience.policy import Watchdog
from repro.runtime.serve_loop import masked_tokens
from repro.serve.buckets import BucketLadder
from repro.serve.scheduler import (
    DONE, FAILED, PREEMPTED, SimScheduler, TERMINAL)

__all__ = ["SimServer", "ReplicaHandle", "ReplicaFault"]


class ReplicaFault(ResilienceError):
    """A replica's trajectory went non-finite inside a batch.

    Raised *to the owning handle only*: the lane is quarantined and
    retired at the next block boundary; co-resident replicas in the same
    bucket keep running bitwise-unchanged.
    """


@dataclasses.dataclass
class _Programs:
    """Compiled batch programs for one shape (cached across reopens)."""

    blk: object
    reb: object
    prune: Optional[object]            # None for the dense backend


@dataclasses.dataclass
class _Runtime:
    """Live device state for one open table."""

    shape: Tuple[int, int]
    cell_f: object                     # (R, gz, gy, gx, K, 7)
    cell_i: object                     # (R, gz, gy, gx, K, 2)


class ReplicaHandle:
    """Client view of one submitted replica: poll / result / cancel."""

    def __init__(self, server: "SimServer", rid: int):
        self._server = server
        self.rid = rid

    @property
    def status(self) -> str:
        return self._server.scheduler.records[self.rid].status

    def poll(self) -> dict:
        rec = self._server.scheduler.records[self.rid]
        return {"status": rec.status, "steps_done": rec.steps_done,
                "budget_steps": rec.budget_steps,
                "requested_steps": rec.requested_steps,
                "shape": rec.shape, "row": rec.row}

    def result(self, wait: bool = True) -> Optional[dict]:
        """The replica's read-out state.  Blocks (serving other replicas
        too) until this replica is terminal when ``wait``.  Raises the
        quarantine error for a FAILED replica; returns ``None`` for one
        cancelled before admission."""
        if wait:
            self._server.drain(until=self.rid)
        rec = self._server.scheduler.records[self.rid]
        if rec.status not in TERMINAL:
            raise RuntimeError(
                f"replica {self.rid} still {rec.status}; pass wait=True")
        if rec.status == FAILED:
            raise rec.error
        return self._server._results.get(self.rid)

    def cancel(self) -> str:
        return self._server.scheduler.cancel(self.rid)


class SimServer:
    """Continuous-batching server over bucketed vmapped MD programs.

    ``mesh`` is either the engine's ``(z, y, x)`` mesh (replica rows live
    on one shard set) or a 4-axis ``(rep, z, y, x)`` mesh whose leading
    axis shards replica rows across devices; row rungs must then divide
    by the ``rep`` extent.  ``engine_kwargs`` pass through to the
    per-atom-bucket template engines (``force_backend``, ``pipeline``,
    ...); ``system_kwargs`` to the canonical bucket systems (density,
    cutoff, ...) — submitted replicas must share the bucket box, i.e. be
    built with ``box_atoms=<atom bucket>`` and the same ``nstlist``.
    """

    def __init__(self, mesh=None, ladder: Optional[BucketLadder] = None,
                 *, block_steps: int = 10,
                 engine_kwargs: Optional[dict] = None,
                 system_kwargs: Optional[dict] = None,
                 wave_timeout_s: Optional[float] = None,
                 watchdog: Optional[Watchdog] = None,
                 obs: Optional[MetricsRegistry] = None):
        if not ensure_barrier_batching():
            raise RuntimeError(
                "this jax exposes no optimization_barrier batching hook; "
                "vmapped MD blocks are unavailable")
        self.mesh = mesh if mesh is not None else make_mesh((1, 1, 1), AXES)
        names = tuple(self.mesh.axis_names)
        if names == AXES:
            self.rep_axis = None
            self._tmpl_mesh = self.mesh
        elif len(names) == 4 and names[1:] == AXES:
            self.rep_axis = names[0]
            # template engines only donate their device-local bodies and
            # layout; park them on a minimal single-device (z,y,x) mesh
            self._tmpl_mesh = make_mesh((1, 1, 1), AXES)
        else:
            raise ValueError(
                f"mesh axes must be {AXES} or ('rep', *{AXES}); got {names}")
        self._row_spec = P(self.rep_axis, *AXES)
        self._lane_spec = P(self.rep_axis)
        self.ladder = ladder or BucketLadder()
        self.block_steps = int(block_steps)
        self.scheduler = SimScheduler(self.ladder, self.block_steps)
        self.engine_kwargs = dict(engine_kwargs or {})
        for k in ("layout_atoms", "health", "static_ladder", "nstprune"):
            if k in self.engine_kwargs:
                raise ValueError(f"engine_kwargs[{k!r}] is server-managed")
        self.system_kwargs = dict(system_kwargs or {})
        self.wave_timeout_s = wave_timeout_s
        self.watchdog = watchdog
        # a private registry by default: serve counters (especially the
        # compile-count contract) must not alias across servers in one
        # process; pass obs=default_registry() to publish globally
        self.obs = obs if obs is not None else MetricsRegistry()
        self._templates: Dict[int, MDEngine] = {}
        self._programs: Dict[Tuple[int, int], _Programs] = {}
        self._runtimes: Dict[Tuple[int, int], _Runtime] = {}
        self._pending_rows: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        self._handles: Dict[int, ReplicaHandle] = {}
        self._results: Dict[int, dict] = {}
        self._blocks = 0
        self._serve_wall_s = 0.0
        self._step_walls: List[float] = []

    # ---- templates & programs ---------------------------------------------

    def _template(self, atoms: int) -> MDEngine:
        """Per-atom-bucket template engine: owns the canonical box, cell
        layout, and device-local block bodies every lane of the bucket
        reuses.  Its own (solo) compiled programs are never invoked."""
        if atoms not in self._templates:
            from repro.core.md.system import make_grappa_like
            sys_kw = dict(self.system_kwargs)
            sys_kw.setdefault("nstlist", self.block_steps)
            if sys_kw["nstlist"] != self.block_steps:
                raise ValueError("system nstlist must equal block_steps")
            tmpl_sys = make_grappa_like(atoms, seed=0, **sys_kw)
            kw = dict(self.engine_kwargs)
            fb = kw.get("force_backend", "dense")
            self._templates[atoms] = MDEngine(
                tmpl_sys, self._tmpl_mesh, health=True,
                static_ladder=(fb != "dense"), **kw)
        return self._templates[atoms]

    def _build_programs(self, shape: Tuple[int, int]) -> _Programs:
        if shape in self._programs:
            return self._programs[shape]
        _rows, atoms = shape
        tmpl = self._template(atoms)
        lp = tmpl.local_programs
        spec, lspec = self._row_spec, self._lane_spec
        nst = self.block_steps
        counter = self.obs.counter("serve/compiles")
        if tmpl.force_backend != "dense":
            M = tmpl.pair_schedule.n_pairs
            L = tmpl.pair_schedule.levels
            K = tmpl.layout.capacity
            # static worst-case ladder: every lane, every block runs the
            # same (M, K) tier — data-independent shapes, inert sentinels
            tiers = tier_plan([M] * L, tmpl.pair_bucket, M,
                              SLOT_QUANTUM, K)

            def body(cf, ci, force, sel):
                counter.inc()          # trace-time only: 1 per compile
                return lp["block_sched"](cf, ci, force, sel, nst, tiers, ())

            blk = jax.jit(shard_map_norep(
                jax.vmap(body), mesh=self.mesh, in_specs=(spec,) * 4,
                out_specs=(spec, spec, spec, lspec, lspec)))
            prune = jax.jit(shard_map_norep(
                jax.vmap(lp["prune"]), mesh=self.mesh,
                in_specs=(spec, spec),
                out_specs=(spec, lspec, lspec, lspec)))
        else:
            def body(cf, ci, force):
                counter.inc()          # trace-time only: 1 per compile
                return lp["block"](cf, ci, force, nst)

            blk = jax.jit(shard_map_norep(
                jax.vmap(body), mesh=self.mesh, in_specs=(spec,) * 3,
                out_specs=(spec, spec, spec, lspec)))
            prune = None
        reb = jax.jit(shard_map_norep(
            jax.vmap(lp["rebin"]), mesh=self.mesh, in_specs=(spec, spec),
            out_specs=(spec, spec, spec, lspec)))
        self._programs[shape] = _Programs(blk=blk, reb=reb, prune=prune)
        return self._programs[shape]

    def _ensure_runtime(self, shape: Tuple[int, int]) -> _Runtime:
        if shape in self._runtimes:
            return self._runtimes[shape]
        rows, atoms = shape
        if self.rep_axis is not None:
            rep = self.mesh.shape[self.rep_axis]
            if rows % rep:
                raise ValueError(
                    f"row bucket {rows} does not divide across "
                    f"{self.rep_axis}={rep}; pick row_buckets that do")
        tmpl = self._template(atoms)
        G, K = tmpl.layout.global_cells, tmpl.layout.capacity
        dtype = tmpl.system.pos.dtype
        shard = NamedSharding(self.mesh, self._row_spec)
        cf = jax.device_put(
            jnp.zeros((rows, G[0], G[1], G[2], K, 7), dtype), shard)
        ci = jax.device_put(
            jnp.full((rows, G[0], G[1], G[2], K, 2), -1, jnp.int32), shard)
        self._build_programs(shape)
        self._runtimes[shape] = _Runtime(shape=shape, cell_f=cf, cell_i=ci)
        return self._runtimes[shape]

    # ---- client API --------------------------------------------------------

    def submit(self, system: MDSystem, n_steps: int,
               state: Optional[Tuple[np.ndarray, np.ndarray]] = None
               ) -> ReplicaHandle:
        """Queue a replica for ``n_steps`` (rounded up to whole blocks).

        ``state`` resumes a previously evacuated replica from its cell
        arrays instead of binning ``system`` fresh (the device-loss
        readmission path)."""
        atoms = self.ladder.atom_bucket_for(system.n_atoms)
        tmpl = self._template(atoms)
        if not np.array_equal(np.asarray(system.box),
                              np.asarray(tmpl.system.box)):
            raise ValueError(
                f"replica box {system.box} != bucket-{atoms} box "
                f"{tmpl.system.box}; build replicas with box_atoms={atoms}")
        if system.params.nstlist != self.block_steps:
            raise ValueError(
                f"replica nstlist={system.params.nstlist} != server "
                f"block_steps={self.block_steps}")
        rid = self.scheduler.submit(system.n_atoms, n_steps)
        if state is None:
            rows = tmpl.bin_host(system)
        else:
            cf_row, ci_row = state
            want = tmpl.layout.global_cells + (tmpl.layout.capacity,)
            if tuple(cf_row.shape[:-1]) != want:
                raise ValueError(
                    f"resume state shape {cf_row.shape} does not match "
                    f"bucket-{atoms} cells {want}")
            rows = (np.asarray(cf_row), np.asarray(ci_row))
        self._pending_rows[rid] = rows
        self._handles[rid] = ReplicaHandle(self, rid)
        return self._handles[rid]

    def run_cycle(self) -> bool:
        """One boundary + block round across every live table: retire ←
        (previous cycle) → admit → rebin (+prune) → block → quarantine →
        retire.  Returns True while work remains."""
        # retire replicas flagged since the last block (client cancels):
        # they must not run another block's physics.  Budget- and
        # fault-retirements already happened post-block, where the
        # read-out state is the solo run's final state.
        for shape in self.scheduler.live_shapes():
            self._retire_due(shape)
        for adm in self.scheduler.tick():
            rt = self._ensure_runtime(adm.shape)
            cf_row, ci_row = self._pending_rows.pop(adm.rid)
            rt.cell_f = rt.cell_f.at[adm.row].set(jnp.asarray(cf_row))
            rt.cell_i = rt.cell_i.at[adm.row].set(jnp.asarray(ci_row))
        for shape in self.scheduler.live_shapes():
            self._dispatch_block(shape)
        return self.scheduler.pending() > 0

    def drain(self, until: Optional[int] = None) -> None:
        """Serve until the queue is empty (or replica ``until`` is
        terminal) — every cycle makes progress, so this terminates."""
        while self.scheduler.pending() > 0:
            if until is not None and \
                    self.scheduler.records[until].status in TERMINAL:
                return
            self.run_cycle()

    def evacuate(self) -> List[Tuple[ReplicaHandle, dict]]:
        """Retire every *resident* replica as PREEMPTED, returning their
        portable snapshots (host cell arrays + remaining budget) for
        readmission via ``submit(..., state=...)`` on a rebuilt server —
        the device-loss shrink path.  Queued replicas stay queued."""
        out = []
        for shape in list(self.scheduler.live_shapes()):
            rt = self._runtimes[shape]
            for row, rid in list(self.scheduler.occupants(shape)):
                rec = self.scheduler.records[rid]
                self._read_out(rt, rec)
                snap = dict(self._results[rid])
                snap["remaining_steps"] = \
                    rec.budget_steps - rec.steps_done
                self.scheduler.release(rid, status=PREEMPTED)
                self._clear_row(rt, row)
                out.append((self._handles[rid], snap))
        return out

    def stats(self) -> dict:
        """Serving summary: throughput, latency percentiles, compiles."""
        walls = np.asarray(self._step_walls, np.float64)
        c = self.obs.counter
        done = c("serve/replicas_done").value
        return {
            "replicas_done": done,
            "replicas_failed": c("serve/replicas_failed").value,
            "blocks": self._blocks,
            "compiles": c("serve/compiles").value,
            "shapes_touched": sorted(self.scheduler.shapes_touched),
            "useful_steps": c("serve/useful_steps").value,
            "wall_s": self._serve_wall_s,
            "replicas_per_s": done / max(self._serve_wall_s, 1e-9),
            "step_latency_p50_ms": float(np.percentile(walls, 50) * 1e3)
            if walls.size else 0.0,
            "step_latency_p99_ms": float(np.percentile(walls, 99) * 1e3)
            if walls.size else 0.0,
        }

    # ---- block dispatch ----------------------------------------------------

    def _dispatch_block(self, shape: Tuple[int, int]) -> None:
        rt = self._runtimes[shape]
        progs = self._programs[shape]
        t0 = time.time()
        cf, ci, force, _diag = progs.reb(rt.cell_f, rt.cell_i)
        if progs.prune is not None:
            sel, _cum, _cum_in, _occ = progs.prune(cf, ci)
            cf, ci, _fl, metrics, _ovf = progs.blk(cf, ci, force, sel)
        else:
            cf, ci, _fl, metrics = progs.blk(cf, ci, force)
        jax.block_until_ready(ci)
        dt = time.time() - t0
        rt.cell_f, rt.cell_i = cf, ci
        self._blocks += 1
        self._serve_wall_s += dt
        self._step_walls.append(dt / self.block_steps)
        self.obs.counter("serve/blocks").inc()
        self.obs.histogram("serve/block_s").observe(dt)
        self.obs.gauge(f"serve/occupancy/{shape[0]}x{shape[1]}").set(
            self.scheduler.occupancy(shape))
        if self.watchdog is not None:
            self.watchdog.observe(self._blocks - 1, dt)
        if self.wave_timeout_s is not None and dt > self.wave_timeout_s:
            raise WaveTimeout(
                f"bucket {shape[0]}x{shape[1]} block exceeded "
                f"{self.wave_timeout_s:.3f}s ({dt:.3f}s elapsed)")
        self.scheduler.advance(shape)
        # per-lane quarantine: the health observer is bitwise-neutral,
        # so reading it never perturbs co-residents
        bad = np.asarray(jax.device_get(metrics["health/nonfinite"]))
        bad = bad.reshape(shape[0], -1).sum(axis=1)
        for row, rid in self.scheduler.occupants(shape):
            if bad[row]:
                self.scheduler.mark_fault(rid, ReplicaFault(
                    f"replica {rid} went non-finite in bucket "
                    f"{shape[0]}x{shape[1]} row {row} "
                    f"({int(bad[row])} bad step-values); lane quarantined"))
        self._retire_due(shape)

    def _retire_due(self, shape: Tuple[int, int]) -> None:
        rt = self._runtimes[shape]
        for rid in self.scheduler.finished(shape):
            rec = self.scheduler.records[rid]
            self._read_out(rt, rec)
            row = rec.row
            rec = self.scheduler.release(rid)
            self._clear_row(rt, row)
            if rec.status == DONE:
                self.obs.counter("serve/replicas_done").inc()
                # reuse the LM wave-accounting mask: useful work is the
                # requested budget, not the padded block multiple
                self.obs.counter("serve/useful_steps").inc(masked_tokens(
                    [rec.steps_done], [rec.requested_steps]))
            elif rec.status == FAILED:
                self.obs.counter("serve/replicas_failed").inc()

    def _read_out(self, rt: _Runtime, rec) -> None:
        cf_row = np.asarray(jax.device_get(rt.cell_f[rec.row]))
        ci_row = np.asarray(jax.device_get(rt.cell_i[rec.row]))
        self._results[rec.rid] = {
            "cell_f": cf_row, "cell_i": ci_row,
            "steps": rec.steps_done,
            "requested_steps": rec.requested_steps,
            "atoms": _export_row(cf_row, ci_row, rec.n_atoms),
        }

    def _clear_row(self, rt: _Runtime, row: int) -> None:
        # a cleared row is physics-inert: no valid ids, zero occupancy —
        # rebin migrates nothing, forces see no atoms
        rt.cell_f = rt.cell_f.at[row].set(0.0)
        rt.cell_i = rt.cell_i.at[row].set(-1)


def _export_row(cf_row: np.ndarray, ci_row: np.ndarray,
                n_atoms: int) -> dict:
    """Per-atom positions/velocities in global-id order for one lane
    (the lane-local analogue of ``MDEngine.export_atoms``)."""
    ids = ci_row[..., 0].reshape(-1)
    valid = ids >= 0
    pos = np.zeros((n_atoms, 3), cf_row.dtype)
    vel = np.zeros((n_atoms, 3), cf_row.dtype)
    pos[ids[valid]] = cf_row[..., 0:3].reshape(-1, 3)[valid]
    vel[ids[valid]] = cf_row[..., 4:7].reshape(-1, 3)[valid]
    return {"pos": pos, "vel": vel}
