"""Bucketed batch shapes for the SimServer.

A *bucket* is a compiled batch shape ``(n_rows, n_atoms)``: a vmapped MD
block program over ``n_rows`` replica lanes, each lane sized for the
bucket's canonical ``n_atoms`` box.  The ladder quantises both axes the
way aphrodite-engine's ``_BATCH_SIZES_TO_CAPTURE`` quantises CUDA-graph
batch sizes: admission picks the smallest rung that fits, so the set of
shapes ever compiled is bounded by ``len(row_buckets) *
len(atom_buckets)`` no matter how replicas churn.

The atom rung fixes the *box* (every replica of an atom bucket is built
with ``make_grappa_like(n, box_atoms=bucket)`` and therefore shares the
bucket's cell layout bitwise); the row rung fixes the vmap width.  Row
choice is padding-waste-aware: a table opens with the smallest rung
covering the queue at that instant rather than the deepest one, so two
queued replicas never pay for a 16-lane program.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

DEFAULT_ROW_BUCKETS: Tuple[int, ...] = (1, 2, 4, 8, 16)
DEFAULT_ATOM_BUCKETS: Tuple[int, ...] = (192, 256)


@dataclasses.dataclass(frozen=True)
class Bucket:
    """One compiled batch shape: ``n_rows`` replica lanes of ``n_atoms``."""

    n_rows: int
    n_atoms: int

    @property
    def key(self) -> Tuple[int, int]:
        return (self.n_rows, self.n_atoms)

    def __str__(self) -> str:  # metric/label form: "4x256"
        return f"{self.n_rows}x{self.n_atoms}"


@dataclasses.dataclass(frozen=True)
class BucketLadder:
    """The quantisation grid admission draws shapes from."""

    row_buckets: Tuple[int, ...] = DEFAULT_ROW_BUCKETS
    atom_buckets: Tuple[int, ...] = DEFAULT_ATOM_BUCKETS

    def __post_init__(self):
        for name, rungs in (("row_buckets", self.row_buckets),
                            ("atom_buckets", self.atom_buckets)):
            if not rungs or list(rungs) != sorted(set(rungs)) or \
                    min(rungs) < 1:
                raise ValueError(
                    f"{name} must be ascending, unique, positive: {rungs}")

    @property
    def n_buckets(self) -> int:
        """Upper bound on distinct compiled shapes."""
        return len(self.row_buckets) * len(self.atom_buckets)

    def atom_bucket_for(self, n_atoms: int) -> int:
        """Smallest atom rung holding ``n_atoms`` (the replica's box)."""
        for b in self.atom_buckets:
            if n_atoms <= b:
                return b
        raise ValueError(
            f"replica of {n_atoms} atoms exceeds the largest atom bucket "
            f"{self.atom_buckets[-1]}")

    def rows_for(self, demand: int) -> int:
        """Smallest row rung covering ``demand`` lanes (clamped to the
        deepest rung — excess demand queues rather than widening)."""
        for b in self.row_buckets:
            if demand <= b:
                return b
        return self.row_buckets[-1]

    def bucket_for(self, demand: int, n_atoms: int) -> Bucket:
        return Bucket(self.rows_for(max(demand, 1)),
                      self.atom_bucket_for(n_atoms))


def padding_waste(bucket: Bucket, resident_atoms) -> float:
    """Fraction of the bucket's atom-lane area carrying no physics.

    ``resident_atoms`` are the per-occupied-row replica sizes; empty rows
    count as fully wasted.  The scheduler reports this per live table so
    the occupancy gauge reflects *useful* work, not just filled rows.
    """
    total = bucket.n_rows * bucket.n_atoms
    used = sum(int(a) for a in resident_atoms)
    return 1.0 - used / total if total else 0.0
