"""SimScheduler: host-side admission/retirement bookkeeping.

Pure Python — no jax — so the admission-churn property suite can drive
thousands of random arrival/retirement sequences without compiling
anything.  The :class:`~repro.serve.sim_server.SimServer` owns the device
arrays and compiled programs; the scheduler owns everything decidable on
the host:

* FIFO queues per atom bucket (submission order is admission order);
* the live *tables* — one per open batch shape, at most one per atom
  bucket — with per-row occupancy;
* padding-waste-aware shape choice (a table opens at the smallest row
  rung covering the queue, via :meth:`BucketLadder.rows_for`);
* per-replica step budgets (rounded up to whole blocks — the block
  program is the admission/retirement quantum) and fault flags;
* the set of shapes ever opened, which the compile-count contract bounds
  by ``ladder.n_buckets``.

Invariants the property suite locks (see ``tests/test_sim_scheduler.py``):
every admitted replica fits its bucket; admission within an atom bucket
is FIFO (no starvation); ``shapes_touched ⊆`` the ladder grid; a
finished/faulted/cancelled replica's row is free again by the next
boundary (`release` precedes the next `tick`).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.serve.buckets import Bucket, BucketLadder, padding_waste

# replica lifecycle
QUEUED = "queued"
RUNNING = "running"
DONE = "done"
CANCELLED = "cancelled"
FAILED = "failed"
PREEMPTED = "preempted"      # evacuated (device loss) — resubmittable

TERMINAL = frozenset({DONE, CANCELLED, FAILED, PREEMPTED})


@dataclasses.dataclass
class ReplicaRecord:
    """Everything the host knows about one replica."""

    rid: int
    n_atoms: int
    requested_steps: int
    budget_steps: int               # requested rounded up to whole blocks
    atom_bucket: int
    status: str = QUEUED
    steps_done: int = 0
    shape: Optional[Tuple[int, int]] = None   # (rows, atoms) while RUNNING
    row: Optional[int] = None
    error: Optional[BaseException] = None
    cancel_flag: bool = False
    fault: Optional[BaseException] = None


@dataclasses.dataclass(frozen=True)
class Admission:
    """One row assignment decided at a boundary."""

    shape: Tuple[int, int]
    row: int
    rid: int


class SimScheduler:
    def __init__(self, ladder: Optional[BucketLadder] = None,
                 block_steps: int = 10):
        if block_steps < 1:
            raise ValueError("block_steps must be >= 1")
        self.ladder = ladder or BucketLadder()
        self.block_steps = int(block_steps)
        self.records: Dict[int, ReplicaRecord] = {}
        self.queues: Dict[int, List[int]] = {}        # atom bucket -> rids
        self.tables: Dict[Tuple[int, int], List[Optional[int]]] = {}
        self.shapes_touched: set = set()
        self._next_rid = 0

    # ---- client side -------------------------------------------------------

    def submit(self, n_atoms: int, n_steps: int) -> int:
        """Enqueue a replica; returns its id.  The step budget rounds up
        to a whole number of blocks (the admission quantum)."""
        if n_steps < 1:
            raise ValueError("n_steps must be >= 1")
        atoms = self.ladder.atom_bucket_for(n_atoms)
        blocks = -(-int(n_steps) // self.block_steps)
        rid = self._next_rid
        self._next_rid += 1
        self.records[rid] = ReplicaRecord(
            rid=rid, n_atoms=int(n_atoms), requested_steps=int(n_steps),
            budget_steps=blocks * self.block_steps, atom_bucket=atoms)
        self.queues.setdefault(atoms, []).append(rid)
        return rid

    def cancel(self, rid: int) -> str:
        """Cancel a replica: dequeued immediately while QUEUED, retired
        at the next boundary while RUNNING.  Returns the new status."""
        rec = self.records[rid]
        if rec.status == QUEUED:
            self.queues[rec.atom_bucket].remove(rid)
            rec.status = CANCELLED
        elif rec.status == RUNNING:
            rec.cancel_flag = True
        return rec.status

    # ---- boundary decisions ------------------------------------------------

    def tick(self) -> List[Admission]:
        """One boundary round of admissions, FIFO within each atom
        bucket.  Opens a table (smallest row rung covering the queue)
        for any atom bucket with demand and no live table."""
        out: List[Admission] = []
        for atoms in sorted(self.queues):
            q = self.queues[atoms]
            if not q:
                continue
            shape = self._table_for(atoms)
            if shape is None:
                b = self.ladder.bucket_for(len(q), atoms)
                shape = b.key
                self.tables[shape] = [None] * b.n_rows
                self.shapes_touched.add(shape)
            rows = self.tables[shape]
            for row, occ in enumerate(rows):
                if occ is not None or not q:
                    continue
                rid = q.pop(0)
                rec = self.records[rid]
                rec.status, rec.shape, rec.row = RUNNING, shape, row
                rows[row] = rid
                out.append(Admission(shape=shape, row=row, rid=rid))
        return out

    def _table_for(self, atoms: int) -> Optional[Tuple[int, int]]:
        for shape in self.tables:
            if shape[1] == atoms:
                return shape
        return None

    def live_shapes(self) -> List[Tuple[int, int]]:
        """Shapes with at least one occupied row, in stable order."""
        return [s for s, rows in self.tables.items()
                if any(r is not None for r in rows)]

    def occupants(self, shape: Tuple[int, int]) -> List[Tuple[int, int]]:
        return [(row, rid)
                for row, rid in enumerate(self.tables[shape])
                if rid is not None]

    def occupancy(self, shape: Tuple[int, int]) -> float:
        """Useful fraction of the table's atom-lane area (1 - padding)."""
        resident = [self.records[rid].n_atoms
                    for _, rid in self.occupants(shape)]
        return 1.0 - padding_waste(Bucket(*shape), resident)

    # ---- block accounting --------------------------------------------------

    def advance(self, shape: Tuple[int, int]) -> None:
        """Credit one block of steps to every resident replica."""
        for _, rid in self.occupants(shape):
            self.records[rid].steps_done += self.block_steps

    def mark_fault(self, rid: int, error: BaseException) -> None:
        """Quarantine flag: the replica retires (FAILED) at the next
        boundary; co-residents are untouched."""
        rec = self.records[rid]
        if rec.status == RUNNING and rec.fault is None:
            rec.fault = error

    def finished(self, shape: Tuple[int, int]) -> List[int]:
        """Residents due for retirement at this boundary: budget met,
        cancel requested, or faulted."""
        return [rid for _, rid in self.occupants(shape)
                if self.records[rid].steps_done >=
                self.records[rid].budget_steps
                or self.records[rid].cancel_flag
                or self.records[rid].fault is not None]

    def release(self, rid: int, status: Optional[str] = None,
                error: Optional[BaseException] = None) -> ReplicaRecord:
        """Free the replica's row (its state has been read out).  The
        table closes once empty with an empty queue, so a later burst
        can reopen the atom bucket at a better row rung."""
        rec = self.records[rid]
        if rec.status != RUNNING:
            raise ValueError(f"release of non-running replica {rid} "
                             f"({rec.status})")
        if status is None:
            status = (FAILED if rec.fault is not None
                      else CANCELLED if rec.cancel_flag else DONE)
        rec.status = status
        rec.error = error if error is not None else rec.fault
        rows = self.tables[rec.shape]
        rows[rec.row] = None
        if all(r is None for r in rows) and \
                not self.queues.get(rec.shape[1]):
            del self.tables[rec.shape]
        rec.shape = rec.row = None
        return rec

    # ---- introspection -----------------------------------------------------

    def pending(self) -> int:
        return sum(len(q) for q in self.queues.values()) + \
            sum(1 for rec in self.records.values()
                if rec.status == RUNNING)
