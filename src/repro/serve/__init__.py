"""Continuous batching of many MD replicas (the SimServer subsystem).

Client side::

    server = SimServer(mesh, BucketLadder(), block_steps=10,
                       engine_kwargs={"force_backend": "sparse"})
    h = server.submit(make_grappa_like(200, box_atoms=256, nstlist=10,
                                       seed=3), n_steps=40)
    out = h.result()          # bitwise == a solo MDEngine run

See :mod:`repro.serve.sim_server` for the isolation contract and
:mod:`repro.serve.scheduler` for the admission/retirement invariants.
"""
from repro.serve.buckets import (Bucket, BucketLadder, DEFAULT_ATOM_BUCKETS,
                                 DEFAULT_ROW_BUCKETS, padding_waste)
from repro.serve.scheduler import (Admission, CANCELLED, DONE, FAILED,
                                   PREEMPTED, QUEUED, RUNNING, ReplicaRecord,
                                   SimScheduler, TERMINAL)
from repro.serve.sim_server import ReplicaFault, ReplicaHandle, SimServer

__all__ = [
    "Bucket", "BucketLadder", "DEFAULT_ROW_BUCKETS", "DEFAULT_ATOM_BUCKETS",
    "padding_waste",
    "Admission", "ReplicaRecord", "SimScheduler",
    "QUEUED", "RUNNING", "DONE", "CANCELLED", "FAILED", "PREEMPTED",
    "TERMINAL",
    "SimServer", "ReplicaHandle", "ReplicaFault",
]
