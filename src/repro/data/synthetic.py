"""Deterministic synthetic LM data pipeline.

Properties a production loader must have, implemented here for the
synthetic stream:
  * deterministic as a function of (seed, step, host) — restart-safe,
  * per-host sharding (each host materializes only its batch slice),
  * checkpointable iterator state (a single step counter),
  * background prefetch with a bounded queue (double buffering).

Tokens follow a mixed unigram/copy process so cross-entropy training has
learnable structure (loss drops well below ln(vocab)).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    copy_period: int = 7        # repeated motif => learnable structure
    n_hosts: int = 1
    host_id: int = 0


def _batch_at(cfg: DataConfig, step: int) -> np.ndarray:
    """Host's slice of the global batch for ``step`` (B_host, L+1).

    Every ROW is seeded by (seed, step, global_row), so the concatenation
    of all hosts' slices is identical to the single-host batch no matter
    how many hosts share the work (host-count elasticity).
    """
    assert cfg.global_batch % cfg.n_hosts == 0
    b_host = cfg.global_batch // cfg.n_hosts
    L = cfg.seq_len + 1
    reps = -(-L // cfg.copy_period)
    rows = []
    for r in range(cfg.host_id * b_host, (cfg.host_id + 1) * b_host):
        rng = np.random.Generator(np.random.PCG64(
            np.random.SeedSequence([cfg.seed, step, r])))
        motif = rng.integers(0, cfg.vocab, size=(cfg.copy_period,))
        seq = np.tile(motif, reps)[:L]
        noise = rng.integers(0, cfg.vocab, size=(L,))
        mask = rng.random(L) < 0.15
        rows.append(np.where(mask, noise, seq))
    return np.stack(rows).astype(np.int32)


class SyntheticStream:
    """Checkpointable iterator with optional background prefetch."""

    def __init__(self, cfg: DataConfig, start_step: int = 0,
                 prefetch: int = 2):
        self.cfg = cfg
        self.step = start_step
        self.prefetch = prefetch
        self._q: Optional[queue.Queue] = None
        self._worker: Optional[threading.Thread] = None
        self._stop = threading.Event()
        if prefetch > 0:
            self._start_worker()

    # ---- iterator state (checkpointable) -----------------------------------

    def state(self) -> Dict:
        return {"step": self.step}

    @classmethod
    def from_state(cls, cfg: DataConfig, state: Dict, prefetch: int = 2):
        return cls(cfg, start_step=int(state["step"]), prefetch=prefetch)

    # ---- iteration -----------------------------------------------------------

    def _start_worker(self):
        self._q = queue.Queue(maxsize=self.prefetch)
        self._next_to_produce = self.step

        def work():
            while not self._stop.is_set():
                s = self._next_to_produce
                batch = _batch_at(self.cfg, s)
                self._next_to_produce = s + 1
                while not self._stop.is_set():
                    try:
                        self._q.put((s, batch), timeout=0.1)
                        break
                    except queue.Full:
                        continue

        self._worker = threading.Thread(target=work, daemon=True)
        self._worker.start()

    def next(self) -> np.ndarray:
        if self._q is None:
            batch = _batch_at(self.cfg, self.step)
            self.step += 1
            return batch
        s, batch = self._q.get()
        # a restore may have rewound the step counter: regenerate if the
        # prefetched element is stale
        while s != self.step:
            if s < self.step:
                s, batch = self._q.get()
            else:
                batch = _batch_at(self.cfg, self.step)
                s = self.step
        self.step += 1
        return batch

    def close(self):
        self._stop.set()
        if self._worker is not None:
            self._worker.join(timeout=1.0)
