"""Mesh construction for single-pod / multi-pod production runs.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then builds the mesh.
"""
from __future__ import annotations

from typing import Sequence

import math
from typing import Sequence

import numpy as np

import jax
from jax.sharding import Mesh

from repro.compat import mesh_axis_types


def make_mesh(shape: Sequence[int], axis_names: Sequence[str]) -> Mesh:
    """Mesh over the first prod(shape) devices, Auto axis types.

    Unlike ``jax.make_mesh`` this tolerates a device count larger than the
    mesh (the dry-run forces 512 host devices but the single-pod mesh uses
    256; tests use subsets of 8).
    """
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise ValueError(f"need {n} devices, have {len(devs)}")
    arr = np.asarray(devs[:n]).reshape(tuple(shape))
    return Mesh(arr, tuple(axis_names), **mesh_axis_types(len(axis_names)))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """The graded production mesh: 16x16 per pod, 2 pods multi-pod.

    Axes: ``data`` carries DP/FSDP/CP, ``model`` carries TP/EP, ``pod`` is
    the DCN dimension (slow links; collectives over it are coarsened and
    optionally compressed — the TPU analogue of the paper's
    NVLink-vs-InfiniBand transport adaptivity).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_md_mesh(n_devices: int | None = None, max_dims: int = 3) -> Mesh:
    """Factor the device count into a (Z, Y, X)-style DD mesh for MD.

    Mirrors GROMACS' automatic 1D -> 2D -> 3D domain-decomposition switch as
    rank count grows (paper §6.3): factors are peeled greedily so e.g.
    8 -> (2,2,2), 16 -> (4,2,2), 256 -> (8,8,4), 512 -> (16,8,4).
    """
    if n_devices is None:
        n_devices = len(jax.devices())
    dims = [1] * max_dims
    remaining = n_devices
    i = 0
    while remaining > 1:
        # peel the smallest prime factor onto the next axis (round robin)
        for f in range(2, remaining + 1):
            if remaining % f == 0:
                dims[i % max_dims] *= f
                remaining //= f
                break
        i += 1
    dims.sort(reverse=True)
    # Always return all three axes (sizes may be 1): the MD cell grid is 3-D
    # regardless of DD dimensionality, and size-1 axes degrade gracefully to
    # periodic self-exchange inside the halo code.
    return make_mesh(tuple(dims), ("z", "y", "x"))
