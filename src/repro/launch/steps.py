"""Step builders: jitted train / prefill / decode programs per (arch, shape).

This is the glue the dry-run, trainer and server all share:
  * ShardingCtx construction per shape (DP/FSDP/TP/CP axes),
  * input_specs() — ShapeDtypeStruct stand-ins for every model input,
  * make_train_step / make_prefill_step / make_decode_step with
    in/out shardings and donation wired for memory fit.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ArchConfig, ShapeCfg
from repro.models.registry import build_model
from repro.optim import adamw
from repro.optim.compression import compressed_pod_mean, ef_init
from repro.parallel.sharding import ShardingCtx

# FSDP when bf16 weights / TP-shard would exceed this per device
FSDP_BYTES_THRESHOLD = 2e9


def param_count(cfg: ArchConfig) -> int:
    """Analytic parameter count (exact for our param defs)."""
    from repro.models.layers import _flatten  # noqa
    model = build_model(cfg, _dummy_ctx())
    flat = _flatten(model.defs)
    return sum(int(np.prod(d.shape)) for d in flat.values())


def active_param_count(cfg: ArchConfig) -> int:
    """Per-token active params (MoE: top_k of n_experts per MoE layer)."""
    total = param_count(cfg)
    if cfg.moe is None:
        return total
    m = cfg.moe
    moe_layers = sum(1 for s in cfg.pattern_unit if s.moe) * cfg.n_units
    per_expert = 3 * cfg.d_model * m.d_expert
    inactive = moe_layers * per_expert * (m.n_experts - m.top_k)
    return total - inactive


def _dummy_ctx() -> ShardingCtx:
    from repro.launch.mesh import make_mesh
    return ShardingCtx(mesh=make_mesh((1, 1), ("data", "model")),
                       batch_axes=("data",))


def make_ctx(cfg: ArchConfig, shape: ShapeCfg, mesh: Mesh,
             fsdp: Optional[bool] = None) -> ShardingCtx:
    """Sharding context for one (arch, shape, mesh) cell."""
    axes = list(mesh.axis_names)
    batch_axes = tuple(a for a in axes if a in ("pod", "data"))
    dp = math.prod(mesh.shape[a] for a in batch_axes)
    seq_axes: Tuple[str, ...] = ()
    if shape.global_batch % dp != 0 or shape.global_batch < dp:
        # batch can't cover DP (long_500k B=1): context-shard the sequence
        batch_axes = ()
        seq_axes = tuple(a for a in axes if a in ("pod", "data"))
    if fsdp is None:
        n = param_count(cfg)
        fsdp = (2 * n / mesh.shape["model"]) > FSDP_BYTES_THRESHOLD
    fsdp_axis = "data" if (fsdp and "data" in axes) else None
    return ShardingCtx(mesh=mesh, batch_axes=batch_axes,
                       fsdp_axis=fsdp_axis, seq_axes=seq_axes)


def auto_microbatches(cfg: ArchConfig, shape: ShapeCfg, ctx: ShardingCtx,
                      budget_bytes: float = 4e9) -> int:
    """Grad-accumulation factor so saved layer inputs fit the budget."""
    dp = max(ctx.dp, 1)
    b_loc = max(shape.global_batch // dp, 1)
    per_mb = b_loc * shape.seq_len * cfg.d_model * 2 * cfg.n_layers
    mb = 1
    while per_mb / mb > budget_bytes and mb < b_loc:
        mb *= 2
    while b_loc % mb:
        mb //= 2
    return max(mb, 1)


# --------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# --------------------------------------------------------------------------

def batch_shapes(cfg: ArchConfig, shape: ShapeCfg) -> Dict[str, Any]:
    B, L = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        text = L
        out = {}
        if cfg.prefix_tokens:
            text = L - cfg.prefix_tokens
            out["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.prefix_tokens, cfg.d_model), jnp.bfloat16)
        if cfg.is_encdec:
            out["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        out["tokens"] = jax.ShapeDtypeStruct((B, text + 1), jnp.int32)
        return out
    if shape.kind == "prefill":
        text = L
        out = {}
        if cfg.prefix_tokens:
            text = L - cfg.prefix_tokens
            out["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.prefix_tokens, cfg.d_model), jnp.bfloat16)
        if cfg.is_encdec:
            out["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        out["tokens"] = jax.ShapeDtypeStruct((B, text), jnp.int32)
        return out
    # decode: one token + cache index
    return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def batch_specs(cfg: ArchConfig, shape: ShapeCfg, ctx: ShardingCtx):
    b = ctx.batch_spec()
    shapes = batch_shapes(cfg, shape)
    specs = {}
    for k, v in shapes.items():
        if k == "pos":
            specs[k] = P()
        elif k == "tokens":
            specs[k] = P(b, None)
        else:
            specs[k] = P(b, None, None)
    return shapes, specs


def input_specs(cfg: ArchConfig, shape: ShapeCfg, ctx: ShardingCtx):
    """All abstract inputs for the cell's step program, with shardings."""
    shapes, specs = batch_specs(cfg, shape, ctx)
    model = build_model(cfg, ctx)
    out = {"batch": (shapes, specs)}
    if shape.kind == "decode":
        cache = model.cache_shapes(shape.global_batch, shape.seq_len)
        out["cache"] = (cache, model.cache_specs())
    return out


# --------------------------------------------------------------------------
# step builders
# --------------------------------------------------------------------------

def _shardings(ctx: ShardingCtx, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(ctx.mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


@dataclasses.dataclass
class TrainProgram:
    step_fn: Any            # jitted (params, opt, batch) -> (params, opt, metrics)
    model: Any
    ctx: ShardingCtx
    param_shardings: Any
    opt_shardings: Any
    batch_shardings: Any
    abstract_params: Any
    abstract_opt: Any
    microbatches: int


def make_train_step(cfg: ArchConfig, shape: ShapeCfg, ctx: ShardingCtx,
                    ocfg: Optional[adamw.AdamWConfig] = None,
                    microbatches: Optional[int] = None,
                    pod_compress: Optional[str] = None,
                    moe_dispatch: str = "fused",
                    zero2: bool = False,
                    donate: bool = True) -> TrainProgram:
    """``zero2``: constrain gradients to the ZeRO-sharded layout before the
    optimizer, turning the data-axis gradient all-reduce into a
    reduce-scatter (each device only reduces the shard its optimizer
    states own); GSPMD all-gathers the updated params afterwards in bf16.
    """
    ocfg = ocfg or adamw.AdamWConfig()
    has_pod_pre = "pod" in ctx.mesh.axis_names and pod_compress is not None
    if has_pod_pre:
        # the grad computation runs inside a shard_map MANUAL over 'pod';
        # activation constraints inside must not name the manual axis
        ctx = dataclasses.replace(
            ctx, batch_axes=tuple(a for a in ctx.batch_axes
                                  if a != "pod"))
    model = build_model(cfg, ctx, moe_dispatch=moe_dispatch)
    mb = microbatches or auto_microbatches(cfg, shape, ctx)
    b_shapes, b_specs = batch_specs(cfg, shape, ctx)

    has_pod = "pod" in ctx.mesh.axis_names and pod_compress is not None

    grad_specs = None
    if zero2:
        grad_specs = adamw.zero1_specs(model.specs(), model.abstract(),
                                       ctx)["m"]

    def shard_grads(g):
        if grad_specs is None:
            return g
        return jax.tree.map(
            lambda x, sp: lax.with_sharding_constraint(
                x, NamedSharding(ctx.mesh, sp)),
            g, grad_specs, is_leaf=lambda x: not isinstance(x, dict))

    def grads_of(params, batch):
        def loss(p, b):
            l, m = model.loss_fn(p, b)
            return l, m
        if mb == 1:
            (l, m), g = jax.value_and_grad(loss, has_aux=True)(params, batch)
            return g, l, m
        split = jax.tree.map(
            lambda x: x.reshape((mb, x.shape[0] // mb) + x.shape[1:]), batch)

        def body(carry, mb_batch):
            gacc, lacc = carry
            (l, m), g = jax.value_and_grad(loss, has_aux=True)(params,
                                                               mb_batch)
            gacc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), gacc, g)
            # under zero2 the f32 accumulator stays ZeRO-sharded: each
            # microbatch's grads reduce-scatter into it instead of living
            # replicated (accumulator bytes /dp)
            gacc = shard_grads(gacc)
            return (gacc, lacc + l), m

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        g0 = shard_grads(g0)
        (g, lsum), ms = lax.scan(body, (g0, jnp.zeros((), jnp.float32)),
                                 split)
        g = jax.tree.map(lambda x: x / mb, g)
        m = jax.tree.map(lambda x: x[-1], ms)
        return g, lsum / mb, m

    if has_pod:
        # manual over the pod axis: per-pod grads -> compressed DCN
        # reduction with error feedback (see optim/compression.py)
        def train_step(params, opt_state, ef, batch):
            def pod_body(params, batch, ef):
                g, l, m = grads_of(params, batch)
                g, ef = compressed_pod_mean(g, ef, pod_compress)
                l = lax.pmean(l, "pod")
                return g, ef, l, m
            g, ef, l, m = shard_map(
                pod_body, mesh=ctx.mesh,
                in_specs=(P(), P("pod"), P()),
                out_specs=(P(), P(), P(), P()),
                axis_names={"pod"}, check_vma=False)(params, batch, ef)
            g = shard_grads(g)
            params, opt_state, om = adamw.update(ocfg, params, g, opt_state)
            m = dict(m, loss=l, **om)
            return params, opt_state, ef, m
    else:
        def train_step(params, opt_state, batch):
            g, l, m = grads_of(params, batch)
            g = shard_grads(g)
            params, opt_state, om = adamw.update(ocfg, params, g, opt_state)
            m = dict(m, loss=l, **om)
            return params, opt_state, m

    p_specs = model.specs()
    p_shard = _shardings(ctx, p_specs)
    o_specs = adamw.zero1_specs(p_specs, model.abstract(), ctx)
    o_shard = _shardings(ctx, o_specs)
    b_shard = _shardings(ctx, b_specs)
    in_sh = (p_shard, o_shard) + ((p_shard,) if has_pod else ()) + (b_shard,)
    out_sh = (p_shard, o_shard) + ((p_shard,) if has_pod else ()) + \
        (NamedSharding(ctx.mesh, P()),)
    donate_n = (0, 1, 2) if has_pod else (0, 1)
    fn = jax.jit(train_step, in_shardings=in_sh, out_shardings=out_sh,
                 donate_argnums=donate_n if donate else ())
    return TrainProgram(step_fn=fn, model=model, ctx=ctx,
                        param_shardings=p_shard, opt_shardings=o_shard,
                        batch_shardings=b_shard,
                        abstract_params=model.abstract(),
                        abstract_opt=adamw.abstract_state(model.abstract()),
                        microbatches=mb)


def make_prefill_step(cfg: ArchConfig, shape: ShapeCfg, ctx: ShardingCtx,
                      moe_dispatch: str = "fused"):
    # inference serves bf16 weights: FSDP gathers then move bf16, not f32
    cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
    model = build_model(cfg, ctx, moe_dispatch=moe_dispatch)
    b_shapes, b_specs = batch_specs(cfg, shape, ctx)

    def prefill(params, batch):
        logits, _ = model.prefill(params, batch)
        return logits

    p_shard = _shardings(ctx, model.specs())
    b_shard = _shardings(ctx, b_specs)
    fn = jax.jit(prefill, in_shardings=(p_shard, b_shard),
                 out_shardings=NamedSharding(
                     ctx.mesh, P(ctx.batch_spec(), None)))
    return fn, model, (p_shard, b_shard)


def make_decode_step(cfg: ArchConfig, shape: ShapeCfg, ctx: ShardingCtx,
                     moe_dispatch: str = "fused", donate: bool = True):
    # inference serves bf16 weights: FSDP gathers then move bf16, not f32
    cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
    model = build_model(cfg, ctx, moe_dispatch=moe_dispatch)
    b = ctx.batch_spec()

    def decode(params, token, pos, cache):
        logits, new_cache = model.decode_step(params, token, pos, cache)
        return logits, new_cache

    p_shard = _shardings(ctx, model.specs())
    c_shard = _shardings(ctx, model.cache_specs())
    tok_shard = NamedSharding(ctx.mesh, P(b, None))
    pos_shard = NamedSharding(ctx.mesh, P())
    fn = jax.jit(
        decode,
        in_shardings=(p_shard, tok_shard, pos_shard, c_shard),
        out_shardings=(NamedSharding(ctx.mesh, P(b, None)), c_shard),
        donate_argnums=(3,) if donate else ())
    return fn, model, (p_shard, tok_shard, pos_shard, c_shard)
