"""Serving launcher: batched LM waves, or continuous MD batching.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
      --requests 16 --batch 4 --new-tokens 16

  PYTHONPATH=src python -m repro.launch.serve --md \
      --replicas 16 --atoms 200 --steps 40 --backend dense
"""
from __future__ import annotations

import argparse

import numpy as np

import jax

from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.parallel.sharding import ShardingCtx
from repro.runtime.serve_loop import BatchServer, Request, throughput_stats


def main_md(args):
    """Continuous batching of MD replicas (the SimServer subsystem)."""
    from repro.core.md.domain import AXES
    from repro.core.md.system import make_grappa_like
    from repro.launch.mesh import make_mesh as mk
    from repro.serve import BucketLadder, SimServer

    mesh = mk((1, 1, 1), AXES)
    ladder = BucketLadder()
    server = SimServer(mesh, ladder, block_steps=args.nstlist,
                       engine_kwargs={"force_backend": args.backend})
    bucket = ladder.atom_bucket_for(args.atoms)
    handles = [server.submit(
        make_grappa_like(args.atoms, seed=i, nstlist=args.nstlist,
                         box_atoms=bucket), args.steps)
        for i in range(args.replicas)]
    server.drain()
    stats = server.stats()
    print(f"served {stats['replicas_done']} replicas "
          f"({stats['useful_steps']} useful steps) in "
          f"{stats['wall_s']:.3f}s -> {stats['replicas_per_s']:.2f} "
          f"replicas/s; {stats['compiles']} compiles over shapes "
          f"{stats['shapes_touched']}; step latency "
          f"p50={stats['step_latency_p50_ms']:.3f}ms "
          f"p99={stats['step_latency_p99_ms']:.3f}ms")
    assert all(h.status == "done" for h in handles)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--md", action="store_true",
                    help="serve MD replicas (SimServer) instead of LM waves")
    ap.add_argument("--replicas", type=int, default=8)
    ap.add_argument("--atoms", type=int, default=200)
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--nstlist", type=int, default=10)
    ap.add_argument("--backend", default="dense",
                    choices=("dense", "sparse"))
    args = ap.parse_args()
    if args.md:
        return main_md(args)
    if args.arch is None:
        ap.error("--arch is required unless --md")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduce()
    mesh = make_mesh((1, 1), ("data", "model"))
    ctx = ShardingCtx(mesh=mesh, batch_axes=("data",))
    model = build_model(cfg, ctx)
    params = model.init(jax.random.PRNGKey(0))
    server = BatchServer(model, params, batch_size=args.batch,
                         max_len=args.max_len,
                         temperature=args.temperature)

    rng = np.random.RandomState(0)
    pending = [Request(prompt=rng.randint(0, cfg.vocab,
                                          size=(args.prompt_len,))
                       .astype(np.int32),
                       max_new_tokens=args.new_tokens)
               for _ in range(args.requests)]
    done = []
    wave = 0
    while pending:
        take, pending = pending[:args.batch], pending[args.batch:]
        out = server.serve_wave(take)
        stats = throughput_stats(out)
        print(f"wave {wave}: {len(take)} requests, "
              f"{stats['tokens']} tokens, {stats['tok_per_s']:.1f} tok/s")
        done.extend(out)
        wave += 1
    print(f"served {len(done)} requests; sample output: "
          f"{done[0].out_tokens.tolist()}")


if __name__ == "__main__":
    main()
