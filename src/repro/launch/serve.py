"""Serving launcher: batched waves of synthetic requests.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
      --requests 16 --batch 4 --new-tokens 16
"""
from __future__ import annotations

import argparse

import numpy as np

import jax

from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.parallel.sharding import ShardingCtx
from repro.runtime.serve_loop import BatchServer, Request, throughput_stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduce()
    mesh = make_mesh((1, 1), ("data", "model"))
    ctx = ShardingCtx(mesh=mesh, batch_axes=("data",))
    model = build_model(cfg, ctx)
    params = model.init(jax.random.PRNGKey(0))
    server = BatchServer(model, params, batch_size=args.batch,
                         max_len=args.max_len,
                         temperature=args.temperature)

    rng = np.random.RandomState(0)
    pending = [Request(prompt=rng.randint(0, cfg.vocab,
                                          size=(args.prompt_len,))
                       .astype(np.int32),
                       max_new_tokens=args.new_tokens)
               for _ in range(args.requests)]
    done = []
    wave = 0
    while pending:
        take, pending = pending[:args.batch], pending[args.batch:]
        out = server.serve_wave(take)
        stats = throughput_stats(out)
        print(f"wave {wave}: {len(take)} requests, "
              f"{stats['tokens']} tokens, {stats['tok_per_s']:.1f} tok/s")
        done.extend(out)
        wave += 1
    print(f"served {len(done)} requests; sample output: "
          f"{done[0].out_tokens.tolist()}")


if __name__ == "__main__":
    main()
