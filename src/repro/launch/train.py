"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
      --steps 200 --batch 8 --seq 64 --ckpt-dir /tmp/run1

Runs the production train step (grad accumulation, ZeRO states, remat,
checkpoint/resume, straggler watchdog) on whatever devices exist; pass
--reduced for the CPU-sized smoke config.  On the production mesh this is
the same code path the dry-run lowers for 256/512 chips.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import SHAPES, get_config
from repro.data.synthetic import DataConfig
from repro.launch.mesh import make_mesh
from repro.launch.steps import make_ctx, make_train_step
from repro.optim import adamw
from repro.runtime.train_loop import TrainLoopConfig, Watchdog, run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--moe-dispatch", default="fused")
    ap.add_argument("--data-vocab", type=int, default=None)
    ap.add_argument("--copy-period", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduce()
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=args.seq,
                                global_batch=args.batch)
    n_dev = len(jax.devices())
    mesh = make_mesh((n_dev, 1) if n_dev > 1 else (1, 1),
                     ("data", "model"))
    ctx = make_ctx(cfg, shape, mesh, fsdp=False)
    prog = make_train_step(
        cfg, shape, ctx,
        ocfg=adamw.AdamWConfig(lr=args.lr, warmup_steps=10,
                               total_steps=args.steps),
        microbatches=args.microbatches, moe_dispatch=args.moe_dispatch,
        donate=False)
    print(f"arch={cfg.name} params on mesh {dict(mesh.shape)} "
          f"microbatches={prog.microbatches}")

    data_cfg = DataConfig(vocab=args.data_vocab or cfg.vocab,
                          seq_len=args.seq, global_batch=args.batch,
                          seed=0, copy_period=args.copy_period)
    loop = TrainLoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                           ckpt_every=args.ckpt_every)
    model = prog.model
    wd = Watchdog(on_straggler=lambda s, dt, ew: print(
        f"[watchdog] step {s} took {dt:.2f}s (ewma {ew:.2f}s)"))
    params, opt, hist = run_training(
        loop, prog, data_cfg, lambda: model.init(jax.random.PRNGKey(0)),
        watchdog=wd)
    print(f"done: final loss {hist[-1]['loss']:.4f} over "
          f"{len(hist)} steps this run")


if __name__ == "__main__":
    main()
