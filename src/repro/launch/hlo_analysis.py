"""Trip-count-aware HLO cost model for the roofline analysis.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified in this
container: a 10-iteration scan of a 256x256 matmul reports 33.5 MF, the
unrolled version 335 MF).  Our models scan over layer units, q/kv blocks and
SSM chunks, so we parse the optimized HLO instead and propagate each while
op's ``known_trip_count`` into a per-computation multiplier:

  * FLOPs        — dot/convolution ops (2 * out_elems * contracted_elems)
  * HBM bytes    — operand + result bytes of top-level ops (fusion internals
                   excluded: a fusion's traffic is its operands/results)
  * collectives  — operand bytes of all-gather / all-reduce / reduce-scatter
                   / all-to-all / collective-permute (+ async -start forms)

The parser is validated against cost_analysis() on loop-free programs and
against analytic 6ND estimates in tests/test_hlo_analysis.py.

All figures are PER DEVICE (the SPMD module is already partitioned), so
roofline terms divide by per-chip peaks directly.
"""
from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Dict, List, Optional, Tuple

# v5e-like hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# HBM-traffic model: count operand/result bytes only at likely fusion
# boundaries.  The CPU backend leaves long elementwise chains unfused that
# XLA:TPU would fuse into single HBM round-trips; counting every top-level
# op overstates traffic ~5-10x.  This whitelist approximates TPU fusion:
# contractions, data movement, reductions and collectives are boundaries,
# pure elementwise/broadcast/compare/convert ops are assumed fused.
_MEMORY_OPS = frozenset({
    "dot", "convolution", "fusion", "custom-call", "reduce",
    "reduce-window", "scatter", "gather", "sort", "dynamic-slice",
    "dynamic-update-slice", "concatenate", "pad", "transpose",
    "select-and-scatter",
    # NOT counted: "copy" — on CPU HLO these are SSA/tuple bookkeeping of
    # while carries (a copy of a loop-carried tuple "moves" every param
    # byte; on TPU these are aliased no-ops)
    *COLLECTIVE_OPS, *(c + "-start" for c in COLLECTIVE_OPS),
})

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s*([\w\-]+)\(")
_COMP_START_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_CALLED_RE = re.compile(
    r"(?:body|condition|to_apply|calls)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _shape_bytes_elems(type_str: str) -> Tuple[int, int]:
    """(bytes, elems) for a possibly-tuple HLO type string."""
    total_b = 0
    total_e = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        elems = 1
        if dims:
            for d in dims.split(","):
                elems *= int(d)
        total_b += elems * _DTYPE_BYTES[dt]
        total_e += elems
    return total_b, total_e


def _first_shape_dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    type_str: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    is_entry: bool


_COMMENT_RE = re.compile(r"/\*.*?\*/")


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    for line in text.splitlines():
        # tuple types embed /*index=N*/ comments whose '=' breaks parsing
        if "/*" in line:
            line = _COMMENT_RE.sub("", line)
        if current is None:
            m = _COMP_START_RE.match(line)
            if m and line.rstrip().endswith("{"):
                current = Computation(name=m.group(2), ops=[],
                                      is_entry=bool(m.group(1)))
            continue
        if line.startswith("}"):
            comps[current.name] = current
            current = None
            continue
        m = _OP_RE.match(line)
        if m:
            current.ops.append(Op(name=m.group(1), type_str=m.group(2),
                                  opcode=m.group(3), line=line))
    if current is not None:
        comps[current.name] = current
    return comps


def _multipliers(comps: Dict[str, Computation]) -> Dict[str, float]:
    """Propagate while trip counts down the call graph."""
    mult: Dict[str, float] = {c.name: 0.0 for c in comps.values()}
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return {k: 1.0 for k in mult}
    mult[entry.name] = 1.0
    # topological-ish: iterate to fixpoint (call graphs here are shallow)
    for _ in range(len(comps) + 2):
        changed = False
        for comp in comps.values():
            m0 = mult.get(comp.name, 0.0)
            if m0 == 0.0:
                continue
            for op in comp.ops:
                called = _CALLED_RE.findall(op.line)
                br = _BRANCHES_RE.search(op.line)
                if br:
                    called += [c.strip().lstrip("%")
                               for c in br.group(1).split(",") if c.strip()]
                if not called:
                    continue
                trip = 1.0
                if op.opcode == "while":
                    t = _TRIP_RE.search(op.line)
                    trip = float(t.group(1)) if t else 1.0
                for cname in called:
                    if cname not in mult:
                        continue
                    new = m0 * trip
                    if new > mult[cname]:
                        mult[cname] = new
                        changed = True
        if not changed:
            break
    return {k: max(v, 0.0) for k, v in mult.items()}


def _fusion_bodies(comps: Dict[str, Computation]) -> set:
    """Computations called via fusion/call ops (their bytes don't count)."""
    out = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode in ("fusion", "call", "reduce", "reduce-window",
                             "scatter", "sort", "map", "select-and-scatter"):
                for cname in _CALLED_RE.findall(op.line):
                    out.add(cname)
    return out


def _dot_flops(op: Op, shapes: Dict[str, str]) -> float:
    _, out_elems = _shape_bytes_elems(op.type_str)
    cm = _LHS_CONTRACT_RE.search(op.line)
    operands = _operands(op)
    if not operands:
        return 0.0
    lhs_dims = _first_shape_dims(shapes.get(operands[0], ""))
    if lhs_dims is None:
        return 0.0
    contract = 1
    if cm and cm.group(1):
        for d in cm.group(1).split(","):
            di = int(d)
            if di < len(lhs_dims):
                contract *= lhs_dims[di]
    return 2.0 * out_elems * contract


def _conv_flops(op: Op, shapes: Dict[str, str]) -> float:
    _, out_elems = _shape_bytes_elems(op.type_str)
    operands = _operands(op)
    if len(operands) < 2:
        return 0.0
    k_dims = _first_shape_dims(shapes.get(operands[1], ""))
    if not k_dims:
        return 0.0
    # approximate: kernel elems / output-feature dim
    k_elems = math.prod(k_dims)
    out_feat = max(k_dims[-1], 1)
    return 2.0 * out_elems * (k_elems / out_feat)


def _operands(op: Op) -> List[str]:
    """Operand names: %refs inside the op's parens before attributes."""
    start = op.line.find(op.opcode + "(")
    if start < 0:
        return []
    seg = op.line[start + len(op.opcode) + 1:]
    depth = 1
    out = []
    for i, ch in enumerate(seg):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                seg = seg[:i]
                break
    return _OPERAND_RE.findall(seg)


def analyze(hlo_text: str) -> Dict:
    comps = parse_hlo(hlo_text)
    mult = _multipliers(comps)
    fusion_bodies = _fusion_bodies(comps)

    flops = 0.0
    bytes_accessed = 0.0
    coll_bytes = 0.0
    coll_detail: Dict[str, Dict[str, float]] = {}
    unknown_trips = 0

    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m <= 0:
            continue
        shapes = {op.name: op.type_str for op in comp.ops}
        in_fusion = comp.name in fusion_bodies
        for op in comp.ops:
            oc = op.opcode
            if oc == "while" and "known_trip_count" not in op.line:
                unknown_trips += 1
            if oc == "dot":
                flops += m * _dot_flops(op, shapes)
            elif oc == "convolution":
                flops += m * _conv_flops(op, shapes)
            base = oc[:-6] if oc.endswith("-start") else oc
            if base in COLLECTIVE_OPS:
                ob = sum(_shape_bytes_elems(shapes.get(o, ""))[0]
                         for o in _operands(op))
                coll_bytes += m * ob
                d = coll_detail.setdefault(base, {"bytes": 0.0, "count": 0})
                d["bytes"] += m * ob
                d["count"] += m
            if not in_fusion and oc in _MEMORY_OPS:
                # producer-side accounting: each materialized tensor is
                # written once and (assumed) read once downstream; counting
                # operands as well would re-count every tensor per consumer
                # in the CPU backend's long chains of small kLoop fusions.
                out_b, _ = _shape_bytes_elems(op.type_str)
                bytes_accessed += m * 2 * out_b

    return {
        "flops": flops,
        "bytes": bytes_accessed,
        "collective_bytes": coll_bytes,
        "collectives": coll_detail,
        "unknown_trip_whiles": unknown_trips,
        "n_computations": len(comps),
    }


def roofline_terms(parsed: Dict, model_flops_per_device: float = 0.0,
                   analytic_bytes: float = 0.0) -> Dict:
    """Three roofline terms in seconds (per-device figures / per-chip peaks).

    ``memory_s`` derives from the parsed HLO (pessimistic: CPU-backend
    fusion granularity); ``memory_lb_s`` is the analytic lower bound
    (params + optimizer + activations + caches touched once).  The true
    TPU traffic lies between them; both are recorded.
    """
    ct = parsed["flops"] / PEAK_FLOPS
    mt = parsed["bytes"] / HBM_BW
    lt = parsed["collective_bytes"] / ICI_BW
    dom = max((("compute", ct), ("memory", mt), ("collective", lt)),
              key=lambda kv: kv[1])[0]
    out = {
        "compute_s": ct,
        "memory_s": mt,
        "collective_s": lt,
        "dominant": dom,
        "bound_s": max(ct, mt, lt),
    }
    if analytic_bytes:
        out["memory_lb_s"] = analytic_bytes / HBM_BW
        out["dominant_analytic"] = max(
            (("compute", ct), ("memory", out["memory_lb_s"]),
             ("collective", lt)), key=lambda kv: kv[1])[0]
        out["bound_lb_s"] = max(ct, out["memory_lb_s"], lt)
    if model_flops_per_device:
        out["model_flops_per_device"] = model_flops_per_device
        out["useful_flops_ratio"] = (model_flops_per_device /
                                     parsed["flops"]) if parsed["flops"] \
            else 0.0
        out["roofline_fraction"] = (model_flops_per_device / PEAK_FLOPS) / \
            out["bound_s"] if out["bound_s"] else 0.0
        if analytic_bytes:
            out["roofline_fraction_analytic"] = \
                (model_flops_per_device / PEAK_FLOPS) / out["bound_lb_s"] \
                if out["bound_lb_s"] else 0.0
    return out


def analytic_memory_bytes(n_params_stored: float, n_params_active: float,
                          tokens_local: float, d_model: int, n_layers: int,
                          kind: str, opt_bytes_per_param: float = 8.0,
                          cache_bytes_local: float = 0.0) -> float:
    """Per-device HBM-traffic lower bound for one step.

    train: weights read (fwd+bwd) + grad write + optimizer state r/w +
    activations written+read once per layer boundary (remat recompute adds
    ~0.5x).  prefill/decode: weights once + cache traffic + activations.
    """
    act = tokens_local * d_model * 2.0 * n_layers
    if kind == "train":
        w = n_params_stored * (2 + 2 + 4)          # bf16 fwd+bwd, f32 grad w
        o = n_params_stored * opt_bytes_per_param * 2
        return w + o + act * 3.0 + cache_bytes_local
    w = n_params_active * 2.0
    return w + act * 2.0 + cache_bytes_local * 2.0
