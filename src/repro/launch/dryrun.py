import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the production mesh ((16,16) or (2,16,16)) and ShardingCtx,
  2. lowers + compiles the step program from ShapeDtypeStruct inputs
     (no allocation),
  3. prints compiled.memory_analysis() (proves it fits) and
     cost_analysis() (XLA's own FLOPs/bytes),
  4. parses the optimized HLO with trip-count multipliers
     (launch/hlo_analysis.py) and derives the three roofline terms,
  5. writes results/dryrun/<arch>__<shape>__<mesh><tag>.json.

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both] [--force]
  python -m repro.launch.dryrun --halo                 # HaloPlan cells
  python -m repro.launch.dryrun --md --force-backend sparse
                                  # MD force-engine cells (prune ratio)
  python -m repro.launch.dryrun --summarize   # markdown table from JSONs
"""
import argparse
import dataclasses
import json
import traceback
from pathlib import Path

import jax
import numpy as np

from repro import compat
from repro.obs import default_registry
from repro.obs import span as obs_span
from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (
    active_param_count,
    batch_specs,
    make_ctx,
    make_decode_step,
    make_prefill_step,
    make_train_step,
    param_count,
)

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides=None, tag: str = ""):
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides.get("cfg", {}))
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = make_ctx(cfg, shape, mesh,
                   fsdp=(overrides or {}).get("fsdp"))
    kw = {}
    for k in ("moe_dispatch", "zero2"):
        if overrides and k in overrides:
            kw[k] = overrides[k]

    with compat.set_mesh(mesh):
        if shape.kind == "train":
            prog = make_train_step(cfg, shape, ctx,
                                   microbatches=(overrides or {})
                                   .get("microbatches"),
                                   pod_compress=(overrides or {})
                                   .get("pod_compress"), **kw)
            args = (prog.abstract_params, prog.abstract_opt)
            if "pod" in mesh.axis_names and \
                    (overrides or {}).get("pod_compress"):
                args = args + (prog.abstract_params,)   # EF state
            bshapes, _ = batch_specs(cfg, shape, ctx)
            args = args + (bshapes,)
            lowered = prog.step_fn.lower(*args)
            extra = {"microbatches": prog.microbatches}
        elif shape.kind == "prefill":
            kw.pop("zero2", None)
            fn, model, _ = make_prefill_step(cfg, shape, ctx, **kw)
            bshapes, _ = batch_specs(cfg, shape, ctx)
            lowered = fn.lower(model.abstract(), bshapes)
            extra = {}
        else:
            kw.pop("zero2", None)
            fn, model, _ = make_decode_step(cfg, shape, ctx, **kw)
            bshapes, _ = batch_specs(cfg, shape, ctx)
            cache = model.cache_shapes(shape.global_batch, shape.seq_len)
            lowered = fn.lower(model.abstract(), bshapes["tokens"],
                               bshapes["pos"], cache)
            extra = {}
    return lowered, cfg, shape, ctx, extra


def run_cell(arch: str, shape_name: str, multi_pod: bool, overrides=None,
             tag: str = "", verbose: bool = True):
    mesh_name = "multi" if multi_pod else "single"
    reg = default_registry()
    sp_cell = None
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "tag": tag, "ok": False}
    try:
      with obs_span("dryrun/cell", reg, arch=arch, shape=shape_name,
                    mesh=mesh_name) as sp_cell:
        cfg = get_config(arch)
        shape = SHAPES[shape_name]
        ok, why = shape_applicable(cfg, shape)
        if not ok:
            record.update({"skipped": why, "ok": True})
            return record
        with obs_span("dryrun/lower", reg) as sp_lower:
            lowered, cfg, shape, ctx, extra = lower_cell(
                arch, shape_name, multi_pod, overrides, tag)
        with obs_span("dryrun/compile", reg) as sp_compile:
            compiled = lowered.compile()

        mem = compiled.memory_analysis()
        mem_d = {k: int(getattr(mem, k)) for k in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")}
        cost = compat.cost_analysis(compiled)
        cost_d = {k: float(v) for k, v in cost.items()
                  if isinstance(v, (int, float)) and
                  k in ("flops", "bytes accessed")}
        parsed = hlo_analysis.analyze(compiled.as_text())

        chips = int(np.prod([lowered._lowering.compile_args[
            "num_partitions"]])) if False else \
            len(jax.devices()[:512 if multi_pod else 256])
        n_act = active_param_count(cfg)
        n_tot = param_count(cfg)
        tokens = shape.global_batch * (
            shape.seq_len if shape.kind == "train" else
            (shape.seq_len if shape.kind == "prefill" else 1))
        factor = 6.0 if shape.kind == "train" else 2.0
        model_flops = factor * n_act * tokens
        chips = 512 if multi_pod else 256
        tp = 16
        dp = chips // tp
        # analytic per-device traffic lower bound (see hlo_analysis)
        cache_local = 0.0
        if shape.kind == "decode":
            n_attn = sum(1 for s in cfg.pattern_unit
                         if s.kind == "attn") * cfg.n_units \
                + (cfg.n_layers if cfg.is_encdec else 0)
            kv_eff = max(cfg.n_kv_heads, 1)
            cache_local = (shape.global_batch * shape.seq_len * kv_eff *
                           cfg.head_dim * 2 * 2 * max(n_attn, 1)) / chips
        analytic = hlo_analysis.analytic_memory_bytes(
            n_params_stored=n_tot / tp,           # per-device weight reads
            n_params_active=n_act / tp,
            tokens_local=tokens / max(dp, 1),
            d_model=cfg.d_model, n_layers=cfg.n_layers,
            kind=shape.kind,
            opt_bytes_per_param=8.0 * tp / chips,  # ZeRO: states /chips
            cache_bytes_local=cache_local)
        terms = hlo_analysis.roofline_terms(parsed, model_flops / chips,
                                            analytic_bytes=analytic)

        record.update({
            "ok": True,
            "lower_s": round(sp_lower.dur, 1),
            "compile_s": round(sp_compile.dur, 1),
            "memory": mem_d,
            "device_total_bytes": mem_d["argument_size_in_bytes"] +
            mem_d["output_size_in_bytes"] + mem_d["temp_size_in_bytes"] -
            mem_d["alias_size_in_bytes"],
            "cost_analysis": cost_d,
            "parsed": {k: v for k, v in parsed.items()},
            "params": param_count(cfg),
            "active_params": n_act,
            "model_flops": model_flops,
            "roofline": terms,
            **extra,
        })
        if verbose:
            print(f"  memory_analysis: {mem_d}")
            print(f"  cost_analysis:   {cost_d}")
            print(f"  parsed:          flops={parsed['flops']:.3e} "
                  f"bytes={parsed['bytes']:.3e} "
                  f"coll={parsed['collective_bytes']:.3e}")
            print(f"  roofline:        {terms}")
    except Exception as e:  # noqa
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(record["traceback"])
    finally:
        if sp_cell is not None and sp_cell.dur is not None:
            record["wall_s"] = round(sp_cell.dur, 1)
        jax.clear_caches()
    return record


def cell_path(arch, shape, mesh_name, tag=""):
    return RESULTS / f"{arch}__{shape}__{mesh_name}{tag}.json"


# ---- halo-plan cells (paper Fig. 5 analogue, compiled) -----------------------

HALO_DD = {"1d": (4, 1, 1), "2d": (4, 4, 1), "3d": (4, 4, 4)}
HALO_BACKENDS = ("serialized", "fused", "pallas", "signal")


def halo_cell_name(dd_name: str, backend: str, width: int = 1,
                   pulses: int = 1, pipeline: str = "off",
                   depth: int = 2, wire_dtype=None) -> str:
    name = f"halo__{dd_name}__{backend}"
    if width != 1:
        name += f"__w{width}"
    if pulses != 1:
        name += f"__p{pulses}"
    if pipeline != "off":
        name += f"__{pipeline}"
        if depth != 2:
            name += f"__d{depth}"
    if wire_dtype:
        name += f"__wd{wire_dtype}"
    return name


def run_halo_cell(dd_name: str, backend: str, local=(8, 8, 8), feat: int = 4,
                  width: int = 1, pulses: int = 1, pipeline: str = "off",
                  depth: int = 2, wire_dtype=None, verbose: bool = True):
    """Lower + compile one HaloPlan.fwd cell and record plan + HLO stats.

    The plan-reported byte/critical-path numbers are the canonical ones
    (results/make_tables.py reads them); the compiled-HLO collective bytes
    cross-check that XLA moves what the plan says it moves.  ``width`` /
    ``pulses`` select the width>1 multi-pulse schedules; ``pipeline`` /
    ``depth`` select the per-step overlap model recorded under
    ``overlap`` (the depth sweep makes the exposed-phase amortization of
    deeper in-flight windows measurable before real-mesh runs);
    ``wire_dtype`` selects a compressed payload format whose
    direction-aware byte accounting lands in ``plan_stats``.
    """
    from repro.core.halo_plan import HaloPlan, HaloSpec
    from repro.launch.mesh import make_mesh

    sp_cell = None
    record = {"kind": "halo", "dd": dd_name, "backend": backend,
              "local": list(local), "width": width, "pulses": pulses,
              "pipeline": pipeline, "pipeline_depth": depth,
              "wire_dtype": wire_dtype, "ok": False}
    try:
      with obs_span("dryrun/halo_cell", default_registry(), dd=dd_name,
                    backend=backend) as sp_cell:
        dd = HALO_DD[dd_name]
        mesh = make_mesh(dd, ("z", "y", "x"))
        # width 0 on non-decomposed dims: a 1D DD exchanges z-slabs only
        widths = tuple(width if n > 1 else 0 for n in dd)
        pulses_per_dim = tuple(pulses if w else 1 for w in widths)
        spec = HaloSpec(axis_names=("z", "y", "x"), widths=widths,
                        backend=backend, dtype="float32",
                        feature_elems=feat, pulses=pulses_per_dim,
                        wire_dtype=wire_dtype)
        plan = HaloPlan.build(spec, mesh)
        gshape = tuple(n * d for n, d in zip(local, dd)) + (feat,)
        arg = jax.ShapeDtypeStruct(gshape, np.float32)
        lowered = jax.jit(lambda a: plan.fwd(a)).lower(arg)
        compiled = lowered.compile()
        parsed = hlo_analysis.analyze(compiled.as_text())
        stats = plan.stats(local, pipeline=pipeline, depth=depth)
        record.update({
            "ok": True,
            "devices": int(np.prod(dd)),
            # latency + overlap models live inside plan_stats (single
            # source of truth; make_tables reads them from there)
            "plan_stats": stats,
            "hlo_collective_bytes": parsed["collective_bytes"],
            "hlo_bytes": parsed["bytes"],
        })
        if verbose:
            st = record["plan_stats"]
            print(f"  plan: total={st['total_bytes']} "
                  f"ser_crit={st['serialized_critical_bytes']} "
                  f"fused_crit={st['fused_critical_bytes']} "
                  f"exposed/step={st['exposed_phases_per_step']}")
            if wire_dtype:
                print(f"  wire: bytes={st['wire_bytes']} "
                      f"reduction={st['wire_reduction']:.2f}x")
            print(f"  hlo collective bytes: {parsed['collective_bytes']:.3e}")
    except Exception as e:  # noqa: BLE001
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(record["traceback"])
    finally:
        if sp_cell is not None and sp_cell.dur is not None:
            record["wall_s"] = round(sp_cell.dur, 1)
        jax.clear_caches()
    return record


def run_halo_cells(force: bool = False, width: int = 1, pulses: int = 1,
                   pipeline: str = "off", depth: int = 2, wire_dtype=None):
    RESULTS.mkdir(parents=True, exist_ok=True)
    for dd_name in HALO_DD:
        for backend in HALO_BACKENDS:
            name = halo_cell_name(dd_name, backend, width, pulses,
                                  pipeline, depth, wire_dtype)
            path = RESULTS / f"{name}.json"
            if path.exists() and not force:
                print(f"[skip] {path.name} exists")
                continue
            print(f"[halo] {dd_name} x {backend} w={width} p={pulses} "
                  f"pipeline={pipeline} depth={depth} "
                  f"wire={wire_dtype}", flush=True)
            rec = run_halo_cell(dd_name, backend, width=width,
                                pulses=pulses, pipeline=pipeline,
                                depth=depth, wire_dtype=wire_dtype)
            path.write_text(json.dumps(rec, indent=1))
            print(f"[done] {path.name}: {'OK' if rec['ok'] else 'FAIL'} "
                  f"({rec['wall_s']}s)", flush=True)


# ---- MD force-engine cells (pair-schedule backends on a live DD mesh) --------

def run_md_cell(force_backend: str = "dense", halo_backend: str = "fused",
                n_atoms: int = 800, steps: int = 6, dd=(2, 2, 2),
                pipeline: str = "off", depth: int = 2,
                overlap_rebin: bool = False, nstprune: int = 0,
                wire_dtype=None, verbose: bool = True):
    """Run a short DD simulation and record the chosen force backend, its
    prune ratio / evaluated-work accounting (tier ladders, rolling-prune
    columns), the occupancy-adjusted halo byte accounting
    (``bytes_index`` / ``useful_bytes``), and the overlap model at the
    engine's pipeline depth."""
    from repro.core.halo_plan import HaloSpec
    from repro.core.md import MDEngine, make_grappa_like
    from repro.launch.mesh import make_mesh

    sp_cell = None
    dd_name = f"{sum(1 for d in dd if d > 1)}d"
    record = {"kind": "mdforce", "dd": dd_name, "backend": halo_backend,
              "force_backend": force_backend, "pipeline": pipeline,
              "pipeline_depth": depth, "overlap_rebin": overlap_rebin,
              "nstprune": nstprune, "wire_dtype": wire_dtype,
              "n_atoms": n_atoms, "ok": False}
    try:
      with obs_span("dryrun/md_cell", default_registry(), dd=dd_name,
                    backend=halo_backend,
                    force_backend=force_backend) as sp_cell:
        mesh = make_mesh(dd, ("z", "y", "x"))
        system = make_grappa_like(n_atoms, seed=1)
        spec = HaloSpec(axis_names=("z", "y", "x"), widths=(1, 1, 1),
                        backend=halo_backend)
        eng = MDEngine(system, mesh, spec, pipeline=pipeline,
                       pipeline_depth=depth, overlap_rebin=overlap_rebin,
                       force_backend=force_backend, nstprune=nstprune,
                       wire_dtype=wire_dtype)
        _, metrics, diags = eng.simulate(steps)
        record.update({
            "ok": True,
            "devices": int(np.prod(dd)),
            "pair_stats": eng.pair_stats(),
            "halo_stats": {k: v for k, v in eng.halo_stats().items()
                           if k in ("total_bytes", "bytes_index",
                                    "useful_bytes", "occupancy",
                                    "wire_bytes", "wire_reduction",
                                    "wire_itemsize_fwd",
                                    "wire_itemsize_rev")},
            "overlap": eng.overlap_stats(),
            "pe_final": float(np.asarray(metrics["pe"])[-1]),
            "n_atoms_conserved": int(np.asarray(diags[-1]["n_atoms"]))
            == n_atoms,
        })
        if verbose:
            ps = record["pair_stats"]
            print(f"  force_backend={force_backend} "
                  f"prune_ratio={ps['prune_ratio']:.2f}x "
                  f"evaluated={ps['evaluated_slot_pairs']} "
                  f"(dense {ps['dense_slot_pairs']})")
    except Exception as e:  # noqa: BLE001
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(record["traceback"])
    finally:
        if sp_cell is not None and sp_cell.dur is not None:
            record["wall_s"] = round(sp_cell.dur, 1)
        jax.clear_caches()
    return record


def run_md_cells(force_backend: str, force: bool = False,
                 halo_backend: str = "fused", pipeline: str = "off",
                 depth: int = 2, overlap_rebin: bool = False,
                 nstprune: int = 0, wire_dtype=None):
    RESULTS.mkdir(parents=True, exist_ok=True)
    name = f"mdforce__3d__{halo_backend}__{force_backend}"
    if pipeline != "off":
        name += f"__{pipeline}"
        if depth != 2:
            name += f"__d{depth}"
    if overlap_rebin:
        name += "__or"
    if nstprune:
        name += f"__np{nstprune}"
    if wire_dtype:
        name += f"__wd{wire_dtype}"
    path = RESULTS / f"{name}.json"
    if path.exists() and not force:
        print(f"[skip] {path.name} exists")
        return
    print(f"[mdforce] 3d x {halo_backend} x force={force_backend} "
          f"pipeline={pipeline} depth={depth} "
          f"overlap_rebin={overlap_rebin} nstprune={nstprune} "
          f"wire={wire_dtype}", flush=True)
    rec = run_md_cell(force_backend=force_backend,
                      halo_backend=halo_backend, pipeline=pipeline,
                      depth=depth, overlap_rebin=overlap_rebin,
                      nstprune=nstprune, wire_dtype=wire_dtype)
    path.write_text(json.dumps(rec, indent=1))
    print(f"[done] {path.name}: {'OK' if rec['ok'] else 'FAIL'} "
          f"({rec['wall_s']}s)", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--summarize", action="store_true")
    ap.add_argument("--halo", action="store_true",
                    help="compile HaloPlan cells (results/dryrun/halo__*)")
    ap.add_argument("--md", action="store_true",
                    help="run MD force-engine cells "
                         "(results/dryrun/mdforce__*)")
    ap.add_argument("--force-backend", default="dense",
                    help="NB force engine for --md cells "
                         "(dense|sparse|pallas)")
    ap.add_argument("--halo-width", type=int, default=1,
                    help="halo width per decomposed dim for --halo cells")
    ap.add_argument("--halo-pulses", type=int, default=1,
                    help="pulses per dim (GROMACS two-pulse case: 2)")
    ap.add_argument("--pipeline", default="off",
                    choices=["off", "double_buffer"],
                    help="step-pipeline overlap model recorded with "
                         "--halo cells")
    ap.add_argument("--pipeline-depth", type=int, default=2,
                    help="in-flight window depth for the overlap model "
                         "(--halo) / the engine ring (--md)")
    ap.add_argument("--overlap-rebin", action="store_true",
                    help="fuse rebin/migration + prune into the --md "
                         "block program (GROMACS DLB analogue)")
    ap.add_argument("--nstprune", type=int, default=0,
                    help="rolling inner-prune cadence for --md cells "
                         "(dual pair list; 0 = outer list only)")
    ap.add_argument("--wire-dtype", default=None,
                    choices=["bfloat16", "float16", "int8_ef", "float32"],
                    help="compressed halo payload format for --halo/--md "
                         "cells (HaloSpec.wire_dtype)")
    ap.add_argument("--moe-dispatch", default=None)
    ap.add_argument("--pod-compress", default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--zero2", action="store_true")
    ap.add_argument("--mamba-dtype", default=None)
    ap.add_argument("--remat-policy", default=None)
    args = ap.parse_args()

    if args.summarize:
        summarize()
        return
    if args.halo:
        run_halo_cells(force=args.force, width=args.halo_width,
                       pulses=args.halo_pulses, pipeline=args.pipeline,
                       depth=args.pipeline_depth,
                       wire_dtype=args.wire_dtype)
        return
    if args.md:
        run_md_cells(force_backend=args.force_backend, force=args.force,
                     pipeline=args.pipeline, depth=args.pipeline_depth,
                     overlap_rebin=args.overlap_rebin,
                     nstprune=args.nstprune, wire_dtype=args.wire_dtype)
        return

    RESULTS.mkdir(parents=True, exist_ok=True)
    archs = ARCH_IDS if args.all or not args.arch else \
        [args.arch.replace("-", "_").replace(".", "_")]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    overrides = {}
    if args.moe_dispatch:
        overrides["moe_dispatch"] = args.moe_dispatch
    if args.pod_compress:
        overrides["pod_compress"] = args.pod_compress
    if args.microbatches:
        overrides["microbatches"] = args.microbatches
    if args.zero2:
        overrides["zero2"] = True
    if args.mamba_dtype:
        overrides.setdefault("cfg", {})["mamba_scan_dtype"] = \
            args.mamba_dtype
    if args.remat_policy:
        overrides.setdefault("cfg", {})["remat_policy"] = args.remat_policy

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "multi" if mp else "single"
                path = cell_path(arch, shape, mesh_name, args.tag)
                if path.exists() and not args.force:
                    print(f"[skip] {path.name} exists")
                    continue
                print(f"[cell] {arch} x {shape} x {mesh_name}", flush=True)
                rec = run_cell(arch, shape, mp, overrides or None, args.tag)
                path.write_text(json.dumps(rec, indent=1))
                status = "OK" if rec["ok"] else "FAIL"
                print(f"[done] {path.name}: {status} "
                      f"({rec['wall_s']}s)", flush=True)


def summarize():
    rows = []
    for p in sorted(RESULTS.glob("*.json")):
        r = json.loads(p.read_text())
        rows.append(r)
    print(f"| arch | shape | mesh | status | GB/dev | flops/dev | "
          f"coll B/dev | compute s | memory s | coll s | dominant | "
          f"roofline frac |")
    print("|" + "---|" * 12)
    for r in rows:
        if r.get("skipped"):
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                  f"{r['skipped']} |" + " |" * 8)
            continue
        if not r["ok"]:
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | FAIL "
                  f"{r.get('error', '')[:60]} |" + " |" * 8)
            continue
        t = r["roofline"]
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
              f"| {r['device_total_bytes'] / 1e9:.2f} "
              f"| {r['parsed']['flops']:.2e} "
              f"| {r['parsed']['collective_bytes']:.2e} "
              f"| {t['compute_s']:.2e} | {t['memory_s']:.2e} "
              f"| {t['collective_s']:.2e} | {t['dominant']} "
              f"| {t.get('roofline_fraction', 0):.3f} |")


if __name__ == "__main__":
    main()
