from repro.parallel.sharding import ShardingCtx, param_spec

__all__ = ["ShardingCtx", "param_spec"]
