"""Context parallelism: ring attention with fused (overlapped) KV pulses.

Long-context attention with the sequence sharded across a mesh axis is the
LM-side instance of the paper's halo problem: every query shard needs every
KV shard, and the KV blocks travel the ring exactly like DD pulses.

Two schedules, mirroring core/halo.py:

  * ``serialized`` — MPI-flavored: compute on the resident KV block, THEN
    rotate (an ``optimization_barrier`` forces the compute->comm ordering a
    host-driven schedule would impose).
  * ``fused``      — GPU/TPU-initiated flavor: the ppermute for step k+1 is
    issued concurrently with step k's attention compute (independent ops,
    XLA overlaps the collective-permute-start with the einsums) — the
    paper's pack/transmit/compute pipelining applied to KV pulses.

Both produce bitwise-comparable results (online-softmax merge), tested in
tests/dist/check_context.py.  Distributed decode (one query token against a
seq-sharded cache) degenerates to per-shard flash decode + a single psum
LSE merge — the 1-pulse case — used by the long_500k cells.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

from repro.compat import shard_map

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

NEG_INF = -1e30


def _block_attn(q, k, v, q_pos, k_pos, causal: bool):
    """Masked attention on one (q-shard, kv-block) pair; f32 partials.

    q: (B, Lq, H, hd); k/v: (B, Lk, H, hd).  Returns (o, m, l) partials
    for online-softmax merging.
    """
    hd = q.shape[-1]
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * hd ** -0.5
    if causal:
        mask = q_pos[:, None] >= k_pos[None, :]
        logits = jnp.where(mask[None, None], logits, NEG_INF)
    m = jnp.max(logits, axis=-1)                       # (B, H, Lq)
    p = jnp.exp(logits - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bhqd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o, m, l


def _merge(acc, new):
    o1, m1, l1 = acc
    o2, m2, l2 = new
    m = jnp.maximum(m1, m2)
    c1 = jnp.exp(m1 - m)
    c2 = jnp.exp(m2 - m)
    return (o1 * c1[..., None] + o2 * c2[..., None], m,
            l1 * c1 + l2 * c2)


def ring_attention(q, k, v, axis: str, ring: int, *, causal: bool = True,
                   mode: str = "fused"):
    """Sequence-sharded attention; call inside shard_map over ``axis``.

    q/k/v: (B, L_loc, H, hd) — this shard's slice of the sequence.
    Shard i holds positions [i*L_loc, (i+1)*L_loc).
    """
    B, L, H, hd = q.shape
    my = lax.axis_index(axis)
    qf = q.astype(jnp.float32)
    q_pos = my * L + jnp.arange(L)

    o0 = jnp.zeros((B, H, L, hd), jnp.float32)
    m0 = jnp.full((B, H, L), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, L), jnp.float32)
    perm = [(j, (j + 1) % ring) for j in range(ring)]

    acc = (o0, m0, l0)
    kv = (k, v)
    for step in range(ring):
        src = jnp.mod(my - step, ring)                 # owner of this block
        k_pos = src * L + jnp.arange(L)
        if mode == "fused" and step < ring - 1:
            # issue the next pulse BEFORE computing: the permute and the
            # einsums are independent, so XLA overlaps them (the paper's
            # fused pack+comm || compute)
            kv_next = jax.tree.map(
                lambda x: lax.ppermute(x, axis, perm), kv)
            part = _block_attn(qf, kv[0], kv[1], q_pos, k_pos, causal)
            acc = _merge(acc, part)
            kv = kv_next
        else:
            part = _block_attn(qf, kv[0], kv[1], q_pos, k_pos, causal)
            acc = _merge(acc, part)
            if step < ring - 1:
                # serialized: comm strictly AFTER compute, like a
                # host-driven schedule waiting on the kernel
                gate, _ = lax.optimization_barrier((part[1], kv))
                kv = jax.tree.map(
                    lambda x: lax.ppermute(x, axis, perm), kv)

    o, m, l = acc
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(v.dtype)   # (B, L, H, hd)


def ring_attention_sharded(q, k, v, mesh: Mesh, axis: str, *,
                           causal: bool = True, mode: str = "fused"):
    """shard_map wrapper: q/k/v (B, L, H, hd) sharded on L over ``axis``."""
    ring = mesh.shape[axis]
    fn = shard_map(
        functools.partial(ring_attention, axis=axis, ring=ring,
                          causal=causal, mode=mode),
        mesh=mesh,
        in_specs=(P(None, axis), P(None, axis), P(None, axis)),
        out_specs=P(None, axis),
        check_vma=False)
    return fn(q, k, v)


def distributed_decode(q, k_shard, v_shard, cache_len, axis: str,
                       shard_offset):
    """One-token decode over a seq-sharded cache: per-shard flash decode +
    LSE merge via psum — the degenerate single-pulse halo (call inside
    shard_map over ``axis``).

    q: (B, 1, H, hd) replicated; k/v_shard: (B, S_loc, HK, hd);
    shard_offset: this shard's global start position.
    """
    B, _, H, hd = q.shape
    S, HK = k_shard.shape[1], k_shard.shape[2]
    G = H // HK
    qf = (q.astype(jnp.float32).reshape(B, HK, G, hd) * hd ** -0.5) \
        .astype(k_shard.dtype)
    logits = jnp.einsum("bhgd,bkhd->bhgk", qf, k_shard,
                        preferred_element_type=jnp.float32)
    pos = shard_offset + jnp.arange(S)
    valid = pos[None] < jnp.reshape(cache_len, (-1, 1))
    logits = jnp.where(valid[:, None, None], logits, NEG_INF)
    m = jnp.max(logits, axis=-1)
    m_g = lax.pmax(m, axis)
    p = jnp.exp(logits - m_g[..., None])
    l = lax.psum(jnp.sum(p, axis=-1), axis)
    o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_shard.dtype), v_shard,
                   preferred_element_type=jnp.float32)
    o = lax.psum(o, axis)
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, 1, H, hd).astype(v_shard.dtype)
