"""Sharding rules: DP / FSDP / TP / EP / CP over the production mesh.

All models express placement through a ``ShardingCtx``; GSPMD inserts the
collectives.  The paper-technique pieces (ring/context-parallel attention,
fused MoE dispatch, halo exchange) use explicit ``shard_map`` sub-regions
instead, so their collective schedules are deterministic.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import jax
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingCtx:
    mesh: Mesh
    batch_axes: Tuple[str, ...]          # ('pod','data') or ('data',)
    model_axis: str = "model"
    fsdp_axis: Optional[str] = None      # 'data' to FSDP-shard params
    seq_axes: Tuple[str, ...] = ()       # context-parallel axes (long ctx)

    @property
    def tp(self) -> int:
        return self.mesh.shape[self.model_axis]

    @property
    def dp(self) -> int:
        return math.prod(self.mesh.shape[a] for a in self.batch_axes)

    # ---- spec builders ---------------------------------------------------

    def sharding(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def batch_spec(self):
        if not self.batch_axes:
            return None
        return self.batch_axes if len(self.batch_axes) > 1 \
            else self.batch_axes[0]

    def act(self, x, *dims):
        """Constraint helper: dims name mesh axes or None per array dim."""
        return lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*dims)))

    # ---- GQA KV-head policy ------------------------------------------------

    def kv_repeat(self, n_kv_heads: int, n_heads: int = 0) -> int:
        """Grouped replication factor so KV heads shard over TP.

        kv % tp == 0 -> shard directly (repeat 1); tp % kv == 0 AND the
        repeat divides the GQA group size -> repeat each head tp/kv times
        (memory x r, collective-free); otherwise replicate KV (repeat 1,
        head axis unsharded) — recorded per arch in DESIGN.md.
        """
        if n_kv_heads <= 0:
            return 1
        if n_kv_heads % self.tp == 0:
            return 1
        if self.tp % n_kv_heads == 0:
            r = self.tp // n_kv_heads
            g0 = (n_heads // n_kv_heads) if n_heads else r
            if r <= g0 and g0 % r == 0:
                return r
        return 1

    def kv_heads_eff(self, n_kv_heads: int, n_heads: int = 0) -> int:
        return n_kv_heads * self.kv_repeat(n_kv_heads, n_heads)

    def kv_head_axis(self, n_kv_heads: int, n_heads: int = 0) -> Optional[str]:
        eff = self.kv_heads_eff(n_kv_heads, n_heads)
        return self.model_axis if eff and eff % self.tp == 0 else None


def fsdp_dim(shape: Sequence[int], fsdp_size: int,
             taken: Sequence[Optional[str]]) -> Optional[int]:
    """Pick the first free dim divisible by the FSDP axis size."""
    for i, n in enumerate(shape):
        if taken[i] is None and n % fsdp_size == 0:
            return i
    return None


def param_spec(ctx: ShardingCtx, shape: Sequence[int],
               tp_dim: Optional[int] = None, *,
               stacked: bool = False) -> P:
    """Weight PartitionSpec: TP on ``tp_dim`` + optional FSDP elsewhere.

    ``stacked`` marks scan-stacked params whose dim 0 is the layer-stack
    axis (never sharded).
    """
    dims: list[Optional[str]] = [None] * len(shape)
    if tp_dim is not None:
        if tp_dim < 0:
            tp_dim += len(shape)
        if shape[tp_dim] % ctx.tp == 0:
            dims[tp_dim] = ctx.model_axis
    if ctx.fsdp_axis is not None:
        fsdp_size = ctx.mesh.shape[ctx.fsdp_axis]
        start = 1 if stacked else 0
        cand = [i for i in range(start, len(shape))
                if dims[i] is None and shape[i] % fsdp_size == 0]
        if cand:
            # prefer the largest dim for even lay-out
            i = max(cand, key=lambda j: shape[j])
            dims[i] = ctx.fsdp_axis
    return P(*dims)


def tree_param_shardings(ctx: ShardingCtx, specs_tree):
    return jax.tree.map(
        lambda s: NamedSharding(ctx.mesh, s), specs_tree,
        is_leaf=lambda x: isinstance(x, P))
