"""Fault-tolerant checkpointing: atomic, hashed, keep-N, resharding restore.

Design for 1000+ nodes: every write goes to a temp file, is fsync'd,
content-hashed, then atomically renamed — a crash mid-save can never
corrupt the latest valid step.  Restore picks the newest step whose hash
verifies, so auto-resume after a node failure is a pure retry loop (see
runtime/train_loop.py).  ``restore`` re-device_puts arrays under the
CURRENT mesh's shardings, which is also the elastic-rescale path (save on
mesh A, resume on mesh B).

Arrays are stored as npz shards keyed by flattened pytree paths; a JSON
manifest carries step, tree structure and integrity hashes.  (In a real
multi-host deployment each host writes its own shard file; this container
is single-process, so there is one shard.)
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flat(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


def _hash_file(path: Path) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3,
                 async_save: bool = False):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    # ---- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None):
        """Atomic save; with async_save=True runs in a background thread."""
        flat, _ = _flat(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, extra or {}))
            self._thread.start()
        else:
            self._write(step, host, extra or {})

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: Dict[str, np.ndarray], extra: Dict):
        final = self.dir / f"step_{step:010d}"
        tmp = self.dir / f".tmp_step_{step:010d}_{os.getpid()}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        shard = tmp / "shard_0.npz"
        np.savez(shard, **host)
        with open(shard, "rb") as f:
            os.fsync(f.fileno())
        manifest = {
            "step": step,
            "time": time.time(),
            "keys": sorted(host.keys()),
            "hash": {"shard_0.npz": _hash_file(shard)},
            "extra": extra,
        }
        mpath = tmp / "manifest.json"
        mpath.write_text(json.dumps(manifest, indent=1))
        with open(mpath, "rb") as f:
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)                      # atomic commit
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # ---- restore ------------------------------------------------------------

    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    def latest_valid_step(self) -> Optional[int]:
        for s in reversed(self.all_steps()):
            if self._verify(s):
                return s
        return None

    def _verify(self, step: int) -> bool:
        d = self.dir / f"step_{step:010d}"
        try:
            manifest = json.loads((d / "manifest.json").read_text())
            for fname, want in manifest["hash"].items():
                if _hash_file(d / fname) != want:
                    return False
            return True
        except (OSError, json.JSONDecodeError, KeyError):
            return False

    def restore(self, step: int, like: Any, shardings: Any = None):
        """Load arrays and device_put under the CURRENT shardings.

        ``like`` provides the pytree structure (arrays or
        ShapeDtypeStructs); ``shardings`` (same structure, NamedSharding
        leaves) re-places the arrays — a different mesh than at save time
        is fine (elastic rescale).
        """
        d = self.dir / f"step_{step:010d}"
        data = np.load(d / "shard_0.npz")
        flat_like, treedef = _flat(like)
        flat_sh = None
        if shardings is not None:
            flat_sh, _ = _flat(shardings)
        out = {}
        for key, ref in flat_like.items():
            arr = data[key]
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} vs "
                    f"expected {ref.shape}")
            arr = arr.astype(ref.dtype)
            if flat_sh is not None and key in flat_sh:
                arr = jax.device_put(arr, flat_sh[key])
            out[key] = arr
        leaves = [out[k] for k in flat_like.keys()]
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def restore_latest(self, like: Any, shardings: Any = None):
        """Restore the newest step whose hash verifies (corrupted or
        truncated newer shards are skipped — the documented contract).

        Returns ``(step, tree)`` or ``None`` when no valid checkpoint
        exists."""
        step = self.latest_valid_step()
        if step is None:
            return None
        return step, self.restore(step, like, shardings=shardings)

    def manifest(self, step: int) -> Dict:
        d = self.dir / f"step_{step:010d}"
        return json.loads((d / "manifest.json").read_text())
