"""Recovery policy: rollback with bounded backoff, then degrade, in order.

Unifies the fallbacks that grew ad hoc across the engine — signal →
serialized halo backend, sparse → dense forces, inner-ladder overflow →
outer ladder, deep window → depth-2 — into ONE ordered, observable
:class:`DegradeLadder`, and pairs it with a :class:`RecoveryPolicy` that
decides, per tripped monitor, between *rollback* (restore the last good
block and retry, exponential backoff, bounded attempts — the transient-
fault path, bitwise-exact because blocks are deterministic), *degrade*
(walk the ladder to the first rung whose triggers match — the persistent-
fault path, correct to the NVE drift bound), *reshard* (device loss →
``MDEngine.reshard`` onto a spare mesh), or *fail* (typed
``RecoveryExhausted``, never a silent divergence).

:class:`Watchdog` (the EWMA step-time straggler monitor) generalized
here from ``runtime/train_loop.py``; the train loop re-exports it and
the MD block loop and ``serve_loop`` now wire it too.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Set, Tuple


@dataclasses.dataclass
class Watchdog:
    """EWMA step-time monitor with a straggler callback."""
    alpha: float = 0.2
    threshold: float = 3.0
    warmup: int = 3
    on_straggler: Optional[Callable[[int, float, float], None]] = None
    ewma: float = 0.0
    n: int = 0
    events: int = 0

    def observe(self, step: int, dt: float):
        if self.n >= self.warmup and self.ewma > 0 and \
                dt > self.threshold * self.ewma:
            self.events += 1
            if self.on_straggler is not None:
                self.on_straggler(step, dt, self.ewma)
        self.ewma = dt if self.n == 0 else \
            (1 - self.alpha) * self.ewma + self.alpha * dt
        self.n += 1


@dataclasses.dataclass(frozen=True)
class DegradeRung:
    """One rung: engine-rebuild ``overrides`` that remove a failure mode.

    ``triggers`` — event kinds this rung is the designated fix for (the
    ladder jumps straight to it); ``clears`` — fault *sites* that
    physically cease to exist once the rung is applied (the serialized
    backend has no put-with-signal to drop), reported to the fault plan
    so sticky faults on them retire."""

    name: str
    overrides: dict
    triggers: Tuple[str, ...] = ()
    clears: Tuple[str, ...] = ()


# Ordered cheapest-first: each rung gives up one optimization from the
# paper's stack, never correctness.
DEFAULT_RUNGS: Tuple[DegradeRung, ...] = (
    DegradeRung("serialized_halo", {"backend": "serialized"},
                triggers=("ledger",),
                clears=("signal_drop", "halo_corrupt")),
    DegradeRung("dense_forces", {"force_backend": "dense"},
                triggers=("nonfinite", "energy_spike"),
                clears=("force_nan",)),
    DegradeRung("outer_ladder", {"nstprune": 0},
                triggers=("overflow",),
                clears=("inner_overflow",)),
    DegradeRung("depth2_window", {"pipeline_depth": 2}),
)


class DegradeLadder:
    """Ordered degrade rungs with applied-state tracking."""

    def __init__(self, rungs: Sequence[DegradeRung] = DEFAULT_RUNGS):
        self.rungs = tuple(rungs)
        self.applied: List[DegradeRung] = []

    def next_rung(self, kinds: Set[str]) -> Optional[DegradeRung]:
        """The rung to apply for these event kinds: the first unapplied
        rung that names one of them as a trigger, else the first
        unapplied rung at all (walk the whole ladder before failing)."""
        pending = [r for r in self.rungs if r not in self.applied]
        for r in pending:
            if any(k in r.triggers for k in kinds):
                return r
        return pending[0] if pending else None

    def apply(self, rung: DegradeRung):
        self.applied.append(rung)

    def summary(self) -> dict:
        return {"applied": [r.name for r in self.applied],
                "available": [r.name for r in self.rungs
                              if r not in self.applied]}


@dataclasses.dataclass(frozen=True)
class RecoveryAction:
    """What the policy chose: ``kind`` in rollback / degrade / reshard /
    fail, plus the rung (degrade) or backoff delay (rollback)."""

    kind: str
    rung: Optional[DegradeRung] = None
    backoff_s: float = 0.0


class RecoveryPolicy:
    """Maps (tripped event kinds, retry attempt) to a recovery action."""

    def __init__(self, max_retries: int = 2,
                 backoff_base_s: float = 0.01,
                 backoff_factor: float = 2.0,
                 backoff_cap_s: float = 1.0,
                 ladder: Optional[DegradeLadder] = None):
        if max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        self.max_retries = int(max_retries)
        self.backoff_base_s = float(backoff_base_s)
        self.backoff_factor = float(backoff_factor)
        self.backoff_cap_s = float(backoff_cap_s)
        self.ladder = ladder if ladder is not None else DegradeLadder()

    def backoff(self, attempt: int) -> float:
        """Bounded exponential backoff for retry ``attempt`` (0-based)."""
        return min(self.backoff_cap_s,
                   self.backoff_base_s * self.backoff_factor ** attempt)

    def decide(self, kinds: Set[str], attempt: int) -> RecoveryAction:
        if "device_loss" in kinds:
            return RecoveryAction("reshard")
        if attempt < self.max_retries:
            return RecoveryAction("rollback",
                                  backoff_s=self.backoff(attempt))
        rung = self.ladder.next_rung(kinds)
        if rung is not None:
            return RecoveryAction("degrade", rung=rung)
        return RecoveryAction("fail")
