"""ResilientMDRunner: the self-healing MD block loop.

Drives ``MDEngine.begin_run / run_block / advance_schedule`` exactly as
``MDEngine.simulate`` does — visiting bitwise-identical states when
nothing fires — but between blocks it also:

* arms the :class:`~repro.resilience.faults.FaultPlan`'s scan/host
  faults for the coming block,
* reads the in-scan health scalars through
  :class:`~repro.resilience.monitors.HealthMonitor` (no extra host
  round-trips — they ride the block metrics),
* checkpoints every clean block boundary (pre-rebin state, so restore +
  ``begin_run`` replays the exact rebin the uninterrupted run performs
  — rollback is bitwise), and
* on a tripped monitor asks the
  :class:`~repro.resilience.policy.RecoveryPolicy`: rollback with
  bounded backoff, degrade down the ladder (engine ``rebuild`` with the
  rung's overrides), reshard onto a spare mesh (device loss), or raise
  ``RecoveryExhausted``.

A :class:`~repro.resilience.policy.Watchdog` observes per-block wall
time (the straggler signal that, at scale, triggers the same
checkpoint-and-remesh path device loss does here).
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.ckpt.checkpoint import CheckpointManager
from repro.core.md.domain import AXES
from repro.core.md.engine import MDEngine
from repro.resilience.faults import (
    DeviceLost,
    FaultPlan,
    ProcessKilled,
    RecoveryExhausted,
    ResilienceError,
)
from repro.resilience.monitors import HealthEvent, HealthMonitor
from repro.resilience.policy import RecoveryPolicy, Watchdog


class ResilientMDRunner:
    """Fault-injecting, self-healing driver around one :class:`MDEngine`.

    The engine must be built with ``health=True`` (the in-scan monitors
    are the detection path) and, if the plan carries scan or overflow
    faults, with ``inject=True``.  ``spare_mesh`` is the failover mesh
    consumed by the device-loss → ``reshard`` escalation.
    """

    def __init__(self, engine: MDEngine, ckpt_dir,
                 plan: Optional[FaultPlan] = None,
                 policy: Optional[RecoveryPolicy] = None,
                 monitor: Optional[HealthMonitor] = None,
                 watchdog: Optional[Watchdog] = None,
                 spare_mesh: Optional[Mesh] = None,
                 keep: int = 3):
        if not engine.health:
            raise ValueError("ResilientMDRunner needs an MDEngine built "
                             "with health=True (the detection path)")
        self.plan = plan if plan is not None else FaultPlan()
        if self.plan.scan_or_overflow_sites and not engine.inject:
            raise ValueError("the fault plan carries scan/overflow sites; "
                             "build the engine with inject=True")
        self.engine = engine
        self.policy = policy if policy is not None else RecoveryPolicy()
        self.monitor = monitor if monitor is not None else \
            HealthMonitor(registry=engine.obs)
        self.watchdog = watchdog if watchdog is not None else Watchdog()
        self.spare_mesh = spare_mesh
        self._mgr = CheckpointManager(ckpt_dir, keep=keep)
        self.report: dict = {"events": [], "recoveries": [],
                             "wasted_steps": 0, "checkpoint_steps": [],
                             "resumed_from": None, "resharded": False}

    # -- checkpoint plumbing ----------------------------------------------

    def _like(self, eng: MDEngine):
        G, K = eng.layout.global_cells, eng.layout.capacity
        dt = eng.system.pos.dtype
        n = eng.system.n_atoms
        return {
            "cell_f": jax.ShapeDtypeStruct(tuple(G) + (K, 7), dt),
            "cell_i": jax.ShapeDtypeStruct(tuple(G) + (K, 2), np.int32),
            "atoms": {"pos": jax.ShapeDtypeStruct((n, 3), dt),
                      "vel": jax.ShapeDtypeStruct((n, 3), dt)},
        }

    def _shardings(self, eng: MDEngine):
        sh = NamedSharding(eng.mesh, P(*AXES))
        return {"cell_f": sh, "cell_i": sh}   # atoms stay host-side

    def _save(self, eng: MDEngine, state, step: int, disable: bool):
        cell_f, cell_i = state
        self._mgr.save(step,
                       {"cell_f": cell_f, "cell_i": cell_i,
                        "atoms": eng.export_atoms(state)},
                       extra={"step": int(step), "disable": bool(disable)})
        self.report["checkpoint_steps"].append(int(step))
        eng.obs.counter("resilience/checkpoints").inc()

    def _restore(self, eng: MDEngine):
        """Rewind to the last good block: restored pre-rebin state +
        ``begin_run`` replays the exact boundary rebin/prune."""
        res = self._mgr.restore_latest(self._like(eng),
                                       self._shardings(eng))
        if res is None:
            raise ResilienceError("no valid checkpoint to roll back to")
        step_c, tree = res
        extra = self._mgr.manifest(step_c)["extra"]
        rs = eng.begin_run((tree["cell_f"], tree["cell_i"]),
                           disable_inner=bool(extra.get("disable", False)))
        rs.step = int(extra.get("step", step_c))
        self.monitor.reset()
        return rs

    # -- recovery actions --------------------------------------------------

    def _record(self, action: str, kinds, step0: int, take: int,
                events, attempt: int, detail: str = ""):
        latency = [int(step0 + take - ev.step) for ev in events] or [0]
        rec = {"action": action, "kinds": sorted(kinds),
               "block_step": int(step0), "attempt": int(attempt),
               "detection_latency_steps": max(latency),
               "rollback_steps": int(take), "detail": detail}
        self.report["recoveries"].append(rec)
        self.engine.obs.emit("recovery", **rec)

    def _degrade(self, rung):
        """Rebuild the engine one rung down and retire the sites the rung
        physically removes."""
        self.engine = self.engine.rebuild(**rung.overrides)
        self.policy.ladder.apply(rung)
        self.plan.disable_sites(rung.clears)
        self.engine.obs.emit("degrade", rung=rung.name,
                             overrides=rung.overrides,
                             clears=list(rung.clears))
        return self._restore(self.engine)

    def _reshard(self, step0: int):
        """Device loss: recover the portable atom snapshot from the last
        checkpoint, rebuild on the spare mesh, re-anchor the checkpoint
        chain under the new layout."""
        if self.spare_mesh is None:
            raise DeviceLost(f"device loss at step {step0} with no spare "
                             "mesh to reshard onto")
        res = self._mgr.restore_latest(self._like(self.engine))
        if res is None:
            raise DeviceLost("device loss before any checkpoint existed")
        step_c, tree = res
        extra = self._mgr.manifest(step_c)["extra"]
        eng2 = self.engine.reshard(self.spare_mesh, atoms=tree["atoms"])
        self.engine, self.spare_mesh = eng2, None
        self.report["resharded"] = True
        eng2.obs.emit("reshard", step=step_c,
                      mesh_shape=tuple(eng2.mesh.shape[a] for a in AXES))
        state2 = eng2.init_state()
        self._save(eng2, state2, step_c,
                   bool(extra.get("disable", False)))
        rs = eng2.begin_run(state2,
                            disable_inner=bool(extra.get("disable",
                                                         False)))
        rs.step = int(extra.get("step", step_c))
        self.monitor.reset()
        return rs

    # -- the loop ----------------------------------------------------------

    def run(self, n_steps: int, state=None, collect: bool = True,
            resume: bool = True):
        """Run ``n_steps``; returns ``((cell_f, cell_i), metrics,
        report)``.  With ``resume=True`` a valid checkpoint in
        ``ckpt_dir`` continues that run (the post-kill path)."""
        eng = self.engine
        nst = eng.system.params.nstlist
        rs = None
        if resume:
            res = self._mgr.restore_latest(self._like(eng),
                                           self._shardings(eng))
            if res is not None:
                step_c, tree = res
                extra = self._mgr.manifest(step_c)["extra"]
                rs = eng.begin_run(
                    (tree["cell_f"], tree["cell_i"]),
                    disable_inner=bool(extra.get("disable", False)))
                rs.step = int(extra.get("step", step_c))
                self.report["resumed_from"] = rs.step
        if rs is None:
            if state is None:
                state = eng.init_state()
            # step-0 anchor: the PRE-rebin state, so a rollback to it
            # replays begin_run's rebin exactly once, like the clean run
            self._save(eng, state, 0, False)
            rs = eng.begin_run(state)

        all_metrics, attempt = [], 0
        while rs.step < n_steps:
            eng = self.engine
            take = min(nst, n_steps - rs.step)
            step0 = rs.step

            # host-side faults fire at the boundary, before the block
            host = self.plan.host_pending(step0, step0 + take)
            kills = [i for i, s in host if s.site == "proc_kill"]
            if kills:
                self.plan.mark_fired(kills)
                self._mgr.wait()
                raise ProcessKilled(
                    f"injected process kill at step {step0}")
            losses = [i for i, s in host if s.site == "device_loss"]
            if losses:
                self.plan.mark_fired(losses)
                ev = HealthEvent("device_loss", step0)
                self.report["events"].append(vars(ev))
                act = self.policy.decide({"device_loss"}, attempt)
                self._record(act.kind, {"device_loss"}, step0, 0, [ev],
                             attempt)
                rs = self._reshard(step0)
                attempt = 0
                continue

            fv, armed = self.plan.arm_scan(step0, step0 + take)
            ovf, ovf_armed = self.plan.overflow_armed(step0, step0 + take)
            t0 = time.time()
            m = eng.run_block(rs, take, fault_vec=fv, force_overflow=ovf)
            mh = jax.device_get(m)     # sync: boundary scalar read
            self.watchdog.observe(step0 // max(nst, 1),
                                  time.time() - t0)
            self.plan.mark_fired(armed)
            self.plan.mark_fired(ovf_armed)
            if ovf:
                # the engine's own outer-ladder fallback IS the recovery
                # (next block runs the outer list); record, don't rewind
                ev = HealthEvent("overflow", step0)
                self.report["events"].append(vars(ev))
                self._record("engine_fallback", {"overflow"}, step0, 0,
                             [ev], attempt, detail="outer_ladder")

            events = self.monitor.check_block(mh, step0)
            if events:
                self.report["events"].extend(vars(e) for e in events)
                kinds = {e.kind for e in events}
                act = self.policy.decide(kinds, attempt)
                self.report["wasted_steps"] += take
                self._record(act.kind, kinds, step0, take, events,
                             attempt,
                             detail=act.rung.name if act.rung else "")
                if act.kind == "rollback":
                    time.sleep(act.backoff_s)
                    rs = self._restore(eng)
                    attempt += 1
                elif act.kind == "degrade":
                    rs = self._degrade(act.rung)
                    attempt = 0
                elif act.kind == "reshard":
                    rs = self._reshard(step0)
                    attempt = 0
                else:
                    raise RecoveryExhausted(
                        f"unrecoverable events {sorted(kinds)} at step "
                        f"{step0}: retries and degrade ladder exhausted")
                continue

            # clean block: commit it
            attempt = 0
            if collect:
                all_metrics.append(mh)
            self._save(eng, (rs.cell_f, rs.cell_i), rs.step,
                       bool(rs.sched is not None and rs.disable))
            if rs.step < n_steps:
                eng.advance_schedule(rs)

        self._mgr.wait()
        metrics = {}
        if collect and all_metrics:
            keys = set(all_metrics[0])
            for mh in all_metrics[1:]:
                keys &= set(mh)
            metrics = {k: np.concatenate([np.atleast_1d(m[k])
                                          for m in all_metrics])
                       for k in sorted(keys)}
        self.report["watchdog_events"] = self.watchdog.events
        self.report["fault_plan"] = self.plan.summary()
        self.report["ladder"] = self.policy.ladder.summary()
        self.engine.obs.emit("resilient_run", n_steps=n_steps,
                             recoveries=len(self.report["recoveries"]),
                             wasted_steps=self.report["wasted_steps"],
                             resharded=self.report["resharded"])
        return (rs.cell_f, rs.cell_i), metrics, self.report
