"""In-scan health monitors, read at block boundaries.

The device side lives in the engine (``MDEngine(health=True)``): a psum'd
NaN/Inf count per step over positions/velocities/forces, and a pmax'd
ledger-invariant violation flag per pipeline invocation — a handful of
scalars riding the block metrics the host already reads, so monitoring
adds **zero** host round-trips.  This module is the host side:
:class:`HealthMonitor` scans a block's metrics for those flags plus an
energy-spike check on the ``pe + ke`` series (corruption that stays
finite — the failure NaN flags cannot see), and turns them into typed
:class:`HealthEvent`\\ s the recovery policy consumes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class HealthEvent:
    """One tripped monitor: ``kind`` at global MD step ``step``.

    ``kind`` is one of ``nonfinite`` / ``ledger`` / ``energy_spike``
    (this module) or ``device_loss`` / ``overflow`` (raised by the
    runner's host-side checks); ``value`` is the offending magnitude."""

    kind: str
    step: int
    value: float = 0.0


class HealthMonitor:
    """Scans block-boundary metrics into :class:`HealthEvent` lists.

    ``energy_spike_rel`` is the per-step relative jump in total energy
    (``|dE| > rel * max(|E_prev|, floor)``) treated as corruption; NVE
    drift over one step is orders of magnitude below any sane setting.
    The previous block's last energy seeds the cross-block comparison;
    :meth:`reset` clears it (call after a rollback — the retried block
    re-derives it from the restored state).
    """

    def __init__(self, energy_spike_rel: float = 0.25,
                 energy_floor: float = 1e-3, registry=None):
        self.energy_spike_rel = float(energy_spike_rel)
        self.energy_floor = float(energy_floor)
        self.registry = registry
        self._last_E: Optional[float] = None

    def reset(self):
        """Forget cross-block state (rollback / degrade / reshard)."""
        self._last_E = None

    def check_block(self, metrics: Dict[str, np.ndarray], step0: int
                    ) -> List[HealthEvent]:
        """Scan one block's host-side metrics; returns tripped events.

        ``step0`` is the block's first global step (per-step metric index
        ``i`` is step ``step0 + i``).  Cross-block energy state advances
        only on a clean block — a block that trips anything leaves the
        monitor where it was, so the rolled-back retry is compared
        against the same last-good reference."""
        events: List[HealthEvent] = []

        nf = np.atleast_1d(np.asarray(metrics.get("health/nonfinite", 0)))
        if (nf > 0).any():
            first = int(np.argmax(nf > 0))
            events.append(HealthEvent("nonfinite", step0 + first,
                                      float(nf.max())))

        lv = np.atleast_1d(np.asarray(
            metrics.get("health/led_violation", 0)))
        if (lv > 0).any():
            # ledger scalars are per pipeline invocation, not per step:
            # block granularity is the best resolution available
            events.append(HealthEvent("ledger", step0, float(lv.max())))

        pe, ke = metrics.get("pe"), metrics.get("ke")
        last_E = self._last_E
        if pe is not None and ke is not None:
            E = (np.asarray(pe, np.float64).reshape(-1)
                 + np.asarray(ke, np.float64).reshape(-1))
            prev = self._last_E
            for i, e in enumerate(E):
                if not np.isfinite(e):
                    prev = None        # NaN steps: nonfinite already fired
                    continue
                if prev is not None:
                    scale = max(abs(prev), self.energy_floor)
                    if abs(e - prev) > self.energy_spike_rel * scale:
                        events.append(HealthEvent(
                            "energy_spike", step0 + i,
                            float(abs(e - prev) / scale)))
                        break
                prev = e
            if np.isfinite(E[-1]):
                last_E = float(E[-1])

        if not events:
            self._last_E = last_E
        if self.registry is not None:
            for ev in events:
                self.registry.counter(f"resilience/{ev.kind}").inc()
                self.registry.emit("health_event", event=ev.kind,
                                   step=ev.step, value=ev.value)
        return events
