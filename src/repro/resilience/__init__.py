"""repro.resilience: fault injection, health monitors, self-healing MD.

Light imports by design: the runner (which pulls in the full MD engine)
loads lazily, so ``from repro.resilience.faults import WaveTimeout``
stays cheap for the serving path.
"""
from repro.resilience.faults import (
    ALL_FAULT_SITES,
    HOST_FAULT_SITES,
    DeviceLost,
    FaultPlan,
    FaultSpec,
    HealthTripped,
    ProcessKilled,
    RecoveryExhausted,
    ResilienceError,
    WaveTimeout,
)
from repro.resilience.monitors import HealthEvent, HealthMonitor
from repro.resilience.policy import (
    DEFAULT_RUNGS,
    DegradeLadder,
    DegradeRung,
    RecoveryAction,
    RecoveryPolicy,
    Watchdog,
)

__all__ = [
    "ALL_FAULT_SITES", "HOST_FAULT_SITES", "DeviceLost", "FaultPlan",
    "FaultSpec", "HealthTripped", "ProcessKilled", "RecoveryExhausted",
    "ResilienceError", "WaveTimeout", "HealthEvent", "HealthMonitor",
    "DEFAULT_RUNGS", "DegradeLadder", "DegradeRung", "RecoveryAction",
    "RecoveryPolicy", "Watchdog", "ResilientMDRunner",
]


def __getattr__(name):          # PEP 562: lazy heavy import
    if name == "ResilientMDRunner":
        from repro.resilience.runner import ResilientMDRunner
        return ResilientMDRunner
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
