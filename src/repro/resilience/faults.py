"""Deterministic fault injection: seeded, replayable fault plans.

At 1000+-node strong scaling, faults are routine; a resilience layer is
only trustworthy if every recovery path can be *provoked on demand*.
:class:`FaultPlan` is the provocation: a list of ``(site, step)`` fault
specs, bit-reproducible from a seed, that the
:class:`~repro.resilience.runner.ResilientMDRunner` arms block by block.

Two families of site:

* **scan sites** (``ledger.SCAN_FAULT_SITES``) perturb the traced block
  program itself — a NaN'd halo payload, a NaN'd force-kernel output, a
  dropped put-with-signal release.  They are armed through the engine's
  traced ``fault_vec`` operand (see ``MDEngine.run_block``), so arming
  never retraces and the injected program is bit-identical to the clean
  one while disarmed.
* **host sites** fire at block boundaries on the host: a forced
  inner-ladder overflow (feeds the engine's overflow monitor a synthetic
  trip), a simulated device loss (escalates to ``MDEngine.reshard``),
  and a process kill (exercises checkpoint auto-resume).

``sticky=True`` faults re-fire every block until their site is disabled
— the handle the degrade ladder uses: a rollback retry cannot outrun a
sticky fault, so the policy must walk to the rung that removes the
faulted component (which then calls :meth:`FaultPlan.disable_sites`).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.pipeline.ledger import DISARMED, SCAN_FAULT_SITES

HOST_FAULT_SITES = ("inner_overflow", "device_loss", "proc_kill")
ALL_FAULT_SITES = SCAN_FAULT_SITES + HOST_FAULT_SITES


class ResilienceError(RuntimeError):
    """Base of the resilience layer's typed exceptions."""


class HealthTripped(ResilienceError):
    """A health monitor fired and no recovery path was taken."""


class RecoveryExhausted(ResilienceError):
    """Retries and the degrade ladder are both spent."""


class DeviceLost(ResilienceError):
    """Simulated device loss with no spare mesh to reshard onto."""


class ProcessKilled(ResilienceError):
    """Injected host-process kill (the checkpoint auto-resume drill)."""


class WaveTimeout(ResilienceError):
    """A serving wave's decode loop exceeded its per-wave deadline."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One named fault: ``site`` fires at global MD step ``step``.

    Scan sites fire inside the block containing ``step``; host sites
    fire at the boundary before that block.  ``sticky`` faults re-fire
    every subsequent block until the site is disabled."""

    site: str
    step: int
    sticky: bool = False

    def __post_init__(self):
        if self.site not in ALL_FAULT_SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"available: {ALL_FAULT_SITES}")
        if self.step < 0:
            raise ValueError("fault step must be >= 0")


class FaultPlan:
    """Replayable schedule of faults, armed block by block.

    The plan is pure host-side bookkeeping: :meth:`arm_scan` /
    :meth:`overflow_armed` / :meth:`host_pending` report what fires in a
    ``[lo, hi)`` step window, and the runner marks specs fired after the
    block executes (so a rolled-back block re-arms nothing — one-shot
    faults fire exactly once, which is what makes the rollback retry
    converge bitwise)."""

    def __init__(self, specs: Iterable[FaultSpec] = ()):
        self.specs: List[FaultSpec] = list(specs)
        self._fired = [False] * len(self.specs)
        self._disabled: set = set()

    @classmethod
    def from_seed(cls, seed: int, n_steps: int,
                  sites: Sequence[str] = SCAN_FAULT_SITES,
                  n_faults: int = 3) -> "FaultPlan":
        """Seeded plan: ``n_faults`` sites/steps drawn reproducibly."""
        rng = np.random.RandomState(seed)
        specs = [FaultSpec(site=sites[int(rng.randint(len(sites)))],
                           step=int(rng.randint(max(1, n_steps))))
                 for _ in range(n_faults)]
        return cls(specs)

    # -- liveness ----------------------------------------------------------

    def _live(self, i: int) -> bool:
        s = self.specs[i]
        if s.site in self._disabled:
            return False
        return s.sticky or not self._fired[i]

    def _in_window(self, s: FaultSpec, lo: int, hi: int) -> bool:
        if s.sticky:
            return s.step < hi          # re-fires every block from `step`
        return lo <= s.step < hi

    # -- block arming ------------------------------------------------------

    def arm_scan(self, lo: int, hi: int
                 ) -> Tuple[Optional[np.ndarray], List[int]]:
        """The ``fault_vec`` operand for a ``[lo, hi)`` block.

        Returns ``(vector, armed_indices)``; the vector is ``None`` when
        no scan site fires (the block runs fully disarmed).  When two
        specs target the same site in one block, the earliest step wins
        (the other stays pending for a later block)."""
        vec = np.full((len(SCAN_FAULT_SITES),), DISARMED, np.int32)
        armed: List[int] = []
        for i, s in enumerate(self.specs):
            if s.site not in SCAN_FAULT_SITES or not self._live(i) \
                    or not self._in_window(s, lo, hi):
                continue
            k = s.site
            rel = max(0, s.step - lo)
            slot = SCAN_FAULT_SITES.index(k)
            if vec[slot] == DISARMED or rel < vec[slot]:
                vec[slot] = rel
            armed.append(i)
        if not armed:
            return None, []
        return vec, armed

    def overflow_armed(self, lo: int, hi: int) -> Tuple[bool, List[int]]:
        """Does the forced inner-ladder-overflow site fire this block?"""
        armed = [i for i, s in enumerate(self.specs)
                 if s.site == "inner_overflow" and self._live(i)
                 and self._in_window(s, lo, hi)]
        return bool(armed), armed

    def host_pending(self, lo: int, hi: int) -> List[Tuple[int, FaultSpec]]:
        """Device-loss / process-kill specs due before this block runs."""
        return [(i, s) for i, s in enumerate(self.specs)
                if s.site in ("device_loss", "proc_kill") and self._live(i)
                and self._in_window(s, lo, hi)]

    # -- outcome bookkeeping ----------------------------------------------

    def mark_fired(self, indices: Iterable[int]):
        """Record that these specs' faults ran (sticky specs stay live —
        only :meth:`disable_sites` retires them)."""
        for i in indices:
            self._fired[i] = True

    def disable_sites(self, sites: Iterable[str]):
        """Retire whole sites — called when a degrade rung physically
        removes the faulted seam (e.g. the serialized halo backend has no
        put-with-signal to drop)."""
        self._disabled.update(sites)

    # -- introspection -----------------------------------------------------

    @property
    def scan_or_overflow_sites(self) -> bool:
        return any(s.site in SCAN_FAULT_SITES or s.site == "inner_overflow"
                   for s in self.specs)

    def summary(self) -> dict:
        return {
            "specs": [dataclasses.asdict(s) for s in self.specs],
            "fired": [bool(f) for f in self._fired],
            "disabled_sites": sorted(self._disabled),
        }

    def __repr__(self):
        return f"FaultPlan({self.specs!r})"
