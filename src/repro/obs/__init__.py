"""repro.obs: metrics registry, phase tracing, Perfetto export, perf gate.

The observability layer the paper's argument is made of: phase-level
visibility into where halo-exchange time goes, and a regression gate on
the measured trajectory.

* :mod:`repro.obs.registry` — typed counters/gauges/histograms with
  per-block snapshots and JSONL export; every existing stats surface
  (``halo_stats``/``overlap_stats``/``pair_stats``, ledger summaries,
  ``sched_history``, the overflow monitor) publishes here.
* :mod:`repro.obs.tracing` — ``jax.named_scope`` phase annotations,
  on-device per-step ledger counters (barrier-neutral: bitwise-identical
  trajectories with tracing on), and the host-side ``span``/``time_fn``
  timing API shared by ``benchmarks/`` and ``launch/dryrun.py``.
* :mod:`repro.obs.perfetto` — metrics JSONL -> Chrome/Perfetto
  ``trace.json`` with measured and model-predicted lanes side by side
  (``python -m repro.obs metrics.jsonl --out trace.json``).
* :mod:`repro.obs.gate` — drift check of a fresh
  ``BENCH_pipeline.json`` against the checked-in baseline (the CI
  ``perf-smoke`` job).
"""
from repro.obs.gate import (
    DEFAULT_GATE,
    KEY_FIELDS,
    SCHEMA_VERSION,
    cell_key,
    compare_bench,
    gate_files,
)
from repro.obs.perfetto import export_trace, predicted_schedule, to_trace
from repro.obs.registry import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    default_registry,
    iter_kind,
    jsonsafe,
    load_jsonl,
)
from repro.obs.tracing import (
    NULL_TRACER,
    PHASES,
    PhaseTracer,
    Span,
    TimingResult,
    is_obs_metric,
    span,
    strip_obs_metrics,
    time_fn,
)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "default_registry", "iter_kind", "jsonsafe", "load_jsonl",
    "NULL_TRACER", "PHASES", "PhaseTracer", "Span", "TimingResult",
    "is_obs_metric", "span", "strip_obs_metrics", "time_fn",
    "export_trace", "predicted_schedule", "to_trace",
    "DEFAULT_GATE", "KEY_FIELDS", "SCHEMA_VERSION", "cell_key",
    "compare_bench", "gate_files",
]
