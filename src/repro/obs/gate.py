"""Perf-trajectory gate: drift check against a checked-in bench baseline.

``benchmarks/run.py --suite pipeline`` emits a schema-versioned
``results/BENCH_pipeline.json`` — step latency, exposed phases,
overlapped bytes, and prune ratio across (backend x pipeline mode x
depth) cells.  The committed copy is the trajectory baseline; CI re-runs
the smoke suite and calls :func:`compare_bench` on the fresh file.

Three comparison classes, declared in the baseline's ``gate`` section so
the tolerance travels with the data it gates:

* ``exact`` — schedule/model invariants (exposed phases, overlapped and
  exchanged bytes, decomposition).  These are *deterministic functions
  of the code*; any drift is a semantic change and must be an explicit
  baseline update in the same PR.
* ``rel_tol`` — deterministic-but-float quantities (prune ratio,
  evaluated pairs) allowed a small relative envelope.
* ``timing_factor`` — wall-clock keys (``ms_per_step``,
  ``ms_force_pass``) only fail when the current run is *slower* than
  baseline by more than the factor: CI machines are noisy, so the gate
  catches trajectory-scale regressions, not jitter.
"""
from __future__ import annotations

import json
from typing import Dict, List, Tuple

SCHEMA_VERSION = 1

# identity of one bench cell inside a suite file
KEY_FIELDS = ("mode", "pipeline", "pipeline_depth", "devices", "n_atoms",
              "force_backend", "nstprune")

DEFAULT_GATE = {
    "exact": ["exposed_phases", "overlapped_bytes", "exchanged_bytes",
              "halo_total_bytes", "dd"],
    "rel_tol": {"prune_ratio": 0.05,
                "evaluated_slot_pairs_per_step": 0.05,
                "modeled_speedup": 1e-6},
    "timing_factor": 10.0,
    "timing_keys": ["ms_per_step", "ms_force_pass"],
}


def cell_key(cell: dict, key_fields: Tuple[str, ...] = KEY_FIELDS) -> Tuple:
    return tuple(cell.get(f) for f in key_fields)


def _index(bench: dict,
           key_fields: Tuple[str, ...] = KEY_FIELDS) -> Dict[Tuple, dict]:
    out: Dict[Tuple, dict] = {}
    for cell in bench.get("cells", []):
        key = cell_key(cell, key_fields)
        if key in out:
            raise ValueError(f"duplicate bench cell {key}")
        out[key] = cell
    return out


def _fmt_key(key: Tuple,
             key_fields: Tuple[str, ...] = KEY_FIELDS) -> str:
    return "/".join(f"{f}={v}" for f, v in zip(key_fields, key))


def compare_bench(baseline: dict, current: dict) -> List[str]:
    """All drift findings of ``current`` vs ``baseline`` ('' = pass)."""
    problems: List[str] = []
    if baseline.get("schema_version") != current.get("schema_version"):
        problems.append(
            f"schema_version drift: baseline "
            f"{baseline.get('schema_version')} vs current "
            f"{current.get('schema_version')}")
        return problems
    gate = {**DEFAULT_GATE, **baseline.get("gate", {})}
    # suites whose cells have a different identity (e.g. the resilience
    # suite keys on fault site x recovery mode) declare their own
    # key_fields in the gate section, next to the tolerances
    kf = tuple(gate.get("key_fields", KEY_FIELDS))
    base_cells, cur_cells = _index(baseline, kf), _index(current, kf)
    for key in sorted(set(base_cells) - set(cur_cells), key=repr):
        problems.append(
            f"cell missing from current run: {_fmt_key(key, kf)}")
    for key in sorted(set(cur_cells) - set(base_cells), key=repr):
        problems.append(
            f"cell not in baseline (update it): {_fmt_key(key, kf)}")
    for key in sorted(set(base_cells) & set(cur_cells), key=repr):
        b, c = base_cells[key], cur_cells[key]
        where = _fmt_key(key, kf)
        for f in gate["exact"]:
            if b.get(f) != c.get(f):
                problems.append(f"{where}: {f} drift "
                                f"{b.get(f)!r} -> {c.get(f)!r} (exact)")
        for f, tol in gate["rel_tol"].items():
            bv, cv = b.get(f), c.get(f)
            if bv is None and cv is None:
                continue
            if bv is None or cv is None:
                problems.append(f"{where}: {f} drift {bv!r} -> {cv!r}")
                continue
            scale = max(abs(bv), abs(cv), 1e-12)
            if abs(bv - cv) > tol * scale:
                problems.append(f"{where}: {f} drift {bv:.6g} -> {cv:.6g} "
                                f"(rel {abs(bv - cv) / scale:.3g} > {tol})")
        for f in gate["timing_keys"]:
            bv, cv = b.get(f), c.get(f)
            if bv is None or cv is None:
                continue
            if cv > bv * gate["timing_factor"]:
                problems.append(
                    f"{where}: {f} regression {bv:.3f} -> {cv:.3f} ms "
                    f"(> {gate['timing_factor']}x baseline)")
    return problems


def gate_files(baseline_path, current_path) -> List[str]:
    with open(baseline_path) as fh:
        baseline = json.load(fh)
    with open(current_path) as fh:
        current = json.load(fh)
    return compare_bench(baseline, current)
