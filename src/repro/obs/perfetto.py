"""Chrome/Perfetto ``trace.json`` export — the repo's Fig. 6 analogue.

Renders a metrics JSONL (written by :class:`~repro.obs.registry.
MetricsRegistry`) as a Chrome trace-event file with two process lanes:

* **pid 0 — measured**: every host-side ``span`` record becomes a
  duration event (one thread row per span name, wall-clock placement),
  and every ``snapshot`` record's counters/gauges become counter tracks.

* **pid 1 — predicted**: a synthetic per-step timeline built from the
  latest ``halo_stats`` record's alpha-beta latency model and overlap
  model — per-step forward/reverse exchanges split into *exposed* and
  *overlapped* rows around the force window, exactly the decomposition
  the paper's profiler timelines show for MPI vs NVSHMEM.  ``obs/*``
  per-step ledger counters (from a ``step_counters`` record) ride along
  as counter tracks on the predicted step grid.

Open the output at https://ui.perfetto.dev (or ``chrome://tracing``).
Reading the lanes: if the measured step wall time tracks
``predicted exposed + force`` the overlap model holds; a measured lane
longer than predicted-with-overlap but matching predicted-serialized
means the exchange is still on the critical path.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

from repro.obs.registry import iter_kind, load_jsonl  # noqa: F401

_US = 1e6   # trace-event timestamps are microseconds


def _meta(pid: int, name: str, tid: Optional[int] = None,
          tname: Optional[str] = None) -> List[dict]:
    evs = [{"ph": "M", "pid": pid, "tid": 0, "name": "process_name",
            "args": {"name": name}}]
    if tid is not None:
        evs.append({"ph": "M", "pid": pid, "tid": tid,
                    "name": "thread_name", "args": {"name": tname}})
    return evs


def _measured_events(records: List[dict]) -> List[dict]:
    spans = iter_kind(records, "span")
    snaps = iter_kind(records, "snapshot")
    events: List[dict] = _meta(0, "measured (host spans)")
    if not spans and not snaps:
        return events
    starts = [r["t"] - r.get("dur", 0.0) for r in spans] + \
             [r["t"] for r in snaps]
    t_base = min(starts)
    tids = {name: i + 1
            for i, name in enumerate(sorted({r["name"] for r in spans}))}
    for name, tid in tids.items():
        events += _meta(0, "", tid=tid, tname=f"span:{name}")[1:]
    for rec in spans:
        dur = float(rec.get("dur", 0.0))
        args = {k: v for k, v in rec.items()
                if k not in ("kind", "t", "t0", "name", "dur")}
        events.append({
            "ph": "X", "pid": 0, "tid": tids[rec["name"]],
            "name": rec["name"],
            "ts": (rec["t"] - dur - t_base) * _US,
            "dur": max(dur * _US, 0.01),
            "args": args,
        })
    for rec in snaps:
        ts = (rec["t"] - t_base) * _US
        for mname, m in sorted(rec.get("metrics", {}).items()):
            val = m.get("value")
            if isinstance(val, dict):       # histogram state -> mean track
                val = val.get("mean")
            if isinstance(val, (int, float)):
                events.append({"ph": "C", "pid": 0, "tid": 0, "name": mname,
                               "ts": ts, "args": {mname: val}})
    return events


def predicted_schedule(halo: dict, n_steps: int,
                       bench: Optional[dict] = None) -> dict:
    """Deterministic per-step phase layout from the analytic models.

    ``halo`` is a ``halo_stats`` record (``data`` holds the plan stats,
    ``critical_path`` the backend's chained-bytes model).  Durations are
    seconds; the caller scales to trace microseconds.
    """
    data = halo.get("data", halo)
    lat, ov = data["latency"], data["overlap"]
    fused = halo.get("critical_path", "serialized") == "fused"
    t_dir = lat["fused_time_s"] if fused else lat["serialized_time_s"]
    exposed = float(ov["exposed_phases_per_step"])
    stages = (exposed + float(ov["overlapped_phases_per_step"])) / 2.0
    exposed_frac = (exposed / (2.0 * stages)) if stages else 1.0
    t_comm = 2.0 * t_dir                       # fwd + rev per step
    t_exposed = t_comm * exposed_frac
    if bench and bench.get("ms_force_pass") is not None:
        t_force = float(bench["ms_force_pass"]) / 1e3
    elif bench and bench.get("ms_per_step") is not None:
        t_force = max(float(bench["ms_per_step"]) / 1e3 - t_exposed, 0.0)
    else:
        t_force = 3.0 * t_dir                  # model units: no measurement
    t_step = max(t_exposed + t_force, 1e-9)
    return {
        "n_steps": int(n_steps),
        "pipeline": ov["pipeline"],
        "depth": ov["depth"],
        "critical_path": "fused" if fused else "serialized",
        "t_step_s": t_step,
        "t_force_s": t_force,
        "t_exposed_s": t_exposed,
        "t_hidden_s": max(t_comm - t_exposed, 0.0),
        "overlapped_bytes_per_step": ov["overlapped_bytes_per_step"],
        "exchanged_bytes_per_step": ov["exchanged_bytes_per_step"],
    }


def _predicted_events(records: List[dict], n_steps: int) -> List[dict]:
    halos = iter_kind(records, "halo_stats")
    if not halos:
        return []
    halo = halos[-1]
    benches = iter_kind(records, "bench")
    steps = iter_kind(records, "step_counters")
    if steps:
        counters = steps[-1].get("data", {})
        n = max((len(v) for v in counters.values()), default=n_steps)
        n_steps = n or n_steps
    else:
        counters = {}
    sched = predicted_schedule(halo, n_steps,
                               benches[-1] if benches else None)
    t_step, t_force = sched["t_step_s"], sched["t_force_s"]
    t_exp, t_hid = sched["t_exposed_s"], sched["t_hidden_s"]
    args = {k: v for k, v in sched.items() if k != "n_steps"}

    events = _meta(1, "predicted (alpha-beta + overlap model)")
    for tid, tname in ((1, "comm exposed"), (2, "compute"),
                       (3, "comm overlapped")):
        events += _meta(1, "", tid=tid, tname=tname)[1:]
    for i in range(n_steps):
        t0 = i * t_step
        if t_exp > 0:
            events.append({"ph": "X", "pid": 1, "tid": 1, "name": "fwd halo",
                           "ts": t0 * _US, "dur": (t_exp / 2) * _US,
                           "args": args})
            events.append({"ph": "X", "pid": 1, "tid": 1, "name": "rev halo",
                           "ts": (t0 + t_exp / 2 + t_force) * _US,
                           "dur": (t_exp / 2) * _US, "args": args})
        events.append({"ph": "X", "pid": 1, "tid": 2,
                       "name": "force + integrate",
                       "ts": (t0 + t_exp / 2) * _US, "dur": t_force * _US,
                       "args": args})
        if t_hid > 0:
            events.append({"ph": "X", "pid": 1, "tid": 3,
                           "name": "overlapped halo",
                           "ts": (t0 + t_exp / 2) * _US,
                           "dur": min(t_hid, max(t_force, 1e-9)) * _US,
                           "args": args})
        for mname, vals in sorted(counters.items()):
            if i < len(vals):
                events.append({"ph": "C", "pid": 1, "tid": 0, "name": mname,
                               "ts": t0 * _US, "args": {mname: vals[i]}})
    return events


def to_trace(records: List[dict], n_steps: int = 8) -> dict:
    """Build the Chrome trace-event document from registry records."""
    events = _measured_events(records) + _predicted_events(records, n_steps)
    other: Dict[str, object] = {"generator": "python -m repro.obs",
                                "n_records": len(records)}
    halos = iter_kind(records, "halo_stats")
    if halos:
        other["backend"] = halos[-1].get("backend")
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": other}


def export_trace(jsonl_path, out_path, n_steps: int = 8) -> dict:
    """JSONL in, ``trace.json`` out; returns the trace document."""
    trace = to_trace(load_jsonl(jsonl_path), n_steps=n_steps)
    with open(out_path, "w") as fh:
        json.dump(trace, fh, indent=1, sort_keys=True)
    return trace
