"""Phase tracing: named scopes in-program, span timers on the host.

Three instruments, matched to where time can hide in the pipeline:

* :meth:`PhaseTracer.scope` — ``jax.named_scope`` annotations on every
  pipeline phase (pack/send, signal release, acquire/wait, unpack,
  force tiers, integrate, rolling prune, rebin seam) so XLA profiles and
  HLO dumps carry the paper's phase vocabulary.  Scopes are pure
  metadata: they are applied *unconditionally* and cannot perturb the
  schedule — trajectories stay bitwise-identical with tracing on.

* :meth:`PhaseTracer.step_metrics` — on-device per-step event counters
  derived from the :class:`~repro.core.pipeline.ledger.SignalLedger`
  state threaded through the scan.  Enabled tracers add ``obs/*`` int32
  outputs to the step metrics dict; they are *extra outputs* computed
  from counters the carry already holds, never extra sequencing — the
  barrier structure (and therefore the trajectory) is untouched.

* :func:`span` / :func:`time_fn` — the host-side timing API every
  hand-rolled ``perf_counter`` loop in ``benchmarks/`` and
  ``launch/dryrun.py`` now shares.  ``span`` is a context manager whose
  ``sync()`` method pins async-dispatched device values so the clock
  stops only after the work is done (the ``md_worker`` bug class RA008
  lints against); ``time_fn`` is the warmup+iters median loop.
"""
from __future__ import annotations

import contextlib
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

# the phase vocabulary (paper Fig. 6 lanes); scopes are free-form but
# these names are what the exporter and README document.
PHASES = (
    "pack_send",          # gather halo payload + issue puts (fwd)
    "fwd_release",        # coordinate put-with-signal released
    "fwd_acquire",        # consumer's signal wait before reading halo
    "force",              # extended-block pair forces (tier ladder)
    "rev_release",        # force-return put released at fill time
    "rev_acquire",        # integrator's wait on returned forces
    "integrate_begin",    # kick-drift half step
    "integrate_finish",   # final kick
    "roll_prune",         # rolling inner prune between rebins
    "rebin_seam",         # rebin/migration gather at the block seam
)


@dataclass(frozen=True)
class PhaseTracer:
    """Per-engine tracing switch, threaded into :class:`StepPipeline`.

    ``scope`` is always active (metadata-only).  ``step_metrics`` is the
    part that grows the program's output signature, so it is gated on
    ``enabled`` — the default :data:`NULL_TRACER` adds nothing and the
    compiled program is byte-for-byte the pre-obs one.
    """

    enabled: bool = False

    def scope(self, name: str):
        """Named scope ``obs.<name>`` for one pipeline phase."""
        return jax.named_scope(f"obs.{name}")

    def step_metrics(self, ledger, led) -> Dict[str, jnp.ndarray]:
        """Per-step ledger counters as extra ``obs/*`` metric outputs."""
        if not self.enabled:
            return {}
        return {
            "obs/in_flight": jnp.asarray(ledger.in_flight(led), jnp.int32),
            "obs/released": jnp.asarray(led.released.sum(), jnp.int32),
            "obs/acquired": jnp.asarray(led.acquired.sum(), jnp.int32),
            "obs/clobbers": jnp.asarray(led.clobbers.sum(), jnp.int32),
        }


NULL_TRACER = PhaseTracer(enabled=False)


def is_obs_metric(key: str) -> bool:
    """True for metric keys owned by tracing (``obs/`` prefix)."""
    return key.startswith("obs/")


def strip_obs_metrics(metrics: Dict[str, Any]) -> Dict[str, Any]:
    """The physics-only view of a step-metrics dict."""
    return {k: v for k, v in metrics.items() if not is_obs_metric(k)}


# --------------------------------------------------------------------------
# host-side spans
# --------------------------------------------------------------------------

class Span:
    """One timed host-side region; ``dur`` is valid after the ``with``."""

    __slots__ = ("name", "meta", "t0", "dur", "_sync")

    def __init__(self, name: str, meta: dict):
        self.name = name
        self.meta = meta
        self.t0 = 0.0
        self.dur = 0.0
        self._sync: Any = None

    def sync(self, tree):
        """Register device values to ``block_until_ready`` before the
        clock stops (returns ``tree`` so call sites stay one-liners)."""
        self._sync = (tree,) if self._sync is None else self._sync + (tree,)
        return tree


@contextlib.contextmanager
def span(name: str, registry=None, **meta):
    """Time a host-side region on ``perf_counter``.

    Any value passed through ``sp.sync(...)`` is blocked on before the
    stop-read, so async-dispatched device work is inside the measurement.
    With a registry, emits a ``span`` record and observes the duration in
    the ``span/<name>`` histogram.
    """
    sp = Span(name, meta)
    sp.t0 = time.perf_counter()
    try:
        yield sp
    finally:
        if sp._sync is not None:
            jax.block_until_ready(sp._sync)
        sp.dur = time.perf_counter() - sp.t0
        if registry is not None:
            registry.emit("span", name=name, t0=sp.t0, dur=sp.dur, **meta)
            registry.histogram(f"span/{name}").observe(sp.dur)


@dataclass
class TimingResult:
    """Per-iteration wall times from :func:`time_fn` (seconds)."""

    name: str
    times: List[float]

    @property
    def median(self) -> float:
        vs = sorted(self.times)
        return vs[len(vs) // 2]

    @property
    def best(self) -> float:
        return min(self.times)

    @property
    def mean(self) -> float:
        return sum(self.times) / len(self.times)


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 10,
            name: Optional[str] = None, registry=None) -> TimingResult:
    """Median-of-``iters`` timing with compile warmup and a hard
    ``block_until_ready`` inside every measured iteration."""
    label = name or getattr(fn, "__name__", "fn")
    for _ in range(max(0, warmup)):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    result = TimingResult(name=label, times=times)
    if registry is not None:
        registry.emit("timing", name=label, iters=len(times),
                      median_s=result.median, best_s=result.best)
        registry.histogram(f"timing/{label}").observe(result.median)
    return result
