"""Typed metrics registry with per-block snapshots and JSONL export.

The repo's observability spine: every layer that used to return a bare
stats dict (``MDEngine.halo_stats`` / ``overlap_stats`` / ``pair_stats``,
``SignalLedger.summary``, ``HaloPlan.stats``, the PR5 overflow monitor,
``engine.sched_history``) still does — and *also* publishes the same
numbers here as typed instruments and structured records, so one JSONL
file carries the whole run:

* :class:`Counter` / :class:`Gauge` / :class:`Histogram` — live,
  get-or-create instruments (``registry.counter("md/steps").inc(8)``);
* :meth:`MetricsRegistry.emit` — append a structured record (``kind`` +
  free-form JSON-safe fields): halo/overlap/pair stats, schedule
  updates, host-side spans;
* :meth:`MetricsRegistry.snapshot` — freeze every instrument's current
  value into one record (the per-block heartbeat);
* :meth:`MetricsRegistry.to_jsonl` — one record per line, the input
  format of the Perfetto exporter (``python -m repro.obs``).

Instruments are process-local and lock-protected; records are plain
dicts so the file format stays greppable and diff-able.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Dict, Iterable, List, Optional


def jsonsafe(value: Any) -> Any:
    """Recursively coerce numpy scalars/arrays and tuples to JSON types."""
    if isinstance(value, dict):
        return {str(k): jsonsafe(v) for k, v in value.items()}
    if isinstance(value, (list, tuple)):
        return [jsonsafe(v) for v in value]
    if isinstance(value, (str, int, float, bool)) or value is None:
        return value
    if hasattr(value, "tolist"):          # numpy / jax scalars and arrays
        return jsonsafe(value.tolist())
    if hasattr(value, "item"):
        return jsonsafe(value.item())
    return repr(value)


class Counter:
    """Monotone integer counter (events, steps, overflow blocks)."""

    kind = "counter"

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> int:
        if n < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease")
        self.value += int(n)
        return self.value

    def state(self) -> Any:
        return self.value


class Gauge:
    """Last-write-wins scalar (schedule rows, prune ratio, occupancy)."""

    kind = "gauge"

    def __init__(self, name: str):
        self.name = name
        self.value: Optional[float] = None

    def set(self, v: float) -> float:
        self.value = float(v)
        return self.value

    def state(self) -> Any:
        return self.value


class Histogram:
    """Streaming distribution (span durations, per-block timings)."""

    kind = "histogram"

    def __init__(self, name: str):
        self.name = name
        self.values: List[float] = []

    def observe(self, v: float) -> None:
        self.values.append(float(v))

    @property
    def count(self) -> int:
        return len(self.values)

    def state(self) -> Any:
        if not self.values:
            return {"count": 0}
        vs = sorted(self.values)
        n = len(vs)
        return {
            "count": n,
            "sum": sum(vs),
            "min": vs[0],
            "max": vs[-1],
            "mean": sum(vs) / n,
            "p50": vs[n // 2],
            "p95": vs[min(n - 1, (19 * n) // 20)],
        }


_INSTRUMENTS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Instruments + an append-only record log, exported as JSONL."""

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: Dict[str, Any] = {}
        self._records: List[dict] = []

    # -- instruments (get-or-create; kind clashes are programming errors) --

    def _instrument(self, kind: str, name: str):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = _INSTRUMENTS[kind](name)
                self._instruments[name] = inst
            elif inst.kind != kind:
                raise ValueError(
                    f"metric {name!r} is a {inst.kind}, not a {kind}")
            return inst

    def counter(self, name: str) -> Counter:
        return self._instrument("counter", name)

    def gauge(self, name: str) -> Gauge:
        return self._instrument("gauge", name)

    def histogram(self, name: str) -> Histogram:
        return self._instrument("histogram", name)

    # -- records -----------------------------------------------------------

    def emit(self, kind: str, **fields) -> dict:
        """Append one structured record (fields are made JSON-safe)."""
        rec = {"kind": str(kind), "t": time.time()}
        rec.update(jsonsafe(fields))
        with self._lock:
            self._records.append(rec)
        return rec

    def snapshot(self, label: str = "", **extra) -> dict:
        """Freeze every instrument's current state into one record."""
        with self._lock:
            metrics = {name: {"kind": inst.kind, "value": inst.state()}
                       for name, inst in sorted(self._instruments.items())}
        return self.emit("snapshot", label=label, metrics=metrics, **extra)

    @property
    def records(self) -> List[dict]:
        with self._lock:
            return list(self._records)

    def metrics(self) -> Dict[str, Any]:
        """Flat ``name -> current value`` view (for tables/tests)."""
        with self._lock:
            return {name: inst.state()
                    for name, inst in sorted(self._instruments.items())}

    def clear(self) -> None:
        with self._lock:
            self._instruments.clear()
            self._records.clear()

    # -- persistence -------------------------------------------------------

    def to_jsonl(self, path) -> int:
        """Write every record as one JSON line; returns the line count."""
        recs = self.records
        with open(path, "w") as fh:
            for rec in recs:
                fh.write(json.dumps(rec, sort_keys=True) + "\n")
        return len(recs)


def load_jsonl(path) -> List[dict]:
    """Read a registry JSONL file back into a record list."""
    out = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def iter_kind(records: Iterable[dict], kind: str) -> List[dict]:
    return [r for r in records if r.get("kind") == kind]


_default: Optional[MetricsRegistry] = None
_default_lock = threading.Lock()


def default_registry() -> MetricsRegistry:
    """The process-wide registry engines publish to unless given one."""
    global _default
    with _default_lock:
        if _default is None:
            _default = MetricsRegistry()
        return _default
