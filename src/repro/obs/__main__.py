"""``python -m repro.obs`` — render or gate observability artifacts.

Two subcommands (``export`` is the default when the first argument is a
metrics JSONL path):

* ``export METRICS.jsonl [--out trace.json] [--steps N]`` — build the
  Chrome/Perfetto trace with measured + predicted lanes (open at
  https://ui.perfetto.dev).
* ``gate --baseline results/BENCH_pipeline.json --current NEW.json``
  — the CI drift check; exits nonzero and prints each finding when the
  current run left the baseline's tolerance envelope.
"""
from __future__ import annotations

import argparse
import sys

from repro.obs.gate import gate_files
from repro.obs.perfetto import export_trace


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] not in ("export", "gate", "-h", "--help"):
        argv.insert(0, "export")

    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="observability CLI: Perfetto export + perf gate")
    sub = ap.add_subparsers(dest="cmd", required=True)

    ex = sub.add_parser("export", help="metrics JSONL -> trace.json")
    ex.add_argument("jsonl", help="metrics JSONL from a MetricsRegistry")
    ex.add_argument("--out", default="trace.json",
                    help="output trace path (default: trace.json)")
    ex.add_argument("--steps", type=int, default=8,
                    help="predicted-lane steps when no step counters "
                         "were recorded (default: 8)")

    ga = sub.add_parser("gate", help="drift-check a bench file")
    ga.add_argument("--baseline", required=True,
                    help="checked-in BENCH_pipeline.json")
    ga.add_argument("--current", required=True,
                    help="freshly generated bench file")

    args = ap.parse_args(argv)
    if args.cmd == "export":
        trace = export_trace(args.jsonl, args.out, n_steps=args.steps)
        print(f"wrote {args.out}: {len(trace['traceEvents'])} events "
              f"({args.jsonl}: {trace['otherData']['n_records']} records)")
        return 0

    problems = gate_files(args.baseline, args.current)
    for p in problems:
        print(f"perf-gate: {p}")
    print(f"perf-gate: {len(problems)} finding(s) "
          f"({args.current} vs {args.baseline})")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
