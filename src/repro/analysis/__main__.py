"""``python -m repro.analysis`` — the repo's static-analysis entry point.

Runs both layers and exits nonzero on any finding:

1. the hazard linter (``RA001``..) over ``src/repro`` (or ``--paths``);
2. the comm-schedule verifier over the full PR4 conformance grid plus
   the PR5 prune-axis grid (and, with ``--config``, ad-hoc cells).

``--report results/analysis_report.json`` writes the machine-readable
report CI uploads as an artifact.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis import grids
from repro.analysis.lint import RULES, lint_paths
from repro.analysis.schedule_verifier import ConfigError, verify_schedule


def _default_root() -> str:
    return str(Path(__file__).resolve().parents[1])    # src/repro


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static comm-schedule verifier + JAX/Pallas linter")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/dirs to lint (default: the repro package)")
    ap.add_argument("--report", metavar="PATH", default=None,
                    help="write the JSON report here (CI artifact)")
    ap.add_argument("--no-lint", action="store_true",
                    help="skip the hazard linter")
    ap.add_argument("--no-verify", action="store_true",
                    help="skip the schedule-verifier grids")
    ap.add_argument("--rules", action="store_true",
                    help="print the lint rule table and exit")
    args = ap.parse_args(argv)

    if args.rules:
        for rule in RULES.values():
            print(f"{rule.code}  {rule.name:<26} {rule.summary}")
        return 0

    failed = False
    report = {"lint": None, "verifier": None}

    if not args.no_lint:
        paths = args.paths or [_default_root()]
        diags, n_files = lint_paths(paths)
        for d in diags:
            print(d.format())
        print(f"lint: {len(diags)} finding(s) over {n_files} file(s)")
        report["lint"] = {
            "n_files": n_files,
            "n_findings": len(diags),
            "findings": [{"path": d.path, "line": d.line, "col": d.col,
                          "code": d.code, "message": d.message}
                         for d in diags],
            "rules": {r.code: {"name": r.name, "summary": r.summary}
                      for r in RULES.values()},
        }
        failed |= bool(diags)

    if not args.no_verify:
        cells = grids.full_grid()
        unsafe, errors = [], []
        for cfg in cells:
            try:
                rep = verify_schedule(cfg)
            except ConfigError as e:
                errors.append({"config": repr(cfg), "error": str(e)})
                continue
            if not rep.safe:
                unsafe.append(rep)
        print(f"verifier: {len(cells)} grid config(s), "
              f"{len(unsafe)} unsafe, {len(errors)} rejected")
        for rep in unsafe:
            print(rep.summary())
            print(rep.counterexample())
        for err in errors:
            print(f"rejected: {err['config']}: {err['error']}")
        report["verifier"] = {
            "n_configs": len(cells),
            "all_safe": not unsafe and not errors,
            "unsafe": [rep.to_dict() for rep in unsafe],
            "config_errors": errors,
        }
        failed |= bool(unsafe) or bool(errors)

    if args.report:
        out = Path(args.report)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(report, indent=2, sort_keys=True))
        print(f"report written to {out}")

    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
