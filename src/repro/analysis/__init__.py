"""repro.analysis: static comm-schedule verifier + JAX/Pallas hazard linter.

Two layers, both promoted to build-time/CI gates:

* :mod:`repro.analysis.schedule_verifier` — symbolically replays the
  put-with-signal protocol any ``(backend, pipeline mode, depth, width,
  pulses, nstprune, overlap_rebin)`` configuration would emit, without
  tracing or running the program, and decides window-safety /
  acquire-before-release / slot-clobber / drain-leaves-zero-in-flight by
  exhaustive slot-state enumeration.  ``StepPipeline.build`` and
  ``MDEngine.__init__`` reject unsafe configs with the counterexample
  event trace in the error (escape hatch: ``verify="warn"``).

* :mod:`repro.analysis.lint` — AST rules (``RA001``..) for the JAX/Pallas
  pitfalls this codebase has repeatedly hand-fixed; run via
  ``python -m repro.analysis`` (nonzero exit on findings).
"""
from repro.analysis.lint import (
    RULES,
    Diagnostic,
    Rule,
    lint_file,
    lint_paths,
)
from repro.analysis.schedule_verifier import (
    CommEvent,
    ConfigError,
    EventSegment,
    ScheduleConfig,
    ScheduleReport,
    ScheduleVerificationError,
    Violation,
    check_halo_config,
    check_md_config,
    extract_events,
    gate_md_build,
    gate_pipeline_build,
    gate_schedule,
    probe_steps,
    verify_build,
    verify_schedule,
)

__all__ = [
    "RULES", "Rule", "Diagnostic", "lint_file", "lint_paths",
    "CommEvent", "EventSegment", "Violation", "ScheduleConfig",
    "ScheduleReport", "ConfigError", "ScheduleVerificationError",
    "check_halo_config", "check_md_config", "extract_events",
    "gate_md_build", "gate_pipeline_build", "gate_schedule",
    "probe_steps", "verify_build", "verify_schedule",
]
