"""Static comm-schedule verifier: prove put-with-signal safety pre-trace.

The paper's GPU-initiated halo exchange stands on its signal protocol: a
``nvshmem_put_signal_nbi`` that lands on a still-outstanding buffer slot,
or an ``acquire_wait`` that returns before the matching deposit, corrupts
trajectories silently.  The runtime :class:`~repro.core.pipeline.ledger.
SignalLedger` *counts* those violations after they happen; this module
decides them **before a single step is traced**, by symbolically replaying
the exact release/acquire event sequence :class:`~repro.core.pipeline.
StepPipeline` emits for a configuration:

* mode ``"off"``   — per step: release fwd -> acquire fwd -> release rev
  -> acquire rev on the single slot (the serialized reference chain);
* mode ``"double_buffer"`` (depth ``d``, acquire skew ``window`` ``w``) —
  the prologue fills slot 0 and releases its force-return at fill time;
  step ``k`` acquires the deposit of step ``k - w`` from the ring, then
  runs its own forward half and releases its own slot ``k % d``; the
  epilogue drains the last ``w`` outstanding slots;
* rolling-prune sub-blocks (``nstprune``) — the block splits into
  fresh-ledger ``run_local`` chains, each preceded by the prune's own
  (immediately-acquired) coordinate exchange;
* ``overlap_rebin`` — the rebin/migration gather and (pruned backends)
  the boundary prune fused after the block's final region.

The deterministic event sequence is replayed with exhaustive slot-state
enumeration: every reachable ``(released, acquired)`` counter state of
every ``(kind, slot)`` signal is visited in program order, flagging
``SLOT_CLOBBER``, ``ACQUIRE_BEFORE_RELEASE`` and ``DRAIN_INCOMPLETE``
exactly where the runtime ledger would count them.  On top of the replay
a happens-before DAG (per-step dataflow chains, the step-boundary
``optimization_barrier`` pins, release->acquire signal edges) checks that
every slot reuse is *ordered* after the previous deposit's acquire —
``UNORDERED_REUSE`` catches schedules that only pass the linear replay by
luck (e.g. skew-2 windows with the step barrier dropped).

The whole analysis is pure Python over :mod:`repro.core.schedule` (which
is jax-free), so it runs at import/CLI speed and is promoted to a
build-time gate in ``StepPipeline.build`` / ``MDEngine.__init__``: unsafe
configurations are rejected with the counterexample event trace in the
error, with ``verify="warn"`` as the experimentation escape hatch.
"""
from __future__ import annotations

import functools
import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.schedule import make_schedule

# kept in lock-step with repro.core.pipeline.PIPELINE_MODES (asserted by
# tests); duplicated here so the analyzer imports no jax-bearing module
MODES = ("off", "double_buffer")
VERIFY_MODES = ("error", "warn", "off")

RELEASE, ACQUIRE = "release", "acquire"


class ConfigError(ValueError):
    """A configuration the verifier can reject without replaying events."""


class ScheduleVerificationError(ValueError):
    """An unsafe schedule, rejected at build time with its counterexample.

    ``report`` carries the full :class:`ScheduleReport` (verdict,
    violations, event segments) for programmatic inspection.
    """

    def __init__(self, message: str, report: "ScheduleReport"):
        super().__init__(message)
        self.report = report


# --------------------------------------------------------------------------
# event model
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class CommEvent:
    """One signal transition of the put-with-signal protocol.

    A ``release`` covers all of one ``(kind, slot)``'s pulse signals firing
    (puts issued at fill time); an ``acquire`` covers the matching
    ``acquire_wait`` completions right before the consumer reads — the
    same granularity as ``SignalLedger.release``/``acquire``.  ``step`` is
    the program step at which the event executes; ``deposit`` the step
    whose payload it concerns.  ``ledgered=False`` marks exchanges the
    runtime issues outside ledger bookkeeping (rolling-prune / rebin
    boundary traffic, self-synchronizing by construction).
    """

    op: str                 # "release" | "acquire"
    kind: str               # "fwd" | "rev"
    slot: int               # buffer ring index
    step: int               # program step executing the event
    deposit: int            # step whose deposit this event concerns
    site: str               # serial|prologue|window|drain|prune|rebin
    ledgered: bool = True

    def describe(self) -> str:
        dep = ("" if self.deposit == self.step
               else f" (deposit of step {self.deposit})")
        tag = "" if self.ledgered else " [unledgered]"
        return (f"{self.op:<7} {self.kind} slot={self.slot} "
                f"@step {self.step:<3} {self.site}{dep}{tag}")


@dataclass(frozen=True)
class EventSegment:
    """One fresh-ledger ``run_local`` invocation's event sequence."""

    label: str
    events: Tuple[CommEvent, ...]


@dataclass(frozen=True)
class Violation:
    """One protocol violation, anchored to its event index."""

    code: str               # SLOT_CLOBBER | ACQUIRE_BEFORE_RELEASE |
    #                         DRAIN_INCOMPLETE | UNORDERED_REUSE
    segment: str
    index: int              # offending event index within the segment
    message: str
    trace: Tuple[str, ...]  # counterexample event trace (formatted lines)


# --------------------------------------------------------------------------
# configuration
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ScheduleConfig:
    """Everything that determines the emitted release/acquire sequence.

    ``window`` is the acquire skew in steps: step ``k``'s force-return
    deposit is consumed at step ``k + window``.  ``StepPipeline`` always
    emits skew 1 (the integrator's serial physics chain forbids more);
    larger values describe deeper-lag schedules — an *over-deep window*
    ``window > depth`` reuses a slot before its deposit drains and is
    exactly the hazard the ring exists to prevent.  ``step_barrier``
    models the per-step ``optimization_barrier`` pin; dropping it only
    affects the happens-before (reordering) analysis, not the replay.
    """

    mode: str = "double_buffer"
    depth: int = 2
    n_steps: int = 8
    window: int = 1
    n_pulses: int = 1
    nstprune: int = 0
    overlap_rebin: bool = False
    backend: str = "fused"          # halo backend (metadata, kept in report)
    force_backend: str = "dense"    # decides the boundary-prune traffic
    step_barrier: bool = True

    @classmethod
    def from_spec(cls, axis_names: Sequence[str], widths: Sequence[int],
                  pulses: Optional[Sequence[int]] = None,
                  **kw) -> "ScheduleConfig":
        """Derive ``n_pulses`` from a halo spec's pulse schedule.

        Routes the spec through :func:`check_halo_config` first, so
        nonsense ``(widths, pulses)`` combinations fail here with the
        same actionable message the build gate raises.
        """
        sched = check_halo_config(axis_names, widths, pulses)
        return cls(n_pulses=max(1, sched.total_pulses), **kw)

    @property
    def ring_depth(self) -> int:
        """Buffer slots actually in play (mode ``off`` has no ring)."""
        return self.depth if self.mode == "double_buffer" else 1

    def validate(self) -> None:
        if self.mode not in MODES:
            raise ConfigError(f"unknown pipeline mode {self.mode!r}; "
                              f"available: {MODES}")
        if self.depth < 1:
            raise ConfigError("depth must be >= 1")
        if self.mode == "double_buffer" and self.depth < 2:
            raise ConfigError("double_buffer needs depth >= 2 (ring "
                              "slots; 2 = double-buffered halos)")
        if self.n_steps < 1:
            raise ConfigError("n_steps must be >= 1")
        if self.window < 1:
            raise ConfigError("window (acquire skew) must be >= 1: skew 0 "
                              "would consume a deposit in the region that "
                              "produces it")
        if self.n_pulses < 1:
            raise ConfigError("n_pulses must be >= 1")
        if self.nstprune < 0:
            raise ConfigError("nstprune must be >= 0 (0 disables the "
                              "rolling inner prune)")


# --------------------------------------------------------------------------
# config checks shared with the build gates
# --------------------------------------------------------------------------

def check_halo_config(axis_names: Sequence[str], widths: Sequence[int],
                      pulses: Optional[Sequence[int]] = None):
    """Validate a halo spec's decomposition before any tracing.

    Returns the :class:`~repro.core.schedule.PulseSchedule` on success.
    Raises :class:`ConfigError` (a ``ValueError``) with an actionable
    message otherwise — including the ``(widths, pulses)`` combinations
    ``make_schedule`` rejects, re-raised with their original wording so
    existing callers keep matching on it.
    """
    names = tuple(axis_names)
    dups = sorted({n for n in names if names.count(n) > 1})
    if dups:
        raise ConfigError(
            f"duplicate mesh axis names {dups} in halo spec {names}: each "
            "decomposition dim needs its own mesh axis, or pulses along "
            "distinct dims would alias one device ring")
    ws = tuple(int(w) for w in widths)
    if any(w < 0 for w in ws):
        raise ConfigError(
            f"halo widths must be >= 0, got {ws}: a negative width has no "
            "slab interpretation (use width 0 to disable a dim)")
    try:
        return make_schedule(names, ws, pulses)
    except ValueError as e:          # preserve make_schedule's wording
        raise ConfigError(str(e)) from e


def check_md_config(*, nstlist: int, nstprune: int, pipeline: str,
                    pipeline_depth: int, overlap_rebin: bool,
                    force_backend: str, inner_safety: float = 1.5,
                    r_list_factor: float = 1.08, mig_frac: float = 0.125,
                    capacity_safety: float = 2.2) -> ScheduleConfig:
    """Engine-level config check: the nonsense the tracer only hits late.

    Returns the :class:`ScheduleConfig` the engine's block programs will
    realize (so the caller can feed it straight to :func:`verify_build`).
    """
    if nstlist < 1:
        raise ConfigError(f"nstlist must be >= 1, got {nstlist}: the "
                          "block program needs at least one step between "
                          "pair-list rebuilds")
    if nstprune > nstlist:
        raise ConfigError(
            f"nstprune={nstprune} exceeds the nstlist block length "
            f"{nstlist}: the rolling inner prune would never fire inside "
            "a block — lower nstprune or raise params.nstlist")
    if nstprune and inner_safety <= 0:
        raise ConfigError(
            f"inner_safety must be > 0, got {inner_safety}: the inner "
            "tier ladder would have no capacity and every block would "
            "overflow to the outer ladder")
    if r_list_factor < 1.0:
        raise ConfigError(
            f"r_list_factor must be >= 1, got {r_list_factor}: a Verlet "
            "list radius below r_cut drops interacting pairs outright")
    if mig_frac <= 0:
        raise ConfigError(f"mig_frac must be > 0, got {mig_frac}: the "
                          "migration pool would hold zero atoms")
    if capacity_safety < 1.0:
        raise ConfigError(
            f"capacity_safety must be >= 1, got {capacity_safety}: cell "
            "slot capacity below the mean occupancy guarantees bin "
            "overflow at the first rebin")
    cfg = ScheduleConfig(mode=pipeline, depth=pipeline_depth,
                         n_steps=nstlist, nstprune=nstprune,
                         overlap_rebin=bool(overlap_rebin),
                         force_backend=force_backend)
    cfg.validate()
    return cfg


# --------------------------------------------------------------------------
# event extraction (mirrors StepPipeline._run_serial / _run_pipelined and
# the engine's block_sched sub-block unrolling)
# --------------------------------------------------------------------------

def _serial_events(n: int, step0: int) -> List[CommEvent]:
    ev = []
    for k in range(n):
        s = step0 + k
        ev.append(CommEvent(RELEASE, "fwd", 0, s, s, "serial"))
        ev.append(CommEvent(ACQUIRE, "fwd", 0, s, s, "serial"))
        ev.append(CommEvent(RELEASE, "rev", 0, s, s, "serial"))
        ev.append(CommEvent(ACQUIRE, "rev", 0, s, s, "serial"))
    return ev


def _pipelined_events(cfg: ScheduleConfig, n: int, step0: int
                      ) -> List[CommEvent]:
    d, w = cfg.depth, cfg.window
    span = d - 1
    n_full = (n - 1) // span if n > 1 else 0
    ev = []
    for k in range(n):
        s = step0 + k
        if k == 0:
            site = "prologue"
        elif k <= n_full * span:
            site = "window"
        else:
            site = "drain"
        if k >= w:
            dep = k - w
            ev.append(CommEvent(ACQUIRE, "rev", dep % d, s, step0 + dep,
                                site))
        ev.append(CommEvent(RELEASE, "fwd", k % d, s, s, site))
        ev.append(CommEvent(ACQUIRE, "fwd", k % d, s, s, site))
        ev.append(CommEvent(RELEASE, "rev", k % d, s, s, site))
    last = step0 + n - 1
    for k in range(max(0, n - w), n):
        ev.append(CommEvent(ACQUIRE, "rev", k % d, last, step0 + k,
                            "drain"))
    return ev


def _boundary_events(kinds: Sequence[str], step: int, site: str
                     ) -> List[CommEvent]:
    """Immediately-acquired exchanges outside ledger bookkeeping."""
    ev = []
    for kind in kinds:
        ev.append(CommEvent(RELEASE, kind, 0, step, step, site,
                            ledgered=False))
        ev.append(CommEvent(ACQUIRE, kind, 0, step, step, site,
                            ledgered=False))
    return ev


def extract_events(cfg: ScheduleConfig) -> Tuple[EventSegment, ...]:
    """The deterministic segment/event sequence one block would emit.

    Each segment corresponds to one fresh-ledger ``run_local`` chain
    (``StepPipeline`` re-inits its ledger per invocation, and the
    engine's rolling prune splits a block into one invocation per
    ``nstprune``-step sub-block).
    """
    run = (_serial_events if cfg.mode == "off" else
           functools.partial(_pipelined_events, cfg))
    segments: List[EventSegment] = []
    if cfg.nstprune:
        done = 0
        i = 0
        while done < cfg.n_steps:
            take = min(cfg.nstprune, cfg.n_steps - done)
            ev = _boundary_events(("fwd",), done, "prune")
            ev += run(take, done)
            segments.append(EventSegment(f"subblock[{i}](+{take})",
                                         tuple(ev)))
            done += take
            i += 1
    else:
        segments.append(EventSegment("block", tuple(run(cfg.n_steps, 0))))
    if cfg.overlap_rebin:
        ev = _boundary_events(("fwd", "rev"), cfg.n_steps, "rebin")
        if cfg.force_backend != "dense":
            ev += _boundary_events(("fwd", "fwd"), cfg.n_steps, "prune")
        segments.append(EventSegment("rebin", tuple(ev)))
    return tuple(segments)


# --------------------------------------------------------------------------
# replay + happens-before analysis
# --------------------------------------------------------------------------

def _trace(events: Sequence[CommEvent], idx: int, note: str,
           extra: Sequence[int] = ()) -> Tuple[str, ...]:
    """Counterexample window: the offending event in context."""
    mark = {idx, *extra}
    lo = max(0, min(mark) - 2)
    lines = []
    for i in range(lo, idx + 1):
        flag = ">>" if i in mark else "  "
        lines.append(f"{flag} [{i:3d}] {events[i].describe()}")
    lines.append(f"   ^ {note}")
    return tuple(lines)


def _replay_segment(seg: EventSegment) -> Tuple[List[Violation], dict,
                                                Dict[int, int]]:
    """Exhaustive slot-state enumeration over one segment's events.

    Walks the program order visiting every reachable
    ``(released, acquired)`` counter state per ``(kind, slot)`` signal;
    returns (violations, stats, acquire->release match map).
    """
    events = seg.events
    outstanding: Dict[Tuple[str, int], List[int]] = {}
    matches: Dict[int, int] = {}
    violations: List[Violation] = []
    in_flight = 0
    max_in_flight = 0
    releases = acquires = 0
    for i, ev in enumerate(events):
        key = (ev.kind, ev.slot)
        pending = outstanding.setdefault(key, [])
        if ev.op == RELEASE:
            releases += 1
            if pending:
                j = pending[0]
                violations.append(Violation(
                    "SLOT_CLOBBER", seg.label, i,
                    f"release {ev.kind} slot={ev.slot} @step {ev.step} "
                    f"lands on a still-outstanding deposit of step "
                    f"{events[j].deposit} (released @event {j}, never "
                    "acquired): the put clobbers an unconsumed buffer",
                    _trace(events, i,
                           f"clobbers the deposit released at [{j}]",
                           extra=[j])))
            pending.append(i)
            in_flight += 1
            max_in_flight = max(max_in_flight, in_flight)
        else:
            acquires += 1
            if not pending:
                violations.append(Violation(
                    "ACQUIRE_BEFORE_RELEASE", seg.label, i,
                    f"acquire {ev.kind} slot={ev.slot} @step {ev.step} "
                    "has no outstanding deposit to consume: the wait "
                    "would return before any put signalled",
                    _trace(events, i, "no matching release precedes "
                           "this acquire")))
            else:
                matches[i] = pending.pop(0)
                in_flight -= 1
    leftovers = [(k, js) for k, js in outstanding.items() if js]
    for (kind, slot), js in sorted(leftovers):
        i = js[-1]
        violations.append(Violation(
            "DRAIN_INCOMPLETE", seg.label, i,
            f"{len(js)} deposit(s) on {kind} slot={slot} still in flight "
            "at the end of the chain: the drain epilogue must leave zero "
            "outstanding signals",
            _trace(events, len(events) - 1,
                   f"deposit(s) released at {js} never acquired",
                   extra=js)))
    stats = {"releases": releases, "acquires": acquires,
             "max_in_flight": max_in_flight}
    return violations, stats, matches


def _hb_check(seg: EventSegment, matches: Dict[int, int],
              step_barrier: bool) -> List[Violation]:
    """Happens-before DAG: every slot reuse ordered after the drain.

    Nodes are the segment's events; edges are (a) per-step dataflow
    chains (events executing in one step's program region), (b) the
    step-boundary ``optimization_barrier`` pin, (c) release->acquire
    signal edges.  For each consecutive pair of releases on one
    ``(kind, slot)``, the earlier deposit's acquire must be an ancestor
    of the later release — otherwise the reuse is only safe under one
    particular linearization and a legal async reordering clobbers it.
    """
    events = seg.events
    n = len(events)
    preds: List[List[int]] = [[] for _ in range(n)]
    last_of_step: Dict[int, int] = {}
    first_of_step: Dict[int, int] = {}
    prev_same_step: Dict[int, int] = {}
    for i, ev in enumerate(events):
        if ev.step in prev_same_step:
            preds[i].append(prev_same_step[ev.step])
        prev_same_step[ev.step] = i
        first_of_step.setdefault(ev.step, i)
        last_of_step[ev.step] = i
    if step_barrier:
        steps = sorted(first_of_step)
        for a, b in zip(steps, steps[1:]):
            preds[first_of_step[b]].append(last_of_step[a])
    for acq, rel in matches.items():
        preds[acq].append(rel)
    # ancestor bitsets in index (= topological) order
    anc = [0] * n
    for i in range(n):
        bits = 0
        for p in preds[i]:
            bits |= anc[p] | (1 << p)
        anc[i] = bits
    acquired_at = {rel: acq for acq, rel in matches.items()}
    by_slot: Dict[Tuple[str, int], List[int]] = {}
    for i, ev in enumerate(events):
        if ev.op == RELEASE:
            by_slot.setdefault((ev.kind, ev.slot), []).append(i)
    violations = []
    for (kind, slot), rels in sorted(by_slot.items()):
        for r1, r2 in zip(rels, rels[1:]):
            a1 = acquired_at.get(r1)
            if a1 is None:
                continue          # replay already reported the clobber
            if not (anc[r2] >> a1) & 1:
                violations.append(Violation(
                    "UNORDERED_REUSE", seg.label, r2,
                    f"release {kind} slot={slot} @step {events[r2].step} "
                    f"is not ordered after the acquire of the previous "
                    f"deposit (step {events[r1].deposit}): no "
                    "happens-before path pins the reuse behind the "
                    "drain, so an async reordering may clobber it",
                    _trace(events, r2, f"no path from the acquire at "
                           f"[{a1}] to this reuse", extra=[r1, a1])))
    return violations


# --------------------------------------------------------------------------
# reports + entry points
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ScheduleReport:
    """Structured verdict of one configuration's static replay."""

    config: ScheduleConfig
    safe: bool
    violations: Tuple[Violation, ...]
    stats: Dict[str, int] = field(default_factory=dict)
    segments: Tuple[EventSegment, ...] = ()

    def counterexample(self) -> str:
        """Formatted event trace of the first violation ('' when safe)."""
        if self.safe:
            return ""
        v = self.violations[0]
        head = (f"{v.code} in segment {v.segment!r} "
                f"(event {v.index}): {v.message}")
        return "\n".join([head, *v.trace])

    def summary(self) -> str:
        c = self.config
        verdict = "SAFE" if self.safe else \
            f"UNSAFE ({len(self.violations)} violation(s))"
        return (f"{verdict}: mode={c.mode} depth={c.depth} "
                f"window={c.window} n_steps={c.n_steps} "
                f"n_pulses={c.n_pulses} nstprune={c.nstprune} "
                f"overlap_rebin={c.overlap_rebin} backend={c.backend} "
                f"[{self.stats.get('n_events', 0)} events / "
                f"{self.stats.get('n_segments', 0)} segment(s), "
                f"max in-flight {self.stats.get('max_in_flight', 0)}]")

    def to_dict(self) -> dict:
        """JSON-able form (the CLI's ``--report`` payload)."""
        return {
            "config": {k: getattr(self.config, k) for k in (
                "mode", "depth", "n_steps", "window", "n_pulses",
                "nstprune", "overlap_rebin", "backend", "force_backend",
                "step_barrier")},
            "safe": self.safe,
            "stats": dict(self.stats),
            "violations": [
                {"code": v.code, "segment": v.segment, "index": v.index,
                 "message": v.message, "trace": list(v.trace)}
                for v in self.violations],
        }


def verify_schedule(cfg: ScheduleConfig) -> ScheduleReport:
    """Statically verify one configuration's comm schedule.

    Raises :class:`ConfigError` for configurations with no schedule
    interpretation; otherwise always returns a report (``safe=False``
    reports carry counterexample traces).
    """
    cfg.validate()
    segments = extract_events(cfg)
    violations: List[Violation] = []
    stats = {"n_segments": len(segments), "n_events": 0, "releases": 0,
             "acquires": 0, "max_in_flight": 0}
    for seg in segments:
        vs, st, matches = _replay_segment(seg)
        violations += vs
        violations += _hb_check(seg, matches, cfg.step_barrier)
        stats["n_events"] += len(seg.events)
        stats["releases"] += st["releases"]
        stats["acquires"] += st["acquires"]
        stats["max_in_flight"] = max(stats["max_in_flight"],
                                     st["max_in_flight"])
    order = {"ACQUIRE_BEFORE_RELEASE": 0, "SLOT_CLOBBER": 1,
             "UNORDERED_REUSE": 2, "DRAIN_INCOMPLETE": 3}
    violations.sort(key=lambda v: (v.segment, v.index, order[v.code]))
    return ScheduleReport(config=cfg, safe=not violations,
                          violations=tuple(violations), stats=stats,
                          segments=segments)


def probe_steps(depth: int, nstprune: int = 0,
                n_steps: Optional[int] = None) -> Tuple[int, ...]:
    """Block lengths that exhaust the ring's reachable phase space.

    The depth-``d`` ring is periodic in ``d``: slot occupancy at step
    ``k`` depends only on ``k mod d`` and on how far the drain tail
    reaches back, so every distinct (ring phase, drain point) pair is
    realized by some ``n_steps <= 2 d + 3``.  ``nstprune`` adds the
    sub-block split points; an explicit ``n_steps`` (the engine's
    nstlist) is always probed as well.
    """
    probes = set(range(1, 2 * max(depth, 1) + 4))
    if nstprune:
        probes.update({nstprune, nstprune + 1, 2 * nstprune + 1})
    if n_steps:
        probes.add(int(n_steps))
    return tuple(sorted(probes))


@functools.lru_cache(maxsize=None)
def verify_build(*, mode: str, depth: int, n_pulses: int = 1,
                 window: int = 1, nstprune: int = 0,
                 overlap_rebin: bool = False, backend: str = "fused",
                 force_backend: str = "dense",
                 n_steps: Optional[int] = None) -> ScheduleReport:
    """Verify a build-time configuration over the exhaustive probe set.

    Replays every block length in :func:`probe_steps` and returns the
    first unsafe report found, else the largest probe's (safe) report.
    Cached: repeated builds of one configuration (every ``MDEngine``
    probes its pipeline) cost one dict lookup.
    """
    report = None
    for n in probe_steps(depth, nstprune=nstprune, n_steps=n_steps):
        report = verify_schedule(ScheduleConfig(
            mode=mode, depth=depth, n_steps=n, window=window,
            n_pulses=n_pulses, nstprune=nstprune,
            overlap_rebin=overlap_rebin, backend=backend,
            force_backend=force_backend))
        if not report.safe:
            return report
    return report


def gate_schedule(report: ScheduleReport, verify: str = "error",
                  where: str = "StepPipeline.build"
                  ) -> Optional[ScheduleReport]:
    """Promote a report to a build-time verdict.

    ``verify="error"`` raises :class:`ScheduleVerificationError` with the
    counterexample trace embedded; ``"warn"`` downgrades to a
    ``RuntimeWarning`` (the experimentation escape hatch); ``"off"`` is
    handled by callers (no report is produced at all).
    """
    if verify not in VERIFY_MODES:
        raise ValueError(f"unknown verify mode {verify!r}; "
                         f"available: {VERIFY_MODES}")
    if report.safe:
        return report
    msg = (f"{where}: statically unsafe comm schedule — "
           f"{report.summary()}\n{report.counterexample()}")
    if verify == "warn":
        warnings.warn(msg, RuntimeWarning, stacklevel=3)
        return report
    raise ScheduleVerificationError(msg, report)


def gate_pipeline_build(*, mode: str, depth: int, n_pulses: int,
                        backend: str, verify: str = "error",
                        window: int = 1) -> Optional[ScheduleReport]:
    """The gate ``StepPipeline.build`` runs before accepting a config."""
    if verify not in VERIFY_MODES:
        raise ValueError(f"unknown verify mode {verify!r}; "
                         f"available: {VERIFY_MODES}")
    if verify == "off":
        return None
    try:
        report = verify_build(mode=mode, depth=depth, n_pulses=n_pulses,
                              backend=backend, window=window)
    except ConfigError:
        if verify == "warn":
            warnings.warn("StepPipeline.build: config rejected by the "
                          "static verifier (verify='warn' keeps going)",
                          RuntimeWarning, stacklevel=3)
            return None
        raise
    return gate_schedule(report, verify, where="StepPipeline.build")


def gate_md_build(*, nstlist: int, nstprune: int, pipeline: str,
                  pipeline_depth: int, overlap_rebin: bool,
                  force_backend: str, n_pulses: int = 1,
                  verify: str = "error", **check_kw
                  ) -> Optional[ScheduleReport]:
    """The gate ``MDEngine.__init__`` runs before building programs."""
    if verify not in VERIFY_MODES:
        raise ValueError(f"unknown verify mode {verify!r}; "
                         f"available: {VERIFY_MODES}")
    if verify == "off":
        return None
    try:
        cfg = check_md_config(nstlist=nstlist, nstprune=nstprune,
                              pipeline=pipeline,
                              pipeline_depth=pipeline_depth,
                              overlap_rebin=overlap_rebin,
                              force_backend=force_backend, **check_kw)
        report = verify_build(
            mode=cfg.mode, depth=cfg.depth, n_pulses=n_pulses,
            nstprune=cfg.nstprune, overlap_rebin=cfg.overlap_rebin,
            force_backend=cfg.force_backend, n_steps=cfg.n_steps)
    except ConfigError as e:
        if verify == "warn":
            warnings.warn(f"MDEngine: config rejected by the static "
                          f"verifier (verify='warn' keeps going): {e}",
                          RuntimeWarning, stacklevel=3)
            return None
        raise
    return gate_schedule(report, verify, where="MDEngine")
