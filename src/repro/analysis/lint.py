"""AST hazard linter for the JAX/Pallas pitfalls this repo hand-fixes.

Every rule encodes a failure mode the codebase has already hit (or
guards against by idiom) while rebuilding the paper's GPU-initiated
halo exchange on TPU:

====== ==========================  =============================================
code   name                        catches
====== ==========================  =============================================
RA001  host-sync-in-traced         ``.item()`` / ``.tolist()`` /
                                   ``jax.device_get`` / ``int()``/``float()``/
                                   ``bool()`` over jnp/lax results /
                                   ``np.asarray`` inside a traced function —
                                   a host round-trip inside the block program
RA002  python-branch-on-traced     ``if``/``while``/``assert`` whose test calls
                                   jnp/lax inside a traced function — trace-time
                                   ConcretizationError (use ``lax.cond``/``where``)
RA003  side-effect-in-traced       ``print`` / ``warnings.warn`` inside a traced
                                   function — silently runs once at trace time
                                   (use ``jax.debug.print``)
RA004  kernel-dtype                jnp array constructors without an explicit
                                   dtype in kernel code — weak-type promotion
                                   drifts across backends/precisions
RA005  unpinned-pair-reduction     axis-reductions downstream of ``pair_terms``
                                   not wrapped in ``lax.optimization_barrier`` —
                                   partial-sum order then depends on how the
                                   surrounding schedule fuses, breaking the
                                   cross-backend bitwise conformance bar (PR2)
RA006  collective-axis-name        literal mesh-axis names in ``lax.psum``/
                                   ``ppermute``/... that no mesh/constant in the
                                   project declares — shard_map binding error
                                   (or worse: a silently wrong reduction)
RA007  scatter-mode                dynamic ``.at[idx].add/max/min`` without an
                                   explicit ``mode=`` — sentinel-row scatters
                                   rely on JAX's implicit out-of-bounds drop;
                                   state ``mode="drop"`` (the masked-add idiom)
RA008  unsynced-timing-span        a ``time.time()``/``perf_counter()`` span
                                   around a dispatched jax computation whose
                                   stop-read (``time...() - t0``) has no
                                   ``jax.block_until_ready`` in the window —
                                   async dispatch means the clock measures
                                   launch, not completion (use
                                   ``repro.obs.span`` / ``repro.obs.time_fn``)
RA009  bare-except-in-recovery     ``except:`` / ``except Exception`` whose
                                   handler neither re-raises nor records the
                                   error (no ``raise``, and no call that
                                   warns/logs/prints/latches a fallback) — a
                                   self-healing runtime must never silently
                                   eat a fault it cannot classify; catch the
                                   concrete types, or make the swallow loud
====== ==========================  =============================================

Suppression: append ``# noqa`` (all rules) or ``# noqa: RA005, RA007``
to the flagged line.  Traced-context detection is a deliberate
under-approximation: a function counts as traced when it is passed to a
jax transform (``lax.scan``/``cond``/..., ``jax.jit``/``vmap``/...,
``shard_map``(_norep), ``pl.pallas_call``, ``StepFns``, ``defvjp``),
named with a ``_kernel`` suffix taking ``*_ref`` args, or decorated with
a transform — helpers only ever called *from* traced code are not
chased, so the linter never false-positives on host-side code.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

__all__ = ["RULES", "Rule", "Diagnostic", "lint_paths", "lint_file",
           "iter_source_files"]


@dataclass(frozen=True)
class Rule:
    code: str
    name: str
    summary: str


RULES: Dict[str, Rule] = {r.code: r for r in (
    Rule("RA001", "host-sync-in-traced",
         "host synchronization inside a traced function"),
    Rule("RA002", "python-branch-on-traced",
         "Python control flow branching on a traced value"),
    Rule("RA003", "side-effect-in-traced",
         "host side effect inside a traced function"),
    Rule("RA004", "kernel-dtype",
         "array constructor without explicit dtype in kernel code"),
    Rule("RA005", "unpinned-pair-reduction",
         "pair reduction not pinned by lax.optimization_barrier"),
    Rule("RA006", "collective-axis-name",
         "collective over an undeclared mesh axis name"),
    Rule("RA007", "scatter-mode",
         "dynamic scatter-accumulate without explicit mode="),
    Rule("RA008", "unsynced-timing-span",
         "timing span over dispatched work stops the clock without "
         "block_until_ready"),
    Rule("RA009", "bare-except-in-recovery",
         "broad except swallows the error without re-raise or logging"),
)}


@dataclass(frozen=True)
class Diagnostic:
    path: str
    line: int
    col: int
    code: str
    message: str

    def format(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.code} "
                f"[{RULES[self.code].name}] {self.message}")


_NOQA_RE = re.compile(r"#\s*noqa(?::\s*(?P<codes>[A-Z0-9,\s]+))?",
                      re.IGNORECASE)

_TRACE_TRANSFORMS = {"scan", "cond", "while_loop", "fori_loop", "switch",
                     "associative_scan", "map", "jit", "vmap", "pmap",
                     "grad", "value_and_grad", "checkpoint", "remat",
                     "custom_vjp", "custom_jvp", "shard_map",
                     "shard_map_norep", "pallas_call", "defvjp", "defjvp",
                     "when"}
_COLLECTIVES = {"psum", "pmax", "pmin", "pmean", "ppermute", "pshuffle",
                "all_gather", "all_to_all", "psum_scatter", "axis_index"}
_CTORS = {"zeros": 2, "ones": 2, "empty": 2, "full": 3}
_TIME_READS = {"time", "perf_counter", "monotonic"}
_JIT_BINDERS = {"jit", "shard_map", "shard_map_norep", "pallas_call"}
_SYNCS = {"block_until_ready", "device_get"}


def _qual(node: ast.AST) -> Optional[str]:
    """Dotted name of a Name/Attribute chain ('lax.psum'), else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _parents(tree: ast.AST) -> Dict[ast.AST, ast.AST]:
    par: Dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            par[child] = node
    return par


def _const_str_set(node: ast.AST) -> Optional[Set[str]]:
    """{'z','y','x'} for a str constant or tuple/list of them, else None."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return {node.value}
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        out: Set[str] = set()
        for el in node.elts:
            sub = _const_str_set(el)
            if sub is None:
                return None
            out |= sub
        return out
    return None


# --------------------------------------------------------------------------
# per-file model
# --------------------------------------------------------------------------

class _FileModel:
    """Aliases, traced functions and constants of one parsed module."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.source_lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self.parents = _parents(self.tree)
        self.jnp: Set[str] = set()
        self.np: Set[str] = set()
        self.lax: Set[str] = set()
        self.jax: Set[str] = set()
        self.time_mods: Set[str] = set()
        self.time_funcs: Set[str] = set()
        self.str_consts: Dict[str, Set[str]] = {}
        self.axis_literals: Set[str] = set()
        self._collect_imports_and_consts()
        self.funcs = self._collect_funcs()
        self.partial_alias = self._collect_partial_aliases()
        self.traced, self.kernels = self._collect_traced()

    # -- imports / module constants ---------------------------------------
    def _collect_imports_and_consts(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    name = a.asname or a.name.split(".")[0]
                    if a.name == "numpy":
                        self.np.add(name)
                    elif a.name in ("jax.numpy",):
                        self.jnp.add(a.asname or "jax.numpy")
                    elif a.name == "jax.lax":
                        self.lax.add(a.asname or "lax")
                    elif a.name == "jax":
                        self.jax.add(name)
                    elif a.name == "time":
                        self.time_mods.add(name)
            elif isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                for a in node.names:
                    name = a.asname or a.name
                    if mod == "jax" and a.name == "numpy":
                        self.jnp.add(name)
                    elif mod == "jax" and a.name == "lax":
                        self.lax.add(name)
                    elif mod == "time" and a.name in _TIME_READS:
                        self.time_funcs.add(name)
        for node in self.tree.body:
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                vals = _const_str_set(node.value)
                if vals:
                    self.str_consts[node.targets[0].id] = vals
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call):
                fq = _qual(node.func) or ""
                last = fq.split(".")[-1]
                if last in ("make_mesh", "Mesh", "HaloSpec"):
                    for sub in ast.walk(node):
                        vals = _const_str_set(sub) if isinstance(
                            sub, (ast.Tuple, ast.List)) else None
                        if vals:
                            self.axis_literals |= vals
                if last == "AbstractMesh":
                    pass
        for kw_name in ("axis_names", "axis_name"):
            for node in ast.walk(self.tree):
                if isinstance(node, ast.keyword) and node.arg == kw_name:
                    vals = _const_str_set(node.value)
                    if vals:
                        self.axis_literals |= vals

    def _root(self, fq: Optional[str]) -> str:
        return fq.split(".")[0] if fq else ""

    def is_jnp(self, fq: Optional[str]) -> bool:
        return bool(fq) and (self._root(fq) in self.jnp
                             or fq.startswith("jax.numpy."))

    def is_laxish(self, fq: Optional[str]) -> bool:
        if not fq:
            return False
        root = self._root(fq)
        return (root in self.lax or fq.startswith("jax.lax.")
                or (root in self.jax and ".lax." in fq))

    def is_jaxish(self, fq: Optional[str]) -> bool:
        return bool(fq) and (self.is_jnp(fq) or self.is_laxish(fq)
                             or self._root(fq) in self.jax)

    # -- function discovery ------------------------------------------------
    def _collect_funcs(self) -> Dict[str, List[ast.AST]]:
        funcs: Dict[str, List[ast.AST]] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                funcs.setdefault(node.name, []).append(node)
        return funcs

    def _collect_partial_aliases(self) -> Dict[str, str]:
        alias: Dict[str, str] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and isinstance(node.value, ast.Call):
                fq = _qual(node.value.func) or ""
                if fq.split(".")[-1] == "partial" and node.value.args \
                        and isinstance(node.value.args[0], ast.Name):
                    alias[node.targets[0].id] = node.value.args[0].id
        return alias

    def _fn_names_of_arg(self, arg: ast.AST) -> List[str]:
        if isinstance(arg, ast.Name):
            name = self.partial_alias.get(arg.id, arg.id)
            return [name]
        if isinstance(arg, ast.Call):
            fq = _qual(arg.func) or ""
            if fq.split(".")[-1] in ("partial", "shard_map",
                                     "shard_map_norep"):
                return [n for a in arg.args
                        for n in self._fn_names_of_arg(a)]
        return []

    def _collect_traced(self) -> Tuple[Set[str], Set[str]]:
        traced: Set[str] = set()
        kernels: Set[str] = set()
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call):
                fq = _qual(node.func) or ""
                last = fq.split(".")[-1]
                if last in _TRACE_TRANSFORMS and "tree" not in fq:
                    # jax.tree.map walks pytrees at trace time, it does
                    # not enter a traced context — never treat it as one
                    names = [n for a in node.args
                             for n in self._fn_names_of_arg(a)]
                    traced.update(names)
                    if last == "pallas_call":
                        kernels.update(names)
                if last == "StepFns":
                    for kw in node.keywords:
                        traced.update(self._fn_names_of_arg(kw.value))
        for name, defs in self.funcs.items():
            for fn in defs:
                for dec in getattr(fn, "decorator_list", []):
                    target = dec.func if isinstance(dec, ast.Call) else dec
                    fq = _qual(target) or ""
                    if fq.split(".")[-1] in _TRACE_TRANSFORMS:
                        traced.add(name)
                if name.endswith("_kernel") and any(
                        a.arg.endswith("_ref")
                        for a in fn.args.args + fn.args.kwonlyargs):
                    kernels.add(name)
        return traced | kernels, kernels

    def traced_nodes(self) -> List[ast.AST]:
        out = []
        for name in sorted(self.traced):
            out.extend(self.funcs.get(name, []))
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Lambda):
                # lambdas passed to transforms: cheap over-approximation —
                # a lambda body is one expression, every rule still applies
                parent = self.parents.get(node)
                if isinstance(parent, ast.Call):
                    fq = _qual(parent.func) or ""
                    if fq.split(".")[-1] in _TRACE_TRANSFORMS:
                        out.append(node)
        return out


# --------------------------------------------------------------------------
# rule passes
# --------------------------------------------------------------------------

def _contains_jax_call(model: _FileModel, node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            fq = _qual(sub.func)
            if model.is_jnp(fq) or model.is_laxish(fq):
                return True
    return False


def _check_traced_body(model: _FileModel, body: ast.AST,
                       out: List[Diagnostic]) -> None:
    path = model.path
    for node in ast.walk(body):
        if isinstance(node, ast.Call):
            fq = _qual(node.func) or ""
            last = fq.split(".")[-1]
            if last in ("item", "tolist") and "." in fq:
                out.append(Diagnostic(
                    path, node.lineno, node.col_offset, "RA001",
                    f"`.{last}()` forces a host sync inside a traced "
                    "function; keep the value on device (or move the "
                    "read outside the block program)"))
            elif last == "device_get" and model.is_jaxish(fq):
                out.append(Diagnostic(
                    path, node.lineno, node.col_offset, "RA001",
                    "`jax.device_get` inside a traced function is a "
                    "host round-trip; hoist it out of the block program"))
            elif isinstance(node.func, ast.Name) \
                    and node.func.id in ("int", "float", "bool") \
                    and node.args \
                    and _contains_jax_call(model, node.args[0]):
                out.append(Diagnostic(
                    path, node.lineno, node.col_offset, "RA001",
                    f"`{node.func.id}()` over a jnp/lax result "
                    "concretizes a tracer (host sync); use the array "
                    "directly or `lax` arithmetic"))
            elif last in ("asarray", "array") \
                    and model._root(fq) in model.np:
                out.append(Diagnostic(
                    path, node.lineno, node.col_offset, "RA001",
                    "`np.asarray`/`np.array` on a traced value pulls it "
                    "to host; use `jnp.asarray`"))
            if isinstance(node.func, ast.Name) and node.func.id == "print":
                out.append(Diagnostic(
                    path, node.lineno, node.col_offset, "RA003",
                    "`print` inside a traced function runs once at trace "
                    "time; use `jax.debug.print`"))
            elif fq == "warnings.warn":
                out.append(Diagnostic(
                    path, node.lineno, node.col_offset, "RA003",
                    "`warnings.warn` inside a traced function fires at "
                    "trace time, not per step; warn from the host driver"))
        elif isinstance(node, (ast.If, ast.While)):
            if _contains_jax_call(model, node.test):
                kind = "if" if isinstance(node, ast.If) else "while"
                out.append(Diagnostic(
                    path, node.lineno, node.col_offset, "RA002",
                    f"Python `{kind}` on a traced value raises at trace "
                    "time; use `lax.cond`/`lax.while_loop`/`jnp.where`"))
        elif isinstance(node, ast.Assert):
            if _contains_jax_call(model, node.test):
                out.append(Diagnostic(
                    path, node.lineno, node.col_offset, "RA002",
                    "`assert` on a traced value raises at trace time; "
                    "use `checkify` or move the check to the host"))


def _check_kernel_dtypes(model: _FileModel, scope: ast.AST,
                         out: List[Diagnostic]) -> None:
    for node in ast.walk(scope):
        if not isinstance(node, ast.Call):
            continue
        fq = _qual(node.func)
        if not model.is_jnp(fq):
            continue
        last = fq.split(".")[-1]
        kwargs = {kw.arg for kw in node.keywords}
        if last in _CTORS:
            if len(node.args) < _CTORS[last] and "dtype" not in kwargs:
                out.append(Diagnostic(
                    model.path, node.lineno, node.col_offset, "RA004",
                    f"`jnp.{last}` without an explicit dtype in kernel "
                    "code; weak-type promotion drifts across backends — "
                    "pass dtype= (match the payload array)"))
        elif last == "arange" and "dtype" not in kwargs and any(
                isinstance(a, ast.Constant) and isinstance(a.value, float)
                for a in node.args):
            out.append(Diagnostic(
                model.path, node.lineno, node.col_offset, "RA004",
                "`jnp.arange` over float bounds without dtype in kernel "
                "code; pass dtype= to pin the element type"))


def _check_pair_reductions(model: _FileModel, out: List[Diagnostic]) -> None:
    for defs in model.funcs.values():
        for fn in defs:
            pt_lines = [n.lineno for n in ast.walk(fn)
                        if isinstance(n, ast.Call)
                        and (_qual(n.func) or "").split(".")[-1]
                        == "pair_terms"]
            if not pt_lines:
                continue
            first_pt = min(pt_lines)
            for node in ast.walk(fn):
                if not (isinstance(node, ast.Call)
                        and model.is_jnp(_qual(node.func))
                        and (_qual(node.func) or "").split(".")[-1]
                        == "sum"):
                    continue
                has_axis = len(node.args) >= 2 or any(
                    kw.arg == "axis" for kw in node.keywords)
                if not has_axis or node.lineno < first_pt:
                    continue
                parent = model.parents.get(node)
                while isinstance(parent, ast.UnaryOp):
                    parent = model.parents.get(parent)
                pinned = (isinstance(parent, ast.Call)
                          and (_qual(parent.func) or "").split(".")[-1]
                          == "optimization_barrier")
                if not pinned:
                    out.append(Diagnostic(
                        model.path, node.lineno, node.col_offset, "RA005",
                        "pair reduction downstream of `pair_terms` is not "
                        "wrapped in `lax.optimization_barrier`; its "
                        "partial-sum order then depends on how the "
                        "surrounding schedule fuses, breaking bitwise "
                        "cross-backend conformance (PR2)"))


def _resolve_axis_names(model: _FileModel, node: ast.AST,
                        project_consts: Dict[str, Set[str]]
                        ) -> Optional[Set[str]]:
    direct = _const_str_set(node)
    if direct is not None:
        return direct
    if isinstance(node, ast.Name):
        return model.str_consts.get(node.id, project_consts.get(node.id))
    if isinstance(node, ast.Subscript) and isinstance(node.value, ast.Name):
        return model.str_consts.get(node.value.id,
                                    project_consts.get(node.value.id))
    return None


def _check_collective_axes(model: _FileModel, declared: Set[str],
                           project_consts: Dict[str, Set[str]],
                           out: List[Diagnostic]) -> None:
    for node in ast.walk(model.tree):
        if not isinstance(node, ast.Call):
            continue
        fq = _qual(node.func) or ""
        last = fq.split(".")[-1]
        if last not in _COLLECTIVES or not model.is_laxish(fq):
            continue
        if last == "axis_index":
            axis_arg = node.args[0] if node.args else None
        else:
            axis_arg = node.args[1] if len(node.args) > 1 else None
        if axis_arg is None:
            for kw in node.keywords:
                if kw.arg in ("axis_name", "axis"):
                    axis_arg = kw.value
        if axis_arg is None:
            continue
        names = _resolve_axis_names(model, axis_arg, project_consts)
        if names is None:
            continue                      # runtime-parameterized: skip
        unknown = sorted(names - declared)
        if unknown:
            out.append(Diagnostic(
                model.path, node.lineno, node.col_offset, "RA006",
                f"collective `{last}` over axis name(s) {unknown} that "
                "no mesh/axis constant in the project declares; a "
                "shard_map binding error (or a silently wrong "
                "reduction) follows"))


def _index_is_dynamic(idx: ast.AST) -> bool:
    if isinstance(idx, ast.Constant):
        return False
    if isinstance(idx, ast.UnaryOp) and isinstance(idx.operand,
                                                   ast.Constant):
        return False
    if isinstance(idx, ast.Slice):
        return False                      # traced slice bounds error anyway
    if isinstance(idx, ast.Tuple):
        return any(_index_is_dynamic(el) for el in idx.elts)
    return True


def _check_scatter_modes(model: _FileModel, out: List[Diagnostic]) -> None:
    for node in ast.walk(model.tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in ("add", "max", "min")):
            continue
        sub = node.func.value
        if not (isinstance(sub, ast.Subscript)
                and isinstance(sub.value, ast.Attribute)
                and sub.value.attr == "at"):
            continue
        if not _index_is_dynamic(sub.slice):
            continue
        if any(kw.arg == "mode" for kw in node.keywords):
            continue
        out.append(Diagnostic(
            model.path, node.lineno, node.col_offset, "RA007",
            f"dynamic `.at[...].{node.func.attr}` without explicit "
            "mode=: sentinel/padding rows rely on JAX's implicit "
            'out-of-bounds drop — state mode="drop" (the masked-add '
            "idiom) to make the contract explicit"))


def _is_time_read(model: _FileModel, call: ast.Call) -> bool:
    fq = _qual(call.func)
    if not fq:
        return False
    parts = fq.split(".")
    if len(parts) == 1:
        return parts[0] in model.time_funcs
    return parts[0] in model.time_mods and parts[-1] in _TIME_READS


def _jit_bound_names(model: _FileModel) -> Set[str]:
    """Names (incl. attribute targets like self.step_c) bound to the
    result of a jit/shard_map/pallas_call — calling one dispatches."""
    names: Set[str] = set()
    for node in ast.walk(model.tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            fq = _qual(node.value.func) or ""
            if fq.split(".")[-1] in _JIT_BINDERS:
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        names.add(t.id)
                    elif isinstance(t, ast.Attribute):
                        names.add(t.attr)
    return names


def _is_dispatch(model: _FileModel, call: ast.Call,
                 jit_names: Set[str]) -> bool:
    fq = _qual(call.func)
    if model.is_jnp(fq) or model.is_laxish(fq):
        return True
    last = fq.split(".")[-1] if fq else ""
    return last.endswith("_fn") or last == "simulate" or last in jit_names


def _scope_nodes(scope: ast.AST) -> List[ast.AST]:
    """Descendants of ``scope``, not descending into nested functions."""
    out: List[ast.AST] = []
    stack = list(ast.iter_child_nodes(scope))
    while stack:
        n = stack.pop()
        out.append(n)
        if not isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
            stack.extend(ast.iter_child_nodes(n))
    return out


def _check_timing_spans(model: _FileModel, out: List[Diagnostic]) -> None:
    """RA008: ``t0 = time...()`` ... dispatch ... ``time...() - t0`` with no
    ``block_until_ready``/``device_get`` inside the span — jax dispatch is
    async, so the stop-read clocks the *launch*, not the computation.

    Host-side rule (no traced-context gate); matched per lexical scope so
    a start in one function never pairs with a stop-read in another.
    Attribute-target starts (``sp.t0 = perf_counter()``) are deliberately
    not matched: that is the obs span machinery itself."""
    jit_names = _jit_bound_names(model)
    scopes: List[ast.AST] = [model.tree]
    scopes += [fn for defs in model.funcs.values() for fn in defs]
    for scope in scopes:
        nodes = _scope_nodes(scope)
        starts: Dict[str, List[int]] = {}
        for n in nodes:
            if isinstance(n, ast.Assign) and len(n.targets) == 1 \
                    and isinstance(n.targets[0], ast.Name) \
                    and isinstance(n.value, ast.Call) \
                    and _is_time_read(model, n.value):
                starts.setdefault(n.targets[0].id, []).append(n.lineno)
        if not starts:
            continue
        calls = [n for n in nodes if isinstance(n, ast.Call)]
        for n in nodes:
            if not (isinstance(n, ast.BinOp) and isinstance(n.op, ast.Sub)
                    and isinstance(n.right, ast.Name)
                    and n.right.id in starts
                    and isinstance(n.left, ast.Call)
                    and _is_time_read(model, n.left)):
                continue
            opened = [ln for ln in starts[n.right.id] if ln <= n.lineno]
            if not opened:
                continue
            t_start = max(opened)
            window = [c for c in calls if t_start <= c.lineno <= n.lineno]
            dispatched = any(_is_dispatch(model, c, jit_names)
                             for c in window)
            # method-style syncs (`y.block_until_ready()`) have a non-Name
            # chain root, so check the attribute directly too
            synced = any(
                (isinstance(c.func, ast.Attribute)
                 and c.func.attr in _SYNCS)
                or (_qual(c.func) or "").split(".")[-1] in _SYNCS
                for c in window)
            if dispatched and not synced:
                out.append(Diagnostic(
                    model.path, n.lineno, n.col_offset, "RA008",
                    f"timing span `{n.right.id}` covers a dispatched jax "
                    "computation but stops the clock without "
                    "`jax.block_until_ready`; async dispatch means this "
                    "measures the launch, not the work — sync the result "
                    "before the read (or use `repro.obs.span` / "
                    "`repro.obs.time_fn`)"))


_BROAD_EXC = {"Exception", "BaseException"}
# a handler "records" the error when it calls anything that, by name,
# warns / logs / prints / emits / latches a fallback / records a report
_HANDLER_OK_RE = re.compile(
    r"(warn|warning|error|exception|critical|print|log|emit|fail|"
    r"fallback|record|latch)", re.IGNORECASE)


def _is_broad_except(type_node: Optional[ast.AST]) -> bool:
    if type_node is None:                 # bare `except:`
        return True
    if isinstance(type_node, ast.Tuple):
        return any(_is_broad_except(el) for el in type_node.elts)
    fq = _qual(type_node) or ""
    return fq.split(".")[-1] in _BROAD_EXC


def _check_except_handlers(model: _FileModel, out: List[Diagnostic]) -> None:
    """RA009: broad ``except`` that silently eats the error.

    A recovery path may catch broadly only when the handler either
    re-raises (possibly a narrower typed error) or makes the swallow
    loud — ``warnings.warn``, a logger call, ``print``, an obs
    ``emit``/``record``, or a warn-once fallback latch (the
    ``_latch_*_fallback`` idiom).  ``except SomeType:`` is never
    flagged: catching concrete types is the fix, not a violation."""
    for node in ast.walk(model.tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        if not _is_broad_except(node.type):
            continue
        handled = False
        for stmt in node.body:
            for sub in ast.walk(stmt):
                if isinstance(sub, ast.Raise):
                    handled = True
                elif isinstance(sub, ast.Call):
                    last = (_qual(sub.func) or "").split(".")[-1]
                    if last and _HANDLER_OK_RE.search(last):
                        handled = True
            if handled:
                break
        if not handled:
            caught = "bare `except:`" if node.type is None else \
                f"`except {ast.unparse(node.type)}`"
            out.append(Diagnostic(
                model.path, node.lineno, node.col_offset, "RA009",
                f"{caught} neither re-raises nor records the error; a "
                "recovery path must not silently eat faults it cannot "
                "classify — catch the concrete exception types, or "
                "warn/log/re-raise in the handler"))


# --------------------------------------------------------------------------
# drivers
# --------------------------------------------------------------------------

def _suppressed(model: _FileModel, diag: Diagnostic) -> bool:
    if diag.line - 1 >= len(model.source_lines):
        return False
    m = _NOQA_RE.search(model.source_lines[diag.line - 1])
    if not m:
        return False
    codes = m.group("codes")
    if not codes:
        return True
    return diag.code in {c.strip().upper() for c in codes.split(",")}


def iter_source_files(paths: Iterable[str]) -> List[Path]:
    files: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            files.extend(sorted(path.rglob("*.py")))
        elif path.suffix == ".py":
            files.append(path)
    return files


def _project_constants(models: Sequence[_FileModel]
                       ) -> Tuple[Dict[str, Set[str]], Set[str]]:
    consts: Dict[str, Set[str]] = {}
    declared: Set[str] = set()
    for m in models:
        for name, vals in m.str_consts.items():
            consts.setdefault(name, set()).update(vals)
            declared |= vals
        declared |= m.axis_literals
    return consts, declared


def lint_models(models: Sequence[_FileModel]) -> List[Diagnostic]:
    project_consts, declared = _project_constants(models)
    diags: List[Diagnostic] = []
    for model in models:
        out: List[Diagnostic] = []
        in_kernels_tree = "kernels" in Path(model.path).parts
        for fn in model.traced_nodes():
            _check_traced_body(model, fn, out)
            name = getattr(fn, "name", None)
            if name in model.kernels and not in_kernels_tree:
                _check_kernel_dtypes(model, fn, out)
        if in_kernels_tree:
            # whole-module scope: kernel helpers build tables/launch args
            _check_kernel_dtypes(model, model.tree, out)
        _check_pair_reductions(model, out)
        _check_collective_axes(model, declared, project_consts, out)
        _check_scatter_modes(model, out)
        _check_timing_spans(model, out)
        _check_except_handlers(model, out)
        seen = set()
        for d in out:
            key = (d.line, d.col, d.code)
            if key in seen or _suppressed(model, d):
                continue
            seen.add(key)
            diags.append(d)
    diags.sort(key=lambda d: (d.path, d.line, d.col, d.code))
    return diags


def lint_file(path: str) -> List[Diagnostic]:
    src = Path(path).read_text()
    return lint_models([_FileModel(str(path), src)])


def lint_paths(paths: Iterable[str]) -> Tuple[List[Diagnostic], int]:
    """Lint every ``*.py`` under ``paths``; returns (diagnostics, n_files).

    The project is modeled jointly so that RA006's declared-axis set
    spans all files (``AXES`` lives in ``core/md/domain.py`` but is
    consumed across the tree).
    """
    files = iter_source_files(paths)
    models = []
    for f in files:
        models.append(_FileModel(str(f), f.read_text()))
    return lint_models(models), len(files)
