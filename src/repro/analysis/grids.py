"""The PR4/PR5 conformance-matrix config grids, as verifier inputs.

One definition shared by the CLI (``python -m repro.analysis``), CI's
``static-analysis`` job and the test suite, mirroring the runtime grids
in ``tests/test_pipeline.py``:

* :func:`pr4_grid` — backend x pipeline mode x halo width x window depth
  (the 48-cell cross-backend conformance matrix, 8-step blocks);
* :func:`pr5_prune_grid` — the dual-pair-list axis: nstprune x
  (mode, depth, overlap_rebin) over 20-step (nstlist) blocks on the
  3-D signal backend with the sparse force engine.

Every cell must verify as statically safe; the CLI fails otherwise.
"""
from __future__ import annotations

from typing import Tuple

from repro.analysis.schedule_verifier import ScheduleConfig

PR4_BACKENDS = ("serialized", "fused", "pallas", "signal")
PR4_MODES = ("off", "double_buffer")
PR4_WIDTHS = (1, 2)
PR4_DEPTHS = (2, 3, 4)
PR4_STEPS = 8

PR5_NSTPRUNE = (0, 4)
PR5_CELLS = (
    ("off", 2, False),
    ("double_buffer", 2, False),
    ("double_buffer", 3, False),
    ("off", 2, True),
    ("double_buffer", 3, True),
)
PR5_STEPS = 20          # the engine's nstlist block length


def pr4_grid() -> Tuple[ScheduleConfig, ...]:
    """The 48-cell PR4 conformance matrix as schedule configs."""
    cells = []
    for backend in PR4_BACKENDS:
        for mode in PR4_MODES:
            for width in PR4_WIDTHS:
                for depth in PR4_DEPTHS:
                    cells.append(ScheduleConfig.from_spec(
                        ("z",), (width,), backend=backend, mode=mode,
                        depth=depth, n_steps=PR4_STEPS))
    return tuple(cells)


def pr5_prune_grid() -> Tuple[ScheduleConfig, ...]:
    """The PR5 dual-pair-list prune axis as schedule configs."""
    cells = []
    for nstprune in PR5_NSTPRUNE:
        for mode, depth, ovr in PR5_CELLS:
            cells.append(ScheduleConfig.from_spec(
                ("z", "y", "x"), (1, 1, 1), backend="signal", mode=mode,
                depth=depth, n_steps=PR5_STEPS, nstprune=nstprune,
                overlap_rebin=ovr, force_backend="sparse"))
    return tuple(cells)


def full_grid() -> Tuple[ScheduleConfig, ...]:
    return pr4_grid() + pr5_prune_grid()
