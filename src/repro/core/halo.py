"""N-D staged halo exchange: serialized (MPI-like) vs fused (NVSHMEM-like).

This is the paper's core algorithm re-expressed for TPU meshes.  Data is
decomposed over up to three mesh axes (Z, Y, X in global pulse order); each
device holds one block and needs a halo of width ``w_d`` from its ``+d``
neighbor along every decomposition dim (eighth-shell: one side only, forces
return on the reverse path).

Two functionally identical implementations are provided:

* :func:`exchange_fwd_serialized` — the CPU-initiated MPI baseline (paper
  Fig. 1): one full slab per pulse, pulses strictly sequential because each
  later dimension forwards data received by the earlier one.  The critical
  path is ``sum_d t(full slab_d)``.

* :func:`exchange_fwd_fused` — the GPU-initiated fused redesign (paper
  Alg. 3/4): each pulse's payload is dependency-partitioned.  Phase 0 sends
  every dimension's *independent* slab concurrently; phase ``p >= 1`` sends
  only the *dependent* (forwarded) regions of depth ``p`` — whose volume is
  smaller by a factor ``~ w/n`` per level.  The critical path is
  ``max_d t(slab_d) + sum of thin forwarded regions``.  XLA lowers the
  per-phase transfers to independent ``collective-permute`` ops that can be
  scheduled concurrently (async start/done), which on TPU plays the role the
  paper's put-with-signal plays on NVLink/InfiniBand.

Reverse (force) exchanges are the exact linear adjoints, walking the
dependency chain backwards (paper Alg. 6) and accumulating contributions.

All four exchange functions are *device-local*: they must be called inside
a ``shard_map`` over the decomposition axes.  The public entry point is
:class:`repro.core.halo_plan.HaloPlan`, which binds a schedule + mesh +
backend once and exposes shard-mapped and differentiable wrappers; the
:func:`halo_exchange` function below is a deprecated per-call shim.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh

from repro.core.schedule import PulseSchedule

Region = Tuple[int, ...]


# --------------------------------------------------------------------------
# small helpers
# --------------------------------------------------------------------------

def _perm_fwd(n: int):
    """Receive from the +1 neighbor (periodic): pairs (src, dst)."""
    return [(j, (j - 1) % n) for j in range(n)]


def _perm_rev(n: int):
    """Send back to the +1 neighbor (periodic)."""
    return [(j, (j + 1) % n) for j in range(n)]


def _slice_low(x: jnp.ndarray, axis: int, width: int) -> jnp.ndarray:
    idx = [slice(None)] * x.ndim
    idx[axis] = slice(0, width)
    return x[tuple(idx)]


def _slice_at(x: jnp.ndarray, axis: int, start: int, width: int
              ) -> jnp.ndarray:
    idx = [slice(None)] * x.ndim
    idx[axis] = slice(start, start + width)
    return x[tuple(idx)]


def _split_high(x: jnp.ndarray, axis: int, width: int):
    n = x.shape[axis] - width
    idx_body = [slice(None)] * x.ndim
    idx_body[axis] = slice(0, n)
    idx_halo = [slice(None)] * x.ndim
    idx_halo[axis] = slice(n, None)
    return x[tuple(idx_body)], x[tuple(idx_halo)]


def _add_at(x: jnp.ndarray, axis: int, start: int, width: int,
            update: jnp.ndarray):
    idx = [slice(None)] * x.ndim
    idx[axis] = slice(start, start + width)
    return x.at[tuple(idx)].add(update)  # noqa: RA007 — all-slice index


class _Shifter:
    """Applies the paper's ``coordShift``: periodic-image shift on wrap.

    When the top-rank receiver along dim ``d`` receives from rank 0 the data
    crossed the periodic boundary; feature components get ``wrap_shift[d]``
    added.  Shifts compose correctly across forwarding hops because each hop
    applies only its own dimension's shift.
    """

    def __init__(self, axis_names: Sequence[str], axis_sizes: Sequence[int],
                 wrap_shift: Optional[jnp.ndarray]):
        self.axis_names = tuple(axis_names)
        self.axis_sizes = tuple(axis_sizes)
        self.wrap_shift = wrap_shift

    def __call__(self, recv: jnp.ndarray, d: int) -> jnp.ndarray:
        if self.wrap_shift is None:
            return recv
        wrapped = lax.axis_index(self.axis_names[d]) == self.axis_sizes[d] - 1
        shift = jnp.where(wrapped, 1.0, 0.0).astype(recv.dtype) * \
            self.wrap_shift[d].astype(recv.dtype)
        return recv + shift


# --------------------------------------------------------------------------
# forward (coordinate) exchange
# --------------------------------------------------------------------------

def exchange_fwd_serialized(local: jnp.ndarray, sched: PulseSchedule,
                            axis_sizes: Sequence[int],
                            wrap_shift: Optional[jnp.ndarray] = None
                            ) -> jnp.ndarray:
    """MPI-like staged exchange: one full slab per pulse, fully sequential."""
    shifter = _Shifter(sched.axis_names, axis_sizes, wrap_shift)
    ext = local
    for pulse in sched.serialized_order():
        d, w, off = pulse.dim, pulse.width, pulse.offset
        if w == 0:
            continue
        # The slab includes halo rows received by earlier pulses: this is the
        # staged *forwarding* that forces strict pulse ordering.  A later
        # pulse of the same dim ships the next ``w`` rows of the dim's halo
        # (slab start ``off``), so multi-pulse dims tile the same region.
        slab = _slice_at(ext, d, off, w)
        recv = lax.ppermute(slab, sched.axis_names[d], _perm_fwd(axis_sizes[d]))
        recv = shifter(recv, d)
        ext = jnp.concatenate([ext, recv], axis=d)
    return ext


def exchange_fwd_fused(local: jnp.ndarray, sched: PulseSchedule,
                       axis_sizes: Sequence[int],
                       wrap_shift: Optional[jnp.ndarray] = None
                       ) -> jnp.ndarray:
    """Fused dependency-partitioned exchange (paper Alg. 3/4).

    Phase 0 ships every dimension's independent slab concurrently; deeper
    phases ship only the forwarded edge/corner regions, each derived from
    the previous phase's receives.
    """
    shifter = _Shifter(sched.axis_names, axis_sizes, wrap_shift)
    regions: Dict[Region, jnp.ndarray] = {(): local}
    for phase in sched.forward_phases():
        new: Dict[Region, jnp.ndarray] = {}
        for region in phase:
            d = max(region)
            w = sched.widths[d]
            if w == 0:
                continue
            src = regions.get(tuple(k for k in region if k != d))
            if src is None:
                continue
            slab = _slice_low(src, d, w)
            recv = lax.ppermute(slab, sched.axis_names[d],
                                _perm_fwd(axis_sizes[d]))
            new[region] = shifter(recv, d)
        regions.update(new)  # phase barrier: next phase may read these
    return _assemble(regions, sched.ndim)


def _assemble(regions: Dict[Region, jnp.ndarray], ndim: int) -> jnp.ndarray:
    """Merge region dict into the extended block by progressive concat."""
    current = dict(regions)
    for d in range(ndim - 1, -1, -1):
        merged: Dict[Region, jnp.ndarray] = {}
        for key, val in current.items():
            if d in key:
                continue
            hi = current.get(tuple(sorted(key + (d,))))
            merged[key] = val if hi is None else jnp.concatenate([val, hi],
                                                                 axis=d)
        current = merged
    return current[()]


def _decompose(ext: jnp.ndarray, sched: PulseSchedule,
               local_shape: Sequence[int]) -> Dict[Region, jnp.ndarray]:
    """Inverse of :func:`_assemble`: slice the extended block into regions."""
    regions: Dict[Region, jnp.ndarray] = {}
    for region in ((),) + sched.regions():
        idx = [slice(None)] * ext.ndim
        skip = False
        for d in range(sched.ndim):
            n, w = local_shape[d], sched.widths[d]
            if d in region:
                if w == 0:
                    skip = True
                    break
                idx[d] = slice(n, n + w)
            else:
                idx[d] = slice(0, n)
        if not skip:
            regions[region] = ext[tuple(idx)]
    return regions


# --------------------------------------------------------------------------
# reverse (force) exchange — exact adjoint of the forward copy graph
# --------------------------------------------------------------------------

def exchange_rev_serialized(ext: jnp.ndarray, sched: PulseSchedule,
                            axis_sizes: Sequence[int]) -> jnp.ndarray:
    """MPI-like reverse: return halo contributions pulse-by-pulse (x->y->z).

    Received contributions may land in still-present halo rows of earlier
    dimensions and are forwarded by the subsequent reverse pulses — the
    transpose of the staged forward path.
    """
    out = ext
    for pulse in reversed(sched.serialized_order()):
        d, w, off = pulse.dim, pulse.width, pulse.offset
        if w == 0:
            continue
        body, halo = _split_high(out, d, w)
        recv = lax.ppermute(halo, sched.axis_names[d],
                            _perm_rev(axis_sizes[d]))
        out = _add_at(body, d, off, w, recv)
    return out


def exchange_rev_fused(ext: jnp.ndarray, sched: PulseSchedule,
                       axis_sizes: Sequence[int],
                       local_shape: Sequence[int]) -> jnp.ndarray:
    """Fused reverse (paper Alg. 6): deepest regions first, faces last.

    Phase 0 returns the (tiny) deepest corners; each subsequent phase sends
    regions that have already absorbed the deeper contributions.  All sends
    within a phase are independent — the bulky face regions travel in a
    single concurrent final phase instead of three chained full slabs.
    """
    regions = _decompose(ext, sched, local_shape)
    for phase in sched.reverse_phases():
        recvs = []
        for region in phase:
            if region not in regions:
                continue
            d = max(region)
            w = sched.widths[d]
            send = regions.pop(region)
            recv = lax.ppermute(send, sched.axis_names[d],
                                _perm_rev(axis_sizes[d]))
            recvs.append((tuple(k for k in region if k != d), d, w, recv))
        for dst_key, d, w, recv in recvs:
            regions[dst_key] = _add_at(regions[dst_key], d, 0, w, recv)
    return regions[()]


# --------------------------------------------------------------------------
# deprecated wrappers (use repro.core.halo_plan.HaloPlan instead)
# --------------------------------------------------------------------------

def halo_exchange(x: jax.Array, mesh: Mesh, axis_names: Sequence[str],
                  widths: Sequence[int], mode: str = "fused",
                  direction: str = "fwd",
                  wrap_shift: Optional[jnp.ndarray] = None,
                  local_shape: Optional[Sequence[int]] = None) -> jax.Array:
    """Deprecated shim over :class:`repro.core.halo_plan.HaloPlan`.

    Build a plan once (``HaloPlan.build(HaloSpec(...), mesh)``) and call
    ``plan.fwd`` / ``plan.rev`` / ``plan.exchange`` instead; this wrapper
    rebuilds the plan on every call and exists only for migration.
    """
    import warnings

    from repro.core.halo_plan import HaloPlan, HaloSpec

    warnings.warn(
        "halo_exchange() is deprecated; build a HaloPlan "
        "(repro.core.halo_plan) once and call plan.fwd/rev/exchange",
        DeprecationWarning, stacklevel=2)
    spec = HaloSpec(axis_names=tuple(axis_names), widths=tuple(widths),
                    backend=mode)
    plan = HaloPlan.build(spec, mesh)
    if direction == "fwd":
        return plan.fwd(x, wrap_shift=wrap_shift)
    if direction == "rev":
        return plan.rev(x)
    raise ValueError(f"unknown direction {direction!r}")


def exchange_stats(sched: PulseSchedule, local_shape: Sequence[int],
                   itemsize: int, feature_elems: int = 1) -> dict:
    """Deprecated shim over ``halo_plan.compute_exchange_stats``.

    Returns the legacy key set (including the historical duplicate
    ``serialized_total_bytes`` / ``fused_total_bytes`` aliases of the
    canonical ``total_bytes``).  Use :meth:`HaloPlan.stats` instead.
    """
    import warnings

    from repro.core.halo_plan import compute_exchange_stats

    warnings.warn(
        "exchange_stats() is deprecated; use HaloPlan.stats()",
        DeprecationWarning, stacklevel=2)
    stats = dict(compute_exchange_stats(sched, local_shape, itemsize,
                                        feature_elems))
    stats["serialized_total_bytes"] = stats["total_bytes"]
    stats["fused_total_bytes"] = stats["total_bytes"]
    return stats
