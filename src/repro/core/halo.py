"""N-D staged halo exchange: serialized (MPI-like) vs fused (NVSHMEM-like).

This is the paper's core algorithm re-expressed for TPU meshes.  Data is
decomposed over up to three mesh axes (Z, Y, X in global pulse order); each
device holds one block and needs a halo of width ``w_d`` from its ``+d``
neighbor along every decomposition dim (eighth-shell: one side only, forces
return on the reverse path).

Two functionally identical implementations are provided:

* :func:`exchange_fwd_serialized` — the CPU-initiated MPI baseline (paper
  Fig. 1): one full slab per pulse, pulses strictly sequential because each
  later dimension forwards data received by the earlier one.  The critical
  path is ``sum_d t(full slab_d)``.

* :func:`exchange_fwd_fused` — the GPU-initiated fused redesign (paper
  Alg. 3/4): each pulse's payload is dependency-partitioned.  Phase 0 sends
  every dimension's *independent* slab concurrently; phase ``p >= 1`` sends
  only the *dependent* (forwarded) regions of depth ``p`` — whose volume is
  smaller by a factor ``~ w/n`` per level.  The critical path is
  ``max_d t(slab_d) + sum of thin forwarded regions``.  XLA lowers the
  per-phase transfers to independent ``collective-permute`` ops that can be
  scheduled concurrently (async start/done), which on TPU plays the role the
  paper's put-with-signal plays on NVLink/InfiniBand.

Reverse (force) exchanges are the exact linear adjoints, walking the
dependency chain backwards (paper Alg. 6) and accumulating contributions.

All four exchange functions are *device-local*: they must be called inside
a ``shard_map`` over the decomposition axes.  :func:`halo_exchange` is a
convenience wrapper that applies the shard_map for you.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.schedule import PulseSchedule, make_schedule

Region = Tuple[int, ...]


# --------------------------------------------------------------------------
# small helpers
# --------------------------------------------------------------------------

def _perm_fwd(n: int):
    """Receive from the +1 neighbor (periodic): pairs (src, dst)."""
    return [(j, (j - 1) % n) for j in range(n)]


def _perm_rev(n: int):
    """Send back to the +1 neighbor (periodic)."""
    return [(j, (j + 1) % n) for j in range(n)]


def _slice_low(x: jnp.ndarray, axis: int, width: int) -> jnp.ndarray:
    idx = [slice(None)] * x.ndim
    idx[axis] = slice(0, width)
    return x[tuple(idx)]


def _split_high(x: jnp.ndarray, axis: int, width: int):
    n = x.shape[axis] - width
    idx_body = [slice(None)] * x.ndim
    idx_body[axis] = slice(0, n)
    idx_halo = [slice(None)] * x.ndim
    idx_halo[axis] = slice(n, None)
    return x[tuple(idx_body)], x[tuple(idx_halo)]


def _add_low(x: jnp.ndarray, axis: int, width: int, update: jnp.ndarray):
    idx = [slice(None)] * x.ndim
    idx[axis] = slice(0, width)
    return x.at[tuple(idx)].add(update)


class _Shifter:
    """Applies the paper's ``coordShift``: periodic-image shift on wrap.

    When the top-rank receiver along dim ``d`` receives from rank 0 the data
    crossed the periodic boundary; feature components get ``wrap_shift[d]``
    added.  Shifts compose correctly across forwarding hops because each hop
    applies only its own dimension's shift.
    """

    def __init__(self, axis_names: Sequence[str], axis_sizes: Sequence[int],
                 wrap_shift: Optional[jnp.ndarray]):
        self.axis_names = tuple(axis_names)
        self.axis_sizes = tuple(axis_sizes)
        self.wrap_shift = wrap_shift

    def __call__(self, recv: jnp.ndarray, d: int) -> jnp.ndarray:
        if self.wrap_shift is None:
            return recv
        wrapped = lax.axis_index(self.axis_names[d]) == self.axis_sizes[d] - 1
        shift = jnp.where(wrapped, 1.0, 0.0).astype(recv.dtype) * \
            self.wrap_shift[d].astype(recv.dtype)
        return recv + shift


# --------------------------------------------------------------------------
# forward (coordinate) exchange
# --------------------------------------------------------------------------

def exchange_fwd_serialized(local: jnp.ndarray, sched: PulseSchedule,
                            axis_sizes: Sequence[int],
                            wrap_shift: Optional[jnp.ndarray] = None
                            ) -> jnp.ndarray:
    """MPI-like staged exchange: one full slab per pulse, fully sequential."""
    shifter = _Shifter(sched.axis_names, axis_sizes, wrap_shift)
    ext = local
    for pulse in sched.serialized_order():
        d, w = pulse.dim, pulse.width
        if w == 0:
            continue
        # The slab includes halo rows received by earlier pulses: this is the
        # staged *forwarding* that forces strict pulse ordering.
        slab = _slice_low(ext, d, w)
        recv = lax.ppermute(slab, sched.axis_names[d], _perm_fwd(axis_sizes[d]))
        recv = shifter(recv, d)
        ext = jnp.concatenate([ext, recv], axis=d)
    return ext


def exchange_fwd_fused(local: jnp.ndarray, sched: PulseSchedule,
                       axis_sizes: Sequence[int],
                       wrap_shift: Optional[jnp.ndarray] = None
                       ) -> jnp.ndarray:
    """Fused dependency-partitioned exchange (paper Alg. 3/4).

    Phase 0 ships every dimension's independent slab concurrently; deeper
    phases ship only the forwarded edge/corner regions, each derived from
    the previous phase's receives.
    """
    shifter = _Shifter(sched.axis_names, axis_sizes, wrap_shift)
    regions: Dict[Region, jnp.ndarray] = {(): local}
    for phase in sched.forward_phases():
        new: Dict[Region, jnp.ndarray] = {}
        for region in phase:
            d = max(region)
            w = sched.widths[d]
            if w == 0:
                continue
            src = regions.get(tuple(k for k in region if k != d))
            if src is None:
                continue
            slab = _slice_low(src, d, w)
            recv = lax.ppermute(slab, sched.axis_names[d],
                                _perm_fwd(axis_sizes[d]))
            new[region] = shifter(recv, d)
        regions.update(new)  # phase barrier: next phase may read these
    return _assemble(regions, sched.ndim)


def _assemble(regions: Dict[Region, jnp.ndarray], ndim: int) -> jnp.ndarray:
    """Merge region dict into the extended block by progressive concat."""
    current = dict(regions)
    for d in range(ndim - 1, -1, -1):
        merged: Dict[Region, jnp.ndarray] = {}
        for key, val in current.items():
            if d in key:
                continue
            hi = current.get(tuple(sorted(key + (d,))))
            merged[key] = val if hi is None else jnp.concatenate([val, hi],
                                                                 axis=d)
        current = merged
    return current[()]


def _decompose(ext: jnp.ndarray, sched: PulseSchedule,
               local_shape: Sequence[int]) -> Dict[Region, jnp.ndarray]:
    """Inverse of :func:`_assemble`: slice the extended block into regions."""
    regions: Dict[Region, jnp.ndarray] = {}
    for region in ((),) + sched.regions():
        idx = [slice(None)] * ext.ndim
        skip = False
        for d in range(sched.ndim):
            n, w = local_shape[d], sched.widths[d]
            if d in region:
                if w == 0:
                    skip = True
                    break
                idx[d] = slice(n, n + w)
            else:
                idx[d] = slice(0, n)
        if not skip:
            regions[region] = ext[tuple(idx)]
    return regions


# --------------------------------------------------------------------------
# reverse (force) exchange — exact adjoint of the forward copy graph
# --------------------------------------------------------------------------

def exchange_rev_serialized(ext: jnp.ndarray, sched: PulseSchedule,
                            axis_sizes: Sequence[int]) -> jnp.ndarray:
    """MPI-like reverse: return halo contributions pulse-by-pulse (x->y->z).

    Received contributions may land in still-present halo rows of earlier
    dimensions and are forwarded by the subsequent reverse pulses — the
    transpose of the staged forward path.
    """
    out = ext
    for pulse in reversed(sched.serialized_order()):
        d, w = pulse.dim, pulse.width
        if w == 0:
            continue
        body, halo = _split_high(out, d, w)
        recv = lax.ppermute(halo, sched.axis_names[d],
                            _perm_rev(axis_sizes[d]))
        out = _add_low(body, d, w, recv)
    return out


def exchange_rev_fused(ext: jnp.ndarray, sched: PulseSchedule,
                       axis_sizes: Sequence[int],
                       local_shape: Sequence[int]) -> jnp.ndarray:
    """Fused reverse (paper Alg. 6): deepest regions first, faces last.

    Phase 0 returns the (tiny) deepest corners; each subsequent phase sends
    regions that have already absorbed the deeper contributions.  All sends
    within a phase are independent — the bulky face regions travel in a
    single concurrent final phase instead of three chained full slabs.
    """
    regions = _decompose(ext, sched, local_shape)
    for phase in sched.reverse_phases():
        recvs = []
        for region in phase:
            if region not in regions:
                continue
            d = max(region)
            w = sched.widths[d]
            send = regions.pop(region)
            recv = lax.ppermute(send, sched.axis_names[d],
                                _perm_rev(axis_sizes[d]))
            recvs.append((tuple(k for k in region if k != d), d, w, recv))
        for dst_key, d, w, recv in recvs:
            regions[dst_key] = _add_low(regions[dst_key], d, w, recv)
    return regions[()]


# --------------------------------------------------------------------------
# public wrapper
# --------------------------------------------------------------------------

def halo_exchange(x: jax.Array, mesh: Mesh, axis_names: Sequence[str],
                  widths: Sequence[int], mode: str = "fused",
                  direction: str = "fwd",
                  wrap_shift: Optional[jnp.ndarray] = None,
                  local_shape: Optional[Sequence[int]] = None) -> jax.Array:
    """Shard-mapped halo exchange over ``mesh``.

    ``x`` is sharded over ``axis_names`` on its leading dims.  ``fwd``
    returns the per-device extended blocks re-stacked along the same axes
    (global shape grows by ``size_d * w_d`` per dim); ``rev`` consumes such
    stacked extended blocks and returns the accumulated local array.
    """
    sched = make_schedule(axis_names, widths)
    sizes = [mesh.shape[a] for a in axis_names]
    specs = P(*axis_names)

    if direction == "fwd":
        def body(local):
            fn = exchange_fwd_fused if mode == "fused" else \
                exchange_fwd_serialized
            return fn(local, sched, sizes, wrap_shift)
    elif direction == "rev":
        if local_shape is None:
            raise ValueError("rev exchange needs local_shape")
        def body(local):
            if mode == "fused":
                return exchange_rev_fused(local, sched, sizes, local_shape)
            return exchange_rev_serialized(local, sched, sizes)
    else:
        raise ValueError(f"unknown direction {direction!r}")

    return jax.shard_map(body, mesh=mesh, in_specs=specs, out_specs=specs)(x)


# --------------------------------------------------------------------------
# analytics (used by benchmarks and the roofline napkin math)
# --------------------------------------------------------------------------

def exchange_stats(sched: PulseSchedule, local_shape: Sequence[int],
                   itemsize: int, feature_elems: int = 1) -> dict:
    """Bytes moved per phase/pulse and the two critical-path models.

    ``serialized_critical_bytes`` sums each pulse's full (forwarding-
    inclusive) slab — the chained bytes of the MPI design.  For the fused
    design the per-phase transfers are concurrent, so the chained bytes are
    ``sum_p max_{region in phase p} bytes(region)``.
    """
    ndim = sched.ndim
    widths = sched.widths

    def vol(region: Region) -> int:
        v = 1
        for d in range(ndim):
            v *= widths[d] if d in region else local_shape[d]
        return v * feature_elems * itemsize

    # serialized: pulse d sends the slab of the partially-extended block
    ser_pulse_bytes = []
    shape = list(local_shape)
    for d in range(ndim):
        slab = 1
        for k in range(ndim):
            slab *= widths[d] if k == d else shape[k]
        ser_pulse_bytes.append(slab * feature_elems * itemsize)
        shape[d] += widths[d]

    fused_phases = []
    for phase in sched.forward_phases():
        fused_phases.append({
            "regions": [
                {"dims": r, "bytes": vol(r)} for r in phase
            ],
            "phase_bytes": sum(vol(r) for r in phase),
            "phase_critical_bytes": max((vol(r) for r in phase), default=0),
        })

    return {
        "serialized_pulse_bytes": ser_pulse_bytes,
        "serialized_total_bytes": sum(ser_pulse_bytes),
        "serialized_critical_bytes": sum(ser_pulse_bytes),
        "fused_phases": fused_phases,
        "fused_total_bytes": sum(p["phase_bytes"] for p in fused_phases),
        "fused_critical_bytes": sum(p["phase_critical_bytes"]
                                    for p in fused_phases),
        "dependent_fraction": sched.dependent_fraction(local_shape),
    }
