"""Core: the paper's fused halo-exchange algorithm and MD substrate.

The public halo API is plan-based: build a :class:`HaloPlan` from a frozen
:class:`HaloSpec` once, then execute it every step.  The four loose
``exchange_*`` functions remain exported as backend implementations;
``halo_exchange``/``exchange_stats`` are deprecated shims.
"""
from repro.core.halo import (
    exchange_fwd_fused,
    exchange_fwd_serialized,
    exchange_rev_fused,
    exchange_rev_serialized,
    exchange_stats,
    halo_exchange,
)
from repro.core.halo_plan import (
    HaloPlan,
    HaloSpec,
    available_backends,
    compute_exchange_stats,
    register_backend,
)
from repro.core.schedule import Pulse, PulseSchedule, make_schedule

__all__ = [
    "Pulse",
    "PulseSchedule",
    "make_schedule",
    "HaloSpec",
    "HaloPlan",
    "register_backend",
    "available_backends",
    "compute_exchange_stats",
    "halo_exchange",
    "exchange_fwd_fused",
    "exchange_fwd_serialized",
    "exchange_rev_fused",
    "exchange_rev_serialized",
    "exchange_stats",
]
