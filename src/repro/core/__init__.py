"""Core: the paper's fused halo-exchange algorithm and MD substrate."""
from repro.core.halo import (
    exchange_fwd_fused,
    exchange_fwd_serialized,
    exchange_rev_fused,
    exchange_rev_serialized,
    exchange_stats,
    halo_exchange,
)
from repro.core.schedule import Pulse, PulseSchedule, make_schedule

__all__ = [
    "Pulse",
    "PulseSchedule",
    "make_schedule",
    "halo_exchange",
    "exchange_fwd_fused",
    "exchange_fwd_serialized",
    "exchange_rev_fused",
    "exchange_rev_serialized",
    "exchange_stats",
]
