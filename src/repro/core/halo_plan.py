"""Plan-based halo exchange: one differentiable object, pluggable backends.

The paper's core design is a *persistent, pre-planned* exchange: pulse
metadata (``PulseData``, ``depOffset``, index maps, signal slots) is built
once at domain-decomposition time and then executed by GPU-initiated
kernels every step.  This module is that construct-once/execute-many seam
for the JAX reproduction:

* :class:`HaloSpec` — frozen, hashable description of the exchange (mesh
  axis names, per-dim halo widths, periodic wrap shifts, dtype / feature
  layout, backend name).

* :class:`HaloPlan` — built via :meth:`HaloPlan.build(spec, mesh)`.  It
  precomputes the :class:`~repro.core.schedule.PulseSchedule`, the per-dim
  ``ppermute`` pairs, region metadata, byte / critical-path statistics
  (:meth:`HaloPlan.stats`, absorbing the old ``exchange_stats``), and — for
  the ``"pallas"`` backend — the static index maps feeding
  :func:`repro.kernels.halo_pack.pack` / ``unpack_add``.

* ``plan.fwd(x)`` / ``plan.rev(ext)`` — shard-mapped coordinate / force
  exchanges over global arrays, plus device-local ``fwd_local`` /
  ``rev_local`` for callers that already sit inside a ``shard_map`` (the
  MD engine's fused step program).

* ``plan.exchange(x)`` — a ``jax.custom_vjp``-registered exchange whose
  adjoint *is* the fused reverse path (paper Alg. 6): ``jax.grad`` through
  a coordinate exchange automatically emits the force-return exchange.

Backends are a registry; ``"serialized"`` and ``"fused"`` wrap the staged
implementations in :mod:`repro.core.halo`, ``"pallas"`` drives the
pack/put kernels of :mod:`repro.kernels.halo_pack` (interpret mode on CPU,
with a pure-jnp oracle fallback).  New backends (double-buffered,
multi-step, NVSHMEM-alike) plug in via :func:`register_backend`.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map_norep
from repro.core import halo as _halo
from repro.core import wire as _wire
from repro.core.schedule import PulseSchedule, make_schedule

Region = Tuple[int, ...]

_UNSET = object()


# --------------------------------------------------------------------------
# spec
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class HaloSpec:
    """Frozen description of a halo exchange (hashable, jit-static).

    ``wrap_shift`` is the per-dimension periodic-image shift added to
    feature components when data crosses the periodic boundary (the
    paper's ``coordShift``); stored as a nested tuple so the spec stays
    hashable — ``HaloSpec.with_wrap_shift`` converts from arrays.
    ``dtype``/``feature_elems`` describe the payload layout and feed the
    default byte accounting in :meth:`HaloPlan.stats`.  ``pulses`` is the
    per-dim pulse count (GROMACS' two-pulse case splits a dim's halo across
    two staged pulses); ``None`` means one pulse per dim.

    ``wire_dtype`` compresses the exchanged payload on the wire
    (``None`` = dense; ``"float32"`` / ``"bfloat16"`` / ``"float16"`` =
    cast, ``"int8_ef"`` = error-feedback int8; see
    :mod:`repro.core.wire` for the measured rationale).  Compression is
    direction-asymmetric: the coordinate (forward) exchange has a
    float32 floor — f64 payloads ship f32 coordinates, f32 ships dense —
    while the named format compresses the force-return (reverse)
    exchange, whose quantization error integrates as zero-mean noise.
    Payloads are quantized before send and dequantized after receive,
    the local body never crosses the wire and stays exact, and integer
    payloads (the MD engine's ``cell_i`` index exchange) always ride
    dense.  Plan build rejects formats whose measured NVE drift exceeds
    the dense-f32 bound (:func:`repro.core.wire.gate_wire_config`).
    """

    axis_names: Tuple[str, ...]
    widths: Tuple[int, ...]
    backend: str = "fused"
    wrap_shift: Optional[Tuple[Tuple[float, ...], ...]] = None
    dtype: str = "float32"
    feature_elems: int = 1
    interpret: bool = True   # pallas backend: interpreter mode (CPU/tests)
    pulses: Optional[Tuple[int, ...]] = None
    wire_dtype: Optional[str] = None

    def __post_init__(self):
        if self.wire_dtype is not None and \
                self.wire_dtype not in _wire.WIRE_DTYPES:
            raise ValueError(
                f"unknown wire_dtype {self.wire_dtype!r}; "
                f"available: {_wire.WIRE_DTYPES} or None")
        object.__setattr__(self, "axis_names", tuple(self.axis_names))
        object.__setattr__(self, "widths",
                           tuple(int(w) for w in self.widths))
        if len(self.axis_names) != len(self.widths):
            raise ValueError("axis_names and widths must have equal length")
        if self.pulses is not None:
            object.__setattr__(self, "pulses",
                               tuple(int(n) for n in self.pulses))
        if self.wrap_shift is not None:
            object.__setattr__(
                self, "wrap_shift",
                tuple(tuple(float(v) for v in row)
                      for row in np.asarray(self.wrap_shift)))

    @property
    def ndim(self) -> int:
        return len(self.axis_names)

    def with_wrap_shift(self, wrap_shift) -> "HaloSpec":
        """Return a copy with ``wrap_shift`` taken from an array-like
        (``__post_init__`` re-normalizes to the hashable nested tuple)."""
        return dataclasses.replace(self, wrap_shift=wrap_shift)

    def wrap_shift_array(self) -> Optional[jnp.ndarray]:
        if self.wrap_shift is None:
            return None
        return jnp.asarray(np.asarray(self.wrap_shift, dtype=self.dtype))


# --------------------------------------------------------------------------
# backend registry
# --------------------------------------------------------------------------

class HaloBackend:
    """Device-local executor: both methods run *inside* a shard_map.

    ``critical_path`` names which of the two chained-bytes models in
    :meth:`HaloPlan.stats` describes this backend's execution —
    ``"serialized"`` for pulse-sequential backends, ``"fused"`` for
    phase-concurrent ones.
    """

    name: str = "?"
    critical_path: str = "serialized"

    def fwd(self, plan: "HaloPlan", local: jnp.ndarray,
            wrap_shift: Optional[jnp.ndarray]) -> jnp.ndarray:
        raise NotImplementedError

    def rev(self, plan: "HaloPlan", ext: jnp.ndarray) -> jnp.ndarray:
        raise NotImplementedError

    def _local_shape(self, plan: "HaloPlan", ext: jnp.ndarray) -> Tuple[int, ...]:
        return tuple(ext.shape[d] - plan.spec.widths[d]
                     for d in range(plan.spec.ndim))


class SerializedBackend(HaloBackend):
    """CPU-initiated MPI baseline: one full slab per pulse, sequential."""

    name = "serialized"

    def fwd(self, plan, local, wrap_shift):
        return _halo.exchange_fwd_serialized(local, plan.sched,
                                             plan.axis_sizes, wrap_shift)

    def rev(self, plan, ext):
        return _halo.exchange_rev_serialized(ext, plan.sched,
                                             plan.axis_sizes)


class FusedBackend(HaloBackend):
    """GPU-initiated fused redesign: dependency-partitioned phases."""

    name = "fused"
    critical_path = "fused"

    def fwd(self, plan, local, wrap_shift):
        return _halo.exchange_fwd_fused(local, plan.sched, plan.axis_sizes,
                                        wrap_shift)

    def rev(self, plan, ext):
        return _halo.exchange_rev_fused(ext, plan.sched, plan.axis_sizes,
                                        self._local_shape(plan, ext))


def _latch_halo_fallback(plan, e: Exception, context: str) -> None:
    """Downgrade this plan to its jnp/ppermute oracle and warn once.

    Trace-time kernel failures are backend-specific and expected (the
    documented CPU fallback); the latch makes the downgrade loud exactly
    once per plan instead of silently eating the error every pulse."""
    if not plan._pallas_broken:
        warnings.warn(
            f"Pallas halo kernel {context} ({type(e).__name__}: {e}); "
            "this halo plan falls back to its jnp/ppermute oracle for "
            "the rest of this process", RuntimeWarning, stacklevel=3)
    plan._pallas_broken = True


class PallasBackend(HaloBackend):
    """Pack/unpack through the Pallas kernels of ``kernels.halo_pack``.

    Realizes each pulse as pack (device-initiated gather into a contiguous
    send buffer, paper Alg. 3 line 7) -> ``ppermute`` (the put) ->
    concat / scatter-add (the unpack).  Index maps are static per local
    shape and cached on the plan — the analogue of the paper's DD-time
    index-map build.  Falls back to pure-jnp oracles when the Pallas
    kernels are unavailable on the current backend.  Pulses execute in
    serialized (forwarding-chained) order, so the serialized
    critical-path model applies.
    """

    name = "pallas"
    critical_path = "serialized"

    # -- kernel dispatch with oracle fallback ------------------------------

    def _pack(self, plan, src2d: jnp.ndarray, idx: np.ndarray,
              wire: Optional[str] = None) -> jnp.ndarray:
        """Pack rows into the send buffer, optionally quantizing into the
        wire dtype inside the kernel (fused quantize-into-pack: the wire
        format never materializes in HBM — only the packed send buffer
        and the received rows are wire-dtyped)."""
        jidx = jnp.asarray(idx)
        if not plan._pallas_broken:
            try:
                from repro.kernels import halo_pack
                return halo_pack.pack(src2d, jidx,
                                      interpret=plan.spec.interpret,
                                      wire_dtype=wire)
            except Exception as e:  # pragma: no cover - backend-specific
                _latch_halo_fallback(plan, e, "pack failed")
        rows = jnp.take(src2d, jidx, axis=0)
        return rows if wire is None else rows.astype(jnp.dtype(wire))

    def _unpack_add(self, plan, dst2d: jnp.ndarray, idx: np.ndarray,
                    rows: jnp.ndarray) -> jnp.ndarray:
        jidx = jnp.asarray(idx)
        if not plan._pallas_broken:
            try:
                from repro.kernels import halo_pack
                return halo_pack.unpack_add(dst2d, jidx, rows,
                                            interpret=plan.spec.interpret)
            except Exception as e:  # pragma: no cover - backend-specific
                _latch_halo_fallback(plan, e, "unpack_add failed")
        return dst2d.at[jidx].add(rows, mode="drop")

    # -- static index maps (built once per local shape, cached) ------------

    @staticmethod
    def _rows_along(shape: Sequence[int], d: int, lo: int, hi: int
                    ) -> np.ndarray:
        """Row ids of ``reshape(prod(shape[:d+1]), -1)`` whose coordinate
        along axis ``d`` lies in ``[lo, hi)``."""
        n_rows = int(np.prod(shape[:d + 1], dtype=np.int64))
        coord = np.arange(n_rows, dtype=np.int64) % shape[d]
        return np.nonzero((coord >= lo) & (coord < hi))[0].astype(np.int32)

    def _maps(self, plan, local_shape: Tuple[int, ...]):
        cached = plan._index_maps.get(local_shape)
        if cached is not None:
            return cached
        fwd_maps, rev_maps = [], []
        shape = list(local_shape)
        for pulse in plan.sched.serialized_order():
            d, w, off = pulse.dim, pulse.width, pulse.offset
            if w:
                fwd_maps.append(self._rows_along(shape, d, off, off + w))
                shape[d] += w
            else:
                fwd_maps.append(None)
        for pulse in reversed(plan.sched.serialized_order()):
            d, w, off = pulse.dim, pulse.width, pulse.offset
            if w:
                n = shape[d] - w
                pack_idx = self._rows_along(shape, d, n, shape[d])
                shape[d] = n
                add_idx = self._rows_along(shape, d, off, off + w)
                rev_maps.append((pack_idx, add_idx))
            else:
                rev_maps.append(None)
        plan._index_maps[local_shape] = (tuple(fwd_maps), tuple(rev_maps))
        return plan._index_maps[local_shape]

    # -- exchange ----------------------------------------------------------

    def fwd(self, plan, local, wrap_shift):
        sched = plan.sched
        shifter = _halo._Shifter(sched.axis_names, plan.axis_sizes,
                                 wrap_shift)
        nd = plan.spec.ndim
        local_shape = tuple(local.shape[:nd])
        fwd_maps, _ = self._maps(plan, local_shape)
        # the coordinate direction's f32 floor ships f32 send buffers for
        # wide payloads (pack casts, receive side casts back before the
        # wrap shift); the payload is already wire-gridded at the plan
        # seam so the cast is exact and results stay bitwise-identical
        # to the serialized reference
        wire = plan.wire_pack_dtype(local.dtype)
        ext = local
        for pulse, idx in zip(sched.serialized_order(), fwd_maps):
            if idx is None:
                continue
            d, w = pulse.dim, pulse.width
            shape = ext.shape
            src2d = ext.reshape(math.prod(shape[:d + 1]), -1)
            slab = self._pack(plan, src2d, idx, wire).reshape(
                shape[:d] + (w,) + shape[d + 1:])
            recv = lax.ppermute(slab, sched.axis_names[d], plan.fwd_perms[d])
            recv = recv.astype(local.dtype)       # dequantize-after-receive
            recv = shifter(recv, d)
            ext = jnp.concatenate([ext, recv], axis=d)
        return ext

    def rev(self, plan, ext):
        sched = plan.sched
        nd = plan.spec.ndim
        local_shape = self._local_shape(plan, ext)
        _, rev_maps = self._maps(plan, local_shape)
        out = ext
        for pulse, maps in zip(reversed(sched.serialized_order()), rev_maps):
            if maps is None:
                continue
            pack_idx, add_idx = maps
            d, w = pulse.dim, pulse.width
            shape = out.shape
            n = shape[d] - w
            src2d = out.reshape(math.prod(shape[:d + 1]), -1)
            halo_rows = self._pack(plan, src2d, pack_idx)
            slab = halo_rows.reshape(shape[:d] + (w,) + shape[d + 1:])
            recv = lax.ppermute(slab, sched.axis_names[d], plan.rev_perms[d])
            body = lax.slice_in_dim(out, 0, n, axis=d)
            bshape = body.shape
            body2d = body.reshape(math.prod(bshape[:d + 1]), -1)
            rows = recv.reshape(add_idx.shape[0], -1)
            body2d = self._unpack_add(plan, body2d, add_idx, rows)
            out = body2d.reshape(bshape)
        return out


_BACKENDS: Dict[str, Callable[[], HaloBackend]] = {}


def register_backend(name: str, factory: Callable[[], HaloBackend]) -> None:
    """Register a halo backend under ``name`` (the config axis value)."""
    _BACKENDS[name] = factory


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_BACKENDS))


def get_backend(name: str) -> HaloBackend:
    try:
        return _BACKENDS[name]()
    except KeyError:
        raise ValueError(
            f"unknown halo backend {name!r}; "
            f"available: {available_backends()}") from None


register_backend("serialized", SerializedBackend)
register_backend("fused", FusedBackend)
register_backend("pallas", PallasBackend)


# --------------------------------------------------------------------------
# byte / critical-path accounting (absorbs the old halo.exchange_stats)
# --------------------------------------------------------------------------

# default link model for the latency term in HaloPlan.stats: an
# InfiniBand-class inter-node hop (~1.5 us) at NVLink/ICI-class payload
# bandwidth; both are per-call configurable
DEFAULT_LINK_LATENCY_S = 1.5e-6
DEFAULT_BANDWIDTH_BPS = 5.0e10


def compute_exchange_stats(sched: PulseSchedule,
                           local_shape: Sequence[int],
                           itemsize: int,
                           feature_elems: int = 1) -> dict:
    """Bytes moved per phase/pulse and the two critical-path models.

    Both designs move the same regions, hence the single ``total_bytes``.
    The serialized design chains every pulse's full (forwarding-inclusive)
    slab, so its critical path *is* the total; the fused design overlaps
    each phase's transfers, chaining only ``max`` bytes per phase.

    ``exchanged_cells`` is the exchanged region volume in *cells* — the
    payload-independent first-class quantity every byte field is derived
    from (``total_bytes = exchanged_cells * feature_elems * itemsize``).
    Callers accounting side-channel payloads with different itemsizes
    (index exchanges, wire formats) must scale from ``exchanged_cells``,
    never back-derive volume from a byte total.
    """
    ndim = sched.ndim
    widths = sched.widths

    def vol_cells(region: Region) -> int:
        v = 1
        for d in range(ndim):
            v *= widths[d] if d in region else local_shape[d]
        return v

    def vol(region: Region) -> int:
        return vol_cells(region) * feature_elems * itemsize

    ser_pulse_bytes = []
    shape = list(local_shape)
    for pulse in sched.serialized_order():
        d = pulse.dim
        slab = 1
        for k in range(ndim):
            slab *= pulse.width if k == d else shape[k]
        ser_pulse_bytes.append(slab * feature_elems * itemsize)
        shape[d] += pulse.width

    fused_phases = []
    for phase in sched.forward_phases():
        fused_phases.append({
            "regions": [{"dims": r, "bytes": vol(r)} for r in phase],
            "phase_bytes": sum(vol(r) for r in phase),
            "phase_critical_bytes": max((vol(r) for r in phase), default=0),
        })

    cells = sum(vol_cells(r) for phase in sched.forward_phases()
                for r in phase)
    total = sum(p["phase_bytes"] for p in fused_phases)
    assert total == cells * feature_elems * itemsize
    assert total == sum(ser_pulse_bytes), "slab/region accounting mismatch"
    return {
        "exchanged_cells": cells,
        "total_bytes": total,
        "serialized_pulse_bytes": ser_pulse_bytes,
        # fully sequential: the chained bytes are all of them
        "serialized_critical_bytes": sum(ser_pulse_bytes),
        "fused_phases": fused_phases,
        "fused_critical_bytes": sum(p["phase_critical_bytes"]
                                    for p in fused_phases),
        "dependent_fraction": sched.dependent_fraction(local_shape),
    }


def latency_model(stats: dict,
                  link_latency_s: float = DEFAULT_LINK_LATENCY_S,
                  bandwidth_Bps: float = DEFAULT_BANDWIDTH_BPS) -> dict:
    """alpha-beta time model for one exchange direction (paper §6.2).

    The serialized (CPU-initiated) design pays one link latency per
    *message* — pulses are strictly chained, so each of its messages adds
    ``alpha + bytes / BW`` to the critical path.  The fused GPU-initiated
    design issues every message of a phase concurrently (put-with-signal,
    no host round-trip), so a phase costs one ``alpha`` plus its chained
    (max-transfer) bytes.  In the strong-scaling limit (bytes -> 0) the
    ratio approaches ``n_messages / n_phases`` — the paper's small-domain
    regime, where GROMACS' two-pulse dims make the serialized path pay
    twice the latency per dim.
    """
    ser_msgs = [b for b in stats["serialized_pulse_bytes"] if b > 0]
    phases = [p for p in stats["fused_phases"] if p["phase_bytes"] > 0]
    serialized_s = sum(link_latency_s + b / bandwidth_Bps for b in ser_msgs)
    fused_s = sum(link_latency_s + p["phase_critical_bytes"] / bandwidth_Bps
                  for p in phases)
    return {
        "link_latency_s": link_latency_s,
        "bandwidth_Bps": bandwidth_Bps,
        "serialized_messages": len(ser_msgs),
        "fused_phase_messages": [len(p["regions"]) for p in phases],
        "serialized_time_s": serialized_s,
        "fused_time_s": fused_s,
        "fused_speedup": serialized_s / fused_s if fused_s else 1.0,
    }


def overlap_model(stats: dict, critical_path: str,
                  pipeline: str = "off", depth: int = 2) -> dict:
    """Per-step exposed-vs-overlapped communication under a step pipeline.

    ``exposed_phases_per_step`` counts the communication stages left on a
    step's critical path (per the backend's ``critical_path`` model: pulses
    when serialized, phases when fused), for both exchange directions.
    ``pipeline="double_buffer"`` overlaps the whole force-return exchange
    of step ``N`` with step ``N+1``'s forward half, so the reverse bytes
    count as overlapped (the drain of the final step is amortized over
    the block).  A ``depth``-deep window (ring of ``depth`` extended-force
    slots, ``depth - 1`` steps resident per fused program region) further
    amortizes the *forward* stages: the coordinate sends of an in-window
    step overlap the force compute of up to ``depth - 2`` older resident
    steps, leaving ``1 / (depth - 1)`` of the forward stages exposed per
    step — monotone decreasing in ``depth``, the paper's deeper-overlap
    limit where only one exchange per window stays on the critical path.

    Like the alpha-beta :func:`latency_model`, this is an *analytic*
    model of what signal-coordinated hardware can hide, not a property
    of the emulated schedule: the CPU pipeline pins each step with
    barriers to guarantee bitwise conformance, so the depth axis is
    measurable here but its predicted win must be validated on a real
    mesh (see the ROADMAP open item).
    """
    if critical_path == "serialized":
        stages = len([b for b in stats["serialized_pulse_bytes"] if b > 0])
    else:
        stages = len([p for p in stats["fused_phases"]
                      if p["phase_bytes"] > 0])
    if pipeline == "double_buffer":
        if depth < 2:
            raise ValueError("double_buffer overlap model needs depth >= 2")
        window = depth - 1                     # steps in flight per region
        exposed = stages / window              # exposed forward fraction
        overlapped_stages = 2 * stages - exposed
        # the whole reverse exchange plus the hidden forward fraction
        overlapped_bytes = int(round(
            stats["total_bytes"] * (2 - 1 / window)))
    else:
        depth = 1
        exposed = 2 * stages                   # forward + reverse chained
        overlapped_bytes = 0
        overlapped_stages = 0
    return {
        "pipeline": pipeline,
        "depth": depth,
        "exposed_phases_per_step": exposed,
        "overlapped_phases_per_step": overlapped_stages,
        "overlapped_bytes_per_step": overlapped_bytes,
        # both directions move the same regions
        "exchanged_bytes_per_step": 2 * stats["total_bytes"],
    }


# --------------------------------------------------------------------------
# plan
# --------------------------------------------------------------------------

class HaloPlan:
    """Construct-once / execute-many halo exchange bound to a mesh.

    Build with :meth:`HaloPlan.build`; execute with :meth:`fwd` /
    :meth:`rev` / :meth:`exchange` (global arrays) or :meth:`fwd_local` /
    :meth:`rev_local` (inside an enclosing ``shard_map``).
    """

    def __init__(self, spec: HaloSpec, mesh: Mesh, verify: str = "error"):
        for a in spec.axis_names:
            if a not in mesh.shape:
                raise ValueError(f"mesh has no axis {a!r}; "
                                 f"mesh axes: {tuple(mesh.shape)}")
        self.spec = spec
        self.mesh = mesh
        self.backend = get_backend(spec.backend)
        # wire-format acceptance gate first: a compressed-payload config
        # whose measured NVE drift exceeds the dense-f32 bound is rejected
        # here (verify="warn"/"off" is the PR 6 escape-hatch convention)
        self.wire = _wire.make_codec(spec.wire_dtype)
        self.wire_drift = _wire.gate_wire_config(spec.wire_dtype, verify)
        # config check next: nonsense (widths, pulses) combinations fail
        # here with an actionable message instead of deep in tracing
        from repro.analysis.schedule_verifier import check_halo_config
        self.sched: PulseSchedule = check_halo_config(
            spec.axis_names, spec.widths, spec.pulses)
        self.axis_sizes: Tuple[int, ...] = tuple(
            int(mesh.shape[a]) for a in spec.axis_names)
        # per-dim ppermute pairs, precomputed once (the plan's PulseData)
        self.fwd_perms = tuple(_halo._perm_fwd(n) for n in self.axis_sizes)
        self.rev_perms = tuple(_halo._perm_rev(n) for n in self.axis_sizes)
        self.partition_spec = P(*spec.axis_names)
        self._wrap = spec.wrap_shift_array()
        self._index_maps: Dict[Tuple[int, ...], Any] = {}
        self._stats_cache: Dict[Tuple, dict] = {}
        self._pallas_broken = False
        self._exchange = self._make_exchange()

    # -- construction ------------------------------------------------------

    @classmethod
    def build(cls, spec: HaloSpec, mesh: Mesh,
              verify: str = "error") -> "HaloPlan":
        return cls(spec, mesh, verify=verify)

    # -- introspection -----------------------------------------------------

    @property
    def regions(self) -> Tuple[Region, ...]:
        return self.sched.regions()

    @property
    def forward_phases(self):
        return self.sched.forward_phases()

    @property
    def reverse_phases(self):
        return self.sched.reverse_phases()

    def extended_shape(self, local_shape: Sequence[int]) -> Tuple[int, ...]:
        """Per-device extended-block shape for a given local block shape."""
        out = list(local_shape)
        for d, w in enumerate(self.spec.widths):
            out[d] += w
        return tuple(out)

    def stats(self, local_shape: Sequence[int],
              itemsize: Optional[int] = None,
              feature_elems: Optional[int] = None,
              pipeline: str = "off", depth: int = 2,
              link_latency_s: float = DEFAULT_LINK_LATENCY_S,
              bandwidth_Bps: float = DEFAULT_BANDWIDTH_BPS,
              index_elems: int = 0, index_itemsize: int = 4,
              occupancy: Optional[float] = None) -> dict:
        """Canonical byte/critical-path stats for this plan's schedule.

        Defaults derive from the spec's dtype / feature layout; results are
        cached per argument tuple.  On top of the byte accounting this
        reports a configurable alpha-beta ``latency`` model (per-message
        link latency + bytes/bandwidth — see :func:`latency_model`) and the
        step-``pipeline`` overlap model (``exposed_phases_per_step`` /
        ``overlapped_bytes_per_step`` under ``"off"`` or
        ``"double_buffer"`` at in-flight window ``depth`` — see
        :func:`overlap_model`).

        ``index_elems`` accounts side-channel *index* payloads the
        canonical float accounting excludes (the MD engine's ``(K, 2)``
        int32 ``cell_i`` exchange: ``index_elems=2 * K``), reported as
        ``bytes_index`` over the same exchanged regions.  ``occupancy``
        (fraction of payload elements carrying real data — for MD, atoms
        per capacity slot) yields ``useful_bytes``: the padded capacity
        slots are exchanged but carry nothing.
        """
        if itemsize is None:
            itemsize = int(np.dtype(self.spec.dtype).itemsize)
        if feature_elems is None:
            feature_elems = self.spec.feature_elems
        key = (tuple(local_shape), itemsize, feature_elems, pipeline,
               depth, link_latency_s, bandwidth_Bps, index_elems,
               index_itemsize, occupancy)
        if key not in self._stats_cache:
            stats = dict(compute_exchange_stats(
                self.sched, tuple(local_shape), itemsize, feature_elems))
            # every byte field derives from the first-class exchanged
            # region volume in cells — NOT back-derived from total_bytes,
            # which silently mis-scales once payload and index itemsizes
            # diverge (e.g. feature_elems=0 index-only accounting, or
            # wire formats whose itemsize differs from the payload's)
            cells = stats["exchanged_cells"]
            stats["bytes_index"] = cells * index_elems * index_itemsize
            stats["occupancy"] = occupancy
            stats["useful_bytes"] = (
                None if occupancy is None
                else int(round(stats["total_bytes"] * occupancy)))
            # wire-format accounting, per direction: coordinates (fwd)
            # ride at the float32 floor, the force return (rev) at the
            # named format (int8 adds one 4-byte scale per serialized
            # message).  ``wire_bytes`` covers BOTH directions of one
            # step against ``2 * total_bytes`` dense.
            wire = self.wire
            stats["wire_dtype"] = self.spec.wire_dtype
            stats["wire_itemsize_fwd"] = (
                itemsize if wire is None
                else wire.fwd_itemsize(self.spec.dtype))
            stats["wire_itemsize_rev"] = (itemsize if wire is None
                                          else wire.wire_itemsize)
            stats["wire_itemsize"] = stats["wire_itemsize_rev"]
            n_msgs = len([b for b in stats["serialized_pulse_bytes"]
                          if b > 0])
            scale_overhead = (0 if wire is None or wire.is_float
                              else 4 * n_msgs)
            stats["wire_bytes_fwd"] = (cells * feature_elems
                                       * stats["wire_itemsize_fwd"])
            stats["wire_bytes_rev"] = (cells * feature_elems
                                       * stats["wire_itemsize_rev"]
                                       + scale_overhead)
            stats["wire_bytes"] = (stats["wire_bytes_fwd"]
                                   + stats["wire_bytes_rev"])
            stats["wire_reduction"] = (
                2 * stats["total_bytes"] / stats["wire_bytes"]
                if stats["wire_bytes"] else 1.0)
            stats["latency"] = latency_model(stats, link_latency_s,
                                             bandwidth_Bps)
            if wire is not None:
                # the predicted win: the same alpha-beta model at the
                # per-direction mean wire itemsize — latency terms
                # unchanged, bandwidth terms scaled by the byte cut
                mean_itemsize = (stats["wire_itemsize_fwd"]
                                 + stats["wire_itemsize_rev"]) / 2
                wstats = compute_exchange_stats(
                    self.sched, tuple(local_shape),
                    mean_itemsize, feature_elems)
                lat_w = latency_model(wstats, link_latency_s,
                                      bandwidth_Bps)
                lat_w["wire_speedup_fused"] = (
                    stats["latency"]["fused_time_s"] / lat_w["fused_time_s"]
                    if lat_w["fused_time_s"] else 1.0)
                lat_w["wire_speedup_serialized"] = (
                    stats["latency"]["serialized_time_s"]
                    / lat_w["serialized_time_s"]
                    if lat_w["serialized_time_s"] else 1.0)
                stats["latency_wire"] = lat_w
            overlap = overlap_model(stats, self.backend.critical_path,
                                    pipeline, depth)
            stats["overlap"] = overlap
            stats["exposed_phases_per_step"] = \
                overlap["exposed_phases_per_step"]
            stats["overlapped_bytes_per_step"] = \
                overlap["overlapped_bytes_per_step"]
            self._stats_cache[key] = stats
        return self._stats_cache[key]

    def publish_stats(self, registry, local_shape: Sequence[int],
                      **kw) -> dict:
        """:meth:`stats`, also published as a ``halo_stats`` record.

        The registry stays out of the stats cache key: this is a separate
        method so ``stats`` callers keep their memoization while emitters
        (engine build, benchmarks) push the same dict — plus the backend's
        critical-path model, which the Perfetto exporter's predicted lanes
        key on — into a :class:`~repro.obs.registry.MetricsRegistry`.
        """
        stats = self.stats(local_shape, **kw)
        registry.emit("halo_stats", backend=self.spec.backend,
                      critical_path=self.backend.critical_path,
                      local_shape=tuple(local_shape), data=stats)
        return stats

    # -- device-local execution (inside an enclosing shard_map) ------------

    def _resolve_shift(self, wrap_shift):
        if wrap_shift is _UNSET:
            wrap_shift = self._wrap
        if wrap_shift is None:
            return None
        return jnp.asarray(wrap_shift)

    def _wire_active(self, x: jnp.ndarray) -> bool:
        """Wire compression applies to floating payloads only: integer
        side channels (the MD engine's ``cell_i`` exchange) ride dense."""
        return self.wire is not None and \
            jnp.issubdtype(x.dtype, jnp.floating)

    def wire_pack_dtype(self, dtype) -> Optional[str]:
        """Wire dtype for fused quantize-into-pack kernels on the
        coordinate (forward) direction: the float32 floor — f64 payloads
        pack/put f32 rows, narrower payloads pack dense.  (The named
        format compresses only the force-return direction, whose
        accumulated sums the kernels never re-round.)"""
        if self.wire is None:
            return None
        if not jnp.issubdtype(jnp.dtype(dtype), jnp.floating):
            return None
        return self.wire.fwd_wire_dtype(dtype)

    def _body_idx(self, local_shape: Sequence[int]) -> Tuple[slice, ...]:
        """Index of the local body inside an extended block (halos are
        appended at the high end of each decomposed dim)."""
        return tuple(slice(0, int(n)) for n in local_shape)

    def fwd_local(self, local: jnp.ndarray, wrap_shift=_UNSET) -> jnp.ndarray:
        """Coordinate exchange on one device's block (needs shard_map).

        With ``spec.wire_dtype`` set the payload is wire-gridded at the
        coordinate direction's float32 floor before the sends and the
        exact local body spliced back afterwards: received halo data is
        wire-lossy, local data never is.  Payloads already at or below
        the floor ride dense (the coordinate cast would be an identity).
        """
        shift = self._resolve_shift(wrap_shift)
        if not self._wire_active(local) or \
                self.wire.fwd_wire_dtype(local.dtype) is None:
            return self.backend.fwd(self, local, shift)
        q = self.wire.fwd_roundtrip(local)
        ext = self.backend.fwd(self, q, shift)
        body = self._body_idx(local.shape[:self.spec.ndim])
        return ext.at[body].set(local)

    def rev_local(self, ext: jnp.ndarray) -> jnp.ndarray:
        """Force-return exchange on one device's extended block.

        The adjoint direction compresses symmetrically: halo-region force
        contributions are wire-quantized before the return puts, the body
        (never transmitted) stays exact.
        """
        if not self._wire_active(ext):
            return self.backend.rev(self, ext)
        return self.backend.rev(self, self._rev_wire(ext, None)[0])

    def rev_local_ef(self, ext: jnp.ndarray, ef: jnp.ndarray
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """:meth:`rev_local` with error-feedback state (ext-shaped)."""
        q, new_ef = self._rev_wire(ext, ef)
        return self.backend.rev(self, q), new_ef

    def rev_local_raw(self, ext: jnp.ndarray) -> jnp.ndarray:
        """Reverse exchange with NO wire seam — for callers that already
        hold a wire-gridded extended buffer (the pipeline's slot ring
        decodes at drain time; re-quantizing would double-apply EF)."""
        return self.backend.rev(self, ext)

    def _rev_wire(self, ext, ef):
        q, new_ef = self.wire.roundtrip(ext, ef)
        body = self._body_idx(tuple(
            ext.shape[d] - self.spec.widths[d]
            for d in range(self.spec.ndim)))
        return q.at[body].set(ext[body]), new_ef

    # -- wire-format slot-ring codec (pipeline extended-force buffers) -----

    def wire_encode_ext(self, F_ext: jnp.ndarray,
                        ef: Optional[jnp.ndarray] = None):
        """Encode an extended-force buffer into wire-format ring parts.

        Returns ``(parts, new_ef)`` where ``parts`` is a tuple of arrays
        to store in the pipeline's slot ring: the wire-dtyped buffer
        (+ scale for int8) plus the exact f32/f64 body — so in-flight
        force windows are HBM-resident in wire format while the local
        body keeps full precision.  ``wire_decode_ext`` inverts it; the
        composition equals :meth:`_rev_wire`'s quantize-and-splice
        bitwise, which keeps ``off`` == ``double_buffer`` conformance.
        """
        parts, new_ef = self.wire.encode(F_ext, ef)
        body = self._body_idx(tuple(
            F_ext.shape[d] - self.spec.widths[d]
            for d in range(self.spec.ndim)))
        return parts + (F_ext[body],), new_ef

    def wire_decode_ext(self, parts, dtype) -> jnp.ndarray:
        """Decode slot-ring parts back to the wire-gridded extended-force
        buffer with the exact body spliced in (drain side)."""
        wire_parts, bodyv = parts[:-1], parts[-1]
        F = self.wire.decode(wire_parts, dtype)
        body = self._body_idx(bodyv.shape[:self.spec.ndim])
        return F.at[body].set(bodyv)

    # -- global execution (plan applies the shard_map) ---------------------

    def _shard(self, body):
        spec = self.partition_spec
        return shard_map_norep(body, mesh=self.mesh, in_specs=spec,
                               out_specs=spec)

    def fwd(self, x: jax.Array, wrap_shift=_UNSET) -> jax.Array:
        """Shard-mapped coordinate exchange over ``mesh``.

        ``x`` is sharded over the spec's axis names on its leading dims;
        the result re-stacks the per-device extended blocks (global shape
        grows by ``size_d * w_d`` per dim).
        """
        shift = self._resolve_shift(wrap_shift)
        return self._shard(lambda lo: self.fwd_local(lo, shift))(x)

    def rev(self, ext: jax.Array) -> jax.Array:
        """Shard-mapped force-return exchange (adjoint of :meth:`fwd`)."""
        return self._shard(lambda e: self.rev_local(e))(ext)

    def exchange(self, x: jax.Array) -> jax.Array:
        """Differentiable exchange: the VJP *is* the reverse exchange.

        ``jax.grad`` through ``plan.exchange`` emits this plan's fused
        (or backend-selected) force-return path instead of XLA's
        transpose of the forward collectives — paper Alg. 6 as an
        autodiff rule.
        """
        return self._exchange(x)

    def _make_exchange(self):
        @jax.custom_vjp
        def exchange(x):
            return self.fwd(x)

        def exchange_fwd(x):
            # the exchange is affine in x (wrap shifts are constants), so
            # no residuals are needed: the VJP is the exact linear adjoint
            return self.fwd(x), None

        def exchange_bwd(_, g):
            return (self.rev(g),)

        exchange.defvjp(exchange_fwd, exchange_bwd)
        return exchange

    def __repr__(self):
        return (f"HaloPlan(backend={self.spec.backend!r}, "
                f"axes={self.spec.axis_names}, widths={self.spec.widths}, "
                f"mesh={dict(self.mesh.shape)})")


# the pipeline subsystem's put-with-signal backend registers itself on
# import; the cycle is benign (it only references names defined above)
import repro.core.pipeline.signal_backend  # noqa: E402,F401
