"""Pulse schedules for staged (eighth-shell style) halo exchange.

Terminology follows the paper (§2.2):
  * *staged communication* — boundary data is forwarded through intermediate
    ranks rather than sent directly to all final consumers,
  * *communication phases* — the sequential z, then y, then x sweeps,
  * *pulses* — the per-dimension communication steps within a phase.

The **global pulse order** concatenates dimensions in Z -> Y -> X order
(paper §5.1), omitting dimensions not present in the current decomposition.
``firstDependentPulse`` encodes the forwarding dependency: pulse ``y0``
forwards data received by ``z0``, pulse ``x0`` forwards data received by
``y0`` (and transitively ``z0``).

The *fused* schedule (paper Alg. 3/4) partitions each pulse's payload at
``depOffset`` into an **independent** part (locally owned data, sent
immediately) and a **dependent** part (data received by earlier pulses,
sent as soon as that pulse's signal fires).  On TPU we realize this as
*phases of concurrent region transfers*: phase ``p`` carries every halo
region whose forwarding depth is ``p`` (see :mod:`repro.core.halo`).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple


@dataclass(frozen=True)
class Pulse:
    """One communication step along one decomposition dimension.

    Mirrors the paper's ``PulseData`` metadata (minus the device pointers,
    which have no meaning under XLA): the send/recv ranks are implied by a
    ``ppermute`` along ``axis_name``; ``width`` is the halo width in grid
    elements (or the per-pulse atom capacity for the MD index-map path).

    With more than one pulse per dimension (GROMACS' two-pulse case) the
    dimension's halo of total width ``W`` is split across its pulses:
    ``offset`` is this pulse's start row within the dim's halo, so pulse
    ``k`` of dim ``d`` ships slab rows ``[offset, offset + width)`` of the
    sender's (extended) block along ``d``.
    """

    index: int            # position in the global pulse order
    dim: int              # spatial dimension this pulse sweeps (0 = Z-like)
    axis_name: str        # mesh axis name used for the ppermute
    width: int            # this pulse's halo width in elements along `dim`
    offset: int = 0       # start row within the dim's total halo
    dim_pulse: int = 0    # position among this dim's pulses
    n_dim_pulses: int = 1  # total pulses along this dim

    @property
    def first_dependent_pulse(self) -> Optional[int]:
        """Index of the earliest pulse whose data this pulse forwards.

        In the single-pulse-per-dim case this is simply the previous pulse
        in global order (paper §5.1: firstDependentPulse(z0)=none;
        firstDependentPulse(y0)=z0; firstDependentPulse(x0)=y0).  Later
        pulses of the same dim forward data only when their slab reaches
        into rows received by the dim's earlier pulses, which also resolves
        to the previous pulse in global order.
        """
        return None if self.index == 0 else self.index - 1


@dataclass(frozen=True)
class PulseSchedule:
    """Global pulse order ``[Z.., Y.., X..]`` plus fused-phase bookkeeping."""

    pulses: Tuple[Pulse, ...]
    axis_names: Tuple[str, ...]   # one mesh axis per decomposition dim
    widths: Tuple[int, ...]       # TOTAL halo width per decomposition dim
    pulses_per_dim: Tuple[int, ...] = ()   # () = one pulse per dim

    def __post_init__(self):
        if not self.pulses_per_dim:
            object.__setattr__(self, "pulses_per_dim",
                               (1,) * len(self.axis_names))

    def dim_pulses(self, d: int) -> Tuple[Pulse, ...]:
        """This dim's pulses in within-dim (offset-ascending) order."""
        return tuple(p for p in self.pulses if p.dim == d)

    @property
    def ndim(self) -> int:
        return len(self.axis_names)

    @property
    def total_pulses(self) -> int:
        return len(self.pulses)

    # ---- fused-phase structure -------------------------------------------------
    #
    # Halo *regions* are indexed by the subset S of dimensions they extend
    # into.  Region S is received from the +max(S) neighbor, which in turn
    # assembled it from region S \ {max(S)} — i.e. the forwarding depth of
    # region S is |S| - 1.  The fused schedule sends, in phase p, every
    # region with |S| == p + 1; all transfers within a phase are mutually
    # independent (the paper's "independent data" for p == 0, and exactly
    # the per-pulse dependent slices for p >= 1).

    def regions(self) -> Tuple[Tuple[int, ...], ...]:
        """All non-empty dimension subsets, sorted by (depth, dims)."""
        dims = range(self.ndim)
        out = []
        for r in range(1, self.ndim + 1):
            out.extend(itertools.combinations(dims, r))
        return tuple(out)

    def phase_of(self, region: Tuple[int, ...]) -> int:
        return len(region) - 1

    def forward_phases(self) -> Tuple[Tuple[Tuple[int, ...], ...], ...]:
        """Regions grouped by fused phase, shallow -> deep (coordinates)."""
        groups: list[list[Tuple[int, ...]]] = [[] for _ in range(self.ndim)]
        for region in self.regions():
            groups[self.phase_of(region)].append(region)
        return tuple(tuple(g) for g in groups)

    def reverse_phases(self) -> Tuple[Tuple[Tuple[int, ...], ...], ...]:
        """Regions grouped by fused phase, deep -> shallow (forces).

        The force halo (paper Alg. 6) walks the dependency chain backwards:
        the deepest (corner) contributions must land before the faces are
        returned, hence phase 0 carries regions of maximal depth.
        """
        return tuple(reversed(self.forward_phases()))

    def serialized_order(self) -> Tuple[Pulse, ...]:
        """MPI-like order: one full (own + forwarded) slab per pulse."""
        return self.pulses

    def dependent_fraction(self, local_shape: Sequence[int]) -> float:
        """Fraction of total halo volume that is forwarding-dependent.

        This is the napkin-math quantity behind the fused design: only this
        fraction of the exchanged bytes sits on a chained critical path; the
        rest moves concurrently in phase 0.
        """
        total = 0
        dependent = 0
        for region in self.regions():
            vol = 1
            for d in range(self.ndim):
                vol *= self.widths[d] if d in region else local_shape[d]
            total += vol
            if len(region) > 1:
                dependent += vol
        return dependent / total if total else 0.0


def split_width(width: int, n_pulses: int) -> Tuple[int, ...]:
    """Balanced per-pulse widths for one dim (GROMACS-style, wide first)."""
    base, rem = divmod(width, n_pulses)
    return tuple(base + (1 if k < rem else 0) for k in range(n_pulses))


def make_schedule(axis_names: Sequence[str], widths: Sequence[int],
                  pulses_per_dim: Optional[Sequence[int]] = None
                  ) -> PulseSchedule:
    """Build the global pulse order [Z.., Y.., X..].

    GROMACS supports up to two pulses per dimension; (paper §2.2) in
    GPU-resident runs with DLB disabled the pulse count per dimension is
    "almost always one", which is the default here.  ``pulses_per_dim``
    opts into the multi-pulse case: dim ``d``'s total halo ``widths[d]`` is
    split into ``pulses_per_dim[d]`` balanced slabs, each shipped by its
    own pulse at its own ``offset`` (within-dim pulses appear consecutively
    in the global order, so staged forwarding semantics are preserved).
    """
    if len(axis_names) != len(widths):
        raise ValueError("axis_names and widths must have equal length")
    if not axis_names:
        raise ValueError("need at least one decomposition dimension")
    widths = tuple(int(w) for w in widths)
    if pulses_per_dim is None:
        pulses_per_dim = (1,) * len(axis_names)
    pulses_per_dim = tuple(int(n) for n in pulses_per_dim)
    if len(pulses_per_dim) != len(axis_names):
        raise ValueError("pulses_per_dim and axis_names must have equal "
                         "length")
    pulses = []
    for d, (name, w, np_) in enumerate(zip(axis_names, widths,
                                           pulses_per_dim)):
        if np_ < 1:
            raise ValueError(f"dim {d}: need at least one pulse, got {np_}")
        if w == 0:
            np_ = 1           # width-0 dims degrade to one no-op pulse
        elif np_ > w:
            raise ValueError(f"dim {d}: {np_} pulses cannot split a "
                             f"width-{w} halo")
        off = 0
        for k, wk in enumerate(split_width(w, np_)):
            pulses.append(Pulse(index=len(pulses), dim=d, axis_name=name,
                                width=wk, offset=off, dim_pulse=k,
                                n_dim_pulses=np_))
            off += wk
    return PulseSchedule(pulses=tuple(pulses),
                         axis_names=tuple(axis_names), widths=widths,
                         pulses_per_dim=pulses_per_dim)
