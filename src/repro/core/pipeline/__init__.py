"""GPU-resident multi-step overlap subsystem.

The layer between :class:`~repro.core.halo_plan.HaloPlan` (construct-once
exchange plans) and the MD engine's step programs:

* :class:`SignalLedger` — functional model of NVSHMEM put-with-signal
  bookkeeping (release/acquire/clobber counters per buffer slot and
  pulse, window-distance invariants for ``depth``-deep rings);
* the ``"signal"`` halo backend — device-initiated pack+put pulses driving
  :func:`repro.kernels.halo_pack.put_signal` / ``fused_pulses`` end to end
  (registered into the :mod:`repro.core.halo_plan` backend registry on
  import);
* :class:`StepPipeline` — software-pipelined multi-step ``lax.scan``
  programs with a ``depth``-slot extended-force ring: step ``N``'s
  force-return exchange overlaps step ``N+1``'s coordinate sends, and
  ``depth > 2`` keeps ``depth - 1`` steps resident per fused program
  region.
"""
from repro.core.pipeline.ledger import KINDS, LedgerState, SignalLedger
from repro.core.pipeline.signal_backend import SignalBackend
from repro.core.pipeline.step_pipeline import (
    PIPELINE_MODES,
    StepFns,
    StepPipeline,
)

__all__ = [
    "KINDS",
    "LedgerState",
    "PIPELINE_MODES",
    "SignalBackend",
    "SignalLedger",
    "StepFns",
    "StepPipeline",
]
