"""StepPipeline: persistent, depth-buffered multi-step halo programs.

The paper's headline gains come from *fusing communication into the step
program*: GPU-initiated sends overlap force compute so hardware hides the
halo latency (Alg. 5/6), and consecutive steps share a persistent,
pre-planned exchange.  :class:`StepPipeline` is that seam between a
:class:`~repro.core.halo_plan.HaloPlan` and an engine's physics:

* ``pipeline="off"`` — the strictly serialized reference: each ``lax.scan``
  iteration runs ``begin -> fwd halo -> forces -> rev halo -> finish``,
  with a scan-iteration barrier between the force return of step ``N``
  and the coordinate sends of step ``N+1`` (the CPU-round-trip analogue).

* ``pipeline="double_buffer"`` — the software-pipelined schedule with an
  arbitrary ``depth >= 2`` in-flight window.  Extended force buffers live
  in a ``depth``-slot ring (two slots = the paper's double-buffered
  halos); each step's force-return signal is *released at fill time* —
  the put is issued the moment the force kernel writes its slot — and
  acquired one step later, right before the integrator consumes it, so
  the transfer spans a step boundary.  The scan body is unrolled over
  ``depth - 1`` consecutive steps: one fused program region carries the
  reverse exchanges of ``depth - 1`` steps alongside the next steps'
  coordinate sends, XLA's async collectives are free to overlap every
  transfer inside the window, and the ring guarantees the puts of step
  ``N + depth - 1`` never clobber a slot step ``N`` is still draining.
  Steps that do not fill a whole window, plus the final force return,
  drain in an epilogue loop over the last (up to) ``depth - 1`` slots.
  A :class:`~repro.core.pipeline.ledger.SignalLedger` threads the
  put-with-signal bookkeeping through the scan carry.

Both modes compute bit-identical trajectories at every depth: pipelining
regroups the exact same per-step operations across scan iterations (the
prologue runs step 0's forward half, the epilogue drains the tail), and
the physics chain itself stays strictly serial — velocity Verlet needs
step ``N``'s returned forces before step ``N+1``'s kick-drift, so the
window deepens the *communication* schedule, never the integrator.
Exchange boundaries are ``optimization_barrier``s — the XLA realization
of the signal acquire: consumers cannot be fused or hoisted across the
wait, so the physics islands compile identically for every backend and
the trajectory stays bitwise-stable across backends, pipeline modes, and
window depths.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.halo_plan import HaloPlan
from repro.core.pipeline.ledger import (
    FAULT_DROP,
    FAULT_FORCE,
    FAULT_HALO,
    LedgerState,
    SignalLedger,
)
from repro.obs.tracing import NULL_TRACER, PhaseTracer

PIPELINE_MODES = ("off", "double_buffer")

# fns signatures (all run device-local, inside the engine's shard_map):
#   begin(state, f, ctx)   -> (state, aux, payload)   kick-drift; payload is
#                                                     the array to exchange
#   force(ext, ctx)        -> (F_ext, metrics)        forces on the extended
#                                                     block (not returned yet)
#   finish(state, aux, f, ctx) -> (state, f_carry, metrics)
#                                                     final kick; f_carry
#                                                     seeds the next begin
Metrics = Dict[str, jnp.ndarray]


@dataclass(frozen=True)
class StepFns:
    """The engine-supplied physics of one step, split at the halo seams.

    Metric keys must be unique across ``force`` and ``finish`` (the
    pipeline merges them into one per-step dict).

    ``ctx`` is the *block-constant* context: it is passed through every
    callback unchanged for the whole multi-step program, so anything in
    it (pre-exchanged index arrays, the MD engine's pruned pair schedule
    — the ``pair_sel`` packed prefix and static ``tiers`` ladder from
    :mod:`repro.core.md.pair_schedule`) is hoisted out of the scan and
    shared by BOTH pipeline modes; per-mode drift in block-level inputs
    would break the bitwise off/double_buffer equivalence.  (The MD
    engine's rolling inner prune swaps the schedule *between* pipeline
    invocations — each ``run_local`` call still sees one constant ctx.)
    """

    begin: Callable[[Any, jnp.ndarray, Any], Tuple[Any, Any, jnp.ndarray]]
    force: Callable[[jnp.ndarray, Any], Tuple[jnp.ndarray, Metrics]]
    finish: Callable[[Any, Any, jnp.ndarray, Any],
                     Tuple[Any, jnp.ndarray, Metrics]]


def _stack1(m: Metrics) -> Metrics:
    """Add a leading length-1 step axis to every metric."""
    return {k: v[None] for k, v in m.items()}


class StepPipeline:
    """Construct-once multi-step program over one :class:`HaloPlan`."""

    def __init__(self, plan: HaloPlan, fns: StepFns,
                 mode: str = "double_buffer", depth: int = 2,
                 verify: str = "error", tracer: PhaseTracer = None,
                 inject: bool = False):
        if mode not in PIPELINE_MODES:
            raise ValueError(f"unknown pipeline mode {mode!r}; "
                             f"available: {PIPELINE_MODES}")
        if depth < 2 and mode == "double_buffer":
            raise ValueError("double_buffer needs depth >= 2")
        self.plan = plan
        self.fns = fns
        self.mode = mode
        # phase tracing: named scopes are always on (pure metadata); an
        # enabled tracer additionally emits per-step ``obs/*`` ledger
        # counters into the metrics dict.  Both are barrier-neutral —
        # trajectories stay bitwise-identical with tracing on (the obs
        # outputs are functions of counters the scan carry already holds).
        self.tracer = tracer if tracer is not None else NULL_TRACER
        # deterministic fault injection (repro.resilience): when enabled,
        # ctx must carry a ``fault_vec`` int32[3] of block-relative step
        # indices (see ledger.SCAN_FAULT_SITES; -1 = disarmed) and the
        # scan threads the step index so the poison/drop selects can key
        # on it.  Zero-cost when disabled: inject=False traces the exact
        # pre-existing program, operand for operand.
        self.inject = bool(inject)
        self.depth = int(depth) if mode == "double_buffer" else 1
        self.ledger = SignalLedger(depth=self.depth,
                                   n_pulses=max(1, plan.sched.total_pulses))
        # build-time gate: statically replay the release/acquire schedule
        # this (mode, depth, pulses) config will emit and reject it with a
        # counterexample event trace if any slot state is unsafe.
        # ``verify="warn"`` downgrades to a warning, ``"off"`` skips.
        from repro.analysis.schedule_verifier import gate_pipeline_build
        self.schedule_report = gate_pipeline_build(
            mode=self.mode, depth=self.depth,
            n_pulses=self.ledger.n_pulses, backend=plan.spec.backend,
            verify=verify)

    @classmethod
    def build(cls, plan: HaloPlan, fns: StepFns, *,
              mode: str = "double_buffer", depth: int = 2,
              verify: str = "error", tracer: PhaseTracer = None,
              inject: bool = False) -> "StepPipeline":
        return cls(plan, fns, mode=mode, depth=depth, verify=verify,
                   tracer=tracer, inject=inject)

    # -- execution (device-local: call inside the engine's shard_map) ------

    def run_local(self, state, f0: jnp.ndarray, n_steps: int, ctx=None
                  ) -> Tuple[Any, jnp.ndarray, Metrics, LedgerState]:
        """Run ``n_steps`` (static) steps; returns the final state, the
        last step's returned forces, per-step stacked metrics, and the
        final signal-ledger state."""
        if n_steps < 1:
            raise ValueError("n_steps must be >= 1")
        if self.mode == "off":
            return self._run_serial(state, f0, n_steps, ctx)
        return self._run_pipelined(state, f0, n_steps, ctx)

    def _fwd(self, payload):
        """Coordinate exchange between its signal release and acquire.

        The barriers are the XLA realization of put-with-signal ordering:
        the producer's release pins the payload before the puts, the
        consumer's acquire pins the received halo after them, so no op can
        be fused or hoisted across either side of the exchange and the
        physics islands compile identically for every backend.
        """
        sc = self.tracer.scope
        with sc("fwd_release"):
            payload = lax.optimization_barrier(payload)
        with sc("pack_send"):
            ext = self.plan.fwd_local(payload)
        with sc("fwd_acquire"):
            return lax.optimization_barrier(ext)

    def _rev(self, F_ext):
        """Force-return exchange between its signal release and acquire."""
        sc = self.tracer.scope
        with sc("rev_release"):
            F_ext = lax.optimization_barrier(F_ext)
        with sc("rev_return"):
            f = self.plan.rev_local(F_ext)
        with sc("rev_acquire"):
            return lax.optimization_barrier(f)

    # -- wire-format variants (spec.wire_dtype; see repro.core.wire) -------
    # Only the force-return direction carries the named wire format (and
    # its EF state for int8_ef); the coordinate direction's float32 floor
    # is applied inside ``plan.fwd_local`` itself, so ``_fwd`` needs no
    # wire variant.

    def _rev_ef(self, F_ext, ef):
        """:meth:`_rev` threading int8_ef error-feedback state."""
        sc = self.tracer.scope
        with sc("rev_release"):
            F_ext, ef = lax.optimization_barrier((F_ext, ef))
        with sc("rev_return"):
            f, ef = self.plan.rev_local_ef(F_ext, ef)
        with sc("rev_acquire"):
            return lax.optimization_barrier((f, ef))

    def _rev_raw(self, F_ext):
        """:meth:`_rev` for an already wire-gridded buffer (slot-ring
        drain: the fill encoded it, so re-quantizing here would
        double-apply error feedback and re-round the halo rows)."""
        sc = self.tracer.scope
        with sc("rev_release"):
            F_ext = lax.optimization_barrier(F_ext)
        with sc("rev_return"):
            f = self.plan.rev_local_raw(F_ext)
        with sc("rev_acquire"):
            return lax.optimization_barrier(f)

    def _wire_state(self, state, f0, ctx):
        """``(wire_on, wef0)``: does this program use the wire slot-ring/
        error-feedback machinery, and the initial rev-direction EF array
        (None for stateless formats — only int8_ef carries state, and
        only on the force return; coordinates never get feedback).

        Shapes come from ``jax.eval_shape`` over the engine callbacks
        (``begin`` emits the exchange payload, ``force`` the extended
        force buffer) — both are device-local and collective-free, so
        abstract evaluation is safe inside the enclosing shard_map.
        When ``wire_dtype`` is None this returns ``(False, None)`` and
        every wire branch below is dead python, keeping the dense trace
        operand-for-operand identical to the pre-wire program.
        """
        plan, fns = self.plan, self.fns
        if plan.wire is None:
            return False, None
        pay = jax.eval_shape(lambda s, f: fns.begin(s, f, ctx),
                             state, f0)[2]
        if not jnp.issubdtype(pay.dtype, jnp.floating):
            return False, None
        if not plan.wire.stateful:
            return True, None
        ext = jax.ShapeDtypeStruct(plan.extended_shape(pay.shape),
                                   pay.dtype)
        F_ext = jax.eval_shape(lambda e: fns.force(e, ctx), ext)[0]
        return True, jnp.zeros(F_ext.shape, F_ext.dtype)

    # -- wire-dtyped slot rings (in-flight force windows stay compressed) --

    def _slot_ring(self, F0, ef, wire_on):
        """Allocate the depth-slot ring and fill slot 0 (prologue).

        Dense mode keeps the single (depth, ...) buffer; with a wire
        format each slot holds the encode parts (wire-dtyped buffer,
        + scale for int8, + the exact-precision body) as a tuple of
        rings, so HBM-resident in-flight windows shrink with the wire.
        """
        depth = self.depth
        if not wire_on:
            slots = jnp.zeros((depth,) + F0.shape, F0.dtype)
            return lax.dynamic_update_index_in_dim(slots, F0, 0, 0), ef
        parts, ef = self.plan.wire_encode_ext(F0, ef)
        slots = tuple(jnp.zeros((depth,) + p.shape, p.dtype)
                      for p in parts)
        slots = tuple(lax.dynamic_update_index_in_dim(s, p, 0, 0)
                      for s, p in zip(slots, parts))
        return slots, ef

    def _slot_fill(self, slots, F_ext, ef, cur, wire_on):
        """Write step ``cur % depth``'s force buffer (encoding it when a
        wire format is active; error feedback updates at fill time, the
        same once-per-step cadence as serial mode's rev quantization)."""
        if not wire_on:
            return lax.dynamic_update_index_in_dim(slots, F_ext, cur, 0), ef
        parts, ef = self.plan.wire_encode_ext(F_ext, ef)
        slots = tuple(lax.dynamic_update_index_in_dim(s, p, cur, 0)
                      for s, p in zip(slots, parts))
        return slots, ef

    def _slot_drain(self, slots, idx, f_dtype, wire_on):
        """Read a slot back as a dense extended-force buffer (decode +
        exact-body splice when a wire format is active)."""
        if not wire_on:
            return lax.dynamic_index_in_dim(slots, idx, 0, keepdims=False)
        parts = tuple(lax.dynamic_index_in_dim(s, idx, 0, keepdims=False)
                      for s in slots)
        return self.plan.wire_decode_ext(parts, f_dtype)

    # -- fault injection (traced; every helper is behind ``self.inject``) --

    def _fire(self, ctx, k, site):
        """Traced predicate: does scan-fault ``site`` fire at in-block
        step ``k``?  ``ctx["fault_vec"]`` holds block-relative arming
        steps (-1 = disarmed), so a disarmed vector never matches."""
        return jnp.equal(jnp.int32(k), ctx["fault_vec"][site])

    def _poison_halo(self, ext, payload, fire):
        """NaN the *received* halo slab — the trailing cells of the last
        decomposed dim, i.e. everything the exchange appended beyond the
        local payload — when ``fire``.  The corrupted-pulse fault: the
        local block stays intact, only remote data is bad, so the NaN
        reaches the trajectory through the force kernel exactly as a
        corrupted put would."""
        ax = len(self.plan.spec.axis_names) - 1
        idx = (slice(None),) * ax + (slice(payload.shape[ax], None),)
        slab = ext[idx]
        bad = jnp.where(fire, jnp.full_like(slab, jnp.nan), slab)
        return ext.at[idx].set(bad)

    def _poison_force(self, F_ext, fire):
        """NaN the force kernel's whole output slab when ``fire``."""
        return jnp.where(fire, jnp.full_like(F_ext, jnp.nan), F_ext)

    def _release_rev(self, led, buf, ctx, k):
        """Rev (force-return) release, droppable under injection."""
        if self.inject:
            return self.ledger.release_dropped(
                led, "rev", buf, self._fire(ctx, k, FAULT_DROP))
        return self.ledger.release(led, "rev", buf)

    def _run_serial(self, state, f0, n_steps, ctx):
        fns, ledger, sc = self.fns, self.ledger, self.tracer.scope
        _, wef0 = self._wire_state(state, f0, ctx)
        stateful = wef0 is not None   # int8_ef: EF rides the scan carry

        def step(carry, k):
            if stateful:
                state, f, wef, led = carry
            else:
                state, f, led = carry
            with sc("integrate_begin"):
                state, aux, payload = fns.begin(state, f, ctx)
            led = ledger.release(led, "fwd", 0)
            ext = self._fwd(payload)
            led = ledger.acquire(led, "fwd", 0)
            if self.inject:
                ext = self._poison_halo(
                    ext, payload, self._fire(ctx, k, FAULT_HALO))
            with sc("force"):
                F_ext, m_force = fns.force(ext, ctx)
            if self.inject:
                F_ext = self._poison_force(
                    F_ext, self._fire(ctx, k, FAULT_FORCE))
            led = self._release_rev(led, 0, ctx, k)
            if stateful:
                f_new, wef = self._rev_ef(F_ext, wef)
            else:
                f_new = self._rev(F_ext)
            led = ledger.acquire(led, "rev", 0)
            with sc("integrate_finish"):
                state, f_new, m_fin = fns.finish(state, aux, f_new, ctx)
            # pin the step boundary (the per-step signal rotation): the
            # carried state is materialized identically in every schedule,
            # keeping trajectories bitwise-stable across pipeline modes
            state, f_new = lax.optimization_barrier((state, f_new))
            m = {**m_force, **m_fin,
                 **self.tracer.step_metrics(ledger, led)}
            if stateful:
                return (state, f_new, wef, led), m
            return (state, f_new, led), m

        xs = jnp.arange(n_steps, dtype=jnp.int32) if self.inject else None
        carry0 = ((state, f0, wef0, ledger.init()) if stateful
                  else (state, f0, ledger.init()))
        carry, metrics = lax.scan(step, carry0, xs, length=n_steps)
        return carry[0], carry[1], metrics, carry[-1]

    # -- the depth-d window ------------------------------------------------

    def _pipelined_step(self, carry, k, ctx, wire_on=False, f_dtype=None):
        """Drain step ``k-1``'s force return, issue step ``k``'s forward
        half (the skew-one unit every window is built from).

        The rev signal of step ``k-1`` was released when the force kernel
        filled its slot (previous step / prologue); here it is acquired
        right before the integrator's kick consumes the returned forces.
        Step ``k``'s own rev release fires at fill time below, so its
        transfer sits in the same program region as the NEXT unit's work
        — and, with ``depth > 2``, the same region as the following
        ``depth - 2`` units of the unrolled window.

        ``wire_on`` switches the slot ring to wire-format parts: fills
        encode (quantize once per step, EF updated there), drains decode
        + splice and run the raw reverse exchange — the composition
        equals serial mode's ``rev_local_ef`` quantize-and-splice
        bitwise, preserving off == double_buffer conformance.
        """
        fns, ledger, depth = self.fns, self.ledger, self.depth
        sc = self.tracer.scope
        stateful = wire_on and self.plan.wire.stateful
        if stateful:
            state, slots, wef, aux, led = carry
        else:
            state, slots, aux, led = carry
        prev, cur = (k - 1) % depth, k % depth
        F_prev = self._slot_drain(slots, prev, f_dtype, wire_on)
        f_prev = self._rev_raw(F_prev) if wire_on else self._rev(F_prev)
        led = ledger.acquire(led, "rev", prev)
        with sc("integrate_finish"):
            state, f_carry, m_fin = fns.finish(state, aux, f_prev, ctx)
        with sc("integrate_begin"):
            state, aux, payload = fns.begin(state, f_carry, ctx)
        led = ledger.release(led, "fwd", cur)
        ext = self._fwd(payload)
        led = ledger.acquire(led, "fwd", cur)
        if self.inject:
            ext = self._poison_halo(
                ext, payload, self._fire(ctx, k, FAULT_HALO))
        with sc("force"):
            F_ext, m_force = fns.force(ext, ctx)
        if self.inject:
            F_ext = self._poison_force(
                F_ext, self._fire(ctx, k, FAULT_FORCE))
        slots, wef = self._slot_fill(
            slots, F_ext, wef if stateful else None, cur, wire_on)
        led = self._release_rev(led, cur, ctx, k)
        # pin the step boundary (see _run_serial)
        state, slots = lax.optimization_barrier((state, slots))
        m_fin = {**m_fin, **self.tracer.step_metrics(ledger, led)}
        if stateful:
            return (state, slots, wef, aux, led), m_force, m_fin
        return (state, slots, aux, led), m_force, m_fin

    def _run_pipelined(self, state, f0, n_steps, ctx):
        fns, ledger, depth = self.fns, self.ledger, self.depth
        span = depth - 1           # steps resident per fused window region
        wire_on, wef0 = self._wire_state(state, f0, ctx)
        stateful = wef0 is not None

        # prologue: step 0's forward half fills buffer slot 0; its force-
        # return signal is released immediately — the put is in flight
        # across the first window boundary
        state, aux, payload = fns.begin(state, f0, ctx)
        led = ledger.release(ledger.init(), "fwd", 0)
        ext = self._fwd(payload)
        led = ledger.acquire(led, "fwd", 0)
        if self.inject:
            ext = self._poison_halo(
                ext, payload, self._fire(ctx, 0, FAULT_HALO))
        F0, m_force0 = fns.force(ext, ctx)
        if self.inject:
            F0 = self._poison_force(F0, self._fire(ctx, 0, FAULT_FORCE))
        f_dtype = F0.dtype
        slots, wef = self._slot_ring(F0, wef0, wire_on)
        led = self._release_rev(led, 0, ctx, 0)

        m_force_chunks = [_stack1(m_force0)]
        m_fin_chunks = []
        carry = ((state, slots, wef, aux, led)
                 if stateful else (state, slots, aux, led))

        def unit(carry, k):
            return self._pipelined_step(carry, k, ctx, wire_on=wire_on,
                                        f_dtype=f_dtype)

        # main scan: whole windows of `span` steps; the python loop
        # unrolls the window into ONE fused program region, so the rev
        # exchanges of `span` consecutive steps overlap inside it
        n_full = (n_steps - 1) // span
        if n_full:
            ks = jnp.arange(1, 1 + n_full * span, dtype=jnp.int32) \
                .reshape(n_full, span)

            def window(carry, ks_row):
                mf, mn = [], []
                for j in range(span):
                    carry, m_force, m_fin = unit(carry, ks_row[j])
                    mf.append(m_force)
                    mn.append(m_fin)
                mf = {k: jnp.stack([m[k] for m in mf]) for k in mf[0]}
                mn = {k: jnp.stack([m[k] for m in mn]) for k in mn[0]}
                return carry, (mf, mn)

            carry, (mfs, mns) = lax.scan(window, carry, ks)
            # (n_full, span, ...) -> (n_full * span, ...)
            m_force_chunks.append(
                {k: v.reshape((-1,) + v.shape[2:]) for k, v in mfs.items()})
            m_fin_chunks.append(
                {k: v.reshape((-1,) + v.shape[2:]) for k, v in mns.items()})

        # epilogue: drain loop over the last (up to) depth-1 slots — the
        # `rem` steps that do not fill a whole window, then the final
        # step's outstanding force return
        for k in range(1 + n_full * span, n_steps):
            carry, m_force, m_fin = unit(carry, jnp.int32(k))
            m_force_chunks.append(_stack1(m_force))
            m_fin_chunks.append(_stack1(m_fin))
        if stateful:
            state, slots, _wef, aux, led = carry
        else:
            state, slots, aux, led = carry
        last = (n_steps - 1) % depth
        F_last = self._slot_drain(slots, last, f_dtype, wire_on)
        f_last = self._rev_raw(F_last) if wire_on else self._rev(F_last)
        led = ledger.acquire(led, "rev", last)
        with self.tracer.scope("integrate_finish"):
            state, f_carry, m_fin_last = fns.finish(state, aux, f_last, ctx)
        m_fin_last = {**m_fin_last,
                      **self.tracer.step_metrics(ledger, led)}
        m_fin_chunks.append(_stack1(m_fin_last))

        # re-align per-step metrics: the prologue/windows emitted step k's
        # force metrics but step k-1's finish metrics
        metrics: Metrics = {}
        for key in m_force0:
            metrics[key] = jnp.concatenate(
                [c[key] for c in m_force_chunks])
        for key in m_fin_last:
            metrics[key] = jnp.concatenate([c[key] for c in m_fin_chunks])
        return state, f_carry, metrics, led

    # -- introspection -----------------------------------------------------

    def stats(self, local_shape, **kw) -> dict:
        """Plan stats at this pipeline mode/depth (overlap + latency)."""
        kw.setdefault("depth", max(self.depth, 2))
        return self.plan.stats(local_shape, pipeline=self.mode, **kw)

    def __repr__(self):
        return (f"StepPipeline(mode={self.mode!r}, depth={self.depth}, "
                f"plan={self.plan!r})")
