"""The ``"signal"`` halo backend: device-initiated put-with-signal pulses.

This backend is the end-to-end consumer of the two Pallas kernels that the
paper's GPU-initiated redesign is built from (and that previously had no
production call-site):

* single-pulse dims run :func:`repro.kernels.halo_pack.put_signal` — the
  fused pack + remote put whose receive semaphore *is* the data signal
  (paper Alg. 3/5);
* multi-pulse dims (GROMACS' two-pulse case, ``HaloSpec.pulses``) run
  :func:`repro.kernels.halo_pack.fused_pulses` — one kernel launch per
  dim, with the dependency-partitioned chunk schedule of Alg. 4 chaining
  within-dim pulses through their signal semaphores;
* the reverse (force-return) path runs ``put_signal`` with ``shift=+1``
  (put to the +1 neighbor) feeding ``unpack_add`` — Alg. 6's
  CommUnpackF.

Kernels execute in interpreter mode on CPU (``HaloSpec.interpret``); when
a kernel is unavailable on the current backend the plan degrades to a
pure-jnp oracle with identical copy/accumulate semantics, so results stay
bitwise-identical either way.  Index maps are static per local shape and
cached on the plan, the analogue of the paper's DD-time index-map build.

Like the other backends this one ships one hop per pulse, so halo widths
must not exceed the local block (``w <= n``, the paper's single-pulse
regime per hop); multi-pulse splits of such widths are fully supported.
"""
from __future__ import annotations

import math
from typing import Tuple

import numpy as np

import jax.numpy as jnp
from jax import lax

from repro.compat import named_axes_in_scope
from repro.core import halo as _halo
from repro.core.halo_plan import (PallasBackend, _latch_halo_fallback,
                                  register_backend)


class SignalBackend(PallasBackend):
    """Put-with-signal exchange over :mod:`repro.kernels.halo_pack`."""

    name = "signal"
    # pack/put/signal are fused per pulse and phases overlap in hardware:
    # the fused critical-path model describes this backend
    critical_path = "fused"

    # -- transports with oracle fallback -----------------------------------

    def _kernel_ok(self, plan) -> bool:
        """Can the remote-copy kernels run at this call site?

        Interpret mode (CPU validation) can only emulate remote DMAs with
        a single named axis in scope; real TPU lowering has no such limit.
        """
        if plan._pallas_broken:
            return False
        if not plan.spec.interpret:
            return True
        axes = named_axes_in_scope()
        return axes is not None and len(axes) <= 1

    def _put_rows(self, plan, src2d: jnp.ndarray, idx: np.ndarray, d: int,
                  shift: int, wire=None) -> jnp.ndarray:
        """One put-with-signal pulse on packed rows; returns received rows.

        ``wire`` (an fp wire dtype name) fuses quantize-into-pack: the
        VMEM scratch and the remote put are wire-dtyped, so the wire
        format never materializes in HBM — only the received buffer is,
        and the caller casts it back on acquire.
        """
        axis = plan.sched.axis_names[d]
        ring = plan.axis_sizes[d]
        jidx = jnp.asarray(idx)
        if self._kernel_ok(plan):
            try:
                from repro.kernels import halo_pack
                return halo_pack.put_signal(src2d, jidx, axis=axis,
                                            ring=ring, shift=shift,
                                            interpret=plan.spec.interpret,
                                            wire_dtype=wire)
            except Exception as e:  # pragma: no cover - backend-specific
                _latch_halo_fallback(plan, e, "put_signal failed")
        rows = jnp.take(src2d, jidx, axis=0)
        if wire is not None:
            rows = rows.astype(jnp.dtype(wire))
        perm = (_halo._perm_fwd(ring) if shift == -1
                else _halo._perm_rev(ring))
        return lax.ppermute(rows, axis, perm)

    def _fused_dim(self, plan, src2d: jnp.ndarray, maps: np.ndarray,
                   d: int) -> jnp.ndarray:
        """All of dim ``d``'s pulses in one fused kernel launch."""
        axis = plan.sched.axis_names[d]
        ring = plan.axis_sizes[d]
        n_local = src2d.shape[0]
        if self._kernel_ok(plan):
            try:
                from repro.kernels import halo_pack
                return halo_pack.fused_pulses(src2d, jnp.asarray(maps),
                                              axis=axis, ring=ring,
                                              n_local=n_local,
                                              interpret=plan.spec.interpret)
            except Exception as e:  # pragma: no cover - backend-specific
                _latch_halo_fallback(plan, e, "fused_pulses failed")
        # jnp oracle with the kernel's exact semantics: entries >= n_local
        # read the previous pulse's receive buffer (staged forwarding),
        # padding entries produce zero rows, puts become ppermutes.
        n_pulses, M = maps.shape
        perm = _halo._perm_fwd(ring)
        prev = jnp.zeros((M, src2d.shape[-1]), src2d.dtype)
        outs = []
        for p in range(n_pulses):
            idx = jnp.asarray(maps[p])
            valid = idx >= 0
            safe = jnp.maximum(idx, 0)
            local_rows = jnp.take(src2d, jnp.clip(safe, 0, n_local - 1),
                                  axis=0)
            dep_rows = jnp.take(prev, jnp.clip(safe - n_local, 0, M - 1),
                                axis=0)
            rows = jnp.where((safe >= n_local)[:, None], dep_rows,
                             local_rows)
            rows = jnp.where(valid[:, None], rows,
                             jnp.zeros((), rows.dtype))
            prev = lax.ppermute(rows, axis, perm)
            outs.append(prev)
        return jnp.stack(outs)

    # -- per-dim forward index maps (cached on the plan) -------------------

    def _dim_fwd_maps(self, plan, local_shape: Tuple[int, ...]):
        key = ("signal_fwd", local_shape)
        cached = plan._index_maps.get(key)
        if cached is not None:
            return cached
        shape = list(local_shape)
        per_dim = []
        for d in range(plan.spec.ndim):
            pulses = plan.sched.dim_pulses(d)
            w_total = plan.sched.widths[d]
            if w_total == 0:
                per_dim.append(None)
                continue
            if w_total > shape[d]:
                raise NotImplementedError(
                    f"signal backend: dim {d} halo width {w_total} exceeds "
                    f"the local block ({shape[d]}); multi-hop forwarding "
                    "(w > n) is not implemented")
            maps = [self._rows_along(shape, d, p.offset, p.offset + p.width)
                    for p in pulses]
            m_max = max(m.shape[0] for m in maps)
            padded = np.full((len(maps), m_max), -1, np.int32)
            for k, m in enumerate(maps):
                padded[k, :m.shape[0]] = m
            per_dim.append((padded, tuple(m.shape[0] for m in maps)))
            shape[d] += w_total
        plan._index_maps[key] = tuple(per_dim)
        return plan._index_maps[key]

    # -- exchange ----------------------------------------------------------

    def fwd(self, plan, local, wrap_shift):
        sched = plan.sched
        shifter = _halo._Shifter(sched.axis_names, plan.axis_sizes,
                                 wrap_shift)
        nd = plan.spec.ndim
        ext = local
        # single-pulse dims ship put_signal buffers at the coordinate
        # direction's f32 floor (the payload is pre-gridded at the plan
        # seam so the cast is exact); multi-pulse staged forwarding stays
        # dense — the
        # fused kernel forwards received rows without an intermediate
        # decode, which only matches the serialized reference bitwise
        # when no per-hop re-rounding is involved
        wire = plan.wire_pack_dtype(local.dtype)
        per_dim = self._dim_fwd_maps(plan, tuple(local.shape[:nd]))
        for d in range(nd):
            if per_dim[d] is None:
                continue
            padded, counts = per_dim[d]
            pulses = sched.dim_pulses(d)
            shape = ext.shape
            src2d = ext.reshape(math.prod(shape[:d + 1]), -1)
            if len(pulses) == 1:
                recvs = [self._put_rows(plan, src2d, padded[0][:counts[0]],
                                        d, shift=-1, wire=wire)]
            else:
                out = self._fused_dim(plan, src2d, padded, d)
                recvs = [out[k, :counts[k]] for k in range(len(pulses))]
            for pulse, rows in zip(pulses, recvs):
                rows = rows.astype(ext.dtype)    # dequantize-after-receive
                slab = rows.reshape(shape[:d] + (pulse.width,)
                                    + shape[d + 1:])
                ext = jnp.concatenate([ext, shifter(slab, d)], axis=d)
        return ext

    def rev(self, plan, ext):
        sched = plan.sched
        local_shape = self._local_shape(plan, ext)
        _, rev_maps = self._maps(plan, local_shape)
        out = ext
        for pulse, maps in zip(reversed(sched.serialized_order()), rev_maps):
            if maps is None:
                continue
            pack_idx, _add_idx = maps
            d, w, off = pulse.dim, pulse.width, pulse.offset
            shape = out.shape
            n = shape[d] - w
            src2d = out.reshape(math.prod(shape[:d + 1]), -1)
            # fused pack + put to the +1 neighbor: the force-return pulse
            recv_rows = self._put_rows(plan, src2d, pack_idx, d, shift=+1)
            body = lax.slice_in_dim(out, 0, n, axis=d)
            # unpack as a slab accumulate (the canonical CommUnpackF form):
            # a scatter here would hand downstream consumers a gather/
            # scatter layout and perturb how the integrator kick compiles,
            # breaking bitwise agreement with the serialized reference
            slab = recv_rows.reshape(shape[:d] + (w,) + shape[d + 1:])
            out = _halo._add_at(body, d, off, w, slab)
        return out


register_backend("signal", SignalBackend)
