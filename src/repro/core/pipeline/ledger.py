"""Signal/flag ledger: functional model of NVSHMEM put-with-signal state.

The paper's GPU-initiated kernels coordinate through *signals*: every
``nvshmem_put_signal_nbi`` atomically deposits data AND bumps a flag on
the receiver; consumers spin on ``acquire_wait(ctx.signal[p])`` before
touching the payload (Alg. 5).  Multi-step overlap (``depth``-buffered
halos) additionally needs per-*slot* flags so step ``N + depth - 1``'s
puts cannot clobber a buffer step ``N`` is still reading — the buffer
ring's reuse distance is exactly the in-flight window ``depth``.

XLA has no blocking primitive, so on TPU the dependency itself is carried
by the dataflow graph (a ``ppermute``/remote-copy result feeding its
consumer); what still needs modeling is the *bookkeeping* — which slot's
signals were released/acquired, whether every acquire had a matching
release, and whether a release ever landed on a slot still holding an
unconsumed deposit (the clobber the ring exists to prevent).
:class:`SignalLedger` is that model: a static slot layout ``(kind, buffer
slot, pulse)`` plus a :class:`LedgerState` pytree of release/acquire/
clobber counters threaded through the step ``lax.scan``.  A real NVSHMEM
backend would block where this ledger counts; tests assert the
conservation laws (acquired <= released, zero clobbers, zero in-flight
after the drain epilogue) that the hardware flags would enforce.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Union

import jax.numpy as jnp

KINDS = ("fwd", "rev")   # coordinate halo signals / force-return signals

# Deterministic fault injection (repro.resilience): canonical layout of
# the traced fault vector the block programs thread through the scan when
# an engine is built with ``inject=True``.  Entry ``s`` holds the
# block-relative step index at which site ``s`` fires (``DISARMED`` = the
# site stays healthy).  The layout lives here — next to the signal
# bookkeeping the ``signal_drop`` site perturbs — so the pipeline and
# ``repro.resilience.faults`` share one definition without an import
# cycle through the engine.
SCAN_FAULT_SITES = ("halo_corrupt", "force_nan", "signal_drop")
FAULT_HALO, FAULT_FORCE, FAULT_DROP = range(len(SCAN_FAULT_SITES))
DISARMED = -1


class LedgerState(NamedTuple):
    """Counters per ledger slot (pytree; scan-carry friendly)."""

    released: jnp.ndarray   # int32[n_slots] — put-with-signal deposits
    acquired: jnp.ndarray   # int32[n_slots] — acquire_wait completions
    clobbers: jnp.ndarray   # int32[n_slots] — releases onto a still-
    #                         outstanding slot (ring-reuse violations)


@dataclass(frozen=True)
class SignalLedger:
    """Static slot layout for a ``depth``-buffered pipeline.

    One signal per (kind, buffer slot, pulse): ``fwd`` signals gate the
    force kernel's reads of received coordinate halos, ``rev`` signals
    gate the integrator's reads of returned halo forces.  ``depth`` is
    the in-flight window: a buffer slot is re-released only ``depth``
    steps after its previous release, so a correctly scheduled window
    keeps every slot's outstanding count in ``{0, 1}`` and the clobber
    counters at zero (see :meth:`window_safe`).
    """

    depth: int       # halo buffer slots (2 = double buffer)
    n_pulses: int    # pulses per exchange direction

    def __post_init__(self):
        if self.depth < 1 or self.n_pulses < 1:
            raise ValueError("depth and n_pulses must be >= 1")

    @property
    def n_slots(self) -> int:
        return len(KINDS) * self.depth * self.n_pulses

    def slot(self, kind: str, buf: Union[int, jnp.ndarray], pulse: int):
        """Flat index of (kind, buffer slot, pulse); ``buf`` may be traced
        (the scan's ``step % depth`` parity)."""
        k = KINDS.index(kind)
        return (k * self.depth + buf % self.depth) * self.n_pulses + pulse

    def init(self) -> LedgerState:
        z = jnp.zeros((self.n_slots,), jnp.int32)
        return LedgerState(released=z, acquired=z, clobbers=z)

    # -- transitions (pure; ``buf`` may be a traced slot parity) -----------

    def release(self, st: LedgerState, kind: str, buf) -> LedgerState:
        """All of (kind, buf)'s pulse signals fire: puts were issued.

        A release onto a slot whose previous deposit is still unacquired
        is the buffer-clobber hazard the ring guards against; it is
        counted (not blocked — the ledger is a monitor, not a lock)."""
        idx = self._idx(kind, buf)
        outstanding = st.released[idx] - st.acquired[idx]
        clobbers = st.clobbers.at[idx].add(
            (outstanding >= 1).astype(jnp.int32), mode="drop")
        return LedgerState(st.released.at[idx].add(1, mode="drop"),
                           st.acquired, clobbers)

    def release_dropped(self, st: LedgerState, kind: str, buf,
                        dropped) -> LedgerState:
        """Injection hook: a put-with-signal whose signal may never land.

        ``dropped`` is a traced bool; when True the release is *skipped*
        (the data transfer itself still happens in the XLA model — this
        is the "dropped or delayed put-with-signal" fault, where the
        receiver's ledger sees a missing release), so the matching
        acquire drives ``consistent()`` False and the block's health
        scalar trips.  With ``dropped`` statically False this is exactly
        :meth:`release`."""
        rel = self.release(st, kind, buf)
        return LedgerState(
            jnp.where(dropped, st.released, rel.released),
            jnp.where(dropped, st.acquired, rel.acquired),
            jnp.where(dropped, st.clobbers, rel.clobbers))

    def acquire(self, st: LedgerState, kind: str, buf) -> LedgerState:
        """All of (kind, buf)'s pulse signals are consumed (acquire_wait)."""
        return LedgerState(st.released,
                           st.acquired.at[self._idx(kind, buf)].add(
                               1, mode="drop"),
                           st.clobbers)

    def _idx(self, kind: str, buf) -> jnp.ndarray:
        return self.slot(kind, buf, 0) + jnp.arange(self.n_pulses)

    # -- invariants --------------------------------------------------------

    def outstanding(self, st: LedgerState) -> jnp.ndarray:
        """released - acquired per slot (>= 0 iff causally consistent)."""
        return st.released - st.acquired

    def in_flight(self, st: LedgerState) -> jnp.ndarray:
        """Total deposits released but not yet acquired."""
        return self.outstanding(st).sum()

    def drained(self, st: LedgerState) -> jnp.ndarray:
        """True iff no deposit is in flight (the epilogue's exit state)."""
        return jnp.all(self.outstanding(st) == 0)

    def consistent(self, st: LedgerState) -> jnp.ndarray:
        """True iff no signal was ever acquired before its release."""
        return jnp.all(st.acquired <= st.released)

    def window_safe(self, st: LedgerState) -> jnp.ndarray:
        """True iff no release ever clobbered an outstanding slot — the
        guarantee a ``depth``-deep ring provides to a window that keeps
        at most ``depth - 1`` steps in flight."""
        return jnp.all(st.clobbers == 0)

    def summary(self, st: LedgerState, registry=None,
                prefix: str = "ledger") -> dict:
        """Host-side totals per kind (call outside jit on a final state).

        With a :class:`~repro.obs.registry.MetricsRegistry`, also
        publishes the totals as a ``ledger_summary`` record plus
        ``<prefix>/*`` gauges (the structured-emitter view of the same
        numbers)."""
        out = {}
        for k, kind in enumerate(KINDS):
            lo = k * self.depth * self.n_pulses
            hi = lo + self.depth * self.n_pulses
            out[kind] = {
                "released": int(st.released[lo:hi].sum()),
                "acquired": int(st.acquired[lo:hi].sum()),
            }
        out["consistent"] = bool(self.consistent(st))
        out["in_flight"] = int(self.in_flight(st))
        out["clobbers"] = int(st.clobbers.sum())
        out["window_safe"] = bool(self.window_safe(st))
        if registry is not None:
            registry.emit("ledger_summary", depth=self.depth,
                          n_pulses=self.n_pulses, data=out)
            for kind in KINDS:
                registry.gauge(f"{prefix}/{kind}_released").set(
                    out[kind]["released"])
                registry.gauge(f"{prefix}/{kind}_acquired").set(
                    out[kind]["acquired"])
            registry.gauge(f"{prefix}/in_flight").set(out["in_flight"])
            registry.gauge(f"{prefix}/clobbers").set(out["clobbers"])
        return out
