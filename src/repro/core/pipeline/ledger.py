"""Signal/flag ledger: functional model of NVSHMEM put-with-signal state.

The paper's GPU-initiated kernels coordinate through *signals*: every
``nvshmem_put_signal_nbi`` atomically deposits data AND bumps a flag on
the receiver; consumers spin on ``acquire_wait(ctx.signal[p])`` before
touching the payload (Alg. 5).  Multi-step overlap (double-buffered halos)
additionally needs per-*slot* flags so step ``N+1``'s puts cannot clobber a
buffer step ``N`` is still reading.

XLA has no blocking primitive, so on TPU the dependency itself is carried
by the dataflow graph (a ``ppermute``/remote-copy result feeding its
consumer); what still needs modeling is the *bookkeeping* — which slot's
signals were released/acquired, and whether every acquire had a matching
release.  :class:`SignalLedger` is that model: a static slot layout
``(kind, buffer slot, pulse)`` plus a :class:`LedgerState` pytree of
release/acquire counters threaded through the step ``lax.scan``.  A real
NVSHMEM backend would block where this ledger counts; tests assert the
conservation laws (acquired <= released, final balance per slot) that the
hardware flags would enforce.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Union

import jax.numpy as jnp

KINDS = ("fwd", "rev")   # coordinate halo signals / force-return signals


class LedgerState(NamedTuple):
    """Counters per ledger slot (pytree; scan-carry friendly)."""

    released: jnp.ndarray   # int32[n_slots] — put-with-signal deposits
    acquired: jnp.ndarray   # int32[n_slots] — acquire_wait completions


@dataclass(frozen=True)
class SignalLedger:
    """Static slot layout for a ``depth``-buffered pipeline.

    One signal per (kind, buffer slot, pulse): ``fwd`` signals gate the
    force kernel's reads of received coordinate halos, ``rev`` signals
    gate the integrator's reads of returned halo forces.
    """

    depth: int       # halo buffer slots (2 = double buffer)
    n_pulses: int    # pulses per exchange direction

    def __post_init__(self):
        if self.depth < 1 or self.n_pulses < 1:
            raise ValueError("depth and n_pulses must be >= 1")

    @property
    def n_slots(self) -> int:
        return len(KINDS) * self.depth * self.n_pulses

    def slot(self, kind: str, buf: Union[int, jnp.ndarray], pulse: int):
        """Flat index of (kind, buffer slot, pulse); ``buf`` may be traced
        (the scan's ``step % depth`` parity)."""
        k = KINDS.index(kind)
        return (k * self.depth + buf % self.depth) * self.n_pulses + pulse

    def init(self) -> LedgerState:
        z = jnp.zeros((self.n_slots,), jnp.int32)
        return LedgerState(released=z, acquired=z)

    # -- transitions (pure; ``buf`` may be a traced slot parity) -----------

    def release(self, st: LedgerState, kind: str, buf) -> LedgerState:
        """All of (kind, buf)'s pulse signals fire: puts were issued."""
        return LedgerState(self._bump(st.released, kind, buf), st.acquired)

    def acquire(self, st: LedgerState, kind: str, buf) -> LedgerState:
        """All of (kind, buf)'s pulse signals are consumed (acquire_wait)."""
        return LedgerState(st.released, self._bump(st.acquired, kind, buf))

    def _bump(self, arr: jnp.ndarray, kind: str, buf) -> jnp.ndarray:
        idx = self.slot(kind, buf, 0) + jnp.arange(self.n_pulses)
        return arr.at[idx].add(1)

    # -- invariants --------------------------------------------------------

    def outstanding(self, st: LedgerState) -> jnp.ndarray:
        """released - acquired per slot (>= 0 iff causally consistent)."""
        return st.released - st.acquired

    def consistent(self, st: LedgerState) -> jnp.ndarray:
        """True iff no signal was ever acquired before its release."""
        return jnp.all(st.acquired <= st.released)

    def summary(self, st: LedgerState) -> dict:
        """Host-side totals per kind (call outside jit on a final state)."""
        out = {}
        for k, kind in enumerate(KINDS):
            lo = k * self.depth * self.n_pulses
            hi = lo + self.depth * self.n_pulses
            out[kind] = {
                "released": int(st.released[lo:hi].sum()),
                "acquired": int(st.acquired[lo:hi].sum()),
            }
        out["consistent"] = bool(self.consistent(st))
        return out
