"""Cell-grid geometry and atom binning (the pair-search substrate).

GROMACS bins atoms into cluster cells and builds pair lists from cell
adjacency; we keep the cell grid itself as the pair structure (cutoff-sized
cells, 14 base-anchored stencil interactions — see forces.py) and re-bin
every ``nstlist`` steps, which plays the role of the pair-list "prune"
cadence in the paper's schedule analysis (§5.4).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class CellLayout:
    """Static geometry of the decomposed cell grid.

    ``mesh_shape`` is the 3-D domain grid (Z, Y, X domains); each domain
    holds ``cells_per_domain`` cutoff-sized cells with ``capacity`` atom
    slots per cell.  Positions are global; a domain's origin is
    ``domain_index * cells_per_domain * cell_size``.
    """

    box: Tuple[float, float, float]
    mesh_shape: Tuple[int, int, int]
    cells_per_domain: Tuple[int, int, int]
    capacity: int

    @property
    def cell_size(self) -> Tuple[float, float, float]:
        return tuple(
            self.box[d] / (self.mesh_shape[d] * self.cells_per_domain[d])
            for d in range(3))

    @property
    def global_cells(self) -> Tuple[int, int, int]:
        return tuple(self.mesh_shape[d] * self.cells_per_domain[d]
                     for d in range(3))

    @property
    def n_local_cells(self) -> int:
        cz, cy, cx = self.cells_per_domain
        return cz * cy * cx

    @property
    def pool(self) -> int:
        """Per-domain atom slot pool (flattened cell slots)."""
        return self.n_local_cells * self.capacity


def choose_layout(box, mesh_shape, r_cut: float, n_atoms: int,
                  safety: float = 2.2, min_capacity: int = 8) -> CellLayout:
    """Pick cutoff-sized cells and a slot capacity with headroom.

    Cell size must be >= r_cut so a one-cell halo covers the cutoff sphere
    (single pulse per dimension — the common GROMACS regime, paper §2.2).
    """
    cells = []
    for d in range(3):
        c = int(np.floor(box[d] / (mesh_shape[d] * r_cut)))
        if c < 1:
            raise ValueError(
                f"domain extent {box[d] / mesh_shape[d]:.3f} < r_cut={r_cut}"
                f" along dim {d}: too many domains for this system")
        cells.append(c)
    n_cells = int(np.prod([mesh_shape[d] * cells[d] for d in range(3)]))
    avg_occ = n_atoms / n_cells
    cap = max(min_capacity, int(np.ceil(avg_occ * safety)))
    cap = int(np.ceil(cap / 4) * 4)   # pad for vectorization
    return CellLayout(box=tuple(float(b) for b in box),
                      mesh_shape=tuple(mesh_shape),
                      cells_per_domain=tuple(cells), capacity=cap)


def bin_to_cells(pos, feats_f, feats_i, layout: CellLayout, domain_index):
    """Scatter a flat atom pool into (cz, cy, cx, K, ...) cell arrays.

    ``pos`` (P,3) with invalid slots marked by ``feats_i[..., 0] < 0`` (the
    atom id).  Returns (cell_f, cell_i, overflow_count).  Overflowing atoms
    (rank >= capacity) are dropped and counted — tests assert the count
    stays zero under the chosen safety factor.

    Pure function of jnp arrays; runs inside shard_map.  ``domain_index``
    is the (3,) int vector of this device's domain coordinates.
    """
    cz, cy, cx = layout.cells_per_domain
    K = layout.capacity
    csz = jnp.asarray(layout.cell_size, pos.dtype)
    origin = domain_index.astype(pos.dtype) * \
        jnp.asarray(layout.cells_per_domain, pos.dtype) * csz

    valid = feats_i[:, 0] >= 0
    rel = (pos - origin) / csz
    cell3 = jnp.floor(rel).astype(jnp.int32)
    cell3 = jnp.clip(cell3, 0, jnp.asarray([cz - 1, cy - 1, cx - 1]))
    cell_id = (cell3[:, 0] * cy + cell3[:, 1]) * cx + cell3[:, 2]
    n_cells = cz * cy * cx
    cell_id = jnp.where(valid, cell_id, n_cells)          # invalid -> sentinel

    order = jnp.argsort(cell_id, stable=True)
    sorted_id = cell_id[order]
    # rank within the cell: index - first occurrence of this cell id
    first = jnp.searchsorted(sorted_id, sorted_id, side="left")
    rank = jnp.arange(sorted_id.shape[0]) - first
    keep = (sorted_id < n_cells) & (rank < K)
    overflow = jnp.sum((sorted_id < n_cells) & (rank >= K))

    slot = jnp.where(keep, sorted_id * K + rank, n_cells * K)
    Pf = feats_f.shape[-1]
    Pi = feats_i.shape[-1]
    cell_f = jnp.zeros((n_cells * K + 1, 3 + Pf), pos.dtype)
    cell_i = jnp.full((n_cells * K + 1, Pi), -1, feats_i.dtype)
    src_f = jnp.concatenate([pos, feats_f], axis=-1)[order]
    cell_f = cell_f.at[slot].set(jnp.where(keep[:, None], src_f, 0.0))
    cell_i = cell_i.at[slot].set(jnp.where(keep[:, None], feats_i[order], -1))
    cell_f = cell_f[:-1].reshape(cz, cy, cx, K, 3 + Pf)
    cell_i = cell_i[:-1].reshape(cz, cy, cx, K, Pi)
    return cell_f, cell_i, overflow


def cell_counts(cell_i) -> jnp.ndarray:
    """Per-cell occupied-slot counts: (..., K, Pi) int arrays -> (...).

    Binning packs each cell's atoms into a contiguous slot prefix (see
    ``bin_to_cells``), so ``counts`` is also the first padding slot — the
    pair-schedule prune relies on both properties.
    """
    return jnp.sum(cell_i[..., 0] >= 0, axis=-1).astype(jnp.int32)


def cell_levels(counts, quantum: int) -> jnp.ndarray:
    """Quantized per-cell occupancy levels: ``ceil(count / quantum)``.

    Level 0 marks empty cells.  The pair schedule's per-pair slot bound is
    the max of the two cells' levels, so a cell-pair batch executed at
    ``level * quantum`` slots covers every occupied slot of both cells
    (binning packs atoms into a contiguous slot prefix — see
    ``bin_to_cells`` / ``cell_counts``).
    """
    q = jnp.asarray(quantum, counts.dtype)
    return ((counts + q - 1) // q).astype(jnp.int32)


def cell_bounds(pos, cell_i, big: float = 1e30):
    """Per-cell position bounding boxes over valid slots.

    pos: (..., K, 3); returns (lo, hi) of shape (..., 3).  Empty cells
    yield inverted boxes at ``(+big, -big)`` — finite sentinels, so
    box-to-box gap computations stay NaN-free and any pair touching an
    empty cell lands beyond every cutoff.
    """
    valid = (cell_i[..., 0] >= 0)[..., None]
    big = jnp.asarray(big, pos.dtype)
    lo = jnp.min(jnp.where(valid, pos, big), axis=-2)
    hi = jnp.max(jnp.where(valid, pos, -big), axis=-2)
    return lo, hi


def cells_to_pool(cell_f, cell_i):
    """Flatten cell arrays back into the (P, ...) atom pool."""
    K = cell_f.shape[3]
    n = cell_f.shape[0] * cell_f.shape[1] * cell_f.shape[2] * K
    return (cell_f.reshape(n, cell_f.shape[-1]),
            cell_i.reshape(n, cell_i.shape[-1]))
