"""TPU-resident MD time-stepping with fused or serialized halo exchange.

The step structure mirrors the paper's Algorithm 2 (GPU-resident skeleton):

  1. coordinate halo exchange            (FusedPackCommX    -> exchange_fwd_*)
  2. non-bonded forces, local + non-local (NB F kernels      -> compute_forces)
  3. force halo exchange + accumulate     (FusedCommUnpackF -> exchange_rev_*)
  4. integration                          (update stream     -> velocity Verlet)

A whole ``nstlist`` block of steps is one jitted shard_map program: no
host round-trip between steps, the TPU analogue of "launch tens to
hundreds of time-steps before CPU-GPU sync" (paper §3).  The scan body is
delegated to :class:`repro.core.pipeline.StepPipeline`: ``pipeline="off"``
runs the strictly serialized reference chain, ``"double_buffer"`` the
software-pipelined schedule in which step N's force-return exchange is
issued in the same program region as step N+1's coordinate sends
(``pipeline_depth``-slot extended-force ring, signal-ledger bookkeeping;
``depth > 2`` unrolls ``depth - 1`` steps per fused region).
Re-binning/migration — GROMACS' DD + neighbor-search work — runs between
blocks as its own program, off the hot path (paper §5.4); with
``overlap_rebin=True`` the rebin/migration gather and the pair-schedule
prune are fused INTO the block program's final region instead (GROMACS'
DLB analogue: the nstlist-cadence work overlaps the last step's
force/epilogue rather than costing its own host dispatch).

State layout per device (all static shapes):
  cell_f (cz, cy, cx, K, 7)  [x, y, z, charge, vx, vy, vz]
  cell_i (cz, cy, cx, K, 2)  [atom id (-1 = empty), type]
  force  (cz, cy, cx, K, 3)  forces at t (velocity-Verlet carry)
"""
from __future__ import annotations

import dataclasses
import functools
import math
import warnings
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map_norep

from repro.core.halo_plan import HaloPlan, HaloSpec
from repro.core.md import integrate
from repro.core.md.cells import CellLayout, choose_layout
from repro.core.md.domain import AXES, domain_index, rebin
from repro.core.md.forces import compute_forces
from repro.core.md.pair_schedule import (
    PAIR_BUCKET,
    SLOT_QUANTUM,
    PairSchedule,
    force_backends,
    get_force_backend,
    inner_radius as default_inner_radius,
    probe_pallas,
    prune_local,
    prune_radius,
    roll_prune,
)
from repro.core.md.schedule_opt import bucket, tier_cum, tier_plan, tier_rows
from repro.core.md.schedule_opt import noop  # critical-path opt hook (§5.4)
from repro.core.md.system import MDSystem
from repro.core.pipeline import PIPELINE_MODES, StepFns, StepPipeline
from repro.core.pipeline.ledger import DISARMED, SCAN_FAULT_SITES
from repro.obs import PhaseTracer, default_registry
from repro.obs import span as obs_span


@dataclasses.dataclass
class RunState:
    """Live block-loop state of one simulation run.

    :meth:`MDEngine.begin_run` creates it; :meth:`MDEngine.run_block` and
    :meth:`MDEngine.advance_schedule` mutate it in place.  ``simulate``
    is a thin loop over these three, and the resilience runner
    (:mod:`repro.resilience`) drives the same API with fault arming,
    health reads, and checkpoint/rollback between blocks — both loops
    visit bitwise-identical states.
    """

    cell_f: jax.Array
    cell_i: jax.Array
    force: jax.Array          # velocity-Verlet force carry (post-rebin)
    sched: tuple | None       # (sel, tiers, tiers_inner) or None (dense)
    disable: bool             # next refresh falls back to the outer ladder
    step: int                 # steps completed so far
    diags: list               # per-rebin migration diagnostics


class MDEngine:
    """Binds a system + mesh + HaloSpec into jitted step/rebin programs.

    ``spec`` selects the halo backend and widths; the engine fills in the
    physics the spec leaves open (periodic wrap shifts from the box) and
    builds one :class:`HaloPlan` reused by every step/rebin/force program.
    ``pipeline`` selects the multi-step schedule (``"off"`` or
    ``"double_buffer"``, see :class:`repro.core.pipeline.StepPipeline`)
    and ``pipeline_depth`` its in-flight window (ring slots; 2 = the
    paper's double-buffered halos, >2 unrolls deeper windows);
    every (mode, depth) produces bitwise-identical trajectories.
    ``overlap_rebin=True`` fuses the between-block rebin/migration and
    pair-schedule prune into the block program's final region (one
    compiled dispatch per block instead of two or three); the fused and
    host-dispatched paths are bitwise-identical as well.

    ``force_backend`` selects the NB force engine
    (:mod:`repro.core.md.pair_schedule`): ``"dense"`` (default) is the
    unchanged 14-zone loop and keeps trajectories bitwise-identical to
    earlier engines; ``"sparse"`` / ``"pallas"`` execute the pruned
    cell-pair schedule (rebuilt every rebin, off the hot path) and match
    dense to tolerance.  ``capacity_safety`` is the per-cell slot
    headroom factor fed to :func:`choose_layout` — the padding the
    pruned backends stop paying for.  Degenerate layouts with a single
    global cell along any dim (a halo cell would alias its own periodic
    image) degrade to the dense backend with a warning instead of
    erroring.

    ``nstprune`` switches the pruned backends to GROMACS' **dual pair
    list**: the rebin-cadence prune builds the outer list at the
    Verlet-buffer radius, and every ``nstprune`` steps *inside* the
    block program a rolling prune re-partitions it with current
    coordinates at ``inner_radius`` (default
    :func:`repro.core.md.pair_schedule.inner_radius`: ``r_cut`` plus
    TWICE the 3-sigma drift over ``nstprune`` steps — both pair members
    move, same convention as the outer radius), so the evaluated tier
    ladder shrinks between rebins with no host round-trips.  The inner
    ladder is sized from the rebin-time histogram times
    ``inner_safety``; a refresh that outgrows it is counted
    (``pair_stats()["inner_overflow_blocks"]``), reported once as a
    warning, and the next block conservatively falls back to the outer
    ladder.
    """

    def __init__(self, system: MDSystem, mesh: Mesh,
                 spec: HaloSpec | None = None,
                 r_list_factor: float = 1.08, mig_frac: float = 0.125,
                 pipeline: str = "off", pipeline_depth: int = 2,
                 overlap_rebin: bool = False,
                 force_backend: str = "dense",
                 capacity_safety: float = 2.2,
                 nstprune: int = 0,
                 inner_radius: float | None = None,
                 inner_safety: float = 1.5,
                 pair_bucket: int = PAIR_BUCKET,
                 wire_dtype: str | None = None,
                 verify: str = "error",
                 obs=None, trace: bool = False,
                 inject: bool = False, health: bool = False,
                 layout_atoms: int | None = None,
                 static_ladder: bool = False):
        if spec is None:
            spec = HaloSpec(axis_names=AXES, widths=(1, 1, 1))
        if spec.axis_names != tuple(AXES):
            raise ValueError(f"MD halo spec must decompose over {AXES}, "
                             f"got {spec.axis_names}")
        if pipeline not in PIPELINE_MODES:
            raise ValueError(f"unknown pipeline mode {pipeline!r}; "
                             f"available: {PIPELINE_MODES}")
        if int(pipeline_depth) < 2:
            raise ValueError("pipeline_depth must be >= 2 (ring slots; "
                             "2 = double-buffered halos)")
        if min(spec.widths) < 1:
            raise ValueError("MD halo widths must be >= 1 (the NB stencil "
                             "consumes one halo cell layer)")
        if force_backend not in force_backends():
            raise ValueError(f"unknown force backend {force_backend!r}; "
                             f"available: {force_backends()}")
        if int(nstprune) < 0:
            raise ValueError("nstprune must be >= 0 (0 disables the "
                             "rolling inner prune)")
        if inject and overlap_rebin:
            raise ValueError(
                "inject=True is incompatible with overlap_rebin: fault "
                "epochs are block-aligned and the fused path would commit "
                "a poisoned block's rebin/migration before the health "
                "scalars are read at the boundary")
        # deterministic fault injection (repro.resilience): inject=True
        # builds the block programs with a traced fault-vector operand
        # (ledger.SCAN_FAULT_SITES layout); inject=False traces the exact
        # pre-existing programs — zero cost, bitwise-identical.  health
        # adds the pmax'd in-scan monitors (NaN/Inf counts, ledger
        # violations) to the block metrics.
        self.inject = bool(inject)
        self.health = bool(health)
        # rebuild()/reshard() recreate the engine from these; captured
        # before the tiny-box degrade below so a rebuilt engine re-derives
        # its own fallbacks for the (possibly different) new layout
        self._init_kwargs = dict(
            spec=spec, r_list_factor=r_list_factor, mig_frac=mig_frac,
            pipeline=pipeline, pipeline_depth=pipeline_depth,
            overlap_rebin=overlap_rebin, force_backend=force_backend,
            capacity_safety=capacity_safety, nstprune=nstprune,
            inner_radius=inner_radius, inner_safety=inner_safety,
            pair_bucket=pair_bucket, wire_dtype=wire_dtype, verify=verify,
            obs=obs, trace=trace, inject=inject, health=health,
            layout_atoms=layout_atoms, static_ladder=static_ladder)
        self.system = system
        self.mesh = mesh
        self.pipeline_mode = pipeline
        self.pipeline_depth = int(pipeline_depth)
        self.overlap_rebin = bool(overlap_rebin)
        mesh_shape = tuple(mesh.shape[a] for a in AXES)
        r_list = system.params.ff.r_cut * r_list_factor
        # ``layout_atoms`` sizes the cell capacity as if the system held
        # that many atoms — the SimServer bucket contract: every replica
        # of one (n_replicas, n_atoms_bucket) bucket shares the bucket's
        # layout, so a sub-bucket replica's solo reference run uses the
        # exact array shapes (and op sequence) of its batched row
        self.layout_atoms = int(layout_atoms) if layout_atoms else None
        self.layout = choose_layout(system.box, mesh_shape, r_list,
                                    self.layout_atoms or system.n_atoms,
                                    safety=capacity_safety)
        if force_backend != "dense" and min(self.layout.global_cells) < 2:
            # tiny-box path: a pair schedule cannot distinguish a halo
            # cell from its own periodic image here; fall back to the
            # dense engine (which masks self-image pairs by atom id)
            warnings.warn(
                f"layout {self.layout.global_cells} has a single global "
                f"cell along some dim; the {force_backend!r} pair "
                "schedule degrades to the 'dense' force backend",
                RuntimeWarning, stacklevel=2)
            force_backend = "dense"
        self.force_backend = force_backend
        if force_backend == "dense":
            nstprune = 0               # dual list rides the pair schedule
        # ``static_ladder``: the pruned backends execute a DATA-INDEPENDENT
        # worst-case tier ladder (every worklist row at the deepest level)
        # instead of the measured histogram's.  Exec shapes then depend on
        # the layout alone — the property the SimServer's no-recompile-at-
        # admission contract and its replica isolation both rest on: a
        # replica's ladder can neither retrace the block program nor leak
        # information about co-resident replicas.  The prune still runs
        # (``sel`` masks dropped pairs with the inert sentinel), so the
        # physics is unchanged; only the padding accounting grows.
        self.static_ladder = bool(static_ladder)
        if self.static_ladder and int(nstprune):
            raise ValueError(
                "static_ladder=True is incompatible with nstprune: the "
                "rolling inner prune exists to shrink the measured ladder "
                "the static ladder deliberately ignores")
        self.nstprune = int(nstprune)
        self.inner_safety = float(inner_safety)
        # pair-count quantum of the tier ladders: smaller = tighter exec
        # shapes (more distinct compiled block programs), larger = fewer
        # recompiles; PAIR_BUCKET is the production default
        self.pair_bucket = max(int(pair_bucket), 1)
        if self.nstprune:
            self.r_inner = float(
                default_inner_radius(system.params, self.nstprune)
                if inner_radius is None else inner_radius)
            if self.r_inner < system.params.ff.r_cut:
                raise ValueError(
                    f"inner_radius {self.r_inner} < r_cut "
                    f"{system.params.ff.r_cut}: the rolling prune would "
                    "drop interacting pairs outright")
        else:
            self.r_inner = None
        self.axis_sizes = mesh_shape
        self.mig_cap = max(64, int(self.layout.pool * mig_frac))
        self.pair_schedule = None
        self.r_prune = prune_radius(system.params)
        self._sched_exec = None     # (sel, tiers, tiers_inner) of last prune
        self._inner_overflows = 0   # blocks whose refresh outgrew the ladder
        # per-block (outer_rows, inner_rows) ladder sizes — the dual
        # list's activity trace (inner < outer = the rolling prune is
        # actually shrinking the evaluated schedule that block)
        self.sched_history: list[tuple[int, int]] = []
        if force_backend != "dense":
            self.pair_schedule = PairSchedule.build(self.layout)
            self._pair_stats = self.pair_schedule.slot_pair_stats()
            if force_backend == "pallas":
                # compile-time kernel failures latch the jnp fallback
                # here, before any block program is built (see
                # pair_schedule.probe_pallas)
                probe_pallas(system.params.ff, interpret=spec.interpret)
        else:
            # dense never builds a worklist (degenerate one-global-cell
            # layouts stay supported); mirror its accounting directly
            from repro.core.md.forces import stencil_pairs
            n_dense = len(stencil_pairs()) * self.layout.n_local_cells
            self._pair_stats = {
                "n_pairs_dense": n_dense,
                "k_capacity": self.layout.capacity,
                "dense_slot_pairs": n_dense * self.layout.capacity ** 2,
                "evaluated_slot_pairs": n_dense * self.layout.capacity ** 2,
                "prune_ratio": 1.0,
            }
        self._pair_stats["force_backend"] = force_backend
        dt = system.pos.dtype
        if spec.wrap_shift is None:
            ws = np.zeros((3, 4), dt)
            for d in range(3):
                ws[d, d] = system.box[d]
            spec = spec.with_wrap_shift(ws)
        # feature layout for byte accounting: each exchanged cell carries
        # `capacity` atom slots of 4 floats (x, y, z, charge); the (K, 2)
        # int32 cell_i exchange is excluded from the canonical stats.
        # ``wire_dtype`` compresses the floating payload on the wire
        # (cell_i always rides dense); plan build runs the drift gate
        # with this engine's verify mode, so an over-aggressive wire
        # format is rejected here unless explicitly waived.
        if wire_dtype is not None:
            spec = dataclasses.replace(spec, wire_dtype=wire_dtype)
        self.wire_dtype = spec.wire_dtype
        self.plan = HaloPlan.build(
            dataclasses.replace(spec, dtype=np.dtype(dt).name,
                                feature_elems=4 * self.layout.capacity),
            mesh, verify=verify)
        self._spec = P(*AXES)
        # build-time gate: config sanity (nstprune vs block length, list
        # radii, pool/capacity factors) plus a static replay of the comm
        # schedule every block program will emit — unsafe configs are
        # rejected here with a counterexample trace instead of failing
        # deep in tracing (or corrupting trajectories silently).
        # ``verify="warn"`` downgrades to warnings, ``"off"`` skips.
        self._verify = verify
        from repro.analysis.schedule_verifier import gate_md_build
        self.schedule_report = gate_md_build(
            nstlist=int(system.params.nstlist), nstprune=self.nstprune,
            pipeline=self.pipeline_mode,
            pipeline_depth=self.pipeline_depth,
            overlap_rebin=self.overlap_rebin,
            force_backend=self.force_backend,
            n_pulses=max(1, self.plan.sched.total_pulses), verify=verify,
            inner_safety=self.inner_safety, r_list_factor=r_list_factor,
            mig_frac=mig_frac, capacity_safety=capacity_safety)
        # observability: every stats surface also publishes structured
        # records/instruments here; ``trace=True`` additionally threads
        # per-step ``obs/*`` ledger counters through the block programs
        # (barrier-neutral — trajectories stay bitwise-identical).
        self.obs = obs if obs is not None else default_registry()
        self.tracer = PhaseTracer(enabled=bool(trace))
        self.obs.emit(
            "engine_build", backend=self.backend,
            pipeline=self.pipeline_mode, pipeline_depth=self.pipeline_depth,
            overlap_rebin=self.overlap_rebin,
            force_backend=self.force_backend, nstprune=self.nstprune,
            n_atoms=system.n_atoms, global_cells=self.layout.global_cells,
            capacity=self.layout.capacity,
            schedule_safe=(None if self.schedule_report is None
                           else self.schedule_report.safe))
        self._build_programs()

    @property
    def spec(self) -> HaloSpec:
        return self.plan.spec

    @property
    def backend(self) -> str:
        return self.plan.spec.backend

    def halo_stats(self) -> dict:
        """Plan-reported bytes/critical-path stats at this DD layout.

        On top of the canonical float payload this accounts the ``(K, 2)``
        int32 ``cell_i`` exchange (``bytes_index`` — hoisted to once per
        block, hence reported separately from the per-step payload) and
        the occupancy-adjusted ``useful_bytes``: the capacity padding is
        exchanged but carries no atoms.
        """
        K = self.layout.capacity
        gz, gy, gx = self.layout.global_cells
        occupancy = self.system.n_atoms / float(gz * gy * gx * K)
        return self.plan.publish_stats(self.obs,
                                       self.layout.cells_per_domain,
                                       index_elems=2 * K, index_itemsize=4,
                                       occupancy=occupancy,
                                       pipeline=self.pipeline_mode,
                                       depth=self.pipeline_depth)

    def pair_stats(self) -> dict:
        """Evaluated-slot-pair accounting of the latest pruned block.

        Per domain per step; ``prune_ratio`` is the dense-over-evaluated
        work reduction (1.0 for the dense backend).
        ``pallas_fallback`` flags a ``"pallas"`` engine whose kernel
        failed and is actually running the jnp twin.
        """
        out = dict(self._pair_stats)
        if self.nstprune:
            # live counter, not the last _bucket_exec's snapshot: a
            # final block's overflow has no further rebin to record it
            out["inner_overflow_blocks"] = self._inner_overflows
        if self.force_backend == "pallas":
            from repro.core.md.pair_schedule import pallas_fallback_active
            out["pallas_fallback"] = pallas_fallback_active()
        self.obs.emit("pair_stats", data=out)
        self.obs.gauge("md/prune_ratio").set(out.get("prune_ratio", 1.0))
        return out

    def overlap_stats(self) -> dict:
        """Per-step overlap model at this engine's pipeline mode/depth."""
        overlap = self.plan.stats(self.layout.cells_per_domain,
                                  pipeline=self.pipeline_mode,
                                  depth=self.pipeline_depth)["overlap"]
        self.obs.emit("overlap_model", backend=self.backend, data=overlap)
        return overlap

    def _trim_ext(self, ext):
        """First halo cell layer of an extended block (the NB stencil
        reaches exactly one cell); identity at the default widths."""
        if max(self.spec.widths) == 1:
            return ext
        n = self.layout.cells_per_domain
        return ext[tuple(slice(0, n[d] + 1) for d in range(3))]

    def _pad_force(self, F_trim, ext_shape):
        """Zero-pad trimmed forces back to the full extended block (layers
        beyond the first contribute nothing, the reverse path still
        returns them so widths > 1 stay trajectory-neutral)."""
        if max(self.spec.widths) == 1:
            return F_trim
        n = self.layout.cells_per_domain
        F = jnp.zeros(tuple(ext_shape[:3]) + F_trim.shape[3:], F_trim.dtype)
        return F.at[tuple(slice(0, n[d] + 1) for d in range(3))].set(F_trim)

    def _force_pass(self, cell_f, cell_i):
        """Coordinate halo -> forces -> force halo (paper Alg. 3/6).

        Runs inside the engine's shard_map, so the plan's device-local
        methods are used; gradients through this pass would follow the
        plan's fused reverse path (``HaloPlan.exchange``).
        """
        ext_f = self.plan.fwd_local(cell_f[..., :4])
        ext_i = self.plan.fwd_local(cell_i, wrap_shift=None)
        F_trim, pe = compute_forces(self._trim_ext(ext_f),
                                    self._trim_ext(ext_i), self.layout,
                                    self.system.params.ff)
        f_local = self.plan.rev_local(self._pad_force(F_trim, ext_f.shape))
        return f_local, lax.psum(pe, AXES)

    def _force_pass_sched(self, cell_f, cell_i, sel, tiers):
        """Schedule-driven force pass (device-local, pruned backends)."""
        ext_f = self.plan.fwd_local(cell_f[..., :4])
        ext_i = self.plan.fwd_local(cell_i, wrap_shift=None)
        backend_fn = get_force_backend(self.force_backend)
        F_trim, pe = backend_fn(
            self._trim_ext(ext_f), self._trim_ext(ext_i), self.layout,
            self.system.params.ff, sched=self.pair_schedule,
            sel=lax.slice(sel.reshape(-1), (0,), (tier_rows(tiers),)),
            tiers=tiers, interpret=self.spec.interpret)
        f_local = self.plan.rev_local(self._pad_force(F_trim, ext_f.shape))
        return f_local, lax.psum(pe, AXES)

    # ---- step physics, split at the halo seams (StepFns) -------------------

    def _make_step_fns(self) -> StepFns:
        """The per-step physics as pipeline callbacks.

        ``ctx`` carries the block-constant arrays: ``cell_i`` (atom
        ids/types never change within a block — migration runs between
        blocks), its pre-exchanged extension ``ext_i``, and — for the
        pruned force backends — the current pair schedule (``pair_sel``
        packed-pair prefix + the static ``tiers`` ladder), so both
        pipeline modes execute the same worklist.  With the rolling
        inner prune the engine swaps ``pair_sel``/``tiers`` between
        sub-blocks; each sub-block's ctx is still block-constant.
        """
        params = self.system.params
        mass, dt = params.mass, params.dt
        layout, ff = self.layout, params.ff
        backend_fn = get_force_backend(self.force_backend)
        sched, interp = self.pair_schedule, self.spec.interpret

        def eval_forces(ext_f_trim, ext_i_trim, ctx):
            if "pair_sel" not in ctx:      # dense: the unchanged path
                return compute_forces(ext_f_trim, ext_i_trim, layout, ff)
            return backend_fn(ext_f_trim, ext_i_trim, layout, ff,
                              sched=sched, sel=ctx["pair_sel"],
                              tiers=ctx["tiers"], interpret=interp)

        def begin(cell_f, force, ctx):
            valid = ctx["cell_i"][..., 0] >= 0
            vmask = valid[..., None]
            # velocity Verlet: kick-drift
            vel_half = cell_f[..., 4:7] + jnp.where(
                vmask, force * (dt / (2 * mass)), 0.0)
            pos_new = cell_f[..., :3] + jnp.where(vmask, vel_half * dt, 0.0)
            cell_f = cell_f.at[..., :3].set(pos_new)
            return cell_f, vel_half, cell_f[..., :4]

        def force(ext_f, ctx):
            F_trim, pe = eval_forces(self._trim_ext(ext_f),
                                     ctx["ext_i_trim"], ctx)
            return self._pad_force(F_trim, ext_f.shape), \
                {"pe": lax.psum(pe, AXES)}

        def finish(cell_f, vel_half, f_new, ctx):
            valid = ctx["cell_i"][..., 0] >= 0
            vmask = valid[..., None]
            f_new = jnp.where(vmask, f_new, 0.0)
            # kick; the where between the product and the sum (same form
            # as the kick-drift in ``begin``) keeps the rounding fixed —
            # a bare mul+add can FMA-contract differently depending on how
            # the surrounding halo-backend graph fuses
            vel_new = vel_half + jnp.where(vmask,
                                           f_new * (dt / (2 * mass)), 0.0)
            cell_f = cell_f.at[..., 4:7].set(jnp.where(vmask, vel_new, 0.0))
            ke = integrate.kinetic_energy(vel_new, valid, mass)
            mom = integrate.momentum(jnp.where(vmask, vel_new, 0.0),
                                     valid, mass)
            noop()  # schedule-optimization hook (see schedule_opt)
            m = {"ke": ke, "mom": mom}
            if self.health:
                # in-scan NaN/Inf monitor: one pmax-free psum'd int32 per
                # step over positions/velocities and the returned forces;
                # a pure observer of barrier-pinned state, so trajectories
                # stay bitwise-identical with health on
                bad = (jnp.sum(~jnp.isfinite(cell_f), dtype=jnp.int32)
                       + jnp.sum(~jnp.isfinite(f_new), dtype=jnp.int32))
                m["health/nonfinite"] = lax.psum(bad, AXES)
            return cell_f, f_new, m

        return StepFns(begin=begin, force=force, finish=finish)

    # ---- programs ----------------------------------------------------------

    def _block_ctx(self, cell_i):
        return {"cell_i": cell_i,
                "ext_i_trim": self._trim_ext(
                    self.plan.fwd_local(cell_i, wrap_shift=None))}

    def _build_programs(self):
        layout, mig_cap = self.layout, self.mig_cap
        # verify="off": the engine's own gate already verified a superset
        # (block length, nstprune sub-blocks, rebin fusion) of what the
        # pipeline-level gate would re-probe
        self.pipeline = StepPipeline.build(self.plan, self._make_step_fns(),
                                           mode=self.pipeline_mode,
                                           depth=self.pipeline_depth,
                                           verify="off",
                                           tracer=self.tracer,
                                           inject=self.inject)
        sc = self.tracer.scope

        def run_pipe(cell_f, force, n_steps, ctx):
            """Pipeline invocation + the per-invocation ledger monitor."""
            cell_f, f_last, m, led = self.pipeline.run_local(
                cell_f, force, n_steps, ctx)
            if self.health:
                # ledger-invariant monitor: 1 iff any put-with-signal
                # bookkeeping law was violated over this invocation
                # (undrained deposits, acquire-before-release, slot
                # clobber) — pmax'd so every device reports the global
                # verdict, read with the other boundary scalars
                lg = self.pipeline.ledger
                bad = (jnp.not_equal(lg.in_flight(led), 0)
                       | ~lg.consistent(led)
                       | ~lg.window_safe(led)).astype(jnp.int32)
                m = {**m, "health/led_violation": lax.pmax(bad, AXES)[None]}
            return cell_f, f_last, m

        def block_impl(cell_f, cell_i, force, fv, n_steps):
            ctx = self._block_ctx(cell_i)
            if fv is not None:
                ctx["fault_vec"] = fv
            cell_f, f_last, metrics = run_pipe(cell_f, force, n_steps, ctx)
            return cell_f, cell_i, f_last, metrics

        def block(cell_f, cell_i, force, n_steps):
            return block_impl(cell_f, cell_i, force, None, n_steps)

        def block_sched_impl(cell_f, cell_i, force, sel, fv, n_steps,
                             tiers, tiers_inner):
            """Pruned-backend block; ``tiers``/``tiers_inner`` static.

            With an inner ladder the block is a python-unrolled chain of
            ``nstprune``-step sub-blocks: each starts with the rolling
            prune (current-coordinate re-partition of the outer prefix,
            :func:`repro.core.md.pair_schedule.roll_prune`) and runs the
            step pipeline over the inner ladder only.  The returned
            overflow scalar counts survivors the static ladder could not
            seat (0 = the inner approximation held).
            """
            ctx = self._block_ctx(cell_i)
            sel_flat = sel.reshape(-1)
            zero = jnp.zeros((), jnp.int32)
            if not tiers_inner:
                ctx["pair_sel"] = lax.slice(sel_flat, (0,),
                                            (tier_rows(tiers),))
                ctx["tiers"] = tiers
                if fv is not None:
                    ctx["fault_vec"] = fv
                cell_f, f_last, metrics = run_pipe(cell_f, force, n_steps,
                                                   ctx)
                return cell_f, cell_i, f_last, metrics, zero
            L = self.pair_schedule.levels
            budget = jnp.asarray(tier_cum(tiers_inner, SLOT_QUANTUM, L),
                                 jnp.int32)
            n_inner = tier_rows(tiers_inner)
            sel_exec = lax.slice(sel_flat, (0,), (tier_rows(tiers),))
            overflow, f_cur, chunks, done = zero, force, [], 0
            while done < n_steps:
                take = min(self.nstprune, n_steps - done)
                # the done=0 refresh re-derives the inner partition the
                # boundary prune already saw (same coordinates) — kept
                # deliberately: sel stays outer-packed so force_fn /
                # the outer-ladder fallback remain valid on it, and the
                # cost is one exchange + sort per nstlist block, off
                # the per-step path
                with sc("roll_prune"):
                    ext_f = self.plan.fwd_local(cell_f[..., :4])
                    sel_exec, cum_s = roll_prune(
                        self.pair_schedule, sel_exec, self._trim_ext(ext_f),
                        ctx["ext_i_trim"], self.r_inner)
                overflow = jnp.maximum(
                    overflow, jnp.max(jnp.maximum(cum_s - budget, 0)))
                ctx_s = dict(ctx)
                ctx_s["pair_sel"] = lax.slice(sel_exec, (0,), (n_inner,))
                ctx_s["tiers"] = tiers_inner
                if fv is not None:
                    # rebase block-relative fault steps onto this
                    # sub-block's local scan indices; out-of-range sites
                    # stay disarmed here and fire in their own sub-block
                    ctx_s["fault_vec"] = jnp.where(
                        (fv >= done) & (fv < done + take),
                        fv - done, jnp.int32(DISARMED))
                cell_f, f_cur, m = run_pipe(cell_f, f_cur, take, ctx_s)
                chunks.append(m)
                done += take
            metrics = {k: jnp.concatenate([c[k] for c in chunks])
                       for k in chunks[0]}
            return (cell_f, cell_i, f_cur, metrics,
                    lax.pmax(overflow, AXES))

        def block_sched(cell_f, cell_i, force, sel, n_steps, tiers,
                        tiers_inner):
            return block_sched_impl(cell_f, cell_i, force, sel, None,
                                    n_steps, tiers, tiers_inner)

        def do_rebin(cell_f, cell_i):
            new_f, new_i, diag = rebin(cell_f, cell_i, layout, mig_cap)
            force, pe = self._force_pass(new_f[..., :4], new_i)
            force = jnp.where(new_i[..., 0:1] >= 0, force, 0.0)
            return new_f, new_i, force, diag

        def do_prune(cell_f, cell_i):
            ext_f = self.plan.fwd_local(cell_f[..., :4])
            ext_i = self.plan.fwd_local(cell_i, wrap_shift=None)
            sel, cum, cum_inner, occ = prune_local(
                self.pair_schedule, self._trim_ext(ext_f),
                self._trim_ext(ext_i), self.r_prune,
                r_inner=self.r_inner)
            # the exec shapes must agree across the SPMD mesh: every
            # domain sizes to the global worst case
            cum = lax.pmax(cum, AXES)
            cum_inner = lax.pmax(cum_inner, AXES)
            occ = lax.pmax(occ, AXES)
            return sel[None, None, None], cum, cum_inner, occ

        # device-local program bodies, exposed for external composition:
        # repro.serve.SimServer wraps these in vmap under its own
        # shard_map to stack independent replicas into one bucketed
        # block program (each vmap lane runs this exact op sequence, so
        # a batched row's trajectory stays bitwise-identical to a solo
        # run of the same engine config)
        self.local_programs = {
            "block": block, "block_sched": block_sched,
            "rebin": do_rebin, "prune": do_prune,
        }

        # overlap_rebin: the nstlist-cadence DLB work (migration gather +
        # occupancy/bbox prune) fused into the block program's final
        # region instead of host-dispatched between blocks.  The seam is
        # barrier-pinned so fusing cannot perturb the step physics — the
        # fused and host-dispatched paths stay bitwise-identical.

        def block_rebin(cell_f, cell_i, force, n_steps):
            cell_f, cell_i, _f_last, metrics = block(cell_f, cell_i, force,
                                                     n_steps)
            cell_f, cell_i = lax.optimization_barrier((cell_f, cell_i))
            with sc("rebin_seam"):
                new_f, new_i, force, diag = do_rebin(cell_f, cell_i)
            return new_f, new_i, force, metrics, diag

        def block_sched_rebin(cell_f, cell_i, force, sel, n_steps, tiers,
                              tiers_inner):
            cell_f, cell_i, _f_last, metrics, ovf = block_sched(
                cell_f, cell_i, force, sel, n_steps, tiers, tiers_inner)
            cell_f, cell_i = lax.optimization_barrier((cell_f, cell_i))
            with sc("rebin_seam"):
                new_f, new_i, force, diag = do_rebin(cell_f, cell_i)
                sel2, cum, cum_inner, occ = do_prune(new_f, new_i)
            return (new_f, new_i, force, metrics, diag, sel2, cum,
                    cum_inner, occ, ovf)

        spec = self._spec
        if self.inject:
            # the fault vector is a small replicated operand — NOT a jit
            # constant — so re-arming between blocks never retraces
            self.block_fn = jax.jit(
                shard_map_norep(
                    block_impl, mesh=self.mesh,
                    in_specs=(spec, spec, spec, P(), None),
                    out_specs=(spec, spec, spec, P()),
                ),
                static_argnums=(4,),
            )
        else:
            self.block_fn = jax.jit(
                shard_map_norep(
                    functools.partial(block),
                    mesh=self.mesh,
                    in_specs=(spec, spec, spec, None),
                    out_specs=(spec, spec, spec, P()),
                ),
                static_argnums=(3,),
            )
        self.rebin_fn = jax.jit(shard_map_norep(
            do_rebin, mesh=self.mesh, in_specs=(spec, spec),
            out_specs=(spec, spec, spec, P())))
        self._force_fn_dense = jax.jit(shard_map_norep(
            lambda f, i: self._force_pass(f[..., :4], i),
            mesh=self.mesh, in_specs=(spec, spec), out_specs=(spec, P())))
        if self.overlap_rebin:
            self.block_rebin_fn = jax.jit(
                shard_map_norep(
                    block_rebin, mesh=self.mesh,
                    in_specs=(spec, spec, spec, None),
                    out_specs=(spec, spec, spec, P(), P()),
                ),
                static_argnums=(3,),
            )
        if self.force_backend != "dense":
            if self.inject:
                self.block_sched_fn = jax.jit(
                    shard_map_norep(
                        block_sched_impl, mesh=self.mesh,
                        in_specs=(spec, spec, spec, spec, P(), None, None,
                                  None),
                        out_specs=(spec, spec, spec, P(), P()),
                    ),
                    static_argnums=(5, 6, 7),
                )
            else:
                self.block_sched_fn = jax.jit(
                    shard_map_norep(
                        block_sched, mesh=self.mesh,
                        in_specs=(spec, spec, spec, spec, None, None, None),
                        out_specs=(spec, spec, spec, P(), P()),
                    ),
                    static_argnums=(4, 5, 6),
                )
            self.prune_fn = jax.jit(shard_map_norep(
                do_prune, mesh=self.mesh, in_specs=(spec, spec),
                out_specs=(spec, P(), P(), P())))
            self._force_fn_sched = jax.jit(
                shard_map_norep(
                    self._force_pass_sched, mesh=self.mesh,
                    in_specs=(spec, spec, spec, None),
                    out_specs=(spec, P()),
                ),
                static_argnums=(3,),
            )
            if self.overlap_rebin:
                self.block_sched_rebin_fn = jax.jit(
                    shard_map_norep(
                        block_sched_rebin, mesh=self.mesh,
                        in_specs=(spec, spec, spec, spec, None, None,
                                  None),
                        out_specs=(spec, spec, spec, P(), P(), spec,
                                   P(), P(), P(), P()),
                    ),
                    static_argnums=(4, 5, 6),
                )

    def force_fn(self, cell_f, cell_i):
        """One force pass (halo fwd -> NB -> halo rev) on global arrays.

        Dispatches to the engine's force backend; the pruned backends use
        the schedule of the most recent rebin (``simulate`` refreshes it),
        falling back to a fresh prune when none exists yet.
        """
        if self.force_backend == "dense":
            return self._force_fn_dense(cell_f, cell_i)
        if self._sched_exec is None:
            self._refresh_schedule(cell_f, cell_i)
        sel, tiers, _tiers_inner = self._sched_exec
        return self._force_fn_sched(cell_f, cell_i, sel, tiers)

    # ---- state init ----------------------------------------------------------

    def bin_host(self, system: MDSystem | None = None):
        """Host-side binning of a system into numpy cell arrays.

        Defaults to the engine's own system; passing another system bins
        it under THIS engine's layout (the SimServer admission path: a
        replica whose box matches the bucket's is binned into the bucket
        shapes before being written into a batch row)."""
        sys, layout = system or self.system, self.layout
        G = layout.global_cells
        K = layout.capacity
        cs = np.asarray(layout.cell_size)
        pos = np.mod(np.asarray(sys.pos, np.float64), sys.box)
        cell3 = np.minimum((pos / cs).astype(np.int64),
                           np.asarray(G) - 1)
        flat = (cell3[:, 0] * G[1] + cell3[:, 1]) * G[2] + cell3[:, 2]
        order = np.argsort(flat, kind="stable")
        sf = flat[order]
        first = np.searchsorted(sf, sf, side="left")
        rank = np.arange(sf.shape[0]) - first
        if np.any(rank >= K):
            raise ValueError("cell capacity overflow at init; raise safety")
        dtype = sys.pos.dtype
        cell_f = np.zeros((G[0], G[1], G[2], K, 7), dtype)
        cell_i = np.full((G[0], G[1], G[2], K, 2), -1, np.int32)
        gz, gy, gx = cell3[order].T
        cell_f[gz, gy, gx, rank, 0:3] = pos[order].astype(dtype)
        cell_f[gz, gy, gx, rank, 3] = np.asarray(sys.charge)[order]
        cell_f[gz, gy, gx, rank, 4:7] = np.asarray(sys.vel)[order]
        cell_i[gz, gy, gx, rank, 0] = np.arange(sys.n_atoms)[order]
        cell_i[gz, gy, gx, rank, 1] = np.asarray(sys.typ)[order]
        return cell_f, cell_i

    def init_state(self):
        """Bin the global system into the stacked global cell arrays."""
        cell_f, cell_i = self.bin_host()
        shard = NamedSharding(self.mesh, self._spec)
        return (jax.device_put(jnp.asarray(cell_f), shard),
                jax.device_put(jnp.asarray(cell_i), shard))

    # ---- drivers ---------------------------------------------------------------

    def _refresh_schedule(self, cell_f, cell_i, disable_inner: bool = False):
        """Re-prune the pair worklist for the next block (nstlist cadence).

        Runs right after ``rebin_fn`` — the same off-hot-path slot as the
        migration/NS program (paper §5.4).  The host reads the global
        per-level pair histograms + max occupancy and buckets them into
        the static tier ladders of the block program.
        """
        if self.force_backend == "dense":
            return None
        sel, cum, cum_inner, occ = self.prune_fn(cell_f, cell_i)
        return self._bucket_exec(sel, cum, cum_inner, occ,
                                 disable_inner=disable_inner)

    def _bucket_exec(self, sel, cum, cum_inner, occ,
                     disable_inner: bool = False):
        """Host half of the prune: read the global histograms and bucket
        them into the static tier ladders of the next block program
        (shared by the host-dispatched and ``overlap_rebin``-fused
        prunes).  ``disable_inner`` is the overflow fallback — one block
        on the outer ladder after a refresh outgrew the inner one."""
        M = self.pair_schedule.n_pairs
        K = self.layout.capacity
        cum = [int(v) for v in jax.device_get(cum)]
        cum_inner = [int(v) for v in jax.device_get(cum_inner)]
        occ = int(jax.device_get(occ))
        n_keep = cum[0]                 # measured survivors (stats stay honest)
        if self.static_ladder:
            # worst-case histogram: all M rows at the deepest level — one
            # (M, K) tier, constant across blocks and across replicas
            cum = [M] * len(cum)
        tiers = tier_plan(cum, self.pair_bucket, M, SLOT_QUANTUM, K)
        tiers_inner = ()
        if self.nstprune and not disable_inner:
            # inner ladder: rebin-time inner histogram, safety-margined
            # for drift until the next rebin, never above the outer one
            cum_in = [min(int(math.ceil(ci * self.inner_safety)), co)
                      for ci, co in zip(cum_inner, cum)]
            tiers_inner = tier_plan(cum_in, self.pair_bucket, M,
                                    SLOT_QUANTUM, K)
        # what the old single-rectangle schedule (one global k_exec)
        # would have evaluated — the PR's per-pair-bound gain baseline
        global_kexec = bucket(cum[0], self.pair_bucket, M) * \
            bucket(occ, SLOT_QUANTUM, K) ** 2 if cum[0] else 0
        self._pair_stats = self.pair_schedule.slot_pair_stats(
            tiers=tiers, tiers_inner=tiers_inner, n_keep=n_keep,
            n_inner=cum_inner[0], max_occupancy=occ,
            global_kexec_slot_pairs=global_kexec)
        self._pair_stats.update({
            "force_backend": self.force_backend,
            "nstprune": self.nstprune,
            "inner_radius": self.r_inner,
            "inner_overflow_blocks": self._inner_overflows,
            "inner_disabled": bool(self.nstprune and disable_inner),
        })
        outer_rows = tier_rows(tiers)
        inner_rows = tier_rows(tiers_inner) if tiers_inner else outer_rows
        self.sched_history.append((outer_rows, inner_rows))
        self.obs.gauge("md/outer_rows").set(outer_rows)
        self.obs.gauge("md/inner_rows").set(inner_rows)
        self.obs.emit("sched_update", block=len(self.sched_history),
                      outer_rows=outer_rows, inner_rows=inner_rows,
                      max_occupancy=occ,
                      inner_disabled=bool(self.nstprune and disable_inner))
        self._sched_exec = (sel, tiers, tiers_inner)
        return self._sched_exec

    def _note_overflow(self, ovf) -> bool:
        """Record a block's rolling-prune overflow scalar; True if the
        next block must fall back to the outer ladder."""
        if not self.nstprune or int(jax.device_get(ovf)) == 0:
            return False
        self._inner_overflows += 1
        self.obs.counter("md/inner_overflow_blocks").inc()
        if self._inner_overflows == 1:
            warnings.warn(
                "rolling inner prune overflowed its tier ladder (more "
                "survivors than the rebin-time sizing allowed); falling "
                "back to the outer pair list for the next block — raise "
                "inner_safety to avoid this", RuntimeWarning,
                stacklevel=3)
        return True

    def begin_run(self, state=None, disable_inner: bool = False):
        """Open a block-loop run: bin (or adopt) the state, run the first
        rebin + prune, and return the live :class:`RunState`.

        ``disable_inner=True`` starts the first block on the outer ladder
        (the resume-after-overflow / degraded-restore path)."""
        if state is None:
            cell_f, cell_i = self.init_state()
        else:
            cell_f, cell_i = state
        with obs_span("rebin_dispatch", self.obs):
            cell_f, cell_i, force, diag = self.rebin_fn(cell_f, cell_i)
            sched = self._refresh_schedule(cell_f, cell_i,
                                           disable_inner=disable_inner)
        return RunState(cell_f, cell_i, force, sched,
                        bool(disable_inner), 0, [jax.device_get(diag)])

    def _fault_operand(self, fault_vec):
        """Normalize a fault vector to the replicated int32 operand the
        injected block programs take (None = every site disarmed)."""
        if fault_vec is None:
            return jnp.full((len(SCAN_FAULT_SITES),), DISARMED, jnp.int32)
        fv = jnp.asarray(fault_vec, jnp.int32)
        if fv.shape != (len(SCAN_FAULT_SITES),):
            raise ValueError(
                f"fault_vec must have shape ({len(SCAN_FAULT_SITES)},) "
                f"— one block-relative step per site in "
                f"{SCAN_FAULT_SITES} — got {fv.shape}")
        return fv

    def run_block(self, rs: RunState, take: int, fuse: bool = False,
                  fault_vec=None, force_overflow: bool = False):
        """Advance one ``take``-step block on a live :class:`RunState`
        (mutated in place); returns the block's device-side metrics.

        ``fault_vec`` arms the scan fault sites of an ``inject=True``
        engine for this block (``ledger.SCAN_FAULT_SITES`` layout,
        block-relative steps, -1 disarmed); ``force_overflow`` feeds the
        overflow monitor a synthetic trip (the forced-inner-ladder-
        overflow fault site — only meaningful on the ``nstprune`` path).
        """
        if (fault_vec is not None or force_overflow) and not self.inject:
            raise ValueError("fault arming requires an inject=True engine")
        sched = rs.sched
        with obs_span("block_dispatch", self.obs, steps=take,
                      fused_rebin=fuse):
            if fuse and sched is None:
                rs.cell_f, rs.cell_i, rs.force, m, diag = \
                    self.block_rebin_fn(rs.cell_f, rs.cell_i, rs.force,
                                        take)
            elif fuse:
                sel, tiers, tiers_inner = sched
                (rs.cell_f, rs.cell_i, rs.force, m, diag, sel2, cum,
                 cum_inner, occ, ovf) = \
                    self.block_sched_rebin_fn(rs.cell_f, rs.cell_i,
                                              rs.force, sel, take, tiers,
                                              tiers_inner)
                rs.sched = self._bucket_exec(
                    sel2, cum, cum_inner, occ,
                    disable_inner=self._note_overflow(ovf))
            elif sched is None:
                if self.inject:
                    rs.cell_f, rs.cell_i, rs.force, m = self.block_fn(
                        rs.cell_f, rs.cell_i, rs.force,
                        self._fault_operand(fault_vec), take)
                else:
                    rs.cell_f, rs.cell_i, rs.force, m = self.block_fn(
                        rs.cell_f, rs.cell_i, rs.force, take)
            else:
                sel, tiers, tiers_inner = sched
                if self.inject:
                    rs.cell_f, rs.cell_i, rs.force, m, ovf = \
                        self.block_sched_fn(
                            rs.cell_f, rs.cell_i, rs.force, sel,
                            self._fault_operand(fault_vec), take, tiers,
                            tiers_inner)
                else:
                    rs.cell_f, rs.cell_i, rs.force, m, ovf = \
                        self.block_sched_fn(rs.cell_f, rs.cell_i,
                                            rs.force, sel, take, tiers,
                                            tiers_inner)
                # read the block's overflow scalar NOW (not at the next
                # boundary) so a final block's overflow is still
                # counted and warned — the monitor has no blind spot
                rs.disable = self._note_overflow(
                    jnp.int32(1) if force_overflow else ovf)
        self.obs.counter("md/blocks").inc()
        self.obs.counter("md/steps").inc(take)
        rs.step += take
        if fuse:
            rs.diags.append(jax.device_get(diag))
        return m

    def advance_schedule(self, rs: RunState):
        """The between-block rebin + prune (host-dispatched path only;
        fused blocks already carried theirs)."""
        old_sched = rs.sched
        with obs_span("rebin_dispatch", self.obs):
            cell_f, cell_i, force, diag = self.rebin_fn(rs.cell_f,
                                                        rs.cell_i)
            rs.sched = self._refresh_schedule(
                cell_f, cell_i,
                disable_inner=old_sched is not None and rs.disable)
        rs.cell_f, rs.cell_i, rs.force = cell_f, cell_i, force
        rs.disable = False
        rs.diags.append(jax.device_get(diag))

    def simulate(self, n_steps: int, state=None, collect=True,
                 on_boundary=None):
        """Run n_steps in nstlist-sized TPU-resident blocks.

        With ``overlap_rebin`` every block that another block follows is
        one fused dispatch (steps + rebin/migration + prune); the final
        block — after which the host path would not rebin either — runs
        the plain block program.  Both paths visit bitwise-identical
        states and the host still reads only the prune histograms (two
        small per-level vectors + occupancy + overflow scalars) per
        block boundary.

        ``on_boundary`` is the block-boundary admission hook: called as
        ``on_boundary(rs)`` at every interior block boundary, BEFORE the
        boundary rebin — the host-visible point the SimServer admits and
        retires replicas at.  The hook may mutate ``rs.cell_f`` /
        ``rs.cell_i`` in place; the boundary rebin that follows
        re-derives the force carry and pair schedule from whatever state
        it finds, so mutated atoms never run under a stale schedule.
        (First-block admission is the ``state`` argument itself.)
        """
        nst = self.system.params.nstlist
        if on_boundary is not None and self.overlap_rebin:
            raise ValueError(
                "on_boundary is incompatible with overlap_rebin: the "
                "fused block carries its own rebin, so a boundary "
                "mutation would run under the already-derived schedule")
        rs = self.begin_run(state)
        all_metrics = []
        while rs.step < n_steps:
            take = min(nst, n_steps - rs.step)
            fuse = self.overlap_rebin and rs.step + take < n_steps
            m = self.run_block(rs, take, fuse=fuse)
            if collect:
                all_metrics.append(jax.device_get(m))
            if not fuse and rs.step < n_steps:
                if on_boundary is not None:
                    on_boundary(rs)
                self.advance_schedule(rs)
        cell_f, cell_i, diags = rs.cell_f, rs.cell_i, rs.diags
        metrics = {}
        if collect and all_metrics:
            metrics = {k: np.concatenate([np.atleast_1d(m[k])
                                          for m in all_metrics])
                       for k in all_metrics[0]}
            obs_keys = [k for k in metrics if k.startswith("obs/")]
            if obs_keys:
                # the traced per-step ledger counters, as one record the
                # Perfetto exporter turns into predicted-lane counters
                self.obs.emit("step_counters",
                              data={k: metrics[k] for k in obs_keys})
        self.obs.snapshot(label="md/simulate", n_steps=n_steps,
                          backend=self.backend,
                          pipeline=self.pipeline_mode)
        return (cell_f, cell_i), metrics, diags

    def gather_by_id(self, arrays, cell_i):
        """Host-side: reassemble per-atom arrays ordered by global id."""
        ids = np.asarray(jax.device_get(cell_i))[..., 0].reshape(-1)
        out = []
        for a in arrays:
            flat = np.asarray(jax.device_get(a)).reshape(ids.shape[0], -1)
            dest = np.zeros((self.system.n_atoms, flat.shape[-1]),
                            flat.dtype)
            valid = ids >= 0
            dest[ids[valid]] = flat[valid]
            out.append(dest)
        return out

    # ---- elasticity (rebuild / reshard) -----------------------------------

    def export_atoms(self, state) -> dict:
        """Mesh-independent snapshot of a cell state: per-atom positions
        and velocities in global-id order (the portable half of a
        checkpoint — restorable onto any mesh/layout)."""
        cell_f, cell_i = state
        pos, vel = self.gather_by_id(
            [cell_f[..., :3], cell_f[..., 4:7]], cell_i)
        return {"pos": pos, "vel": vel}

    def rebuild(self, mesh: Mesh = None, system: MDSystem = None,
                **overrides) -> "MDEngine":
        """A fresh engine with this engine's construction parameters,
        selectively overridden.

        Any ``__init__`` keyword can be overridden; additionally
        ``backend="..."`` rewrites the halo spec's backend (the degrade
        ladder's signal→serialized rung).  The caller re-enters via
        :meth:`begin_run` / :meth:`init_state` — compiled programs are
        not carried over.
        """
        kw = dict(self._init_kwargs)
        backend = overrides.pop("backend", None)
        kw.update(overrides)
        if backend is not None:
            base = kw["spec"] if kw["spec"] is not None else \
                HaloSpec(axis_names=AXES, widths=(1, 1, 1))
            kw["spec"] = dataclasses.replace(base, backend=backend)
        return MDEngine(system if system is not None else self.system,
                        mesh if mesh is not None else self.mesh, **kw)

    def reshard(self, mesh: Mesh, state=None, atoms=None,
                **overrides) -> "MDEngine":
        """Elastic reshard: rebuild this engine on a different mesh and
        carry the atoms over (the device-loss shrink path, promoting the
        ``check_elastic.py`` restore-on-smaller-mesh math to runtime).

        Pass either the live cell ``state`` (exported here) or a
        pre-exported ``atoms`` dict (the checkpointed form — the one a
        *lost* device's state is recovered from).  Returns the new
        engine; the caller re-bins with ``begin_run()`` (``init_state``
        re-bins the carried atoms under the new layout/sharding).
        """
        if atoms is None:
            if state is None:
                raise ValueError("reshard needs `state` or `atoms`")
            atoms = self.export_atoms(state)
        dt = self.system.pos.dtype
        system = dataclasses.replace(
            self.system,
            pos=np.asarray(atoms["pos"], dt),
            vel=np.asarray(atoms["vel"], dt))
        return self.rebuild(mesh=mesh, system=system, **overrides)
