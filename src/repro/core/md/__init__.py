"""GROMACS-style MD substrate (the paper's application domain)."""
from repro.core.md.cells import CellLayout, choose_layout
from repro.core.md.engine import MDEngine
from repro.core.md.forces import compute_forces, direct_forces_reference
from repro.core.md.pair_schedule import (
    PairSchedule,
    force_backends,
    get_force_backend,
    register_force_backend,
)
from repro.core.md.system import (
    DEFAULT_FF,
    GRAPPA_SIZES,
    ForceField,
    MDParams,
    MDSystem,
    make_grappa_like,
)

__all__ = [
    "CellLayout", "choose_layout", "MDEngine", "compute_forces",
    "direct_forces_reference", "ForceField", "MDParams", "MDSystem",
    "make_grappa_like", "GRAPPA_SIZES", "DEFAULT_FF", "PairSchedule",
    "force_backends", "get_force_backend", "register_force_backend",
]
