"""Pruned cell-pair force schedules: the sparse NB engine (paper §5.4).

The paper's speedups depend on the non-bonded force kernels — the hot
loop — staying saturated while halo communication overlaps (§5.4).
GROMACS gets there with cluster pair lists: built coarsely at
domain-decomposition time, pruned on the ``nstlist`` cadence, and executed
by batched cluster-pair kernels (Páll et al. 2020).  The dense engine path
(:func:`repro.core.md.forces.compute_forces`) ignores all of that: it
evaluates every ``K x K`` slot pair of all 14 eighth-shell zone products
over the full cell grid, padding slots included.

This module is the pair-list analogue for the cell scheme:

* :class:`PairSchedule` — the **static worklist**: all
  ``14 * n_local_cells`` eighth-shell cell pairs of one domain, enumerated
  once per :class:`~repro.core.md.cells.CellLayout` as flat indices into
  the trimmed extended (home + one halo layer) cell array.  This is the
  DD-time coarse list build.

* :func:`prune_local` — the ``nstlist``-cadence **prune**: runs device-
  local (inside the engine's shard_map) right where ``rebin_fn`` already
  executes, off the hot step path (see
  :mod:`repro.core.md.schedule_opt`).  Pairs are dropped when either cell
  is empty (cell membership is frozen within a block, so this is exact)
  or when the cells' atom bounding boxes are further apart than the prune
  radius (:func:`prune_radius`, the Verlet-buffer analogue: ``r_cut``
  plus twice the expected per-block drift).  Survivors are packed
  front-first so a static-shape prefix of the worklist covers them.

* :func:`get_force_backend` — a registry of force engines sharing one
  signature:

  - ``"dense"``  — the unchanged 14-zone jnp loop; the **bitwise
    reference** (trajectories are identical to the pre-schedule engine).
  - ``"sparse"`` — jnp evaluation over the pruned worklist only, packed
    ``(N, K_exec, 4)`` A/B batches with gather/scatter-add epilogues.
  - ``"pallas"`` — the same batches executed by the tuned Pallas
    cluster-pair kernel (:func:`repro.kernels.nonbonded.pair_forces_accum`,
    interpret mode on CPU) with a jnp fallback if the kernel is
    unavailable on the current backend.

  Sparse and pallas match dense to tolerance (summation order differs);
  they are *not* bitwise.  ``K_exec`` (the evaluated slot depth) can be
  smaller than the layout capacity ``K`` because binning packs each
  cell's atoms into a contiguous slot prefix — the 2.2x capacity safety
  padding is what the schedule stops paying for.

The engine threads the block-constant schedule (``pair_sel``, ``k_exec``)
through the :class:`~repro.core.pipeline.step_pipeline.StepFns` context,
so both pipeline modes (``off`` / ``double_buffer``) execute the same
pruned worklist.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.md.cells import CellLayout, cell_bounds, cell_counts
from repro.core.md.forces import compute_forces, pair_terms
from repro.core.md.system import ForceField, MDParams

# exec-shape quanta: surviving pair counts bucket to multiples of
# PAIR_BUCKET and slot depths to multiples of SLOT_QUANTUM (matching the
# capacity padding in choose_layout), so the per-block prune produces only
# a handful of distinct compiled block programs
PAIR_BUCKET = 64
SLOT_QUANTUM = 4

_BIG = 1e30  # empty-cell bounding-box sentinel (finite: no inf-inf NaNs)


# --------------------------------------------------------------------------
# static worklist (built once per layout — the DD-time list build)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PairSchedule:
    """Static eighth-shell cell-pair worklist of one domain.

    ``cell_a`` / ``cell_b`` are flat indices into the trimmed extended
    cell array ``(cz+1, cy+1, cx+1)`` reshaped to ``(n_ext_cells, K,
    ...)``; ``same`` flags the self pairs (triangle masking).  Shapes are
    static per layout; the dynamic part (which pairs survive a block) is
    the ``sel`` vector produced by :func:`prune_local`.
    """

    layout: CellLayout
    cell_a: np.ndarray    # (M,) int32
    cell_b: np.ndarray    # (M,) int32
    same: np.ndarray      # (M,) int32

    @classmethod
    def build(cls, layout: CellLayout) -> "PairSchedule":
        for d in range(3):
            if layout.global_cells[d] < 2:
                raise ValueError(
                    "pair schedules need >= 2 global cells per dim "
                    f"(got {layout.global_cells}): with one global cell a "
                    "halo cell aliases its own periodic image, which only "
                    "the dense path's id mask handles")
        from repro.core.md.forces import stencil_pairs
        cz, cy, cx = layout.cells_per_domain
        ez, ey, ex = cz + 1, cy + 1, cx + 1
        base = np.stack(np.meshgrid(np.arange(cz), np.arange(cy),
                                    np.arange(cx), indexing="ij"),
                        axis=-1).reshape(-1, 3)

        def flat(cells3):
            return ((cells3[:, 0] * ey + cells3[:, 1]) * ex
                    + cells3[:, 2]).astype(np.int32)

        cell_a, cell_b, same = [], [], []
        for a, b in stencil_pairs():
            cell_a.append(flat(base + np.asarray(a)))
            cell_b.append(flat(base + np.asarray(b)))
            same.append(np.full(base.shape[0], int(a == b), np.int32))
        return cls(layout=layout,
                   cell_a=np.concatenate(cell_a),
                   cell_b=np.concatenate(cell_b),
                   same=np.concatenate(same))

    @property
    def n_pairs(self) -> int:
        """Worklist length M = 14 * n_local_cells (the dense pair count)."""
        return int(self.cell_a.shape[0])

    @property
    def n_ext_cells(self) -> int:
        cz, cy, cx = self.layout.cells_per_domain
        return (cz + 1) * (cy + 1) * (cx + 1)

    def dense_slot_pairs(self) -> int:
        """Slot pairs the dense engine evaluates per domain per step."""
        return self.n_pairs * self.layout.capacity ** 2

    def slot_pair_stats(self, n_exec: Optional[int] = None,
                        k_exec: Optional[int] = None,
                        n_keep: Optional[int] = None,
                        max_occupancy: Optional[int] = None) -> dict:
        """Evaluated-work accounting for one pruned block (per domain)."""
        dense = self.dense_slot_pairs()
        out = {
            "n_pairs_dense": self.n_pairs,
            "k_capacity": self.layout.capacity,
            "dense_slot_pairs": dense,
        }
        if n_exec is None:
            out.update({"evaluated_slot_pairs": dense, "prune_ratio": 1.0})
            return out
        evaluated = int(n_exec) * int(k_exec) ** 2
        out.update({
            "n_pairs_exec": int(n_exec),
            "n_pairs_kept": None if n_keep is None else int(n_keep),
            "k_exec": int(k_exec),
            "max_occupancy": None if max_occupancy is None
            else int(max_occupancy),
            "evaluated_slot_pairs": evaluated,
            "prune_ratio": dense / max(evaluated, 1),
        })
        return out


def prune_radius(params: MDParams) -> float:
    """Verlet-buffer analogue for the bounding-box prune.

    Bounding boxes are sampled at rebin time and go stale as atoms drift
    during the block, so the prune keeps every pair whose boxes come
    within ``r_cut`` plus twice the expected per-block drift (3-sigma
    thermal velocity over ``nstlist`` steps) — GROMACS' ``r_list``
    buffer, sized for the same cadence.
    """
    drift = params.nstlist * params.dt * 3.0 * math.sqrt(
        params.temperature / params.mass)
    return params.ff.r_cut + 2.0 * drift


# --------------------------------------------------------------------------
# nstlist-cadence prune (device-local, off the hot path)
# --------------------------------------------------------------------------

def prune_local(sched: PairSchedule, ext_f: jnp.ndarray, ext_i: jnp.ndarray,
                r_prune: float):
    """Prune the static worklist for one block; runs inside shard_map.

    ``ext_f`` / ``ext_i`` are the TRIMMED extended arrays (home + one halo
    cell layer, the NB stencil's reach).  Returns ``(sel, n_keep,
    max_occ)``: ``sel`` (M,) int32 holds the surviving worklist rows
    packed first (original order preserved) with the sentinel ``M`` in
    the padding tail; ``n_keep`` and ``max_occ`` are scalars the host
    uses to choose the static exec shapes (see
    :func:`repro.core.md.schedule_opt.bucket`).
    """
    M = sched.n_pairs
    ne = sched.n_ext_cells
    K = ext_f.shape[3]
    counts = cell_counts(ext_i).reshape(ne)
    lo, hi = cell_bounds(ext_f[..., :3], ext_i, big=_BIG)
    lo, hi = lo.reshape(ne, 3), hi.reshape(ne, 3)

    ca = jnp.asarray(sched.cell_a)
    cb = jnp.asarray(sched.cell_b)
    same = jnp.asarray(sched.same)
    gap = jnp.maximum(0.0, jnp.maximum(lo[ca] - hi[cb], lo[cb] - hi[ca]))
    d2 = jnp.sum(gap * gap, axis=-1)
    occupied = (counts[ca] > 0) & (counts[cb] > 0)
    keep = jnp.where(
        same > 0,
        counts[ca] >= 2,                           # self pair: >= 1 real pair
        occupied & (d2 < jnp.asarray(r_prune ** 2, d2.dtype)))
    n_keep = jnp.sum(keep).astype(jnp.int32)
    order = jnp.argsort(jnp.where(keep, 0, 1), stable=True).astype(jnp.int32)
    sel = jnp.where(jnp.arange(M) < n_keep, order, M).astype(jnp.int32)
    max_occ = jnp.max(counts).astype(jnp.int32)
    return sel, n_keep, max_occ


# --------------------------------------------------------------------------
# batched execution over the pruned worklist
# --------------------------------------------------------------------------

def _gather_batches(sched: PairSchedule, ext_f, ext_i, sel, k_exec: int):
    """Pack the selected pairs into (N, K_exec, ...) A/B batches.

    The sentinel worklist row ``M`` routes padding entries to an extra
    all-empty cell at flat index ``n_ext_cells`` (types -1, coords 0), so
    no masking branch is needed downstream — the kernels' validity masks
    kill padding work and the scatter epilogue accumulates it into the
    sliced-off sentinel row.
    """
    ne = sched.n_ext_cells
    K = ext_f.shape[3]
    k_exec = min(int(k_exec), K)
    f2 = ext_f.reshape(ne, K, ext_f.shape[-1])[:, :k_exec]
    id2 = ext_i[..., 0].reshape(ne, K)[:, :k_exec]
    t2 = ext_i[..., 1].reshape(ne, K)[:, :k_exec]
    typ = jnp.where(id2 >= 0, t2, -1).astype(jnp.int32)

    f2p = jnp.concatenate([f2, jnp.zeros((1,) + f2.shape[1:], f2.dtype)])
    tp = jnp.concatenate([typ, jnp.full((1, k_exec), -1, jnp.int32)])
    ca = jnp.concatenate([jnp.asarray(sched.cell_a),
                          jnp.asarray([ne], jnp.int32)])[sel]
    cb = jnp.concatenate([jnp.asarray(sched.cell_b),
                          jnp.asarray([ne], jnp.int32)])[sel]
    same = jnp.concatenate([jnp.asarray(sched.same),
                            jnp.asarray([0], jnp.int32)])[sel]
    return (f2p[ca], f2p[cb], tp[ca], tp[cb], same, ca, cb)


def _pair_forces_jnp(a, b, ta, tb, same, ff: ForceField):
    """jnp twin of the Pallas cluster-pair kernel (one batch).

    Same masks and math as ``kernels.nonbonded._pair_kernel``; the
    optimization barriers pin the K-wide reductions exactly like the
    dense path does (see forces.py), so sparse trajectories stay bitwise
    stable across halo backends and pipeline modes.
    """
    kk = a.shape[1]
    dtype = a.dtype
    pos_a, q_a = a[..., :3], a[..., 3]
    pos_b, q_b = b[..., :3], b[..., 3]
    dx = pos_a[:, :, None, :] - pos_b[:, None, :, :]
    r2 = jnp.sum(dx * dx, axis=-1)
    mask = (ta >= 0)[:, :, None] & (tb >= 0)[:, None, :]
    mask &= r2 < jnp.asarray(ff.r_cut ** 2, dtype)
    tri = jnp.triu(jnp.ones((kk, kk), jnp.bool_), k=1)[None]
    mask &= jnp.where(same[:, None, None] > 0, tri,
                      jnp.ones((1, kk, kk), jnp.bool_))

    eps_t = jnp.asarray(ff.eps, dtype)
    sig_t = jnp.asarray(ff.sigma, dtype)
    tai = jnp.clip(ta, 0, eps_t.shape[0] - 1)
    tbi = jnp.clip(tb, 0, eps_t.shape[0] - 1)
    eps = eps_t[tai[:, :, None], tbi[:, None, :]]
    sig = sig_t[tai[:, :, None], tbi[:, None, :]]
    fac, pe = pair_terms(dx, r2, q_a[:, :, None], q_b[:, None, :],
                         eps, sig, ff, mask)
    fvec = lax.optimization_barrier(fac[..., None] * dx)
    fa = lax.optimization_barrier(jnp.sum(fvec, axis=2))
    fb = lax.optimization_barrier(-jnp.sum(fvec, axis=1))
    return fa, fb, jnp.sum(pe, axis=(1, 2))


# pallas kernel availability is probed once and latched, mirroring
# HaloPlan._pallas_broken (the jnp twin is the oracle fallback)
_PALLAS_BROKEN = [False]


def pallas_fallback_active() -> bool:
    """True once the Pallas NB kernel has failed and the ``"pallas"``
    backend is executing the jnp twin (surfaced via engine pair_stats)."""
    return _PALLAS_BROKEN[0]


def _latch_pallas_fallback(e: Exception, context: str) -> None:
    """Latch the process-global jnp fallback and say so once, loudly."""
    import warnings
    _PALLAS_BROKEN[0] = True
    warnings.warn(
        f"Pallas NB kernel {context} ({type(e).__name__}: {e}); the "
        "'pallas' force backend falls back to the jnp pair evaluator "
        "for the rest of this process", RuntimeWarning, stacklevel=3)


def probe_pallas(ff: ForceField, interpret: bool = True) -> bool:
    """Eagerly compile+run the NB kernel on a tiny batch; latch fallback.

    The try/except inside :func:`_eval_schedule` only sees *trace-time*
    failures — on a real backend (``interpret=False``) Mosaic lowering
    errors surface at jit-compile time, outside that guard.  Engines
    selecting the ``"pallas"`` backend run this probe once at build time
    so compile-time kernel failures also downgrade to the documented jnp
    fallback instead of crashing the first block program.
    """
    if _PALLAS_BROKEN[0]:
        return False
    try:
        from repro.kernels import nonbonded
        z4 = jnp.zeros((8, 4, 4), jnp.float32)
        t4 = jnp.full((8, 4), -1, jnp.int32)
        c4 = jnp.zeros((8,), jnp.int32)
        F, pe = nonbonded.pair_forces_accum(
            z4, z4, t4, t4, c4, c4, c4, ff, 2, interpret=interpret)
        F.block_until_ready()
        return True
    except Exception as e:  # pragma: no cover - backend-specific
        _latch_pallas_fallback(e, "failed its build-time probe")
        return False


def _eval_schedule(ext_f, ext_i, layout: CellLayout, ff: ForceField, *,
                   sched: PairSchedule, sel, k_exec: int,
                   use_pallas: bool, interpret: bool = True):
    """Evaluate the pruned worklist: gather -> pair kernel -> scatter-add.

    Returns ``(F_ext, pe)`` in the same layout as ``compute_forces`` (the
    trimmed extended force array with halo partial sums).
    """
    ne = sched.n_ext_cells
    K = ext_f.shape[3]
    k_exec = min(int(k_exec), K)
    a, b, ta, tb, same, ca, cb = _gather_batches(sched, ext_f, ext_i, sel,
                                                 k_exec)
    F = pe_pairs = None
    if use_pallas and not _PALLAS_BROKEN[0]:
        try:
            from repro.kernels import nonbonded
            # the kernel + its scatter-accumulate epilogue; the sentinel
            # row ne absorbs padding entries and is sliced off below
            F, pe_pairs = nonbonded.pair_forces_accum(
                a, b, ta, tb, same, ca, cb, ff, ne + 1,
                interpret=interpret)
        except Exception as e:  # pragma: no cover - backend-specific
            _latch_pallas_fallback(e, "unavailable at trace time")
    if F is None:
        fa, fb, pe_pairs = _pair_forces_jnp(a, b, ta, tb, same, ff)
        F = jnp.zeros((ne + 1, k_exec, 3), ext_f.dtype)
        F = F.at[ca].add(fa)
        F = F.at[cb].add(fb)
    F = lax.optimization_barrier(F[:ne])
    Fk = jnp.zeros((ne, K, 3), ext_f.dtype).at[:, :k_exec].set(F)
    F_ext = Fk.reshape(ext_f.shape[:3] + (K, 3))
    return F_ext, jnp.sum(pe_pairs)


# --------------------------------------------------------------------------
# force-backend registry
# --------------------------------------------------------------------------

def _dense(ext_f, ext_i, layout, ff, **_):
    """The unchanged 14-zone loop: the bitwise trajectory reference."""
    return compute_forces(ext_f, ext_i, layout, ff)


def _sparse(ext_f, ext_i, layout, ff, *, sched, sel, k_exec,
            interpret=True):
    return _eval_schedule(ext_f, ext_i, layout, ff, sched=sched, sel=sel,
                          k_exec=k_exec, use_pallas=False,
                          interpret=interpret)


def _pallas(ext_f, ext_i, layout, ff, *, sched, sel, k_exec,
            interpret=True):
    return _eval_schedule(ext_f, ext_i, layout, ff, sched=sched, sel=sel,
                          k_exec=k_exec, use_pallas=True,
                          interpret=interpret)


ForceBackend = Callable[..., Tuple[jnp.ndarray, jnp.ndarray]]
_FORCE_BACKENDS: Dict[str, ForceBackend] = {}


def register_force_backend(name: str, fn: ForceBackend) -> None:
    """Register a force engine under ``name`` (the config axis value)."""
    _FORCE_BACKENDS[name] = fn


def force_backends() -> Tuple[str, ...]:
    return tuple(sorted(_FORCE_BACKENDS))


def get_force_backend(name: str) -> ForceBackend:
    try:
        return _FORCE_BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown force backend {name!r}; "
            f"available: {force_backends()}") from None


register_force_backend("dense", _dense)
register_force_backend("sparse", _sparse)
register_force_backend("pallas", _pallas)
