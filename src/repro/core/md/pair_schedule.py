"""Pruned cell-pair force schedules: the sparse NB engine (paper §5.4).

The paper's speedups depend on the non-bonded force kernels — the hot
loop — staying saturated while halo communication overlaps (§5.4).
GROMACS gets there with its **dual pair list** (Páll et al. 2020): an
outer list built coarsely at neighbor-search time with the Verlet-buffer
radius, re-pruned cheaply every few steps into an inner list at a tighter
cutoff, executed by batched cluster-pair kernels.  The dense engine path
(:func:`repro.core.md.forces.compute_forces`) ignores all of that: it
evaluates every ``K x K`` slot pair of all 14 eighth-shell zone products
over the full cell grid, padding slots included.

This module is the pair-list analogue for the cell scheme:

* :class:`PairSchedule` — the **static worklist**: all
  ``14 * n_local_cells`` eighth-shell cell pairs of one domain, enumerated
  once per :class:`~repro.core.md.cells.CellLayout` as flat indices into
  the trimmed extended (home + one halo layer) cell array.  This is the
  DD-time coarse list build.

* :func:`prune_local` — the rebin-cadence **outer prune**: runs device-
  local (inside the engine's shard_map) right where ``rebin_fn`` already
  executes, off the hot step path (see
  :mod:`repro.core.md.schedule_opt`).  Pairs are dropped when either cell
  is empty (cell membership is frozen within a block, so this is exact)
  or when the cells' atom bounding boxes are further apart than the prune
  radius (:func:`prune_radius`, the Verlet-buffer analogue: ``r_cut``
  plus twice the expected per-block drift).  Survivors are packed
  front-first **sorted by descending per-pair slot bound** (the
  occupancy level ``ceil(max(count_a, count_b) / SLOT_QUANTUM)``), so
  dense cell pairs land in full batches at the head of the list and the
  shallow/sentinel tail shrinks; the prune reports a cumulative
  per-level histogram that :func:`repro.core.md.schedule_opt.tier_plan`
  turns into a static ladder of ``(n_rows, k_slots)`` tiers — per-pair
  slot bounds replace the old single rectangular ``k_exec``.

* :func:`roll_prune` — the ``nstprune``-cadence **rolling inner prune**
  (GROMACS' dual-cutoff scheme): *inside* the fused block program, the
  outer exec prefix is re-partitioned with current coordinates — pairs
  whose bounding boxes sit beyond :func:`inner_radius` are stably sorted
  behind the survivors (survivors stay in descending-level order, so the
  tier invariant holds) and the force pass evaluates only the
  host-sized inner tier ladder.  ``n_exec`` shrinks between rebins with
  no host round-trip; a dropped pair re-enters on a later refresh
  because every refresh re-examines the full outer prefix.  A refresh
  whose survivors outgrow the inner ladder reports a nonzero overflow
  count (read by the host with the block's other prune scalars), and
  the engine falls back to the outer ladder for the next block.

* :func:`get_force_backend` — a registry of force engines sharing one
  signature:

  - ``"dense"``  — the unchanged 14-zone jnp loop; the **bitwise
    reference** (trajectories are identical to the pre-schedule engine).
  - ``"sparse"`` — jnp evaluation over the pruned worklist only, packed
    per-tier ``(N_t, K_t, 4)`` A/B batches with gather/scatter-add
    epilogues.
  - ``"pallas"`` — the same batches executed by the tuned Pallas
    cluster-pair kernel (:func:`repro.kernels.nonbonded.pair_forces_accum`,
    interpret mode on CPU) with a jnp fallback if the kernel is
    unavailable on the current backend.  Both sparse and pallas consume
    the per-pair occupancy counts directly (validity masks are
    ``slot < count`` — binning packs each cell's atoms into a contiguous
    slot prefix).

  Sparse and pallas match dense to tolerance (summation order differs);
  they are *not* bitwise.  Per-tier ``K_t`` (the evaluated slot depth)
  can be much smaller than the layout capacity ``K`` — the 2.2x capacity
  safety padding is what the schedule stops paying for, and the tier
  ladder stops paying the global-max occupancy for mostly-shallow pairs.

The engine threads the block-constant schedule (``pair_sel``, ``tiers``)
through the :class:`~repro.core.pipeline.step_pipeline.StepFns` context,
so both pipeline modes (``off`` / ``double_buffer``) execute the same
pruned worklist.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.md.cells import CellLayout, cell_bounds, cell_counts, \
    cell_levels
from repro.core.md.forces import compute_forces, pair_terms
from repro.core.md.schedule_opt import tier_rows, tier_slot_pairs
from repro.core.md.system import ForceField, MDParams

# exec-shape quanta: surviving pair counts bucket to multiples of
# PAIR_BUCKET and slot depths to multiples of SLOT_QUANTUM (matching the
# capacity padding in choose_layout), so the per-block prune produces only
# a handful of distinct compiled block programs
PAIR_BUCKET = 64
SLOT_QUANTUM = 4

_BIG = 1e30  # empty-cell bounding-box sentinel (finite: no inf-inf NaNs)


def n_levels(capacity: int) -> int:
    """Occupancy levels of a layout: ``ceil(capacity / SLOT_QUANTUM)``."""
    return -(-int(capacity) // SLOT_QUANTUM)


# --------------------------------------------------------------------------
# static worklist (built once per layout — the DD-time list build)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PairSchedule:
    """Static eighth-shell cell-pair worklist of one domain.

    ``cell_a`` / ``cell_b`` are flat indices into the trimmed extended
    cell array ``(cz+1, cy+1, cx+1)`` reshaped to ``(n_ext_cells, K,
    ...)``; ``same`` flags the self pairs (triangle masking).  Shapes are
    static per layout; the dynamic part (which pairs survive a block) is
    the ``sel`` vector produced by :func:`prune_local` /
    :func:`roll_prune`.
    """

    layout: CellLayout
    cell_a: np.ndarray    # (M,) int32
    cell_b: np.ndarray    # (M,) int32
    same: np.ndarray      # (M,) int32

    @classmethod
    def build(cls, layout: CellLayout) -> "PairSchedule":
        for d in range(3):
            if layout.global_cells[d] < 2:
                raise ValueError(
                    "pair schedules need >= 2 global cells per dim "
                    f"(got {layout.global_cells}): with one global cell a "
                    "halo cell aliases its own periodic image, which only "
                    "the dense path's id mask handles")
        from repro.core.md.forces import stencil_pairs
        cz, cy, cx = layout.cells_per_domain
        ez, ey, ex = cz + 1, cy + 1, cx + 1
        base = np.stack(np.meshgrid(np.arange(cz), np.arange(cy),
                                    np.arange(cx), indexing="ij"),
                        axis=-1).reshape(-1, 3)

        def flat(cells3):
            return ((cells3[:, 0] * ey + cells3[:, 1]) * ex
                    + cells3[:, 2]).astype(np.int32)

        cell_a, cell_b, same = [], [], []
        for a, b in stencil_pairs():
            cell_a.append(flat(base + np.asarray(a)))
            cell_b.append(flat(base + np.asarray(b)))
            same.append(np.full(base.shape[0], int(a == b), np.int32))
        return cls(layout=layout,
                   cell_a=np.concatenate(cell_a),
                   cell_b=np.concatenate(cell_b),
                   same=np.concatenate(same))

    @property
    def n_pairs(self) -> int:
        """Worklist length M = 14 * n_local_cells (the dense pair count)."""
        return int(self.cell_a.shape[0])

    @property
    def n_ext_cells(self) -> int:
        cz, cy, cx = self.layout.cells_per_domain
        return (cz + 1) * (cy + 1) * (cx + 1)

    @property
    def levels(self) -> int:
        """Occupancy-level count of this layout's tier ladders."""
        return n_levels(self.layout.capacity)

    def dense_slot_pairs(self) -> int:
        """Slot pairs the dense engine evaluates per domain per step."""
        return self.n_pairs * self.layout.capacity ** 2

    def slot_pair_stats(self, tiers: Optional[Sequence] = None,
                        tiers_inner: Optional[Sequence] = None,
                        n_keep: Optional[int] = None,
                        n_inner: Optional[int] = None,
                        max_occupancy: Optional[int] = None,
                        global_kexec_slot_pairs: Optional[int] = None
                        ) -> dict:
        """Evaluated-work accounting for one pruned block (per domain).

        ``tiers`` is the outer ladder, ``tiers_inner`` the rolling-prune
        ladder actually executed between refreshes (when the dual list is
        on).  ``global_kexec_slot_pairs`` is the accounting the old
        single-rectangle schedule (one global ``k_exec``) would have
        reported — kept so the per-pair-bound gain stays visible.
        """
        dense = self.dense_slot_pairs()
        out = {
            "n_pairs_dense": self.n_pairs,
            "k_capacity": self.layout.capacity,
            "dense_slot_pairs": dense,
        }
        if tiers is None:
            out.update({"evaluated_slot_pairs": dense, "prune_ratio": 1.0})
            return out
        outer = tier_slot_pairs(tiers)
        evaluated = tier_slot_pairs(tiers_inner) if tiers_inner else outer
        out.update({
            "n_pairs_exec": tier_rows(tiers),
            "n_pairs_kept": None if n_keep is None else int(n_keep),
            "tiers": [list(t) for t in tiers],
            "tiers_inner": None if not tiers_inner
            else [list(t) for t in tiers_inner],
            "n_pairs_inner": None if n_inner is None else int(n_inner),
            "max_occupancy": None if max_occupancy is None
            else int(max_occupancy),
            "outer_slot_pairs": outer,
            "evaluated_slot_pairs": evaluated,
            "global_kexec_slot_pairs": global_kexec_slot_pairs,
            "prune_ratio": dense / max(evaluated, 1),
        })
        if global_kexec_slot_pairs:
            out["per_pair_bound_gain"] = \
                global_kexec_slot_pairs / max(evaluated, 1)
        return out


def _drift(params: MDParams, steps: int) -> float:
    """Expected 3-sigma thermal drift of one atom over ``steps`` steps."""
    return steps * params.dt * 3.0 * math.sqrt(
        params.temperature / params.mass)


def prune_radius(params: MDParams) -> float:
    """Verlet-buffer analogue for the outer bounding-box prune.

    Bounding boxes are sampled at rebin time and go stale as atoms drift
    during the block, so the prune keeps every pair whose boxes come
    within ``r_cut`` plus twice the expected per-block drift (3-sigma
    thermal velocity over ``nstlist`` steps) — GROMACS' ``r_list``
    buffer, sized for the same cadence.
    """
    return params.ff.r_cut + 2.0 * _drift(params, params.nstlist)


def inner_radius(params: MDParams, nstprune: int) -> float:
    """Inner cutoff of the rolling prune (the dual list's second radius).

    Sized like :func:`prune_radius` but for the ``nstprune`` refresh
    cadence: a pair dropped by a refresh needs more than a 3-sigma drift
    to come within ``r_cut`` before the next refresh re-examines it.
    """
    return params.ff.r_cut + 2.0 * _drift(params, max(int(nstprune), 1))


# --------------------------------------------------------------------------
# rebin-cadence outer prune (device-local, off the hot path)
# --------------------------------------------------------------------------

def _pair_geometry(sched: PairSchedule, ext_f, ext_i, idx):
    """Per-pair (bbox gap^2, same flag, occupancy level) at ``idx`` rows.

    ``idx`` holds worklist rows in ``[0, M]`` (``M`` = sentinel).  The
    level is the per-pair slot bound quantized by ``SLOT_QUANTUM``
    (sentinel rows report level 0).
    """
    M = sched.n_pairs
    ne = sched.n_ext_cells
    counts = cell_counts(ext_i).reshape(ne)
    lvl_cell = cell_levels(counts, SLOT_QUANTUM)
    lo, hi = cell_bounds(ext_f[..., :3], ext_i, big=_BIG)
    lo, hi = lo.reshape(ne, 3), hi.reshape(ne, 3)

    ca = jnp.concatenate([jnp.asarray(sched.cell_a),
                          jnp.asarray([ne], jnp.int32)])[idx]
    cb = jnp.concatenate([jnp.asarray(sched.cell_b),
                          jnp.asarray([ne], jnp.int32)])[idx]
    same = jnp.concatenate([jnp.asarray(sched.same),
                            jnp.asarray([0], jnp.int32)])[idx]
    counts_p = jnp.concatenate([counts, jnp.zeros((1,), counts.dtype)])
    lvl_p = jnp.concatenate([lvl_cell, jnp.zeros((1,), lvl_cell.dtype)])
    gap = jnp.maximum(0.0, jnp.maximum(
        lo[jnp.clip(ca, 0, ne - 1)] - hi[jnp.clip(cb, 0, ne - 1)],
        lo[jnp.clip(cb, 0, ne - 1)] - hi[jnp.clip(ca, 0, ne - 1)]))
    d2 = jnp.sum(gap * gap, axis=-1)
    d2 = jnp.where(idx >= M, jnp.asarray(_BIG, d2.dtype), d2)
    lvl = jnp.maximum(lvl_p[ca], lvl_p[cb])
    return d2, same, lvl, counts_p[ca], counts_p[cb]


def _pack_by_level(keep, lvl, L: int, base=None):
    """Occupancy-sorted packing: kept rows first, by DESCENDING level,
    original order preserved within a level (stable argsort).  Returns
    the permutation and the cumulative per-level histogram ``cum``
    (``cum[l-1]`` = kept rows with level >= ``l``)."""
    n = keep.shape[0]
    key = jnp.where(keep, L - lvl, L + 1).astype(jnp.int32)
    order = jnp.argsort(key, stable=True).astype(jnp.int32)
    hist = jnp.zeros((L + 1,), jnp.int32).at[
        jnp.where(keep, lvl, 0)].add(1, mode="drop")
    cum = jnp.flip(jnp.cumsum(jnp.flip(hist[1:])))
    if base is None:
        base = jnp.arange(n, dtype=jnp.int32)
    return base[order], cum


def prune_local(sched: PairSchedule, ext_f: jnp.ndarray, ext_i: jnp.ndarray,
                r_prune: float, r_inner: Optional[float] = None):
    """Outer prune of the static worklist for one block (in shard_map).

    ``ext_f`` / ``ext_i`` are the TRIMMED extended arrays (home + one halo
    cell layer, the NB stencil's reach).  Returns ``(sel, cum, cum_inner,
    max_occ)``: ``sel`` (M,) int32 holds the surviving worklist rows
    packed first, sorted by descending occupancy level (original order
    within a level), with the sentinel ``M`` in the padding tail;
    ``cum`` / ``cum_inner`` are the cumulative per-level histograms of
    the outer survivors and of the subset also within ``r_inner`` (for
    sizing the rolling prune's ladder — ``r_inner=None`` reports the
    outer histogram twice); ``max_occ`` is the max cell occupancy.  The
    host buckets the histograms into static tier ladders (see
    :func:`repro.core.md.schedule_opt.tier_plan`).
    """
    M = sched.n_pairs
    L = sched.levels
    idx = jnp.arange(M, dtype=jnp.int32)
    d2, same, lvl, cnt_a, cnt_b = _pair_geometry(sched, ext_f, ext_i, idx)
    occupied = (cnt_a > 0) & (cnt_b > 0)
    keep = jnp.where(
        same > 0,
        cnt_a >= 2,                                # self pair: >= 1 real pair
        occupied & (d2 < jnp.asarray(r_prune ** 2, d2.dtype)))
    order, cum = _pack_by_level(keep, lvl, L)
    sel = jnp.where(jnp.arange(M) < cum[0], order, M).astype(jnp.int32)
    if r_inner is None:
        cum_inner = cum
    else:
        keep_in = keep & ((same > 0) |
                          (d2 < jnp.asarray(r_inner ** 2, d2.dtype)))
        _, cum_inner = _pack_by_level(keep_in, lvl, L)
    ne = sched.n_ext_cells
    max_occ = jnp.max(cell_counts(ext_i).reshape(ne)).astype(jnp.int32)
    return sel, cum, cum_inner, max_occ


# --------------------------------------------------------------------------
# nstprune-cadence rolling inner prune (inside the block program)
# --------------------------------------------------------------------------

def roll_prune(sched: PairSchedule, sel: jnp.ndarray, ext_f, ext_i,
               r_inner: float):
    """Re-partition the outer exec prefix with CURRENT coordinates.

    ``sel`` is the packed outer prefix (rows in ``[0, M]``, sentinel
    ``M``).  Pairs whose bounding boxes now sit beyond ``r_inner`` are
    stably sorted behind the survivors; survivors are re-sorted by
    descending occupancy level, so the inner tier ladder's per-pair
    bounds stay valid.  Dropped pairs remain in the list (a later
    refresh re-examines every row, so pairs drifting back in are
    resurrected) — rows past the inner ladder are simply not evaluated,
    and any dropped pair still inside the ladder contributes exactly
    zero force (its bbox gap lower-bounds every atom distance at
    ``r_inner >= r_cut``).

    Returns ``(new_sel, cum_surv)``; ``cum_surv[l-1]`` (survivors with
    level >= ``l``) is compared against the ladder's static row budget
    by the engine's overflow monitor.
    """
    L = sched.levels
    d2, same, lvl, cnt_a, _cnt_b = _pair_geometry(sched, ext_f, ext_i, sel)
    keep = (sel < sched.n_pairs) & \
        ((same > 0) | (d2 < jnp.asarray(r_inner ** 2, d2.dtype)))
    new_sel, cum = _pack_by_level(keep, lvl, L, base=sel)
    return new_sel, cum


# --------------------------------------------------------------------------
# batched execution over the pruned worklist (per-tier)
# --------------------------------------------------------------------------

def _padded_ext(sched: PairSchedule, ext_f, ext_i):
    """Flatten + pad the extended arrays for sentinel-safe pair gathers.

    The sentinel worklist row ``M`` routes padding entries to an extra
    all-empty cell at flat index ``n_ext_cells`` (count 0, types -1,
    coords 0), so no masking branch is needed downstream — the kernels'
    count masks kill padding work and the scatter epilogue accumulates it
    into the sliced-off sentinel row.
    """
    ne = sched.n_ext_cells
    K = ext_f.shape[3]
    f2 = ext_f.reshape(ne, K, ext_f.shape[-1])
    id2 = ext_i[..., 0].reshape(ne, K)
    t2 = ext_i[..., 1].reshape(ne, K)
    typ = jnp.where(id2 >= 0, t2, -1).astype(jnp.int32)
    f2p = jnp.concatenate([f2, jnp.zeros((1,) + f2.shape[1:], f2.dtype)])
    tp = jnp.concatenate([typ, jnp.full((1, K), -1, jnp.int32)])
    counts = cell_counts(ext_i).reshape(ne)
    cp = jnp.concatenate([counts, jnp.zeros((1,), counts.dtype)]) \
        .astype(jnp.int32)
    ca_p = jnp.concatenate([jnp.asarray(sched.cell_a),
                            jnp.asarray([ne], jnp.int32)])
    cb_p = jnp.concatenate([jnp.asarray(sched.cell_b),
                            jnp.asarray([ne], jnp.int32)])
    same_p = jnp.concatenate([jnp.asarray(sched.same),
                              jnp.asarray([0], jnp.int32)])
    return f2p, tp, cp, ca_p, cb_p, same_p


def _gather_tier(padded, sel_t, k_exec: int):
    """Pack one tier's pairs into (N_t, K_t, ...) A/B batches + counts."""
    f2p, tp, cp, ca_p, cb_p, same_p = padded
    ca = ca_p[sel_t]
    cb = cb_p[sel_t]
    same = same_p[sel_t]
    fk = f2p[:, :k_exec]
    tk = tp[:, :k_exec]
    return (fk[ca], fk[cb], tk[ca], tk[cb], same, ca, cb,
            jnp.minimum(cp[ca], k_exec), jnp.minimum(cp[cb], k_exec))


def _pair_forces_jnp(a, b, ta, tb, same, cnt_a, cnt_b, ff: ForceField):
    """jnp twin of the Pallas cluster-pair kernel (one batch).

    Same masks and math as ``kernels.nonbonded._pair_kernel``; validity
    comes from the per-pair occupancy counts (``slot < count`` — binning
    packs atoms into a contiguous slot prefix).  The optimization
    barriers pin the K-wide reductions exactly like the dense path does
    (see forces.py), so sparse trajectories stay bitwise stable across
    halo backends and pipeline modes.
    """
    kk = a.shape[1]
    dtype = a.dtype
    pos_a, q_a = a[..., :3], a[..., 3]
    pos_b, q_b = b[..., :3], b[..., 3]
    dx = pos_a[:, :, None, :] - pos_b[:, None, :, :]
    r2 = jnp.sum(dx * dx, axis=-1)
    iota = jnp.arange(kk, dtype=jnp.int32)[None, :]
    mask = (iota < cnt_a[:, None])[:, :, None] & \
        (iota < cnt_b[:, None])[:, None, :]
    mask &= r2 < jnp.asarray(ff.r_cut ** 2, dtype)
    tri = jnp.triu(jnp.ones((kk, kk), jnp.bool_), k=1)[None]
    mask &= jnp.where(same[:, None, None] > 0, tri,
                      jnp.ones((1, kk, kk), jnp.bool_))

    eps_t = jnp.asarray(ff.eps, dtype)
    sig_t = jnp.asarray(ff.sigma, dtype)
    tai = jnp.clip(ta, 0, eps_t.shape[0] - 1)
    tbi = jnp.clip(tb, 0, eps_t.shape[0] - 1)
    eps = eps_t[tai[:, :, None], tbi[:, None, :]]
    sig = sig_t[tai[:, :, None], tbi[:, None, :]]
    fac, pe = pair_terms(dx, r2, q_a[:, :, None], q_b[:, None, :],
                         eps, sig, ff, mask)
    fvec = lax.optimization_barrier(fac[..., None] * dx)
    fa = lax.optimization_barrier(jnp.sum(fvec, axis=2))
    fb = lax.optimization_barrier(-jnp.sum(fvec, axis=1))
    return fa, fb, lax.optimization_barrier(jnp.sum(pe, axis=(1, 2)))


# pallas kernel availability is probed once and latched, mirroring
# HaloPlan._pallas_broken (the jnp twin is the oracle fallback)
_PALLAS_BROKEN = [False]


def pallas_fallback_active() -> bool:
    """True once the Pallas NB kernel has failed and the ``"pallas"``
    backend is executing the jnp twin (surfaced via engine pair_stats)."""
    return _PALLAS_BROKEN[0]


def _latch_pallas_fallback(e: Exception, context: str) -> None:
    """Latch the process-global jnp fallback and say so once, loudly."""
    import warnings
    _PALLAS_BROKEN[0] = True
    warnings.warn(
        f"Pallas NB kernel {context} ({type(e).__name__}: {e}); the "
        "'pallas' force backend falls back to the jnp pair evaluator "
        "for the rest of this process", RuntimeWarning, stacklevel=3)


def probe_pallas(ff: ForceField, interpret: bool = True) -> bool:
    """Eagerly compile+run the NB kernel on a tiny batch; latch fallback.

    The try/except inside :func:`_eval_schedule` only sees *trace-time*
    failures — on a real backend (``interpret=False``) Mosaic lowering
    errors surface at jit-compile time, outside that guard.  Engines
    selecting the ``"pallas"`` backend run this probe once at build time
    so compile-time kernel failures also downgrade to the documented jnp
    fallback instead of crashing the first block program.
    """
    if _PALLAS_BROKEN[0]:
        return False
    try:
        from repro.kernels import nonbonded
        z4 = jnp.zeros((8, 4, 4), jnp.float32)
        t4 = jnp.full((8, 4), -1, jnp.int32)
        c4 = jnp.zeros((8,), jnp.int32)
        F, pe = nonbonded.pair_forces_accum(
            z4, z4, t4, t4, c4, c4, c4, ff, 2, cnt_a=c4, cnt_b=c4,
            interpret=interpret)
        F.block_until_ready()
        return True
    except Exception as e:  # pragma: no cover - backend-specific
        _latch_pallas_fallback(e, "failed its build-time probe")
        return False


def _eval_schedule(ext_f, ext_i, layout: CellLayout, ff: ForceField, *,
                   sched: PairSchedule, sel, tiers,
                   use_pallas: bool, interpret: bool = True):
    """Evaluate the tiered worklist: gather -> pair kernel -> scatter-add.

    ``tiers`` is the static ``((n_rows, k_slots), ...)`` ladder, deepest
    first; ``sel`` covers at least the ladder's total rows.  Returns
    ``(F_ext, pe)`` in the same layout as ``compute_forces`` (the trimmed
    extended force array with halo partial sums).  Tier accumulation
    order is fixed by the python loop, so reductions stay deterministic.
    """
    ne = sched.n_ext_cells
    K = ext_f.shape[3]
    padded = _padded_ext(sched, ext_f, ext_i)
    F_acc = jnp.zeros((ne + 1, K, 3), ext_f.dtype)
    pe_total = jnp.zeros((), ext_f.dtype)
    off = 0
    for n_t, k_t in tiers:
        k_t = min(int(k_t), K)
        sel_t = lax.slice(sel, (off,), (off + int(n_t),))
        off += int(n_t)
        a, b, ta, tb, same, ca, cb, cnt_a, cnt_b = _gather_tier(
            padded, sel_t, k_t)
        F = pe_pairs = None
        if use_pallas and not _PALLAS_BROKEN[0]:
            try:
                from repro.kernels import nonbonded
                # the kernel + its scatter-accumulate epilogue; the
                # sentinel row ne absorbs padding entries and is sliced
                # off below
                F, pe_pairs = nonbonded.pair_forces_accum(
                    a, b, ta, tb, same, ca, cb, ff, ne + 1,
                    cnt_a=cnt_a, cnt_b=cnt_b, interpret=interpret)
            except Exception as e:  # pragma: no cover - backend-specific
                _latch_pallas_fallback(e, "unavailable at trace time")
        if F is None:
            fa, fb, pe_pairs = _pair_forces_jnp(a, b, ta, tb, same,
                                                cnt_a, cnt_b, ff)
            F = jnp.zeros((ne + 1, k_t, 3), ext_f.dtype)
            F = F.at[ca].add(fa, mode="drop")
            F = F.at[cb].add(fb, mode="drop")
        F_acc = F_acc.at[:, :k_t].add(F)
        pe_total = pe_total + jnp.sum(pe_pairs)
    F_out = lax.optimization_barrier(F_acc[:ne])
    F_ext = F_out.reshape(ext_f.shape[:3] + (K, 3))
    return F_ext, pe_total


# --------------------------------------------------------------------------
# force-backend registry
# --------------------------------------------------------------------------

def _dense(ext_f, ext_i, layout, ff, **_):
    """The unchanged 14-zone loop: the bitwise trajectory reference."""
    return compute_forces(ext_f, ext_i, layout, ff)


def _norm_tiers(sel, tiers, k_exec):
    """Accept the legacy single-rectangle call shape (``k_exec=`` alone
    means one tier spanning the whole ``sel`` prefix)."""
    if tiers is None:
        if k_exec is None:
            raise ValueError("pruned backends need tiers= (or k_exec=)")
        return ((int(sel.shape[0]), int(k_exec)),)
    return tuple((int(n), int(k)) for n, k in tiers)


def _sparse(ext_f, ext_i, layout, ff, *, sched, sel, tiers=None,
            k_exec=None, interpret=True):
    return _eval_schedule(ext_f, ext_i, layout, ff, sched=sched, sel=sel,
                          tiers=_norm_tiers(sel, tiers, k_exec),
                          use_pallas=False, interpret=interpret)


def _pallas(ext_f, ext_i, layout, ff, *, sched, sel, tiers=None,
            k_exec=None, interpret=True):
    return _eval_schedule(ext_f, ext_i, layout, ff, sched=sched, sel=sel,
                          tiers=_norm_tiers(sel, tiers, k_exec),
                          use_pallas=True, interpret=interpret)


ForceBackend = Callable[..., Tuple[jnp.ndarray, jnp.ndarray]]
_FORCE_BACKENDS: Dict[str, ForceBackend] = {}


def register_force_backend(name: str, fn: ForceBackend) -> None:
    """Register a force engine under ``name`` (the config axis value)."""
    _FORCE_BACKENDS[name] = fn


def force_backends() -> Tuple[str, ...]:
    return tuple(sorted(_FORCE_BACKENDS))


def get_force_backend(name: str) -> ForceBackend:
    try:
        return _FORCE_BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown force backend {name!r}; "
            f"available: {force_backends()}") from None


register_force_backend("dense", _dense)
register_force_backend("sparse", _sparse)
register_force_backend("pallas", _pallas)
