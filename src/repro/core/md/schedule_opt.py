"""Critical-path schedule notes (paper §5.4) — TPU mapping.

The paper moves the pair-list prune kernel to a low-priority stream and adds
a medium-priority stream for reduction/update so pruning cannot block the
next step's critical path.  Under XLA there are no user-visible streams:
the equivalent lever is *program partitioning* — we keep the rebin/migration
("prune") work in a SEPARATE jitted program executed every ``nstlist``
blocks, so the hot per-step program contains only force/halo/integration
work and XLA's latency-hiding scheduler never interleaves prune work into
the step's critical path.  That structural choice lives in
``MDEngine._build_programs``; this module documents it and provides the
hook point used by the engine so the design intent is greppable.
"""


def noop() -> None:
    """Placeholder hook marking where stream-priority tuning would sit."""
    return None
