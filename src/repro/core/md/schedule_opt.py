"""Critical-path schedule notes (paper §5.4) — TPU mapping.

The paper moves the pair-list prune kernel to a low-priority stream and adds
a medium-priority stream for reduction/update so pruning cannot block the
next step's critical path.  Under XLA there are no user-visible streams:
the equivalent lever is *program partitioning* — the rebin/migration
("prune") work runs as SEPARATE jitted programs executed every ``nstlist``
blocks, so the hot per-step program contains only force/halo/integration
work and XLA's latency-hiding scheduler never interleaves prune work into
the step's critical path.  Two programs live at that cadence:

* ``MDEngine.rebin_fn`` — migration + re-binning (GROMACS' DD/NS step);
* ``MDEngine.prune_fn`` — the pair-schedule prune
  (:func:`repro.core.md.pair_schedule.prune_local`): occupancy counts and
  cell bounding boxes re-derive the surviving cell-pair worklist, whose
  packed prefix the next block's force programs execute.

The prune emits *dynamic* sizes (surviving pairs, max cell occupancy) that
must become *static* exec shapes for the jitted block program.  ``bucket``
below quantizes them so a whole run compiles only a handful of distinct
block programs while keeping the evaluated-work accounting honest (no
power-of-two overshoot).

``tier_plan`` generalizes the single ``(n_exec, k_exec)`` rectangle to the
tiered schedule of the dual pair-list engine: the prune reports a
cumulative per-level histogram (level ``l`` = per-pair slot bound
quantized to ``ceil(bound / slot_quantum)``; ``cum[l-1]`` = pairs whose
bound needs level >= ``l``), and the planner turns it into a static
descending ladder of ``(n_rows, k_slots)`` tiers.  Because the prune
packs pairs front-first by DESCENDING level, a tier's rows can only hold
pairs whose own bound is <= the tier's ``k_slots`` — per-pair bounds are
never truncated, they are only ever rounded up to the tier above.
"""
from typing import Sequence, Tuple

Tier = Tuple[int, int]          # (n_rows, k_slots)


def bucket(n: int, quantum: int, cap: int) -> int:
    """Round ``n`` up to a multiple of ``quantum``, clamped to [quantum, cap].

    Used by the engine to turn prune-reported dynamic sizes into stable
    static shapes: occupancy drifts by a few atoms between blocks, but the
    bucketed shape — hence the compiled program — stays put.
    """
    n = max(int(n), 1)
    b = -(-n // quantum) * quantum
    return int(min(max(b, quantum), cap))


def bucket0(n: int, quantum: int, cap: int) -> int:
    """``bucket`` that maps 0 to 0 (an empty tier is dropped, not padded)."""
    return 0 if int(n) <= 0 else bucket(n, quantum, cap)


def tier_plan(cum: Sequence[int], pair_bucket: int, cap_pairs: int,
              slot_quantum: int, capacity: int) -> Tuple[Tier, ...]:
    """Static tier ladder from a cumulative per-level pair histogram.

    ``cum[l-1]`` is the (mesh-global, pmax'd) count of surviving pairs
    whose per-pair slot bound needs level >= ``l`` (i.e. bound >
    ``(l-1) * slot_quantum``); it is non-increasing in ``l``.  Returns
    ``((n_rows, k_slots), ...)`` ordered deepest tier first, matching the
    prune's descending-level packing: tier boundaries are the bucketed
    cumulative counts, so row ``r`` of the packed worklist lands in a
    tier whose ``k_slots`` is >= the bound of every pair the prune can
    place there.  Empty tiers are dropped; the total row count is the
    bucketed ``cum[0]``.
    """
    L = len(cum)
    # bucketed cumulative boundary per level (monotone by construction:
    # cum is non-increasing in l and bucket0 is monotone)
    b = [bucket0(cum[lv], pair_bucket, cap_pairs) for lv in range(L)]
    for lv in range(L - 2, -1, -1):      # enforce monotonicity after clamp
        b[lv] = max(b[lv], b[lv + 1])
    tiers = []
    prev = 0
    for lv in range(L - 1, -1, -1):      # deepest level first
        n_rows = b[lv] - prev
        if n_rows > 0:
            tiers.append((n_rows, min((lv + 1) * slot_quantum, capacity)))
        prev = b[lv]
    return tuple(tiers)


def tier_rows(tiers: Sequence[Tier]) -> int:
    """Total packed rows a tier ladder evaluates."""
    return int(sum(n for n, _ in tiers))


def tier_slot_pairs(tiers: Sequence[Tier]) -> int:
    """Evaluated slot pairs of a tier ladder (sum of n * k^2)."""
    return int(sum(n * k * k for n, k in tiers))


def tier_cum(tiers: Sequence[Tier], slot_quantum: int,
             n_levels: int) -> Tuple[int, ...]:
    """Invert ``tier_plan``: cumulative row capacity per level.

    ``out[l-1]`` = rows available to pairs of level >= ``l`` — the static
    bound the rolling prune's overflow monitor compares its current
    survivor histogram against (a refresh whose level-``l`` survivors
    exceed ``out[l-1]`` would spill into a tier too shallow for them).
    """
    out = [0] * n_levels
    for n, k in tiers:
        lv = min(-(-k // slot_quantum), n_levels)      # tier's level
        for i in range(lv):
            out[i] += n
    return tuple(out)


def noop() -> None:
    """Placeholder hook marking where stream-priority tuning would sit."""
    return None
