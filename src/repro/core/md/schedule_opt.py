"""Critical-path schedule notes (paper §5.4) — TPU mapping.

The paper moves the pair-list prune kernel to a low-priority stream and adds
a medium-priority stream for reduction/update so pruning cannot block the
next step's critical path.  Under XLA there are no user-visible streams:
the equivalent lever is *program partitioning* — the rebin/migration
("prune") work runs as SEPARATE jitted programs executed every ``nstlist``
blocks, so the hot per-step program contains only force/halo/integration
work and XLA's latency-hiding scheduler never interleaves prune work into
the step's critical path.  Two programs live at that cadence:

* ``MDEngine.rebin_fn`` — migration + re-binning (GROMACS' DD/NS step);
* ``MDEngine.prune_fn`` — the pair-schedule prune
  (:func:`repro.core.md.pair_schedule.prune_local`): occupancy counts and
  cell bounding boxes re-derive the surviving cell-pair worklist, whose
  packed prefix the next block's force programs execute.

The prune emits *dynamic* sizes (surviving pairs, max cell occupancy) that
must become *static* exec shapes for the jitted block program.  ``bucket``
below quantizes them so a whole run compiles only a handful of distinct
block programs while keeping the evaluated-work accounting honest (no
power-of-two overshoot).
"""


def bucket(n: int, quantum: int, cap: int) -> int:
    """Round ``n`` up to a multiple of ``quantum``, clamped to [quantum, cap].

    Used by the engine to turn prune-reported dynamic sizes into stable
    static shapes: occupancy drifts by a few atoms between blocks, but the
    bucketed shape — hence the compiled program — stays put.
    """
    n = max(int(n), 1)
    b = -(-n // quantum) * quantum
    return int(min(max(b, quantum), cap))


def noop() -> None:
    """Placeholder hook marking where stream-priority tuning would sit."""
    return None
