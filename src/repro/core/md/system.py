"""Grappa-like benchmark systems: homogeneous LJ + reaction-field fluid.

The paper's evaluation uses the "grappa" set — water/ethanol mixtures from
45k to 46M atoms with reaction-field electrostatics, chosen because their
computational profile matches typical biomolecular runs while staying
homogeneous (paper §6.1).  We reproduce that profile in reduced LJ units:
a dense two-type fluid (water-like / ethanol-like LJ parameters) carrying
small alternating partial charges, reaction-field electrostatics with a
potential shift, and a van-der-Waals potential-shift at the cutoff.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class ForceField:
    """Pairwise LJ (per type pair, Lorentz-Berthelot) + reaction field."""

    eps: Tuple[Tuple[float, ...], ...]      # (T, T) LJ epsilon table
    sigma: Tuple[Tuple[float, ...], ...]    # (T, T) LJ sigma table
    r_cut: float
    eps_rf: float                           # RF dielectric (inf -> k_rf=1/(2rc^3))

    @property
    def k_rf(self) -> float:
        if np.isinf(self.eps_rf):
            return 1.0 / (2.0 * self.r_cut ** 3)
        e = self.eps_rf
        return (e - 1.0) / (2.0 * e + 1.0) / self.r_cut ** 3

    @property
    def c_rf(self) -> float:
        """Potential shift making the RF term vanish at the cutoff."""
        return 1.0 / self.r_cut + self.k_rf * self.r_cut ** 2


@dataclasses.dataclass(frozen=True)
class MDParams:
    ff: ForceField
    dt: float = 0.002
    mass: float = 1.0
    nstlist: int = 20          # rebin/migration cadence (pair-list horizon)
    temperature: float = 1.0


@dataclasses.dataclass
class MDSystem:
    """Global (pre-decomposition) description of one benchmark system."""

    box: np.ndarray            # (3,) box lengths
    pos: np.ndarray            # (N, 3) float
    vel: np.ndarray            # (N, 3) float
    charge: np.ndarray         # (N,)
    typ: np.ndarray            # (N,) int8
    params: MDParams

    @property
    def n_atoms(self) -> int:
        return self.pos.shape[0]


DEFAULT_FF = ForceField(
    eps=((1.0, 0.9), (0.9, 0.8)),
    sigma=((1.0, 1.05), (1.05, 1.1)),
    r_cut=2.5,
    eps_rf=float("inf"),
)


def make_grappa_like(n_atoms: int, density: float = 0.78,
                     temperature: float = 1.0, charge_mag: float = 0.25,
                     ethanol_fraction: float = 0.2, seed: int = 0,
                     dtype=np.float32, ff: ForceField = DEFAULT_FF,
                     dt: float = 0.002, nstlist: int = 20,
                     box_atoms: int | None = None) -> MDSystem:
    """Build a charge-neutral two-type fluid on a jittered FCC-ish lattice.

    Lattice start avoids overlaps (stable from step 0); velocities are
    Maxwell-Boltzmann with the center-of-mass motion removed, as GROMACS
    does at generation time.

    ``box_atoms`` sizes the box as if the system held that many atoms (at
    the same density), while only ``n_atoms`` are actually placed — the
    SimServer bucket contract: every replica of an ``n_atoms_bucket``
    shares the bucket's canonical box (hence cell layout), and sub-bucket
    replicas simply run more dilute.
    """
    rng = np.random.RandomState(seed)
    # cubic box from density
    L = ((box_atoms or n_atoms) / density) ** (1.0 / 3.0)
    box = np.array([L, L, L], dtype=np.float64)

    # simple-cubic lattice with jitter, then trim to n_atoms
    per_dim = int(np.ceil(n_atoms ** (1 / 3)))
    spacing = L / per_dim
    grid = np.stack(np.meshgrid(*[np.arange(per_dim)] * 3, indexing="ij"),
                    axis=-1).reshape(-1, 3).astype(np.float64)
    pos = (grid + 0.5) * spacing
    order = rng.permutation(pos.shape[0])[:n_atoms]
    pos = pos[order]
    pos += rng.uniform(-0.08, 0.08, pos.shape) * spacing
    pos %= box

    # velocities ~ Maxwell(T), zero total momentum
    vel = rng.normal(0.0, np.sqrt(temperature), (n_atoms, 3))
    vel -= vel.mean(axis=0, keepdims=True)

    # alternating charges in pairs -> exactly neutral
    charge = np.zeros(n_atoms)
    half = n_atoms // 2
    charge[:half] = charge_mag
    charge[half:2 * half] = -charge_mag
    rng.shuffle(charge)

    typ = (rng.uniform(size=n_atoms) < ethanol_fraction).astype(np.int8)

    params = MDParams(ff=ff, dt=dt, nstlist=nstlist, temperature=temperature)
    if ff.r_cut >= L / 2:
        raise ValueError(
            f"r_cut={ff.r_cut} must be < box/2={L / 2:.3f} "
            f"(n_atoms={n_atoms} too small for this density/cutoff)")
    return MDSystem(box=box, pos=pos.astype(dtype), vel=vel.astype(dtype),
                    charge=charge.astype(dtype), typ=typ, params=params)


# the paper's grappa ladder (§6.1): 45k .. 2.88M atoms as used in Figs. 3-8
GRAPPA_SIZES = {
    "grappa-45k": 45_000,
    "grappa-90k": 90_000,
    "grappa-180k": 180_000,
    "grappa-360k": 360_000,
    "grappa-720k": 720_000,
    "grappa-1440k": 1_440_000,
    "grappa-2880k": 2_880_000,
}
