"""Velocity-Verlet integration + diagnostics (NVE; optional rescale)."""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

AXES = ("z", "y", "x")


def kinetic_energy(vel, valid, mass: float):
    v2 = jnp.sum(vel * vel, axis=-1)
    ke_local = 0.5 * mass * jnp.sum(jnp.where(valid, v2, 0.0))
    return lax.psum(ke_local, AXES)


def momentum(vel, valid, mass: float):
    p_local = mass * jnp.sum(jnp.where(valid[..., None], vel, 0.0),
                             axis=tuple(range(vel.ndim - 1)))
    return lax.psum(p_local, AXES)


def n_atoms_global(valid):
    return lax.psum(jnp.sum(valid), AXES)


def temperature(ke, n_atoms, dof_per_atom: int = 3):
    return 2.0 * ke / (dof_per_atom * jnp.maximum(n_atoms, 1))


def velocity_rescale(vel, valid, mass, target_T, tau_steps: float):
    """Weak Berendsen-style rescale toward target temperature."""
    ke = kinetic_energy(vel, valid, mass)
    n = n_atoms_global(valid)
    T = temperature(ke, n)
    lam = jnp.sqrt(1.0 + (target_T / jnp.maximum(T, 1e-8) - 1.0) / tau_steps)
    return jnp.where(valid[..., None], vel * lam, vel)
