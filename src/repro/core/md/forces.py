"""Non-bonded forces: LJ + reaction-field over cutoff-sized cell pairs.

Pair assignment follows the neutral-territory eighth-shell rule [Liem'91,
Hess'08]: with one-sided halos (the extended array covers offsets {0, +1}
per dim), every global cell pair within the cutoff stencil is computed by
exactly one domain — the owner of the componentwise-min "base" cell.  Per
base cell that yields 14 interactions: the cell with itself plus 13
unordered pairs of disjoint offsets (a, b) in {0,1}^3 (a AND b == 0, the
classic half stencil re-anchored so only POSITIVE offsets are touched —
which is precisely why the one-directional staged halo suffices).

Periodic images are pre-shifted by the halo exchange (coordShift), so no
minimum-image logic appears here — exactly like GROMACS' shifted halo
coordinates.

``compute_forces`` is the ``"dense"`` entry of the force-backend registry
(:mod:`repro.core.md.pair_schedule`): it evaluates every K x K slot pair
of every zone product and is the bitwise trajectory reference that the
pruned ``"sparse"`` / ``"pallas"`` pair-schedule engines are validated
against.
"""
from __future__ import annotations

import itertools
from typing import List, Tuple

import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.md.cells import CellLayout
from repro.core.md.system import ForceField

Offset = Tuple[int, int, int]


def stencil_pairs() -> List[Tuple[Offset, Offset]]:
    """Self pair + the 13 disjoint-offset cell pairs (eighth-shell zones)."""
    offs = list(itertools.product((0, 1), repeat=3))
    pairs: List[Tuple[Offset, Offset]] = [((0, 0, 0), (0, 0, 0))]
    for a, b in itertools.combinations(offs, 2):
        if all(x * y == 0 for x, y in zip(a, b)):
            pairs.append((a, b))
    assert len(pairs) == 14
    return pairs


def _zone(arr, off, shape):
    cz, cy, cx = shape
    return arr[off[0]:off[0] + cz, off[1]:off[1] + cy, off[2]:off[2] + cx]


def pair_terms(dx, r2, qa, qb, eps, sig, ff: ForceField, mask):
    """Per-pair scalar force factor (F = fac * dx) and potential energy.

    Shared by the dense 14-zone loop below and the sparse pair-schedule
    engine (:mod:`repro.core.md.pair_schedule`), so every force backend
    evaluates the identical per-pair math and differs only in which slot
    pairs it touches and in reduction order.
    """
    dtype = dx.dtype
    r2safe = jnp.where(mask, r2, jnp.asarray(1.0, dtype))
    inv_r2 = 1.0 / r2safe
    sr2 = (sig * sig) * inv_r2
    sr6 = sr2 * sr2 * sr2
    sr12 = sr6 * sr6
    # LJ with potential-shift at the cutoff (forces unchanged)
    fac_lj = 24.0 * eps * (2.0 * sr12 - sr6) * inv_r2
    src2 = (sig * sig) / (ff.r_cut * ff.r_cut)
    src6 = src2 * src2 * src2
    e_lj = 4.0 * eps * ((sr12 - sr6) - (src6 * src6 - src6))
    # reaction field with potential shift c_rf
    inv_r = jnp.sqrt(inv_r2)
    qq = qa * qb
    k_rf = jnp.asarray(ff.k_rf, dtype)
    c_rf = jnp.asarray(ff.c_rf, dtype)
    fac_c = qq * (inv_r * inv_r2 - 2.0 * k_rf)
    e_c = qq * (inv_r + k_rf * r2safe - c_rf)
    fac = jnp.where(mask, fac_lj + fac_c, 0.0)
    pe = jnp.where(mask, e_lj + e_c, 0.0)
    return fac, pe


def compute_forces(ext_f, ext_i, layout: CellLayout, ff: ForceField):
    """Forces + potential energy on the extended (home + halo) cell array.

    ext_f: (cz+1, cy+1, cx+1, K, 4) — [x, y, z, charge] halo-shifted coords
    ext_i: (cz+1, cy+1, cx+1, K, 2) — [atom id, type]; id < 0 marks padding
    Returns (F_ext, pe): forces accumulated at BOTH pair members (halo
    members hold partial sums to be returned by the reverse exchange) and
    this domain's share of the potential energy.
    """
    shape = layout.cells_per_domain
    dtype = ext_f.dtype
    eps_t = jnp.asarray(ff.eps, dtype)
    sig_t = jnp.asarray(ff.sigma, dtype)
    rc2 = jnp.asarray(ff.r_cut * ff.r_cut, dtype)
    K = layout.capacity

    F_ext = jnp.zeros(ext_f.shape[:-1] + (3,), dtype)
    pe_total = jnp.zeros((), dtype)
    eye = jnp.eye(K, dtype=bool)
    tri = jnp.triu(jnp.ones((K, K), dtype=bool), k=1)

    for a, b in stencil_pairs():
        A_f, B_f = _zone(ext_f, a, shape), _zone(ext_f, b, shape)
        A_i, B_i = _zone(ext_i, a, shape), _zone(ext_i, b, shape)
        pos_a, q_a = A_f[..., :3], A_f[..., 3]
        pos_b, q_b = B_f[..., :3], B_f[..., 3]
        valid_a, valid_b = A_i[..., 0] >= 0, B_i[..., 0] >= 0
        typ_a = jnp.clip(A_i[..., 1], 0, eps_t.shape[0] - 1)
        typ_b = jnp.clip(B_i[..., 1], 0, eps_t.shape[0] - 1)

        dx = pos_a[..., :, None, :] - pos_b[..., None, :, :]
        r2 = jnp.sum(dx * dx, axis=-1)
        mask = (valid_a[..., :, None] & valid_b[..., None, :]) & (r2 < rc2)
        if a == b:
            mask = mask & tri        # each intra-cell pair once
        else:
            mask = mask & ~(eye & (A_i[..., 0:1] == B_i[..., None, :, 0]))

        eps = eps_t[typ_a[..., :, None], typ_b[..., None, :]]
        sig = sig_t[typ_a[..., :, None], typ_b[..., None, :]]
        fac, pe = pair_terms(dx, r2, q_a[..., :, None], q_b[..., None, :],
                             eps, sig, ff, mask)
        # barriers pin the K-wide pair reductions to standalone, canonical
        # compilations: their partial-sum order must not depend on how the
        # surrounding program (halo backend, step-pipeline schedule) fuses,
        # or different schedules would drift apart at the ulp level
        fvec = lax.optimization_barrier(fac[..., None] * dx)
        fa = lax.optimization_barrier(
            jnp.sum(fvec, axis=-2))          # force on A atoms
        fb = lax.optimization_barrier(
            -jnp.sum(fvec, axis=-3))         # Newton's third law
        cz, cy, cx = shape
        F_ext = F_ext.at[a[0]:a[0] + cz, a[1]:a[1] + cy,
                         a[2]:a[2] + cx].add(fa)
        F_ext = F_ext.at[b[0]:b[0] + cz, b[1]:b[1] + cy,
                         b[2]:b[2] + cx].add(fb)
        pe_total = pe_total + jnp.sum(pe)

    return F_ext, pe_total


# --------------------------------------------------------------------------
# O(N^2) minimum-image oracle (tests only)
# --------------------------------------------------------------------------

def direct_forces_reference(pos, charge, typ, box, ff: ForceField):
    """Direct-sum reference with minimum image; float64 numpy."""
    pos = np.asarray(pos, np.float64)
    q = np.asarray(charge, np.float64)
    t = np.asarray(typ, np.int64)
    box = np.asarray(box, np.float64)
    n = pos.shape[0]
    eps_t = np.asarray(ff.eps, np.float64)
    sig_t = np.asarray(ff.sigma, np.float64)

    dx = pos[:, None, :] - pos[None, :, :]
    dx -= box * np.round(dx / box)
    r2 = np.sum(dx * dx, axis=-1)
    mask = (r2 < ff.r_cut ** 2) & ~np.eye(n, dtype=bool)
    r2safe = np.where(mask, r2, 1.0)
    inv_r2 = 1.0 / r2safe
    eps = eps_t[t[:, None], t[None, :]]
    sig = sig_t[t[:, None], t[None, :]]
    sr2 = sig * sig * inv_r2
    sr6 = sr2 ** 3
    sr12 = sr6 ** 2
    fac_lj = 24 * eps * (2 * sr12 - sr6) * inv_r2
    src6 = (sig * sig / ff.r_cut ** 2) ** 3
    e_lj = 4 * eps * ((sr12 - sr6) - (src6 ** 2 - src6))
    inv_r = np.sqrt(inv_r2)
    qq = q[:, None] * q[None, :]
    fac_c = qq * (inv_r * inv_r2 - 2 * ff.k_rf)
    e_c = qq * (inv_r + ff.k_rf * r2safe - ff.c_rf)
    fac = np.where(mask, fac_lj + fac_c, 0.0)
    pe = 0.5 * np.sum(np.where(mask, e_lj + e_c, 0.0))
    forces = np.sum(fac[..., None] * dx, axis=1)
    return forces, pe
