"""Neutral-territory domain decomposition: migration and re-binning.

Atom migration runs every ``nstlist`` steps, off the hot time-step path —
the analogue of GROMACS' "Domain Decomposition / Neighbor Search" special
steps that the paper's timing methodology subtracts out (§6.3).  Routing is
dimension-ordered (Z then Y then X) with one hop per dimension, which is
sufficient because the rebin cadence bounds drift to under one cell.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.md.cells import CellLayout, bin_to_cells, cells_to_pool

AXES = ("z", "y", "x")


def domain_index(axis_names: Sequence[str] = AXES) -> jnp.ndarray:
    return jnp.stack([lax.axis_index(a) for a in axis_names])


def _take_rows(flag, pool_f, pool_i, cap: int):
    """Compact up to ``cap`` flagged rows into a fixed-size buffer."""
    order = jnp.argsort(jnp.where(flag, 0, 1), stable=True)
    sel = order[:cap]
    sel_valid = flag[sel]
    buf_f = jnp.where(sel_valid[:, None], pool_f[sel], 0.0)
    buf_i = jnp.where(sel_valid[:, None], pool_i[sel], -1)
    sent = jnp.zeros_like(flag).at[sel].set(sel_valid)
    dropped = jnp.sum(flag) - jnp.sum(sel_valid)
    return buf_f, buf_i, sent, dropped


def _merge_rows(pool_f, pool_i, buf_f, buf_i):
    """Place received atoms into empty pool slots; count losses."""
    empty = pool_i[:, 0] < 0
    order = jnp.argsort(jnp.where(empty, 0, 1), stable=True)
    m = buf_f.shape[0]
    dst = order[:m]
    incoming = buf_i[:, 0] >= 0
    ok = incoming & empty[dst]
    pool_f = pool_f.at[dst].set(jnp.where(ok[:, None], buf_f, pool_f[dst]))
    pool_i = pool_i.at[dst].set(jnp.where(ok[:, None], buf_i, pool_i[dst]))
    lost = jnp.sum(incoming & ~empty[dst])
    return pool_f, pool_i, lost


def migrate(pool_f, pool_i, layout: CellLayout, mig_cap: int):
    """Dimension-ordered migration of atoms that left their domain.

    pool_f: (P, 4) [x, y, z, charge]; pool_i: (P, 2) [id, type] with id < 0
    marking empty slots.  Returns updated pools + a diagnostics dict whose
    counters must stay zero in healthy runs (asserted by tests).
    """
    box = jnp.asarray(layout.box, pool_f.dtype)
    dropped_total = jnp.zeros((), jnp.int32)
    lost_total = jnp.zeros((), jnp.int32)

    # wrap positions into the box first (global coordinates)
    pos = jnp.mod(pool_f[:, :3], box)
    pool_f = pool_f.at[:, :3].set(pos)

    for d in range(3):
        S = layout.mesh_shape[d]
        if S == 1:
            continue
        extent = layout.cells_per_domain[d] * layout.cell_size[d]
        valid = pool_i[:, 0] >= 0
        dest = jnp.floor(pool_f[:, d] / extent).astype(jnp.int32)
        dest = jnp.clip(dest, 0, S - 1)
        me = lax.axis_index(AXES[d])
        rel = jnp.mod(dest - me, S)
        send_hi = valid & (rel == 1)
        send_lo = valid & (rel == S - 1) & (S > 2)
        # anything farther than one domain is a physics bug; route it high
        # and count it so tests can fail loudly
        too_far = valid & (rel != 0) & (rel != 1) & (rel != S - 1)
        send_hi = send_hi | too_far
        dropped_total = dropped_total + jnp.sum(too_far).astype(jnp.int32)

        buf_f, buf_i, sent, drop1 = _take_rows(send_hi, pool_f, pool_i,
                                               mig_cap)
        pool_i = jnp.where(sent[:, None], -1, pool_i)
        lbuf_f, lbuf_i, lsent, drop2 = _take_rows(send_lo, pool_f, pool_i,
                                                  mig_cap)
        pool_i = jnp.where(lsent[:, None], -1, pool_i)
        dropped_total = dropped_total + (drop1 + drop2).astype(jnp.int32)

        perm_hi = [(j, (j + 1) % S) for j in range(S)]
        perm_lo = [(j, (j - 1) % S) for j in range(S)]
        rf = lax.ppermute(buf_f, AXES[d], perm_hi)
        ri = lax.ppermute(buf_i, AXES[d], perm_hi)
        pool_f, pool_i, lost1 = _merge_rows(pool_f, pool_i, rf, ri)
        rf = lax.ppermute(lbuf_f, AXES[d], perm_lo)
        ri = lax.ppermute(lbuf_i, AXES[d], perm_lo)
        pool_f, pool_i, lost2 = _merge_rows(pool_f, pool_i, rf, ri)
        lost_total = lost_total + (lost1 + lost2).astype(jnp.int32)

    diag = {"migration_dropped": lax.psum(dropped_total, AXES),
            "migration_lost": lax.psum(lost_total, AXES)}
    return pool_f, pool_i, diag


def rebin(cell_f, cell_i, layout: CellLayout, mig_cap: int):
    """Wrap, migrate and re-bin the domain's atoms (every nstlist steps)."""
    pool_f, pool_i = cells_to_pool(cell_f, cell_i)
    pool_f, pool_i, diag = migrate(pool_f, pool_i, layout, mig_cap)
    new_f, new_i, overflow = bin_to_cells(pool_f[:, :3], pool_f[:, 3:],
                                          pool_i, layout, domain_index())
    diag["bin_overflow"] = lax.psum(overflow.astype(jnp.int32), AXES)
    diag["n_atoms"] = lax.psum(jnp.sum(new_i[..., 0] >= 0), AXES)
    return new_f, new_i, diag
