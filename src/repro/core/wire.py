"""Drift-bounded compressed halo payloads (``HaloSpec.wire_dtype``).

Halo bytes are the strong-scaling ceiling in the paper's alpha-beta model
once latency is hidden, so the next multiple comes from shrinking the wire
payload itself.  This module is the single codec seam every layer shares:

* :class:`WireCodec` — elementwise encode/decode between the payload dtype
  and a wire format.  The two exchange directions compress differently,
  because they fail differently (all numbers measured by the PR 5 NVE
  harness, see MEASURED_DRIFT):

  - the *coordinate* (forward) direction has a **float32 floor**: pair
    distances consume coordinate error directly, so quantizing absolute
    positions below single precision corrupts the potential — a raw bf16
    coordinate cast measures ~50x the dense drift, and error feedback on
    coordinates makes it *worse* (it dithers positions).  f64 payloads
    ship f32 coordinates (GROMACS' mixed-precision comm choice for
    double-precision trajectories); f32 payloads ship dense.
  - the *force-return* (reverse) direction carries the named format:
    force contributions are summed and their quantization error acts as
    zero-mean noise the integrator tolerates, so ``"bfloat16"`` /
    ``"float16"`` casts measure at the dense drift level.  ``"int8_ef"``
    is per-tensor-scaled int8 with error feedback — the EF machinery's
    legitimate domain (summed gradient-like quantities), shared with
    :mod:`repro.optim.compression` so the gradient path and the halo
    path cannot drift apart; ``"int8"`` (no feedback) exists as the
    documented over-aggressive config the drift gate rejects.

* shared int8 helpers (:func:`int8_scale` / :func:`int8_quantize` /
  :func:`int8_dequantize`) — hardened against nonfinite inputs: the scale
  is taken over finite entries only and nonfinite entries quantize to 0,
  so a single NaN no longer poisons the whole tensor's dequant (it used
  to propagate through ``max(|g|)``).

* the build-time drift gate (:func:`gate_wire_config`) — the PR 5 NVE
  harness measured each wire format's 200-step energy drift on the slab
  system (``tests/test_nve_drift.py`` keeps the table honest); a config
  whose measured drift exceeds the dense-f32 bound raises
  :class:`WireDriftError` at plan-build time, with the same
  ``verify="warn"/"off"`` escape hatch as the PR 6 schedule verifier.

Emulation contract: quantization is applied once per exchange direction
at the plan seam (quantize-before-send, body spliced back exactly —
only data that crosses the wire is lossy), so every backend transports
the same wire-gridded payload and the PR 4 bitwise cross-backend
conformance carries over to compressed exchanges.  Staged multi-hop
forwarding re-rounds implicitly (fp casts are idempotent on wire-grid
values); per-hop re-scaling of int8 accumulations is not emulated.
"""
from __future__ import annotations

import warnings
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

# recognized wire formats; None (dense) is always legal
WIRE_DTYPES = ("float32", "bfloat16", "float16", "int8_ef", "int8")

# wire bytes per payload element
WIRE_ITEMSIZE = {"float32": 4, "bfloat16": 2, "float16": 2,
                 "int8_ef": 1, "int8": 1}

_FP_WIRE = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}

# ---------------------------------------------------------------------------
# drift gate: measured NVE drift per wire format vs the dense-f32 bound
# ---------------------------------------------------------------------------

# the dense-f32 drift level of tests/test_nve_drift.py (DRIFT_BOUND there):
# measured dense drift is ~4e-4/atom over 200 steps, integrator-truncation
# dominated; a compressed exchange must stay at this level to be accepted
DENSE_F32_DRIFT_BOUND = 1.5e-3

# measured by tests/test_nve_drift.py (float64 two-slab system, 200 steps,
# drift = (E.max - E.min) / n_atoms, fused backend; dense reference
# measures 3.4e-4).  All formats ship f32-floor coordinates; the named
# format applies to the force return.  The test suite re-measures and
# asserts these classifications so the table cannot silently go stale.
MEASURED_DRIFT = {
    "float32": 3.4e-4,    # bitwise == dense on f32 payloads
    "bfloat16": 3.2e-4,   # force quant noise integrates as zero-mean
    "float16": 3.4e-4,    # at the dense level
    "int8_ef": 4.3e-4,    # error feedback keeps the bias corrected
    "int8": 3.0e-3,       # no feedback: bias accumulates -> REJECTED
}

VERIFY_MODES = ("error", "warn", "off")


class WireDriftError(ValueError):
    """A wire format whose measured NVE drift exceeds the dense-f32 bound."""


def gate_wire_config(wire_dtype: Optional[str], verify: str = "error",
                     bound: float = DENSE_F32_DRIFT_BOUND
                     ) -> Optional[float]:
    """Build-time acceptance gate for a compressed-halo config.

    Returns the measured drift for ``wire_dtype`` (None for dense).
    Raises :class:`WireDriftError` when that drift exceeds ``bound``
    (``verify="warn"`` downgrades to a ``RuntimeWarning``, ``"off"``
    skips — the PR 6 escape-hatch convention), and ``ValueError`` for
    unknown formats regardless of ``verify`` (never silently degrade).
    """
    if verify not in VERIFY_MODES:
        raise ValueError(f"unknown verify mode {verify!r}; "
                         f"available: {VERIFY_MODES}")
    if wire_dtype is None:
        return None
    if wire_dtype not in WIRE_DTYPES:
        raise ValueError(f"unknown wire_dtype {wire_dtype!r}; "
                         f"available: {WIRE_DTYPES} or None")
    if verify == "off":
        return MEASURED_DRIFT[wire_dtype]
    drift = MEASURED_DRIFT[wire_dtype]
    if drift > bound:
        msg = (f"wire_dtype={wire_dtype!r}: measured NVE drift "
               f"{drift:.2e}/atom exceeds the dense-f32 bound "
               f"{bound:.2e} (tests/test_nve_drift.py harness); this "
               "config corrupts trajectories and is rejected at build "
               "time.  Use 'int8_ef' (error feedback) or a 16-bit wire "
               "format, or pass verify='warn' to measure it anyway.")
        if verify == "warn":
            warnings.warn(msg, RuntimeWarning, stacklevel=3)
        else:
            raise WireDriftError(msg)
    return drift


# ---------------------------------------------------------------------------
# shared int8 quantize/dequant helpers (also used by optim.compression)
# ---------------------------------------------------------------------------

def int8_scale(x: jnp.ndarray) -> jnp.ndarray:
    """Per-tensor int8 scale, hardened against nonfinite inputs.

    ``max(|x|) / 127 + eps`` over *finite* entries only: a NaN/Inf in
    ``x`` must corrupt at most its own slot, never the whole tensor's
    dequant through a poisoned scale.  A zero (or all-nonfinite) tensor
    yields the epsilon scale, quantizing everything to 0.
    """
    finite = jnp.isfinite(x)
    amax = jnp.max(jnp.abs(jnp.where(finite, x, 0)))
    return amax / 127.0 + jnp.asarray(1e-12, amax.dtype)


def int8_quantize(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Round/clip to int8 at ``scale``; nonfinite entries quantize to 0."""
    q = jnp.where(jnp.isfinite(x), jnp.round(x / scale), 0)
    return jnp.clip(q, -127, 127).astype(jnp.int8)


def int8_dequantize(q: jnp.ndarray, scale: jnp.ndarray,
                    dtype=jnp.float32) -> jnp.ndarray:
    return q.astype(dtype) * scale.astype(dtype)


def int8_encode(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray,
                                         jnp.ndarray]:
    """Quantize + the error-feedback residual: ``(q, scale, err)``.

    ``err`` is the finite part of ``x - dequant(q)`` — what error
    feedback carries to the next round so the quantization bias is
    corrected over steps instead of accumulating.
    """
    scale = int8_scale(x)
    q = int8_quantize(x, scale)
    err = jnp.where(jnp.isfinite(x), x, 0) - int8_dequantize(q, scale,
                                                             x.dtype)
    return q, scale, err


# ---------------------------------------------------------------------------
# codec
# ---------------------------------------------------------------------------

class WireCodec:
    """Elementwise wire-format codec for one ``HaloSpec.wire_dtype``.

    ``encode(x, ef)`` / ``decode(parts, dtype)`` / ``roundtrip(x, ef)``
    are the *force-return* (reverse) direction: the named format, with
    error feedback for int8_ef.  ``fwd_roundtrip(x)`` is the coordinate
    (forward) direction: a float32-floor cast regardless of the named
    format (see the module docstring for the measured rationale).
    ``encode``'s parts are what send buffers / pipeline slot rings
    store; ``roundtrip`` composes encode+decode — the value every
    consumer of wire-crossed data sees at the plan seam.
    """

    def __init__(self, name: str):
        if name not in WIRE_DTYPES:
            raise ValueError(f"unknown wire_dtype {name!r}; "
                             f"available: {WIRE_DTYPES} or None")
        self.name = name
        self.wire_itemsize = WIRE_ITEMSIZE[name]
        self.is_float = name in _FP_WIRE
        self.jdtype = _FP_WIRE.get(name)
        # stateful formats thread EF arrays through the caller's scan
        self.stateful = name == "int8_ef"

    @staticmethod
    def fwd_itemsize(payload_dtype) -> int:
        """Coordinate-direction wire bytes/elem: the float32 floor."""
        return min(4, np.dtype(payload_dtype).itemsize)

    @staticmethod
    def fwd_wire_dtype(payload_dtype) -> Optional[str]:
        """Coordinate-direction wire dtype, or None when the payload
        already sits at (or below) the float32 floor and rides dense."""
        if np.dtype(payload_dtype).itemsize > 4:
            return "float32"
        return None

    def fwd_roundtrip(self, x: jnp.ndarray) -> jnp.ndarray:
        """Wire-grid a coordinate payload: f32 cast for wide payloads,
        identity at or below the floor."""
        if self.fwd_wire_dtype(x.dtype) is None:
            return x
        return x.astype(jnp.float32).astype(x.dtype)

    def encode(self, x: jnp.ndarray, ef: Optional[jnp.ndarray] = None
               ) -> Tuple[Tuple[jnp.ndarray, ...], Optional[jnp.ndarray]]:
        if self.is_float:
            return (x.astype(self.jdtype),), ef
        comp = x if ef is None else x + ef
        if ef is None:
            scale = int8_scale(comp)
            return (int8_quantize(comp, scale), scale), None
        q, scale, err = int8_encode(comp)
        return (q, scale), err

    def decode(self, parts: Tuple[jnp.ndarray, ...], dtype) -> jnp.ndarray:
        if self.is_float:
            return parts[0].astype(dtype)
        q, scale = parts
        return int8_dequantize(q, scale, dtype)

    def roundtrip(self, x: jnp.ndarray, ef: Optional[jnp.ndarray] = None
                  ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
        """``decode(encode(x))`` — the wire-gridded payload (+ new EF)."""
        parts, new_ef = self.encode(x, ef)
        return self.decode(parts, x.dtype), new_ef

    def part_shapes(self, shape, dtype):
        """Shape/dtype structs of ``encode``'s parts for a payload shape
        (what a pipeline slot ring allocates per slot)."""
        if self.is_float:
            return ((tuple(shape), self.jdtype),)
        return ((tuple(shape), jnp.int8), ((), np.dtype(dtype)))

    def __repr__(self):
        return f"WireCodec({self.name!r})"


def make_codec(wire_dtype: Optional[str]) -> Optional[WireCodec]:
    """Codec for a spec's ``wire_dtype`` (None = dense, no codec)."""
    if wire_dtype is None:
        return None
    return WireCodec(wire_dtype)
