"""Production training driver: auto-resume, straggler watchdog, logging.

Fault-tolerance contract (exercised by tests/test_fault_tolerance.py):
  * the loop can be killed at ANY step and restarted with the same config;
    it resumes from the latest valid checkpoint bit-exactly (deterministic
    data + deterministic step function),
  * checkpoint writes are atomic (see ckpt/checkpoint.py), so mid-save
    crashes roll back to the previous step,
  * a per-step watchdog tracks an EWMA of step time; a step exceeding
    ``threshold x EWMA`` fires the straggler hook (at scale: trigger
    checkpoint + hot-spare re-mesh, which reuses the elastic-restore path).
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager
from repro.data.synthetic import DataConfig, SyntheticStream
# Watchdog generalized into the resilience layer (it now also monitors
# the MD block loop and serve waves); re-exported here for callers.
from repro.resilience.policy import Watchdog

__all__ = ["Watchdog", "TrainLoopConfig", "run_training"]


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    async_save: bool = False


def run_training(loop_cfg: TrainLoopConfig, program, data_cfg: DataConfig,
                 init_params_fn, batch_to_inputs=None,
                 fail_at_step: Optional[int] = None,
                 watchdog: Optional[Watchdog] = None,
                 log: Optional[Callable[[str], None]] = print):
    """Run (or resume) training; returns (params, opt_state, history).

    ``program`` is a TrainProgram from launch/steps.py.  ``fail_at_step``
    raises just after that step completes (BEFORE its checkpoint) — the
    failure-injection hook used by the fault-tolerance tests.
    """
    mgr = CheckpointManager(loop_cfg.ckpt_dir, keep=loop_cfg.keep,
                            async_save=loop_cfg.async_save)
    watchdog = watchdog or Watchdog()

    start_step = 0
    resume = mgr.latest_valid_step()
    if resume is not None:
        state_tree = {"params": program.abstract_params,
                      "opt": program.abstract_opt}
        shardings = {"params": program.param_shardings,
                     "opt": program.opt_shardings}
        restored = mgr.restore(resume, state_tree, shardings)
        params, opt_state = restored["params"], restored["opt"]
        extra = mgr.manifest(resume)["extra"]
        start_step = int(extra.get("next_step", resume))
        if log:
            log(f"[resume] step {start_step} from checkpoint {resume}")
    else:
        params = init_params_fn()
        from repro.optim import adamw
        params = jax.device_put(params, program.param_shardings)
        opt_state = jax.device_put(adamw.init_state(params),
                                   program.opt_shardings)

    stream = SyntheticStream(data_cfg, start_step=start_step)
    history = []
    try:
        for step in range(start_step, loop_cfg.total_steps):
            batch_np = stream.next()
            batch = {"tokens": jnp.asarray(batch_np)}
            if batch_to_inputs is not None:
                batch = batch_to_inputs(batch_np)
            t0 = time.time()
            params, opt_state, metrics = program.step_fn(params, opt_state,
                                                         batch)
            loss = float(metrics["loss" if "loss" in metrics else "ce"])
            # the loss read above syncs metrics only; the updated params /
            # opt state are still in flight — block so dt clocks the step
            jax.block_until_ready((params, opt_state))
            dt = time.time() - t0
            watchdog.observe(step, dt)
            history.append({"step": step, "loss": loss, "dt": dt})
            if log and step % loop_cfg.log_every == 0:
                log(f"[step {step}] loss={loss:.4f} {dt * 1e3:.0f}ms")
            done = step + 1
            if done % loop_cfg.ckpt_every == 0 or \
                    done == loop_cfg.total_steps:
                mgr.save(done, {"params": params, "opt": opt_state},
                         extra={"next_step": done,
                                "data_state": stream.state()})
            if fail_at_step is not None and done == fail_at_step:
                raise RuntimeError(f"injected failure after step {step}")
    finally:
        mgr.wait()
        stream.close()
    return params, opt_state, history
