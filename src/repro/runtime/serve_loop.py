"""Batched serving driver: prefill + lockstep decode with a request queue.

Continuous-batching-lite: requests are admitted in waves; each wave is
prefilled into the shared KV cache and decoded in lockstep (one jitted
decode_step per token across the whole batch).  Per-request stop lengths
mask finished rows (their outputs are ignored; slots recycle at the next
wave boundary).  Greedy or temperature sampling.  A per-wave deadline
(``wave_timeout_s``) turns a decode step that never completes into a
typed :class:`~repro.resilience.faults.WaveTimeout` instead of a hung
queue, and an optional :class:`~repro.resilience.policy.Watchdog`
watches per-wave wall time for stragglers.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.resilience.faults import WaveTimeout
from repro.resilience.policy import Watchdog


@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # (L,) int32
    max_new_tokens: int
    out_tokens: Optional[np.ndarray] = None
    latency_s: float = 0.0
    wave: int = -1                # which wave served it (-1 = not served)


def masked_tokens(decoded, budgets) -> int:
    """Useful work across padded rows: ``sum(min(decoded_i, budget_i))``.

    Batched programs run every row to the padded maximum — a finished or
    short-budget row still *executes* decode steps (or MD block steps),
    but only the requested budget is useful.  Throughput accounting must
    mask the padding out or tok/s (and the SimServer's replica-steps/s)
    overcounts.  Shared by :func:`throughput_stats` and
    ``SimServer`` replica-step accounting.
    """
    return int(sum(max(0, min(int(d), int(b)))
                   for d, b in zip(decoded, budgets)))


class BatchServer:
    def __init__(self, model, params, batch_size: int, max_len: int,
                 temperature: float = 0.0, seed: int = 0,
                 wave_timeout_s: Optional[float] = None,
                 watchdog: Optional[Watchdog] = None):
        self.model = model
        self.params = params
        self.B = batch_size
        self.max_len = max_len
        self.temperature = temperature
        self.wave_timeout_s = wave_timeout_s
        self.watchdog = watchdog
        self._waves = 0
        self.rng = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step, donate_argnums=(3,))

    def serve_wave(self, requests: List[Request]) -> List[Request]:
        """Serve up to B same-length-padded requests as one wave.

        Raises :class:`WaveTimeout` when the wave's decode loop exceeds
        ``wave_timeout_s`` — callers retire the wave and keep the queue
        draining rather than hanging every later request behind it."""
        assert len(requests) <= self.B
        t0 = time.time()
        B = self.B
        plen = max(r.prompt.shape[0] for r in requests)
        new_tokens = max(r.max_new_tokens for r in requests)
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(requests):
            toks[i, plen - r.prompt.shape[0]:] = r.prompt   # left-pad
        cache = self.model.init_cache(B, self.max_len)
        logits, cache = self._prefill(self.params,
                                      {"tokens": jnp.asarray(toks)}, cache)
        outs = np.zeros((B, new_tokens), np.int32)
        pos = plen - 1
        tok = self._sample(logits)
        for t in range(new_tokens):
            outs[:, t] = np.asarray(tok)[:, 0]
            pos += 1
            logits, cache = self._decode(self.params, tok,
                                         jnp.int32(pos), cache)
            tok = self._sample(logits)
            if self.wave_timeout_s is not None:
                # sync the step before reading the clock: without it the
                # deadline would be checked against dispatch time, not
                # the (possibly hung) device work
                jax.block_until_ready(tok)
                elapsed = time.time() - t0
                if elapsed > self.wave_timeout_s:
                    raise WaveTimeout(
                        f"wave exceeded {self.wave_timeout_s:.3f}s after "
                        f"{t + 1}/{new_tokens} decode steps "
                        f"({elapsed:.3f}s elapsed)")
        # the final sampled token is still in flight (outs[] reads synced
        # every earlier iteration) — block so dt covers the whole wave
        jax.block_until_ready(tok)
        dt = time.time() - t0
        if self.watchdog is not None:
            self.watchdog.observe(self._waves, dt)
        for i, r in enumerate(requests):
            r.out_tokens = outs[i, : r.max_new_tokens]
            r.latency_s = dt
            r.wave = self._waves
        self._waves += 1
        return requests

    def _sample(self, logits):
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        self.rng, sub = jax.random.split(self.rng)
        return jax.random.categorical(
            sub, logits / self.temperature, axis=-1
        ).astype(jnp.int32)[:, None]


def throughput_stats(requests: List[Request]) -> Dict[str, float]:
    """Token throughput over any mix of served requests.

    Tokens are budget-masked (:func:`masked_tokens`): padded decode
    steps past a request's ``max_new_tokens`` never count.  Wall time is
    wave-aware: requests in one wave share a wave latency (take the max
    within the wave), and the serving wall is the *sum over distinct
    waves* — the old ``max`` over all requests counted only the longest
    single wave and overstated tok/s for multi-wave request sets.
    """
    served = [r for r in requests if r.out_tokens is not None]
    tokens = masked_tokens((r.out_tokens.shape[0] for r in served),
                           (r.max_new_tokens for r in served))
    per_wave: Dict[int, float] = {}
    for r in served:
        per_wave[r.wave] = max(per_wave.get(r.wave, 0.0), r.latency_s)
    wall = sum(per_wave.values())
    return {"tokens": tokens, "wall_s": wall,
            "tok_per_s": tokens / max(wall, 1e-9)}
