"""Compatibility shims across jax versions (0.4.x .. 0.6.x).

The repo targets the modern public API (``jax.shard_map``,
``jax.sharding.AxisType``, ``jax.set_mesh``); older runtimes only ship the
experimental spellings.  Import the symbols from here so every module works
on both.
"""
from __future__ import annotations

import contextlib
import inspect

import jax

try:  # jax >= 0.5
    _shard_map = jax.shard_map
except AttributeError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_SM_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f, **kwargs):
    """``shard_map`` accepting both kwarg spellings of the replication
    check (``check_rep`` in jax<=0.5, ``check_vma`` later)."""
    if "check_vma" in kwargs and "check_vma" not in _SM_PARAMS:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    if "check_rep" in kwargs and "check_rep" not in _SM_PARAMS:
        kwargs["check_vma"] = kwargs.pop("check_rep")
    return _shard_map(f, **kwargs)


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a dict across jax versions (older
    jaxlibs return a one-element list of dicts)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}

try:  # jax >= 0.5
    from jax.sharding import AxisType

    def mesh_axis_types(n: int):
        return {"axis_types": (AxisType.Auto,) * n}
except ImportError:  # jax 0.4.x: meshes are Auto-typed implicitly
    AxisType = None

    def mesh_axis_types(n: int):
        return {}


def shard_map_norep(f, *, mesh, in_specs, out_specs):
    """shard_map with replication checking off.

    Pallas calls have no replication rule, so bodies that may invoke them
    (the halo-plan backends) disable the check.
    """
    return shard_map(f, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


def set_mesh(mesh):
    """``jax.set_mesh`` where available, else the Mesh context manager."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return contextlib.nullcontext(mesh) if mesh is None else mesh


def ensure_barrier_batching() -> bool:
    """Register a vmap batching rule for ``lax.optimization_barrier``.

    jax 0.4.x ships no batching rule for the barrier primitive, which
    blocks ``vmap`` over any barrier-pinned program — including every MD
    block body (the SimServer stacks replicas exactly that way).  The
    barrier is semantically an elementwise identity, so the rule is the
    identity on batch dims: bind the batched operands, pass the dims
    through.  Idempotent; returns False when the private primitive
    handle is unreadable (callers then know vmap-of-blocks is
    unavailable on this jax).
    """
    try:
        from jax._src.interpreters import batching
        from jax._src.lax import lax as _lax_internal
        prim = _lax_internal.optimization_barrier_p
    except (ImportError, AttributeError):  # pragma: no cover - jax drift
        return False
    if prim in batching.primitive_batchers:
        return True

    def _rule(args, dims, **params):
        return prim.bind(*args, **params), dims

    batching.primitive_batchers[prim] = _rule
    return True


def named_axes_in_scope():
    """Mesh axis names bound by enclosing shard_maps at trace time.

    Used by the ``"signal"`` halo backend: the Pallas *interpret-mode*
    remote-DMA emulation only supports a single named axis in scope
    (``dma_start_p`` discharge), so multi-axis callers fall back to the
    ppermute oracle on CPU.  Best-effort across jax versions — returns
    ``None`` when the axis env is unreadable (callers should then assume
    the conservative multi-axis case).
    """
    try:
        from jax._src import core as _core
        env = _core.get_axis_env()
        return tuple(n for n in env.axis_sizes if n is not None)
    except (ImportError, AttributeError, TypeError):
        # private-API probe: any jax version drift lands here, and the
        # documented contract is "None = assume multi-axis"
        return None
