import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
SRC = REPO / "src"


def run_dist_script(script: str, *args: str, devices: int = 8,
                    timeout: int = 900, extra_env: dict | None = None) -> str:
    """Run a tests/dist/ script in a subprocess with N virtual devices.

    The main pytest process must keep a single CPU device (smoke tests and
    benches see the real topology); multi-device checks therefore run in
    subprocesses that set XLA_FLAGS before importing jax.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = f"{SRC}:{env.get('PYTHONPATH', '')}"
    if extra_env:
        env.update(extra_env)
    proc = subprocess.run(
        [sys.executable, str(REPO / "tests" / "dist" / script), *args],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"{script} failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}"
        )
    return proc.stdout


@pytest.fixture(scope="session")
def dist():
    return run_dist_script
