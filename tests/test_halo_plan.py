"""HaloPlan: backend registry, adjoint property, custom VJP, plan stats."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.halo_plan import (
    HaloPlan,
    HaloSpec,
    available_backends,
    get_backend,
)
from repro.launch.mesh import make_mesh

BACKENDS = ("serialized", "fused", "pallas", "signal")


@pytest.fixture(scope="module")
def mesh1d():
    return make_mesh((1,), ("z",))


def _plan(backend, widths=(2,), mesh=None, **kw):
    mesh = mesh if mesh is not None else make_mesh((1,) * len(widths),
                                                   ("z", "y", "x")[:len(widths)])
    spec = HaloSpec(axis_names=("z", "y", "x")[:len(widths)],
                    widths=widths, backend=backend, **kw)
    return HaloPlan.build(spec, mesh)


# --------------------------------------------------------------------------
# spec / registry basics
# --------------------------------------------------------------------------

def test_spec_is_frozen_and_hashable():
    spec = HaloSpec(axis_names=("z",), widths=(2,),
                    wrap_shift=np.ones((1, 4)))
    assert isinstance(hash(spec), int)
    with pytest.raises(Exception):
        spec.widths = (3,)
    # wrap shift round-trips through the hashable nested-tuple form
    np.testing.assert_array_equal(np.asarray(spec.wrap_shift_array()),
                                  np.ones((1, 4), np.float32))


def test_backend_registry():
    assert set(BACKENDS) <= set(available_backends())
    with pytest.raises(ValueError, match="unknown halo backend"):
        get_backend("nvshmem-tbd")
    with pytest.raises(ValueError, match="unknown halo backend"):
        HaloPlan.build(HaloSpec(("z",), (1,), backend="nope"),
                       make_mesh((1,), ("z",)))


def test_plan_rejects_missing_mesh_axis(mesh1d):
    with pytest.raises(ValueError, match="no axis"):
        HaloPlan.build(HaloSpec(("q",), (1,)), mesh1d)


def test_extended_shape(mesh1d):
    plan = _plan("fused", widths=(2,), mesh=mesh1d)
    assert plan.extended_shape((6, 4)) == (8, 4)


# --------------------------------------------------------------------------
# adjoint property: <fwd(x), y> == <x, rev(y)> for every backend
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("width,n,f", [(1, 5, 3), (2, 6, 4), (3, 9, 2)])
def test_adjoint_dot_product(backend, width, n, f, mesh1d):
    plan = _plan(backend, widths=(width,), mesh=mesh1d)
    rng = np.random.RandomState(width * 10 + n)
    x = jnp.asarray(rng.randn(n, f).astype(np.float32))
    y = jnp.asarray(rng.randn(n + width, f).astype(np.float32))
    lhs = float(jnp.vdot(plan.fwd(x), y))
    rhs = float(jnp.vdot(x, plan.rev(y)))
    assert abs(lhs - rhs) <= 1e-5 * max(abs(lhs), 1.0)


@pytest.mark.parametrize("backend", BACKENDS)
def test_backends_bitwise_identical_fwd(backend, mesh1d):
    """Single-device periodic self-exchange: every backend must reproduce
    the serialized bytes exactly (the multi-device version runs in
    tests/dist/check_halo_plan.py on an 8-device mesh)."""
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(6, 5).astype(np.float32))
    shift = np.zeros((1, 5)); shift[0, 0] = 17.0
    ref = np.asarray(_plan("serialized", widths=(2,), mesh=mesh1d,
                           wrap_shift=shift).fwd(x))
    got = np.asarray(_plan(backend, widths=(2,), mesh=mesh1d,
                           wrap_shift=shift).fwd(x))
    np.testing.assert_array_equal(got, ref)


# --------------------------------------------------------------------------
# custom VJP: grad through plan.exchange is the plan's reverse path
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_grad_through_exchange_matches_serialized_autodiff(backend, mesh1d):
    plan = _plan(backend, widths=(2,), mesh=mesh1d)
    ser = _plan("serialized", widths=(2,), mesh=mesh1d)
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(6, 4).astype(np.float32))
    y = jnp.asarray(rng.randn(8, 4).astype(np.float32))

    g_plan = jax.grad(lambda a: jnp.sum(plan.exchange(a) * y))(x)
    # reference: plain autodiff (XLA transpose) of the serialized forward
    g_ref = jax.grad(lambda a: jnp.sum(ser.fwd(a) * y))(x)
    np.testing.assert_allclose(np.asarray(g_plan), np.asarray(g_ref),
                               atol=1e-6)


def test_exchange_vjp_is_rev(mesh1d):
    """The VJP cotangent equals plan.rev(g) exactly — the fused force-return
    path, not XLA's transposed forward."""
    plan = _plan("fused", widths=(2,), mesh=mesh1d)
    rng = np.random.RandomState(2)
    x = jnp.asarray(rng.randn(6, 4).astype(np.float32))
    g = jnp.asarray(rng.randn(8, 4).astype(np.float32))
    _, vjp = jax.vjp(plan.exchange, x)
    np.testing.assert_array_equal(np.asarray(vjp(g)[0]),
                                  np.asarray(plan.rev(g)))


def test_grad_with_wrap_shift_unaffected(mesh1d):
    """Wrap shifts are additive constants: they move values, not gradients."""
    shift = np.zeros((1, 4)); shift[0, 0] = 123.0
    plan = _plan("fused", widths=(2,), mesh=mesh1d, wrap_shift=shift)
    plain = _plan("fused", widths=(2,), mesh=mesh1d)
    rng = np.random.RandomState(3)
    x = jnp.asarray(rng.randn(6, 4).astype(np.float32))
    y = jnp.asarray(rng.randn(8, 4).astype(np.float32))
    g1 = jax.grad(lambda a: jnp.sum(plan.exchange(a) * y))(x)
    g2 = jax.grad(lambda a: jnp.sum(plain.exchange(a) * y))(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-6)


# --------------------------------------------------------------------------
# stats: canonical single total, no duplicate aliases
# --------------------------------------------------------------------------

def test_plan_stats_canonical_keys(mesh1d):
    plan = _plan("fused", widths=(2,), mesh=mesh1d, dtype="float32",
                 feature_elems=4)
    stats = plan.stats((6,))
    assert stats["total_bytes"] == 2 * 4 * 4        # w * feat * itemsize
    assert "serialized_total_bytes" not in stats     # legacy duplicate gone
    assert "fused_total_bytes" not in stats
    assert stats["serialized_critical_bytes"] == stats["total_bytes"]
    # cached: same dict object for same key
    assert plan.stats((6,)) is stats


def test_plan_stats_index_and_useful_bytes(mesh1d):
    """The (K, 2)-style int32 index exchange and occupancy-adjusted
    useful bytes are reported alongside (never inside) the canonical
    float payload accounting."""
    plan = _plan("fused", widths=(2,), mesh=mesh1d, dtype="float32",
                 feature_elems=4)
    stats = plan.stats((6,))
    assert stats["bytes_index"] == 0 and stats["useful_bytes"] is None
    k = 8                                 # capacity: feature_elems = 4 * K
    plan2 = _plan("fused", widths=(2,), mesh=mesh1d, dtype="float32",
                  feature_elems=4 * k)
    s = plan2.stats((6,), index_elems=2 * k, index_itemsize=4,
                    occupancy=0.45)
    cells = s["total_bytes"] // (4 * k * 4)
    assert s["bytes_index"] == cells * 2 * k * 4
    assert s["useful_bytes"] == round(s["total_bytes"] * 0.45)
    assert s["occupancy"] == 0.45
    # total_bytes itself is unchanged by the side-channel accounting
    assert s["total_bytes"] == plan2.stats((6,))["total_bytes"]


def test_engine_halo_stats_accounts_cell_i_exchange():
    from repro.core.md import MDEngine, make_grappa_like
    from repro.launch.mesh import make_mesh

    eng = MDEngine(make_grappa_like(300, seed=11),
                   make_mesh((1, 1, 1), ("z", "y", "x")))
    s = eng.halo_stats()
    K = eng.layout.capacity
    cells = s["total_bytes"] // (4 * K * 4)
    assert s["bytes_index"] == cells * 2 * K * 4      # (K, 2) int32
    gz, gy, gx = eng.layout.global_cells
    occ = eng.system.n_atoms / (gz * gy * gx * K)
    assert abs(s["occupancy"] - occ) < 1e-12
    assert s["useful_bytes"] == round(s["total_bytes"] * occ)


def test_plan_stats_cells_first_class_mixed_itemsizes(mesh1d):
    """Regression: byte fields must scale from the first-class
    ``exchanged_cells`` volume, never back-derive it from
    ``total_bytes`` — with a float64 payload and an int32 index
    side-channel (mixed itemsizes) the back-derivation overcounted
    the index bytes 2x, and with ``feature_elems=0`` (index-only
    accounting) it collapsed the volume to zero."""
    plan = _plan("fused", widths=(2,), mesh=mesh1d, dtype="float64",
                 feature_elems=4)
    s = plan.stats((6,), index_elems=2, index_itemsize=4)
    assert s["exchanged_cells"] == 2     # width-2 halo on a 1-shard dim
    assert s["total_bytes"] == 2 * 4 * 8
    assert s["bytes_index"] == 2 * 2 * 4  # cells * elems * int32, NOT /8
    # index-only accounting: zero payload bytes, nonzero index bytes
    plan0 = _plan("fused", widths=(2,), mesh=mesh1d, dtype="float64",
                  feature_elems=0)
    s0 = plan0.stats((6,), index_elems=2, index_itemsize=4)
    assert s0["total_bytes"] == 0
    assert s0["exchanged_cells"] == 2
    assert s0["bytes_index"] == 2 * 2 * 4


def test_plan_stats_wire_direction_aware(mesh1d):
    """Wire accounting is per-direction: the coordinate (fwd) leg sits
    at the float32 floor, the force return (rev) at the named format,
    and ``wire_reduction`` compares both legs against dense."""
    plan = _plan("fused", widths=(2,), mesh=mesh1d, dtype="float64",
                 feature_elems=4, wire_dtype="bfloat16")
    s = plan.stats((6,))
    cells = s["exchanged_cells"]
    assert s["wire_itemsize_fwd"] == 4 and s["wire_itemsize_rev"] == 2
    assert s["wire_bytes_fwd"] == cells * 4 * 4
    assert s["wire_bytes_rev"] == cells * 4 * 2
    assert s["wire_bytes"] == s["wire_bytes_fwd"] + s["wire_bytes_rev"]
    assert s["wire_reduction"] == pytest.approx(2 * 8 / (4 + 2))
    assert s["latency_wire"]["wire_speedup_fused"] > 1.0
    # f32 payload: fwd rides dense (at the floor), rev still compresses
    p32 = _plan("fused", widths=(2,), mesh=mesh1d, dtype="float32",
                feature_elems=4, wire_dtype="bfloat16")
    s32 = p32.stats((6,))
    assert s32["wire_itemsize_fwd"] == 4 and s32["wire_itemsize_rev"] == 2
    assert s32["wire_reduction"] == pytest.approx(2 * 4 / (4 + 2))
    # int8_ef adds one 4-byte scale per serialized message on the rev leg
    p8 = _plan("fused", widths=(2,), mesh=mesh1d, dtype="float64",
               feature_elems=4, wire_dtype="int8_ef")
    s8 = p8.stats((6,))
    n_msgs = len([b for b in s8["serialized_pulse_bytes"] if b > 0])
    assert s8["wire_bytes_rev"] == cells * 4 * 1 + 4 * n_msgs
    # dense plans carry no wire block beyond the null fields
    sd = _plan("fused", widths=(2,), mesh=mesh1d, dtype="float64",
               feature_elems=4).stats((6,))
    assert sd["wire_dtype"] is None
    assert sd["wire_reduction"] == 1.0
    assert "latency_wire" not in sd


def test_legacy_exchange_stats_shim_warns():
    from repro.core.halo import exchange_stats
    from repro.core.schedule import make_schedule
    sched = make_schedule(("z", "y"), (1, 1))
    with pytest.warns(DeprecationWarning):
        legacy = exchange_stats(sched, (8, 8), itemsize=4)
    assert legacy["serialized_total_bytes"] == legacy["total_bytes"]
    assert legacy["fused_total_bytes"] == legacy["total_bytes"]


# --------------------------------------------------------------------------
# multi-device: bitwise backend equivalence + adjoint on an 8-device mesh
# --------------------------------------------------------------------------

def test_multi_device_backend_equivalence(dist):
    """Runs in a subprocess with 8 virtual CPU devices (2x2x2 DD mesh);
    part of tier-1 (not dist-marked) because it is the acceptance bar for
    the plan API."""
    out = dist("check_halo_plan.py")
    assert "check_halo_plan OK" in out
