"""Checkpoint manager: atomicity, integrity, keep-N, resharding restore."""
import json
import os
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import CheckpointManager


def tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32),
                  "d": jnp.full((2, 2), 3.5)}}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    t = tree()
    mgr.save(10, t, extra={"next_step": 10})
    assert mgr.latest_valid_step() == 10
    out = mgr.restore(10, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert mgr.manifest(10)["extra"]["next_step"] == 10


def test_keep_n_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    t = tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    assert mgr.all_steps() == [3, 4]


def test_corruption_detected_and_skipped(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    t = tree()
    mgr.save(1, t)
    mgr.save(2, t)
    # corrupt the newest shard
    shard = Path(tmp_path) / "step_0000000002" / "shard_0.npz"
    data = bytearray(shard.read_bytes())
    data[len(data) // 2] ^= 0xFF
    shard.write_bytes(bytes(data))
    assert mgr.latest_valid_step() == 1


def test_restore_latest_skips_corrupt_shards(tmp_path):
    """The resilience rollback path: with the newest shard truncated and
    the next bit-flipped, ``restore_latest`` must fall back to the last
    VERIFIED step and return its (step, tree) — never corrupt data."""
    mgr = CheckpointManager(tmp_path, keep=3)
    t1 = tree()
    t2 = jax.tree.map(lambda a: a + 1, t1)
    t3 = jax.tree.map(lambda a: a + 2, t1)
    mgr.save(1, t1)
    mgr.save(2, t2)
    mgr.save(3, t3)

    s3 = Path(tmp_path) / "step_0000000003" / "shard_0.npz"
    s3.write_bytes(s3.read_bytes()[: s3.stat().st_size // 2])  # truncate
    s2 = Path(tmp_path) / "step_0000000002" / "shard_0.npz"
    data = bytearray(s2.read_bytes())
    data[len(data) // 3] ^= 0x01                               # bit-flip
    s2.write_bytes(bytes(data))

    res = mgr.restore_latest(t1)
    assert res is not None
    step, out = res
    assert step == 1
    for a, b in zip(jax.tree.leaves(t1), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # every shard corrupt -> None (the runner raises its typed error)
    s1 = Path(tmp_path) / "step_0000000001" / "shard_0.npz"
    s1.write_bytes(b"")
    assert mgr.restore_latest(t1) is None


def test_partial_write_is_invisible(tmp_path):
    """A stale temp dir (crash mid-save) must not count as a checkpoint."""
    mgr = CheckpointManager(tmp_path, keep=3)
    t = tree()
    mgr.save(5, t)
    (Path(tmp_path) / ".tmp_step_0000000006_999").mkdir()
    assert mgr.latest_valid_step() == 5
    assert mgr.all_steps() == [5]


def test_restore_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, tree())
    bad = {"a": jnp.zeros((2, 2)), "b": {"c": jnp.ones((5,), jnp.int32),
                                         "d": jnp.zeros((2, 2))}}
    with pytest.raises(ValueError, match="shape mismatch"):
        mgr.restore(1, bad)


@pytest.mark.dist
def test_elastic_reshard_between_meshes(dist):
    out = dist("check_elastic.py")
    assert "check_elastic OK" in out
