"""Observability subsystem: registry, tracing neutrality, export, gate.

Four contracts:

1. the metrics registry's instruments/records are typed, JSON-safe and
   round-trip through JSONL;
2. every engine layer that returns a stats dict also publishes it as a
   structured record with a stable schema (the emitter tests);
3. tracing is barrier-neutral — a tracer-enabled pipeline/engine cell is
   bitwise-identical to its untraced twin across the full backend x mode
   x depth matrix, the ``obs/*`` outputs being strictly additive;
4. the Perfetto exporter is deterministic against a golden fixture and
   the perf gate separates exact / rel-tol / timing drift classes.
"""
import functools
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map_norep
from repro.core.halo_plan import HaloPlan, HaloSpec
from repro.core.pipeline import SignalLedger, StepFns, StepPipeline
from repro.launch.mesh import make_mesh
from repro.obs import (
    DEFAULT_GATE,
    KEY_FIELDS,
    SCHEMA_VERSION,
    MetricsRegistry,
    NULL_TRACER,
    PhaseTracer,
    cell_key,
    compare_bench,
    default_registry,
    export_trace,
    is_obs_metric,
    iter_kind,
    jsonsafe,
    load_jsonl,
    span,
    strip_obs_metrics,
    time_fn,
    to_trace,
)
from repro.obs.__main__ import main as obs_main
from pathlib import Path

FIXTURES = Path(__file__).parent / "fixtures" / "obs"


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

def test_registry_instruments_typed_and_get_or_create():
    reg = MetricsRegistry()
    c = reg.counter("md/steps")
    assert reg.counter("md/steps") is c
    c.inc(3)
    c.inc()
    with pytest.raises(ValueError, match="cannot decrease"):
        c.inc(-1)
    reg.gauge("md/occ").set(0.75)
    h = reg.histogram("span/x")
    for v in (3.0, 1.0, 2.0):
        h.observe(v)
    m = reg.metrics()
    assert m["md/steps"] == 4
    assert m["md/occ"] == 0.75
    assert m["span/x"]["count"] == 3
    assert m["span/x"]["min"] == 1.0 and m["span/x"]["max"] == 3.0
    assert m["span/x"]["p50"] == 2.0
    with pytest.raises(ValueError, match="is a counter"):
        reg.gauge("md/steps")


def test_registry_emit_is_jsonsafe_and_ordered():
    reg = MetricsRegistry()
    reg.emit("halo_stats", backend="signal",
             data={"bytes": np.int64(4096), "occ": np.float32(0.5),
                   "dd": (2, 2, 2)})
    reg.emit("pair_stats", ratio=jnp.float32(3.0))
    kinds = [r["kind"] for r in reg.records]
    assert kinds == ["halo_stats", "pair_stats"]
    rec = reg.records[0]
    assert rec["data"] == {"bytes": 4096, "occ": 0.5, "dd": [2, 2, 2]}
    assert isinstance(rec["t"], float)
    json.dumps(reg.records)          # everything emitted is serializable


def test_registry_snapshot_and_jsonl_roundtrip(tmp_path):
    reg = MetricsRegistry()
    reg.counter("md/blocks").inc(2)
    reg.gauge("md/rows").set(112)
    reg.snapshot(label="md/simulate", n_steps=8)
    p = tmp_path / "m.jsonl"
    assert reg.to_jsonl(p) == 1
    back = load_jsonl(p)
    assert back == reg.records
    snap = iter_kind(back, "snapshot")[0]
    assert snap["label"] == "md/simulate" and snap["n_steps"] == 8
    assert snap["metrics"]["md/blocks"] == {"kind": "counter", "value": 2}
    assert snap["metrics"]["md/rows"] == {"kind": "gauge", "value": 112.0}


def test_default_registry_is_a_singleton():
    assert default_registry() is default_registry()


def test_jsonsafe_falls_back_to_repr():
    class Opaque:
        def __repr__(self):
            return "<opaque>"
    assert jsonsafe({"x": Opaque()}) == {"x": "<opaque>"}


# --------------------------------------------------------------------------
# host-side spans / timers
# --------------------------------------------------------------------------

def test_span_records_duration_and_syncs():
    reg = MetricsRegistry()
    with span("work", reg, steps=4) as sp:
        y = sp.sync(jnp.arange(8) * 2)
    assert sp.dur > 0.0
    assert int(y[-1]) == 14
    rec = iter_kind(reg.records, "span")[0]
    assert rec["name"] == "work" and rec["steps"] == 4
    assert rec["dur"] == sp.dur
    assert reg.metrics()["span/work"]["count"] == 1


def test_time_fn_medians_and_emits():
    reg = MetricsRegistry()
    res = time_fn(lambda: jnp.ones(4).sum(), warmup=1, iters=5,
                  name="toy", registry=reg)
    assert len(res.times) == 5
    assert res.best <= res.median <= max(res.times)
    rec = iter_kind(reg.records, "timing")[0]
    assert rec["name"] == "toy" and rec["iters"] == 5


# --------------------------------------------------------------------------
# engine emitters: every stats dict has a structured twin
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _md_run(trace: bool):
    from repro.core.md import MDEngine, make_grappa_like

    reg = MetricsRegistry()
    eng = MDEngine(make_grappa_like(200, seed=5),
                   make_mesh((1, 1, 1), ("z", "y", "x")),
                   HaloSpec(("z", "y", "x"), (1, 1, 1), backend="signal"),
                   pipeline="double_buffer", pipeline_depth=3,
                   force_backend="sparse", nstprune=4,
                   obs=reg, trace=trace)
    (cf, ci), metrics, _ = eng.simulate(12)
    eng.halo_stats()
    eng.pair_stats()
    eng.overlap_stats()
    return reg, np.asarray(cf), {k: np.asarray(v)
                                 for k, v in metrics.items()}


def test_engine_publishes_structured_records():
    reg, _, _ = _md_run(True)
    kinds = {r["kind"] for r in reg.records}
    assert {"engine_build", "sched_update", "span", "step_counters",
            "snapshot", "halo_stats", "pair_stats",
            "overlap_model"} <= kinds

    build = iter_kind(reg.records, "engine_build")[0]
    assert build["backend"] == "signal"
    assert build["pipeline"] == "double_buffer"
    assert build["pipeline_depth"] == 3 and build["nstprune"] == 4

    halo = iter_kind(reg.records, "halo_stats")[-1]
    assert halo["critical_path"] in ("serialized", "fused")
    assert {"latency", "overlap"} <= set(halo["data"])
    ov = halo["data"]["overlap"]
    assert ov["depth"] == 3 and ov["pipeline"] == "double_buffer"

    pair = iter_kind(reg.records, "pair_stats")[-1]
    assert pair["data"]["prune_ratio"] >= 1.0

    sched = iter_kind(reg.records, "sched_update")[0]
    assert sched["outer_rows"] > 0

    steps = iter_kind(reg.records, "step_counters")[-1]
    assert all(k.startswith("obs/") for k in steps["data"])
    assert all(len(v) == 12 for v in steps["data"].values())

    snap = iter_kind(reg.records, "snapshot")[-1]
    vals = snap["metrics"]
    assert vals["md/steps"]["value"] == 12
    assert "span/block_dispatch" in vals
    # pair_stats() runs after the simulate snapshot: gauge is live-only
    assert reg.metrics()["md/prune_ratio"] >= 1.0
    json.dumps(reg.records)


def test_ledger_summary_publishes_gauges():
    led = SignalLedger(depth=2, n_pulses=3)
    st = led.init()
    st = led.release(st, "fwd", 0)
    st = led.acquire(st, "fwd", 0)
    reg = MetricsRegistry()
    out = led.summary(st, registry=reg)
    assert out["fwd"]["released"] == 3
    m = reg.metrics()
    assert m["ledger/fwd_released"] == 3
    assert m["ledger/in_flight"] == 0
    rec = iter_kind(reg.records, "ledger_summary")[0]
    assert rec["data"] == out


# --------------------------------------------------------------------------
# tracing neutrality: obs on == obs off, bitwise, across the matrix
# --------------------------------------------------------------------------

TRACE_MATRIX = [(b, m, d)
                for b in ("serialized", "fused", "pallas", "signal")
                for m in ("off", "double_buffer")
                for d in (2, 3, 4)]


def _toy_fns():
    def begin(state, f, ctx):
        state = state + 0.1 * f
        return state, state.sum(), state

    def force(ext, ctx):
        F = jnp.tanh(ext) * ctx
        return F, {"pe": jnp.sum(F)}

    def finish(state, aux, f, ctx):
        state = state + 0.01 * f + 1e-3 * aux
        return state, f, {"ke": jnp.sum(state)}

    return StepFns(begin=begin, force=force, finish=finish)


@functools.lru_cache(maxsize=None)
def _trace_cell(backend, mode, depth, traced, n_steps=8):
    if mode == "off":
        depth = 2
    mesh = make_mesh((1,), ("z",))
    plan = HaloPlan.build(HaloSpec(("z",), (1,), backend=backend), mesh)
    tracer = PhaseTracer(enabled=True) if traced else NULL_TRACER
    pipe = StepPipeline.build(plan, _toy_fns(), mode=mode, depth=depth,
                              tracer=tracer)
    x0 = jnp.asarray(np.random.RandomState(0).randn(6, 4)
                     .astype(np.float32))

    def run(state, f):
        return pipe.run_local(state, f, n_steps, jnp.float32(0.5))

    fn = shard_map_norep(run, mesh=mesh, in_specs=(P(), P()),
                         out_specs=(P(), P(), P(), P()))
    state, f, metrics, led = jax.jit(fn)(x0, jnp.zeros_like(x0))
    return (np.asarray(state), np.asarray(f),
            {k: np.asarray(v) for k, v in metrics.items()},
            pipe.ledger.summary(led))


@pytest.mark.parametrize("backend,mode,depth", TRACE_MATRIX,
                         ids=[f"{b}-{m}-d{d}" for b, m, d in TRACE_MATRIX])
def test_tracing_is_bitwise_neutral(backend, mode, depth):
    """A tracer-enabled cell must equal its untraced twin bit for bit;
    the obs/* outputs are additive (full-length per-step counters)."""
    ref = _trace_cell(backend, mode, depth, False)
    got = _trace_cell(backend, mode, depth, True)
    np.testing.assert_array_equal(got[0], ref[0])
    np.testing.assert_array_equal(got[1], ref[1])
    assert strip_obs_metrics(got[2]).keys() == ref[2].keys()
    for k in ref[2]:
        np.testing.assert_array_equal(got[2][k], ref[2][k])
    obs_keys = [k for k in got[2] if is_obs_metric(k)]
    assert sorted(obs_keys) == ["obs/acquired", "obs/clobbers",
                                "obs/in_flight", "obs/released"]
    for k in obs_keys:
        assert got[2][k].shape[0] == 8
        assert got[2][k].dtype == np.int32
    assert got[3]["consistent"] and got[3]["clobbers"] == 0
    assert int(got[2]["obs/clobbers"][-1]) == 0


def test_md_engine_tracing_is_bitwise_neutral():
    """The full MD engine (signal + deep window + rolling prune), traced
    vs untraced: identical trajectory and physics metrics."""
    _, cf_ref, m_ref = _md_run(False)
    _, cf, m = _md_run(True)
    np.testing.assert_array_equal(cf, cf_ref)
    assert strip_obs_metrics(m).keys() == m_ref.keys()
    for k in m_ref:
        np.testing.assert_array_equal(m[k], m_ref[k])
    obs = {k: v for k, v in m.items() if is_obs_metric(k)}
    assert obs and all(v.shape[0] == 12 for v in obs.values())


# --------------------------------------------------------------------------
# Perfetto export (golden file)
# --------------------------------------------------------------------------

def test_perfetto_export_matches_golden(tmp_path):
    out = tmp_path / "trace.json"
    trace = export_trace(FIXTURES / "sample.jsonl", out)
    golden = json.loads((FIXTURES / "trace_golden.json").read_text())
    assert json.loads(out.read_text()) == golden
    assert trace == golden


def test_perfetto_trace_structure():
    trace = to_trace(load_jsonl(FIXTURES / "sample.jsonl"))
    evs = trace["traceEvents"]
    assert sorted({e["pid"] for e in evs}) == [0, 1]   # measured+predicted
    for e in evs:
        assert e["ph"] in ("M", "X", "C")
        if e["ph"] == "X":
            assert e["dur"] > 0 and e["ts"] >= 0
    names = {e["name"] for e in evs if e["ph"] == "X" and e["pid"] == 1}
    assert {"fwd halo", "rev halo", "force + integrate",
            "overlapped halo"} <= names
    # 8 recorded steps drive the predicted lane, not the default
    assert sum(1 for e in evs
               if e["ph"] == "X" and e["name"] == "fwd halo") == 8
    counters = {e["name"] for e in evs if e["ph"] == "C" and e["pid"] == 1}
    assert {"obs/in_flight", "obs/clobbers"} <= counters
    assert trace["otherData"]["backend"] == "signal"


def test_perfetto_export_from_live_registry(tmp_path):
    reg, _, _ = _md_run(True)
    p = tmp_path / "live.jsonl"
    reg.to_jsonl(p)
    trace = export_trace(p, tmp_path / "trace.json")
    evs = trace["traceEvents"]
    assert sorted({e["pid"] for e in evs}) == [0, 1]
    assert any(e["ph"] == "X" and e["pid"] == 0 for e in evs)
    json.dumps(trace)


# --------------------------------------------------------------------------
# perf-trajectory gate
# --------------------------------------------------------------------------

def _bench(**over):
    cell = {"mode": "signal", "pipeline": "double_buffer",
            "pipeline_depth": 3, "devices": 1, "n_atoms": 600,
            "force_backend": "sparse", "nstprune": 4,
            "exposed_phases": 2.0, "overlapped_bytes": 4096,
            "exchanged_bytes": 6144, "halo_total_bytes": 8192,
            "dd": [1, 1, 1], "prune_ratio": 3.5,
            "evaluated_slot_pairs_per_step": 1000,
            "modeled_speedup": 2.5, "ms_per_step": 10.0,
            "ms_force_pass": 6.0}
    cell.update(over)
    return {"suite": "pipeline", "schema_version": SCHEMA_VERSION,
            "gate": DEFAULT_GATE, "cells": [cell]}


def test_gate_passes_identical_and_jittered_runs():
    base = _bench()
    assert compare_bench(base, base) == []
    # timing jitter inside the factor + tiny float drift: still green
    cur = _bench(ms_per_step=19.0, prune_ratio=3.51)
    assert compare_bench(base, cur) == []
    # timing *improvement* never fails (upper bound only)
    assert compare_bench(base, _bench(ms_per_step=0.1)) == []


def test_gate_fails_on_semantic_drift():
    base = _bench()
    probs = compare_bench(base, _bench(exposed_phases=4.0))
    assert len(probs) == 1 and "exposed_phases" in probs[0]
    assert "exact" in probs[0]
    probs = compare_bench(base, _bench(prune_ratio=5.0))
    assert len(probs) == 1 and "prune_ratio" in probs[0]
    probs = compare_bench(base, _bench(ms_per_step=150.0))
    assert len(probs) == 1 and "regression" in probs[0]


def test_gate_fails_on_cell_and_schema_mismatch():
    base = _bench()
    probs = compare_bench(base, _bench(pipeline_depth=4))
    assert any("missing from current" in p for p in probs)
    assert any("not in baseline" in p for p in probs)
    cur = dict(base, schema_version=SCHEMA_VERSION + 1)
    probs = compare_bench(base, cur)
    assert probs == [f"schema_version drift: baseline {SCHEMA_VERSION} "
                     f"vs current {SCHEMA_VERSION + 1}"]


def test_cell_key_covers_identity_fields():
    assert len(cell_key(_bench()["cells"][0])) == len(KEY_FIELDS)


def test_checked_in_baseline_gates_itself():
    """The committed BENCH_pipeline.json must be self-consistent (schema
    version, unique cell keys, green against itself)."""
    path = Path(__file__).parents[1] / "results" / "BENCH_pipeline.json"
    doc = json.loads(path.read_text())
    assert doc["schema_version"] == SCHEMA_VERSION
    assert doc["cells"]
    assert compare_bench(doc, doc) == []


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def test_cli_export_default_subcommand(tmp_path, capsys):
    out = tmp_path / "t.json"
    rc = obs_main([str(FIXTURES / "sample.jsonl"), "--out", str(out)])
    assert rc == 0
    assert "wrote" in capsys.readouterr().out
    assert json.loads(out.read_text())["traceEvents"]


def test_cli_gate_exit_codes(tmp_path, capsys):
    base = tmp_path / "base.json"
    good = tmp_path / "good.json"
    bad = tmp_path / "bad.json"
    base.write_text(json.dumps(_bench()))
    good.write_text(json.dumps(_bench(ms_per_step=12.0)))
    bad.write_text(json.dumps(_bench(overlapped_bytes=1)))
    assert obs_main(["gate", "--baseline", str(base),
                     "--current", str(good)]) == 0
    assert "0 finding(s)" in capsys.readouterr().out
    assert obs_main(["gate", "--baseline", str(base),
                     "--current", str(bad)]) == 1
    assert "overlapped_bytes" in capsys.readouterr().out
