"""Unit coverage for the wire-format codec seam (repro.core.wire).

The int8 scale/quantize helpers are shared by the compressed-halo path
and the DCN gradient compressor (repro.optim.compression) — one
implementation, both wires — so the nonfinite-hardening regressions
here exercise BOTH call sites: a NaN element must corrupt at most its
own slot, never the whole tensor's dequant through a poisoned
``max(|g|)`` scale, and zero tensors must round-trip to zero instead
of dividing by zero.
"""
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.wire import (
    DENSE_F32_DRIFT_BOUND,
    MEASURED_DRIFT,
    WIRE_DTYPES,
    WIRE_ITEMSIZE,
    WireCodec,
    WireDriftError,
    gate_wire_config,
    int8_dequantize,
    int8_encode,
    int8_quantize,
    int8_scale,
    make_codec,
)


# --------------------------------------------------------------------------
# int8 helpers: nonfinite hardening (shared by halo wire + optim path)
# --------------------------------------------------------------------------

def test_int8_scale_ignores_nonfinite():
    x = jnp.asarray([1.0, -3.0, np.nan, np.inf, 2.0], jnp.float32)
    s = float(int8_scale(x))
    assert abs(s - 3.0 / 127.0) < 1e-6      # max over FINITE entries only
    clean = jnp.asarray([1.0, -3.0, 0.0, 0.0, 2.0], jnp.float32)
    assert float(int8_scale(clean)) == pytest.approx(s)


def test_int8_quantize_nan_corrupts_only_its_slot():
    x = jnp.asarray([1.0, np.nan, -2.0, np.inf], jnp.float32)
    q, scale, err = int8_encode(x)
    deq = np.asarray(int8_dequantize(q, scale))
    assert np.all(np.isfinite(deq))
    assert deq[1] == 0.0 and deq[3] == 0.0   # nonfinite slots -> 0
    assert abs(deq[0] - 1.0) < 0.05 and abs(deq[2] + 2.0) < 0.05
    assert np.all(np.isfinite(np.asarray(err)))


def test_int8_zero_tensor_roundtrips_to_zero():
    x = jnp.zeros((7,), jnp.float32)
    q, scale, err = int8_encode(x)
    assert float(scale) > 0                  # epsilon floor, no div-by-0
    np.testing.assert_array_equal(np.asarray(q), 0)
    np.testing.assert_array_equal(np.asarray(int8_dequantize(q, scale)), 0.0)
    np.testing.assert_array_equal(np.asarray(err), 0.0)


def test_int8_all_nonfinite_tensor():
    x = jnp.full((4,), jnp.nan, jnp.float32)
    q, scale, _ = int8_encode(x)
    np.testing.assert_array_equal(
        np.asarray(int8_dequantize(q, scale)), 0.0)


@pytest.mark.parametrize("bad", [np.nan, np.inf, -np.inf])
def test_compression_call_site_survives_nonfinite(bad):
    """The DCN gradient compressor (the other consumer of the shared
    helpers) must reduce a tensor containing a nonfinite element to a
    finite mean — previously one NaN poisoned every element."""
    from repro.compat import shard_map_norep
    from repro.launch.mesh import make_mesh
    from repro.optim.compression import compressed_pod_mean, ef_init
    from jax.sharding import PartitionSpec as P

    mesh = make_mesh((1,), ("pod",))
    g = {"w": jnp.asarray([1.0, bad, -2.0, 0.5], jnp.float32)}
    ef = ef_init(g)

    def run(gw, efw):
        out, new_ef = compressed_pod_mean({"w": gw}, {"w": efw}, "int8",
                                          axis="pod")
        return out["w"], new_ef["w"]

    out, new_ef = shard_map_norep(run, mesh=mesh, in_specs=(P(), P()),
                                  out_specs=(P(), P()))(g["w"], ef["w"])
    out = np.asarray(out)
    assert np.all(np.isfinite(out))
    assert abs(out[0] - 1.0) < 0.05 and abs(out[2] + 2.0) < 0.05
    assert np.all(np.isfinite(np.asarray(new_ef)))


def test_compression_zero_grads():
    from repro.compat import shard_map_norep
    from repro.launch.mesh import make_mesh
    from repro.optim.compression import compressed_pod_mean, ef_init
    from jax.sharding import PartitionSpec as P

    mesh = make_mesh((1,), ("pod",))
    g = jnp.zeros((5,), jnp.float32)

    def run(gw, efw):
        out, _ = compressed_pod_mean({"w": gw}, {"w": efw}, "int8",
                                     axis="pod")
        return out["w"]

    out = shard_map_norep(run, mesh=mesh, in_specs=(P(), P()),
                          out_specs=P())(g, g)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


# --------------------------------------------------------------------------
# codec semantics
# --------------------------------------------------------------------------

def test_codec_fp_roundtrip_is_cast():
    c = WireCodec("bfloat16")
    x = jnp.asarray(np.random.RandomState(0).randn(8), jnp.float32)
    y, ef = c.roundtrip(x)
    assert ef is None
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(x.astype(jnp.bfloat16)
                                  .astype(jnp.float32)))


def test_codec_int8_ef_error_feedback_reduces_bias():
    """Accumulated mean of EF round-trips converges to the input; the
    same accumulation WITHOUT feedback keeps a constant bias."""
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(64), jnp.float32)
    c = make_codec("int8_ef")
    acc_ef = np.zeros(64)
    ef = jnp.zeros_like(x)
    plain = make_codec("int8")
    acc_plain = np.zeros(64)
    n = 64
    for _ in range(n):
        y, ef = c.roundtrip(x, ef)
        acc_ef += np.asarray(y) / n
        acc_plain += np.asarray(plain.roundtrip(x)[0]) / n
    err_ef = np.abs(acc_ef - np.asarray(x)).max()
    err_plain = np.abs(acc_plain - np.asarray(x)).max()
    assert err_ef < 0.25 * err_plain, (err_ef, err_plain)


def test_codec_fwd_floor():
    c = WireCodec("int8_ef")            # named format is rev-only
    assert c.fwd_wire_dtype(np.dtype("float64")) == "float32"
    assert c.fwd_wire_dtype(np.dtype("float32")) is None
    assert c.fwd_itemsize(np.dtype("float64")) == 4
    assert c.fwd_itemsize(np.dtype("float32")) == 4
    assert c.fwd_itemsize(np.dtype("float16")) == 2
    old_x64 = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        x64 = jnp.asarray([1 + 1e-12], jnp.float64)
        assert x64.dtype == jnp.float64          # not vacuously f32
        got = c.fwd_roundtrip(x64)
        assert float(got[0]) == float(np.float32(1 + 1e-12))
        assert float(got[0]) != float(x64[0])    # the cast actually bites
    finally:
        jax.config.update("jax_enable_x64", old_x64)
    x32 = jnp.asarray([1.25], jnp.float32)
    assert c.fwd_roundtrip(x32) is x32  # identity at/below the floor


def test_codec_part_shapes_match_encode():
    for name in WIRE_DTYPES:
        c = WireCodec(name)
        x = jnp.ones((3, 2), jnp.float32)
        parts, _ = c.encode(x, jnp.zeros_like(x) if c.stateful else None)
        shapes = c.part_shapes((3, 2), np.float32)
        assert len(parts) == len(shapes)
        for p, (shape, dt) in zip(parts, shapes):
            assert tuple(p.shape) == tuple(shape)
            assert p.dtype == jnp.dtype(dt)


def test_make_codec_rejects_unknown():
    assert make_codec(None) is None
    with pytest.raises(ValueError, match="unknown wire_dtype"):
        make_codec("float8")


# --------------------------------------------------------------------------
# the build-time drift gate
# --------------------------------------------------------------------------

def test_gate_accepts_bounded_formats():
    for wd in ("float32", "bfloat16", "float16", "int8_ef"):
        assert gate_wire_config(wd) == MEASURED_DRIFT[wd]
    assert gate_wire_config(None) is None


def test_gate_rejects_over_bound_format():
    assert MEASURED_DRIFT["int8"] > DENSE_F32_DRIFT_BOUND  # table honest
    with pytest.raises(WireDriftError, match="exceeds the dense-f32"):
        gate_wire_config("int8")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        gate_wire_config("int8", verify="warn")
    assert any(issubclass(x.category, RuntimeWarning) for x in w)
    gate_wire_config("int8", verify="off")      # escape hatch


def test_gate_unknown_format_always_raises():
    for verify in ("error", "warn", "off"):
        with pytest.raises(ValueError, match="unknown wire_dtype"):
            gate_wire_config("float8", verify=verify)
    with pytest.raises(ValueError, match="unknown verify mode"):
        gate_wire_config("bfloat16", verify="maybe")


def test_wire_itemsize_table_complete():
    assert set(WIRE_ITEMSIZE) == set(WIRE_DTYPES) == set(MEASURED_DRIFT)
