"""Per-arch smoke tests: reduced config, one train + decode step on CPU.

Each assigned architecture instantiates its REDUCED same-family config and
runs: (i) a full train step (loss + grads + AdamW update) asserting
finiteness, (ii) prefill vs incremental decode logit consistency.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.launch.mesh import make_mesh
from repro.launch.steps import batch_specs, make_ctx, make_train_step
from repro.models import build_model
from repro.optim import adamw
from repro.parallel.sharding import ShardingCtx


def _batch_for(cfg, B, L, rng):
    batch = {"tokens": rng.integers(0, cfg.vocab, size=(B, L + 1))
             .astype(np.int32)}
    if cfg.prefix_tokens:
        batch["prefix_embeds"] = rng.normal(
            size=(B, cfg.prefix_tokens, cfg.d_model)).astype(np.float32)
    if cfg.is_encdec:
        batch["frames"] = rng.normal(
            size=(B, cfg.encoder_seq, cfg.d_model)).astype(np.float32)
    return {k: jnp.asarray(v) for k, v in batch.items()}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_config(arch).reduce()
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=16,
                                global_batch=2)
    mesh = make_mesh((1, 1), ("data", "model"))
    ctx = make_ctx(cfg, shape, mesh, fsdp=False)
    prog = make_train_step(cfg, shape, ctx, microbatches=1, donate=False)
    rng = np.random.default_rng(0)
    model = prog.model
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.init_state(params)
    batch = _batch_for(cfg, 2, 16, rng)
    p2, o2, metrics = prog.step_fn(params, opt, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    delta = max(float(jnp.abs(a - b).max())
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(p2)))
    assert delta > 0
    # output shapes preserved
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        assert a.shape == b.shape and a.dtype == b.dtype


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_consistency_smoke(arch):
    cfg = get_config(arch).reduce()
    mesh = make_mesh((1, 1), ("data", "model"))
    ctx = ShardingCtx(mesh=mesh, batch_axes=("data",))
    model = build_model(cfg, ctx)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    B, L = 2, 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(B, L))
                       .astype(np.int32))
    batch_full = {"tokens": toks}
    batch_pre = {"tokens": toks[:, :L - 1]}
    if cfg.prefix_tokens:
        pe = jnp.asarray(rng.normal(
            size=(B, cfg.prefix_tokens, cfg.d_model)).astype(np.float32))
        batch_full["prefix_embeds"] = pe
        batch_pre["prefix_embeds"] = pe
    if cfg.is_encdec:
        fr = jnp.asarray(rng.normal(
            size=(B, cfg.encoder_seq, cfg.d_model)).astype(np.float32))
        batch_full["frames"] = fr
        batch_pre["frames"] = fr

    logits_full, _ = jax.jit(model.prefill)(params, batch_full)
    cache = model.init_cache(B, 16 + cfg.prefix_tokens)
    _, cache = jax.jit(model.prefill)(params, batch_pre, cache)
    pos = L - 1 + cfg.prefix_tokens
    lg, _ = jax.jit(model.decode_step)(params, toks[:, L - 1:L],
                                       jnp.int32(pos), cache)
    err = float(jnp.abs(logits_full - lg).max())
    assert err < 2e-2, err
    assert np.all(np.isfinite(np.asarray(lg)))
