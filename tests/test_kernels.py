"""Pallas kernels vs ref.py oracles (interpret mode), shape/dtype sweeps."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.md.system import DEFAULT_FF
from repro.kernels import ops, ref


# ---- pack -------------------------------------------------------------------

@pytest.mark.parametrize("p,m,f", [(64, 32, 4), (100, 60, 7), (16, 128, 3)])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_pack_matches_ref(p, m, f, dtype):
    rng = np.random.RandomState(p + m)
    src = rng.randn(p, f).astype(dtype)
    idx = rng.randint(-1, p, size=(m,)).astype(np.int32)
    out = np.asarray(ops.pack(jnp.asarray(src), jnp.asarray(idx)))
    np.testing.assert_allclose(out, ref.pack_ref(src, idx), rtol=1e-6)


# ---- nonbonded pair forces ----------------------------------------------------

@pytest.mark.parametrize("n,k", [(6, 8), (10, 16), (3, 24)])
def test_pair_forces_matches_ref(n, k):
    rng = np.random.RandomState(n * k)
    ff = DEFAULT_FF
    a = rng.uniform(0, 3.0, (n, k, 4)).astype(np.float32)
    b = rng.uniform(0, 3.0, (n, k, 4)).astype(np.float32)
    a[..., 3] = rng.uniform(-0.3, 0.3, (n, k))
    b[..., 3] = rng.uniform(-0.3, 0.3, (n, k))
    ta = rng.randint(-1, 2, (n, k)).astype(np.int32)
    tb = rng.randint(-1, 2, (n, k)).astype(np.int32)
    same = np.zeros((n,), np.int32)
    same[: n // 2] = 1
    b[same > 0] = a[same > 0]
    tb[same > 0] = ta[same > 0]

    fa, fb, pe = ops.pair_forces(*map(jnp.asarray, (a, b, ta, tb, same)), ff)
    ra, rb, rp = ref.pair_forces_ref(a, b, ta, tb, same, ff)
    scale = max(np.abs(ra).max(), 1.0)
    np.testing.assert_allclose(np.asarray(fa) / scale, ra / scale,
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(fb) / scale, rb / scale,
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(pe), rp,
                               rtol=2e-4, atol=2e-4)


def test_pair_forces_newton(  ):
    rng = np.random.RandomState(0)
    ff = DEFAULT_FF
    n, k = 4, 16
    a = rng.uniform(0, 2.5, (n, k, 4)).astype(np.float32)
    b = rng.uniform(0, 2.5, (n, k, 4)).astype(np.float32)
    ta = np.zeros((n, k), np.int32)
    tb = np.zeros((n, k), np.int32)
    same = np.zeros((n,), np.int32)
    fa, fb, _ = ops.pair_forces(*map(jnp.asarray, (a, b, ta, tb, same)), ff)
    total = np.asarray(fa).sum(axis=(1,)) + np.asarray(fb).sum(axis=(1,))
    # random placements include near-overlaps with r^-14 forces; Newton's
    # third law must hold relative to the force scale
    scale = max(np.abs(np.asarray(fa)).max(), 1.0)
    np.testing.assert_allclose(total / scale, 0.0, atol=1e-5)


# ---- flash attention -----------------------------------------------------------

@pytest.mark.parametrize("bh,l,s,g,hd", [
    (2, 64, 64, 1, 32), (1, 128, 128, 4, 16), (3, 32, 96, 2, 64)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [np.float32])
def test_flash_attention_matches_ref(bh, l, s, g, hd, causal, dtype):
    if causal and l != s:
        pytest.skip("causal requires L == S in this test")
    rng = np.random.RandomState(l + s)
    q = rng.randn(bh, l, g, hd).astype(dtype)
    k = rng.randn(bh, s, hd).astype(dtype)
    v = rng.randn(bh, s, hd).astype(dtype)
    out = ops.flash_attention(*map(jnp.asarray, (q, k, v)), causal=causal,
                              bq=32, bk=32)
    expect = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), expect, atol=2e-5)


def test_flash_attention_bf16():
    rng = np.random.RandomState(7)
    q = rng.randn(2, 64, 2, 32).astype(np.float32)
    k = rng.randn(2, 64, 32).astype(np.float32)
    v = rng.randn(2, 64, 32).astype(np.float32)
    out = ops.flash_attention(
        jnp.asarray(q, jnp.bfloat16), jnp.asarray(k, jnp.bfloat16),
        jnp.asarray(v, jnp.bfloat16), causal=True, bq=32, bk=32)
    expect = ref.flash_attention_ref(q, k, v, causal=True)
    assert np.abs(np.asarray(out, np.float64) - expect).max() < 0.06


# ---- distributed kernels (remote DMA) in subprocess -----------------------------

@pytest.mark.dist
def test_halo_put_and_fused_pulses(dist):
    out = dist("check_kernel_halo.py", devices=4)
    assert "check_kernel_halo OK" in out
