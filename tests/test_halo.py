"""Halo exchange: schedule properties (hypothesis) + multi-device equivalence."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # property tests skip; hypothesis is a dev extra
    from _hypothesis_stub import given, settings, st

import jax.numpy as jnp

from repro.core.halo import halo_exchange
from repro.core.halo_plan import HaloPlan, HaloSpec, compute_exchange_stats
from repro.core.schedule import make_schedule
from repro.launch.mesh import make_mesh


# --------------------------------------------------------------------------
# pure-logic properties (in-process, hypothesis)
# --------------------------------------------------------------------------

dims_st = st.integers(min_value=1, max_value=3)


@st.composite
def schedule_case(draw):
    ndim = draw(dims_st)
    names = ("z", "y", "x")[:ndim]
    widths = tuple(draw(st.integers(1, 4)) for _ in range(ndim))
    shape = tuple(draw(st.integers(4, 12)) for _ in range(ndim))
    return names, widths, shape


@given(schedule_case())
@settings(max_examples=60, deadline=None)
def test_phases_partition_regions(case):
    names, widths, _ = case
    sched = make_schedule(names, widths)
    phases = sched.forward_phases()
    flat = [r for p in phases for r in p]
    assert sorted(flat) == sorted(sched.regions())
    assert len(set(flat)) == len(flat)
    # phase p holds exactly the regions of forwarding depth p
    for p, group in enumerate(phases):
        assert all(len(r) == p + 1 for r in group)
    # reverse phases are the mirror
    assert sched.reverse_phases() == tuple(reversed(phases))


@given(schedule_case())
@settings(max_examples=60, deadline=None)
def test_pulse_dependency_chain(case):
    names, widths, _ = case
    sched = make_schedule(names, widths)
    assert sched.pulses[0].first_dependent_pulse is None
    for p in sched.pulses[1:]:
        assert p.first_dependent_pulse == p.index - 1


@given(schedule_case(), st.integers(1, 3))
@settings(max_examples=60, deadline=None)
def test_multi_pulse_schedule_tiles_and_conserves_bytes(case, np_max):
    """Width>1 multi-pulse schedules: per-dim pulses tile the halo with
    contiguous offsets, and the per-pulse byte accounting still sums to
    the canonical total (same regions, more messages)."""
    names, widths, shape = case
    pulses_per_dim = tuple(min(np_max, w) if w else 1 for w in widths)
    sched = make_schedule(names, widths, pulses_per_dim=pulses_per_dim)
    single = make_schedule(names, widths)
    for d, w in enumerate(widths):
        dim_pulses = sched.dim_pulses(d)
        assert len(dim_pulses) == pulses_per_dim[d]
        off = 0
        for p in dim_pulses:
            assert p.offset == off
            off += p.width
        assert off == w
    s_multi = compute_exchange_stats(sched, shape, itemsize=4)
    s_single = compute_exchange_stats(single, shape, itemsize=4)
    assert s_multi["total_bytes"] == s_single["total_bytes"]
    assert s_multi["serialized_critical_bytes"] == \
        s_single["serialized_critical_bytes"]
    assert s_multi["fused_phases"] == s_single["fused_phases"]
    assert len(s_multi["serialized_pulse_bytes"]) == sched.total_pulses


@given(schedule_case())
@settings(max_examples=60, deadline=None)
def test_exchange_stats_byte_conservation(case):
    """Fused and serialized schedules move identical total bytes (the single
    canonical ``total_bytes``); the fused chained (critical-path) bytes
    never exceed the serialized ones."""
    names, widths, shape = case
    sched = make_schedule(names, widths)
    stats = compute_exchange_stats(sched, shape, itemsize=4,
                                   feature_elems=3)
    assert stats["serialized_critical_bytes"] == stats["total_bytes"]
    assert sum(stats["serialized_pulse_bytes"]) == stats["total_bytes"]
    assert sum(p["phase_bytes"] for p in stats["fused_phases"]) == \
        stats["total_bytes"]
    assert stats["fused_critical_bytes"] <= stats["serialized_critical_bytes"]
    assert 0.0 <= stats["dependent_fraction"] < 1.0
    if len(names) == 1:
        # no forwarding in 1D: everything is independent
        assert stats["dependent_fraction"] == 0.0
        assert stats["fused_critical_bytes"] == \
            stats["serialized_critical_bytes"]


def test_dependent_fraction_matches_paper_intuition():
    """With domain size >> halo width, the dependent fraction is small —
    the quantitative reason fused pulses shorten the critical path."""
    sched = make_schedule(("z", "y", "x"), (1, 1, 1))
    small = sched.dependent_fraction((32, 32, 32))
    assert small < 0.07
    # and it grows as domains shrink (strong-scaling limit)
    tight = sched.dependent_fraction((4, 4, 4))
    assert tight > small


# --------------------------------------------------------------------------
# single-device periodic self-exchange (PBC images, runs in-process)
# --------------------------------------------------------------------------

def test_single_domain_periodic_self_halo():
    mesh = make_mesh((1,), ("z",))
    x = jnp.arange(24, dtype=jnp.float32).reshape(6, 4)
    shift = np.asarray([[100.0, 0.0, 0.0, 0.0]])
    plan = HaloPlan.build(
        HaloSpec(axis_names=("z",), widths=(2,), backend="fused",
                 wrap_shift=shift), mesh)
    out = plan.fwd(x)
    # halo rows are this domain's own first rows, shifted by the box image
    np.testing.assert_allclose(np.asarray(out[:6]), np.asarray(x))
    np.testing.assert_allclose(np.asarray(out[6:]),
                               np.asarray(x[:2] + shift[0]))
    ser = HaloPlan.build(
        HaloSpec(axis_names=("z",), widths=(2,), backend="serialized",
                 wrap_shift=shift), mesh).fwd(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ser))


def test_halo_exchange_shim_is_deprecated_but_equivalent():
    mesh = make_mesh((1,), ("z",))
    x = jnp.arange(24, dtype=jnp.float32).reshape(6, 4)
    plan = HaloPlan.build(
        HaloSpec(axis_names=("z",), widths=(2,), backend="fused"), mesh)
    with pytest.warns(DeprecationWarning):
        legacy = halo_exchange(x, mesh, ("z",), (2,), mode="fused")
    np.testing.assert_array_equal(np.asarray(legacy),
                                  np.asarray(plan.fwd(x)))


# --------------------------------------------------------------------------
# multi-device equivalence (subprocess, 8 virtual devices)
# --------------------------------------------------------------------------

@pytest.mark.dist
def test_multi_device_halo_equivalence(dist):
    out = dist("check_halo.py")
    assert "check_halo OK" in out


@pytest.mark.dist
def test_ring_attention_and_distributed_decode(dist):
    out = dist("check_context.py")
    assert "check_context OK" in out


@pytest.mark.dist
def test_compression_reductions(dist):
    out = dist("check_compression.py")
    assert "check_compression OK" in out
