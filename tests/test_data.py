"""Data pipeline: determinism, host sharding, checkpointable state."""
import numpy as np

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # property tests skip; hypothesis is a dev extra
    from _hypothesis_stub import given, settings, st

from repro.data.synthetic import DataConfig, SyntheticStream, _batch_at


def test_deterministic_across_restarts():
    cfg = DataConfig(vocab=97, seq_len=16, global_batch=8, seed=3)
    s1 = SyntheticStream(cfg, prefetch=0)
    ref = [s1.next() for _ in range(5)]
    s2 = SyntheticStream(cfg, prefetch=2)
    got = [s2.next() for _ in range(5)]
    s2.close()
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(a, b)


def test_resume_from_state():
    cfg = DataConfig(vocab=97, seq_len=16, global_batch=8, seed=3)
    s1 = SyntheticStream(cfg, prefetch=0)
    for _ in range(3):
        s1.next()
    state = s1.state()
    want = s1.next()
    s2 = SyntheticStream.from_state(cfg, state, prefetch=0)
    np.testing.assert_array_equal(s2.next(), want)


@given(step=st.integers(0, 500), seed=st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_host_shards_partition_global_batch(step, seed):
    """Concatenated per-host slices == the single-host global batch."""
    base = DataConfig(vocab=211, seq_len=8, global_batch=8, seed=seed)
    whole = _batch_at(base, step)
    parts = [
        _batch_at(DataConfig(vocab=211, seq_len=8, global_batch=8,
                             seed=seed, n_hosts=4, host_id=h), step)
        for h in range(4)
    ]
    np.testing.assert_array_equal(np.concatenate(parts), whole)


def test_tokens_in_range_and_learnable():
    cfg = DataConfig(vocab=64, seq_len=128, global_batch=4, seed=0)
    b = _batch_at(cfg, 0)
    assert b.min() >= 0 and b.max() < 64
    # the copy-motif makes token t equal token t-period most of the time
    same = (b[:, cfg.copy_period:] == b[:, :-cfg.copy_period]).mean()
    assert same > 0.6
