"""Failure injection: kill mid-run, restart, verify bit-exact continuation."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.data.synthetic import DataConfig
from repro.launch.mesh import make_mesh
from repro.launch.steps import make_ctx, make_train_step
from repro.optim import adamw
from repro.runtime.train_loop import TrainLoopConfig, Watchdog, run_training


@pytest.fixture(scope="module")
def tiny_program():
    cfg = get_config("qwen3-1.7b").reduce()
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=16,
                                global_batch=4)
    mesh = make_mesh((1, 1), ("data", "model"))
    ctx = make_ctx(cfg, shape, mesh, fsdp=False)
    prog = make_train_step(cfg, shape, ctx,
                           ocfg=adamw.AdamWConfig(lr=8e-3, warmup_steps=2,
                                                  total_steps=60),
                           microbatches=1, donate=False)
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4,
                          seed=11)
    model = prog.model

    def init():
        return model.init(jax.random.PRNGKey(0))

    return cfg, prog, data_cfg, init


def _loss_trace(history):
    return [round(h["loss"], 6) for h in history]


def test_crash_restart_bit_exact(tmp_path, tiny_program):
    cfg, prog, data_cfg, init = tiny_program
    loop = TrainLoopConfig(total_steps=12, ckpt_dir=str(tmp_path / "a"),
                           ckpt_every=4, log_every=100)

    # uninterrupted reference
    _, _, ref = run_training(loop, prog, data_cfg, init, log=None)

    # crash after step 6 (checkpoint exists at 4), then resume
    loop2 = dataclasses.replace(loop, ckpt_dir=str(tmp_path / "b"))
    with pytest.raises(RuntimeError, match="injected failure"):
        run_training(loop2, prog, data_cfg, init, fail_at_step=6, log=None)
    _, _, hist2 = run_training(loop2, prog, data_cfg, init, log=None)

    # continuation must resume from step 4 and match the reference losses
    assert hist2[0]["step"] == 4
    ref_by_step = {h["step"]: round(h["loss"], 6) for h in ref}
    for h in hist2:
        assert ref_by_step[h["step"]] == round(h["loss"], 6), h


def test_loss_decreases(tmp_path, tiny_program):
    cfg, prog, data_cfg, init = tiny_program
    # easily-learnable stream (small effective vocab, period-1 motif) so a
    # 2-layer d=64 model shows clear progress within ~60 steps
    data_cfg = dataclasses.replace(data_cfg, vocab=64, copy_period=1)
    loop = TrainLoopConfig(total_steps=60, ckpt_dir=str(tmp_path / "c"),
                           ckpt_every=100, log_every=100)
    _, _, hist = run_training(loop, prog, data_cfg, init, log=None)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.5, (first, last)


def test_watchdog_flags_straggler():
    events = []
    wd = Watchdog(alpha=0.5, threshold=2.0, warmup=2,
                  on_straggler=lambda s, dt, ew: events.append((s, dt, ew)))
    for s in range(6):
        wd.observe(s, 0.1)
    wd.observe(6, 1.0)          # 10x slower step
    assert wd.events == 1 and events[0][0] == 6
    wd.observe(7, 0.1)
    assert wd.events == 1
