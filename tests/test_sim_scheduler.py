"""SimScheduler admission-churn properties (hypothesis sweep).

The scheduler is pure host bookkeeping — no jax — so random
arrival/cancel/retirement sequences are cheap to drive end to end.  The
locked invariants (the ones the SimServer's bitwise isolation sits on):

* every admitted replica fits its bucket (atoms ≤ rung, row < rows);
* FIFO within an atom bucket — admission order equals submission order
  (minus cancelled-in-queue), so no replica starves;
* the set of shapes ever opened stays inside the ladder grid, hence
  distinct compiled shapes ≤ ``ladder.n_buckets``;
* a finished/faulted/cancelled replica's row is free again by the next
  boundary (release precedes the next tick), and every submission
  reaches a terminal state in bounded boundaries.
"""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # property tests skip; hypothesis is a dev extra
    from _hypothesis_stub import given, settings, st

from repro.serve import (BucketLadder, CANCELLED, DONE, FAILED,
                         SimScheduler, TERMINAL)

LADDER = BucketLadder(row_buckets=(1, 2, 4), atom_buckets=(64, 128, 256))
BLOCK = 10


def _drive(ops, fault_every=0):
    """Run a random op sequence to quiescence; return the evidence."""
    sched = SimScheduler(LADDER, block_steps=BLOCK)
    submit_order = {a: [] for a in LADDER.atom_buckets}
    admit_order = {a: [] for a in LADDER.atom_buckets}
    live_rids = []

    def boundary():
        for adm in sched.tick():
            rec = sched.records[adm.rid]
            rows, atoms = adm.shape
            # fits-its-bucket invariant, checked at the admission edge
            assert rec.n_atoms <= atoms
            assert 0 <= adm.row < rows
            assert adm.shape in {(r, a) for r in LADDER.row_buckets
                                 for a in LADDER.atom_buckets}
            admit_order[atoms].append(adm.rid)
        for shape in sched.live_shapes():
            sched.advance(shape)
            if fault_every:
                for _, rid in sched.occupants(shape):
                    if rid % fault_every == 0:
                        sched.mark_fault(rid, RuntimeError("boom"))
            for rid in sched.finished(shape):
                sched.release(rid)
                # slot freed by this boundary: the row reads empty
                assert all(r != rid for row in sched.tables.values()
                           for r in row)

    for kind, a, b in ops:
        if kind == "submit":
            rid = sched.submit(n_atoms=a, n_steps=b)
            submit_order[sched.records[rid].atom_bucket].append(rid)
            live_rids.append(rid)
        elif kind == "cancel" and live_rids:
            sched.cancel(live_rids[a % len(live_rids)])
        else:
            boundary()

    for _ in range(200):               # bounded drain: no starvation
        if all(sched.records[r].status in TERMINAL for r in live_rids):
            break
        boundary()
    else:
        pytest.fail("scheduler failed to drain in 200 boundaries")
    return sched, submit_order, admit_order


OPS = st.lists(
    st.one_of(
        st.tuples(st.just("submit"), st.integers(1, 256),
                  st.integers(1, 45)),
        st.tuples(st.just("cancel"), st.integers(0, 63), st.just(0)),
        st.tuples(st.just("boundary"), st.just(0), st.just(0)),
    ),
    min_size=1, max_size=64)


@settings(max_examples=60, deadline=None)
@given(OPS)
def test_random_churn_respects_invariants(ops):
    sched, submit_order, admit_order = _drive(ops)
    assert len(sched.shapes_touched) <= LADDER.n_buckets
    assert sched.shapes_touched <= {(r, a) for r in LADDER.row_buckets
                                    for a in LADDER.atom_buckets}
    for atoms in LADDER.atom_buckets:
        expected = [rid for rid in submit_order[atoms]
                    if sched.records[rid].status != CANCELLED
                    or sched.records[rid].steps_done > 0]
        # FIFO within the bucket: admitted exactly in submit order (no
        # starvation: everyone not cancelled-in-queue was admitted)
        assert admit_order[atoms] == expected
    for rec in sched.records.values():
        assert rec.status in TERMINAL
        if rec.status == DONE:
            assert rec.steps_done >= rec.requested_steps
            assert rec.steps_done == rec.budget_steps


@settings(max_examples=25, deadline=None)
@given(OPS)
def test_random_churn_with_faults_still_drains(ops):
    sched, _, _ = _drive(ops, fault_every=3)
    for rec in sched.records.values():
        assert rec.status in TERMINAL
        if rec.status == FAILED:
            assert isinstance(rec.error, RuntimeError)


# ---- deterministic corners (run even without hypothesis) -------------------

def test_budget_rounds_to_blocks_and_fifo_order():
    sched = SimScheduler(LADDER, block_steps=BLOCK)
    rids = [sched.submit(60, 25) for _ in range(5)]   # atoms rung 64
    assert all(sched.records[r].budget_steps == 30 for r in rids)
    adms = sched.tick()                  # rows_for(5) -> clamped to 4
    assert [a.rid for a in adms] == rids[:4]
    assert adms[0].shape == (4, 64)
    for _ in range(3):                   # 3 blocks retire the first four
        sched.advance((4, 64))
    for rid in sched.finished((4, 64)):
        sched.release(rid)
    adms2 = sched.tick()                 # the straggler takes a freed row
    assert [a.rid for a in adms2] == rids[4:]


def test_table_closes_when_drained_and_reopens_sized_to_demand():
    sched = SimScheduler(LADDER, block_steps=BLOCK)
    r0 = sched.submit(100, 10)
    sched.tick()
    sched.advance((1, 128))
    assert sched.finished((1, 128)) == [r0]
    sched.release(r0)
    assert (1, 128) not in sched.tables   # empty + no queue -> closed
    for _ in range(3):
        sched.submit(100, 10)
    [adm, *rest] = sched.tick()           # reopens at the 4-row rung
    assert adm.shape == (4, 128) and len(rest) == 2
    assert sched.shapes_touched == {(1, 128), (4, 128)}


def test_cancel_semantics():
    sched = SimScheduler(BucketLadder(row_buckets=(1,),
                                      atom_buckets=(64,)), BLOCK)
    r0 = sched.submit(10, 10)
    r1 = sched.submit(10, 10)
    sched.tick()
    assert sched.cancel(r1) == CANCELLED          # dequeued immediately
    assert sched.cancel(r0) == "running"          # flagged for boundary
    assert sched.finished((1, 64)) == [r0]
    rec = sched.release(r0)
    assert rec.status == CANCELLED


def test_rejects_oversized_and_bad_args():
    sched = SimScheduler(LADDER, block_steps=BLOCK)
    with pytest.raises(ValueError, match="atom bucket"):
        sched.submit(10_000, 10)
    with pytest.raises(ValueError, match="n_steps"):
        sched.submit(10, 0)
    with pytest.raises(ValueError):
        BucketLadder(row_buckets=(4, 2))
