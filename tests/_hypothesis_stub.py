"""Stand-ins so property-based tests skip cleanly without ``hypothesis``.

``hypothesis`` is a dev extra (``pip install -e .[dev]``); the tier-1 suite
must collect without it.  Modules import via::

    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
    except ImportError:
        from _hypothesis_stub import given, settings, st

With the stub, ``@given(...)`` replaces the test body with a skip, and the
strategy expressions evaluated at module import become inert placeholders.
"""
import pytest


class _Strategy:
    """Inert placeholder: any attribute/call returns another placeholder."""

    def __call__(self, *args, **kwargs):
        return self

    def __getattr__(self, name):
        return self


class _Strategies:
    def composite(self, fn):
        return _Strategy()

    def __getattr__(self, name):
        return _Strategy()


st = _Strategies()


def given(*args, **kwargs):
    def deco(fn):
        # deliberately parameterless: the wrapped test's arguments are
        # hypothesis-drawn, and pytest must not mistake them for fixtures
        def skipper():
            pytest.skip("hypothesis not installed (pip install -e .[dev])")
        skipper.__name__ = fn.__name__
        skipper.__doc__ = fn.__doc__
        return skipper
    return deco


def settings(*args, **kwargs):
    def deco(fn):
        return fn
    return deco
