"""Tier-1 smoke coverage for ``examples/*.py``.

The examples are the repo's public face and were previously untested —
import errors and API drift (renamed kwargs, moved modules) only
surfaced when a user ran them.  Every example must (a) import cleanly
without side effects (module-scope work is wrapped in ``main()`` +
``__main__`` guards) and (b) expose a ``main`` whose cheap
configurations actually run.  Heavyweight mains (LM training/serving —
tens of seconds even reduced) are import-checked only and exercised by
their own subsystem tests.
"""
import importlib
import pathlib
import sys

import pytest

EXAMPLES_DIR = pathlib.Path(__file__).resolve().parent.parent / "examples"
EXAMPLES = sorted(p.stem for p in EXAMPLES_DIR.glob("*.py"))


@pytest.fixture(scope="module", autouse=True)
def _examples_on_path():
    sys.path.insert(0, str(EXAMPLES_DIR))
    try:
        yield
    finally:
        sys.path.remove(str(EXAMPLES_DIR))


@pytest.mark.parametrize("name", EXAMPLES)
def test_example_imports_cleanly(name):
    """Importing an example must not run its workload (guards intact)
    and must resolve every repro API it references."""
    mod = importlib.import_module(name)
    assert callable(getattr(mod, "main", None)), \
        f"examples/{name}.py must expose a main() entry point"


def test_quickstart_main_runs():
    import quickstart
    stats = quickstart.main(n_atoms=200, steps=2)
    assert stats["total_bytes"] > 0


def test_md_halo_demo_main_runs():
    import md_halo_demo
    results = md_halo_demo.main(n_atoms=200, warmup=1, steps=2)
    assert set(results) == {"serialized", "fused"}
    assert all(dt > 0 for dt in results.values())


def test_md_halo_demo_wire_runs():
    import md_halo_demo
    results = md_halo_demo.main(n_atoms=200, warmup=1, steps=2,
                                wire_dtype="bfloat16")
    assert all(dt > 0 for dt in results.values())


def test_ring_attention_demo_main_runs():
    import ring_attention_demo
    err = ring_attention_demo.main(seq_per_shard=16, iters=1, B=1, H=2,
                                   hd=8)
    assert err < 1e-4
