"""Serving loop: wave batching, greedy decode == full-context argmax."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.parallel.sharding import ShardingCtx
from repro.resilience import Watchdog, WaveTimeout
from repro.runtime.serve_loop import (BatchServer, Request, masked_tokens,
                                      throughput_stats)


@pytest.fixture(scope="module")
def served():
    cfg = get_config("qwen3-1.7b").reduce()
    mesh = make_mesh((1, 1), ("data", "model"))
    ctx = ShardingCtx(mesh=mesh, batch_axes=("data",))
    model = build_model(cfg, ctx)
    params = model.init(jax.random.PRNGKey(1))
    return cfg, model, params


def test_wave_serving_matches_stepwise_prefill(served):
    cfg, model, params = served
    rng = np.random.RandomState(0)
    server = BatchServer(model, params, batch_size=3, max_len=32)
    reqs = [Request(prompt=rng.randint(0, cfg.vocab, size=(5,))
                    .astype(np.int32), max_new_tokens=4) for _ in range(3)]
    out = server.serve_wave(reqs)
    stats = throughput_stats(out)
    assert stats["tokens"] == 12 and stats["tok_per_s"] > 0

    # oracle: greedy continuation via repeated full prefill
    prefill = jax.jit(model.prefill)
    for r in out:
        toks = list(r.prompt)
        for t in range(r.max_new_tokens):
            logits, _ = prefill(params, {"tokens": jnp.asarray(
                np.asarray(toks, np.int32)[None])})
            nxt = int(jnp.argmax(logits[0]))
            assert nxt == int(r.out_tokens[t]), (t, toks)
            toks.append(nxt)


def test_wave_timeout_raises_typed_error(served):
    """An impossible per-wave deadline turns the decode loop into a
    typed WaveTimeout instead of a hung queue (the deadline is checked
    against synced device work, not dispatch time)."""
    cfg, model, params = served
    rng = np.random.RandomState(2)
    server = BatchServer(model, params, batch_size=1, max_len=32,
                         wave_timeout_s=1e-9)
    req = Request(prompt=rng.randint(0, cfg.vocab, size=(4,))
                  .astype(np.int32), max_new_tokens=6)
    with pytest.raises(WaveTimeout, match="decode steps"):
        server.serve_wave([req])


def test_generous_timeout_does_not_fire_and_watchdog_observes(served):
    cfg, model, params = served
    rng = np.random.RandomState(3)
    wd = Watchdog()
    server = BatchServer(model, params, batch_size=1, max_len=32,
                         wave_timeout_s=600.0, watchdog=wd)
    for _ in range(2):
        req = Request(prompt=rng.randint(0, cfg.vocab, size=(4,))
                      .astype(np.int32), max_new_tokens=3)
        out = server.serve_wave([req])
        assert out[0].out_tokens.shape == (3,)
    assert wd.n == 2 and wd.events == 0      # one observation per wave


def test_throughput_masks_padding_and_sums_waves():
    """Regression for the wave throughput overcount: padded decode rows
    beyond a request's budget must not count as tokens, and the serving
    wall must cover *every* wave, not just the longest one."""
    def fake(budget, decoded, wave, latency):
        return Request(prompt=np.zeros(1, np.int32), max_new_tokens=budget,
                       out_tokens=np.zeros(decoded, np.int32), wave=wave,
                       latency_s=latency)

    reqs = [fake(5, 5, 0, 1.0),      # wave 0: padded to 5 new tokens
            fake(3, 5, 0, 1.0),      #   3-budget row decoded 5 -> count 3
            fake(4, 4, 1, 2.0),      # wave 1
            Request(prompt=np.zeros(1, np.int32), max_new_tokens=9)]
    stats = throughput_stats(reqs)   # unserved request is ignored
    assert stats["tokens"] == 5 + 3 + 4
    assert stats["wall_s"] == pytest.approx(3.0)   # 1.0 + 2.0, not max
    assert stats["tok_per_s"] == pytest.approx(12 / 3.0)
    assert masked_tokens([5, 5, 4], [5, 3, 4]) == 12


def test_multi_wave_mixed_budgets_end_to_end(served):
    """Two real waves with mixed max_new_tokens: per-request outputs are
    budget-trimmed and the summed stats stay wave-aware."""
    cfg, model, params = served
    rng = np.random.RandomState(4)
    server = BatchServer(model, params, batch_size=2, max_len=32)
    def req(budget):
        return Request(prompt=rng.randint(0, cfg.vocab, size=(4,))
                       .astype(np.int32), max_new_tokens=budget)
    done = server.serve_wave([req(6), req(2)])    # padded to 6 decodes
    done += server.serve_wave([req(3)])
    assert [r.wave for r in done] == [0, 0, 1]
    assert [r.out_tokens.shape[0] for r in done] == [6, 2, 3]
    stats = throughput_stats(done)
    assert stats["tokens"] == 11                  # not 6+6+3
    assert stats["wall_s"] == pytest.approx(
        done[0].latency_s + done[2].latency_s)


def test_temperature_sampling_changes_output(served):
    cfg, model, params = served
    rng = np.random.RandomState(1)
    prompt = rng.randint(0, cfg.vocab, size=(6,)).astype(np.int32)
    greedy = BatchServer(model, params, batch_size=1, max_len=32)
    hot = BatchServer(model, params, batch_size=1, max_len=32,
                      temperature=2.0, seed=3)
    g = greedy.serve_wave([Request(prompt=prompt, max_new_tokens=8)])
    h = hot.serve_wave([Request(prompt=prompt, max_new_tokens=8)])
    assert not np.array_equal(g[0].out_tokens, h[0].out_tokens)
