"""Pair-schedule force engine: worklist/prune invariants + backend parity.

The functional guarantee under test: for any occupancy pattern — empty
cells, capacity-full (overflow-adjacent) cells, random fills — the pruned
``"sparse"`` / ``"pallas"`` backends reproduce the dense 14-zone forces
(and the O(N^2) direct oracle) to dtype-scaled tolerance, i.e. the prune
never drops a contributing pair and padding slots contribute nothing.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # property tests skip; hypothesis is a dev extra
    from _hypothesis_stub import given, settings, st

import jax.numpy as jnp
from jax import lax

from repro.core.md import make_grappa_like
from repro.core.md import pair_schedule as psched
from repro.core.md.cells import (
    bin_to_cells,
    cell_bounds,
    cell_counts,
    choose_layout,
)
from repro.core.md.forces import compute_forces, stencil_pairs
from repro.core.md.schedule_opt import bucket
from repro.core.md.system import DEFAULT_FF, MDParams

# tolerance of the sparse/pallas-vs-dense parity, scaled to max |F|:
# identical per-pair math, different summation order (float32)
FORCE_RTOL = 5e-6
PE_RTOL = 5e-6


def periodic_extend(cell_f4, cell_i, box):
    """One-device halo oracle: wrap each dim's first layer to the far side
    (coordinate-shifted), mirroring the engine's fused exchange."""
    ef = np.array(cell_f4)
    ei = np.array(cell_i)
    for d in range(3):
        slab_f = np.take(ef, [0], axis=d).copy()
        slab_valid = np.take(ei, [0], axis=d)[..., 0] >= 0
        slab_f[..., d] = np.where(slab_valid, slab_f[..., d] + box[d], 0.0)
        ef = np.concatenate([ef, slab_f], axis=d)
        ei = np.concatenate([ei, np.take(ei, [0], axis=d)], axis=d)
    return jnp.asarray(ef), jnp.asarray(ei)


def eval_backends(layout, ext_f, ext_i, ff, params):
    """Dense + pruned-backend forces on the same extended arrays."""
    F_d, pe_d = compute_forces(ext_f, ext_i, layout, ff)
    sched = psched.PairSchedule.build(layout)
    sel, n_keep, occ = psched.prune_local(sched, ext_f, ext_i,
                                          psched.prune_radius(params))
    n_exec = bucket(int(n_keep), psched.PAIR_BUCKET, sched.n_pairs)
    k_exec = bucket(int(occ), psched.SLOT_QUANTUM, layout.capacity)
    sel_exec = lax.slice(sel, (0,), (n_exec,))
    out = {"dense": (F_d, pe_d), "_shapes": (int(n_keep), n_exec, k_exec)}
    for name in ("sparse", "pallas"):
        out[name] = psched.get_force_backend(name)(
            ext_f, ext_i, layout, ff, sched=sched, sel=sel_exec,
            k_exec=k_exec)
    return out


def assert_parity(out):
    F_d, pe_d = out["dense"]
    scale = max(float(jnp.abs(F_d).max()), 1.0)
    for name in ("sparse", "pallas"):
        F, pe = out[name]
        assert float(jnp.abs(F - F_d).max()) / scale < FORCE_RTOL, name
        assert abs(float(pe - pe_d)) / max(abs(float(pe_d)), 1.0) \
            < PE_RTOL, name


# ---- static worklist ------------------------------------------------------

def test_worklist_is_static_eighth_shell():
    layout = choose_layout((8.0, 8.0, 8.0), (1, 1, 1), 2.6, 400)
    sched = psched.PairSchedule.build(layout)
    ncells = layout.n_local_cells
    assert sched.n_pairs == 14 * ncells == len(stencil_pairs()) * ncells
    ne = sched.n_ext_cells
    assert sched.cell_a.min() >= 0 and sched.cell_a.max() < ne
    assert sched.cell_b.min() >= 0 and sched.cell_b.max() < ne
    assert int(sched.same.sum()) == ncells
    assert np.all(sched.cell_a[sched.same > 0]
                  == sched.cell_b[sched.same > 0])
    assert sched.dense_slot_pairs() == 14 * ncells * layout.capacity ** 2


def test_worklist_rejects_single_global_cell():
    layout = choose_layout((3.0, 8.0, 8.0), (1, 1, 1), 2.6, 100)
    assert layout.global_cells[0] == 1
    with pytest.raises(ValueError, match="2 global cells"):
        psched.PairSchedule.build(layout)


def test_bucket_quantization():
    assert bucket(0, 64, 1000) == 64
    assert bucket(65, 64, 1000) == 128
    assert bucket(999, 64, 140) == 140        # capped
    assert bucket(7, 4, 84) == 8
    assert bucket(84, 4, 84) == 84


def test_cell_counts_and_bounds():
    rng = np.random.RandomState(0)
    pos = rng.uniform(0, 2.0, (2, 3, 5)).astype(np.float32)
    ci = np.full((2, 3, 2), -1, np.int32)
    ci[0, :2, 0] = [4, 9]                       # cell 0: two atoms
    counts = cell_counts(jnp.asarray(ci))
    assert counts.tolist() == [2, 0]
    lo, hi = cell_bounds(jnp.asarray(pos[..., :3]), jnp.asarray(ci))
    np.testing.assert_allclose(np.asarray(lo[0]),
                               pos[0, :2, :3].min(axis=0))
    np.testing.assert_allclose(np.asarray(hi[0]),
                               pos[0, :2, :3].max(axis=0))
    assert np.all(np.asarray(lo[1]) > np.asarray(hi[1]))   # empty: inverted


# ---- backend parity on a real system -------------------------------------

@pytest.fixture(scope="module")
def binned_system():
    sys_ = make_grappa_like(420, seed=5)
    layout = choose_layout(sys_.box, (1, 1, 1),
                           sys_.params.ff.r_cut * 1.08, sys_.n_atoms)
    feats_f = np.concatenate([sys_.charge[:, None], sys_.vel], axis=1)
    feats_i = np.stack([np.arange(sys_.n_atoms), sys_.typ],
                       axis=1).astype(np.int32)
    cell_f, cell_i, ovf = bin_to_cells(
        jnp.asarray(sys_.pos), jnp.asarray(feats_f), jnp.asarray(feats_i),
        layout, jnp.zeros(3, jnp.int32))
    assert int(ovf) == 0
    ext_f, ext_i = periodic_extend(np.asarray(cell_f)[..., :4], cell_i,
                                   sys_.box)
    return sys_, layout, ext_f, ext_i


def test_sparse_and_pallas_match_dense(binned_system):
    sys_, layout, ext_f, ext_i = binned_system
    out = eval_backends(layout, ext_f, ext_i, sys_.params.ff, sys_.params)
    assert_parity(out)
    n_keep, n_exec, k_exec = out["_shapes"]
    # the headline claim: pruned work is at least 2x below dense at the
    # default 2.2 capacity safety
    sched = psched.PairSchedule.build(layout)
    assert n_exec * k_exec ** 2 * 2 <= sched.dense_slot_pairs()


def test_prune_is_conservative(binned_system):
    """Disabling the distance prune (huge radius) must not change forces —
    i.e. the bounded prune only ever removes non-contributing pairs."""
    sys_, layout, ext_f, ext_i = binned_system
    ff = sys_.params.ff
    sched = psched.PairSchedule.build(layout)
    sel_all, n_all, occ = psched.prune_local(sched, ext_f, ext_i,
                                             r_prune=1e6)
    sel, n_keep, _ = psched.prune_local(sched, ext_f, ext_i,
                                        psched.prune_radius(sys_.params))
    assert int(n_keep) <= int(n_all)
    k_exec = bucket(int(occ), psched.SLOT_QUANTUM, layout.capacity)
    F_a, pe_a = psched.get_force_backend("sparse")(
        ext_f, ext_i, layout, ff, sched=sched,
        sel=lax.slice(sel_all, (0,), (sched.n_pairs,)), k_exec=k_exec)
    F_p, pe_p = psched.get_force_backend("sparse")(
        ext_f, ext_i, layout, ff, sched=sched,
        sel=lax.slice(sel, (0,),
                      (bucket(int(n_keep), psched.PAIR_BUCKET,
                              sched.n_pairs),)), k_exec=k_exec)
    scale = max(float(jnp.abs(F_a).max()), 1.0)
    assert float(jnp.abs(F_a - F_p).max()) / scale < FORCE_RTOL


# ---- crafted occupancies: empty + capacity-full cells --------------------

def test_empty_and_overflow_adjacent_cells():
    """One cell at exactly capacity K, one region fully empty."""
    rng = np.random.RandomState(7)
    box = (10.8, 10.8, 10.8)
    layout = choose_layout(box, (1, 1, 1), 2.7, 120, min_capacity=8)
    cz, cy, cx = layout.cells_per_domain
    K = layout.capacity
    cs = np.asarray(layout.cell_size)

    pos, typ = [], []
    for iz in range(cz):
        for iy in range(cy):
            for ix in range(cx):
                if (iz, iy, ix) == (cz - 1, cy - 1, cx - 1):
                    n = 0                       # fully-empty cell
                elif (iz, iy, ix) == (0, 0, 0):
                    n = K                       # overflow-adjacent: full
                else:
                    n = int(rng.randint(0, max(K // 3, 2)))
                origin = np.asarray([iz, iy, ix]) * cs
                p = origin + rng.uniform(0.05, 0.95, (n, 3)) * cs
                pos.append(p)
                typ.append(rng.randint(0, 2, n))
    pos = np.concatenate(pos).astype(np.float32)
    typ = np.concatenate(typ).astype(np.int32)
    n_atoms = pos.shape[0]
    charge = (rng.uniform(size=n_atoms) - 0.5).astype(np.float32) * 0.5

    feats_f = np.concatenate([charge[:, None],
                              np.zeros((n_atoms, 3), np.float32)], axis=1)
    feats_i = np.stack([np.arange(n_atoms), typ], axis=1).astype(np.int32)
    cell_f, cell_i, ovf = bin_to_cells(
        jnp.asarray(pos), jnp.asarray(feats_f), jnp.asarray(feats_i),
        layout, jnp.zeros(3, jnp.int32))
    assert int(ovf) == 0
    counts = np.asarray(cell_counts(cell_i))
    assert counts[0, 0, 0] == K and counts[-1, -1, -1] == 0

    ext_f, ext_i = periodic_extend(np.asarray(cell_f)[..., :4], cell_i, box)
    params = MDParams(ff=DEFAULT_FF)
    out = eval_backends(layout, ext_f, ext_i, DEFAULT_FF, params)
    assert_parity(out)
    # empty-cell pairs must actually be pruned
    sched = psched.PairSchedule.build(layout)
    _, n_keep, occ = psched.prune_local(sched, ext_f, ext_i,
                                        psched.prune_radius(params))
    assert int(n_keep) < sched.n_pairs
    assert int(occ) == K                        # the full cell drives k_exec


# ---- hypothesis sweep -----------------------------------------------------

@given(n=st.integers(200, 420), seed=st.integers(0, 10_000))
@settings(max_examples=6, deadline=None)
def test_backend_parity_random_systems(n, seed):
    sys_ = make_grappa_like(n, seed=seed)
    layout = choose_layout(sys_.box, (1, 1, 1),
                           sys_.params.ff.r_cut * 1.08, sys_.n_atoms)
    feats_f = np.concatenate([sys_.charge[:, None], sys_.vel], axis=1)
    feats_i = np.stack([np.arange(n), sys_.typ], axis=1).astype(np.int32)
    cell_f, cell_i, ovf = bin_to_cells(
        jnp.asarray(sys_.pos), jnp.asarray(feats_f), jnp.asarray(feats_i),
        layout, jnp.zeros(3, jnp.int32))
    assert int(ovf) == 0
    ext_f, ext_i = periodic_extend(np.asarray(cell_f)[..., :4], cell_i,
                                   sys_.box)
    out = eval_backends(layout, ext_f, ext_i, sys_.params.ff, sys_.params)
    assert_parity(out)


# ---- overlap_rebin: fused rebin/migration/prune invariants ----------------

def test_overlap_rebin_fused_path_matches_host_dispatch():
    """24 steps (nstlist=20: one rebin/migration/prune boundary): fusing
    the DLB work into the block program must (a) reproduce the
    host-dispatched trajectory and migration diagnostics bit for bit,
    (b) hand the next block the exact same pruned schedule, and (c) keep
    the prune conservative across the block boundary — evaluating the
    full unpruned worklist on the final state changes nothing."""
    from repro.core.halo_plan import HaloSpec
    from repro.core.md import MDEngine, make_grappa_like
    from repro.launch.mesh import make_mesh

    sys_ = make_grappa_like(300, seed=9)
    mesh = make_mesh((1, 1, 1), ("z", "y", "x"))
    spec = HaloSpec(axis_names=("z", "y", "x"), widths=(1, 1, 1),
                    backend="fused")
    host = MDEngine(sys_, mesh, spec, force_backend="sparse")
    fused = MDEngine(sys_, mesh, spec, force_backend="sparse",
                     overlap_rebin=True)
    (cf_h, ci_h), m_h, d_h = host.simulate(24)
    (cf_f, ci_f), m_f, d_f = fused.simulate(24)

    np.testing.assert_array_equal(np.asarray(cf_f), np.asarray(cf_h))
    np.testing.assert_array_equal(np.asarray(ci_f), np.asarray(ci_h))
    for k in m_h:
        np.testing.assert_array_equal(np.asarray(m_f[k]),
                                      np.asarray(m_h[k]))
    assert len(d_f) == len(d_h)
    for a, b in zip(d_f, d_h):
        for k in b:
            np.testing.assert_array_equal(np.asarray(a[k]),
                                          np.asarray(b[k]))

    # (b) identical post-boundary exec schedule (fused prune == prune_fn)
    sel_h, n_h, k_h = host._sched_exec
    sel_f, n_f, k_f = fused._sched_exec
    assert (n_h, k_h) == (n_f, k_f)
    np.testing.assert_array_equal(np.asarray(sel_f), np.asarray(sel_h))

    # (c) conservativeness across the boundary: the pruned schedule's
    # forces on the final state match the full unpruned worklist's
    F_pruned, pe_pruned = fused._force_fn_sched(cf_f, ci_f, sel_f, n_f,
                                                k_f)
    sched = fused.pair_schedule
    F_full, pe_full = fused._force_fn_sched(cf_f, ci_f, sel_f,
                                            sched.n_pairs, k_f)
    scale = max(float(jnp.abs(F_full).max()), 1.0)
    assert float(jnp.abs(F_pruned - F_full).max()) / scale < FORCE_RTOL
    assert abs(float(pe_pruned - pe_full)) / \
        max(abs(float(pe_full)), 1.0) < PE_RTOL


# ---- sparse forces against the O(N^2) oracle ------------------------------

def test_sparse_engine_matches_direct_oracle():
    from repro.core.halo_plan import HaloSpec
    from repro.core.md import MDEngine, direct_forces_reference
    from repro.launch.mesh import make_mesh

    sys_ = make_grappa_like(300, seed=11)
    mesh = make_mesh((1, 1, 1), ("z", "y", "x"))
    spec = HaloSpec(axis_names=("z", "y", "x"), widths=(1, 1, 1),
                    backend="fused")
    eng = MDEngine(sys_, mesh, spec, force_backend="sparse")
    cf, ci = eng.init_state()
    cf, ci, force, diag = eng.rebin_fn(cf, ci)
    eng._refresh_schedule(cf, ci)
    f_s, pe_s = eng.force_fn(cf, ci)
    f_eng, = eng.gather_by_id([f_s], ci)
    f_ref, _ = direct_forces_reference(sys_.pos, sys_.charge, sys_.typ,
                                       sys_.box, sys_.params.ff)
    scale = np.abs(f_ref).max()
    assert np.abs(f_eng - f_ref).max() / scale < 5e-5
    assert eng.pair_stats()["prune_ratio"] >= 2.0
