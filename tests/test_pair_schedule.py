"""Pair-schedule force engine: worklist/prune invariants + backend parity.

The functional guarantee under test: for any occupancy pattern — empty
cells, capacity-full (overflow-adjacent) cells, random fills — the pruned
``"sparse"`` / ``"pallas"`` backends reproduce the dense 14-zone forces
(and the O(N^2) direct oracle) to dtype-scaled tolerance, i.e. the prune
never drops a contributing pair and padding slots contribute nothing.

The dual pair-list properties ride on top (hypothesis sweeps below):
the outer list is conservative under any bounded drift replay, the
rolling inner prune never drops a pair within the inner radius, the
occupancy-sorted tier packing never truncates a pair's real occupancy,
and every packing is a permutation (no duplicated or lost worklist
rows).
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # property tests skip; hypothesis is a dev extra
    from _hypothesis_stub import given, settings, st

import jax.numpy as jnp
from jax import lax

from repro.core.md import make_grappa_like
from repro.core.md import pair_schedule as psched
from repro.core.md.cells import (
    bin_to_cells,
    cell_bounds,
    cell_counts,
    cell_levels,
    choose_layout,
)
from repro.core.md.forces import compute_forces, stencil_pairs
from repro.core.md.schedule_opt import (
    bucket,
    bucket0,
    tier_cum,
    tier_plan,
    tier_rows,
    tier_slot_pairs,
)
from repro.core.md.system import DEFAULT_FF, MDParams

# tolerance of the sparse/pallas-vs-dense parity, scaled to max |F|:
# identical per-pair math, different summation order (float32)
FORCE_RTOL = 5e-6
PE_RTOL = 5e-6


def periodic_extend(cell_f4, cell_i, box):
    """One-device halo oracle: wrap each dim's first layer to the far side
    (coordinate-shifted), mirroring the engine's fused exchange."""
    ef = np.array(cell_f4)
    ei = np.array(cell_i)
    for d in range(3):
        slab_f = np.take(ef, [0], axis=d).copy()
        slab_valid = np.take(ei, [0], axis=d)[..., 0] >= 0
        slab_f[..., d] = np.where(slab_valid, slab_f[..., d] + box[d], 0.0)
        ef = np.concatenate([ef, slab_f], axis=d)
        ei = np.concatenate([ei, np.take(ei, [0], axis=d)], axis=d)
    return jnp.asarray(ef), jnp.asarray(ei)


def plan_tiers(sched, layout, cum):
    return tier_plan([int(v) for v in cum], psched.PAIR_BUCKET,
                     sched.n_pairs, psched.SLOT_QUANTUM, layout.capacity)


def eval_backends(layout, ext_f, ext_i, ff, params):
    """Dense + pruned-backend forces on the same extended arrays."""
    F_d, pe_d = compute_forces(ext_f, ext_i, layout, ff)
    sched = psched.PairSchedule.build(layout)
    sel, cum, _cum_in, occ = psched.prune_local(
        sched, ext_f, ext_i, psched.prune_radius(params))
    tiers = plan_tiers(sched, layout, cum)
    sel_exec = lax.slice(sel, (0,), (tier_rows(tiers),))
    out = {"dense": (F_d, pe_d),
           "_shapes": (int(cum[0]), tiers, int(occ))}
    for name in ("sparse", "pallas"):
        out[name] = psched.get_force_backend(name)(
            ext_f, ext_i, layout, ff, sched=sched, sel=sel_exec,
            tiers=tiers)
    return out


def assert_parity(out):
    F_d, pe_d = out["dense"]
    scale = max(float(jnp.abs(F_d).max()), 1.0)
    for name in ("sparse", "pallas"):
        F, pe = out[name]
        assert float(jnp.abs(F - F_d).max()) / scale < FORCE_RTOL, name
        assert abs(float(pe - pe_d)) / max(abs(float(pe_d)), 1.0) \
            < PE_RTOL, name


# ---- static worklist ------------------------------------------------------

def test_worklist_is_static_eighth_shell():
    layout = choose_layout((8.0, 8.0, 8.0), (1, 1, 1), 2.6, 400)
    sched = psched.PairSchedule.build(layout)
    ncells = layout.n_local_cells
    assert sched.n_pairs == 14 * ncells == len(stencil_pairs()) * ncells
    ne = sched.n_ext_cells
    assert sched.cell_a.min() >= 0 and sched.cell_a.max() < ne
    assert sched.cell_b.min() >= 0 and sched.cell_b.max() < ne
    assert int(sched.same.sum()) == ncells
    assert np.all(sched.cell_a[sched.same > 0]
                  == sched.cell_b[sched.same > 0])
    assert sched.dense_slot_pairs() == 14 * ncells * layout.capacity ** 2
    assert sched.levels == -(-layout.capacity // psched.SLOT_QUANTUM)


def test_worklist_rejects_single_global_cell():
    layout = choose_layout((3.0, 8.0, 8.0), (1, 1, 1), 2.6, 100)
    assert layout.global_cells[0] == 1
    with pytest.raises(ValueError, match="2 global cells"):
        psched.PairSchedule.build(layout)


def test_engine_degrades_to_dense_on_single_global_cell():
    """Tiny-box regression: the engine must not crash on layouts the
    pair schedule rejects — it degrades to the dense backend (which
    masks self-image pairs by atom id) with a warning, and the rolling
    prune is disabled along with it."""
    from repro.core.md import MDEngine
    from repro.launch.mesh import make_mesh

    sys_ = make_grappa_like(110, seed=3)
    mesh = make_mesh((1, 1, 1), ("z", "y", "x"))
    with pytest.warns(RuntimeWarning, match="degrades to the 'dense'"):
        eng = MDEngine(sys_, mesh, force_backend="sparse", nstprune=5)
    assert min(eng.layout.global_cells) == 1
    assert eng.force_backend == "dense"
    assert eng.nstprune == 0 and eng.pair_schedule is None
    (cf, ci), m, diags = eng.simulate(8)
    assert np.all(np.isfinite(np.asarray(m["pe"])))
    assert eng.pair_stats()["prune_ratio"] == 1.0


def test_bucket_quantization():
    assert bucket(0, 64, 1000) == 64
    assert bucket(65, 64, 1000) == 128
    assert bucket(999, 64, 140) == 140        # capped
    assert bucket(7, 4, 84) == 8
    assert bucket(84, 4, 84) == 84
    assert bucket0(0, 64, 1000) == 0          # empty tiers are dropped
    assert bucket0(1, 64, 1000) == 64


def test_tier_plan_ladder():
    # cum[l-1] = pairs needing level >= l; quantum 4, capacity 12
    tiers = tier_plan([100, 40, 3], 16, 1000, 4, 12)
    assert tiers == ((16, 12), (32, 8), (64, 4))
    assert tier_rows(tiers) == 112            # bucketed cum[0]
    assert tier_slot_pairs(tiers) == 16 * 144 + 32 * 64 + 64 * 16
    # inverse: row budget per level
    assert tier_cum(tiers, 4, 3) == (112, 48, 16)
    # empty levels are dropped, cap respected
    assert tier_plan([10, 0, 0], 16, 1000, 4, 12) == ((16, 4),)
    assert tier_plan([0, 0, 0], 16, 1000, 4, 12) == ()
    assert tier_rows(tier_plan([999, 999, 999], 16, 140, 4, 12)) == 140


def test_cell_counts_and_bounds():
    rng = np.random.RandomState(0)
    pos = rng.uniform(0, 2.0, (2, 3, 5)).astype(np.float32)
    ci = np.full((2, 3, 2), -1, np.int32)
    ci[0, :2, 0] = [4, 9]                       # cell 0: two atoms
    counts = cell_counts(jnp.asarray(ci))
    assert counts.tolist() == [2, 0]
    assert cell_levels(counts, 4).tolist() == [1, 0]
    assert cell_levels(jnp.asarray([5, 4, 13]), 4).tolist() == [2, 1, 4]
    lo, hi = cell_bounds(jnp.asarray(pos[..., :3]), jnp.asarray(ci))
    np.testing.assert_allclose(np.asarray(lo[0]),
                               pos[0, :2, :3].min(axis=0))
    np.testing.assert_allclose(np.asarray(hi[0]),
                               pos[0, :2, :3].max(axis=0))
    assert np.all(np.asarray(lo[1]) > np.asarray(hi[1]))   # empty: inverted


# ---- backend parity on a real system -------------------------------------

@pytest.fixture(scope="module")
def binned_system():
    sys_ = make_grappa_like(420, seed=5)
    layout = choose_layout(sys_.box, (1, 1, 1),
                           sys_.params.ff.r_cut * 1.08, sys_.n_atoms)
    feats_f = np.concatenate([sys_.charge[:, None], sys_.vel], axis=1)
    feats_i = np.stack([np.arange(sys_.n_atoms), sys_.typ],
                       axis=1).astype(np.int32)
    cell_f, cell_i, ovf = bin_to_cells(
        jnp.asarray(sys_.pos), jnp.asarray(feats_f), jnp.asarray(feats_i),
        layout, jnp.zeros(3, jnp.int32))
    assert int(ovf) == 0
    ext_f, ext_i = periodic_extend(np.asarray(cell_f)[..., :4], cell_i,
                                   sys_.box)
    return sys_, layout, ext_f, ext_i


def test_sparse_and_pallas_match_dense(binned_system):
    sys_, layout, ext_f, ext_i = binned_system
    out = eval_backends(layout, ext_f, ext_i, sys_.params.ff, sys_.params)
    assert_parity(out)
    n_keep, tiers, occ = out["_shapes"]
    # the headline claim: pruned work is at least 2x below dense at the
    # default 2.2 capacity safety, and the tier ladder never exceeds the
    # old single-rectangle (global k_exec) accounting
    sched = psched.PairSchedule.build(layout)
    assert tier_slot_pairs(tiers) * 2 <= sched.dense_slot_pairs()
    global_kexec = bucket(n_keep, psched.PAIR_BUCKET, sched.n_pairs) * \
        bucket(occ, psched.SLOT_QUANTUM, layout.capacity) ** 2
    assert tier_slot_pairs(tiers) <= global_kexec


def test_prune_is_conservative(binned_system):
    """Disabling the distance prune (huge radius) must not change forces —
    i.e. the bounded prune only ever removes non-contributing pairs."""
    sys_, layout, ext_f, ext_i = binned_system
    ff = sys_.params.ff
    sched = psched.PairSchedule.build(layout)
    sel_all, cum_all, _, occ = psched.prune_local(sched, ext_f, ext_i,
                                                  r_prune=1e6)
    sel, cum, _, _ = psched.prune_local(sched, ext_f, ext_i,
                                        psched.prune_radius(sys_.params))
    assert int(cum[0]) <= int(cum_all[0])
    k_exec = bucket(int(occ), psched.SLOT_QUANTUM, layout.capacity)
    F_a, pe_a = psched.get_force_backend("sparse")(
        ext_f, ext_i, layout, ff, sched=sched,
        sel=lax.slice(sel_all, (0,), (sched.n_pairs,)), k_exec=k_exec)
    tiers = plan_tiers(sched, layout, cum)
    F_p, pe_p = psched.get_force_backend("sparse")(
        ext_f, ext_i, layout, ff, sched=sched,
        sel=lax.slice(sel, (0,), (tier_rows(tiers),)), tiers=tiers)
    scale = max(float(jnp.abs(F_a).max()), 1.0)
    assert float(jnp.abs(F_a - F_p).max()) / scale < FORCE_RTOL


# ---- crafted occupancies: empty + capacity-full cells --------------------

def test_empty_and_overflow_adjacent_cells():
    """One cell at exactly capacity K, one region fully empty."""
    rng = np.random.RandomState(7)
    box = (10.8, 10.8, 10.8)
    layout = choose_layout(box, (1, 1, 1), 2.7, 120, min_capacity=8)
    cz, cy, cx = layout.cells_per_domain
    K = layout.capacity
    cs = np.asarray(layout.cell_size)

    pos, typ = [], []
    for iz in range(cz):
        for iy in range(cy):
            for ix in range(cx):
                if (iz, iy, ix) == (cz - 1, cy - 1, cx - 1):
                    n = 0                       # fully-empty cell
                elif (iz, iy, ix) == (0, 0, 0):
                    n = K                       # overflow-adjacent: full
                else:
                    # occupied but shallow (one quantum level below the
                    # full cell), so the tier ladder must split
                    n = int(rng.randint(1, max(K // 2, 2)))
                origin = np.asarray([iz, iy, ix]) * cs
                p = origin + rng.uniform(0.05, 0.95, (n, 3)) * cs
                pos.append(p)
                typ.append(rng.randint(0, 2, n))
    pos = np.concatenate(pos).astype(np.float32)
    typ = np.concatenate(typ).astype(np.int32)
    n_atoms = pos.shape[0]
    charge = (rng.uniform(size=n_atoms) - 0.5).astype(np.float32) * 0.5

    feats_f = np.concatenate([charge[:, None],
                              np.zeros((n_atoms, 3), np.float32)], axis=1)
    feats_i = np.stack([np.arange(n_atoms), typ], axis=1).astype(np.int32)
    cell_f, cell_i, ovf = bin_to_cells(
        jnp.asarray(pos), jnp.asarray(feats_f), jnp.asarray(feats_i),
        layout, jnp.zeros(3, jnp.int32))
    assert int(ovf) == 0
    counts = np.asarray(cell_counts(cell_i))
    assert counts[0, 0, 0] == K and counts[-1, -1, -1] == 0

    ext_f, ext_i = periodic_extend(np.asarray(cell_f)[..., :4], cell_i, box)
    params = MDParams(ff=DEFAULT_FF)
    out = eval_backends(layout, ext_f, ext_i, DEFAULT_FF, params)
    assert_parity(out)
    # empty-cell pairs must actually be pruned, and the tier ladder must
    # be heterogeneous: the full cell forces one max-level tier while
    # the shallow cells populate cheaper tiers
    sched = psched.PairSchedule.build(layout)
    _, cum, _, occ = psched.prune_local(sched, ext_f, ext_i,
                                        psched.prune_radius(params))
    assert int(cum[0]) < sched.n_pairs
    assert int(occ) == K                        # the full cell tops a tier
    tiers = plan_tiers(sched, layout, cum)
    assert tiers[0][1] == K                     # deepest tier at capacity
    assert len(tiers) >= 2                      # shallow tiers split off
    assert tier_slot_pairs(tiers) < tier_rows(tiers) * K ** 2


# ---- hypothesis sweep -----------------------------------------------------

@given(n=st.integers(200, 420), seed=st.integers(0, 10_000))
@settings(max_examples=6, deadline=None)
def test_backend_parity_random_systems(n, seed):
    sys_ = make_grappa_like(n, seed=seed)
    layout = choose_layout(sys_.box, (1, 1, 1),
                           sys_.params.ff.r_cut * 1.08, sys_.n_atoms)
    feats_f = np.concatenate([sys_.charge[:, None], sys_.vel], axis=1)
    feats_i = np.stack([np.arange(n), sys_.typ], axis=1).astype(np.int32)
    cell_f, cell_i, ovf = bin_to_cells(
        jnp.asarray(sys_.pos), jnp.asarray(feats_f), jnp.asarray(feats_i),
        layout, jnp.zeros(3, jnp.int32))
    assert int(ovf) == 0
    ext_f, ext_i = periodic_extend(np.asarray(cell_f)[..., :4], cell_i,
                                   sys_.box)
    out = eval_backends(layout, ext_f, ext_i, sys_.params.ff, sys_.params)
    assert_parity(out)


# ---- dual pair-list properties (random occupancy + drift replays) ---------

def _random_binned(n, seed):
    """A binned random system + its periodic extension (numpy views)."""
    sys_ = make_grappa_like(n, seed=seed)
    layout = choose_layout(sys_.box, (1, 1, 1),
                           sys_.params.ff.r_cut * 1.08, sys_.n_atoms)
    feats_f = np.concatenate([sys_.charge[:, None], sys_.vel], axis=1)
    feats_i = np.stack([np.arange(n), sys_.typ], axis=1).astype(np.int32)
    cell_f, cell_i, ovf = bin_to_cells(
        jnp.asarray(sys_.pos), jnp.asarray(feats_f), jnp.asarray(feats_i),
        layout, jnp.zeros(3, jnp.int32))
    assert int(ovf) == 0
    return sys_, layout, np.asarray(cell_f), np.asarray(cell_i)


def _drifted_ext(cell_f, cell_i, box, budget, seed):
    """Displace every occupied slot by a random vector of norm <= budget
    (cell membership frozen — the within-block invariant), re-extend."""
    rng = np.random.RandomState(seed)
    disp = rng.normal(size=cell_f.shape[:-1] + (3,))
    norm = np.linalg.norm(disp, axis=-1, keepdims=True)
    disp = disp / np.maximum(norm, 1e-9) * \
        rng.uniform(0, budget, norm.shape)
    moved = cell_f.copy()
    valid = (cell_i[..., 0] >= 0)[..., None]
    moved[..., :3] = np.where(valid, moved[..., :3] + disp, 0.0)
    return periodic_extend(moved[..., :4], jnp.asarray(cell_i), box)


def _pair_min_dist(sched, ext_f, ext_i):
    """Brute-force per-worklist-pair min atom distance (numpy oracle)."""
    ne = sched.n_ext_cells
    K = np.asarray(ext_f).shape[3]
    f2 = np.asarray(ext_f).reshape(ne, K, -1)[..., :3]
    valid = np.asarray(ext_i)[..., 0].reshape(ne, K) >= 0
    out = np.full(sched.n_pairs, np.inf)
    for p in range(sched.n_pairs):
        a, b, same = sched.cell_a[p], sched.cell_b[p], sched.same[p]
        va, vb = valid[a], valid[b]
        if not va.any() or not vb.any():
            continue
        d = np.linalg.norm(f2[a][va][:, None] - f2[b][vb][None], axis=-1)
        if same:
            if va.sum() < 2:
                continue
            d = d[np.triu_indices(va.sum(), k=1)]
        out[p] = d.min() if d.size else np.inf
    return out


@given(n=st.integers(150, 260), seed=st.integers(0, 1000),
       dseed=st.integers(0, 1000))
@settings(max_examples=4, deadline=None)
def test_outer_list_conservative_under_drift(n, seed, dseed):
    """Any pair within r_cut at ANY bounded-drift replay state before the
    next rebuild must be on the outer list built at rebin time."""
    sys_, layout, cell_f, cell_i = _random_binned(n, seed)
    sched = psched.PairSchedule.build(layout)
    r_outer = psched.prune_radius(sys_.params)
    budget = (r_outer - sys_.params.ff.r_cut) / 2.0   # per-atom drift bound
    ext_f0, ext_i0 = periodic_extend(cell_f[..., :4], jnp.asarray(cell_i),
                                     sys_.box)
    sel, cum, _, _ = psched.prune_local(sched, ext_f0, ext_i0, r_outer)
    kept = set(np.asarray(sel)[:int(cum[0])].tolist())
    ext_fd, ext_id = _drifted_ext(cell_f, cell_i, sys_.box, budget, dseed)
    dmin = _pair_min_dist(sched, ext_fd, ext_id)
    within = np.where(dmin < sys_.params.ff.r_cut)[0]
    missing = [int(p) for p in within if int(p) not in kept]
    assert not missing, f"outer list dropped in-range pairs {missing[:5]}"


@given(n=st.integers(150, 260), seed=st.integers(0, 1000),
       dseed=st.integers(0, 1000))
@settings(max_examples=4, deadline=None)
def test_inner_prune_never_drops_within_inner_radius(n, seed, dseed):
    """After a drift replay, roll_prune's survivor prefix must contain
    every outer pair whose current min atom distance is < r_inner (the
    bbox gap lower-bounds atom distances, so this holds by construction
    — the test pins it against a brute-force oracle)."""
    sys_, layout, cell_f, cell_i = _random_binned(n, seed)
    sched = psched.PairSchedule.build(layout)
    params = sys_.params
    r_outer = psched.prune_radius(params)
    r_inner = psched.inner_radius(params, nstprune=5)
    ext_f0, ext_i0 = periodic_extend(cell_f[..., :4], jnp.asarray(cell_i),
                                     sys_.box)
    sel, cum, _, _ = psched.prune_local(sched, ext_f0, ext_i0, r_outer)
    tiers = plan_tiers(sched, layout, cum)
    sel_exec = lax.slice(sel, (0,), (tier_rows(tiers),))
    budget = (r_outer - params.ff.r_cut) / 2.0
    ext_fd, ext_id = _drifted_ext(cell_f, cell_i, sys_.box, budget, dseed)
    new_sel, cum_s = psched.roll_prune(sched, sel_exec, ext_fd, ext_id,
                                       r_inner)
    survivors = set(np.asarray(new_sel)[:int(cum_s[0])].tolist())
    dmin = _pair_min_dist(sched, ext_fd, ext_id)
    in_prefix = set(np.asarray(sel_exec).tolist())
    for p in np.where(dmin < r_inner)[0]:
        if int(p) in in_prefix:
            assert int(p) in survivors, \
                f"inner prune dropped pair {p} at d={dmin[p]:.3f}"
    # permutation: the refresh reorders, never duplicates or loses rows
    assert sorted(np.asarray(new_sel).tolist()) == \
        sorted(np.asarray(sel_exec).tolist())


@given(n=st.integers(150, 260), seed=st.integers(0, 1000))
@settings(max_examples=4, deadline=None)
def test_per_pair_bounds_and_packing_permutation(n, seed):
    """The occupancy-sorted packing is a permutation of the kept rows,
    and every packed row lands in a tier whose slot depth covers BOTH
    cells' real occupancy (per-pair bounds never truncate)."""
    sys_, layout, cell_f, cell_i = _random_binned(n, seed)
    sched = psched.PairSchedule.build(layout)
    ext_f, ext_i = periodic_extend(cell_f[..., :4], jnp.asarray(cell_i),
                                   sys_.box)
    sel, cum, _, occ = psched.prune_local(sched, ext_f, ext_i,
                                          psched.prune_radius(sys_.params))
    sel_np = np.asarray(sel)
    n_keep = int(cum[0])
    packed, tail = sel_np[:n_keep], sel_np[n_keep:]
    assert np.all(tail == sched.n_pairs)              # sentinel-only tail
    assert len(set(packed.tolist())) == n_keep        # no duplicates
    ne = sched.n_ext_cells
    K = layout.capacity
    counts = np.asarray(cell_counts(ext_i)).reshape(ne)
    tiers = plan_tiers(sched, layout, cum)
    assert tier_rows(tiers) >= n_keep                 # nothing spills
    row = 0
    for n_t, k_t in tiers:
        for r in range(row, row + n_t):
            if r >= n_keep:
                break
            p = int(packed[r])
            bound = max(counts[sched.cell_a[p]], counts[sched.cell_b[p]])
            assert bound <= k_t, (r, p, bound, k_t)
        row += n_t
    # levels are packed descending (dense pairs first, tail shrinks)
    lvls = np.maximum(
        -(-counts[sched.cell_a[packed]] // psched.SLOT_QUANTUM),
        -(-counts[sched.cell_b[packed]] // psched.SLOT_QUANTUM))
    assert np.all(np.diff(lvls) <= 0)
    assert int(occ) == counts.max()


# ---- overlap_rebin: fused rebin/migration/prune invariants ----------------

def test_overlap_rebin_fused_path_matches_host_dispatch():
    """24 steps (nstlist=20: one rebin/migration/prune boundary): fusing
    the DLB work into the block program must (a) reproduce the
    host-dispatched trajectory and migration diagnostics bit for bit,
    (b) hand the next block the exact same pruned schedule, and (c) keep
    the prune conservative across the block boundary — evaluating the
    full unpruned worklist on the final state changes nothing."""
    from repro.core.halo_plan import HaloSpec
    from repro.core.md import MDEngine, make_grappa_like
    from repro.launch.mesh import make_mesh

    sys_ = make_grappa_like(300, seed=9)
    mesh = make_mesh((1, 1, 1), ("z", "y", "x"))
    spec = HaloSpec(axis_names=("z", "y", "x"), widths=(1, 1, 1),
                    backend="fused")
    host = MDEngine(sys_, mesh, spec, force_backend="sparse", nstprune=4)
    fused = MDEngine(sys_, mesh, spec, force_backend="sparse", nstprune=4,
                     overlap_rebin=True)
    (cf_h, ci_h), m_h, d_h = host.simulate(24)
    (cf_f, ci_f), m_f, d_f = fused.simulate(24)

    np.testing.assert_array_equal(np.asarray(cf_f), np.asarray(cf_h))
    np.testing.assert_array_equal(np.asarray(ci_f), np.asarray(ci_h))
    for k in m_h:
        np.testing.assert_array_equal(np.asarray(m_f[k]),
                                      np.asarray(m_h[k]))
    assert len(d_f) == len(d_h)
    for a, b in zip(d_f, d_h):
        for k in b:
            np.testing.assert_array_equal(np.asarray(a[k]),
                                          np.asarray(b[k]))

    # (b) identical post-boundary exec schedule (fused prune == prune_fn)
    sel_h, t_h, ti_h = host._sched_exec
    sel_f, t_f, ti_f = fused._sched_exec
    assert (t_h, ti_h) == (t_f, ti_f)
    np.testing.assert_array_equal(np.asarray(sel_f), np.asarray(sel_h))

    # (c) conservativeness across the boundary: the pruned schedule's
    # forces on the final state match the full unpruned worklist's
    F_pruned, pe_pruned = fused._force_fn_sched(cf_f, ci_f, sel_f, t_f)
    sched = fused.pair_schedule
    k_max = max(k for _, k in t_f)
    F_full, pe_full = fused._force_fn_sched(
        cf_f, ci_f, sel_f, ((sched.n_pairs, k_max),))
    scale = max(float(jnp.abs(F_full).max()), 1.0)
    assert float(jnp.abs(F_pruned - F_full).max()) / scale < FORCE_RTOL
    assert abs(float(pe_pruned - pe_full)) / \
        max(abs(float(pe_full)), 1.0) < PE_RTOL


# ---- sparse forces against the O(N^2) oracle ------------------------------

def test_sparse_engine_matches_direct_oracle():
    from repro.core.halo_plan import HaloSpec
    from repro.core.md import MDEngine, direct_forces_reference
    from repro.launch.mesh import make_mesh

    sys_ = make_grappa_like(300, seed=11)
    mesh = make_mesh((1, 1, 1), ("z", "y", "x"))
    spec = HaloSpec(axis_names=("z", "y", "x"), widths=(1, 1, 1),
                    backend="fused")
    eng = MDEngine(sys_, mesh, spec, force_backend="sparse")
    cf, ci = eng.init_state()
    cf, ci, force, diag = eng.rebin_fn(cf, ci)
    eng._refresh_schedule(cf, ci)
    f_s, pe_s = eng.force_fn(cf, ci)
    f_eng, = eng.gather_by_id([f_s], ci)
    f_ref, _ = direct_forces_reference(sys_.pos, sys_.charge, sys_.typ,
                                       sys_.box, sys_.params.ff)
    scale = np.abs(f_ref).max()
    assert np.abs(f_eng - f_ref).max() / scale < 5e-5
    assert eng.pair_stats()["prune_ratio"] >= 2.0
    # the tier ladder beats (or matches) the single-rectangle schedule
    ps = eng.pair_stats()
    assert ps["evaluated_slot_pairs"] <= ps["global_kexec_slot_pairs"]
