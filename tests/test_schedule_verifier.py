"""Static comm-schedule verifier: grids, rejections, gates, replay parity.

Locks in the PR's static-analysis tentpole:

* every cell of the PR4 conformance matrix and the PR5 prune grid is
  statically SAFE (the same grids CI verifies via ``python -m
  repro.analysis``);
* an over-deep window (``window > depth``) is statically rejected with a
  ``SLOT_CLOBBER`` counterexample event trace;
* dropping the per-step ``optimization_barrier`` pin on a skew-2 window
  is caught by the happens-before pass (``UNORDERED_REUSE``) even though
  the linear replay alone would pass it;
* the config checks reject nonsense ``HaloSpec``/``MDEngine`` shapes
  with actionable messages (and preserve ``make_schedule``'s wording);
* the ``verify=`` build gates error / warn / skip as documented, on both
  ``StepPipeline.build`` and ``MDEngine``;
* the static verdict agrees with a runtime :class:`SignalLedger` replay
  of the extracted event sequence (property-based when ``hypothesis`` is
  installed).
"""
import warnings

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from _hypothesis_stub import given, settings, st

from repro.analysis import grids
from repro.analysis.schedule_verifier import (
    MODES,
    ConfigError,
    ScheduleConfig,
    ScheduleVerificationError,
    check_halo_config,
    check_md_config,
    extract_events,
    gate_md_build,
    gate_pipeline_build,
    probe_steps,
    verify_build,
    verify_schedule,
)


# --------------------------------------------------------------------------
# the shipped grids are exhaustively safe
# --------------------------------------------------------------------------

PR4 = grids.pr4_grid()
PR5 = grids.pr5_prune_grid()


def _cfg_id(c):
    return (f"{c.backend}-{c.mode}-d{c.depth}-p{c.n_pulses}"
            f"-np{c.nstprune}-ovr{int(c.overlap_rebin)}")


def test_pr4_grid_shape():
    """48 cells: 4 backends x 2 modes x 2 widths x 3 depths."""
    assert len(PR4) == 48
    assert {c.backend for c in PR4} == set(grids.PR4_BACKENDS)
    assert all(c.n_steps == grids.PR4_STEPS for c in PR4)


@pytest.mark.parametrize("cfg", PR4, ids=[_cfg_id(c) for c in PR4])
def test_pr4_grid_statically_safe(cfg):
    report = verify_schedule(cfg)
    assert report.safe, report.counterexample()
    assert report.violations == ()
    assert report.counterexample() == ""
    # every deposit consumed: releases balance acquires, ring never
    # holds more than one deposit per slot
    assert report.stats["releases"] == report.stats["acquires"]
    assert report.stats["max_in_flight"] == 1


@pytest.mark.parametrize("cfg", PR5, ids=[_cfg_id(c) for c in PR5])
def test_pr5_prune_grid_statically_safe(cfg):
    report = verify_schedule(cfg)
    assert report.safe, report.counterexample()
    assert report.stats["releases"] == report.stats["acquires"]
    if cfg.nstprune:
        # nstlist=20 / nstprune=4 -> five fresh-ledger sub-blocks
        # (+1 rebin segment when the overlap region is fused on)
        assert report.stats["n_segments"] == 5 + int(cfg.overlap_rebin)


def test_probe_steps_cover_ring_phase_space():
    """Probes reach past 2*depth (every (phase, drain) pair) and always
    include the caller's nstlist and the prune split points."""
    ps = probe_steps(3, nstprune=4, n_steps=20)
    assert set(range(1, 10)) <= set(ps)
    assert {4, 5, 9, 20} <= set(ps)


def test_verify_build_safe_over_all_probes():
    rep = verify_build(mode="double_buffer", depth=4, n_pulses=3)
    assert rep.safe


# --------------------------------------------------------------------------
# unsafe schedules: over-deep window, missing step barrier
# --------------------------------------------------------------------------

def test_over_deep_window_rejected_with_counterexample():
    """window > depth reuses a slot before its deposit drains: the
    verifier must find the clobber and show the offending event pair."""
    report = verify_schedule(ScheduleConfig(depth=2, window=3, n_steps=8))
    assert not report.safe
    first = report.violations[0]
    assert first.code == "SLOT_CLOBBER"
    assert "still-outstanding deposit" in first.message
    cx = report.counterexample()
    assert "SLOT_CLOBBER" in cx
    assert "clobbers the deposit" in cx
    # the trace marks both the clobbered release and the clobbering one
    marked = [ln for ln in first.trace if ln.startswith(">>")]
    assert len(marked) == 2
    assert all("release rev" in ln for ln in marked)


@pytest.mark.parametrize("depth", (2, 3, 4))
def test_window_within_depth_is_safe(depth):
    for w in range(1, depth + 1):
        rep = verify_schedule(ScheduleConfig(depth=depth, window=w,
                                             n_steps=2 * depth + 3))
        assert rep.safe, (depth, w, rep.counterexample())


def test_unbarriered_skew2_fails_happens_before():
    """depth=3 window=2 passes the linear replay — only the per-step
    ``optimization_barrier`` pin orders the slot reuse behind the
    previous acquire.  Dropping the barrier must flip the verdict."""
    pinned = verify_schedule(ScheduleConfig(depth=3, window=2, n_steps=8))
    assert pinned.safe
    loose = verify_schedule(ScheduleConfig(depth=3, window=2, n_steps=8,
                                           step_barrier=False))
    assert not loose.safe
    assert {v.code for v in loose.violations} == {"UNORDERED_REUSE"}
    assert "no happens-before path" in loose.violations[0].message


def test_report_to_dict_roundtrips_config():
    rep = verify_schedule(ScheduleConfig(depth=2, window=3, n_steps=6))
    d = rep.to_dict()
    assert d["safe"] is False
    assert d["config"]["window"] == 3
    assert d["violations"][0]["code"] == "SLOT_CLOBBER"
    assert isinstance(d["violations"][0]["trace"], list)


# --------------------------------------------------------------------------
# config validation (ConfigError regressions)
# --------------------------------------------------------------------------

def test_modes_in_sync_with_pipeline():
    """The verifier keeps a jax-free copy of PIPELINE_MODES; they must
    never drift."""
    from repro.core.pipeline import PIPELINE_MODES
    assert MODES == PIPELINE_MODES


@pytest.mark.parametrize("kw,match", [
    (dict(mode="triple"), "unknown pipeline mode"),
    (dict(mode="double_buffer", depth=1), "depth >= 2"),
    (dict(depth=0), "depth must be >= 1"),
    (dict(n_steps=0), "n_steps must be >= 1"),
    (dict(window=0), "acquire skew"),
    (dict(n_pulses=0), "n_pulses must be >= 1"),
    (dict(nstprune=-1), "nstprune must be >= 0"),
])
def test_schedule_config_validation(kw, match):
    with pytest.raises(ConfigError, match=match):
        verify_schedule(ScheduleConfig(**kw))


def test_check_halo_config_rejections():
    with pytest.raises(ConfigError, match="duplicate mesh axis"):
        check_halo_config(("z", "z"), (1, 1))
    with pytest.raises(ConfigError, match="widths must be >= 0"):
        check_halo_config(("z",), (-1,))
    # make_schedule's own rejections surface with their original wording
    with pytest.raises(ConfigError, match="equal length"):
        check_halo_config(("z", "y"), (1,))
    with pytest.raises(ConfigError, match="at least one pulse"):
        check_halo_config(("z",), (1,), pulses=(0,))
    # and the happy path returns the pulse schedule
    sched = check_halo_config(("z", "y"), (2, 1))
    assert sched.total_pulses >= 2


def test_from_spec_derives_pulses_and_rejects():
    cfg = ScheduleConfig.from_spec(("z", "y", "x"), (1, 1, 1))
    assert cfg.n_pulses == 3
    with pytest.raises(ConfigError, match="duplicate mesh axis"):
        ScheduleConfig.from_spec(("z", "z"), (1, 1))


@pytest.mark.parametrize("kw,match", [
    (dict(nstlist=0), "nstlist must be >= 1"),
    (dict(nstprune=25), "exceeds the nstlist block length"),
    (dict(nstprune=4, inner_safety=0.0), "inner_safety must be > 0"),
    (dict(r_list_factor=0.9), "r_list_factor must be >= 1"),
    (dict(mig_frac=0.0), "mig_frac must be > 0"),
    (dict(capacity_safety=0.5), "capacity_safety must be >= 1"),
])
def test_check_md_config_rejections(kw, match):
    base = dict(nstlist=20, nstprune=0, pipeline="double_buffer",
                pipeline_depth=2, overlap_rebin=False,
                force_backend="sparse")
    base.update(kw)
    with pytest.raises(ConfigError, match=match):
        check_md_config(**base)


def test_check_md_config_returns_realized_schedule():
    cfg = check_md_config(nstlist=20, nstprune=4, pipeline="double_buffer",
                          pipeline_depth=3, overlap_rebin=True,
                          force_backend="sparse")
    assert cfg == ScheduleConfig(mode="double_buffer", depth=3,
                                 n_steps=20, nstprune=4,
                                 overlap_rebin=True,
                                 force_backend="sparse")
    assert verify_schedule(cfg).safe


# --------------------------------------------------------------------------
# build gates: error / warn / off
# --------------------------------------------------------------------------

def test_gate_pipeline_build_error_carries_report():
    with pytest.raises(ScheduleVerificationError) as ei:
        gate_pipeline_build(mode="double_buffer", depth=2, n_pulses=1,
                            backend="signal", window=3)
    assert "SLOT_CLOBBER" in str(ei.value)
    assert "clobbers the deposit" in str(ei.value)   # trace is embedded
    assert not ei.value.report.safe


def test_gate_pipeline_build_warn_and_off():
    with pytest.warns(RuntimeWarning, match="statically unsafe"):
        rep = gate_pipeline_build(mode="double_buffer", depth=2,
                                  n_pulses=1, backend="signal",
                                  window=3, verify="warn")
    assert rep is not None and not rep.safe
    assert gate_pipeline_build(mode="double_buffer", depth=2, n_pulses=1,
                               backend="signal", window=3,
                               verify="off") is None
    with pytest.raises(ValueError, match="unknown verify mode"):
        gate_pipeline_build(mode="off", depth=2, n_pulses=1,
                            backend="signal", verify="loud")


def test_gate_pipeline_build_safe_config_reports():
    rep = gate_pipeline_build(mode="double_buffer", depth=3, n_pulses=2,
                              backend="pallas")
    assert rep.safe


def test_gate_md_build_rejects_and_warns():
    bad = dict(nstlist=20, nstprune=25, pipeline="double_buffer",
               pipeline_depth=2, overlap_rebin=False,
               force_backend="sparse")
    with pytest.raises(ConfigError, match="exceeds the nstlist"):
        gate_md_build(**bad)
    with pytest.warns(RuntimeWarning, match="rejected by the static"):
        assert gate_md_build(**bad, verify="warn") is None
    assert gate_md_build(**bad, verify="off") is None
    good = dict(bad, nstprune=4)
    assert gate_md_build(**good).safe


def test_step_pipeline_build_gate_integration():
    """The real ``StepPipeline.build`` runs the gate and records the
    report; ``verify='off'`` skips it."""
    from repro.core.halo_plan import HaloPlan, HaloSpec
    from repro.core.pipeline import StepPipeline
    from repro.launch.mesh import make_mesh
    from test_pipeline import _toy_fns

    mesh = make_mesh((1,), ("z",))
    plan = HaloPlan.build(HaloSpec(("z",), (1,)), mesh)
    pipe = StepPipeline.build(plan, _toy_fns(), mode="double_buffer",
                              depth=3)
    assert pipe.schedule_report is not None and pipe.schedule_report.safe
    off = StepPipeline.build(plan, _toy_fns(), mode="off", verify="off")
    assert off.schedule_report is None


def test_halo_plan_rejects_duplicate_axes():
    from repro.core.halo_plan import HaloPlan, HaloSpec
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1, 1), ("z", "y"))
    with pytest.raises(ConfigError, match="duplicate mesh axis"):
        HaloPlan.build(HaloSpec(("z", "z"), (1, 1)), mesh)


def test_md_engine_gate_rejects_before_tracing():
    """A nonsense engine config fails fast in ``__init__`` — the gate
    fires before any program is built or traced."""
    from repro.core.md import MDEngine, make_grappa_like
    from repro.launch.mesh import make_mesh

    sys_ = make_grappa_like(512, seed=0)
    mesh = make_mesh((1, 1, 1), ("z", "y", "x"))
    with pytest.raises(ConfigError, match="exceeds the nstlist"):
        MDEngine(sys_, mesh, force_backend="sparse", nstprune=25)
    with pytest.raises(ConfigError, match="r_list_factor"):
        MDEngine(sys_, mesh, force_backend="sparse", nstprune=4,
                 r_list_factor=0.5)


# --------------------------------------------------------------------------
# static verdict == runtime SignalLedger replay
# --------------------------------------------------------------------------

def _replay_through_ledger(cfg):
    """Feed each segment's ledgered events through a real SignalLedger
    (fresh per segment, as run_local re-inits) and collect summaries."""
    from repro.core.pipeline.ledger import SignalLedger

    out = []
    for seg in extract_events(cfg):
        led = SignalLedger(depth=cfg.ring_depth, n_pulses=cfg.n_pulses)
        st_ = led.init()
        for ev in seg.events:
            if not ev.ledgered:
                continue
            if ev.op == "release":
                st_ = led.release(st_, ev.kind, ev.slot)
            else:
                st_ = led.acquire(st_, ev.kind, ev.slot)
        out.append((seg, led, led.summary(st_)))
    return out


@pytest.mark.parametrize("cfg", [
    ScheduleConfig(mode="off", n_steps=5),
    ScheduleConfig(depth=2, n_steps=8),
    ScheduleConfig(depth=3, n_steps=7, n_pulses=3),
    ScheduleConfig(depth=4, n_steps=20, nstprune=4, overlap_rebin=True,
                   force_backend="sparse"),
    ScheduleConfig(depth=2, window=3, n_steps=8),       # unsafe
], ids=["off", "d2", "d3-p3", "prune-rebin", "overdeep"])
def test_static_verdict_matches_ledger_replay(cfg):
    report = verify_schedule(cfg)
    clobbers = total_in_flight = 0
    for seg, led, summary in _replay_through_ledger(cfg):
        assert summary["consistent"]
        clobbers += summary["clobbers"]
        total_in_flight += summary["in_flight"]
    static_clobbers = sum(1 for v in report.violations
                          if v.code == "SLOT_CLOBBER")
    # the ledger counts one clobber per pulse signal on the slot
    assert clobbers == static_clobbers * cfg.n_pulses
    if report.safe:
        assert clobbers == 0 and total_in_flight == 0
    else:
        assert clobbers > 0 or total_in_flight > 0


@given(depth=st.integers(2, 4), window=st.integers(1, 6),
       n_steps=st.integers(1, 12), n_pulses=st.integers(1, 3))
@settings(max_examples=25, deadline=None)
def test_replay_agreement_property(depth, window, n_steps, n_pulses):
    """For any drawn config the static SLOT_CLOBBER count equals the
    runtime ledger's clobber counter, and a SAFE verdict implies the
    ledger's window-safety + drain invariants hold."""
    cfg = ScheduleConfig(depth=depth, window=window, n_steps=n_steps,
                         n_pulses=n_pulses)
    report = verify_schedule(cfg)
    clobbers = in_flight = 0
    for seg, led, summary in _replay_through_ledger(cfg):
        clobbers += summary["clobbers"]
        in_flight += summary["in_flight"]
    static_clobbers = sum(1 for v in report.violations
                          if v.code == "SLOT_CLOBBER")
    assert clobbers == static_clobbers * n_pulses
    if report.safe:
        assert clobbers == 0 and in_flight == 0
        assert all(s["window_safe"]
                   for _, _, s in _replay_through_ledger(cfg))
    if window > depth and n_steps > depth:
        assert not report.safe          # over-deep windows never pass
