"""Self-healing MD runtime: inject -> detect -> recover, deterministically.

The contract under test (ISSUE 8 acceptance bar):

* **disarmed is free** — an ``inject=True, health=True`` engine driven by
  the runner with an empty fault plan visits bitwise-identical states to
  the plain engine's ``simulate`` (the injection seams trace to the same
  program while disarmed, and the monitors ride existing block metrics);
* **one-shot scan faults roll back bitwise** — every traced fault site
  (NaN'd halo payload, NaN'd force kernel, dropped put-with-signal
  release) is detected within its block, the runner rewinds to the last
  good checkpoint, and the finished trajectory bitwise-matches the
  fault-free reference (blocks are deterministic; checkpoints hold the
  pre-rebin boundary state so restore replays the exact same rebin);
* **sticky faults walk the degrade ladder** — a fault retries cannot
  outrun escalates to the rung that removes the component (e.g. the
  serialized halo backend, which has no put-with-signal to drop);
  degraded runs finish within the NVE drift bound, not bitwise (a
  backend swap regroups partial force sums);
* **host faults** — a forced inner-ladder overflow takes the engine's
  own outer-ladder fallback (warn-once + counter + next-block downgrade,
  satellite S3), a process kill resumes bitwise from the checkpoint
  chain, and a device loss reshards onto the spare mesh within the NVE
  drift bound (rebinning changes summation order, so NOT bitwise).

Multi-device (8 virtual) coverage lives in ``tests/dist/check_faults.py``.
"""
import numpy as np
import pytest

from repro.core.md import MDEngine, make_grappa_like
from repro.launch.mesh import make_mesh
from repro.resilience import (
    DEFAULT_RUNGS,
    DegradeLadder,
    FaultPlan,
    FaultSpec,
    HealthMonitor,
    ProcessKilled,
    RecoveryExhausted,
    RecoveryPolicy,
    ResilientMDRunner,
    Watchdog,
)

N_STEPS = 18          # 3 blocks of nstlist=6
NSTLIST = 6


@pytest.fixture(scope="module")
def system():
    return make_grappa_like(300, seed=11, nstlist=NSTLIST)


@pytest.fixture(scope="module")
def mesh():
    return make_mesh((1, 1, 1), ("z", "y", "x"))


@pytest.fixture(scope="module")
def reference(system, mesh):
    """Fault-free trajectory from the plain engine (no inject, no health)."""
    eng = MDEngine(system, mesh)
    (cf, ci), metrics, _ = eng.simulate(N_STEPS)
    return {"cell_f": np.asarray(cf), "cell_i": np.asarray(ci),
            "atoms": eng.export_atoms((cf, ci)), "metrics": metrics}


@pytest.fixture(scope="module")
def inj_engine(system, mesh):
    """One compiled inject+health engine shared by the recovery tests."""
    return MDEngine(system, mesh, inject=True, health=True)


def _runner(eng, ckpt_dir, plan=None, **kw):
    return ResilientMDRunner(eng, ckpt_dir, plan=plan, **kw)


# --------------------------------------------------------------------------
# disarmed == free
# --------------------------------------------------------------------------

def test_disarmed_runner_is_bitwise_and_silent(inj_engine, reference,
                                               tmp_path):
    (cf, ci), metrics, report = _runner(
        inj_engine, tmp_path / "ck").run(N_STEPS)
    np.testing.assert_array_equal(np.asarray(cf), reference["cell_f"])
    np.testing.assert_array_equal(np.asarray(ci), reference["cell_i"])
    assert report["events"] == [] and report["recoveries"] == []
    assert report["wasted_steps"] == 0 and not report["resharded"]
    # monitors rode the block metrics and saw nothing
    assert (metrics["health/nonfinite"] == 0).all()
    assert (metrics["health/led_violation"] == 0).all()
    # every clean block boundary checkpointed (plus the step-0 anchor)
    assert report["checkpoint_steps"] == [0, 6, 12, 18]


def test_physics_metrics_survive_injection_plumbing(inj_engine, reference,
                                                    tmp_path):
    """pe/ke series of the disarmed injected run == plain simulate's."""
    _, metrics, _ = _runner(inj_engine, tmp_path / "ck").run(N_STEPS)
    for key in ("pe", "ke"):
        np.testing.assert_array_equal(metrics[key],
                                      reference["metrics"][key])


# --------------------------------------------------------------------------
# one-shot scan faults: detect within the block, roll back bitwise
# --------------------------------------------------------------------------

@pytest.mark.parametrize("site,step,kind", [
    ("halo_corrupt", 8, "nonfinite"),
    ("force_nan", 13, "nonfinite"),
    ("signal_drop", 2, "ledger"),
])
def test_one_shot_fault_detected_and_rolled_back(inj_engine, reference,
                                                 tmp_path, site, step, kind):
    plan = FaultPlan([FaultSpec(site, step)])
    runner = _runner(inj_engine, tmp_path / "ck", plan=plan)
    (cf, ci), _, report = runner.run(N_STEPS)

    assert len(report["recoveries"]) == 1
    rec = report["recoveries"][0]
    assert rec["action"] == "rollback" and kind in rec["kinds"]
    # detected within the faulted block (one-block latency bound)
    assert 0 < rec["detection_latency_steps"] <= NSTLIST
    assert rec["block_step"] == (step // NSTLIST) * NSTLIST
    assert report["wasted_steps"] == rec["rollback_steps"] <= NSTLIST
    assert plan.summary()["fired"] == [True]

    # the retried trajectory converges bitwise on the fault-free run
    np.testing.assert_array_equal(np.asarray(cf), reference["cell_f"])
    np.testing.assert_array_equal(np.asarray(ci), reference["cell_i"])


def test_fault_runs_are_deterministic(inj_engine, tmp_path):
    """Same plan, same seed state -> byte-identical recovery report."""
    def one(d):
        plan = FaultPlan([FaultSpec("force_nan", 7)])
        _, _, report = _runner(inj_engine, d, plan=plan).run(N_STEPS)
        return report
    r1 = one(tmp_path / "a")
    r2 = one(tmp_path / "b")
    assert r1["recoveries"] == r2["recoveries"]
    assert r1["events"] == r2["events"]


# --------------------------------------------------------------------------
# sticky fault: retries exhaust, the ladder removes the component
# --------------------------------------------------------------------------

def test_sticky_fault_walks_degrade_ladder(inj_engine, reference, tmp_path):
    plan = FaultPlan([FaultSpec("signal_drop", 2, sticky=True)])
    runner = _runner(inj_engine, tmp_path / "ck", plan=plan,
                     policy=RecoveryPolicy(max_retries=2,
                                           backoff_base_s=0.0))
    (cf, ci), _, report = runner.run(N_STEPS)

    actions = [r["action"] for r in report["recoveries"]]
    assert actions == ["rollback", "rollback", "degrade"]
    assert report["recoveries"][-1]["detail"] == "serialized_halo"
    assert report["ladder"]["applied"] == ["serialized_halo"]
    # the rung physically removed the faulted seam
    assert set(report["fault_plan"]["disabled_sites"]) == \
        {"halo_corrupt", "signal_drop"}
    assert runner.engine is not inj_engine
    assert runner.engine.spec.backend == "serialized"
    # the serialized backend regroups halo partial sums differently from
    # the fused default, so degrade lands within float accumulation
    # noise of the reference, not bitwise (the ISSUE 8 acceptance bar:
    # rollback is bitwise, degrade is drift-bound); cell assignment is
    # identical, only force summation order moved
    np.testing.assert_array_equal(np.asarray(ci), reference["cell_i"])
    np.testing.assert_allclose(np.asarray(cf), reference["cell_f"],
                               atol=1e-5, rtol=1e-4)


def test_unrecoverable_raises_typed_error(inj_engine, tmp_path):
    """No retries, no ladder -> RecoveryExhausted, never a silent pass."""
    plan = FaultPlan([FaultSpec("force_nan", 2, sticky=True)])
    runner = _runner(inj_engine, tmp_path / "ck", plan=plan,
                     policy=RecoveryPolicy(max_retries=0,
                                           ladder=DegradeLadder(rungs=())))
    with pytest.raises(RecoveryExhausted, match="nonfinite"):
        runner.run(N_STEPS)


# --------------------------------------------------------------------------
# forced inner-ladder overflow (satellite S3)
# --------------------------------------------------------------------------

def test_forced_overflow_warns_once_and_falls_back(system, mesh, tmp_path):
    # private registry: the counter/record asserts below must not see
    # overflow traffic other tests put on the shared default registry
    from repro.obs import MetricsRegistry
    eng = MDEngine(system, mesh, force_backend="sparse", nstprune=3,
                   inject=True, health=True, obs=MetricsRegistry())
    # two overflow faults: the warn-once latch must still fire only once
    plan = FaultPlan([FaultSpec("inner_overflow", 0),
                      FaultSpec("inner_overflow", 6)])
    runner = _runner(eng, tmp_path / "ck", plan=plan)
    with pytest.warns(RuntimeWarning, match="rolling inner prune") as rec:
        (cf, ci), _, report = runner.run(N_STEPS)
    assert len([w for w in rec
                if "rolling inner prune" in str(w.message)]) == 1

    falls = [r for r in report["recoveries"]
             if r["action"] == "engine_fallback"]
    assert len(falls) == 2
    assert all(r["detail"] == "outer_ladder" for r in falls)
    assert report["wasted_steps"] == 0          # fallback, not rewind
    assert eng.obs.counter("md/inner_overflow_blocks").value == 2

    # each overflow downgraded the FOLLOWING block to the outer ladder
    sched = [r for r in eng.obs.records if r.get("kind") == "sched_update"]
    assert [s["inner_disabled"] for s in sched] == [False, True, True]

    # the degraded run still finishes and matches the same engine's own
    # forced-fallback trajectory deterministically
    assert np.isfinite(np.asarray(cf)).all()


# --------------------------------------------------------------------------
# host faults: process kill -> resume; device loss -> reshard
# --------------------------------------------------------------------------

def test_proc_kill_resumes_bitwise(inj_engine, reference, tmp_path):
    plan = FaultPlan([FaultSpec("proc_kill", 12)])
    runner = _runner(inj_engine, tmp_path / "ck", plan=plan)
    with pytest.raises(ProcessKilled, match="step 12"):
        runner.run(N_STEPS)

    # a fresh runner over the same checkpoint dir picks the run back up
    runner2 = _runner(inj_engine, tmp_path / "ck")
    (cf, ci), _, report = runner2.run(N_STEPS)
    assert report["resumed_from"] == 12
    np.testing.assert_array_equal(np.asarray(cf), reference["cell_f"])
    np.testing.assert_array_equal(np.asarray(ci), reference["cell_i"])


def test_device_loss_reshards_within_drift_bound(inj_engine, reference,
                                                 tmp_path):
    spare = make_mesh((1, 1, 1), ("z", "y", "x"))
    plan = FaultPlan([FaultSpec("device_loss", 12)])
    runner = _runner(inj_engine, tmp_path / "ck", plan=plan,
                     spare_mesh=spare)
    (cf, ci), _, report = runner.run(N_STEPS)

    assert report["resharded"] is True
    assert [r["action"] for r in report["recoveries"]] == ["reshard"]
    assert runner.engine is not inj_engine
    assert runner.engine.mesh is spare and runner.spare_mesh is None

    # re-binning the checkpointed atoms host-side changes packing and
    # summation order: NOT bitwise, but within float accumulation noise
    # (measured 5e-7 over the 6 resumed steps; NVE bound is far looser)
    atoms = runner.engine.export_atoms((cf, ci))
    ref = reference["atoms"]
    vscale = np.abs(ref["vel"]).max()
    assert np.abs(atoms["pos"] - ref["pos"]).max() < 1e-4
    assert np.abs(atoms["vel"] - ref["vel"]).max() / vscale < 1e-4


def test_device_loss_without_spare_mesh_raises(inj_engine, tmp_path):
    from repro.resilience import DeviceLost
    plan = FaultPlan([FaultSpec("device_loss", 6)])
    runner = _runner(inj_engine, tmp_path / "ck", plan=plan)
    with pytest.raises(DeviceLost, match="no spare"):
        runner.run(N_STEPS)


# --------------------------------------------------------------------------
# unit layer: FaultPlan / HealthMonitor / RecoveryPolicy / Watchdog
# --------------------------------------------------------------------------

def test_fault_plan_from_seed_is_replayable():
    a = FaultPlan.from_seed(7, 100, n_faults=5)
    b = FaultPlan.from_seed(7, 100, n_faults=5)
    assert [vars(s) for s in a.specs] == [vars(s) for s in b.specs]
    c = FaultPlan.from_seed(8, 100, n_faults=5)
    assert [vars(s) for s in a.specs] != [vars(s) for s in c.specs]
    for s in a.specs:
        assert 0 <= s.step < 100


def test_fault_plan_windows_and_retirement():
    plan = FaultPlan([FaultSpec("halo_corrupt", 8),
                      FaultSpec("signal_drop", 2, sticky=True),
                      FaultSpec("proc_kill", 13)])
    fv, armed = plan.arm_scan(0, 6)          # only the sticky drop
    assert armed == [1] and fv[2] == 2 and fv[0] == -1
    plan.mark_fired(armed)
    fv, armed = plan.arm_scan(6, 12)         # halo @8 + sticky re-fires
    assert armed == [0, 1] and fv[0] == 2 and fv[2] == 0
    plan.mark_fired(armed)
    fv, armed = plan.arm_scan(12, 18)        # one-shot retired, sticky not
    assert armed == [1]
    assert [s.site for _, s in plan.host_pending(12, 18)] == ["proc_kill"]
    plan.disable_sites(["signal_drop"])
    fv, armed = plan.arm_scan(12, 18)
    assert fv is None and armed == []


def test_fault_plan_rejects_unknown_site():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultSpec("cosmic_ray", 3)
    with pytest.raises(ValueError, match="step"):
        FaultSpec("force_nan", -1)


def test_health_monitor_nonfinite_and_ledger():
    mon = HealthMonitor()
    evs = mon.check_block({"health/nonfinite": np.array([0, 0, 3, 9]),
                           "health/led_violation": np.array([1])}, 12)
    kinds = {e.kind: e for e in evs}
    assert kinds["nonfinite"].step == 14       # first offending step
    assert kinds["ledger"].step == 12          # block granularity
    assert mon.check_block({"health/nonfinite": np.zeros(4)}, 18) == []


def test_health_monitor_energy_spike_and_reset():
    mon = HealthMonitor(energy_spike_rel=0.25)
    pe = np.full(4, -100.0)
    ke = np.full(4, 40.0)
    assert mon.check_block({"pe": pe, "ke": ke}, 0) == []
    # a 50% jump mid-block trips; cross-block state did NOT advance
    pe2 = pe.copy()
    pe2[2:] -= 30.0
    evs = mon.check_block({"pe": pe2, "ke": ke}, 4)
    assert [e.kind for e in evs] == ["energy_spike"] and evs[0].step == 6
    # the tripped block left _last_E at the previous clean value
    assert mon.check_block({"pe": pe, "ke": ke}, 4) == []
    mon.reset()
    assert mon.check_block({"pe": pe2[2:] * 0 - 130.0,
                            "ke": ke[2:] * 0 + 40.0}, 8) == []


def test_recovery_policy_escalation_order():
    pol = RecoveryPolicy(max_retries=2, backoff_base_s=0.01,
                         backoff_factor=2.0, backoff_cap_s=0.03)
    a0 = pol.decide({"nonfinite"}, 0)
    a1 = pol.decide({"nonfinite"}, 1)
    assert (a0.kind, a1.kind) == ("rollback", "rollback")
    assert a0.backoff_s == 0.01 and a1.backoff_s == 0.02
    assert pol.backoff(10) == 0.03             # capped
    a2 = pol.decide({"nonfinite"}, 2)
    assert a2.kind == "degrade" and a2.rung.name == "dense_forces"
    assert pol.decide({"device_loss"}, 0).kind == "reshard"


def test_degrade_ladder_trigger_matching():
    lad = DegradeLadder()
    assert lad.next_rung({"ledger"}).name == "serialized_halo"
    assert lad.next_rung({"overflow"}).name == "outer_ladder"
    for r in DEFAULT_RUNGS:
        lad.apply(r)
    assert lad.next_rung({"ledger"}) is None
    assert lad.summary()["available"] == []


def test_watchdog_flags_stragglers():
    events = []
    wd = Watchdog(alpha=0.5, threshold=3.0, warmup=2,
                  on_straggler=lambda s, dt, ew: events.append((s, dt)))
    for i in range(4):
        wd.observe(i, 0.1)
    wd.observe(4, 1.0)                         # 10x the EWMA
    assert wd.events == 1 and events[0][0] == 4
    wd.observe(5, 0.1)
    assert wd.events == 1


def test_runner_requires_matching_engine_flags(system, mesh, tmp_path):
    plain = MDEngine(system, mesh)
    with pytest.raises(ValueError, match="health=True"):
        ResilientMDRunner(plain, tmp_path / "ck")


@pytest.mark.dist
def test_fault_matrix_on_8_devices(dist, tmp_path):
    """Every fault site x {recover, degrade} on a 2x2x2 DD mesh,
    including the device-loss -> 1x2x2 reshard shrink."""
    out = tmp_path / "fault_matrix.jsonl"
    stdout = dist("check_faults.py", "--out", str(out), timeout=1800)
    assert "check_faults OK" in stdout
    rows = [__import__("json").loads(ln)
            for ln in out.read_text().splitlines()]
    assert {(r["site"], r["mode"]) for r in rows} == {
        ("halo_corrupt", "recover"), ("force_nan", "recover"),
        ("signal_drop", "recover"), ("signal_drop", "degrade"),
        ("force_nan", "degrade"), ("inner_overflow", "recover"),
        ("proc_kill", "recover"), ("device_loss", "recover")}
