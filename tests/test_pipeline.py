"""StepPipeline subsystem: ledger, overlap schedules, signal backend, MD.

The pipeline's conformance bar is a single parametrized MATRIX — backend
x pipeline mode x halo width x window depth — every cell of which must be
bitwise-identical to the serialized/off reference (replacing the old
hand-enumerated per-case tests, which could not keep up with the
multiplicative axis growth).  Single-device (periodic self-exchange)
cells run in-process; the multi-device versions live in
tests/dist/check_halo.py / check_md.py.
"""
import functools

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # property tests skip; hypothesis is a dev extra
    from _hypothesis_stub import given, settings, st

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map_norep
from repro.core.halo_plan import HaloPlan, HaloSpec
from repro.core.pipeline import (
    PIPELINE_MODES,
    SignalLedger,
    StepFns,
    StepPipeline,
)
from repro.core.schedule import make_schedule, split_width
from repro.launch.mesh import make_mesh


# --------------------------------------------------------------------------
# width>1 multi-pulse schedules
# --------------------------------------------------------------------------

def test_split_width_balanced():
    assert split_width(2, 2) == (1, 1)
    assert split_width(5, 2) == (3, 2)
    assert split_width(3, 3) == (1, 1, 1)


def test_multi_pulse_schedule_offsets_tile_the_halo():
    sched = make_schedule(("z", "y"), (3, 2), pulses_per_dim=(2, 2))
    assert sched.total_pulses == 4
    for d, w in enumerate(sched.widths):
        pulses = sched.dim_pulses(d)
        assert [p.offset for p in pulses] == \
            [sum(q.width for q in pulses[:k]) for k in range(len(pulses))]
        assert sum(p.width for p in pulses) == w
    # global order still concatenates dims Z -> Y
    assert [p.dim for p in sched.serialized_order()] == [0, 0, 1, 1]


def test_multi_pulse_schedule_validation():
    with pytest.raises(ValueError, match="cannot split"):
        make_schedule(("z",), (1,), pulses_per_dim=(2,))
    with pytest.raises(ValueError, match="at least one pulse"):
        make_schedule(("z",), (2,), pulses_per_dim=(0,))
    # width-0 dims degrade to a single no-op pulse
    sched = make_schedule(("z", "y"), (2, 0), pulses_per_dim=(2, 2))
    assert len(sched.dim_pulses(1)) == 1


@pytest.mark.parametrize("backend",
                         ("serialized", "fused", "pallas", "signal"))
def test_width2_two_pulse_bitwise_identical(backend):
    """Width-2 halos, one- vs two-pulse schedules: same bytes, same bits,
    across all four backends (single-device periodic self-exchange; the
    8-device version is in tests/dist/check_halo.py)."""
    mesh = make_mesh((1,), ("z",))
    rng = np.random.RandomState(3)
    x = jnp.asarray(rng.randn(6, 5).astype(np.float32))
    shift = np.zeros((1, 5))
    shift[0, 0] = 17.0
    ref = np.asarray(HaloPlan.build(
        HaloSpec(("z",), (2,), backend="serialized", wrap_shift=shift),
        mesh).fwd(x))
    for pulses in (None, (2,)):
        plan = HaloPlan.build(
            HaloSpec(("z",), (2,), backend=backend, wrap_shift=shift,
                     pulses=pulses), mesh)
        np.testing.assert_array_equal(np.asarray(plan.fwd(x)), ref)


@pytest.mark.parametrize("backend",
                         ("serialized", "fused", "pallas", "signal"))
def test_width2_two_pulse_adjoint(backend):
    mesh = make_mesh((1,), ("z",))
    plan = HaloPlan.build(
        HaloSpec(("z",), (2,), backend=backend, pulses=(2,)), mesh)
    rng = np.random.RandomState(4)
    x = jnp.asarray(rng.randn(6, 4).astype(np.float32))
    y = jnp.asarray(rng.randn(8, 4).astype(np.float32))
    lhs = float(jnp.vdot(plan.fwd(x), y))
    rhs = float(jnp.vdot(x, plan.rev(y)))
    assert abs(lhs - rhs) <= 1e-5 * max(abs(lhs), 1.0)


# --------------------------------------------------------------------------
# signal ledger
# --------------------------------------------------------------------------

def test_ledger_release_acquire_balance():
    led = SignalLedger(depth=2, n_pulses=3)
    st = led.init()
    st = led.release(st, "fwd", 0)
    st = led.acquire(st, "fwd", 0)
    st = led.release(st, "rev", 1)
    assert bool(led.consistent(st))
    s = led.summary(st)
    assert s["fwd"] == {"released": 3, "acquired": 3}
    assert s["rev"] == {"released": 3, "acquired": 0}
    assert int(led.outstanding(st).sum()) == 3


def test_ledger_detects_unreleased_acquire():
    led = SignalLedger(depth=2, n_pulses=1)
    st = led.acquire(led.init(), "rev", 0)
    assert not bool(led.consistent(st))


def test_ledger_slot_parity_is_traceable():
    led = SignalLedger(depth=2, n_pulses=2)

    def f(k):
        return led.release(led.init(), "fwd", k % 2).released

    out = jax.jit(f)(jnp.int32(3))          # slot 1
    assert int(out[led.slot("fwd", 1, 0)]) == 1
    assert int(out[led.slot("fwd", 0, 0)]) == 0


def test_ledger_detects_slot_clobber():
    """A second release onto a still-outstanding slot is the buffer
    overwrite the depth-d ring exists to prevent."""
    led = SignalLedger(depth=2, n_pulses=1)
    st_ = led.release(led.init(), "rev", 0)
    assert bool(led.window_safe(st_))
    st_ = led.release(st_, "rev", 0)         # slot 0 never acquired
    assert not bool(led.window_safe(st_))
    assert int(st_.clobbers.sum()) == 1
    # acquire-then-release is the legal reuse and adds no clobber
    st2 = led.release(led.init(), "rev", 0)
    st2 = led.acquire(st2, "rev", 0)
    st2 = led.release(st2, "rev", 0)
    assert bool(led.window_safe(st2))


def _replay_window_schedule(led, depth, n_steps, watch):
    """Replay the deep-window pipeline's exact ledger transition sequence
    (prologue, skew-one steps with release-at-fill, epilogue drain),
    calling ``watch`` after every transition."""
    st_ = led.init()
    st_ = watch(led.release(st_, "fwd", 0))
    st_ = watch(led.acquire(st_, "fwd", 0))
    st_ = watch(led.release(st_, "rev", 0))
    for k in range(1, n_steps):
        st_ = watch(led.acquire(st_, "rev", k - 1))
        st_ = watch(led.release(st_, "fwd", k))
        st_ = watch(led.acquire(st_, "fwd", k))
        st_ = watch(led.release(st_, "rev", k))
    return watch(led.acquire(st_, "rev", n_steps - 1))


@given(depth=st.integers(2, 6), n_steps=st.integers(1, 16),
       n_pulses=st.integers(1, 3))
@settings(max_examples=25, deadline=None)
def test_ledger_window_replay_properties(depth, n_steps, n_pulses):
    """For random (depth, n_steps): no acquire ever observes a slot
    before its release, counters are monotone non-decreasing, and the
    drain epilogue leaves zero in-flight slots and zero clobbers."""
    led = SignalLedger(depth=depth, n_pulses=n_pulses)
    seen = {"released": None, "acquired": None}

    def watch(st_):
        assert bool(led.consistent(st_))              # causal at all times
        assert bool(led.window_safe(st_))             # ring never clobbers
        # skew-one window: at most one kind's pulses in flight at once
        assert int(led.in_flight(st_)) <= n_pulses
        for name in seen:                             # monotone counters
            cur = np.asarray(getattr(st_, name))
            assert np.all(cur >= 0)
            if seen[name] is not None:
                assert np.all(cur >= seen[name])
            seen[name] = cur
        return st_

    st_ = _replay_window_schedule(led, depth, n_steps, watch)
    assert bool(led.drained(st_))                     # epilogue drains all
    assert int(led.in_flight(st_)) == 0
    s = led.summary(st_)
    assert s["fwd"]["released"] == s["fwd"]["acquired"] == n_steps
    assert s["rev"]["released"] == s["rev"]["acquired"] == n_steps


@given(depth=st.integers(2, 4), extra=st.integers(1, 4))
@settings(max_examples=10, deadline=None)
def test_ledger_overdeep_window_is_flagged(depth, extra):
    """Keeping more than ``depth`` deposits in flight MUST trip the
    clobber monitor: releases wrap the ring onto unacquired slots."""
    led = SignalLedger(depth=depth, n_pulses=1)
    st_ = led.init()
    for k in range(depth + extra):                    # no acquires at all
        st_ = led.release(st_, "rev", k)
    assert not bool(led.window_safe(st_))
    assert bool(led.consistent(st_))                  # still causal


# --------------------------------------------------------------------------
# cross-backend conformance matrix: every (backend, mode, width, depth)
# cell must reproduce the serialized/off reference bit for bit
# --------------------------------------------------------------------------

MATRIX_BACKENDS = ("serialized", "fused", "pallas", "signal")
MATRIX_MODES = ("off", "double_buffer")
MATRIX_WIDTHS = (1, 2)
MATRIX_DEPTHS = (2, 3, 4)
MATRIX_STEPS = 8     # 7 post-prologue steps: exercises rem != 0 at span 2/3

MATRIX = [(b, m, w, d)
          for b in MATRIX_BACKENDS
          for m in MATRIX_MODES
          for w in MATRIX_WIDTHS
          for d in MATRIX_DEPTHS]


def _toy_fns():
    def begin(state, f, ctx):
        state = state + 0.1 * f
        return state, state.sum(), state

    def force(ext, ctx):
        F = jnp.tanh(ext) * ctx
        return F, {"pe": jnp.sum(F)}

    def finish(state, aux, f, ctx):
        state = state + 0.01 * f + 1e-3 * aux
        return state, f, {"ke": jnp.sum(state)}

    return StepFns(begin=begin, force=force, finish=finish)


@functools.lru_cache(maxsize=None)
def _run_cell(backend, mode, width, depth, n_steps=MATRIX_STEPS):
    """One matrix cell (cached: ``off`` collapses the depth axis, and
    reference cells are shared by every comparison against them)."""
    if mode == "off":
        depth = 2        # the serialized chain has no ring to deepen
    mesh = make_mesh((1,), ("z",))
    plan = HaloPlan.build(HaloSpec(("z",), (width,), backend=backend),
                          mesh)
    pipe = StepPipeline.build(plan, _toy_fns(), mode=mode, depth=depth)
    x0 = jnp.asarray(np.random.RandomState(0).randn(6, 4)
                     .astype(np.float32))

    def run(state, f):
        return pipe.run_local(state, f, n_steps, jnp.float32(0.5))

    fn = shard_map_norep(run, mesh=mesh, in_specs=(P(), P()),
                         out_specs=(P(), P(), P(), P()))
    state, f, metrics, led = jax.jit(fn)(x0, jnp.zeros_like(x0))
    return (np.asarray(state), np.asarray(f),
            {k: np.asarray(v) for k, v in metrics.items()},
            pipe.ledger.summary(jax.device_get(led)))


@pytest.mark.parametrize(
    "backend,mode,width,depth", MATRIX,
    ids=[f"{b}-{m}-w{w}-d{d}" for b, m, w, d in MATRIX])
def test_conformance_matrix(backend, mode, width, depth):
    """Bitwise trajectory identity of every cell vs serialized/off, plus
    the ledger conservation laws (balanced, causal, clobber-free,
    drained) the hardware signal flags would enforce."""
    ref = _run_cell("serialized", "off", width, 2)
    got = _run_cell(backend, mode, width, depth)
    np.testing.assert_array_equal(got[0], ref[0])
    np.testing.assert_array_equal(got[1], ref[1])
    for k in ref[2]:
        assert ref[2][k].shape[0] == MATRIX_STEPS
        np.testing.assert_array_equal(got[2][k], ref[2][k])
    summary = got[3]
    assert summary["consistent"] and summary["window_safe"]
    assert summary["in_flight"] == 0 and summary["clobbers"] == 0
    for kind in ("fwd", "rev"):
        assert summary[kind]["released"] == MATRIX_STEPS
        assert summary[kind]["acquired"] == MATRIX_STEPS


@pytest.mark.parametrize("n_steps", (1, 2, 3))
@pytest.mark.parametrize("depth", (3, 4))
def test_deep_window_short_blocks(depth, n_steps):
    """Blocks shorter than the window: the whole run is prologue +
    epilogue drain loop (n_full = 0), which must still match ``off``."""
    ref = _run_cell("signal", "off", 1, 2, n_steps=n_steps)
    got = _run_cell("signal", "double_buffer", 1, depth, n_steps=n_steps)
    np.testing.assert_array_equal(got[0], ref[0])
    np.testing.assert_array_equal(got[1], ref[1])
    for k in ref[2]:
        np.testing.assert_array_equal(got[2][k], ref[2][k])
    assert got[3]["in_flight"] == 0 and got[3]["window_safe"]


def test_pipeline_rejects_bad_mode_and_depth():
    mesh = make_mesh((1,), ("z",))
    plan = HaloPlan.build(HaloSpec(("z",), (1,)), mesh)
    with pytest.raises(ValueError, match="unknown pipeline mode"):
        StepPipeline.build(plan, _toy_fns(), mode="triple")
    with pytest.raises(ValueError, match="depth >= 2"):
        StepPipeline.build(plan, _toy_fns(), mode="double_buffer",
                           depth=1)
    # "off" has no ring: depth is normalized away, not an error
    assert StepPipeline.build(plan, _toy_fns(), mode="off",
                              depth=7).depth == 1


# --------------------------------------------------------------------------
# wire-dtype cells: compressed halo payloads (HaloSpec.wire_dtype) must
# preserve the off == double_buffer bitwise conformance per wire format
# — fills encode once per step at the same cadence serial mode
# quantizes, drains decode + splice, so regrouping steps across scan
# iterations cannot re-round.  (float32 payloads here: the force-return
# carries the named format; the f64 coordinate floor is covered by the
# NVE harness and tests/dist/check_halo.py.)
# --------------------------------------------------------------------------

WIRE_MATRIX = [(wd, b, m, d)
               for wd in ("bfloat16", "float16", "int8_ef")
               for b in ("fused", "signal")
               for (m, d) in (("double_buffer", 2), ("double_buffer", 3))]


@functools.lru_cache(maxsize=None)
def _run_wire_cell(wire, backend, mode, depth, n_steps=MATRIX_STEPS):
    mesh = make_mesh((1,), ("z",))
    plan = HaloPlan.build(HaloSpec(("z",), (1,), backend=backend,
                                   wire_dtype=wire), mesh)
    pipe = StepPipeline.build(plan, _toy_fns(), mode=mode, depth=depth)
    x0 = jnp.asarray(np.random.RandomState(0).randn(6, 4)
                     .astype(np.float32))

    def run(state, f):
        return pipe.run_local(state, f, n_steps, jnp.float32(0.5))

    fn = shard_map_norep(run, mesh=mesh, in_specs=(P(), P()),
                         out_specs=(P(), P(), P(), P()))
    state, f, metrics, _ = jax.jit(fn)(x0, jnp.zeros_like(x0))
    return (np.asarray(state), np.asarray(f),
            {k: np.asarray(v) for k, v in metrics.items()})


@pytest.mark.parametrize("wire,backend,mode,depth", WIRE_MATRIX,
                         ids=[f"{wd}-{b}-{m}-d{d}"
                              for wd, b, m, d in WIRE_MATRIX])
def test_wire_conformance_matrix(wire, backend, mode, depth):
    ref = _run_wire_cell(wire, "serialized", "off", 2)
    got = _run_wire_cell(wire, backend, mode, depth)
    np.testing.assert_array_equal(got[0], ref[0])
    np.testing.assert_array_equal(got[1], ref[1])
    for k in ref[2]:
        np.testing.assert_array_equal(got[2][k], ref[2][k])


def test_wire_none_trace_unchanged():
    """wire_dtype=None must be bitwise-identical to the pre-wire
    program (the dense path's selection happens in python, so the
    traced computation is operand-for-operand the same)."""
    ref = _run_cell("fused", "double_buffer", 1, 3)
    got = _run_wire_cell(None, "fused", "double_buffer", 3)
    np.testing.assert_array_equal(got[0], ref[0])
    np.testing.assert_array_equal(got[1], ref[1])


def test_wire_compression_is_live():
    """bf16 force-return must actually perturb the trajectory relative
    to dense (guards against the wire path silently short-circuiting)."""
    dense = _run_wire_cell(None, "fused", "off", 2)
    comp = _run_wire_cell("bfloat16", "fused", "off", 2)
    d = np.abs(dense[0] - comp[0]).max()
    assert 0 < d < 1e-1, d


# --------------------------------------------------------------------------
# overlap + latency stats (plan-level, the ROADMAP items)
# --------------------------------------------------------------------------

def test_double_buffer_exposes_strictly_fewer_phases():
    mesh = make_mesh((1, 1, 1), ("z", "y", "x"))
    for backend in ("serialized", "fused", "pallas", "signal"):
        plan = HaloPlan.build(
            HaloSpec(("z", "y", "x"), (1, 1, 1), backend=backend), mesh)
        off = plan.stats((8, 8, 8), pipeline="off")
        db = plan.stats((8, 8, 8), pipeline="double_buffer")
        assert db["exposed_phases_per_step"] < \
            off["exposed_phases_per_step"]
        assert off["overlapped_bytes_per_step"] == 0
        assert db["overlapped_bytes_per_step"] == db["total_bytes"]


def test_overlap_model_depth_sweep_is_monotone():
    """Deeper in-flight windows expose strictly fewer phases per step and
    hide strictly more bytes, for every backend's critical-path model."""
    mesh = make_mesh((1, 1, 1), ("z", "y", "x"))
    for backend in ("serialized", "fused", "pallas", "signal"):
        plan = HaloPlan.build(
            HaloSpec(("z", "y", "x"), (1, 1, 1), backend=backend), mesh)
        cells = [plan.stats((8, 8, 8), pipeline="double_buffer", depth=d)
                 for d in (2, 3, 4, 5)]
        exposed = [c["exposed_phases_per_step"] for c in cells]
        hidden = [c["overlapped_bytes_per_step"] for c in cells]
        assert exposed == sorted(exposed, reverse=True)
        assert len(set(exposed)) == len(exposed)      # strictly decreasing
        assert hidden == sorted(hidden)
        assert all(c["overlap"]["depth"] == d
                   for c, d in zip(cells, (2, 3, 4, 5)))
        # depth 2 reproduces the legacy double-buffer accounting
        assert cells[0]["overlapped_bytes_per_step"] == \
            cells[0]["total_bytes"]
        # hidden bytes never exceed what is exchanged
        assert all(h < c["overlap"]["exchanged_bytes_per_step"]
                   for h, c in zip(hidden, cells))
    with pytest.raises(ValueError, match="depth >= 2"):
        plan.stats((8, 8, 8), pipeline="double_buffer", depth=1)


def test_latency_model_two_pulse_small_domain_regime():
    """Strong-scaling limit: with two pulses per dim the serialized path
    pays twice the per-message latency; the fused (put-with-signal) path
    still pays one latency per phase — the paper's crossover driver."""
    mesh = make_mesh((1, 1, 1), ("z", "y", "x"))
    plan = HaloPlan.build(
        HaloSpec(("z", "y", "x"), (2, 2, 2), pulses=(2, 2, 2)), mesh)
    lat = plan.stats((4, 4, 4))["latency"]
    assert lat["serialized_messages"] == 6
    assert len(lat["fused_phase_messages"]) == 3
    assert lat["serialized_time_s"] > lat["fused_time_s"]
    # tiny domains: latency-dominated, speedup approaches 6/3
    tiny = HaloPlan.build(
        HaloSpec(("z", "y", "x"), (2, 2, 2), pulses=(2, 2, 2)), mesh) \
        .stats((2, 2, 2), bandwidth_Bps=1e15)
    assert tiny["latency"]["fused_speedup"] == pytest.approx(2.0, rel=1e-3)


def test_stats_latency_configurable():
    mesh = make_mesh((1,), ("z",))
    plan = HaloPlan.build(HaloSpec(("z",), (1,)), mesh)
    fast = plan.stats((8,), link_latency_s=1e-9)["latency"]
    slow = plan.stats((8,), link_latency_s=1e-3)["latency"]
    assert slow["serialized_time_s"] > fast["serialized_time_s"]


# --------------------------------------------------------------------------
# MD engine through the pipeline (single device; 8-device in tests/dist)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("pipeline", PIPELINE_MODES)
def test_md_engine_pipeline_bitwise(pipeline):
    from repro.core.md import MDEngine, make_grappa_like

    sys_ = make_grappa_like(200, seed=5)
    mesh = make_mesh((1, 1, 1), ("z", "y", "x"))
    spec = HaloSpec(("z", "y", "x"), (1, 1, 1), backend="serialized")
    ref_eng = MDEngine(sys_, mesh, spec)
    (cf_ref, _), m_ref, _ = ref_eng.simulate(12)

    eng = MDEngine(sys_, mesh,
                   HaloSpec(("z", "y", "x"), (1, 1, 1), backend="signal"),
                   pipeline=pipeline)
    (cf, _), m, _ = eng.simulate(12)
    np.testing.assert_array_equal(np.asarray(jax.device_get(cf)),
                                  np.asarray(jax.device_get(cf_ref)))
    for k in m_ref:
        np.testing.assert_array_equal(np.asarray(m[k]),
                                      np.asarray(m_ref[k]))


def test_md_engine_overlap_stats_and_validation():
    from repro.core.md import MDEngine, make_grappa_like

    sys_ = make_grappa_like(200, seed=5)
    mesh = make_mesh((1, 1, 1), ("z", "y", "x"))
    with pytest.raises(ValueError, match="unknown pipeline"):
        MDEngine(sys_, mesh, pipeline="buffered")
    with pytest.raises(ValueError, match="pipeline_depth must be >= 2"):
        MDEngine(sys_, mesh, pipeline="double_buffer", pipeline_depth=1)
    with pytest.raises(ValueError, match="widths must be >= 1"):
        MDEngine(sys_, mesh, HaloSpec(("z", "y", "x"), (1, 0, 1)))
    eng = MDEngine(sys_, mesh, pipeline="double_buffer")
    ov = eng.overlap_stats()
    assert ov["pipeline"] == "double_buffer"
    assert ov["overlapped_bytes_per_step"] > 0
    deep = MDEngine(sys_, mesh, pipeline="double_buffer",
                    pipeline_depth=4)
    assert deep.pipeline.depth == 4
    assert deep.overlap_stats()["depth"] == 4
    assert deep.overlap_stats()["exposed_phases_per_step"] < \
        ov["exposed_phases_per_step"]


# --------------------------------------------------------------------------
# prune axis: the conformance matrix extended over the dual pair list.
# For a FIXED prune schedule (nstprune setting), every pipeline mode /
# depth / rebin-fusion cell must be bitwise-identical — the rolling
# prune's sub-block refreshes ride the same block-constant ctx contract
# as the static schedule, so software pipelining cannot perturb them.
# --------------------------------------------------------------------------

PRUNE_MATRIX = [(nstprune, mode, depth, ovr)
                for nstprune in (0, 4)
                for (mode, depth, ovr) in (
                    ("off", 2, False),          # the reference cell
                    ("double_buffer", 2, False),
                    ("double_buffer", 3, False),
                    ("off", 2, True),           # overlap_rebin fused
                    ("double_buffer", 3, True),
                )]


@functools.lru_cache(maxsize=None)
def _run_md_prune_cell(nstprune, mode, depth, ovr, n_steps=24):
    from repro.core.md import MDEngine, make_grappa_like

    sys_ = make_grappa_like(200, seed=5)
    mesh = make_mesh((1, 1, 1), ("z", "y", "x"))
    eng = MDEngine(sys_, mesh,
                   HaloSpec(("z", "y", "x"), (1, 1, 1), backend="signal"),
                   pipeline=mode, pipeline_depth=depth, overlap_rebin=ovr,
                   force_backend="sparse", nstprune=nstprune)
    (cf, ci), m, diags = eng.simulate(n_steps)
    sel, tiers, tiers_inner = eng._sched_exec
    return (np.asarray(jax.device_get(cf)), np.asarray(jax.device_get(ci)),
            {k: np.asarray(v) for k, v in m.items()},
            [{k: np.asarray(v) for k, v in d.items()} for d in diags],
            (np.asarray(jax.device_get(sel)), tiers, tiers_inner),
            eng.pair_stats())


@pytest.mark.parametrize(
    "nstprune,mode,depth,ovr", PRUNE_MATRIX,
    ids=[f"np{p}-{m}-d{d}" + ("-ovr" if o else "")
         for p, m, d, o in PRUNE_MATRIX])
def test_prune_conformance_matrix(nstprune, mode, depth, ovr):
    """Sparse trajectories are bitwise-identical across pipeline modes
    and the fused/host-dispatched rebin paths for a fixed nstprune, and
    every cell hands the next block the identical post-prune exec
    schedule (same packed sel, same tier ladders)."""
    ref = _run_md_prune_cell(nstprune, "off", 2, False)
    got = _run_md_prune_cell(nstprune, mode, depth, ovr)
    np.testing.assert_array_equal(got[0], ref[0])
    np.testing.assert_array_equal(got[1], ref[1])
    for k in ref[2]:
        np.testing.assert_array_equal(got[2][k], ref[2][k])
    assert len(got[3]) == len(ref[3])            # same rebin cadence
    for gd, rd in zip(got[3], ref[3]):
        for k in rd:
            np.testing.assert_array_equal(gd[k], rd[k])
    sel_g, tiers_g, inner_g = got[4]
    sel_r, tiers_r, inner_r = ref[4]
    assert (tiers_g, inner_g) == (tiers_r, inner_r)
    np.testing.assert_array_equal(sel_g, sel_r)
    ps = got[5]
    assert ps["nstprune"] == nstprune
    assert ps["inner_overflow_blocks"] == 0


def test_md_engine_deep_window_and_overlap_rebin_bitwise():
    """24 steps (one rebin/migration boundary at nstlist=20): deep
    windows and the fused rebin path must all reproduce the
    host-dispatched serialized/off trajectory bit for bit."""
    from repro.core.md import MDEngine, make_grappa_like

    sys_ = make_grappa_like(200, seed=5)
    mesh = make_mesh((1, 1, 1), ("z", "y", "x"))
    spec = HaloSpec(("z", "y", "x"), (1, 1, 1), backend="serialized")
    ref_eng = MDEngine(sys_, mesh, spec)
    (cf_ref, ci_ref), m_ref, diags_ref = ref_eng.simulate(24)

    cases = [
        dict(pipeline="double_buffer", pipeline_depth=3),
        dict(pipeline="off", overlap_rebin=True),
        dict(pipeline="double_buffer", pipeline_depth=4,
             overlap_rebin=True),
    ]
    for kw in cases:
        eng = MDEngine(
            sys_, mesh,
            HaloSpec(("z", "y", "x"), (1, 1, 1), backend="signal"), **kw)
        (cf, ci), m, diags = eng.simulate(24)
        np.testing.assert_array_equal(np.asarray(jax.device_get(cf)),
                                      np.asarray(jax.device_get(cf_ref)))
        np.testing.assert_array_equal(np.asarray(jax.device_get(ci)),
                                      np.asarray(jax.device_get(ci_ref)))
        for k in m_ref:
            np.testing.assert_array_equal(np.asarray(m[k]),
                                          np.asarray(m_ref[k]))
        assert len(diags) == len(diags_ref)          # same rebin cadence
        for got_d, ref_d in zip(diags, diags_ref):
            for k in ref_d:
                np.testing.assert_array_equal(np.asarray(got_d[k]),
                                              np.asarray(ref_d[k]))
