"""StepPipeline subsystem: ledger, overlap schedules, signal backend, MD.

Single-device (periodic self-exchange) checks run in-process; the
multi-device versions live in tests/dist/check_halo.py / check_md.py.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map_norep
from repro.core.halo_plan import HaloPlan, HaloSpec
from repro.core.pipeline import (
    PIPELINE_MODES,
    SignalLedger,
    StepFns,
    StepPipeline,
)
from repro.core.schedule import make_schedule, split_width
from repro.launch.mesh import make_mesh


# --------------------------------------------------------------------------
# width>1 multi-pulse schedules
# --------------------------------------------------------------------------

def test_split_width_balanced():
    assert split_width(2, 2) == (1, 1)
    assert split_width(5, 2) == (3, 2)
    assert split_width(3, 3) == (1, 1, 1)


def test_multi_pulse_schedule_offsets_tile_the_halo():
    sched = make_schedule(("z", "y"), (3, 2), pulses_per_dim=(2, 2))
    assert sched.total_pulses == 4
    for d, w in enumerate(sched.widths):
        pulses = sched.dim_pulses(d)
        assert [p.offset for p in pulses] == \
            [sum(q.width for q in pulses[:k]) for k in range(len(pulses))]
        assert sum(p.width for p in pulses) == w
    # global order still concatenates dims Z -> Y
    assert [p.dim for p in sched.serialized_order()] == [0, 0, 1, 1]


def test_multi_pulse_schedule_validation():
    with pytest.raises(ValueError, match="cannot split"):
        make_schedule(("z",), (1,), pulses_per_dim=(2,))
    with pytest.raises(ValueError, match="at least one pulse"):
        make_schedule(("z",), (2,), pulses_per_dim=(0,))
    # width-0 dims degrade to a single no-op pulse
    sched = make_schedule(("z", "y"), (2, 0), pulses_per_dim=(2, 2))
    assert len(sched.dim_pulses(1)) == 1


@pytest.mark.parametrize("backend",
                         ("serialized", "fused", "pallas", "signal"))
def test_width2_two_pulse_bitwise_identical(backend):
    """Width-2 halos, one- vs two-pulse schedules: same bytes, same bits,
    across all four backends (single-device periodic self-exchange; the
    8-device version is in tests/dist/check_halo.py)."""
    mesh = make_mesh((1,), ("z",))
    rng = np.random.RandomState(3)
    x = jnp.asarray(rng.randn(6, 5).astype(np.float32))
    shift = np.zeros((1, 5))
    shift[0, 0] = 17.0
    ref = np.asarray(HaloPlan.build(
        HaloSpec(("z",), (2,), backend="serialized", wrap_shift=shift),
        mesh).fwd(x))
    for pulses in (None, (2,)):
        plan = HaloPlan.build(
            HaloSpec(("z",), (2,), backend=backend, wrap_shift=shift,
                     pulses=pulses), mesh)
        np.testing.assert_array_equal(np.asarray(plan.fwd(x)), ref)


@pytest.mark.parametrize("backend",
                         ("serialized", "fused", "pallas", "signal"))
def test_width2_two_pulse_adjoint(backend):
    mesh = make_mesh((1,), ("z",))
    plan = HaloPlan.build(
        HaloSpec(("z",), (2,), backend=backend, pulses=(2,)), mesh)
    rng = np.random.RandomState(4)
    x = jnp.asarray(rng.randn(6, 4).astype(np.float32))
    y = jnp.asarray(rng.randn(8, 4).astype(np.float32))
    lhs = float(jnp.vdot(plan.fwd(x), y))
    rhs = float(jnp.vdot(x, plan.rev(y)))
    assert abs(lhs - rhs) <= 1e-5 * max(abs(lhs), 1.0)


# --------------------------------------------------------------------------
# signal ledger
# --------------------------------------------------------------------------

def test_ledger_release_acquire_balance():
    led = SignalLedger(depth=2, n_pulses=3)
    st = led.init()
    st = led.release(st, "fwd", 0)
    st = led.acquire(st, "fwd", 0)
    st = led.release(st, "rev", 1)
    assert bool(led.consistent(st))
    s = led.summary(st)
    assert s["fwd"] == {"released": 3, "acquired": 3}
    assert s["rev"] == {"released": 3, "acquired": 0}
    assert int(led.outstanding(st).sum()) == 3


def test_ledger_detects_unreleased_acquire():
    led = SignalLedger(depth=2, n_pulses=1)
    st = led.acquire(led.init(), "rev", 0)
    assert not bool(led.consistent(st))


def test_ledger_slot_parity_is_traceable():
    led = SignalLedger(depth=2, n_pulses=2)

    def f(k):
        return led.release(led.init(), "fwd", k % 2).released

    out = jax.jit(f)(jnp.int32(3))          # slot 1
    assert int(out[led.slot("fwd", 1, 0)]) == 1
    assert int(out[led.slot("fwd", 0, 0)]) == 0


# --------------------------------------------------------------------------
# step pipeline: off == double_buffer, bit for bit
# --------------------------------------------------------------------------

def _toy_fns():
    def begin(state, f, ctx):
        state = state + 0.1 * f
        return state, state.sum(), state

    def force(ext, ctx):
        F = jnp.tanh(ext) * ctx
        return F, {"pe": jnp.sum(F)}

    def finish(state, aux, f, ctx):
        state = state + 0.01 * f + 1e-3 * aux
        return state, f, {"ke": jnp.sum(state)}

    return StepFns(begin=begin, force=force, finish=finish)


def _run_pipeline(mode, n_steps, backend="signal"):
    mesh = make_mesh((1,), ("z",))
    plan = HaloPlan.build(HaloSpec(("z",), (2,), backend=backend), mesh)
    pipe = StepPipeline.build(plan, _toy_fns(), mode=mode)
    x0 = jnp.asarray(np.random.RandomState(0).randn(6, 4)
                     .astype(np.float32))

    def run(state, f):
        return pipe.run_local(state, f, n_steps, jnp.float32(0.5))

    fn = shard_map_norep(run, mesh=mesh, in_specs=(P(), P()),
                         out_specs=(P(), P(), P(), P()))
    state, f, metrics, led = jax.jit(fn)(x0, jnp.zeros_like(x0))
    return (np.asarray(state), np.asarray(f),
            {k: np.asarray(v) for k, v in metrics.items()},
            pipe.ledger.summary(jax.device_get(led)))


@pytest.mark.parametrize("n_steps", (1, 2, 7))
def test_pipeline_modes_bitwise_identical(n_steps):
    ref = _run_pipeline("off", n_steps)
    got = _run_pipeline("double_buffer", n_steps)
    np.testing.assert_array_equal(got[0], ref[0])
    np.testing.assert_array_equal(got[1], ref[1])
    for k in ref[2]:
        assert ref[2][k].shape[0] == n_steps
        np.testing.assert_array_equal(got[2][k], ref[2][k])


@pytest.mark.parametrize("mode", PIPELINE_MODES)
def test_pipeline_ledger_balances(mode):
    _, _, _, summary = _run_pipeline(mode, 5)
    assert summary["consistent"]
    for kind in ("fwd", "rev"):
        assert summary[kind]["released"] == 5
        assert summary[kind]["acquired"] == 5


def test_pipeline_rejects_bad_mode():
    mesh = make_mesh((1,), ("z",))
    plan = HaloPlan.build(HaloSpec(("z",), (1,)), mesh)
    with pytest.raises(ValueError, match="unknown pipeline mode"):
        StepPipeline.build(plan, _toy_fns(), mode="triple")


# --------------------------------------------------------------------------
# overlap + latency stats (plan-level, the ROADMAP items)
# --------------------------------------------------------------------------

def test_double_buffer_exposes_strictly_fewer_phases():
    mesh = make_mesh((1, 1, 1), ("z", "y", "x"))
    for backend in ("serialized", "fused", "pallas", "signal"):
        plan = HaloPlan.build(
            HaloSpec(("z", "y", "x"), (1, 1, 1), backend=backend), mesh)
        off = plan.stats((8, 8, 8), pipeline="off")
        db = plan.stats((8, 8, 8), pipeline="double_buffer")
        assert db["exposed_phases_per_step"] < \
            off["exposed_phases_per_step"]
        assert off["overlapped_bytes_per_step"] == 0
        assert db["overlapped_bytes_per_step"] == db["total_bytes"]


def test_latency_model_two_pulse_small_domain_regime():
    """Strong-scaling limit: with two pulses per dim the serialized path
    pays twice the per-message latency; the fused (put-with-signal) path
    still pays one latency per phase — the paper's crossover driver."""
    mesh = make_mesh((1, 1, 1), ("z", "y", "x"))
    plan = HaloPlan.build(
        HaloSpec(("z", "y", "x"), (2, 2, 2), pulses=(2, 2, 2)), mesh)
    lat = plan.stats((4, 4, 4))["latency"]
    assert lat["serialized_messages"] == 6
    assert len(lat["fused_phase_messages"]) == 3
    assert lat["serialized_time_s"] > lat["fused_time_s"]
    # tiny domains: latency-dominated, speedup approaches 6/3
    tiny = HaloPlan.build(
        HaloSpec(("z", "y", "x"), (2, 2, 2), pulses=(2, 2, 2)), mesh) \
        .stats((2, 2, 2), bandwidth_Bps=1e15)
    assert tiny["latency"]["fused_speedup"] == pytest.approx(2.0, rel=1e-3)


def test_stats_latency_configurable():
    mesh = make_mesh((1,), ("z",))
    plan = HaloPlan.build(HaloSpec(("z",), (1,)), mesh)
    fast = plan.stats((8,), link_latency_s=1e-9)["latency"]
    slow = plan.stats((8,), link_latency_s=1e-3)["latency"]
    assert slow["serialized_time_s"] > fast["serialized_time_s"]


# --------------------------------------------------------------------------
# MD engine through the pipeline (single device; 8-device in tests/dist)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("pipeline", PIPELINE_MODES)
def test_md_engine_pipeline_bitwise(pipeline):
    from repro.core.md import MDEngine, make_grappa_like

    sys_ = make_grappa_like(200, seed=5)
    mesh = make_mesh((1, 1, 1), ("z", "y", "x"))
    spec = HaloSpec(("z", "y", "x"), (1, 1, 1), backend="serialized")
    ref_eng = MDEngine(sys_, mesh, spec)
    (cf_ref, _), m_ref, _ = ref_eng.simulate(12)

    eng = MDEngine(sys_, mesh,
                   HaloSpec(("z", "y", "x"), (1, 1, 1), backend="signal"),
                   pipeline=pipeline)
    (cf, _), m, _ = eng.simulate(12)
    np.testing.assert_array_equal(np.asarray(jax.device_get(cf)),
                                  np.asarray(jax.device_get(cf_ref)))
    for k in m_ref:
        np.testing.assert_array_equal(np.asarray(m[k]),
                                      np.asarray(m_ref[k]))


def test_md_engine_overlap_stats_and_validation():
    from repro.core.md import MDEngine, make_grappa_like

    sys_ = make_grappa_like(200, seed=5)
    mesh = make_mesh((1, 1, 1), ("z", "y", "x"))
    with pytest.raises(ValueError, match="unknown pipeline"):
        MDEngine(sys_, mesh, pipeline="buffered")
    with pytest.raises(ValueError, match="widths must be >= 1"):
        MDEngine(sys_, mesh, HaloSpec(("z", "y", "x"), (1, 0, 1)))
    eng = MDEngine(sys_, mesh, pipeline="double_buffer")
    ov = eng.overlap_stats()
    assert ov["pipeline"] == "double_buffer"
    assert ov["overlapped_bytes_per_step"] > 0
