"""NVE energy-drift regression harness for the dual pair-list engine.

The rolling inner prune is an *approximate-but-bounded* optimization: a
pair dropped at a refresh contributes exactly zero force at that instant
(its bounding-box gap lower-bounds every atom distance at
``inner_radius >= r_cut``), and the Verlet-style buffer sizes the inner
radius so pairs cannot cross into ``r_cut`` before the next refresh
re-examines them.  This harness turns that argument into a measured
bound: float64 runs of the dense reference vs the sparse/pallas engines
with the rolling prune at several ``nstprune`` / ``inner_radius``
settings must all conserve energy to the same drift level over >= 200
steps — including a deliberately aggressive setting (``inner_radius ==
r_cut``, long refresh period) that removes the safety buffer entirely.

The test system is built so the dual list is actually *active* (not
vacuously conservative): two lattice slabs whose facing surfaces sit
inside the (inner_radius, outer_radius) band — on the outer list, off
the inner list — and drift toward each other, so cross-slab pairs
migrate between the lists during the run.  A homogeneous fluid would
never exercise this: its occupied bounding boxes fill the cutoff-sized
cells and no pair is ever distance-pruned.

The multi-device version of this check lives in
``tests/dist/check_md_nve.py``.
"""
import warnings

import numpy as np
import pytest

import jax

from repro.core.md.system import DEFAULT_FF, MDParams, MDSystem

# tight float64 drift ceiling for every engine/prune setting (measured
# dense drift of this system is ~4e-4/atom; integrator-truncation
# dominated, so all backends must land at the same level)
DRIFT_BOUND = 1.5e-3
N_STEPS = 200


def make_slab_system(ds=2.70, planes=2, a=1.09, L=10.8, temperature=1.5,
                     vclose=1.0, dt=2e-3, nstlist=20, seed=0,
                     dtype=np.float64):
    """Two lattice slabs with facing surfaces ``ds`` apart, closing at
    ``vclose`` — every cross-slab cell-column pair starts inside the
    Verlet band (kept by the outer list, dropped by the inner one) and
    crosses into the cutoff as the slabs approach.  The void is aligned
    with a cell boundary (cells are L/4 wide at these parameters): a
    cell straddling the void would see its bounding box span it and
    every cross-slab gap would collapse to zero."""
    rng = np.random.RandomState(seed)
    line = np.arange(int(L / a) + 1) * a
    line = line[line < L - 0.5 * a]
    yz = np.stack(np.meshgrid(line, line, indexing="ij"),
                  axis=-1).reshape(-1, 2)
    boundary = 2.0 * L / 4.0
    x1 = boundary - 0.05 - np.arange(planes) * a      # slab 1 planes
    x2 = boundary - 0.05 + ds + np.arange(planes) * a  # slab 2 planes
    pos = np.concatenate([
        np.concatenate([np.full((yz.shape[0], 1), x), yz], axis=1)
        for x in np.concatenate([x1, x2])])
    n = pos.shape[0]
    n1 = planes * yz.shape[0]
    vel = rng.normal(0, np.sqrt(temperature), (n, 3))
    vel -= vel.mean(0, keepdims=True)
    vel[:n1, 0] += vclose / 2
    vel[n1:, 0] -= vclose / 2
    params = MDParams(ff=DEFAULT_FF, dt=dt, nstlist=nstlist,
                      temperature=temperature)
    return MDSystem(box=np.array([L] * 3, np.float64),
                    pos=pos.astype(dtype), vel=vel.astype(dtype),
                    charge=np.zeros(n, dtype), typ=np.zeros(n, np.int8),
                    params=params)


# (name, engine kwargs) — the prune-setting sweep; "aggressive" removes
# the inner Verlet buffer entirely and refreshes only twice per block,
# "tight" drops the inner ladder's sizing margin to zero so the band
# pairs actually leave the evaluated schedule (and drift-induced growth
# exercises the overflow monitor + next-block fallback)
CONFIGS = {
    "dense": dict(),
    "sparse": dict(force_backend="sparse"),
    "sparse_np5": dict(force_backend="sparse", nstprune=5),
    "sparse_np5_tight": dict(force_backend="sparse", nstprune=5,
                             inner_safety=1.0),
    "sparse_np10": dict(force_backend="sparse", nstprune=10),
    "sparse_np10_aggressive": dict(force_backend="sparse", nstprune=10,
                                   inner_radius=DEFAULT_FF.r_cut,
                                   inner_safety=1.0),
    "pallas_np5": dict(force_backend="pallas", nstprune=5),
}


@pytest.fixture(scope="module")
def nve_runs():
    """One float64 N_STEPS run per prune setting (x64 scoped to here)."""
    from repro.core.halo_plan import HaloSpec
    from repro.core.md import MDEngine
    from repro.launch.mesh import make_mesh

    old_x64 = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        system = make_slab_system()
        mesh = make_mesh((1, 1, 1), ("z", "y", "x"))
        spec = HaloSpec(("z", "y", "x"), (1, 1, 1), backend="fused")
        out = {}
        for name, kw in CONFIGS.items():
            eng = MDEngine(system, mesh, spec, capacity_safety=4.0,
                           pair_bucket=8, **kw)
            with warnings.catch_warnings():
                # tight-safety configs may legitimately trip the
                # overflow fallback warning; it is asserted on below
                warnings.simplefilter("ignore", RuntimeWarning)
                _, metrics, diags = eng.simulate(N_STEPS)
            E = np.asarray(metrics["pe"]) + np.asarray(metrics["ke"])
            out[name] = {
                "E": E,
                "drift": float((E.max() - E.min()) / system.n_atoms),
                "mom": np.asarray(metrics["mom"]),
                "history": list(eng.sched_history),
                "pair_stats": eng.pair_stats(),
                "n_atoms_ok": all(
                    int(np.asarray(d["n_atoms"])) == system.n_atoms
                    for d in diags),
            }
        return out
    finally:
        jax.config.update("jax_enable_x64", old_x64)


# an inner ladder sized with zero margin can be outgrown mid-block by
# drift; the refresh then cannot seat every survivor and real pairs go
# unevaluated until the next rebin.  That breach is ALLOWED only if the
# overflow monitor flags it — the loose ceiling just rules out blowups.
LOOSE_BOUND = 0.5


def _overflowed(run) -> bool:
    return run["pair_stats"].get("inner_overflow_blocks", 0) > 0


@pytest.mark.parametrize("name", list(CONFIGS))
def test_drift_bounded(nve_runs, name):
    """Every overflow-free prune setting — including the buffer-free
    aggressive one — must conserve energy to the float64
    integrator-truncation level; flagged-overflow runs stay bounded."""
    run = nve_runs[name]
    assert np.all(np.isfinite(run["E"])), name
    assert run["n_atoms_ok"], name
    bound = LOOSE_BOUND if _overflowed(run) else DRIFT_BOUND
    assert run["drift"] < bound, (name, run["drift"])


@pytest.mark.parametrize("name", [n for n in CONFIGS if n != "dense"])
def test_prune_matches_dense_drift(nve_runs, name):
    """Without overflow the pruned engines' drift must sit at the dense
    reference's level: the inner prune then only drops pairs beyond the
    cutoff, so it cannot add an energy-drift channel of its own."""
    run = nve_runs[name]
    if _overflowed(run):
        pytest.skip("overflow flagged; covered by "
                    "test_overflow_is_flagged_not_silent")
    d_ref = nve_runs["dense"]["drift"]
    assert abs(run["drift"] - d_ref) <= 0.5 * d_ref + 1e-5, \
        (name, run["drift"], d_ref)


def test_overflow_is_flagged_not_silent(nve_runs):
    """The central safety contract: a prune approximation that actually
    perturbs the trajectory beyond the integrator's own drift MUST have
    been flagged by the overflow monitor — corruption is never silent."""
    d_ref = nve_runs["dense"]["drift"]
    for name in (n for n in CONFIGS if n != "dense"):
        run = nve_runs[name]
        if run["drift"] > 3 * d_ref + 1e-4:
            assert _overflowed(run), \
                (name, run["drift"], run["pair_stats"])
    # and the zero-margin config does deterministically trip it
    assert _overflowed(nve_runs["sparse_np5_tight"])


def test_dual_list_is_active(nve_runs):
    """The harness must not pass vacuously: with the sizing margin at
    zero the cross-slab band pairs leave the inner ladder, so at some
    block it is strictly smaller than the outer one."""
    for name in ("sparse_np5_tight", "sparse_np10_aggressive"):
        hist = nve_runs[name]["history"]
        assert any(inner < outer for outer, inner in hist), (name, hist)
        ps = nve_runs[name]["pair_stats"]
        assert ps["nstprune"] == CONFIGS[name]["nstprune"]
        assert ps["evaluated_slot_pairs"] <= ps["outer_slot_pairs"]
        # overflow blocks are allowed (the monitor + fallback is part of
        # the contract) but must be counted, not silent
        assert ps["inner_overflow_blocks"] >= 0
    for name in ("sparse_np5", "sparse_np10"):
        ps = nve_runs[name]["pair_stats"]
        assert ps["nstprune"] == CONFIGS[name]["nstprune"]
    # the un-pruned run reports inner == outer everywhere
    assert all(i == o for o, i in nve_runs["sparse"]["history"])


def test_momentum_conserved(nve_runs):
    for name, run in nve_runs.items():
        assert np.abs(run["mom"]).max() < 1e-2, name


def test_final_block_overflow_is_counted():
    """Regression: a run whose ONLY block overflows (n_steps <= nstlist,
    so no rebin boundary ever reads the prune outputs again) must still
    count and warn — the monitor contract has no final-block blind
    spot."""
    from repro.core.halo_plan import HaloSpec
    from repro.core.md import MDEngine
    from repro.launch.mesh import make_mesh

    old_x64 = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        system = make_slab_system()
        mesh = make_mesh((1, 1, 1), ("z", "y", "x"))
        spec = HaloSpec(("z", "y", "x"), (1, 1, 1), backend="fused")
        eng = MDEngine(system, mesh, spec, capacity_safety=4.0,
                       pair_bucket=8, force_backend="sparse", nstprune=5,
                       inner_safety=1.0)
        with pytest.warns(RuntimeWarning, match="overflowed its tier"):
            eng.simulate(system.params.nstlist)      # exactly one block
        assert eng.pair_stats()["inner_overflow_blocks"] == 1
    finally:
        jax.config.update("jax_enable_x64", old_x64)


# --- compressed halo payloads (HaloSpec.wire_dtype) -------------------------
# The drift-bounded wire-format contract (see repro.core.wire): every
# accepted format must conserve energy at the dense-f32 level under the
# same slab harness, and the documented over-aggressive config (plain
# int8, no error feedback) must be rejected at build time.

WIRE_CONFIGS = ("float32", "bfloat16", "float16", "int8_ef")


@pytest.fixture(scope="module")
def wire_nve_runs():
    """One float64 N_STEPS run per accepted wire format (fused backend,
    dense force path — isolates the wire's contribution to drift)."""
    from repro.core.halo_plan import HaloSpec
    from repro.core.md import MDEngine
    from repro.launch.mesh import make_mesh

    old_x64 = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        system = make_slab_system()
        mesh = make_mesh((1, 1, 1), ("z", "y", "x"))
        spec = HaloSpec(("z", "y", "x"), (1, 1, 1), backend="fused")
        out = {}
        for wd in (None,) + WIRE_CONFIGS:
            eng = MDEngine(system, mesh, spec, capacity_safety=4.0,
                           pair_bucket=8, wire_dtype=wd)
            _, metrics, _ = eng.simulate(N_STEPS)
            E = np.asarray(metrics["pe"]) + np.asarray(metrics["ke"])
            out[wd] = {"E": E,
                       "drift": float((E.max() - E.min()) / system.n_atoms)}
        return out
    finally:
        jax.config.update("jax_enable_x64", old_x64)


@pytest.mark.parametrize("wire_dtype", WIRE_CONFIGS)
def test_wire_drift_at_dense_level(wire_nve_runs, wire_dtype):
    """Accepted wire formats must conserve energy to the same
    integrator-truncation level as the dense exchange: compression is
    only legal when it does not open a new drift channel."""
    run = wire_nve_runs[wire_dtype]
    assert np.all(np.isfinite(run["E"])), wire_dtype
    assert run["drift"] < DRIFT_BOUND, (wire_dtype, run["drift"])
    d_ref = wire_nve_runs[None]["drift"]
    assert run["drift"] <= 2 * d_ref + 1e-5, \
        (wire_dtype, run["drift"], d_ref)


@pytest.mark.parametrize("wire_dtype", WIRE_CONFIGS)
def test_wire_drift_table_is_honest(wire_nve_runs, wire_dtype):
    """The build-time gate decides from repro.core.wire.MEASURED_DRIFT;
    this re-measurement keeps that table from going stale: the recorded
    value must classify the format the same way the fresh run does and
    stay within a small factor of it."""
    from repro.core.wire import DENSE_F32_DRIFT_BOUND, MEASURED_DRIFT

    measured = wire_nve_runs[wire_dtype]["drift"]
    recorded = MEASURED_DRIFT[wire_dtype]
    assert (measured < DENSE_F32_DRIFT_BOUND) == \
        (recorded < DENSE_F32_DRIFT_BOUND), (measured, recorded)
    assert recorded / 3 < measured < recorded * 3, (measured, recorded)


def test_wire_int8_rejected_at_build():
    """The over-aggressive config (int8 without error feedback: its
    quantization bias accumulates, measured drift 2x over the bound) is
    rejected when the engine builds its plan — before any step runs —
    and the verify escape hatch still lets it be measured."""
    from repro.core.halo_plan import HaloSpec
    from repro.core.md import MDEngine
    from repro.core.wire import WireDriftError
    from repro.launch.mesh import make_mesh

    system = make_slab_system(dtype=np.float32)
    mesh = make_mesh((1, 1, 1), ("z", "y", "x"))
    spec = HaloSpec(("z", "y", "x"), (1, 1, 1), backend="fused")
    with pytest.raises(WireDriftError, match="exceeds the dense-f32"):
        MDEngine(system, mesh, spec, capacity_safety=4.0, pair_bucket=8,
                 wire_dtype="int8")
    with pytest.warns(RuntimeWarning, match="exceeds the dense-f32"):
        MDEngine(system, mesh, spec, capacity_safety=4.0, pair_bucket=8,
                 wire_dtype="int8", verify="warn")
