"""4-virtual-device check: the Pallas halo + NB kernels against jnp oracles.

Drives ``put_signal`` (both ring directions) and ``fused_pulses``
(independent + staged-dependent index maps, padding entries) inside a
shard_map and compares against ppermute oracles bit for bit; plus the NB
cluster-pair kernel with its scatter-accumulate epilogue
(``pair_forces_accum``) against a sequential numpy oracle, per device
inside the same shard_map.

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python tests/dist/check_kernel_halo.py
"""
import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map_norep
from repro.core.md.system import DEFAULT_FF
from repro.kernels import halo_pack, nonbonded
from repro.launch.mesh import make_mesh

RING = 4


def run_sharded(mesh, body, *args, out_specs=P("z")):
    fn = shard_map_norep(body, mesh=mesh, in_specs=(P("z"),) * len(args),
                         out_specs=out_specs)
    return np.asarray(jax.jit(fn)(*args))


def main():
    assert len(jax.devices()) >= RING, "need 4 virtual devices"
    mesh = make_mesh((RING,), ("z",))
    rng = np.random.RandomState(0)
    n_local, F = 6, 3
    x = jnp.asarray(rng.randn(RING * n_local, F).astype(np.float32))

    # ---- put_signal, both directions ---------------------------------
    idx = jnp.asarray([0, 1, 4], dtype=jnp.int32)
    for shift, perm in ((-1, [(j, (j - 1) % RING) for j in range(RING)]),
                        (+1, [(j, (j + 1) % RING) for j in range(RING)])):
        got = run_sharded(
            mesh, functools.partial(halo_pack.put_signal, index_map=idx,
                                    axis="z", ring=RING, shift=shift), x)
        ref = run_sharded(
            mesh, lambda lo: lax.ppermute(jnp.take(lo, idx, axis=0), "z",
                                          perm), x)
        assert np.array_equal(got, ref), f"put_signal shift={shift}"
        print(f"put_signal shift={shift:+d}: bitwise == ppermute oracle")

    # ---- fused_pulses: pulse 1 independent, pulse 2 dependent+padded --
    maps = np.full((2, 4), -1, np.int32)
    maps[0] = [0, 1, 2, 3]            # independent rows
    maps[1, :3] = [4, n_local + 1, n_local + 3]   # own + prev-recv rows
    jmaps = jnp.asarray(maps)

    got = run_sharded(
        mesh, functools.partial(halo_pack.fused_pulses, index_maps=jmaps,
                                axis="z", ring=RING, n_local=n_local), x)

    def oracle(lo):
        perm = [(j, (j - 1) % RING) for j in range(RING)]
        outs, prev = [], jnp.zeros((4, F), lo.dtype)
        for p in range(2):
            mrow = jnp.asarray(maps[p])
            valid = mrow >= 0
            safe = jnp.maximum(mrow, 0)
            local = jnp.take(lo, jnp.clip(safe, 0, n_local - 1), axis=0)
            dep = jnp.take(prev, jnp.clip(safe - n_local, 0, 3), axis=0)
            rows = jnp.where((safe >= n_local)[:, None], dep, local)
            rows = jnp.where(valid[:, None], rows, 0.0)
            prev = lax.ppermute(rows, "z", perm)
            outs.append(prev)
        return jnp.stack(outs)

    ref = run_sharded(mesh, oracle, x)
    assert np.array_equal(got, ref), "fused_pulses vs staged oracle"
    # padding entries must land as zero rows
    assert np.all(got.reshape(RING, 2, 4, F)[:, 1, 3] == 0.0)
    print("fused_pulses: bitwise == staged-forwarding oracle "
          "(dependent entries + padding)")

    # ---- pack / unpack_add round trip --------------------------------
    rows = jnp.asarray(rng.randn(4, F).astype(np.float32))
    dst = jnp.asarray(rng.randn(n_local, F).astype(np.float32))
    pidx = jnp.asarray([5, 0, 3, 2], dtype=jnp.int32)
    packed = halo_pack.pack(dst, pidx)
    np.testing.assert_array_equal(np.asarray(packed),
                                  np.asarray(dst)[np.asarray(pidx)])
    added = halo_pack.unpack_add(dst, pidx, rows)
    ref_add = np.array(dst)
    ref_add[np.asarray(pidx)] += np.asarray(rows)
    np.testing.assert_allclose(np.asarray(added), ref_add, atol=0)
    print("pack/unpack_add: exact gather / scatter-add")

    # ---- NB pair kernel + scatter-accumulate epilogue vs oracle -------
    # each device runs the kernel on its own batch (sharded over z); the
    # pallas epilogue must match a strictly sequential accumulation
    n_pair, k, n_cells = 8, 8, 6
    a = rng.uniform(0, 2.5, (RING * n_pair, k, 4)).astype(np.float32)
    b = rng.uniform(0, 2.5, (RING * n_pair, k, 4)).astype(np.float32)
    ta = rng.randint(-1, 2, (RING * n_pair, k)).astype(np.int32)
    tb = rng.randint(-1, 2, (RING * n_pair, k)).astype(np.int32)
    same = np.zeros(RING * n_pair, np.int32)
    same[::4] = 1
    b[same > 0] = a[same > 0]
    tb[same > 0] = ta[same > 0]
    ca = rng.randint(0, n_cells, RING * n_pair).astype(np.int32)
    cb = rng.randint(0, n_cells, RING * n_pair).astype(np.int32)

    def nb_body(a, b, ta, tb, same, ca, cb):
        F, pe = nonbonded.pair_forces_accum(a, b, ta, tb, same, ca, cb,
                                            DEFAULT_FF, n_cells,
                                            epilogue="pallas")
        return F, pe

    fn = shard_map_norep(nb_body, mesh=mesh, in_specs=(P("z"),) * 7,
                         out_specs=(P("z"), P("z")))
    F_got, pe_got = jax.jit(fn)(*map(jnp.asarray,
                                     (a, b, ta, tb, same, ca, cb)))
    F_got = np.asarray(F_got).reshape(RING, n_cells, k, 3)

    fa, fb, pe_ref = nonbonded.pair_forces(
        *map(jnp.asarray, (a, b, ta, tb, same)), DEFAULT_FF)
    fa, fb = np.asarray(fa), np.asarray(fb)
    F_ref = np.zeros((RING, n_cells, k, 3), np.float32)
    for i in range(RING * n_pair):
        F_ref[i // n_pair, ca[i]] += fa[i]
        F_ref[i // n_pair, cb[i]] += fb[i]
    assert np.array_equal(F_got, F_ref), "pair_forces_accum vs oracle"
    assert np.array_equal(np.asarray(pe_got).reshape(-1),
                          np.asarray(pe_ref)), "pair energies"
    print("pair_forces_accum: scatter epilogue bitwise == sequential "
          "oracle (4 device batches)")

    print("check_kernel_halo OK")


if __name__ == "__main__":
    main()
