"""4-virtual-device check: the Pallas halo kernels against jnp oracles.

Drives ``put_signal`` (both ring directions) and ``fused_pulses``
(independent + staged-dependent index maps, padding entries) inside a
shard_map and compares against ppermute oracles bit for bit.

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python tests/dist/check_kernel_halo.py
"""
import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map_norep
from repro.kernels import halo_pack
from repro.launch.mesh import make_mesh

RING = 4


def run_sharded(mesh, body, *args, out_specs=P("z")):
    fn = shard_map_norep(body, mesh=mesh, in_specs=(P("z"),) * len(args),
                         out_specs=out_specs)
    return np.asarray(jax.jit(fn)(*args))


def main():
    assert len(jax.devices()) >= RING, "need 4 virtual devices"
    mesh = make_mesh((RING,), ("z",))
    rng = np.random.RandomState(0)
    n_local, F = 6, 3
    x = jnp.asarray(rng.randn(RING * n_local, F).astype(np.float32))

    # ---- put_signal, both directions ---------------------------------
    idx = jnp.asarray([0, 1, 4], dtype=jnp.int32)
    for shift, perm in ((-1, [(j, (j - 1) % RING) for j in range(RING)]),
                        (+1, [(j, (j + 1) % RING) for j in range(RING)])):
        got = run_sharded(
            mesh, functools.partial(halo_pack.put_signal, index_map=idx,
                                    axis="z", ring=RING, shift=shift), x)
        ref = run_sharded(
            mesh, lambda lo: lax.ppermute(jnp.take(lo, idx, axis=0), "z",
                                          perm), x)
        assert np.array_equal(got, ref), f"put_signal shift={shift}"
        print(f"put_signal shift={shift:+d}: bitwise == ppermute oracle")

    # ---- fused_pulses: pulse 1 independent, pulse 2 dependent+padded --
    maps = np.full((2, 4), -1, np.int32)
    maps[0] = [0, 1, 2, 3]            # independent rows
    maps[1, :3] = [4, n_local + 1, n_local + 3]   # own + prev-recv rows
    jmaps = jnp.asarray(maps)

    got = run_sharded(
        mesh, functools.partial(halo_pack.fused_pulses, index_maps=jmaps,
                                axis="z", ring=RING, n_local=n_local), x)

    def oracle(lo):
        perm = [(j, (j - 1) % RING) for j in range(RING)]
        outs, prev = [], jnp.zeros((4, F), lo.dtype)
        for p in range(2):
            mrow = jnp.asarray(maps[p])
            valid = mrow >= 0
            safe = jnp.maximum(mrow, 0)
            local = jnp.take(lo, jnp.clip(safe, 0, n_local - 1), axis=0)
            dep = jnp.take(prev, jnp.clip(safe - n_local, 0, 3), axis=0)
            rows = jnp.where((safe >= n_local)[:, None], dep, local)
            rows = jnp.where(valid[:, None], rows, 0.0)
            prev = lax.ppermute(rows, "z", perm)
            outs.append(prev)
        return jnp.stack(outs)

    ref = run_sharded(mesh, oracle, x)
    assert np.array_equal(got, ref), "fused_pulses vs staged oracle"
    # padding entries must land as zero rows
    assert np.all(got.reshape(RING, 2, 4, F)[:, 1, 3] == 0.0)
    print("fused_pulses: bitwise == staged-forwarding oracle "
          "(dependent entries + padding)")

    # ---- pack / unpack_add round trip --------------------------------
    rows = jnp.asarray(rng.randn(4, F).astype(np.float32))
    dst = jnp.asarray(rng.randn(n_local, F).astype(np.float32))
    pidx = jnp.asarray([5, 0, 3, 2], dtype=jnp.int32)
    packed = halo_pack.pack(dst, pidx)
    np.testing.assert_array_equal(np.asarray(packed),
                                  np.asarray(dst)[np.asarray(pidx)])
    added = halo_pack.unpack_add(dst, pidx, rows)
    ref_add = np.array(dst)
    ref_add[np.asarray(pidx)] += np.asarray(rows)
    np.testing.assert_allclose(np.asarray(added), ref_add, atol=0)
    print("pack/unpack_add: exact gather / scatter-add")

    print("check_kernel_halo OK")


if __name__ == "__main__":
    main()
