"""8-virtual-device check: error-feedback compressed pod reductions.

The cross-pod (DCN) analogue of the paper's transport adaptivity
(optim/compression.py): int8 and top-k reductions with error feedback
must converge to the uncompressed mean over steps, and with mode=None
``compressed_pod_mean`` must equal the plain pmean exactly.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python tests/dist/check_compression.py
"""
import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.launch.mesh import make_mesh
from repro.optim.compression import compressed_pod_mean, ef_init


def main():
    assert len(jax.devices()) >= 8, "need 8 virtual devices"
    mesh = make_mesh((8,), ("pod",))
    rng = np.random.RandomState(0)
    # per-pod gradients: shared signal + pod-dependent noise
    base = rng.randn(64, 8).astype(np.float32)
    noise = rng.randn(8, 64, 8).astype(np.float32) * 0.1
    gstack = jnp.asarray(base[None] + noise)            # (pods, ...)
    g_true = np.asarray(jnp.mean(gstack, axis=0))

    def reduce_step(g, e, mode):
        params = {"w": g}
        ef = {"w": e}
        out, ef = compressed_pod_mean(params, ef, mode, axis="pod",
                                      topk_frac=0.25)
        return out["w"], ef["w"]

    for mode in (None, "int8", "topk"):
        fn = shard_map(functools.partial(reduce_step, mode=mode),
                       mesh=mesh, in_specs=(P("pod"), P("pod")),
                       out_specs=(P("pod"), P("pod")), check_vma=False)
        gshard = gstack.reshape(8 * 64, 8)
        e = jnp.asarray(ef_init({"w": np.zeros((64, 8), np.float32)})["w"])
        eshard = jnp.tile(e, (8, 1))

        if mode is None:
            out, _ = fn(gshard, eshard)
            out = np.asarray(out).reshape(8, 64, 8)
            for p in range(8):
                assert np.allclose(out[p], g_true, atol=1e-6)
            print("mode=None: matches plain pmean")
            continue

        # EF accumulation over repeated steps of the same gradient: the
        # compressed running sum must converge to the true mean
        acc = np.zeros_like(g_true)
        eshard_cur = eshard
        steps = 50
        for _ in range(steps):
            out, enew = fn(gshard, eshard_cur)
            acc += np.asarray(out).reshape(8, 64, 8)[0]
            eshard_cur = enew
        rel = np.abs(acc / steps - g_true).max() / np.abs(g_true).max()
        assert rel < 0.05, (mode, rel)
        print(f"mode={mode}: EF-compressed mean rel err {rel:.3f} "
              f"after {steps} steps")

    print("check_compression OK")


if __name__ == "__main__":
    main()
