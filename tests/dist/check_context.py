"""8-virtual-device check: ring attention schedules + distributed decode.

The LM-side instance of the halo problem (parallel/context.py): the
serialized and fused KV-pulse schedules must agree with each other and
with single-device full attention; distributed decode over a seq-sharded
cache must match the full-cache reference.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python tests/dist/check_context.py
"""
import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.launch.mesh import make_mesh
from repro.parallel.context import (
    distributed_decode,
    ring_attention_sharded,
)


def full_attention_reference(q, k, v, causal=True):
    B, L, H, hd = q.shape
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * hd ** -0.5
    if causal:
        mask = jnp.arange(L)[:, None] >= jnp.arange(L)[None, :]
        logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(v.dtype)


def main():
    assert len(jax.devices()) >= 8, "need 8 virtual devices"
    mesh = make_mesh((8,), ("seq",))
    rng = np.random.RandomState(0)
    B, L, H, hd = 2, 64, 4, 16
    q = jnp.asarray(rng.randn(B, L, H, hd).astype(np.float32) * 0.3)
    k = jnp.asarray(rng.randn(B, L, H, hd).astype(np.float32) * 0.3)
    v = jnp.asarray(rng.randn(B, L, H, hd).astype(np.float32))

    ref = np.asarray(full_attention_reference(q, k, v))
    outs = {}
    for mode in ("serialized", "fused"):
        out = np.asarray(ring_attention_sharded(q, k, v, mesh, "seq",
                                                mode=mode))
        err = np.abs(out - ref).max() / np.abs(ref).max()
        assert err < 1e-5, (mode, err)
        outs[mode] = out
        print(f"ring_attention[{mode}]: rel err vs full attention "
              f"{err:.2e}")
    # the two schedules compute identical online-softmax merges
    assert np.array_equal(outs["serialized"], outs["fused"]), \
        "fused and serialized ring schedules disagree"
    print("fused == serialized bitwise")

    # ---- distributed decode over the seq-sharded cache -----------------
    cache_len = jnp.asarray([L, L // 2])
    q1 = jnp.asarray(rng.randn(B, 1, H, hd).astype(np.float32) * 0.3)
    S_loc = L // 8

    def decode_local(q1, k_shard, v_shard, cache_len):
        off = jax.lax.axis_index("seq") * S_loc
        return distributed_decode(q1, k_shard, v_shard, cache_len, "seq",
                                  off)

    fn = shard_map(functools.partial(decode_local), mesh=mesh,
                   in_specs=(P(), P(None, "seq"), P(None, "seq"), P()),
                   out_specs=P(), check_vma=False)
    got = np.asarray(fn(q1, k, v, cache_len))

    # reference: full attention of the single token over the valid cache
    logits = jnp.einsum("bqhd,bkhd->bhqk", q1.astype(jnp.float32),
                        k.astype(jnp.float32)) * hd ** -0.5
    valid = jnp.arange(L)[None] < cache_len[:, None]
    logits = jnp.where(valid[:, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    ref1 = np.asarray(jnp.einsum("bhqk,bkhd->bqhd", p,
                                 v.astype(jnp.float32)))
    err = np.abs(got - ref1).max() / np.abs(ref1).max()
    assert err < 1e-5, err
    print(f"distributed_decode: rel err {err:.2e}")

    print("check_context OK")


if __name__ == "__main__":
    main()
