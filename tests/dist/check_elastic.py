"""8-virtual-device check: elastic reshard — save on mesh A, resume on B.

The checkpoint manager's restore path re-device_puts arrays under the
CURRENT mesh's shardings, so a run saved on an 8-way mesh must resume
bit-identically on a 4-way (or 2x4) mesh and vice versa — the paper-era
fault-tolerance requirement for 1000+-node runs.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python tests/dist/check_elastic.py
"""
import tempfile

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.ckpt.checkpoint import CheckpointManager
from repro.launch.mesh import make_mesh


def shardings_for(mesh, tree, spec):
    return jax.tree.map(lambda _: NamedSharding(mesh, spec), tree)


def main():
    assert len(jax.devices()) >= 8, "need 8 virtual devices"
    rng = np.random.RandomState(0)
    tree = {"w": jnp.asarray(rng.randn(16, 8).astype(np.float32)),
            "opt": {"m": jnp.asarray(rng.randn(16, 8).astype(np.float32)),
                    "step": jnp.asarray(7, jnp.int32)}}

    mesh_a = make_mesh((8,), ("data",))
    sh_a = {"w": NamedSharding(mesh_a, P("data")),
            "opt": {"m": NamedSharding(mesh_a, P("data")),
                    "step": NamedSharding(mesh_a, P())}}
    placed = jax.tree.map(jax.device_put, tree, sh_a)

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(3, placed)
        mgr.wait()
        assert mgr.latest_valid_step() == 3

        # resume on a SMALLER mesh (8 -> 4 devices) and a 2-D mesh
        for shape, axes, spec in (((4,), ("data",), P("data")),
                                  ((2, 4), ("data", "model"),
                                   P("data", "model"))):
            mesh_b = make_mesh(shape, axes)
            sh_b = {"w": NamedSharding(mesh_b, spec),
                    "opt": {"m": NamedSharding(mesh_b, spec),
                            "step": NamedSharding(mesh_b, P())}}
            restored = mgr.restore(3, placed, shardings=sh_b)
            for path, a in [("w", restored["w"]),
                            ("m", restored["opt"]["m"])]:
                assert a.sharding.mesh.shape == dict(
                    zip(axes, shape)), (path, a.sharding)
            np.testing.assert_array_equal(np.asarray(restored["w"]),
                                          np.asarray(tree["w"]))
            np.testing.assert_array_equal(
                np.asarray(restored["opt"]["m"]),
                np.asarray(tree["opt"]["m"]))
            assert int(restored["opt"]["step"]) == 7
            print(f"reshard 8-way -> {shape} {axes}: values bitwise, "
                  "shardings re-placed")

        # and a compute sanity pass on the resharded state
        mesh_b = make_mesh((4,), ("data",))
        restored = mgr.restore(
            3, placed,
            shardings={"w": NamedSharding(mesh_b, P("data")),
                       "opt": {"m": NamedSharding(mesh_b, P("data")),
                               "step": NamedSharding(mesh_b, P())}})
        out = jax.jit(lambda t: t["w"] @ t["opt"]["m"].T)(restored)
        ref = np.asarray(tree["w"]) @ np.asarray(tree["opt"]["m"]).T
        np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-6)
        print("post-reshard jitted compute matches")

    print("check_elastic OK")


if __name__ == "__main__":
    main()
