"""8-virtual-device float64 NVE check: tight energy conservation under DD.

float32 runs tolerate ~1e-3/atom energy drift; in float64 the velocity-
Verlet + cutoff-LJ/RF integrator on the 2x2x2 DD mesh must conserve
energy orders of magnitude tighter, for both pipeline schedules.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python tests/dist/check_md_nve.py
"""
import numpy as np

import jax

jax.config.update("jax_enable_x64", True)

from repro.core.halo_plan import HaloSpec                     # noqa: E402
from repro.core.md import MDEngine, make_grappa_like          # noqa: E402
from repro.launch.mesh import make_mesh                       # noqa: E402

AXES = ("z", "y", "x")


def main():
    assert len(jax.devices()) >= 8, "need 8 virtual devices"
    mesh = make_mesh((2, 2, 2), AXES)
    # drift is integrator-truncation dominated (O(dt^2)), so the tight
    # threshold needs the smaller step; float64 removes the rounding floor
    system = make_grappa_like(600, seed=9, dtype=np.float64, dt=5e-4)
    assert system.pos.dtype == np.float64

    drifts = {}
    for pipeline in ("off", "double_buffer"):
        eng = MDEngine(system, mesh,
                       HaloSpec(AXES, (1, 1, 1), backend="signal"),
                       pipeline=pipeline)
        assert eng.plan.spec.dtype == "float64"
        _, metrics, diags = eng.simulate(30)
        for d in diags:
            assert int(np.asarray(d["n_atoms"])) == system.n_atoms
        E = np.asarray(metrics["pe"]) + np.asarray(metrics["ke"])
        assert np.all(np.isfinite(E))
        drift = float((E.max() - E.min()) / system.n_atoms)
        drifts[pipeline] = drift
        assert drift < 3e-4, (pipeline, drift)
        print(f"{pipeline}: float64 NVE drift/atom {drift:.2e}")

    assert drifts["off"] == drifts["double_buffer"], \
        "pipelined float64 trajectory diverged from serialized"

    # --- dual pair list under DD: the rolling inner prune must hold the
    # same float64 drift bound on the 2x2x2 mesh, for both pipeline
    # schedules (bitwise-identical to each other at a fixed nstprune)
    sparse_drifts = {}
    for pipeline in ("off", "double_buffer"):
        eng = MDEngine(system, mesh,
                       HaloSpec(AXES, (1, 1, 1), backend="signal"),
                       pipeline=pipeline, force_backend="sparse",
                       nstprune=5)
        _, metrics, diags = eng.simulate(30)
        for d in diags:
            assert int(np.asarray(d["n_atoms"])) == system.n_atoms
        E = np.asarray(metrics["pe"]) + np.asarray(metrics["ke"])
        assert np.all(np.isfinite(E))
        drift = float((E.max() - E.min()) / system.n_atoms)
        sparse_drifts[pipeline] = drift
        assert drift < 3e-4, ("sparse/np5", pipeline, drift)
        assert eng.pair_stats()["inner_overflow_blocks"] == 0
        print(f"sparse/np5/{pipeline}: float64 NVE drift/atom {drift:.2e}")
    assert sparse_drifts["off"] == sparse_drifts["double_buffer"], \
        "pipelined dual-list trajectory diverged from serialized"
    print("check_md_nve OK")


if __name__ == "__main__":
    main()
