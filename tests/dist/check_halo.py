"""8-virtual-device check: four backends, width>1 and multi-pulse halos.

Extends check_halo_plan.py to the ``"signal"`` (put-with-signal) backend
and the width=2 / two-pulse schedules of the step-pipeline PR: every
backend must reproduce the serialized forward exchange bitwise, for
single-pulse AND two-pulse splits of the same widths, and every backend's
reverse must be the exact adjoint of its forward.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python tests/dist/check_halo.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core.halo_plan import HaloPlan, HaloSpec
from repro.launch.mesh import make_mesh

BACKENDS = ("serialized", "fused", "pallas", "signal")


def check_case(mesh, widths, pulses, shape):
    axes = ("z", "y", "x")
    rng = np.random.RandomState(sum(widths))
    x = jnp.asarray(rng.randn(*shape).astype(np.float32))
    shift = np.zeros((3, shape[-1]))
    shift[0, 0], shift[1, 1], shift[2, 2] = 10.0, 20.0, 30.0

    ref = np.asarray(HaloPlan.build(
        HaloSpec(axis_names=axes, widths=widths, backend="serialized",
                 wrap_shift=shift), mesh).fwd(x))
    ext_shape = tuple(s + w * mesh.shape[a]
                      for s, w, a in zip(shape, widths, axes)) + shape[3:]
    assert ref.shape == ext_shape, (ref.shape, ext_shape)
    y = jnp.asarray(rng.randn(*ref.shape).astype(np.float32))

    for b in BACKENDS:
        plan = HaloPlan.build(
            HaloSpec(axis_names=axes, widths=widths, backend=b,
                     wrap_shift=shift, pulses=pulses), mesh)
        got = np.asarray(plan.fwd(x))
        assert np.array_equal(got, ref), \
            f"{b} fwd (pulses={pulses}) differs from serialized"
        plain = HaloPlan.build(
            HaloSpec(axis_names=axes, widths=widths, backend=b,
                     pulses=pulses), mesh)
        lhs = float(jnp.vdot(plain.fwd(x), y))
        rhs = float(jnp.vdot(x, plain.rev(y)))
        rel = abs(lhs - rhs) / max(abs(lhs), 1.0)
        assert rel < 1e-5, (b, pulses, lhs, rhs)
    print(f"widths={widths} pulses={pulses}: fwd bitwise + adjoint OK "
          f"across {BACKENDS}")


def check_signal_rev_bitwise(mesh):
    """The force-return paths that must agree bit-for-bit (the pipelined
    MD acceptance depends on signal.rev == serialized.rev exactly)."""
    axes = ("z", "y", "x")
    rng = np.random.RandomState(7)
    y = jnp.asarray(rng.randn(10, 10, 6, 5).astype(np.float32))
    widths = (1, 2, 1)
    ref = np.asarray(HaloPlan.build(
        HaloSpec(axes, widths, backend="serialized"), mesh).rev(y))
    for b, pulses in (("signal", None), ("signal", (1, 2, 1)),
                      ("pallas", None)):
        got = np.asarray(HaloPlan.build(
            HaloSpec(axes, widths, backend=b, pulses=pulses),
            mesh).rev(y))
        assert np.array_equal(got, ref), f"{b} rev differs (pulses={pulses})"
    print("signal/pallas rev bitwise identical to serialized")


def check_wire_case(mesh):
    """Compressed payloads (HaloSpec.wire_dtype) on the real 8-device
    grid: every backend must transport the same wire-gridded payload —
    cross-backend bitwise equality holds per wire format (fused rev at
    its usual one-ulp accumulation tolerance, same as dense), the body
    never crosses the wire, and f32 coordinate sends ride dense (the
    forward direction's float32 floor)."""
    axes = ("z", "y", "x")
    widths = (1, 2, 1)
    rng = np.random.RandomState(3)
    old_x64 = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        x = jnp.asarray(rng.randn(8, 6, 4, 5))          # float64 payload
        dense = np.asarray(HaloPlan.build(
            HaloSpec(axes, widths, backend="serialized", dtype="float64"),
            mesh).fwd(x))
        # each device's extended block keeps its exact body in the
        # leading corner with halo rows appended per dim — these index
        # vectors pick the body rows out of the stacked global array
        dd = [int(mesh.shape[a]) for a in axes]
        locs = [g // n for g, n in zip((8, 6, 4), dd)]
        ids = [np.concatenate([np.arange(d * (lo + w), d * (lo + w) + lo)
                               for d in range(n)])
               for lo, w, n in zip(locs, widths, dd)]
        for wd in ("float32", "bfloat16", "float16", "int8_ef"):
            ref_e = ref_r = None
            for b in BACKENDS:
                plan = HaloPlan.build(
                    HaloSpec(axes, widths, backend=b, dtype="float64",
                             wire_dtype=wd), mesh)
                ext = plan.fwd(x)
                got_e = np.asarray(ext)
                got_r = np.asarray(plan.rev(ext))
                if ref_e is None:
                    ref_e, ref_r = got_e, got_r
                assert np.array_equal(got_e, ref_e), (wd, b, "fwd")
                if b == "fused":
                    # fused rev accumulates return contributions in a
                    # different order than serialized — one-ulp f64
                    # rounding even on DENSE payloads, so the wire path
                    # inherits the same (tight) tolerance
                    assert np.allclose(got_r, ref_r, rtol=0,
                                       atol=1e-12), (wd, b, "rev")
                else:
                    assert np.array_equal(got_r, ref_r), (wd, b, "rev")
            # local body exact: the spliced rows equal the original
            # payload bit-for-bit (only halo rows are wire-gridded)
            assert np.array_equal(ref_e[np.ix_(*ids)], np.asarray(x)), wd
            if wd == "float32":
                # the f32 rev format's halo rows are exactly the
                # f32-rounded dense rows: fwd is pure data movement
                # here (no wrap shift), so cast and exchange commute
                expect = dense.astype(np.float32).astype(np.float64)
                expect[np.ix_(*ids)] = np.asarray(x)
                assert np.array_equal(ref_e, expect), "f32 grid"
        # f32 payloads sit at the floor: forward exchange bitwise dense
        x32 = jnp.asarray(rng.randn(8, 6, 4, 5).astype(np.float32))
        d32 = np.asarray(HaloPlan.build(
            HaloSpec(axes, widths, backend="fused"), mesh).fwd(x32))
        w32 = np.asarray(HaloPlan.build(
            HaloSpec(axes, widths, backend="fused",
                     wire_dtype="bfloat16"), mesh).fwd(x32))
        assert np.array_equal(d32, w32), "f32 fwd must ride dense"
    finally:
        jax.config.update("jax_enable_x64", old_x64)
    print("wire formats: cross-backend bitwise + f32 floor OK")


def main():
    assert len(jax.devices()) >= 8, "need 8 virtual devices"
    mesh = make_mesh((2, 2, 2), ("z", "y", "x"))
    # the paper's single-pulse regime
    check_case(mesh, (1, 2, 1), None, (8, 6, 4, 5))
    # width=2 halos: one pulse vs GROMACS' two-pulse split per dim
    check_case(mesh, (2, 2, 2), None, (8, 6, 4, 5))
    check_case(mesh, (2, 2, 2), (2, 2, 2), (8, 6, 4, 5))
    # mixed pulse counts
    check_case(mesh, (2, 3, 1), (2, 2, 1), (8, 6, 4, 5))
    check_signal_rev_bitwise(mesh)
    check_wire_case(mesh)

    # overlap model sanity on the 8-device plan
    plan = HaloPlan.build(HaloSpec(("z", "y", "x"), (1, 1, 1),
                                   backend="signal"), mesh)
    off = plan.stats((8, 6, 4), pipeline="off")
    db = plan.stats((8, 6, 4), pipeline="double_buffer")
    assert db["exposed_phases_per_step"] < off["exposed_phases_per_step"]
    print("double_buffer exposes", db["exposed_phases_per_step"],
          "phases/step vs", off["exposed_phases_per_step"], "serialized")

    print("check_halo OK")


if __name__ == "__main__":
    main()
