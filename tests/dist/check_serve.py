"""8-virtual-device SimServer checks: rep-sharded rows, quarantine,
device loss.

Three cells:

1. **rep-sharded rows** — an 8-row bucket sharded across a
   ``("rep", z, y, x) = (8, 1, 1, 1)`` mesh (one replica lane per
   device): all 8 mixed-size replicas must be bitwise-identical to solo
   single-device runs.
2. **quarantine** — a poisoned lane (inf velocity) among 7 healthy ones
   on the sharded mesh: the poisoned replica retires FAILED with a typed
   ReplicaFault; a co-resident stays bitwise.
3. **device loss** — serve 2 of 4 blocks on the rep=8 mesh, evacuate,
   rebuild the server on a rep=4 mesh (half the devices "lost"), readmit
   every snapshot, and the stitched trajectories must equal
   uninterrupted solo runs — continuous batching's elastic-shrink path.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python tests/dist/check_serve.py
"""
import numpy as np

import jax

from repro.core.md.engine import MDEngine
from repro.core.md.system import make_grappa_like
from repro.launch.mesh import make_mesh
from repro.serve import BucketLadder, FAILED, PREEMPTED, SimServer

AXES = ("z", "y", "x")
NST = 10
BUCKET = 256
SIZES = (200, 256, 230, 210, 256, 240, 224, 250)


def _sys(n, seed):
    return make_grappa_like(n, seed=seed, nstlist=NST, box_atoms=BUCKET)


def _solo(n, seed, n_steps):
    eng = MDEngine(_sys(n, seed), make_mesh((1, 1, 1), AXES),
                   force_backend="dense", layout_atoms=BUCKET)
    (cf, ci), _, _ = eng.simulate(n_steps)
    return (np.asarray(jax.device_get(cf)), np.asarray(jax.device_get(ci)))


def _server(mesh, rows):
    return SimServer(mesh, BucketLadder(row_buckets=rows,
                                        atom_buckets=(BUCKET,)),
                     block_steps=NST,
                     engine_kwargs={"force_backend": "dense"})


def main():
    assert len(jax.devices()) >= 8, "need 8 virtual devices"
    mesh8 = make_mesh((8, 1, 1, 1), ("rep",) + AXES)

    # --- cell 1: rep-sharded rows, one lane per device -----------------
    srv = _server(mesh8, rows=(8,))
    handles = [srv.submit(_sys(n, seed=i), 20)
               for i, n in enumerate(SIZES)]
    srv.drain()
    for i, (n, h) in enumerate(zip(SIZES, handles)):
        out = h.result()
        cf, ci = _solo(n, i, 20)
        assert np.array_equal(out["cell_f"], cf), f"lane {i} cell_f diff"
        assert np.array_equal(out["cell_i"], ci), f"lane {i} cell_i diff"
    st = srv.stats()
    assert st["compiles"] == 1 and st["replicas_done"] == 8
    print("rep-sharded rows: 8/8 replicas bitwise vs solo "
          f"(1 compile, {st['blocks']} blocks)")

    # --- cell 2: quarantine on the sharded mesh ------------------------
    srv = _server(mesh8, rows=(8,))
    bad_sys = _sys(200, seed=99)
    bad_sys.vel[0] = np.inf
    handles = [srv.submit(_sys(n, seed=i), 20)
               for i, n in enumerate(SIZES[:7])]
    h_bad = srv.submit(bad_sys, 20)
    srv.drain()
    assert h_bad.status == FAILED
    for i, (n, h) in enumerate(zip(SIZES[:7], handles)):
        out = h.result()
        cf, ci = _solo(n, i, 20)
        assert np.array_equal(out["cell_f"], cf), f"co-resident {i} diff"
    print("quarantine: co-residents bitwise around a poisoned lane "
          "(typed ReplicaFault, batch kept serving)")

    # --- cell 3: device loss -> evacuate -> resume on rep=4 ------------
    srv = _server(mesh8, rows=(8,))
    systems = [_sys(n, seed=i) for i, n in enumerate(SIZES)]
    for s in systems:
        srv.submit(s, 40)
    srv.run_cycle()
    srv.run_cycle()                      # 2 of 4 blocks served
    snaps = srv.evacuate()
    assert len(snaps) == 8
    assert all(h.status == PREEMPTED and s["remaining_steps"] == 20
               for h, s in snaps)
    mesh4 = make_mesh((4, 1, 1, 1), ("rep",) + AXES)
    srv2 = _server(mesh4, rows=(8,))     # 8 rows / 4 devices: 2 lanes each
    resumed = [srv2.submit(systems[i], snap["remaining_steps"],
                           state=(snap["cell_f"], snap["cell_i"]))
               for i, (_h, snap) in enumerate(snaps)]
    srv2.drain()
    for i, (n, h) in enumerate(zip(SIZES, resumed)):
        out = h.result()
        cf, ci = _solo(n, i, 40)
        assert np.array_equal(out["cell_f"], cf), f"resumed {i} cell_f diff"
        assert np.array_equal(out["cell_i"], ci), f"resumed {i} cell_i diff"
    print("device-loss: evacuated replicas resumed bitwise on rep=4 "
          "(8 -> 4 devices, 2 lanes/device)")


if __name__ == "__main__":
    main()
