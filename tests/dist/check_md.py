"""8-virtual-device MD check: DD equivalence, migration, step pipeline.

The step-pipeline acceptance bar: on a 2x2x2 DD mesh the pipelined engine
(``backend="signal"``, ``pipeline="double_buffer"`` at any window depth
>= 2, with or without the fused ``overlap_rebin`` DLB program) must
produce trajectories bitwise-identical to the serialized non-pipelined
host-dispatched engine over >= 10 steps, including across a
rebin/migration boundary; and the 8-device run must agree with the
single-device reference physics (DD equivalence, atom conservation).

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python tests/dist/check_md.py
"""
import numpy as np

import jax

from repro.core.halo_plan import HaloSpec
from repro.core.md import MDEngine, make_grappa_like
from repro.launch.mesh import make_mesh

AXES = ("z", "y", "x")


def run(system, mesh, backend, pipeline, n_steps, pulses=None, widths=None,
        force_backend="dense", depth=2, overlap_rebin=False, nstprune=0):
    spec = HaloSpec(axis_names=AXES, widths=widths or (1, 1, 1),
                    backend=backend, pulses=pulses)
    eng = MDEngine(system, mesh, spec, pipeline=pipeline,
                   pipeline_depth=depth, overlap_rebin=overlap_rebin,
                   force_backend=force_backend, nstprune=nstprune)
    (cf, ci), metrics, diags = eng.simulate(n_steps)
    return (np.asarray(jax.device_get(cf)), np.asarray(jax.device_get(ci)),
            {k: np.asarray(v) for k, v in metrics.items()}, diags, eng)


def main():
    assert len(jax.devices()) >= 8, "need 8 virtual devices"
    mesh = make_mesh((2, 2, 2), AXES)
    system = make_grappa_like(900, seed=3)
    n_steps = 24          # nstlist=20 -> crosses one rebin/migration

    cf_ref, ci_ref, m_ref, diags_ref, eng_ref = run(
        system, mesh, "serialized", "off", n_steps)
    for d in diags_ref:
        assert int(np.asarray(d["n_atoms"])) == system.n_atoms
        assert int(np.asarray(d["bin_overflow"])) == 0
    print("serialized/off reference: atoms conserved across",
          len(diags_ref), "rebins")

    # --- pipelined put-with-signal engine: bitwise-identical trajectory ---
    # (window depths 2/3/4 and the fused overlap_rebin DLB program all
    # regroup the same per-step ops; every cell must match bit for bit)
    cases = [("signal", "double_buffer", 2, False),
             ("signal", "off", 2, False),
             ("serialized", "double_buffer", 2, False),
             ("signal", "double_buffer", 3, False),
             ("signal", "double_buffer", 4, True),
             ("serialized", "off", 2, True)]
    for backend, pipeline, depth, ovr in cases:
        cf, ci, m, diags, eng = run(system, mesh, backend, pipeline,
                                    n_steps, depth=depth,
                                    overlap_rebin=ovr)
        tag = f"{backend}/{pipeline}/d{depth}" + ("/ovr" if ovr else "")
        assert np.array_equal(cf, cf_ref), \
            f"{tag} cell_f differs from serialized/off"
        assert np.array_equal(ci, ci_ref), f"{tag} cell_i differs"
        for k in m_ref:
            assert np.array_equal(m[k], m_ref[k]), (tag, k)
        assert len(diags) == len(diags_ref), tag   # same rebin cadence
        for got_d, ref_d in zip(diags, diags_ref):
            for k in ref_d:
                assert np.array_equal(np.asarray(got_d[k]),
                                      np.asarray(ref_d[k])), (tag, k)
        print(f"{tag}: trajectory bitwise identical over {n_steps} steps")

    deep_stats = [MDEngine(system, mesh,
                           HaloSpec(axis_names=AXES, widths=(1, 1, 1),
                                    backend="signal"),
                           pipeline="double_buffer", pipeline_depth=d)
                  .overlap_stats() for d in (2, 3, 4)]
    assert all(ov["overlapped_bytes_per_step"] > 0 for ov in deep_stats)
    exposed = [ov["exposed_phases_per_step"] for ov in deep_stats]
    assert exposed[0] > exposed[1] > exposed[2], exposed
    print("overlap model exposed phases decrease with depth:", exposed)

    # --- energy sanity on the DD run -----------------------------------
    E = m_ref["pe"] + m_ref["ke"]
    assert np.all(np.isfinite(E))
    drift = float((E.max() - E.min()) / system.n_atoms)
    assert drift < 5e-3, drift
    assert np.abs(m_ref["mom"]).max() < 1e-2
    print(f"NVE drift/atom {drift:.2e}, momentum conserved")

    # --- DD equivalence: 8-device vs single-device energies ------------
    mesh1 = make_mesh((1, 1, 1), AXES)
    _, _, m1, _, _ = run(system, mesh1, "serialized", "off", n_steps)
    rel = np.abs(m_ref["pe"] - m1["pe"]) / np.abs(m1["pe"])
    assert rel.max() < 1e-4, rel.max()
    print("DD potential energies match single-device within",
          f"{rel.max():.1e}")

    # --- pruned force backends: tolerance vs the dense trajectory ------
    # (documented guarantee: same per-pair math, different summation
    # order -> NOT bitwise; positions/velocities agree to float32
    # round-off accumulated over 24 steps, energies tighter)
    pos_ref, vel_ref = eng_ref.gather_by_id(
        [cf_ref[..., 0:3], cf_ref[..., 4:7]], ci_ref)
    for fb in ("sparse", "pallas"):
        cf, ci, m, _, eng = run(system, mesh, "serialized", "off", n_steps,
                                force_backend=fb)
        pos, vel = eng.gather_by_id([cf[..., 0:3], cf[..., 4:7]], ci)
        dpos = np.abs(pos - pos_ref).max()
        dvel = np.abs(vel - vel_ref).max()
        assert dpos < 1e-3 and dvel < 1e-2, (fb, dpos, dvel)
        rel_pe = np.abs(m["pe"] - m_ref["pe"]).max() / \
            np.abs(m_ref["pe"]).max()
        assert rel_pe < 1e-5, (fb, rel_pe)
        ratio = eng.pair_stats()["prune_ratio"]
        assert ratio >= 2.0, (fb, ratio)
        assert not eng.pair_stats().get("pallas_fallback"), \
            "pallas backend silently downgraded to the jnp twin"
        print(f"force_backend={fb}: 24-step trajectory within tolerance "
              f"(dpos {dpos:.1e}, dpe {rel_pe:.1e}), "
              f"prune ratio {ratio:.2f}x")

    # --- pruned backend under the step pipeline: schedule threading ----
    # sparse/off, sparse/double_buffer (any depth), and the fused
    # overlap_rebin path must stay bitwise-identical to EACH OTHER (the
    # block-constant schedule rides the StepFns ctx, and the fused
    # rebin+prune program computes the exact host-dispatched schedule),
    # for the static schedule (nstprune=0) AND the rolling dual pair
    # list (nstprune>0: in-block refreshes, host-read overflow scalar)
    for nstprune in (0, 4):
        cf_a, ci_a, m_a, d_a, eng_a = run(system, mesh, "signal", "off",
                                          n_steps, force_backend="sparse",
                                          nstprune=nstprune)
        variants = [("double_buffer", 3, False),
                    ("double_buffer", 2, True), ("off", 2, True)]
        for pipeline, depth, ovr in variants:
            cf_b, ci_b, m_b, d_b, eng_b = run(
                system, mesh, "signal", pipeline, n_steps,
                force_backend="sparse", depth=depth, overlap_rebin=ovr,
                nstprune=nstprune)
            tag = f"sparse/np{nstprune}/{pipeline}/d{depth}" + \
                ("/ovr" if ovr else "")
            assert np.array_equal(cf_a, cf_b) and \
                np.array_equal(ci_a, ci_b), \
                f"{tag} trajectory differs from sparse/off"
            for k in m_a:
                assert np.array_equal(m_a[k], m_b[k]), (tag, k)
            # the fused prune must hand the NEXT block the same exec
            # schedule (prune conservativeness across the block
            # boundary: identical surviving-pair sets, identical
            # bucketed tier ladders)
            sel_a, t_a, ti_a = eng_a._sched_exec
            sel_b, t_b, ti_b = eng_b._sched_exec
            assert (t_a, ti_a) == (t_b, ti_b), tag
            assert np.array_equal(np.asarray(jax.device_get(sel_a)),
                                  np.asarray(jax.device_get(sel_b))), tag
            assert eng_b.pair_stats()["nstprune"] == nstprune
            assert eng_b.pair_stats()["inner_overflow_blocks"] == 0, tag
            print(f"{tag} == sparse/off bitwise, same post-boundary "
                  "schedule")

    print("check_md OK")


if __name__ == "__main__":
    main()
