"""8-virtual-device MD check: DD equivalence, migration, step pipeline.

The step-pipeline acceptance bar: on a 2x2x2 DD mesh the pipelined engine
(``backend="signal"``, ``pipeline="double_buffer"``) must produce
trajectories bitwise-identical to the serialized non-pipelined engine
over >= 10 steps, including across a rebin/migration boundary; and the
8-device run must agree with the single-device reference physics (DD
equivalence, atom conservation).

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python tests/dist/check_md.py
"""
import numpy as np

import jax

from repro.core.halo_plan import HaloSpec
from repro.core.md import MDEngine, make_grappa_like
from repro.launch.mesh import make_mesh

AXES = ("z", "y", "x")


def run(system, mesh, backend, pipeline, n_steps, pulses=None, widths=None,
        force_backend="dense"):
    spec = HaloSpec(axis_names=AXES, widths=widths or (1, 1, 1),
                    backend=backend, pulses=pulses)
    eng = MDEngine(system, mesh, spec, pipeline=pipeline,
                   force_backend=force_backend)
    (cf, ci), metrics, diags = eng.simulate(n_steps)
    return (np.asarray(jax.device_get(cf)), np.asarray(jax.device_get(ci)),
            {k: np.asarray(v) for k, v in metrics.items()}, diags, eng)


def main():
    assert len(jax.devices()) >= 8, "need 8 virtual devices"
    mesh = make_mesh((2, 2, 2), AXES)
    system = make_grappa_like(900, seed=3)
    n_steps = 24          # nstlist=20 -> crosses one rebin/migration

    cf_ref, ci_ref, m_ref, diags_ref, eng_ref = run(
        system, mesh, "serialized", "off", n_steps)
    for d in diags_ref:
        assert int(np.asarray(d["n_atoms"])) == system.n_atoms
        assert int(np.asarray(d["bin_overflow"])) == 0
    print("serialized/off reference: atoms conserved across",
          len(diags_ref), "rebins")

    # --- pipelined put-with-signal engine: bitwise-identical trajectory ---
    cases = [("signal", "double_buffer", None),
             ("signal", "off", None),
             ("serialized", "double_buffer", None)]
    for backend, pipeline, pulses in cases:
        cf, ci, m, _, eng = run(system, mesh, backend, pipeline, n_steps,
                                pulses=pulses)
        assert np.array_equal(cf, cf_ref), \
            f"{backend}/{pipeline} cell_f differs from serialized/off"
        assert np.array_equal(ci, ci_ref), \
            f"{backend}/{pipeline} cell_i differs"
        for k in m_ref:
            assert np.array_equal(m[k], m_ref[k]), \
                (backend, pipeline, k)
        print(f"{backend}/{pipeline}: trajectory bitwise identical over "
              f"{n_steps} steps")

    ov = eng.overlap_stats()
    assert ov["overlapped_bytes_per_step"] > 0

    # --- energy sanity on the DD run -----------------------------------
    E = m_ref["pe"] + m_ref["ke"]
    assert np.all(np.isfinite(E))
    drift = float((E.max() - E.min()) / system.n_atoms)
    assert drift < 5e-3, drift
    assert np.abs(m_ref["mom"]).max() < 1e-2
    print(f"NVE drift/atom {drift:.2e}, momentum conserved")

    # --- DD equivalence: 8-device vs single-device energies ------------
    mesh1 = make_mesh((1, 1, 1), AXES)
    _, _, m1, _, _ = run(system, mesh1, "serialized", "off", n_steps)
    rel = np.abs(m_ref["pe"] - m1["pe"]) / np.abs(m1["pe"])
    assert rel.max() < 1e-4, rel.max()
    print("DD potential energies match single-device within",
          f"{rel.max():.1e}")

    # --- pruned force backends: tolerance vs the dense trajectory ------
    # (documented guarantee: same per-pair math, different summation
    # order -> NOT bitwise; positions/velocities agree to float32
    # round-off accumulated over 24 steps, energies tighter)
    pos_ref, vel_ref = eng_ref.gather_by_id(
        [cf_ref[..., 0:3], cf_ref[..., 4:7]], ci_ref)
    for fb in ("sparse", "pallas"):
        cf, ci, m, _, eng = run(system, mesh, "serialized", "off", n_steps,
                                force_backend=fb)
        pos, vel = eng.gather_by_id([cf[..., 0:3], cf[..., 4:7]], ci)
        dpos = np.abs(pos - pos_ref).max()
        dvel = np.abs(vel - vel_ref).max()
        assert dpos < 1e-3 and dvel < 1e-2, (fb, dpos, dvel)
        rel_pe = np.abs(m["pe"] - m_ref["pe"]).max() / \
            np.abs(m_ref["pe"]).max()
        assert rel_pe < 1e-5, (fb, rel_pe)
        ratio = eng.pair_stats()["prune_ratio"]
        assert ratio >= 2.0, (fb, ratio)
        assert not eng.pair_stats().get("pallas_fallback"), \
            "pallas backend silently downgraded to the jnp twin"
        print(f"force_backend={fb}: 24-step trajectory within tolerance "
              f"(dpos {dpos:.1e}, dpe {rel_pe:.1e}), "
              f"prune ratio {ratio:.2f}x")

    # --- pruned backend under the step pipeline: schedule threading ----
    # sparse/off and sparse/double_buffer must stay bitwise-identical to
    # EACH OTHER (the block-constant schedule rides the StepFns ctx, so
    # the pipeline invariant holds per force backend)
    cf_a, ci_a, m_a, _, _ = run(system, mesh, "signal", "off", n_steps,
                                force_backend="sparse")
    cf_b, ci_b, m_b, _, _ = run(system, mesh, "signal", "double_buffer",
                                n_steps, force_backend="sparse")
    assert np.array_equal(cf_a, cf_b) and np.array_equal(ci_a, ci_b), \
        "sparse off vs double_buffer trajectories differ"
    for k in m_a:
        assert np.array_equal(m_a[k], m_b[k]), k
    print("sparse/off == sparse/double_buffer bitwise (signal backend)")

    print("check_md OK")


if __name__ == "__main__":
    main()
