"""8-virtual-device check: HaloPlan backends agree bitwise; VJP is adjoint.

Launched by tests/test_halo_plan.py (and usable standalone):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python tests/dist/check_halo_plan.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core.halo_plan import HaloPlan, HaloSpec
from repro.launch.mesh import make_mesh

BACKENDS = ("serialized", "fused", "pallas")


def main():
    assert len(jax.devices()) >= 8, "need 8 virtual devices"
    mesh = make_mesh((2, 2, 2), ("z", "y", "x"))
    axes = ("z", "y", "x")
    widths = (1, 2, 1)
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(8, 6, 4, 5).astype(np.float32))
    shift = np.zeros((3, 5))
    shift[0, 0], shift[1, 1], shift[2, 2] = 10.0, 20.0, 30.0

    # ---- forward: all backends bitwise identical -------------------------
    exts = {}
    for b in BACKENDS:
        plan = HaloPlan.build(
            HaloSpec(axis_names=axes, widths=widths, backend=b,
                     wrap_shift=shift), mesh)
        exts[b] = np.asarray(plan.fwd(x))
        assert exts[b].shape == (10, 10, 6, 5), exts[b].shape
    for b in BACKENDS[1:]:
        assert np.array_equal(exts[b], exts["serialized"]), \
            f"{b} fwd differs from serialized"
    print("fwd bitwise identical across", BACKENDS)

    # ---- adjoint: <fwd(x), y> == <x, rev(y)> per backend -----------------
    y = jnp.asarray(rng.randn(10, 10, 6, 5).astype(np.float32))
    for b in BACKENDS:
        plan = HaloPlan.build(
            HaloSpec(axis_names=axes, widths=widths, backend=b), mesh)
        lhs = float(jnp.vdot(plan.fwd(x), y))
        rhs = float(jnp.vdot(x, plan.rev(y)))
        rel = abs(lhs - rhs) / max(abs(lhs), 1.0)
        assert rel < 1e-5, (b, lhs, rhs)
        print(f"{b}: adjoint rel err {rel:.2e}")

    # ---- custom VJP: fused reverse path == serialized autodiff -----------
    ser = HaloPlan.build(
        HaloSpec(axis_names=axes, widths=widths, backend="serialized"),
        mesh)
    g_ref = jax.grad(lambda a: jnp.sum(ser.fwd(a) * y))(x)
    for b in BACKENDS:
        plan = HaloPlan.build(
            HaloSpec(axis_names=axes, widths=widths, backend=b), mesh)
        g = jax.grad(lambda a: jnp.sum(plan.exchange(a) * y))(x)
        err = float(jnp.abs(g - g_ref).max())
        assert err < 1e-6, (b, err)
        print(f"{b}: grad-vs-serialized-autodiff max err {err:.2e}")

    print("check_halo_plan OK")


if __name__ == "__main__":
    main()
