"""8-virtual-device fault matrix: every site x {recover, degrade}.

The multi-device acceptance drill for the self-healing MD runtime: on a
2x2x2 DD mesh, every :data:`~repro.resilience.faults.ALL_FAULT_SITES`
entry is provoked and recovered —

* one-shot scan faults (NaN'd halo payload, NaN'd force kernel, dropped
  put-with-signal release) roll back and finish **bitwise** equal to the
  fault-free reference;
* sticky scan faults exhaust retries and walk the degrade ladder
  (signal -> serialized halo is bitwise per the PR2 conformance bar;
  sparse -> dense forces is drift-bound);
* a forced inner-ladder overflow takes the engine's own outer-ladder
  fallback (no rewind);
* a process kill resumes bitwise from the checkpoint chain;
* a device loss reshards 2x2x2 -> 1x2x2 (the shrink path) and finishes
  within the NVE drift bound.

Each scenario appends one JSON line to ``--out`` (default
``results/obs/fault_matrix.jsonl``) — the recovery report artifact the
CI ``fault-matrix`` job uploads.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python tests/dist/check_faults.py
"""
import argparse
import json
import tempfile
from pathlib import Path

import numpy as np

import jax

from repro.core.halo_plan import HaloSpec
from repro.core.md import MDEngine, make_grappa_like
from repro.launch.mesh import make_mesh
from repro.resilience import (
    FaultPlan,
    FaultSpec,
    ProcessKilled,
    RecoveryPolicy,
    ResilientMDRunner,
)

AXES = ("z", "y", "x")
N_STEPS = 18
NSTLIST = 6


def build_engine(system, mesh, **kw):
    spec = HaloSpec(axis_names=AXES, widths=(1, 1, 1), backend="signal")
    return MDEngine(system, mesh, spec, pipeline="double_buffer",
                    inject=True, health=True, **kw)


def max_err(atoms, ref):
    scale = max(np.abs(ref["vel"]).max(), 1e-9)
    return float(max(np.abs(atoms["pos"] - ref["pos"]).max(),
                     np.abs(atoms["vel"] - ref["vel"]).max() / scale))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/obs/fault_matrix.jsonl")
    args = ap.parse_args()
    assert len(jax.devices()) >= 8, "need 8 virtual devices"

    tmp = Path(tempfile.mkdtemp(prefix="ck_faults_"))
    mesh = make_mesh((2, 2, 2), AXES)
    system = make_grappa_like(900, seed=3, nstlist=NSTLIST)

    # fault-free reference: same signal/double_buffer config, no inject
    spec = HaloSpec(axis_names=AXES, widths=(1, 1, 1), backend="signal")
    ref_eng = MDEngine(system, mesh, spec, pipeline="double_buffer")
    (cf_r, ci_r), _, _ = ref_eng.simulate(N_STEPS)
    ref_cf, ref_ci = np.asarray(cf_r), np.asarray(ci_r)
    ref_atoms = ref_eng.export_atoms((cf_r, ci_r))

    eng = build_engine(system, mesh)
    rows = []

    def record(site, mode, report, **extra):
        row = {"site": site, "mode": mode,
               "recoveries": report["recoveries"],
               "wasted_steps": report["wasted_steps"],
               "resharded": report["resharded"], **extra}
        rows.append(row)
        print(f"{site}/{mode}: "
              + ", ".join(f"{k}={v}" for k, v in extra.items()))

    # --- scan sites, one-shot -> rollback, bitwise ------------------------
    for site, step in (("halo_corrupt", 8), ("force_nan", 13),
                       ("signal_drop", 2)):
        plan = FaultPlan([FaultSpec(site, step)])
        r = ResilientMDRunner(eng, tmp / f"ck_{site}", plan=plan)
        (cf, ci), _, report = r.run(N_STEPS, resume=False)
        assert [x["action"] for x in report["recoveries"]] == ["rollback"]
        assert report["recoveries"][0]["detection_latency_steps"] <= NSTLIST
        np.testing.assert_array_equal(np.asarray(cf), ref_cf)
        np.testing.assert_array_equal(np.asarray(ci), ref_ci)
        record(site, "recover", report, bitwise=True,
               latency=report["recoveries"][0]["detection_latency_steps"])

    # --- sticky signal_drop -> degrade: serialized halo is bitwise --------
    # (the PR2/check_md bar: signal and serialized trajectories match bit
    # for bit, so removing the put-with-signal seam costs nothing here)
    plan = FaultPlan([FaultSpec("signal_drop", 2, sticky=True)])
    r = ResilientMDRunner(eng, tmp / "ck_drop_sticky", plan=plan,
                          policy=RecoveryPolicy(max_retries=1,
                                                backoff_base_s=0.0))
    (cf, ci), _, report = r.run(N_STEPS, resume=False)
    acts = [x["action"] for x in report["recoveries"]]
    assert acts == ["rollback", "degrade"], acts
    assert r.engine.spec.backend == "serialized"
    np.testing.assert_array_equal(np.asarray(cf), ref_cf)
    np.testing.assert_array_equal(np.asarray(ci), ref_ci)
    record("signal_drop", "degrade", report, bitwise=True,
           rung="serialized_halo")

    # --- sticky force_nan -> degrade: dense forces, drift-bound ----------
    plan = FaultPlan([FaultSpec("force_nan", 2, sticky=True)])
    r = ResilientMDRunner(eng, tmp / "ck_nan_sticky", plan=plan,
                          policy=RecoveryPolicy(max_retries=1,
                                                backoff_base_s=0.0))
    (cf, ci), _, report = r.run(N_STEPS, resume=False)
    assert report["recoveries"][-1]["action"] == "degrade"
    assert report["recoveries"][-1]["detail"] == "dense_forces"
    err = max_err(r.engine.export_atoms((cf, ci)), ref_atoms)
    assert err < 1e-4, err
    record("force_nan", "degrade", report, rung="dense_forces",
           max_err=err)

    # --- forced inner-ladder overflow: the engine's own fallback ----------
    eng_prune = build_engine(system, mesh, force_backend="sparse",
                             nstprune=3)
    plan = FaultPlan([FaultSpec("inner_overflow", 6)])
    r = ResilientMDRunner(eng_prune, tmp / "ck_ovf", plan=plan)
    (cf, ci), _, report = r.run(N_STEPS, resume=False)
    falls = [x for x in report["recoveries"]
             if x["action"] == "engine_fallback"]
    assert len(falls) == 1 and falls[0]["detail"] == "outer_ladder"
    assert report["wasted_steps"] == 0
    assert np.isfinite(np.asarray(cf)).all()
    record("inner_overflow", "recover", report, fallback="outer_ladder")

    # --- process kill -> checkpoint auto-resume, bitwise ------------------
    plan = FaultPlan([FaultSpec("proc_kill", 12)])
    r = ResilientMDRunner(eng, tmp / "ck_kill", plan=plan)
    try:
        r.run(N_STEPS, resume=False)
        raise AssertionError("proc_kill did not fire")
    except ProcessKilled:
        pass
    r2 = ResilientMDRunner(eng, tmp / "ck_kill")
    (cf, ci), _, report = r2.run(N_STEPS)
    assert report["resumed_from"] == 12
    np.testing.assert_array_equal(np.asarray(cf), ref_cf)
    np.testing.assert_array_equal(np.asarray(ci), ref_ci)
    record("proc_kill", "recover", report, bitwise=True, resumed_from=12)

    # --- device loss -> reshard 2x2x2 -> 1x2x2 (shrink), drift-bound ------
    spare = make_mesh((1, 2, 2), AXES)
    plan = FaultPlan([FaultSpec("device_loss", 12)])
    r = ResilientMDRunner(eng, tmp / "ck_loss", plan=plan,
                          spare_mesh=spare)
    (cf, ci), _, report = r.run(N_STEPS, resume=False)
    assert report["resharded"] is True
    assert tuple(r.engine.mesh.shape[a] for a in AXES) == (1, 2, 2)
    err = max_err(r.engine.export_atoms((cf, ci)), ref_atoms)
    assert err < 1e-4, err
    record("device_loss", "recover", report, mesh_shape=[1, 2, 2],
           max_err=err)

    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    with open(out, "w") as fh:
        for row in rows:
            fh.write(json.dumps(row) + "\n")
    print(f"wrote {out}: {len(rows)} scenarios")
    print("check_faults OK")


if __name__ == "__main__":
    main()
