"""MD engine: single-domain oracle checks in-process; DD checks in subprocess."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # property tests skip; hypothesis is a dev extra
    from _hypothesis_stub import given, settings, st

from repro.core.halo_plan import HaloSpec
from repro.core.md import (
    MDEngine,
    direct_forces_reference,
    make_grappa_like,
)
from repro.core.md.forces import stencil_pairs
from repro.launch.mesh import make_mesh


def test_stencil_is_exact_half_shell():
    """14 zone pairs; offsets disjoint; every {-1,0,1}^3 displacement covered
    exactly once (the eighth-shell uniqueness argument)."""
    pairs = stencil_pairs()
    assert len(pairs) == 14
    seen = set()
    for a, b in pairs:
        assert all(x * y == 0 for x, y in zip(a, b))
        d = tuple(bi - ai for ai, bi in zip(a, b))
        assert d not in seen and tuple(-x for x in d) not in seen
        seen.add(d)
    # 13 distinct non-zero displacements + the self pair
    assert len(seen) == 14 and (0, 0, 0) in seen


@pytest.fixture(scope="module")
def small_system():
    return make_grappa_like(300, seed=11)


@pytest.fixture(scope="module")
def single_engine(small_system):
    mesh = make_mesh((1, 1, 1), ("z", "y", "x"))
    spec = HaloSpec(axis_names=("z", "y", "x"), widths=(1, 1, 1),
                    backend="fused")
    return MDEngine(small_system, mesh, spec)


def test_forces_match_direct_oracle(small_system, single_engine):
    eng = single_engine
    cf, ci = eng.init_state()
    cf, ci, force, diag = eng.rebin_fn(cf, ci)
    assert int(np.asarray(diag["bin_overflow"])) == 0
    f_eng, = eng.gather_by_id([force], ci)
    f_ref, _ = direct_forces_reference(
        small_system.pos, small_system.charge, small_system.typ,
        small_system.box, small_system.params.ff)
    scale = np.abs(f_ref).max()
    assert np.abs(f_eng - f_ref).max() / scale < 5e-5


def test_newtons_third_law(small_system, single_engine):
    eng = single_engine
    cf, ci = eng.init_state()
    cf, ci, force, _ = eng.rebin_fn(cf, ci)
    f_eng, = eng.gather_by_id([force], ci)
    assert np.abs(f_eng.sum(axis=0)).max() < 1e-3


def test_short_nve_run_is_stable(small_system, single_engine):
    _, metrics, diags = single_engine.simulate(40)
    E = metrics["pe"] + metrics["ke"]
    assert np.all(np.isfinite(E))
    assert (E.max() - E.min()) / small_system.n_atoms < 5e-3
    assert np.abs(metrics["mom"]).max() < 1e-3
    for d in diags:
        assert int(np.asarray(d["n_atoms"])) == small_system.n_atoms


@given(n=st.integers(120, 300), seed=st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_system_builder_properties(n, seed):
    sys_ = make_grappa_like(n, seed=seed)
    assert sys_.n_atoms == n
    assert abs(sys_.charge.sum()) < 1e-5          # neutral
    assert np.abs(sys_.vel.mean(axis=0)).max() < 1e-6   # no COM drift
    assert np.all((sys_.pos >= 0) & (sys_.pos < sys_.box))
    assert sys_.params.ff.r_cut < sys_.box.min() / 2


@pytest.mark.dist
def test_dd_equivalence_and_migration(dist):
    out = dist("check_md.py")
    assert "check_md OK" in out


@pytest.mark.dist
def test_nve_float64(dist):
    out = dist("check_md_nve.py")
    assert "check_md_nve OK" in out
