"""HLO cost parser: validated against XLA cost_analysis and analytics."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax import lax

from repro.compat import cost_analysis
from repro.launch import hlo_analysis as H


def test_flops_match_cost_analysis_loop_free():
    """On a loop-free program the parser's dot FLOPs == XLA's count."""
    def f(x, w1, w2):
        return jnp.tanh(x @ w1) @ w2

    args = [jax.ShapeDtypeStruct(s, jnp.float32)
            for s in [(64, 128), (128, 256), (256, 32)]]
    c = jax.jit(f).lower(*args).compile()
    want = cost_analysis(c)["flops"]
    got = H.analyze(c.as_text())["flops"]
    # the parser counts dots only; elementwise tanh adds a small delta
    assert abs(got - want) / want < 0.01, (got, want)


def test_scan_flops_multiply_by_trip_count():
    def body(c, _):
        return jnp.tanh(c @ c.T @ c), ()

    def f(x):
        out, _ = lax.scan(body, x, None, length=7)
        return out

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = jax.jit(f).lower(x).compile()
    got = H.analyze(c.as_text())["flops"]
    per_iter = 2 * 2 * 64 ** 3
    assert abs(got - 7 * per_iter) / (7 * per_iter) < 0.01

    def g(x):
        for _ in range(7):
            x, _ = body(x, None)
        return x

    c2 = jax.jit(g).lower(x).compile()
    got2 = H.analyze(c2.as_text())["flops"]
    assert abs(got - got2) / got2 < 0.01


def test_nested_scan_multipliers_compose():
    def inner(c, _):
        return c @ c, ()

    def outer(c, _):
        c, _ = lax.scan(inner, c, None, length=3)
        return c, ()

    def f(x):
        out, _ = lax.scan(outer, x, None, length=5)
        return out

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    c = jax.jit(f).lower(x).compile()
    got = H.analyze(c.as_text())["flops"]
    want = 15 * 2 * 32 ** 3
    assert abs(got - want) / want < 0.01


def test_roofline_terms_dominance():
    parsed = {"flops": 197e12, "bytes": 819e9 * 2, "collective_bytes": 0.0}
    t = H.roofline_terms(parsed, model_flops_per_device=197e12 * 0.5)
    assert t["dominant"] == "memory"
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(2.0)
    assert t["roofline_fraction"] == pytest.approx(0.25)


def test_shape_bytes_parses_tuples_and_comments():
    b, e = H._shape_bytes_elems("(f32[2,3]{1,0}, bf16[4], pred[8])")
    assert b == 24 + 8 + 8 and e == 6 + 4 + 8
