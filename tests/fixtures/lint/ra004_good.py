"""RA004 fixture (clean): every kernel constructor pins its dtype."""
import jax.numpy as jnp


def scale_kernel(x_ref, o_ref):
    acc = jnp.zeros((8, 128), jnp.float32)
    ramp = jnp.arange(0.0, 8.0, dtype=jnp.float32)
    fill = jnp.full((8,), 0.5, jnp.float32)
    o_ref[...] = x_ref[...] + acc + ramp[:, None] + fill[:, None]
