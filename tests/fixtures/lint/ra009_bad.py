"""RA009 fixture: broad excepts that silently eat the error."""


def load_checkpoint(path):
    try:
        return open(path, "rb").read()
    except Exception:                     # RA009: swallowed, no record
        return None


def step_with_retry(fn, x):
    try:
        return fn(x)
    except:                               # RA009: bare except, silent
        x = None
    return x


def probe_backend(kernel, arg):
    try:
        return kernel(arg)
    except (ValueError, BaseException):   # RA009: tuple hides a broad catch
        return None
