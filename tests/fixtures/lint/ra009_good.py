"""RA009 fixture (clean): typed catches, loud swallows, re-raises."""
import warnings


def load_checkpoint_typed(path):
    try:
        return open(path, "rb").read()
    except (OSError, ValueError):         # concrete types: fine
        return None


def probe_backend_loud(kernel, arg):
    try:
        return kernel(arg)
    except Exception as e:                # broad, but warns: fine
        warnings.warn(f"kernel probe failed: {e}", RuntimeWarning)
        return None


def run_block_reraise(fn, x):
    try:
        return fn(x)
    except Exception as e:                # broad, but re-raises typed: fine
        raise RuntimeError("block failed") from e


def eval_with_latch(kernel, arg, latch):
    try:
        return kernel(arg)
    except Exception as e:                # warn-once fallback latch: fine
        _latch_kernel_fallback(latch, e)
        return None


def _latch_kernel_fallback(latch, e):
    if not latch["broken"]:
        print(f"kernel fallback latched: {e}")
    latch["broken"] = True
