"""RA008 fixture (clean): synced spans and host-only timing."""
import time
from time import perf_counter

import jax


def time_simulate_synced(eng, steps):
    t0 = perf_counter()
    state, metrics, diags = eng.simulate(steps)
    jax.block_until_ready(state)            # the clock covers the work
    return state, perf_counter() - t0

def time_jitted_synced(fn, x):
    step = jax.jit(fn)
    t0 = time.time()
    y = step(x).block_until_ready()
    return y, time.time() - t0

def time_host_only(rows):
    t0 = time.time()
    total = sum(len(r) for r in rows)       # pure host work: no sync needed
    return total, time.time() - t0
