"""RA005 fixture: unpinned axis-reduction downstream of pair_terms."""
import jax.numpy as jnp


def pair_terms(d2, slot_a, slot_b):
    return jnp.exp(-d2), d2, -d2


def tile_energy(R, pairs):
    d2 = jnp.sum(R * R, axis=-1)       # upstream of pair_terms: fine
    e, fa, fb = pair_terms(d2, pairs, pairs)
    pe = jnp.sum(e, axis=(1, 2))       # RA005: fusion-order dependent
    return pe, fa, fb
