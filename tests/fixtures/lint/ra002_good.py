"""RA002 fixture (clean): traced branches via lax.cond / jnp.where."""
import jax.numpy as jnp
from jax import lax


def body(carry, x):
    carry = lax.cond(jnp.any(x > 0), lambda c: c + 1.0,
                     lambda c: c, carry)
    carry = jnp.where(carry < 0.0, 0.0, carry)
    return carry, carry


def run(xs, n_steps):
    # Python control flow on *static* values is fine
    if n_steps < 1:
        raise ValueError("n_steps must be >= 1")
    return lax.scan(body, jnp.float32(0.0), xs)
