"""RA001 fixture (clean): everything stays on device in the scan body."""
import jax.numpy as jnp
from jax import lax


def body(carry, x):
    total = jnp.sum(x)
    return carry + total, total


def run(xs):
    state, totals = lax.scan(body, jnp.float32(0.0), xs)
    return float(state)                # host read outside the traced body
