"""RA006 fixture (clean): every collective axis is declared."""
import jax.numpy as jnp
from jax import lax

AXES = ("rows", "cols")


def reduce_tile(x, axis_name):
    a = lax.psum(x, "rows")
    b = lax.pmean(x, AXES)
    c = lax.psum(x, axis_name)         # runtime-parameterized: skipped
    return a + b + c
