"""RA007 fixture: dynamic scatter-accumulate without explicit mode."""
import jax.numpy as jnp


def bin_forces(F, cell_idx, fa):
    F = F.at[cell_idx].add(fa)         # RA007: implicit OOB semantics
    F = F.at[cell_idx].max(fa)         # RA007: ditto
    return F
