"""RA001 fixture: host syncs inside a traced scan body."""
import numpy as np
import jax.numpy as jnp
from jax import lax


def body(carry, x):
    total = float(jnp.sum(x))          # RA001: concretizes a tracer
    host = np.asarray(carry)           # RA001: pulls the carry to host
    peek = carry.item()                # RA001: device->host round-trip
    flat = x.tolist()                  # RA001: ditto
    return carry + total + host, (peek, flat)


def run(xs):
    return lax.scan(body, jnp.float32(0.0), xs)
