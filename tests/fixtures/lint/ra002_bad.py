"""RA002 fixture: Python control flow branching on traced values."""
import jax.numpy as jnp
from jax import lax


def body(carry, x):
    if jnp.any(x > 0):                 # RA002: trace-time branch
        carry = carry + 1.0
    while jnp.sum(carry) < 10.0:       # RA002: trace-time loop
        carry = carry * 2.0
    assert jnp.all(carry >= 0.0)       # RA002: trace-time assert
    return carry, carry


def run(xs):
    return lax.scan(body, jnp.float32(0.0), xs)
