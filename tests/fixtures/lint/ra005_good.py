"""RA005 fixture (clean): the pair reduction is barrier-pinned."""
import jax.numpy as jnp
from jax import lax


def pair_terms(d2, slot_a, slot_b):
    return jnp.exp(-d2), d2, -d2


def tile_energy(R, pairs):
    d2 = jnp.sum(R * R, axis=-1)
    e, fa, fb = pair_terms(d2, pairs, pairs)
    pe = lax.optimization_barrier(jnp.sum(e, axis=(1, 2)))
    return pe, fa, fb
