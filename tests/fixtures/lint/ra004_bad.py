"""RA004 fixture: dtype-less constructors inside a pallas kernel."""
import jax.numpy as jnp


def scale_kernel(x_ref, o_ref):
    acc = jnp.zeros((8, 128))              # RA004: weak-typed accumulator
    ramp = jnp.arange(0.0, 8.0)            # RA004: float bounds, no dtype
    fill = jnp.full((8,), 0.5)             # RA004: weak-typed fill
    o_ref[...] = x_ref[...] + acc + ramp[:, None] + fill[:, None]
