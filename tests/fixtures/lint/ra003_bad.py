"""RA003 fixture: host side effects inside a traced cond branch."""
import warnings

import jax.numpy as jnp
from jax import lax


def on_true(c):
    print("took the true branch", c)               # RA003: trace-time only
    warnings.warn("this fires once at trace time")  # RA003
    return c + 1.0


def run(flag, c):
    return lax.cond(flag, on_true, lambda c: c, c)
