"""RA006 fixture: collective over an axis name nothing declares."""
import jax.numpy as jnp
from jax import lax

AXES = ("rows", "cols")


def reduce_tile(x):
    good = lax.psum(x, "rows")
    bad = lax.pmean(x, "ghost")        # RA006: no mesh declares "ghost"
    idx = lax.axis_index("phantom")    # RA006: ditto
    return good + bad + idx
