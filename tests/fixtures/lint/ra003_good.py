"""RA003 fixture (clean): jax.debug.print runs per execution, not trace."""
import jax
import jax.numpy as jnp
from jax import lax


def on_true(c):
    jax.debug.print("took the true branch {c}", c=c)
    return c + 1.0


def run(flag, c):
    out = lax.cond(flag, on_true, lambda c: c, c)
    print("host-side summary:", out)   # outside the traced function
    return out
