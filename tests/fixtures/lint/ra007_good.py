"""RA007 fixture (clean): explicit mode= / static indices."""
import jax.numpy as jnp


def bin_forces(F, cell_idx, fa):
    F = F.at[cell_idx].add(fa, mode="drop")   # sentinel rows drop
    F = F.at[0].add(fa[0])                    # static index: fine
    F = F.at[:, 1].add(fa)                    # slice index: fine
    return F
