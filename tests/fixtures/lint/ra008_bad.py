"""RA008 fixture: timing spans that stop the clock on async dispatch."""
import time
from time import perf_counter

import jax


def time_simulate(eng, steps):
    t0 = perf_counter()
    state, metrics, diags = eng.simulate(steps)
    return state, perf_counter() - t0       # RA008: clocks the launch

def time_jitted(fn, x):
    step = jax.jit(fn)
    t0 = time.time()
    y = step(x)
    dt = time.time() - t0                   # RA008: same hazard
    return y, dt
