"""Golden-file tests for the JAX/Pallas hazard linter (RA001..RA009).

Each rule is proven by a failing ``tests/fixtures/lint/raXXX_bad.py``
fixture and a clean ``raXXX_good.py`` counterpart; the repo's own
``src/repro`` tree must lint clean (the baseline the CI
``static-analysis`` job enforces), and ``# noqa`` suppression must work
both bare and code-scoped.
"""
from pathlib import Path

import pytest

from repro.analysis import RULES, lint_file, lint_paths
from repro.analysis.__main__ import main as analysis_main

FIXTURES = Path(__file__).parent / "fixtures" / "lint"
REPO_SRC = Path(__file__).parents[1] / "src" / "repro"

# every rule and the finding count its bad fixture must produce
EXPECTED_BAD = {
    "RA001": 4,    # float(jnp...), np.asarray, .item(), .tolist()
    "RA002": 3,    # if / while / assert on traced values
    "RA003": 2,    # print, warnings.warn in a traced branch
    "RA004": 3,    # zeros / arange / full without dtype in a kernel
    "RA005": 1,    # unpinned pair reduction
    "RA006": 2,    # pmean over "ghost", axis_index over "phantom"
    "RA007": 2,    # .at[idx].add / .at[idx].max without mode=
    "RA008": 2,    # eng.simulate span / jit-bound call span, no sync
    "RA009": 3,    # silent broad excepts: Exception / bare / tuple-hidden
}


def test_rule_table_is_complete():
    assert set(RULES) == set(EXPECTED_BAD)
    assert len(RULES) >= 6                 # the acceptance floor
    for code, rule in RULES.items():
        assert rule.code == code and rule.name and rule.summary


@pytest.mark.parametrize("code", sorted(EXPECTED_BAD))
def test_bad_fixture_fails_its_rule(code):
    path = FIXTURES / f"{code.lower()}_bad.py"
    diags = lint_file(str(path))
    hits = [d for d in diags if d.code == code]
    assert len(hits) == EXPECTED_BAD[code], [d.format() for d in diags]
    # no cross-contamination: a fixture only trips its own rule
    assert {d.code for d in diags} == {code}
    for d in hits:
        assert d.path.endswith(f"{code.lower()}_bad.py")
        assert d.line > 0 and d.col >= 0
        assert f"{d.path}:{d.line}:{d.col}: {code}" in d.format()


@pytest.mark.parametrize("code", sorted(EXPECTED_BAD))
def test_good_fixture_is_clean(code):
    path = FIXTURES / f"{code.lower()}_good.py"
    assert lint_file(str(path)) == []


def test_noqa_suppression(tmp_path):
    bad = (FIXTURES / "ra007_bad.py").read_text().splitlines()
    # scope one line to its code, blanket-suppress the other
    patched = []
    for ln in bad:
        if ".add(fa)" in ln:
            ln = ln.split("#")[0].rstrip() + "  # noqa: RA007"
        elif ".max(fa)" in ln:
            ln = ln.split("#")[0].rstrip() + "  # noqa"
        patched.append(ln)
    p = tmp_path / "suppressed.py"
    p.write_text("\n".join(patched) + "\n")
    assert lint_file(str(p)) == []
    # a noqa for a different code does NOT suppress
    p2 = tmp_path / "wrong_code.py"
    p2.write_text("\n".join(
        ln.replace("# noqa: RA007", "# noqa: RA001") for ln in patched
    ) + "\n")
    assert [d.code for d in lint_file(str(p2))] == ["RA007"]


def test_repo_tree_lints_clean():
    """Satellite 1's contract: the shipped src/repro is a clean baseline."""
    diags, n_files = lint_paths([str(REPO_SRC)])
    assert n_files > 50
    assert diags == [], "\n".join(d.format() for d in diags)


def test_lint_paths_aggregates_project_constants():
    """RA006 resolution is project-wide: an axis constant declared in one
    module legitimizes collectives in another."""
    diags, _ = lint_paths([str(FIXTURES / "ra006_bad.py"),
                           str(FIXTURES / "ra006_good.py")])
    assert [d.code for d in diags] == ["RA006", "RA006"]


# --------------------------------------------------------------------------
# the CLI entry point (what CI runs)
# --------------------------------------------------------------------------

def test_cli_exits_nonzero_on_findings(capsys):
    rc = analysis_main([str(FIXTURES / "ra001_bad.py"), "--no-verify"])
    out = capsys.readouterr().out
    assert rc == 1
    assert "RA001" in out and "[host-sync-in-traced]" in out


def test_cli_clean_run_writes_report(tmp_path, capsys):
    import json

    report = tmp_path / "out" / "analysis_report.json"
    rc = analysis_main([str(FIXTURES / "ra001_good.py"),
                        "--report", str(report)])
    out = capsys.readouterr().out
    assert rc == 0
    assert "0 finding(s)" in out
    assert "0 unsafe, 0 rejected" in out      # the PR4+PR5 grids
    payload = json.loads(report.read_text())
    assert payload["lint"]["n_findings"] == 0
    assert payload["verifier"]["all_safe"] is True
    assert payload["verifier"]["n_configs"] == 58
    assert set(payload["lint"]["rules"]) == set(RULES)


def test_cli_rules_table(capsys):
    assert analysis_main(["--rules"]) == 0
    out = capsys.readouterr().out
    for code in RULES:
        assert code in out
