"""Optimizer + gradient compression invariants."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.optim import adamw
from repro.optim.compression import _int8_reduce, _topk_reduce, ef_init


def quad_loss(params):
    return sum(jnp.sum((p - 1.5) ** 2) for p in jax.tree.leaves(params))


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.zeros((8, 8)), "b": jnp.zeros((8,))}
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=5,
                            total_steps=300)
    state = adamw.init_state(params)
    g = jax.jit(jax.grad(quad_loss))
    step = jax.jit(lambda p, s: adamw.update(cfg, p, g(p), s))
    for _ in range(300):
        params, state, m = step(params, state)
    assert float(quad_loss(params)) < 1e-3
    assert int(state["step"]) == 300


def test_clipping_bounds_update():
    params = {"w": jnp.zeros((4,))}
    cfg = adamw.AdamWConfig(lr=1.0, clip_norm=1.0, warmup_steps=0,
                            weight_decay=0.0)
    state = adamw.init_state(params)
    grads = {"w": jnp.full((4,), 1e6)}
    new_p, state, m = adamw.update(cfg, params, grads, state)
    assert float(m["grad_norm"]) > 1e5
    assert np.all(np.isfinite(np.asarray(new_p["w"])))


def test_schedule_shape():
    cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                            min_lr_frac=0.1)
    lrs = [float(adamw.schedule(cfg, jnp.int32(s))) for s in range(101)]
    assert lrs[0] < lrs[9] <= lrs[10]
    assert abs(lrs[10] - 1e-3) < 1e-9
    assert lrs[100] == pytest.approx(1e-4, rel=1e-3)
    assert all(b <= a + 1e-12 for a, b in zip(lrs[10:], lrs[11:]))


def test_zero1_specs_add_data_axis():
    from repro.launch.mesh import make_mesh
    from repro.parallel.sharding import ShardingCtx
    from jax.sharding import PartitionSpec as P
    mesh = make_mesh((1, 1), ("data", "model"))
    ctx = ShardingCtx(mesh=mesh, batch_axes=("data",))
    specs = {"w": P(None, "model")}
    ap = {"w": jax.ShapeDtypeStruct((8, 4), jnp.float32)}
    out = adamw.zero1_specs(specs, ap, ctx)
    assert out["m"]["w"] == P("data", "model")
    assert out["step"] == P()


# ---- compression (single-device math; collective path tested in dist) ------

def test_int8_error_feedback_is_unbiased_over_steps():
    """With EF, the accumulated compressed sum tracks the true sum."""
    rng = np.random.RandomState(0)
    g_true = rng.randn(256).astype(np.float32)
    err = np.zeros_like(g_true)
    acc_comp = np.zeros_like(g_true)
    for _ in range(50):
        g = g_true + err
        scale = np.abs(g).max() / 127.0 + 1e-12
        q = np.clip(np.round(g / scale), -127, 127) * scale
        err = g - q
        acc_comp += q
    acc_true = g_true * 50
    rel = np.abs(acc_comp - acc_true).max() / np.abs(acc_true).max()
    assert rel < 0.02


@pytest.mark.dist
def test_compressed_pod_reduction(dist):
    out = dist("check_compression.py")
    assert "check_compression OK" in out
