"""SimServer conformance: replica isolation, churn, compiles, faults.

The batching contract is bitwise, not statistical: a replica served
inside a bucketed vmapped batch must produce the *identical* trajectory
to a solo :class:`MDEngine` run of the same system (same seed, same
bucket box/layout) — regardless of which bucket it lands in, which
replicas share the batch, the order replicas were admitted, or a
co-resident retiring mid-run.  Solo references are lru-cached like the
PR 4 matrix so every comparison against the same (backend, pipeline,
replica, steps) cell is computed once.

On top of the isolation matrix: the no-recompile-at-admission contract
(``serve/compiles`` == distinct shapes touched, exactly), per-lane NaN
quarantine (typed :class:`ReplicaFault`, co-residents untouched), cancel
and evacuate/resume round-trips, per-block deadlines, the engine's
block-boundary admission hook, and the wave-accounting helpers shared
with the LM server.
"""
import functools

import numpy as np
import pytest

import jax

from repro.core.md.domain import AXES
from repro.core.md.engine import MDEngine
from repro.core.md.system import make_grappa_like
from repro.launch.mesh import make_mesh
from repro.resilience.faults import WaveTimeout
from repro.runtime.serve_loop import masked_tokens
from repro.serve import (BucketLadder, CANCELLED, DONE, FAILED, PREEMPTED,
                         ReplicaFault, SimServer)

NST = 10            # block quantum: nstlist steps per dispatch
BUCKET = 256        # canonical atom bucket for most cells

# the shared replica roster: (n_atoms, seed) — sub-bucket sizes exercise
# padded lanes, distinct seeds make cross-lane leaks visible
R0, R1, R2 = (200, 5), (256, 7), (230, 9)

MATRIX = [(fb, pipe) for fb in ("dense", "sparse")
          for pipe in ("off", "double_buffer")]


@functools.lru_cache(maxsize=None)
def _mesh():
    return make_mesh((1, 1, 1), AXES)


@functools.lru_cache(maxsize=None)
def _sys(n_atoms, seed):
    return make_grappa_like(n_atoms, seed=seed, nstlist=NST,
                            box_atoms=BUCKET)


@functools.lru_cache(maxsize=None)
def _solo(fb, pipe, n_atoms, seed, n_steps):
    """Solo reference trajectory under the bucket's box and layout."""
    eng = MDEngine(_sys(n_atoms, seed), _mesh(), force_backend=fb,
                   pipeline=pipe, static_ladder=(fb != "dense"),
                   layout_atoms=BUCKET)
    (cf, ci), _, _ = eng.simulate(n_steps)
    return (np.asarray(jax.device_get(cf)), np.asarray(jax.device_get(ci)))


def _server(fb, pipe, rows=(1, 2, 4), atoms=(BUCKET,), **kw):
    return SimServer(_mesh(),
                     BucketLadder(row_buckets=rows, atom_buckets=atoms),
                     block_steps=NST,
                     engine_kwargs={"force_backend": fb, "pipeline": pipe},
                     **kw)


def _assert_bitwise(out, fb, pipe, spec, n_steps):
    n, seed = spec
    cf, ci = _solo(fb, pipe, n, seed, n_steps)
    assert np.array_equal(out["cell_f"], cf), \
        f"cell_f diverged for replica {spec} under {fb}/{pipe}"
    assert np.array_equal(out["cell_i"], ci), \
        f"cell_i diverged for replica {spec} under {fb}/{pipe}"


# ---- replica isolation matrix ---------------------------------------------

@pytest.mark.parametrize("fb,pipe", MATRIX,
                         ids=[f"{fb}-{pipe}" for fb, pipe in MATRIX])
def test_batched_replicas_bitwise_match_solo(fb, pipe):
    """Three mixed-size replicas in one 4-row bucket (one lane empty):
    every lane equals its solo run bit for bit."""
    srv = _server(fb, pipe)
    handles = [(spec, srv.submit(_sys(*spec), 20))
               for spec in (R0, R1, R2)]
    srv.drain()
    for spec, h in handles:
        assert h.status == DONE
        _assert_bitwise(h.result(), fb, pipe, spec, 20)
    st = srv.stats()
    assert st["replicas_done"] == 3
    assert st["useful_steps"] == 60


@pytest.mark.parametrize("order", [(R0, R1, R2), (R2, R0, R1), (R1, R2, R0)],
                         ids=["012", "201", "120"])
def test_admission_order_is_invisible(order):
    """A 2-row bucket forces churn (the third replica waits for a freed
    row); every admission order yields the same bitwise trajectories."""
    srv = _server("sparse", "off", rows=(1, 2))
    handles = [(spec, srv.submit(_sys(*spec), 20)) for spec in order]
    srv.drain()
    for spec, h in handles:
        _assert_bitwise(h.result(), "sparse", "off", spec, 20)


def test_mid_run_neighbor_retirement_is_invisible():
    """Mixed budgets in a 2-row bucket: the short replica retires
    mid-run, a queued one is admitted into its freed row, and the
    long-running neighbor's trajectory never notices."""
    srv = _server("dense", "off", rows=(1, 2))
    ha = srv.submit(_sys(*R0), 40)   # runs blocks 1..4
    hb = srv.submit(_sys(*R1), 20)   # retires after block 2
    hc = srv.submit(_sys(*R2), 30)   # admitted into B's row at block 3
    srv.drain()
    _assert_bitwise(ha.result(), "dense", "off", R0, 40)
    _assert_bitwise(hb.result(), "dense", "off", R1, 20)
    _assert_bitwise(hc.result(), "dense", "off", R2, 30)
    # churn reused the one open table: a single compiled shape
    assert srv.stats()["compiles"] == 1
    assert srv.stats()["shapes_touched"] == [(2, BUCKET)]


# ---- compile-count contract -----------------------------------------------

def test_compile_count_equals_buckets_touched():
    """32 replicas churned through 4 shapes: the traced-lowering counter
    (incremented inside the jitted block body, i.e. once per trace)
    equals the number of distinct buckets touched — exactly."""
    ladder = BucketLadder(row_buckets=(2, 4), atom_buckets=(192, 256))
    srv = SimServer(_mesh(), ladder, block_steps=NST,
                    engine_kwargs={"force_backend": "dense"})
    batches = ([(2, 192), (4, 192), (2, 256), (4, 256)] * 2
               + [(4, 192), (4, 256)])         # 2+4+2+4 = 12, x2, +8 = 32
    total = 0
    for count, atoms in batches:
        for i in range(count):
            sys_ = make_grappa_like(atoms - (i % 2) * 8, seed=total,
                                    nstlist=NST, box_atoms=atoms)
            srv.submit(sys_, NST)
            total += 1
        srv.drain()     # table closes empty -> next batch reopens a shape
    assert total == 32
    st = srv.stats()
    assert st["replicas_done"] == 32
    touched = set(srv.scheduler.shapes_touched)
    assert touched == {(2, 192), (4, 192), (2, 256), (4, 256)}
    assert st["compiles"] == len(touched)      # == 4, gated exactly


# ---- fault quarantine ------------------------------------------------------

def test_nan_replica_quarantined_not_the_batch():
    """A poisoned lane retires with a typed ReplicaFault at its block
    boundary; the co-resident replica finishes bitwise-unchanged."""
    bad_sys = make_grappa_like(200, seed=11, nstlist=NST, box_atoms=BUCKET)
    bad_sys.vel[0] = np.inf        # NaN positions within the first block
    srv = _server("dense", "off")
    h_ok = srv.submit(_sys(*R1), 20)
    h_bad = srv.submit(bad_sys, 20)
    srv.drain()
    assert h_bad.status == FAILED
    with pytest.raises(ReplicaFault, match="non-finite"):
        h_bad.result()
    assert h_ok.status == DONE
    _assert_bitwise(h_ok.result(), "dense", "off", R1, 20)
    st = srv.stats()
    assert st["replicas_failed"] == 1 and st["replicas_done"] == 1


def test_block_deadline_raises_wave_timeout():
    srv = _server("dense", "off", wave_timeout_s=1e-9)
    srv.submit(_sys(*R0), NST)
    with pytest.raises(WaveTimeout):
        srv.run_cycle()


# ---- cancel / evacuate-resume ---------------------------------------------

def test_cancel_queued_and_running():
    srv = _server("dense", "off", rows=(1,))
    h_run = srv.submit(_sys(*R0), 40)
    h_q = srv.submit(_sys(*R1), 20)      # 1-row bucket: stays queued
    assert h_q.cancel() == CANCELLED
    assert h_q.result() is None
    srv.run_cycle()                      # block 1 for the running replica
    assert h_run.cancel() == "running"   # flagged; retires next boundary
    srv.drain()
    assert h_run.status == CANCELLED
    out = h_run.result()                 # partial state: exactly 1 block
    assert out["steps"] == NST
    _assert_bitwise(out, "dense", "off", R0, NST)


def test_evacuate_and_resume_is_bitwise():
    """Preempt a replica mid-run, readmit its snapshot on a *fresh*
    server, and the stitched trajectory equals an uninterrupted solo
    run — the device-loss recovery path, single-process edition."""
    srv = _server("dense", "off")
    h = srv.submit(_sys(*R2), 30)
    srv.run_cycle()                      # 1 of 3 blocks
    [(h_old, snap)] = srv.evacuate()
    assert h_old.status == PREEMPTED
    assert snap["steps"] == NST and snap["remaining_steps"] == 20
    srv2 = _server("dense", "off")
    h2 = srv2.submit(_sys(*R2), snap["remaining_steps"],
                     state=(snap["cell_f"], snap["cell_i"]))
    srv2.drain()
    _assert_bitwise(h2.result(), "dense", "off", R2, 30)


# ---- engine admission hook -------------------------------------------------

def test_engine_boundary_hook_fires_and_mutates():
    sys_ = _sys(*R0)
    eng = MDEngine(sys_, _mesh(), force_backend="dense")
    calls = []
    (cf, ci), _, _ = eng.simulate(3 * NST,
                                  on_boundary=lambda rs: calls.append(rs.step))
    assert calls == [NST, 2 * NST]       # interior boundaries only
    # a mutating hook visibly changes the trajectory (freeze velocities)
    def freeze(rs):
        cf = np.array(jax.device_get(rs.cell_f))   # writable copy
        cf[..., 4:7] = 0.0
        rs.cell_f = jax.numpy.asarray(cf)
    (cf2, _), _, _ = eng.simulate(2 * NST, on_boundary=freeze)
    base, _ = _solo("dense", "off", *R0, 2 * NST)
    assert not np.array_equal(np.asarray(jax.device_get(cf2)), base)
    eng_ovr = MDEngine(sys_, _mesh(), force_backend="dense",
                       overlap_rebin=True)
    with pytest.raises(ValueError, match="overlap_rebin"):
        eng_ovr.simulate(2 * NST, on_boundary=lambda rs: None)


# ---- server guardrails -----------------------------------------------------

def test_submit_validates_box_and_cadence():
    srv = _server("dense", "off")
    with pytest.raises(ValueError, match="box_atoms"):
        srv.submit(make_grappa_like(200, seed=1, nstlist=NST), 20)
    with pytest.raises(ValueError, match="nstlist"):
        srv.submit(make_grappa_like(256, seed=1, nstlist=20), 20)
    with pytest.raises(ValueError, match="atom bucket"):
        srv.submit(make_grappa_like(400, seed=1, nstlist=NST), 20)


def test_step_budget_rounds_up_to_blocks():
    srv = _server("dense", "off")
    h = srv.submit(_sys(*R0), 15)        # 1.5 blocks -> 2 blocks run
    srv.drain()
    out = h.result()
    assert out["steps"] == 20 and out["requested_steps"] == 15
    # useful-step accounting masks the padding, LM-server style
    assert srv.stats()["useful_steps"] == masked_tokens([20], [15]) == 15


# ---- dist cells ------------------------------------------------------------

@pytest.mark.dist
def test_sharded_rows_quarantine_and_device_loss(dist):
    out = dist("check_serve.py")
    assert "rep-sharded rows: 8/8 replicas bitwise" in out
    assert "quarantine: co-residents bitwise around a poisoned lane" in out
    assert "device-loss: evacuated replicas resumed bitwise on rep=4" in out
