"""Emit the EXPERIMENTS.md tables from results/dryrun/*.json."""
import json
import sys
from pathlib import Path

DRY = Path(__file__).parent / "dryrun"


def load(name):
    p = DRY / f"{name}.json"
    return json.loads(p.read_text()) if p.exists() else None


def fmt_cell(r):
    if r is None:
        return None
    if r.get("skipped"):
        return {"status": r["skipped"]}
    if not r["ok"]:
        return {"status": "FAIL: " + r.get("error", "")[:40]}
    t = r["roofline"]
    return {
        "status": "ok",
        "gb": r["device_total_bytes"] / 1e9,
        "fits": r["device_total_bytes"] / 1e9 <= 16.0,
        "flops": r["parsed"]["flops"],
        "bytes": r["parsed"]["bytes"],
        "coll": r["parsed"]["collective_bytes"],
        "ct": t["compute_s"], "mt": t["memory_s"], "lt": t["collective_s"],
        "mlb": t.get("memory_lb_s", 0), "dom": t["dominant"],
        "doma": t.get("dominant_analytic", "?"),
        "frac": t.get("roofline_fraction", 0),
        "fraca": t.get("roofline_fraction_analytic", 0),
        "mb": r.get("microbatches"),
        "compile": r.get("compile_s", 0) + r.get("lower_s", 0),
    }


ARCHS = ["internvl2_26b", "mistral_nemo_12b", "command_r_plus_104b",
         "qwen3_1_7b", "starcoder2_7b", "whisper_small", "olmoe_1b_7b",
         "llama4_maverick_400b_a17b", "rwkv6_3b", "jamba_v0_1_52b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def dryrun_table(mesh):
    print(f"\n### {mesh} mesh\n")
    print("| arch | shape | status | GB/dev | fits 16GB | compile s |")
    print("|---|---|---|---|---|---|")
    n_ok = n_skip = 0
    for a in ARCHS:
        for s in SHAPES:
            c = fmt_cell(load(f"{a}__{s}__{mesh}"))
            if c is None:
                print(f"| {a} | {s} | MISSING | | | |")
                continue
            if c["status"] != "ok":
                n_skip += 1
                print(f"| {a} | {s} | {c['status']} | | | |")
                continue
            n_ok += 1
            print(f"| {a} | {s} | ok | {c['gb']:.2f} | "
                  f"{'yes' if c['fits'] else 'NO'} | {c['compile']:.0f} |")
    print(f"\n{n_ok} compiled OK, {n_skip} assignment skips.")


def roofline_table():
    print("\n| arch | shape | flops/dev | coll B/dev | compute s | "
          "memory s (hlo) | memory s (lb) | coll s | dom (hlo/lb) | "
          "frac | frac(lb) |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for a in ARCHS:
        for s in SHAPES:
            c = fmt_cell(load(f"{a}__{s}__single"))
            if c is None or c["status"] != "ok":
                st = c["status"] if c else "missing"
                print(f"| {a} | {s} | {st} |" + " |" * 9)
                continue
            print(f"| {a} | {s} | {c['flops']:.2e} | {c['coll']:.2e} | "
                  f"{c['ct']:.2e} | {c['mt']:.2e} | {c['mlb']:.2e} | "
                  f"{c['lt']:.2e} | {c['dom']}/{c['doma']} | "
                  f"{c['frac']:.3f} | {c['fraca']:.3f} |")


def variants_table(cells):
    print("\n| cell | variant | coll B/dev | compute s | memory s (hlo) | "
          "coll s | GB/dev | frac(lb) |")
    print("|---|---|---|---|---|---|---|---|")
    for base, tags in cells:
        for tag, label in tags:
            c = fmt_cell(load(base + tag))
            if c is None or c.get("status") != "ok":
                print(f"| {base} | {label} | "
                      f"{(c or {}).get('status', 'missing')} |" + " |" * 6)
                continue
            print(f"| {base.split('__')[0]}/{base.split('__')[1]} | "
                  f"{label} | {c['coll']:.3e} | {c['ct']:.2e} | "
                  f"{c['mt']:.2e} | {c['lt']:.3e} | {c['gb']:.2f} | "
                  f"{c['fraca']:.4f} |")


def halo_table():
    """Plan-reported halo bytes per DD dimensionality (halo__*.json).

    Numbers come straight from ``HaloPlan.stats`` as recorded by
    ``python -m repro.launch.dryrun --halo`` — no local recomputation —
    with the compiled-HLO collective bytes as a cross-check column.
    The latency columns are the alpha-beta model (per-message link
    latency + bytes/bandwidth); exposed/ovl are the step-pipeline
    overlap model at the recorded window depth (cells run with
    ``--pipeline double_buffer`` overlap the reverse exchange; a
    ``--pipeline-depth`` sweep shows the exposed phases amortizing as
    the in-flight window deepens).  Old-format records show '-'.
    """
    print("\n| dd | backend | w | pulses | pipe | depth | total B | "
          "chained B | dep frac | ser t (us) | fused t (us) | "
          "exposed/step | ovl B | HLO coll B/dev |")
    print("|" + "---|" * 14)
    for p in sorted(DRY.glob("halo__*.json")):
        r = json.loads(p.read_text())
        if not r.get("ok"):
            print(f"| {r.get('dd', '?')} | {r.get('backend', '?')} | FAIL "
                  f"{r.get('error', '')[:40]} |" + " |" * 11)
            continue
        st = r["plan_stats"]
        chained = (st["serialized_critical_bytes"]
                   if r["backend"] == "serialized"
                   else st["fused_critical_bytes"])
        coll = r["hlo_collective_bytes"] / max(r["devices"], 1)
        lat = r.get("latency") or st.get("latency")
        ovl = r.get("overlap") or st.get("overlap")
        ser_us = f"{lat['serialized_time_s'] * 1e6:.2f}" if lat else "-"
        fus_us = f"{lat['fused_time_s'] * 1e6:.2f}" if lat else "-"
        exposed = ovl["exposed_phases_per_step"] if ovl else "-"
        if isinstance(exposed, float):
            exposed = f"{exposed:g}"
        ovl_b = ovl["overlapped_bytes_per_step"] if ovl else "-"
        depth = r.get("pipeline_depth") or (ovl or {}).get("depth") or "-"
        if r.get("pipeline", "off") == "off":
            depth = "-"
        print(f"| {r['dd']} | {r['backend']} | {r.get('width', 1)} | "
              f"{r.get('pulses', 1)} | {r.get('pipeline', 'off')} | "
              f"{depth} | {st['total_bytes']} | {chained} | "
              f"{st['dependent_fraction']:.4f} | {ser_us} | {fus_us} | "
              f"{exposed} | {ovl_b} | {coll:.3e} |")


def nb_table():
    """Force-engine bench (results/BENCH_nb.json): dense vs sparse vs
    pallas pair schedules — tier-ladder (per-pair slot bound) and
    rolling-prune (dual pair list) columns included — with the prune
    ratio (dense-over-evaluated slot pairs) per cell; the
    ``benchmarks/run.py --suite nb`` output.
    """
    p = Path(__file__).parent / "BENCH_nb.json"
    if not p.exists():
        print("\n(no BENCH_nb.json — run `python -m benchmarks.run "
              "--suite nb`)")
        return
    r = json.loads(p.read_text())
    mode = "SMOKE (CI-sized — not the baseline; regenerate with " \
        "`--suite nb --full`)" if r.get("smoke") else "full sweep"
    print(f"\nsuite mode: {mode}")
    print("\n| dev | atoms | safety | variant | step ms | "
          "slot pairs/step | global-kexec pairs | tiers | prune ratio | "
          "pairs/s |")
    print("|" + "---|" * 10)
    for c in r["cells"]:
        tiers = c.get("tiers_inner") or c.get("tiers")
        tiers_s = "-" if not tiers else \
            " ".join(f"{n}x{k}" for n, k in tiers)
        gk = c.get("global_kexec_slot_pairs_per_step")
        print(f"| {c['devices']} | {c['n_atoms']} | "
              f"{c['capacity_safety']:g} | "
              f"{c.get('variant', c['force_backend'])} | "
              f"{c['ms_per_step']:.2f} | "
              f"{c['evaluated_slot_pairs_per_step']} | "
              f"{gk if gk is not None else '-'} | {tiers_s} | "
              f"{c['prune_ratio']:.2f}x | {c['pairs_per_s']:.3e} |")
    print("\n| dev | atoms | safety | slot-pair reduction | "
          "per-pair-bound gain | rolling-prune pairs | "
          "sparse step speedup |")
    print("|" + "---|" * 7)
    for s in r.get("summary", []):
        gain = s.get("per_pair_bound_gain")
        roll = s.get("rolling_prune_slot_pairs")
        print(f"| {s['devices']} | {s['n_atoms']} | {s['safety']:g} | "
              f"{s['slot_pair_reduction']:.2f}x | "
              f"{'-' if gain is None else f'{gain:.2f}x'} | "
              f"{'-' if roll is None else roll} | "
              f"{s['sparse_step_speedup']:.2f}x |")
    print(f"\n>= 2x slot-pair reduction at default 2.2 safety: "
          f"{r.get('target_2x_at_default_safety')}")
    print(f"per-pair bounds beat global-k_exec at default safety: "
          f"{r.get('per_pair_bounds_beat_global_kexec')}")


def pipeline_table():
    """Perf-trajectory suite (results/BENCH_pipeline.json): one row per
    (backend x pipeline mode x depth) cell — the baseline the CI
    ``perf-smoke`` job drift-checks with ``python -m repro.obs gate`` —
    plus the obs snapshot counters from the traced sample run
    (results/obs/pipeline_smoke.jsonl)."""
    p = Path(__file__).parent / "BENCH_pipeline.json"
    if not p.exists():
        print("\n(no BENCH_pipeline.json — run `python -m benchmarks.run "
              "--suite pipeline`)")
        return
    r = json.loads(p.read_text())
    mode = "SMOKE (CI-sized baseline)" if r.get("smoke") else "full sweep"
    print(f"\nsuite mode: {mode}; schema v{r.get('schema_version')}; "
          f"exposed phases monotone in depth: "
          f"{r.get('exposed_phases_monotone_in_depth')}")
    print("\n| dev | backend | pipe | depth | nstprune | step ms | "
          "force ms | exposed/step | ovl B | exch B | prune ratio | "
          "modeled speedup |")
    print("|" + "---|" * 12)
    for c in r["cells"]:
        pipe = c["pipeline"]
        depth = c["pipeline_depth"] if pipe != "off" else "-"
        print(f"| {c['devices']} | {c['mode']} | {pipe} | {depth} | "
              f"{c['nstprune']} | {c['ms_per_step']:.2f} | "
              f"{c['ms_force_pass']:.2f} | {c['exposed_phases']:g} | "
              f"{c['overlapped_bytes']} | {c['exchanged_bytes']} | "
              f"{c['prune_ratio']:.2f}x | {c['modeled_speedup']:.2f}x |")
    jsonl = Path(__file__).parent / "obs" / "pipeline_smoke.jsonl"
    if jsonl.exists():
        snaps = [json.loads(ln) for ln in jsonl.read_text().splitlines()
                 if ln.strip() and '"snapshot"' in ln]
        snaps = [s for s in snaps if s.get("kind") == "snapshot"]
        if snaps:
            print("\nobs snapshot (traced sample run — counters/gauges at "
                  "end of simulate):\n")
            print("| metric | kind | value |")
            print("|---|---|---|")
            for name, m in sorted(snaps[-1]["metrics"].items()):
                v = m["value"]
                if isinstance(v, dict):       # histogram: show the mean
                    v = f"mean {v.get('mean', 0):.4g} (n={v.get('count')})"
                print(f"| {name} | {m['kind']} | {v} |")


def force_table():
    """MD force-engine dry-run cells (mdforce__*.json): chosen backend +
    prune ratio / tier ladders as recorded by
    ``repro.launch.dryrun --md``."""
    files = sorted(DRY.glob("mdforce__*.json"))
    if not files:
        return
    print("\n| dd | halo backend | force backend | pipe | depth | "
          "ovl rebin | nstprune | prune ratio | slot pairs/step | "
          "tiers | occupancy | index B | useful B |")
    print("|" + "---|" * 13)
    for p in files:
        r = json.loads(p.read_text())
        if not r.get("ok"):
            print(f"| {r.get('dd', '?')} | {r.get('backend', '?')} | "
                  f"{r.get('force_backend', '?')} | FAIL "
                  f"{r.get('error', '')[:40]} |" + " |" * 9)
            continue
        ps = r["pair_stats"]
        hs = r["halo_stats"]
        pipe = r.get("pipeline", "off")
        depth = r.get("pipeline_depth", "-") if pipe != "off" else "-"
        ovr = "yes" if r.get("overlap_rebin") else "no"
        tiers = ps.get("tiers_inner") or ps.get("tiers")
        tiers_s = "-" if not tiers else \
            " ".join(f"{n}x{k}" for n, k in tiers)
        print(f"| {r['dd']} | {r['backend']} | {r['force_backend']} | "
              f"{pipe} | {depth} | {ovr} | {r.get('nstprune', 0)} | "
              f"{ps['prune_ratio']:.2f}x | "
              f"{ps['evaluated_slot_pairs']} | {tiers_s} | "
              f"{hs['occupancy']:.3f} | {hs['bytes_index']} | "
              f"{hs['useful_bytes']} |")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "halo"):
        print("\n## Halo exchange (plan-reported)")
        halo_table()
    if which in ("all", "nb"):
        print("\n## NB force engine (pair schedules)")
        nb_table()
        force_table()
    if which in ("all", "pipeline"):
        print("\n## Perf trajectory (pipeline suite + obs metrics)")
        pipeline_table()
    if which in ("all", "dryrun"):
        print("## Dry-run status")
        dryrun_table("single")
        dryrun_table("multi")
    if which in ("all", "roofline"):
        print("\n## Roofline (single pod, per device)")
        roofline_table()
    if which in ("all", "perf"):
        print("\n## Perf variants")
        variants_table([
            ("qwen3_1_7b__train_4k__single",
             [("", "baseline"), ("_zero2", "+zero2"),
              ("_fix2", "+bf16-gather (fix2)"),
              ("_zero2mb4", "+zero2+mb4")]),
            ("olmoe_1b_7b__train_4k__single",
             [("", "baseline(fused)"), ("_moeser", "serialized dispatch"),
              ("_zero2", "+zero2")]),
            ("jamba_v0_1_52b__train_4k__single",
             [("", "baseline"), ("_mambabf16", "+mamba bf16"),
              ("_mb16", "+mb16"), ("_fix2", "per-layer remat (fix2)"),
              ("_fix2opt", "fix2+bf16+zero2")]),
            ("command_r_plus_104b__train_4k__single",
             [("", "baseline"), ("_fix2", "bf16-gather (fix2)"),
              ("_fix2opt", "fix2+zero2")]),
            ("llama4_maverick_400b_a17b__train_4k__single",
             [("", "baseline"), ("_fix2opt", "fix2+zero2")]),
            ("qwen3_1_7b__train_4k__multi",
             [("", "baseline"), ("_int8", "pod int8 EF"),
              ("_topk", "pod topk EF")]),
        ])
